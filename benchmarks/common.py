"""Shared protocol for the paper-reproduction benchmarks (§VI-C).

Scenario definitions:
  local  — the traditional single-user situation: training data from ONE
           context group (all context features fixed; scale-out and dataset
           size still vary); multiple valid local datasets exist and splits
           sample them uniformly.
  global — collaboratively shared data: all contexts of the target machine
           type mixed together.

Each split trains on a fraction of the scenario's data and evaluates MAPE on
held-out points; the C3O row additionally runs LOO-CV model selection on the
train split first (exactly the paper's protocol).
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.core.predictor import evaluate_split
from repro.workloads import spark_emul as W

JOBS = ("sort", "grep", "sgd", "kmeans", "pagerank")
MODELS = ("ernest", "gbm", "bom", "ogb")
TARGET_MACHINE = "m5.xlarge"

# Paper Table II values for side-by-side reporting (local, global); Sort has
# a single column (local == global).
PAPER_TABLE2 = {
    "sort": {"ernest": (.0582, .0582), "gbm": (.0443, .0443),
             "bom": (.0639, .0639), "ogb": (.0261, .0261),
             "c3o": (.0261, .0261)},
    "grep": {"ernest": (.0753, .3938), "gbm": (.0554, .0274),
             "bom": (.0645, .1295), "ogb": (.0447, .0935),
             "c3o": (.0505, .0274)},
    "sgd": {"ernest": (.1000, .2185), "gbm": (.0689, .0225),
            "bom": (.0604, .1266), "ogb": (.0654, .0779),
            "c3o": (.0622, .0225)},
    "kmeans": {"ernest": (.1404, .1531), "gbm": (.0860, .0217),
               "bom": (.0551, .0574), "ogb": (.0570, .0550),
               "c3o": (.0522, .0217)},
    "pagerank": {"ernest": (.1093, .3485), "gbm": (.0525, .0271),
                 "bom": (.0399, .1508), "ogb": (.0405, .0317),
                 "c3o": (.0429, .0277)},
}


def scenario_splits(data, scenario: str, n_splits: int, seed: int,
                    train_frac: float = 0.7):
    """Yields (X_tr, y_tr, X_te, y_te) per split."""
    rng = np.random.default_rng(seed)
    d = data.filter_machine(TARGET_MACHINE)
    groups = W.context_groups(d)
    for i in range(n_splits):
        if scenario == "local":
            g = groups[rng.integers(len(groups))]
            idx = rng.permutation(g)
        else:
            idx = rng.permutation(len(d))
        k = max(int(len(idx) * train_frac), 3)
        tr, te = idx[:k], idx[k:]
        if len(te) == 0:
            tr, te = idx[:-2], idx[-2:]
        yield d.X[tr], d.y[tr], d.X[te], d.y[te]


def run_scenario(job: str, scenario: str, n_splits: int = 100,
                 seed: int = 0, max_cv_folds: int = 20) -> Dict[str, float]:
    data = W.generate_job_data(job)
    errs: Dict[str, List[float]] = {}
    for i, (Xtr, ytr, Xte, yte) in enumerate(
            scenario_splits(data, scenario, n_splits, seed)):
        r = evaluate_split(MODELS, Xtr, ytr, Xte, yte,
                           max_cv_folds=max_cv_folds, seed=seed + i)
        for k, v in r.items():
            if k != "c3o_selected":
                errs.setdefault(k, []).append(v)
    return {k: float(np.mean(v)) for k, v in errs.items()}
