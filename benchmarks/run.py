"""Benchmark harness: one function per paper table/figure + framework perf.

Prints ``name,us_per_call,derived`` CSV rows (us_per_call = wall time per
inner evaluation where meaningful; derived = headline metric).

  engine        fused prediction engine: fit throughput (cold vs warm
                executable cache), candidate-grid scoring predictions/sec on
                a 3-machine x 7-scale-out x 256-context grid, and speedup
                over the seed per-row/fresh-jit path
  serve         configuration service: joint choose_cluster_batch
                throughput and async micro-batched front-end requests/s
  gateway       Hub Gateway API v1: single-job choose requests/s through
                the per-job batch lanes vs the legacy front-end (target
                >= 1x: the redesign may not regress the hot path), plus
                multi-job mixed-operation requests/s and mean per-lane
                batch size
  edge          socket-level serving edge: closed-loop load test (64
                keep-alive connections over a real localhost socket)
                against the in-process gateway on the SAME seeded request
                stream — requests/s, p50/p95/p99, realized predict-lane
                mean batch, and byte-identical-response parity; the
                >=0.5x-of-in-process throughput, mean-batch>1, and parity
                checks are hard SystemExit gates
  ingest        contribution ingestion at 10k stored rows: contributions/s
                and rows/s, cold vs warm, vs the pre-refactor
                re-encode/re-hash/refit-from-scratch path
  compact       store lifecycle: one coverage-aware compaction of a 10k-row
                store — rows retained (>=4x reduction), warm refit speedup
                (>=2x), held-out MAPE delta (<=1pp); all three are hard
                SystemExit gates
  eval          collaborative replay plane smoke: leave-one-user-out mini
                replay wall-clock + per-job accuracy/monotonicity summary
  trust         trust plane smoke: twin-arm adversarial replay (reputation
                weighting off vs on) + gateway token-auth overhead on the
                predict hot path (target <= 5%)
  transfer      cold-start cross-job transfer: nearest-donor lookup cost
                (cold sketch vs version-keyed cache hits; flat re-sketch
                counters are a hard gate) and borrowed-model MAPE on a
                zero-history twin job vs the global-mean baseline (must
                beat it; hard gate)
  table1        dataset structure vs paper Table I
  table2        MAPE local/global x 5 jobs x {ernest,gbm,bom,ogb,c3o} (§VI-C.a)
  fig5          MAPE vs training-set size (§VI-C.b)
  configurator  deadline satisfaction + cost vs overprovisioning (§IV)
  autoconfig    C3O-for-TPU mesh selection quality (beyond-paper)
  kernels       Pallas kernel wall times (interpret) vs jitted jnp oracles
  roofline      per-cell roofline table from experiments/dryrun_*.json

Usage: PYTHONPATH=src python -m benchmarks.run [--splits N] [--only NAME]
"""
from __future__ import annotations

import argparse
import glob
import json
import math
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def _row(name, us, derived):
    print(f"{name},{us:.1f},{derived}", flush=True)


def bench_engine(args):
    import jax

    from repro.core import engine
    from repro.core.configurator import Configurator
    from repro.core.predictor import C3OPredictor
    from repro.workloads import spark_emul as W

    prices = {m.name: m.price for m in W.MACHINES.values()}
    machines = sorted(W.MACHINES)[:3]
    scaleouts = [2, 3, 4, 6, 8, 12, 16]
    rng = np.random.default_rng(0)
    contexts = np.stack([rng.uniform(10, 20, 256),
                         rng.choice([.002, .02, .08], 256)], axis=1)
    data = {m: W.generate_job_data("grep").filter_machine(m)
            for m in machines}

    # --- fit throughput: cold (trace+compile) vs warm executable cache ----
    t0 = time.time()
    preds = {m: C3OPredictor(max_cv_folds=25).fit(d.X, d.y)
             for m, d in data.items()}
    cold = (time.time() - t0) / len(machines)
    t0 = time.time()
    preds = {m: C3OPredictor(max_cv_folds=25).fit(d.X, d.y)
             for m, d in data.items()}
    warm = (time.time() - t0) / len(machines)
    _row("engine.fit_cold", cold * 1e6, "fit+select, per machine type")
    _row("engine.fit_warm", warm * 1e6,
         f"cached executables, speedup={cold / max(warm, 1e-9):.1f}x")

    # --- warm candidate-grid scoring: machines x scale-outs x contexts ----
    n_cand = len(machines) * len(scaleouts) * len(contexts)
    engine.machine_grid_costs(preds, prices, scaleouts, contexts)  # warm-up
    reps = 5
    t0 = time.time()
    for _ in range(reps):
        names, t, cost = engine.machine_grid_costs(preds, prices, scaleouts,
                                                   contexts)
    grid_s = (time.time() - t0) / reps
    _row("engine.grid_score", grid_s / n_cand * 1e6,
         f"candidates/s={n_cand / grid_s:.0f} grid={len(machines)}x"
         f"{len(scaleouts)}x{len(contexts)}")

    # --- choose_batch serving throughput -----------------------------------
    conf = Configurator(preds[machines[0]], machines[0], prices, scaleouts)
    conf.choose_batch(contexts, t_max=400.0)                       # warm-up
    t0 = time.time()
    for _ in range(reps):
        conf.choose_batch(contexts, t_max=400.0)
    batch_s = (time.time() - t0) / reps
    _row("engine.choose_batch", batch_s / len(contexts) * 1e6,
         f"choices/s={len(contexts) / batch_s:.0f}")

    # --- seed per-row path: fresh jax.jit per predict call, one context at
    # a time (the pre-engine FittedModel behavior), measured on a subset ---
    fm = preds[machines[0]]._fitted
    n_sub = 8
    t0 = time.time()
    for ctx in contexts[:n_sub]:
        rows = np.stack([np.concatenate([[s], ctx]) for s in scaleouts])
        import jax.numpy as jnp
        np.asarray(jax.jit(fm.spec.predict)(
            fm.params, jnp.asarray(rows, jnp.float32), fm.aux))
    naive_per_ctx = (time.time() - t0) / n_sub
    warm_per_ctx = batch_s / len(contexts)
    _row("engine.seed_per_row_path", naive_per_ctx * 1e6,
         f"speedup_warm_vs_seed={naive_per_ctx / max(warm_per_ctx, 1e-12):.1f}x"
         " (target >=5x)")


def bench_serve(args):
    import asyncio

    from repro.core.predictor import C3OPredictor
    from repro.core.service import ConfigurationService
    from repro.serve.config_service import AsyncConfigService
    from repro.workloads import spark_emul as W

    prices = {m.name: m.price for m in W.MACHINES.values()}
    machines = sorted(W.MACHINES)
    scaleouts = [2, 3, 4, 6, 8, 12, 16]
    rng = np.random.default_rng(0)
    contexts = np.stack([rng.uniform(10, 20, 256),
                         rng.choice([.002, .02, .08], 256)], axis=1)
    preds = {}
    for m in machines:
        d = W.generate_job_data("grep").filter_machine(m)
        preds[m] = C3OPredictor(max_cv_folds=20).fit(d.X, d.y)
    svc = ConfigurationService(preds, prices, scaleouts)

    # --- synchronous joint grid selection ---------------------------------
    svc.choose_cluster_batch(contexts, t_max=400.0)                # warm-up
    reps = 5
    t0 = time.time()
    for _ in range(reps):
        svc.choose_cluster_batch(contexts, t_max=400.0)
    joint_s = (time.time() - t0) / reps
    n_cand = len(machines) * len(scaleouts) * len(contexts)
    _row("serve.choose_cluster_batch", joint_s / len(contexts) * 1e6,
         f"choices/s={len(contexts) / joint_s:.0f} "
         f"grid={len(machines)}x{len(scaleouts)}x{len(contexts)} "
         f"candidates/s={n_cand / joint_s:.0f}")

    # --- async micro-batched front-end ------------------------------------
    n_req = 512
    t_maxes = [None if i % 4 == 0 else float(rng.uniform(200, 600))
               for i in range(n_req)]

    async def drive():
        async with AsyncConfigService(svc, max_batch=128) as front:
            await asyncio.gather(*[
                front.choose(contexts[i % len(contexts)], t_max=t_maxes[i])
                for i in range(n_req)])
            return front.stats

    asyncio.run(drive())                                           # warm-up
    t0 = time.time()
    stats = asyncio.run(drive())
    serve_s = time.time() - t0
    _row("serve.async_frontend", serve_s / n_req * 1e6,
         f"requests/s={n_req / serve_s:.0f} "
         f"mean_batch={stats.mean_batch:.1f} batches={stats.batches}")


def bench_gateway(args):
    """Hub Gateway API v1 serving throughput.

    ``gateway.single_job``  512 typed choose requests for ONE job through
                            the gateway's batch lane vs the same workload
                            through the legacy ``AsyncConfigService``
                            front-end — the redesign's hot-path guard
                            (target: speedup_vs_legacy >= 1x).
    ``gateway.multi_job``   mixed multi-job stream (choose across jobs +
                            predict/search/contribute riding along):
                            requests/s and realized mean per-lane batch.
    """
    import asyncio

    from repro.api import (AsyncHubGateway, ChooseRequest, ContributeRequest,
                           HubGateway, PredictRequest, SearchRequest)
    from repro.core.datastore import RuntimeDataStore
    from repro.core.hub import Hub, JobRepo
    from repro.core.service import ConfigurationService
    from repro.serve.config_service import AsyncConfigService
    from repro.workloads import spark_emul as W

    prices = {m.name: m.price for m in W.MACHINES.values()}
    scaleouts = [2, 3, 4, 6, 8, 12, 16]
    jobs = ("grep", "sort")

    def make_hub(**predictor_kw):
        hub = Hub()
        for job in jobs:
            d = W.generate_job_data(job)
            hub.publish(JobRepo(job, job, d.schema,
                                RuntimeDataStore(d, seed=0),
                                predictor_kw=dict(predictor_kw)))
        return hub

    # single-job hot path: predictors constructed exactly like the serve
    # lane's (same fold cap, no padding), so gateway-vs-legacy isolates
    # the gateway layer itself
    hub = make_hub(max_cv_folds=20)
    gw = HubGateway(hub, prices, scaleouts)
    # mixed stream: pad_rows, because accepted contributions grow the
    # store and bucketed refits keep the service rebuild hitting cached
    # executables instead of retracing per exact store size
    hub_mixed = make_hub(pad_rows=True, max_cv_folds=15)
    gw_mixed = HubGateway(hub_mixed, prices, scaleouts)
    rng = np.random.default_rng(0)
    n_req = 512
    ctx_grep = [(float(rng.uniform(10, 20)),
                 float(rng.choice([.002, .02, .08]))) for _ in range(n_req)]
    t_maxes = [math.nan if i % 4 == 0 else float(rng.uniform(200, 600))
               for i in range(n_req)]

    # --- legacy single-service front-end on the same workload -------------
    svc = ConfigurationService.from_repo(hub.get("grep"), None, prices,
                                         scaleouts)
    legacy_ctxs = [np.asarray(c) for c in ctx_grep]

    async def drive_legacy():
        async with AsyncConfigService(svc, max_batch=128) as front:
            await asyncio.gather(*[
                front.choose(legacy_ctxs[i], t_max=t_maxes[i])
                for i in range(n_req)])

    # --- gateway lane, single job (typed requests pre-built: the lane is
    # being measured, not the client's envelope construction) --------------
    single_reqs = [ChooseRequest("grep", ctx_grep[i], t_max=t_maxes[i])
                   for i in range(n_req)]
    stats = {}

    async def drive_single():
        async with AsyncHubGateway(gw, max_batch=128) as agw:
            out = await asyncio.gather(*[agw.choose(q) for q in single_reqs])
            assert all(r.ok for r in out)
            stats.update(agw.lane_stats)

    # interleaved best-of-reps: machine drift (CI neighbors, GC) hits both
    # paths alike instead of whichever happened to run second
    legacy_s = single_s = math.inf
    asyncio.run(drive_legacy())                                    # warm-up
    asyncio.run(drive_single())
    for _ in range(5):
        t0 = time.time()
        asyncio.run(drive_legacy())
        legacy_s = min(legacy_s, time.time() - t0)
        t0 = time.time()
        asyncio.run(drive_single())
        single_s = min(single_s, time.time() - t0)
    _row("gateway.single_job", single_s / n_req * 1e6,
         f"requests/s={n_req / single_s:.0f} "
         f"mean_batch={stats['grep'].mean_batch:.1f} "
         f"legacy_rps={n_req / legacy_s:.0f} "
         f"speedup_vs_legacy={legacy_s / single_s:.2f}x (target >=1x)")

    # --- mixed multi-job stream -------------------------------------------
    grep_store = hub_mixed.get("grep").store.data
    sub = grep_store.subset(np.arange(4))
    mixed = []
    for i in range(n_req):
        k = i % 8
        if k == 5:
            mixed.append(PredictRequest("grep", "m5.xlarge",
                                        ((4.0,) + ctx_grep[i],)))
        elif k == 6:
            mixed.append(SearchRequest(""))
        elif k == 7 and i % 128 == 127:
            # an accepted contribution bumps the store version and forces
            # a service rebuild (refit at the grown size) on the next
            # choose tick — rare relative to reads, like hub traffic
            mixed.append(ContributeRequest(
                "grep", tuple(sub.machine_type),
                tuple(map(tuple, sub.X)), tuple(sub.y),
                contributor_id=f"bench{i % 3}"))
        elif k % 2:
            mixed.append(ChooseRequest("sort", ctx_grep[i][:1],
                                       t_max=t_maxes[i]))
        else:
            mixed.append(ChooseRequest("grep", ctx_grep[i],
                                       t_max=t_maxes[i]))

    async def drive_mixed():
        async with AsyncHubGateway(gw_mixed, max_batch=128) as agw:
            out = await asyncio.gather(*[agw.handle_async(q) for q in mixed])
            assert all(r.ok for r in out)
            return dict(agw.lane_stats)

    asyncio.run(drive_mixed())                                     # warm-up
    t0 = time.time()
    lanes = asyncio.run(drive_mixed())
    mixed_s = time.time() - t0
    per_lane = " ".join(f"{j}:batch={s.mean_batch:.1f}"
                        for j, s in sorted(lanes.items()))
    _row("gateway.multi_job", mixed_s / n_req * 1e6,
         f"requests/s={n_req / mixed_s:.0f} jobs={len(jobs)} "
         f"ops=choose+predict+search+contribute {per_lane}")


def bench_edge(args):
    """Socket-level serving edge vs the in-process gateway.

    One seeded read-only request stream (predict/choose/search over two
    jobs) is played twice against the SAME warm ``HubGateway``:

    ``edge.socket``  closed loop over a real localhost socket — 64
                     keep-alive HTTP/1.1 connections through
                     ``EdgeServer`` + ``HubEdgeApp`` (requests/s, client
                     p50/p95/p99, realized predict-lane mean batch)
    ``edge.inproc``  the same stream through ``AsyncHubGateway``
                     in-process at the same concurrency (the socket
                     path's overhead budget)
    ``edge.parity``  byte-for-byte comparison of every HTTP response
                     body against the codec-encoded in-process envelope

    Hard SystemExit gates (CI smoke): socket requests/s >= 0.5x
    in-process, predict-lane mean batch > 1 under 64 connections, and
    zero parity mismatches.  The full report also lands as JSON in
    ``experiments/edge_bench.json``.
    """
    import asyncio

    from repro.api import AsyncHubGateway, decode, encode
    from repro.serve.edge import _demo_gateway, serve_edge
    from repro.serve.loadgen import _request, build_workload, run_loadgen

    n_req, n_conn, tick_s = 1024, 64, 0.004
    gw = _demo_gateway(("grep", "sort"))
    workload = build_workload(n_req, jobs=("grep", "sort"), seed=0)

    async def capture(host, port, connections=8):
        """Replay the workload collecting each response body by index."""
        out = [b""] * len(workload)

        async def worker(c):
            reader, writer = await asyncio.open_connection(host, port)
            try:
                for k in range(c, len(workload), connections):
                    path, body = workload[k]
                    _, out[k] = await _request(reader, writer, "POST",
                                               path, body)
            finally:
                writer.close()
                try:
                    await writer.wait_closed()
                except (ConnectionResetError, BrokenPipeError):
                    pass

        await asyncio.gather(*(worker(c) for c in range(connections)))
        return out

    reqs = [decode(body.decode("utf-8")) for _, body in workload]

    async def inproc_pass():
        """The same stream through the in-process gateway at the same
        closed-loop concurrency (a semaphore plays the connections)."""
        sem = asyncio.Semaphore(n_conn)

        async def one(agw, q):
            async with sem:
                return await agw.handle_async(q)

        async with AsyncHubGateway(gw, max_batch=256,
                                   tick_s=tick_s) as agw:
            t0 = time.monotonic()
            out = await asyncio.gather(*[one(agw, q) for q in reqs])
            return out, time.monotonic() - t0

    async def socket_pass():
        """The stream over a real localhost socket through a fresh
        edge (clean stats) on the same warm gateway."""
        app, server = await serve_edge(gw, tick_s=tick_s)
        try:
            return await run_loadgen(server.host, server.port,
                                     connections=n_conn,
                                     workload=workload)
        finally:
            await server.stop()

    async def run():
        # warm-up: one full-size pass per path, so every (job, machine)
        # predictor is fit and every realized batch shape is compiled —
        # otherwise whichever path runs later wins on cache warmth
        await inproc_pass()
        await socket_pass()

        # interleaved rep pairs: drift (CI neighbours, GC pauses) hits
        # both paths of a pair alike, so gate on the best PER-PAIR
        # ratio — best-socket-vs-best-inproc across different reps
        # would let uncorrelated noise fail a healthy edge
        report, inproc_out, inproc_s = None, None, math.inf
        best_ratio = -math.inf
        for _ in range(3):
            rep = await socket_pass()
            out, dt = await inproc_pass()
            pair_ratio = rep.rps * dt / n_req
            if pair_ratio > best_ratio:
                best_ratio = pair_ratio
                report, inproc_out, inproc_s = rep, out, dt

        # parity capture: every HTTP response body by workload index
        app, server = await serve_edge(gw, tick_s=tick_s)
        try:
            http_bytes = await capture(server.host, server.port)
        finally:
            await server.stop()
        return report, http_bytes, inproc_out, inproc_s

    report, http_bytes, inproc_out, inproc_s = asyncio.run(run())
    if report.errors:
        raise SystemExit(
            f"edge.socket: {report.errors}/{report.requests} requests "
            "answered error envelopes on a fully-valid workload")
    mean_batch = report.predict_mean_batch()
    _row("edge.socket", report.wall_s / report.requests * 1e6,
         f"requests/s={report.rps:.0f} connections={report.connections} "
         f"p50_ms={report.p50_ms:.1f} p95_ms={report.p95_ms:.1f} "
         f"p99_ms={report.p99_ms:.1f} predict_mean_batch={mean_batch:.2f}")

    inproc_rps = n_req / inproc_s
    ratio = report.rps / inproc_rps
    _row("edge.inproc", inproc_s / n_req * 1e6,
         f"requests/s={inproc_rps:.0f} "
         f"socket_vs_inproc={ratio:.2f}x (target >=0.5x)")

    expected = [encode(r).encode("ascii") for r in inproc_out]
    mismatch = sum(1 for a, b in zip(http_bytes, expected) if a != b)
    _row("edge.parity", 0.0,
         f"identical={n_req - mismatch}/{n_req} "
         "(HTTP body vs in-process envelope, byte-for-byte)")

    os.makedirs("experiments", exist_ok=True)
    with open("experiments/edge_bench.json", "w") as f:
        json.dump({"socket": report.to_json(),
                   "inproc_rps": inproc_rps,
                   "socket_vs_inproc": ratio,
                   "parity_mismatches": mismatch}, f, indent=2,
                  sort_keys=True)

    if mismatch:
        raise SystemExit(
            f"edge.parity: {mismatch}/{n_req} HTTP responses differ from "
            "the in-process gateway on the same seeded stream")
    if mean_batch <= 1.0:
        raise SystemExit(
            f"edge.socket: predict-lane mean batch {mean_batch:.2f} under "
            f"{n_conn} connections — the lanes are not coalescing")
    if ratio < 0.5:
        raise SystemExit(
            f"edge.socket: {report.rps:.0f} req/s is {ratio:.2f}x the "
            f"in-process gateway ({inproc_rps:.0f} req/s); the socket "
            "path must hold >= 0.5x")


def bench_ingest(args):
    """Contribution-ingestion throughput on a 10k-row collaborative store.

    ``ingest.contribute_cold``  first contribution (compiles executables)
    ``ingest.contribute_warm``  steady-state contributions/s and rows/s
    ``ingest.legacy_path``      pre-refactor emulation: O(N) TSV re-encode +
                                re-hash per contribution, fresh CV predictor
                                per machine group, full-copy concat — the
                                headline reports the warm speedup over it
                                (acceptance target >= 10x).
    """
    import hashlib

    from repro.core.datastore import RuntimeDataStore
    from repro.core.features import RuntimeData
    from repro.core.predictor import C3OPredictor
    from repro.workloads import spark_emul as W

    base = W.generate_job_data("grep")
    rng = np.random.default_rng(0)
    n_store, n_delta = 10_000, 20
    idx = np.tile(np.arange(len(base)), -(-n_store // len(base)))[:n_store]
    data = RuntimeData.from_columns(
        base.schema, base.machines, base.codes[idx], base.scale_out[idx],
        base.context[idx],
        base.runtime[idx] * rng.lognormal(0.0, 0.01, n_store))

    def delta():
        j = rng.integers(0, len(base), n_delta)
        return RuntimeData.from_columns(
            base.schema, base.machines, base.codes[j], base.scale_out[j],
            base.context[j],
            base.runtime[j] * rng.lognormal(0.0, 0.01, n_delta))

    store = RuntimeDataStore(data, seed=0)
    t0 = time.time()
    assert store.contribute(delta()).accepted
    cold = time.time() - t0
    _row("ingest.contribute_cold", cold * 1e6,
         f"first contribution at {n_store} stored rows (compiles)")

    reps = 10
    t0 = time.time()
    accepted = sum(store.contribute(delta()).accepted for _ in range(reps))
    warm = (time.time() - t0) / reps
    _row("ingest.contribute_warm", warm * 1e6,
         f"contributions/s={1 / warm:.1f} rows/s={n_delta / warm:.0f} "
         f"accepted={accepted}/{reps} store_rows={len(store)}")

    # --- pre-refactor path: full re-encode/re-hash + fresh CV predictors --
    def legacy_contribute(st, contribution):
        hashlib.sha256(st.data.to_tsv().encode()).hexdigest()  # O(N) rehash
        vrng = np.random.default_rng(st.seed)
        n = len(st.data)
        pidx = vrng.permutation(n)
        test = st.data.subset(pidx[: max(2, n // 5)])
        train = st.data.subset(pidx[max(2, n // 5):][:1024])
        cand = train.concat(contribution)
        for m in dict.fromkeys(contribution.machine_type):
            for dset in (train, cand):
                tr = dset.filter_machine(m)
                te = test.filter_machine(m)
                pred = C3OPredictor(max_cv_folds=15, seed=st.seed) \
                    .fit(tr.X, tr.y)
                p = np.nan_to_num(pred.predict(te.X), nan=1e12, posinf=1e12)
                np.mean(np.abs(p - te.y) / np.maximum(te.y, 1e-9))
        st.data = st.data.concat(contribution)

    store_l = RuntimeDataStore(data, seed=0)
    legacy_contribute(store_l, delta())                        # warm-up
    reps_l = 3
    t0 = time.time()
    for _ in range(reps_l):
        legacy_contribute(store_l, delta())
    legacy = (time.time() - t0) / reps_l
    _row("ingest.legacy_path", legacy * 1e6,
         f"contributions/s={1 / legacy:.1f} "
         f"speedup_warm_vs_legacy={legacy / max(warm, 1e-9):.1f}x "
         "(target >=10x)")


def bench_compact(args):
    """Store lifecycle: coverage-aware compaction of a 10k-row store.

    ``compact.reduce``    one ``compact()`` epoch transition at the default
                          knobs: wall time + rows retained (acceptance
                          gate: >= 4x row reduction)
    ``compact.refit``     warm full-machine refit wall time on the store
                          data before vs after the epoch transition
                          (acceptance gate: >= 2x faster after)
    ``compact.accuracy``  held-out MAPE of predictors fit on the full vs
                          the compacted store — the grid is re-measured
                          under an independent noise draw (acceptance
                          gate: degradation <= 1pp MAPE)

    The gates raise ``SystemExit`` (escaping the harness's per-bench
    except clause) so CI fails loudly when the reduction policy regresses.
    """
    from repro.core.datastore import RuntimeDataStore
    from repro.core.features import RuntimeData
    from repro.core.predictor import C3OPredictor
    from repro.workloads import spark_emul as W

    base = W.generate_job_data("grep")
    rng = np.random.default_rng(0)
    n_store = 10_000
    idx = np.tile(np.arange(len(base)), -(-n_store // len(base)))[:n_store]
    data = RuntimeData.from_columns(
        base.schema, base.machines, base.codes[idx], base.scale_out[idx],
        base.context[idx],
        base.runtime[idx] * rng.lognormal(0.0, 0.01, n_store))
    # held-out truth: the same measurement grid under an independent
    # noise draw — what a NEW reader of the store would need predicted
    test = RuntimeData.from_columns(
        base.schema, base.machines, base.codes, base.scale_out,
        base.context, base.runtime * rng.lognormal(0.0, 0.01, len(base)))
    machines = sorted(dict.fromkeys(data.machine_type))

    def fit_all(d):
        return {m: C3OPredictor(max_cv_folds=10, seed=0)
                .fit(d.machine_view(m).X, d.machine_view(m).y)
                for m in machines}

    def refit_time(d):
        best = math.inf
        for _ in range(2):
            t0 = time.time()
            fit_all(d)
            best = min(best, time.time() - t0)
        return best

    def held_out_mape(preds):
        errs = []
        for m in machines:
            te = test.machine_view(m)
            p = np.nan_to_num(preds[m].predict(te.X), nan=1e12, posinf=1e12,
                              neginf=-1e12)
            errs.append(float(np.mean(
                np.abs(p - te.y) / np.maximum(np.abs(te.y), 1e-9))))
        return float(np.mean(errs))

    store = RuntimeDataStore(data, seed=0)
    mape_full = held_out_mape(fit_all(data))      # also warms executables
    refit_full = refit_time(data)

    t0 = time.time()
    report = store.compact(seed=0)
    compact_s = time.time() - t0
    if not report.accepted:
        raise SystemExit(
            f"compact.reduce: default-knob compaction of the {n_store}-row "
            f"corpus must be accepted, got: {report.reason}")
    reduction = report.rows_before / max(report.rows_after, 1)
    _row("compact.reduce", compact_s * 1e6,
         f"rows={report.rows_before}->{report.rows_after} "
         f"reduction={reduction:.1f}x cells={report.cells} "
         f"epoch={store.epoch} (target >=4x)")
    if reduction < 4.0:
        raise SystemExit(
            f"compact.reduce: {reduction:.1f}x row reduction is below the "
            "4x acceptance floor")

    refit_small = refit_time(store.data)
    speedup = refit_full / max(refit_small, 1e-9)
    _row("compact.refit", refit_small * 1e6,
         f"full_us={refit_full * 1e6:.0f} "
         f"speedup={speedup:.1f}x (target >=2x)")
    if speedup < 2.0:
        raise SystemExit(
            f"compact.refit: warm refit sped up only {speedup:.1f}x; "
            "the epoch transition must buy >= 2x")

    mape_small = held_out_mape(fit_all(store.data))
    delta_pp = (mape_small - mape_full) * 100
    _row("compact.accuracy", compact_s * 1e6,
         f"mape_full={mape_full:.4f} mape_compacted={mape_small:.4f} "
         f"delta={delta_pp:+.2f}pp (target <=+1pp)")
    if delta_pp > 1.0:
        raise SystemExit(
            f"compact.accuracy: compaction degraded held-out MAPE by "
            f"{delta_pp:+.2f}pp (> +1pp budget)")


def bench_eval(args):
    """Collaborative replay plane: wall-clock and accuracy summary.

    A small leave-one-user-out replay (4 users, grep + sort) — enough
    contributions for real trajectories while staying CI-smoke sized.
    Reports per-checkpoint cost, each job's C3O final MAPE vs the
    optimistic/linear baselines, and quartile-median monotonicity; the
    full-scale run is ``python -m repro.eval.replay --users 8``.
    """
    from repro.eval.replay import ReplayConfig, run_replay

    cfg = ReplayConfig(jobs=("grep", "sort"), n_users=4, seed=0,
                       chunks_per_user=2)
    res = run_replay(cfg)
    checkpoints = len({(r["job"], r["held_out"], r["step"])
                       for r in res.records})
    _row("eval.replay", res.wall_s / max(checkpoints, 1) * 1e6,
         f"users={cfg.n_users} jobs={len(cfg.jobs)} "
         f"checkpoints={checkpoints} rows={len(res.records)} "
         f"accepted={res.accepted}/{res.contributions} "
         f"fingerprint={res.fingerprint[:12]} wall_s={res.wall_s:.1f}")
    for job, s in res.summary.items():
        best_base = min(s["baselines"].values())
        _row(f"eval.{job}", res.wall_s * 1e6 / max(checkpoints, 1),
             f"c3o_final={s['c3o_final']:.4f} "
             f"best_baseline={best_base:.4f} monotone={s['monotone']} "
             f"quartiles={'>'.join(f'{q:.3f}' for q in s['quartile_medians'])}")


def bench_trust(args):
    """Trust plane: adversarial-replay value + gateway auth overhead.

    ``trust.adversarial``  small twin-arm poisoned replay (one job, 25%
                           poisoners): final C3O MAPE with reputation
                           weighting off vs on — the improvement IS the
                           trust plane's measured value (the full 5-job
                           acceptance run is ``python -m
                           repro.eval.adversarial``).
    ``trust.auth_overhead``  hot-path cost of token admission: authed vs
                           plain predict requests through the gateway
                           (target <= 5% overhead).
    """
    from repro.api import (AuthedRequest, HubGateway, PredictRequest,
                           TrustAuthority)
    from repro.core.datastore import RuntimeDataStore
    from repro.core.hub import Hub, JobRepo
    from repro.eval.adversarial import AdversarialConfig, run_adversarial
    from repro.workloads import spark_emul as W

    # pagerank at the acceptance run's user mix: single-job smoke with a
    # visible off-vs-on gap (a scale + a noise poisoner slip data past
    # plain validation that reputation weighting then defuses)
    cfg = AdversarialConfig(jobs=("pagerank",), n_users=8,
                            poison_fraction=0.25, seed=0, chunks_per_user=2)
    res = run_adversarial(cfg)
    s = res.summary["pagerank"]
    _row("trust.adversarial", res.wall_s * 1e6 / max(res.contributions, 1),
         f"users={cfg.n_users} poisoners={len(cfg.poisoners())} "
         f"off_final={s['off_final']:.4f} on_final={s['on_final']:.4f} "
         f"improvement={s['improvement']:.4f} ok={s['ok']} "
         f"accepted={res.accepted}/{res.contributions} "
         f"fingerprint={res.fingerprint[:12]} wall_s={res.wall_s:.1f}")
    if not s["ok"]:
        # a hard acceptance gate, not a reported target: SystemExit
        # escapes the harness's per-bench except clause and fails CI
        raise SystemExit(
            "trust.adversarial: reputation weighting must strictly beat "
            f"weighting-off (off={s['off_final']:.4f} on={s['on_final']:.4f})")

    # --- auth admission overhead on the serving hot path ------------------
    prices = {m.name: m.price for m in W.MACHINES.values()}
    d = W.generate_job_data("grep")
    hub = Hub()
    hub.publish(JobRepo("grep", "grep", d.schema, RuntimeDataStore(d)))
    auth = TrustAuthority(rate=1e9, burst=1e9)     # meter, never refuse
    gw_plain = HubGateway(hub, prices, [2, 4, 8])
    gw_auth = HubGateway(hub, prices, [2, 4, 8], auth=auth)
    token = gw_auth.issue_token("bench")
    req = PredictRequest("grep", "m5.xlarge", ((4.0, 15.0, 0.02),))
    wrapped = AuthedRequest(token, req)
    gw_plain.predict(req)                          # warm the predictor
    gw_auth.predict(wrapped)
    n = 2000
    plain_s = authed_s = math.inf
    for _ in range(3):                             # interleaved best-of-reps
        t0 = time.time()
        for _ in range(n):
            gw_plain.predict(req)
        plain_s = min(plain_s, time.time() - t0)
        t0 = time.time()
        for _ in range(n):
            gw_auth.predict(wrapped)
        authed_s = min(authed_s, time.time() - t0)
    _row("trust.auth_overhead", authed_s / n * 1e6,
         f"plain_us={plain_s / n * 1e6:.1f} "
         f"overhead={(authed_s / plain_s - 1) * 100:+.1f}% (target <=5%)")


def bench_transfer(args):
    """Cold-start cross-job transfer: borrowed accuracy + lookup cost.

    ``transfer.lookup``    nearest-donor lookup on the hub's transfer
                           index: cold (sketches every store) vs warm
                           (unchanged store versions — pure cache hits);
                           flat signature-build/pair-eval counters across
                           the warm reps are a hard SystemExit gate
    ``transfer.borrowed``  MAPE of the gateway's borrowed predictions on
                           a zero-history twin job's full ground truth
                           vs the global-mean no-history baseline (what a
                           hub without transfer could answer) — borrowing
                           must beat it (hard SystemExit gate)
    """
    from repro.api import HubGateway, PredictRequest
    from repro.core.datastore import RuntimeDataStore
    from repro.core.hub import Hub, JobRepo
    from repro.core.transfer import TransferPolicy
    from repro.workloads import spark_emul as W

    prices = {m.name: m.price for m in W.MACHINES.values()}
    donors = ("sgd", "kmeans", "pagerank")   # schema-compatible donor pool
    hub = Hub()
    stores = {}
    for job in donors:
        d = W.generate_job_data(job, seed=0)
        stores[job] = RuntimeDataStore(d, seed=0)
        hub.publish(JobRepo(job, job, d.schema, stores[job],
                            predictor_kw={"max_cv_folds": 15}))
    cold = W.cold_job_name("sgd")
    hub.publish(JobRepo(cold, "sgd (cold twin)", W.cold_schema("sgd"),
                        RuntimeDataStore(W.cold_probe("sgd", 0), seed=0)))
    pol = TransferPolicy()
    gw = HubGateway(hub, prices, (2, 3, 4, 6, 8, 12), transfer=pol)

    # --- lookup cost: cold sketch vs version-keyed cache hits -------------
    index = hub.transfer_index(pol)
    t0 = time.time()
    match = index.nearest(cold)
    cold_us = (time.time() - t0) * 1e6
    builds = index.stats["signature_builds"]
    pairs = index.stats["pair_evals"]
    reps = 200
    t0 = time.time()
    for _ in range(reps):
        index.nearest(cold)
    warm_us = (time.time() - t0) / reps * 1e6
    _row("transfer.lookup", warm_us,
         f"cold_us={cold_us:.0f} warm_us={warm_us:.1f} "
         f"amortized={cold_us / max(warm_us, 1e-9):.0f}x "
         f"source={match.source} sim={match.similarity:.3f}")
    if index.stats["signature_builds"] != builds \
            or index.stats["pair_evals"] != pairs:
        raise SystemExit(
            "transfer.lookup: repeated lookups against unchanged store "
            "versions re-sketched "
            f"({index.stats['signature_builds'] - builds} builds, "
            f"{index.stats['pair_evals'] - pairs} pair evals) — the "
            "version-keyed caches are not amortizing")

    # --- borrowed accuracy vs the no-history global-mean baseline ---------
    test = W.generate_cold_job_data("sgd", seed=0)
    gmean = float(np.concatenate(
        [s.data.runtime for s in stores.values()]).mean())
    errs_b, errs_m = [], []
    n_rows, confidence = 0, 0.0
    t0 = time.time()
    for machine in sorted(test.present_machines()):
        te = test.machine_view(machine)
        y = np.asarray(te.y, np.float64)
        resp = gw.predict(PredictRequest(
            cold, machine, tuple(tuple(r) for r in te.X.tolist()), seed=0))
        if not resp.ok:
            raise SystemExit(
                f"transfer.borrowed: predict for {cold!r} on {machine!r} "
                f"failed: {resp.error_code}: {resp.detail}")
        p = np.asarray(resp.result.runtimes_s, np.float64)
        errs_b.append(float(np.mean(np.abs(p - y) / y)))
        errs_m.append(float(np.mean(np.abs(gmean - y) / y)))
        n_rows += len(y)
        confidence = resp.result.transfer_confidence
    dt = time.time() - t0
    mape_b, mape_m = float(np.mean(errs_b)), float(np.mean(errs_m))
    _row("transfer.borrowed", dt / max(n_rows, 1) * 1e6,
         f"source={match.source} confidence={confidence:.3f} "
         f"borrowed_mape={mape_b:.4f} mean_mape={mape_m:.4f} "
         f"rows={n_rows} (target: borrowed < mean)")
    if mape_b >= mape_m:
        raise SystemExit(
            f"transfer.borrowed: borrowed MAPE {mape_b:.4f} does not beat "
            f"the global-mean no-history baseline {mape_m:.4f}")


def bench_market(args):
    """Cloud market plane: interruption-adjusted placement selection.

    ``market.replay``     seeded spot-market replay (5 job families):
                          interruption-adjusted choice vs the naive
                          cheapest-listed-price baseline on REALIZED
                          completion cost — adjusted must win on every
                          family (hard SystemExit gate)
    ``market.grid_axis``  warm ``choose_cluster_batch`` wall-clock with
                          the full Z-zone placement axis (3 zones x 2
                          purchase options) vs a flat single-placement
                          book — the axis is vectorized broadcasting on
                          the same fused dispatch, so it must stay
                          within 2x (hard SystemExit gate)
    """
    from repro.core.datastore import RuntimeDataStore
    from repro.core.hub import JobRepo
    from repro.core.market import PriceBook
    from repro.core.service import ConfigurationService
    from repro.eval.replay import SpotMarketConfig, run_spot_market
    from repro.workloads import spark_emul as W

    # --- realized-cost win over the naive cheapest-price baseline ---------
    cfg = SpotMarketConfig(n_queries=10)     # CI-smoke sized
    res = run_spot_market(cfg)
    n_choices = 2 * cfg.n_queries * len(cfg.jobs)
    worst = min(res.summary.values(), key=lambda s: s["savings"])
    _row("market.replay", res.wall_s / n_choices * 1e6,
         f"families={len(res.summary)} "
         f"savings_worst={worst['savings']:.2f}x "
         f"diverged={sum(s['diverged'] for s in res.summary.values())}"
         f"/{sum(s['queries'] for s in res.summary.values())} "
         f"fingerprint={res.fingerprint[:12]} (target: adjusted < naive "
         "realized cost on every family)")
    for job, s in sorted(res.summary.items()):
        _row(f"market.{job}", 0.0,
             f"adjusted=${s['adjusted_cost']:.4f} "
             f"naive=${s['naive_cost']:.4f} savings={s['savings']:.2f}x "
             f"diverged={s['diverged']}/{s['queries']}")
    if not res.ok:
        losers = [j for j, s in res.summary.items() if not s["ok"]]
        raise SystemExit(
            "market.replay: interruption-adjusted selection does not "
            "beat the naive cheapest-listed-price baseline on realized "
            f"cost for: {', '.join(losers)}")

    # --- placement axis is broadcasting, not a loop -----------------------
    data = W.generate_job_data("grep", seed=0)
    repo = JobRepo("grep", "grep", data.schema,
                   RuntimeDataStore(data, seed=0),
                   predictor_kw={"max_cv_folds": 15})
    preds = {m: repo.predictor_for(m) for m in sorted(W.MACHINES)}
    prices = {m.name: m.price for m in W.MACHINES.values()}
    scaleouts = (2, 3, 4, 6, 8, 12)
    flat_svc = ConfigurationService(preds, {}, scaleouts,
                                    market=PriceBook.flat(prices))
    full_svc = ConfigurationService(preds, {}, scaleouts,
                                    market=W.generate_price_book(0))
    ctx = np.stack([np.array([15.0 * (1 + 0.05 * i), 0.02])
                    for i in range(64)])

    def best_of(svc, reps=5):
        svc.choose_cluster_batch(ctx)                      # warm-up
        best = float("inf")
        for _ in range(reps):
            t0 = time.time()
            svc.choose_cluster_batch(ctx)
            best = min(best, time.time() - t0)
        return best

    flat_s, full_s = best_of(flat_svc), best_of(full_svc)
    z = len(full_svc.market.placements)
    ratio = full_s / max(flat_s, 1e-12)
    _row("market.grid_axis", full_s / len(ctx) * 1e6,
         f"placements={z} flat_us={flat_s * 1e6:.0f} "
         f"full_us={full_s * 1e6:.0f} ratio={ratio:.2f}x "
         "(target: <= 2x — a vectorized axis, not a loop)")
    if ratio > 2.0:
        raise SystemExit(
            f"market.grid_axis: scoring {z} placements costs "
            f"{ratio:.2f}x the single-placement grid (> 2x): the "
            "placement axis is not amortizing like a vectorized axis")


def bench_table1(args):
    from repro.workloads import spark_emul as W
    t0 = time.time()
    data = W.generate_all()
    total = sum(len(d) for d in data.values())
    per = ";".join(f"{j}:{len(d)}" for j, d in data.items())
    _row("table1.dataset", (time.time() - t0) * 1e6 / max(total, 1),
         f"total={total} (paper:930) {per}")


def bench_table2(args):
    from benchmarks.common import JOBS, PAPER_TABLE2, run_scenario
    for job in JOBS:
        for scenario in (("local", "global") if job != "sort"
                         else ("global",)):
            t0 = time.time()
            r = run_scenario(job, scenario, n_splits=args.splits)
            dt = (time.time() - t0) * 1e6 / args.splits
            for model in ("ernest", "gbm", "bom", "ogb", "c3o"):
                paper = PAPER_TABLE2[job][model][scenario != "local"]
                _row(f"table2.{job}.{scenario}.{model}", dt,
                     f"mape={r[model]:.4f} paper={paper:.4f}")


def bench_fig5(args):
    from benchmarks.common import MODELS, TARGET_MACHINE
    from repro.core.predictor import evaluate_split
    from repro.workloads import spark_emul as W
    sizes = [3, 6, 9, 12, 15, 18, 21, 24, 27, 30]
    n_splits = max(args.splits // 4, 10)
    for job in ("grep", "kmeans"):          # representative pair of panels
        data = W.generate_job_data(job).filter_machine(TARGET_MACHINE)
        rng = np.random.default_rng(1)
        for n in sizes:
            t0 = time.time()
            errs = {}
            for i in range(n_splits):
                idx = rng.permutation(len(data))
                tr, te = idx[:n], idx[n:]
                r = evaluate_split(MODELS, data.X[tr], data.y[tr],
                                   data.X[te], data.y[te],
                                   max_cv_folds=min(n, 10), seed=i)
                for k, v in r.items():
                    if k != "c3o_selected":
                        errs.setdefault(k, []).append(v)
            dt = (time.time() - t0) * 1e6 / n_splits
            summary = " ".join(
                f"{m}={np.mean(np.minimum(errs[m], 10.0)):.3f}"
                for m in ("ernest", "gbm", "bom", "ogb", "c3o"))
            _row(f"fig5.{job}.n{n}", dt, summary)


def bench_configurator(args):
    from repro.core.configurator import Configurator
    from repro.core.predictor import C3OPredictor
    from repro.workloads import spark_emul as W
    prices = {m.name: m.price for m in W.MACHINES.values()}
    scaleouts = [2, 3, 4, 6, 8, 12, 16]
    rng = np.random.default_rng(0)
    for job, ctx_fn in (("grep", lambda: (rng.uniform(10, 20),
                                          rng.choice([.002, .02, .08]))),
                        ("sgd", lambda: (rng.uniform(10, 30),
                                         rng.choice([5, 20, 40, 70, 100]),
                                         rng.choice([50, 100])))):
        d = W.generate_job_data(job).filter_machine("m5.xlarge")
        pred = C3OPredictor(max_cv_folds=25).fit(d.X, d.y)
        conf = Configurator(pred, "m5.xlarge", prices, scaleouts,
                            confidence=0.95)
        hits = total = 0
        cost_c3o = cost_max = 0.0
        t0 = time.time()
        for _ in range(60):
            ctx = np.asarray(ctx_fn(), dtype=float)
            feasible_t = [W.true_runtime(job, "m5.xlarge", s, tuple(ctx))
                          for s in scaleouts]
            t_max = float(rng.uniform(1.15, 2.0) * min(feasible_t))
            ch = conf.choose_scaleout(ctx, t_max=t_max)
            truth = feasible_t[scaleouts.index(ch.scale_out)]
            total += 1
            hits += truth <= t_max
            cost_c3o += prices["m5.xlarge"] * truth / 3600 * ch.scale_out
            cost_max += prices["m5.xlarge"] * feasible_t[-1] / 3600 \
                * scaleouts[-1]
        dt = (time.time() - t0) * 1e6 / total
        _row(f"configurator.{job}", dt,
             f"deadline_hit={hits/total:.3f} (target>=0.95) "
             f"cost_vs_overprovision={cost_c3o/cost_max:.3f}")


def bench_autoconfig(args):
    from repro.configs import SHAPES, get_config
    from repro.launch.autoconfig import (SLICES, autoconfigure,
                                         predicted_step_time)
    for arch, shape in (("gemma3-1b", "train_4k"),
                        ("deepseek-7b", "train_4k"),
                        ("kimi-k2-1t-a32b", "train_4k")):
        t0 = time.time()
        choice, pred = autoconfigure(arch, shape,
                                     chip_counts=(64, 128, 256, 512))
        dt = (time.time() - t0) * 1e6
        cfg = get_config(arch)
        true_t = predicted_step_time(cfg, SHAPES[shape], SLICES["v5e"],
                                     choice.scale_out)
        err = abs(choice.predicted_runtime_s - true_t) / true_t
        _row(f"autoconfig.{arch}", dt,
             f"chips={choice.scale_out} model={pred.selected} "
             f"step_pred={choice.predicted_runtime_s*1e3:.0f}ms "
             f"pred_err={err:.3f}")


def bench_kernels(args):
    import jax
    import jax.numpy as jnp
    from repro.kernels import ref as R
    from repro.kernels.flash_attention import flash_attention
    key = jax.random.PRNGKey(0)
    B, S, H, KV, hd = 1, 512, 4, 2, 64

    def timed(fn, *a, n=3, **kw):
        fn(*a, **kw)           # compile/warm
        t0 = time.time()
        for _ in range(n):
            jax.block_until_ready(fn(*a, **kw))
        return (time.time() - t0) / n * 1e6

    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    ref_t = timed(jax.jit(lambda q, k, v: R.attention_ref(q, k, v)), q, k, v)
    _row("kernels.attention_ref_jit", ref_t, "oracle (XLA:CPU)")
    pal_t = timed(lambda q, k, v: flash_attention(
        q, k, v, q_block=128, kv_block=128, interpret=True), q, k, v, n=1)
    _row("kernels.flash_attention_interpret", pal_t,
         "correctness path (TPU kernel interpreted on CPU)")

    r_ = jax.random.normal(ks[0], (B, 256, H, 32)) * 0.5
    k_ = jax.random.normal(ks[1], (B, 256, H, 32)) * 0.5
    v_ = jax.random.normal(ks[2], (B, 256, H, 32)) * 0.5
    w_ = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (B, 256, H, 32)) * 0.5))
    u_ = jnp.zeros((H, 32))
    seq_t = timed(jax.jit(lambda *a: R.wkv6_ref(*a)[0]), r_, k_, v_, w_, u_)
    chk_t = timed(jax.jit(lambda *a: R.wkv6_chunked_ref(*a)[0]),
                  r_, k_, v_, w_, u_)
    _row("kernels.wkv6_sequential_ref", seq_t, "token-recurrent oracle")
    _row("kernels.wkv6_chunked_jnp", chk_t,
         f"chunked form, speedup={seq_t/max(chk_t,1e-9):.1f}x over sequential")


def bench_roofline(args):
    recs = []
    for p in sorted(glob.glob("experiments/dryrun_*.json")):
        with open(p) as f:
            recs.extend(json.load(f))
    ok = [r for r in recs if r.get("status") == "ok"
          and r["mesh"] == "16x16"]
    if not ok:
        _row("roofline", 0.0, "no dryrun records yet (run launch.dryrun)")
        return
    for r in ok:
        rl = r["roofline"]
        _row(f"roofline.{r['arch']}.{r['shape']}", r["compile_s"] * 1e6,
             f"dom={rl['dominant']} bound_ms={rl['bound_s']*1e3:.1f} "
             f"compute_ms={rl['compute_s']*1e3:.1f} "
             f"mem_ms={rl['memory_s']*1e3:.1f} "
             f"coll_ms={rl['collective_s']*1e3:.1f} "
             f"useful={r['useful_flops_ratio']:.2f} "
             f"fits={r['fits_hbm']}")


BENCHES = {
    "engine": bench_engine,
    "serve": bench_serve,
    "gateway": bench_gateway,
    "edge": bench_edge,
    "ingest": bench_ingest,
    "compact": bench_compact,
    "eval": bench_eval,
    "trust": bench_trust,
    "transfer": bench_transfer,
    "market": bench_market,
    "table1": bench_table1,
    "table2": bench_table2,
    "fig5": bench_fig5,
    "configurator": bench_configurator,
    "autoconfig": bench_autoconfig,
    "kernels": bench_kernels,
    "roofline": bench_roofline,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--splits", type=int, default=60)
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for name, fn in BENCHES.items():
        if args.only and name != args.only:
            continue
        try:
            fn(args)
        except Exception as e:       # report, keep the harness going
            _row(f"{name}.ERROR", 0.0, f"{type(e).__name__}: {e}")


if __name__ == "__main__":
    main()
