"""C3O-for-TPU: pick a pod slice + chip count for a training workload from
collaboratively shared step-time records (the paper's technique applied to
this framework's own scheduling problem).

Run:  PYTHONPATH=src python examples/autoconfigure_cluster.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.autoconfig import autoconfigure


def main():
    for arch, budget in (("gemma3-1b", None),
                         ("deepseek-7b", 0.8),
                         ("kimi-k2-1t-a32b", None)):
        choice, pred = autoconfigure(arch, "train_4k",
                                     step_budget_s=budget,
                                     chip_counts=(64, 128, 256, 512))
        b = f"{budget}s" if budget else "cheapest"
        print(f"{arch:18s} budget={b:9s} -> {choice.scale_out:4d} chips "
              f"(model={pred.selected}, step={choice.predicted_runtime_s*1e3:.0f}ms, "
              f"CV mape={pred.cv_mape[pred.selected]:.3f})")


if __name__ == "__main__":
    main()
