"""Quickstart: the paper's full workflow (Fig. 4) in one script.

1. search the hub for a job  2. download shared runtime data
3-4. provide inputs          5. get a cluster configuration
6. contribute your run's metrics back.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import Hub, JobRepo, RuntimeDataStore
from repro.core.features import RuntimeData
from repro.workloads import spark_emul as W


def main():
    # --- maintainers publish job repos with shared runtime data ----------
    hub = Hub()
    for job in ("sort", "grep", "kmeans"):
        data = W.generate_job_data(job)
        hub.publish(JobRepo(job, f"apache spark {job}", data.schema,
                            RuntimeDataStore(data)))

    # --- (1) the user searches for an algorithm --------------------------
    repo = hub.search("grep")[0]
    print(f"found job '{repo.job}' with {len(repo.store)} shared runs")

    # --- (2-5) configure a cluster for the user's dataset + deadline -----
    prices = {m.name: m.price for m in W.MACHINES.values()}
    conf = repo.configurator("m5.xlarge", prices,
                             scaleouts=[2, 3, 4, 6, 8, 12])
    ctx = np.asarray([18.0, 0.02])      # 18 GB dataset, 2% keyword hits
    print("\nruntime/cost menu (scale-out, est. seconds, $):")
    for s, t_s, cost in conf.runtime_cost_pairs(ctx):
        print(f"  {s:3d} nodes   {t_s:7.1f}s   ${cost:.4f}")
    choice = conf.choose_scaleout(ctx, t_max=420.0)
    print(f"\ndeadline 420s @95% confidence -> {choice.scale_out} nodes "
          f"(bound {choice.runtime_bound_s:.0f}s, ${choice.cost_usd:.4f})")

    # --- run it (emulated) and (6) contribute the measurement ------------
    measured = W._measure("grep", "m5.xlarge", choice.scale_out,
                          (18.0, 0.02), seed=123)
    print(f"measured runtime: {measured:.1f}s "
          f"({'deadline met' if measured <= 420 else 'MISSED'})")
    new = RuntimeData(repo.schema, np.asarray(["m5.xlarge"]),
                      np.asarray([[choice.scale_out, 18.0, 0.02]]),
                      np.asarray([measured]))
    report = repo.contribute(new, contributor="quickstart-user")
    print(f"contribution validation: accepted={report.accepted} "
          f"({report.reason})")

    # --- the same loop through the API v1 gateway (canonical surface) ----
    from repro.api import (ChooseRequest, ContributeRequest, SearchRequest)
    gw = hub.gateway(prices, scaleouts=(2, 3, 4, 6, 8, 12))
    hit = gw.search(SearchRequest("grep")).result.jobs[0]
    resp = gw.choose(ChooseRequest(hit.job, (18.0, 0.02), t_max=420.0))
    c = resp.result
    print(f"\ngateway: {hit.job} -> {c.machine_type} x{c.scale_out} "
          f"(bound {c.runtime_bound_s:.0f}s, ${c.cost_usd:.4f})")
    measured = W._measure("grep", c.machine_type, c.scale_out,
                          (18.0, 0.02), seed=124)
    out = gw.contribute(ContributeRequest(
        hit.job, (c.machine_type,),
        ((float(c.scale_out), 18.0, 0.02),), (measured,),
        contributor_id="quickstart-user")).result
    print(f"gateway contribution: accepted={out.accepted} "
          f"store_rows={out.store_rows} by {out.contributor_id}")


if __name__ == "__main__":
    main()
