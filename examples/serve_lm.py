"""Batched serving example: prefill + autoregressive decode with KV caches
(ring buffers on sliding-window layers, int8 quantization optional).

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import os
import sys

import jax

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import smoke_config
from repro.modeling import model as M
from repro.serve.serve_step import greedy_generate


def main():
    for kv_dtype in ("", "int8"):
        cfg = smoke_config("gemma3-1b", kv_cache_dtype=kv_dtype)
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        B, S0 = 4, 16
        prompt = jax.random.randint(jax.random.PRNGKey(1), (B, S0), 0,
                                    cfg.vocab_size)
        toks = greedy_generate(cfg, params, prompt, max_new=24, max_seq=64)
        tag = kv_dtype or "bf16/fp32"
        print(f"kv_cache={tag:9s} generated {toks.shape[1]} tokens/req "
              f"x {B} requests: {toks[0][:10].tolist()}...")


if __name__ == "__main__":
    main()
