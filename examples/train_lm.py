"""End-to-end training driver example: a ~100M-parameter gemma3-family model
for a few hundred steps on CPU/host devices, with checkpointing, crash
recovery and C3O runtime capture.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
(defaults to 60 steps to stay quick; pass --steps 300 for the full curve)
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import get_config
from repro.launch.train import run as train_run


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--hundred-m", action="store_true",
                    help="full ~100M config (use on real accelerators; the "
                    "CPU default is a ~20M variant of the same family)")
    args = ap.parse_args()

    import dataclasses
    import repro.configs  # noqa: F401
    from repro.configs.base import _REGISTRY
    base = get_config("gemma3-1b")
    if args.hundred_m:   # ~100M params, gemma3 family
        cfg = dataclasses.replace(
            base, n_layers=12, d_model=512, n_heads=4, n_kv_heads=1,
            head_dim=128, d_ff=2048, vocab_size=32768, window_size=256,
            dtype="float32", param_dtype="float32", remat="none",
            grad_accum=1, attention_impl="reference")
        batch, seq = 8, 256
    else:                # ~20M CPU-friendly variant, same code paths
        cfg = dataclasses.replace(
            base, n_layers=6, d_model=256, n_heads=4, n_kv_heads=1,
            head_dim=64, d_ff=1024, vocab_size=8192, window_size=64,
            dtype="float32", param_dtype="float32", remat="none",
            grad_accum=1, attention_impl="reference")
        batch, seq = 4, 128
    _REGISTRY["gemma3-example"] = lambda: cfg
    n = cfg.param_counts()["total"] / 1e6
    print(f"training gemma3-example (~{n:.0f}M params) for {args.steps} steps")

    losses = train_run("gemma3-example", steps=args.steps, batch=batch,
                       seq=seq, ckpt_dir=args.ckpt_dir, smoke=False,
                       ckpt_every=20,
                       runtime_log="/tmp/repro_runtime_log.jsonl")
    k = max(len(losses) // 10, 1)
    for i in range(0, len(losses), k):
        print(f"  step {i:4d}  loss {losses[i]:.4f}")
    print(f"  final loss {losses[-1]:.4f} (start {losses[0]:.4f})")
    assert losses[-1] < losses[0], "loss must decrease"


if __name__ == "__main__":
    main()
