"""C3O Hub Gateway API v1 — the canonical public surface.

One versioned, serializable request/response vocabulary for the paper's
whole collaborative loop (Fig. 4): discover a job (``SearchRequest``),
predict runtimes (``PredictRequest``), choose a cluster
(``ChooseRequest``), evaluate models (``ModelErrorsRequest``), and
contribute runtime data back with provenance (``ContributeRequest``).
The trust plane rides the same vocabulary: any request wraps in an
``AuthedRequest`` bearer-token envelope (mandatory on auth-enabled
gateways) and ``TrustStateRequest`` inspects a contributor's standing.
``HubGateway`` routes these across every published ``JobRepo``;
``repro.api.codec`` gives every envelope a deterministic JSON form so the
same objects work in-process today and over HTTP later.
"""
from repro.api.auth import TrustAuthority
from repro.api.codec import decode, encode
from repro.api.gateway import AsyncHubGateway, HubGateway
from repro.api.types import (API_VERSION, AuthedRequest, ChooseRequest,
                             ChooseResult, CompactRequest, CompactResult,
                             ContributeRequest, ContributeResult,
                             HealthResult, JobInfo, LaneSnapshot,
                             ModelErrorsRequest, ModelErrorsResult,
                             PredictRequest, PredictResult, Response,
                             SearchRequest, SearchResult, StatsResult,
                             TrustStateRequest, TrustStateResult)
from repro.core.market import (ON_DEMAND, SPOT, MarketError, Placement,
                               PriceBook)
from repro.core.transfer import TransferPolicy

__all__ = [
    "API_VERSION", "AuthedRequest", "ChooseRequest", "ChooseResult",
    "CompactRequest", "CompactResult", "ContributeRequest",
    "ContributeResult", "HealthResult", "JobInfo", "LaneSnapshot",
    "ModelErrorsRequest", "ModelErrorsResult", "PredictRequest",
    "PredictResult", "Response", "SearchRequest", "SearchResult",
    "StatsResult", "TrustStateRequest", "TrustStateResult", "HubGateway",
    "AsyncHubGateway", "TrustAuthority", "TransferPolicy", "MarketError",
    "ON_DEMAND", "SPOT", "Placement", "PriceBook", "decode", "encode",
]
