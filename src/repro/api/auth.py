"""TrustAuthority: token auth + per-contributor rate quotas for the
gateway (trust plane, gateway layer).

A hub operator issues bearer tokens per contributor; an auth-enabled
``HubGateway`` asks the authority to ``admit`` every request BEFORE it
touches any ``JobRepo``.  Admission answers in trust-plane error codes —
``unauthorized`` (missing / revoked token, banned contributor) or
``quota_exceeded`` (token-bucket empty) — which the gateway turns into
typed error envelopes, never exceptions.

Quotas are per CONTRIBUTOR, not per token: all of a contributor's tokens
drain one shared ``TokenBucket``, so re-issuing tokens does not multiply
the allowance.  The clock is injectable (monotonic seconds) so tests and
replays drive admission deterministically.
"""
from __future__ import annotations

import math
import secrets
import time
from typing import Callable, Dict, Optional, Tuple

from repro.api.types import ERR_QUOTA_EXCEEDED, ERR_UNAUTHORIZED
from repro.core.trust import TokenBucket


class TrustAuthority:
    """Issues/revokes contributor tokens and meters per-contributor quotas.

    ``rate`` is the sustained allowance in requests/second, ``burst`` the
    bucket capacity (how far a contributor can run ahead of the sustained
    rate).  ``clock`` must be monotonic; it defaults to
    ``time.monotonic``.
    """

    def __init__(self, *, rate: float = 50.0, burst: float = 100.0,
                 clock: Callable[[], float] = time.monotonic):
        if rate <= 0 or burst <= 0:
            raise ValueError("rate and burst must be positive")
        self.rate = float(rate)
        self.burst = float(burst)
        self._clock = clock
        self._tokens: Dict[str, str] = {}        # token -> contributor id
        self._buckets: Dict[str, TokenBucket] = {}
        self._banned: set = set()
        self._operators: set = set()

    # ------------------------- admin surface ------------------------------
    def issue_token(self, contributor_id: str) -> str:
        """Mint a bearer token for ``contributor_id`` (one contributor may
        hold several; they share one quota bucket)."""
        cid = str(contributor_id)
        if not cid:
            raise ValueError("contributor_id must be non-empty")
        token = secrets.token_hex(16)
        self._tokens[token] = cid
        return token

    def revoke_token(self, token: str) -> bool:
        """Invalidate one token; returns whether it was active."""
        return self._tokens.pop(token, None) is not None

    def ban(self, contributor_id: str) -> None:
        """Refuse ALL of this contributor's tokens until ``unban``."""
        self._banned.add(str(contributor_id))

    def unban(self, contributor_id: str) -> bool:
        cid = str(contributor_id)
        if cid in self._banned:
            self._banned.remove(cid)
            return True
        return False

    def grant_operator(self, contributor_id: str) -> None:
        """Mark a contributor as a hub OPERATOR: authorized for store
        lifecycle operations (``CompactRequest``) on an auth-enabled
        gateway.  Operator standing rides the same token auth — the
        contributor still needs an issued token; this only widens what an
        admitted identity may do."""
        cid = str(contributor_id)
        if not cid:
            raise ValueError("contributor_id must be non-empty")
        self._operators.add(cid)

    def revoke_operator(self, contributor_id: str) -> bool:
        """Withdraw operator standing; returns whether it was held."""
        cid = str(contributor_id)
        if cid in self._operators:
            self._operators.remove(cid)
            return True
        return False

    # ------------------------- inspection ---------------------------------
    def identify(self, token: Optional[str]) -> Optional[str]:
        """Contributor id behind an active token, else None."""
        return None if token is None else self._tokens.get(token)

    def known(self, contributor_id: str) -> bool:
        """Does this contributor hold at least one active token?"""
        return str(contributor_id) in self._tokens.values()

    def is_banned(self, contributor_id: str) -> bool:
        return str(contributor_id) in self._banned

    def is_operator(self, contributor_id: str) -> bool:
        return str(contributor_id) in self._operators

    def quota_remaining(self, contributor_id: str) -> float:
        """Tokens currently available in the contributor's bucket (the
        full ``burst`` for a contributor who has never been metered)."""
        bucket = self._buckets.get(str(contributor_id))
        if bucket is None:
            return self.burst
        return bucket.remaining(self._clock())

    # ------------------------- admission ----------------------------------
    def admit(self, token: Optional[str], cost: float = 1.0
              ) -> Tuple[Optional[str], str, str]:
        """Authenticate + meter one request.

        Returns ``(contributor_id, "", "")`` on admission, else
        ``(None, error_code, detail)`` with a trust-plane error code the
        gateway can put straight into an error envelope."""
        if token is None or not token:
            return None, ERR_UNAUTHORIZED, (
                "authentication required: wrap the request in an "
                "AuthedRequest carrying an issued token")
        cid = self._tokens.get(token)
        if cid is None:
            return None, ERR_UNAUTHORIZED, "unknown or revoked token"
        if cid in self._banned:
            return None, ERR_UNAUTHORIZED, f"contributor {cid!r} is banned"
        bucket = self._buckets.get(cid)
        if bucket is None:
            bucket = self._buckets[cid] = TokenBucket(self.rate, self.burst)
        if not bucket.admit(self._clock(), cost=cost):
            return None, ERR_QUOTA_EXCEEDED, (
                f"rate quota exhausted for contributor {cid!r} "
                f"(sustained {self.rate:g}/s, burst {self.burst:g})")
        return cid, "", ""


#: quota_remaining value reported by gateways WITHOUT an authority
UNMETERED = math.inf
