"""Deterministic JSON codec for the API v1 envelopes.

``encode`` maps any envelope (or plain JSON-able value) to ONE canonical
byte sequence; ``decode`` inverts it.  Guarantees:

  * byte stability: ``encode(decode(encode(x))) == encode(x)`` — sorted
    keys, minimal separators, ASCII-escaped unicode, shortest-repr floats;
  * strict JSON on the wire: non-finite floats (NaN deadlines, infinite
    bounds) encode as a tagged object ``{"__float__": "nan"|"inf"|"-inf"}``
    instead of the non-standard ``NaN`` literal, so any JSON parser can
    read gateway traffic;
  * type fidelity: every dataclass carries a ``"__type__"`` tag and is
    reconstructed as the same class; sequences decode as tuples (the
    envelope field convention), so ``decode(encode(x)) == x`` for every
    envelope whose float fields are finite.  NaN fields (a no-deadline
    ``ChooseRequest``) decode back to NaN, where ``==`` is false by IEEE
    semantics — compare by ``encode`` bytes (``encode(decode(s)) == s``
    always holds) when identity over NaN payloads matters.
"""
from __future__ import annotations

import dataclasses
import json
import math
from typing import Any, Dict

from repro.api import types as T

_TYPES: Dict[str, type] = {cls.__name__: cls for cls in T.MESSAGE_TYPES}

_NONFINITE = {math.inf: "inf", -math.inf: "-inf"}


def _to_jsonable(v: Any) -> Any:
    if dataclasses.is_dataclass(v) and not isinstance(v, type):
        name = type(v).__name__
        if name not in _TYPES:
            raise TypeError(f"not an API v1 message type: {name}")
        out = {"__type__": name}
        for f in dataclasses.fields(v):
            val = getattr(v, f.name)
            # fields marked omit_default are dropped from the wire when
            # they hold their default: new optional envelope fields can
            # be added without changing a single existing golden byte,
            # and decode reconstructs the default for legacy payloads
            if f.metadata.get("omit_default") and val == f.default:
                continue
            out[f.name] = _to_jsonable(val)
        return out
    if isinstance(v, float):
        if math.isnan(v):
            return {"__float__": "nan"}
        if math.isinf(v):
            return {"__float__": _NONFINITE[v]}
        return v
    if isinstance(v, (tuple, list)):
        return [_to_jsonable(x) for x in v]
    if isinstance(v, dict):
        return {str(k): _to_jsonable(x) for k, x in v.items()}
    if v is None or isinstance(v, (str, int, bool)):
        return v
    raise TypeError(f"unencodable value of type {type(v).__name__}: {v!r}")


def _from_jsonable(v: Any) -> Any:
    if isinstance(v, dict):
        if "__float__" in v and len(v) == 1:
            return float(v["__float__"])        # "nan" / "inf" / "-inf"
        if "__type__" in v:
            cls = _TYPES[v["__type__"]]
            kw = {k: _from_jsonable(x) for k, x in v.items()
                  if k != "__type__"}
            return cls(**kw)
        return {k: _from_jsonable(x) for k, x in v.items()}
    if isinstance(v, list):
        return tuple(_from_jsonable(x) for x in v)
    return v


def encode(message: Any) -> str:
    """Canonical JSON text for one envelope (or nested JSON-able value)."""
    return json.dumps(_to_jsonable(message), sort_keys=True,
                      separators=(",", ":"), ensure_ascii=True,
                      allow_nan=False)


def decode(text: str) -> Any:
    """Inverse of ``encode``: reconstructs tagged dataclasses and tuples."""
    return _from_jsonable(json.loads(text))
