"""HubGateway: one routed entry point for the whole C3O workflow.

``HubGateway`` serves the five typed API v1 requests across every
``JobRepo`` published on a ``Hub``, holding per-(job, store-version)
``ConfigurationService`` state so repeated traffic reuses warm predictors
and compiled executables.  Every answer is a uniform ``Response``
envelope; operational failures (unknown job, malformed payload) are error
envelopes, never raised exceptions — a front-end can serialize whatever
comes back.

``AsyncHubGateway`` adds per-job micro-batch lanes: concurrent ``choose``
requests are routed to their job's ``BatchLane`` (``repro.serve``), so a
mixed multi-job request stream coalesces into ONE
``ConfigurationService.choose_cluster_batch`` engine dispatch *per job
per tick* — the single-service micro-batcher generalized to the full hub.

The gateway answers request-for-request identically to the legacy direct
object path (``JobRepo.predictor_for`` / ``choose_cluster_batch`` /
``RuntimeDataStore.contribute`` / ``JobRepo.model_errors``);
``tests/test_api_gateway.py`` pins that parity.
"""
from __future__ import annotations

import asyncio
from collections import OrderedDict
from dataclasses import replace
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.api.auth import UNMETERED, TrustAuthority
from repro.api.types import (ERR_BAD_REQUEST, ERR_INTERNAL, ERR_TIMEOUT,
                             ERR_UNAUTHORIZED, ERR_UNKNOWN_JOB, AuthedRequest,
                             ChooseRequest, ChooseResult, CompactRequest,
                             CompactResult, ContributeRequest,
                             ContributeResult, JobInfo, ModelErrorsRequest,
                             ModelErrorsResult, PredictRequest, PredictResult,
                             Response, SearchRequest, SearchResult,
                             TrustStateRequest, TrustStateResult)
from repro.core.features import RuntimeData
from repro.core.market import MarketError, PriceBook
from repro.core.service import ConfigurationService
from repro.core.transfer import TransferPolicy
from repro.serve.config_service import BatchLane, LaneTimeoutError, ServeStats


class UnknownJobError(KeyError):
    """Request named a job no published repo serves."""


class HubGateway:
    """Routes typed API v1 requests across all published job repos.

    ``prices`` ($ per node-hour per machine type) and ``scaleouts`` are
    the serving-time configuration grid shared by every job; they would
    come from the deployment's cloud catalog in production.

    ``auth`` (a ``repro.api.auth.TrustAuthority``) turns the trust plane
    on: EVERY operation must then arrive wrapped in an ``AuthedRequest``
    whose token authenticates an unbanned contributor with quota left —
    admission happens before the request touches any ``JobRepo``, and
    refusals are typed ``unauthorized`` / ``quota_exceeded`` error
    envelopes.  With ``auth=None`` (the default) the gateway stays
    unauthenticated and wrapped requests are transparently unwrapped.
    """

    def __init__(self, hub, prices: Dict[str, float],
                 scaleouts: Sequence[int], *, confidence: float = 0.95,
                 seed: int = 0, auth: Optional[TrustAuthority] = None,
                 transfer: Optional[TransferPolicy] = None,
                 market: Optional[PriceBook] = None):
        self.hub = hub
        self.auth = auth
        # cloud market plane (repro.core.market): with a PriceBook set,
        # choose scores a (machine x zone x purchase-option x scale-out)
        # grid on interruption-adjusted expected cost and stamps the
        # envelope with zone / purchase_option / expected_cost_usd.
        # None (the default) keeps the static $/node-hour model and the
        # pre-market wire format byte-for-byte.
        self.market = market
        # cold-start cross-job transfer (Flora-style): with a policy set,
        # predict/choose for unknown or under-supported jobs borrow the
        # nearest published job's fitted models and stamp the envelope
        # with transfer_source / transfer_confidence.  None (the default)
        # keeps the pre-transfer behavior: unknown jobs are errors.
        self.transfer = transfer
        self.prices = dict(prices)
        self.scaleouts = tuple(int(s) for s in scaleouts)
        self.confidence = confidence
        self.seed = seed
        # (job, seed) -> (store version, trust version, model-spec
        # objects, service): an accepted contribution bumps the store
        # version, a judged contribution can bump the TRUST version
        # (reputation moved, so stored rows re-weight), and a
        # maintainer's add_custom_model / spec re-registration changes
        # the spec tuple (the same invalidation contract
        # JobRepo.predictor_for keeps) — any of them lazily rebuilds the
        # service from the repo's (cached, possibly warm-started)
        # predictors on the next request.
        # LRU-capped: the seed is CLIENT-supplied, so an uncapped dict
        # would grow one service per distinct seed in hostile traffic
        self._services: "OrderedDict[Tuple[str, int], tuple]" = OrderedDict()
        # job -> ((store version, model names), JobInfo): search /
        # provenance metadata is recomputed only when the repo actually
        # changed, not per request
        self._jobinfo: Dict[str, tuple] = {}

    # ------------------------- routing helpers ----------------------------
    def _repo(self, job: str):
        try:
            return self.hub.get(job)
        except KeyError:
            raise UnknownJobError(job) from None

    #: bound on cached per-(job, seed) services (LRU eviction)
    MAX_SERVICES = 64

    def _service(self, job: str,
                 seed: Optional[int] = None) -> ConfigurationService:
        from repro.core.models.api import get_model
        seed = self.seed if seed is None else int(seed)
        repo = self._repo(job)
        version = repo.store.version
        trust_version = repo.store.trust_version
        # key on the spec OBJECTS like predictor_for: a re-registered or
        # newly added custom model must invalidate the cached service
        specs = tuple(get_model(n) for n in repo.model_names)
        entry = self._services.get((job, seed))
        if entry is None or entry[0] != version \
                or entry[1] != trust_version or entry[2] != specs:
            svc = ConfigurationService.from_repo(
                repo, None, self.prices, self.scaleouts, seed=seed,
                confidence=self.confidence, market=self.market)
            self._services[(job, seed)] = entry = (version, trust_version,
                                                   specs, svc)
            while len(self._services) > self.MAX_SERVICES:
                self._services.popitem(last=False)
        self._services.move_to_end((job, seed))
        return entry[3]

    def _evict_superseded(self, job: str) -> int:
        """Drop cached services for ``job`` keyed on a dead store state.

        The per-(job, seed) LRU would otherwise strand one entry per seed
        across a store-version discontinuity (an accepted contribution,
        and especially an epoch transition, which no future request can
        ever revalidate against) until cap pressure pushes them out —
        N compactions must not grow the cache.  Returns how many entries
        were evicted."""
        repo = self._repo(job)
        version = repo.store.version
        trust_version = repo.store.trust_version
        dead = [k for k, e in self._services.items()
                if k[0] == job and (e[0] != version or e[1] != trust_version)]
        for k in dead:
            del self._services[k]
        return len(dead)

    def _rows(self, repo, X, y=None) -> np.ndarray:
        """Validated [n, d] feature block for ``repo``'s schema."""
        X = np.asarray(X, np.float64)
        if X.ndim != 2 or X.shape[1] != repo.schema.n_features:
            raise ValueError(
                f"expected [n, {repo.schema.n_features}] feature rows "
                f"(scale-out first) for job {repo.job!r}, got shape "
                f"{X.shape}")
        if y is not None and len(np.asarray(y)) != len(X):
            raise ValueError(f"{len(X)} feature rows but "
                             f"{len(np.asarray(y))} runtimes")
        return X

    def _machine(self, repo, machine_type: str,
                 job: Optional[str] = None) -> str:
        """Vocabulary check; ``job`` labels errors with the REQUESTED job
        when ``repo`` is a transfer donor answering for it."""
        if machine_type not in repo.store.data.machines:
            raise ValueError(
                f"job {job if job is not None else repo.job!r} has no "
                f"shared runtime data for machine type {machine_type!r} "
                f"(known: {', '.join(repo.store.data.machines) or 'none'})")
        return machine_type

    #: fewest stored rows a machine type needs before the gateway will
    #: fit (and serve) a predictor for it — below this, fitting either
    #: raises (0 rows: the store vocabulary can outlive a machine's rows
    #: across subset/compaction) or yields an uncalibratable model
    MIN_FIT_ROWS = 2

    def _support(self, repo, machine_type: str,
                 job: Optional[str] = None) -> None:
        """Refuse fits the data cannot support with a typed, countable
        reason instead of letting them raise through ``_respond`` as
        ``internal`` (regression: ``tests/test_api_gateway.py``)."""
        rows = len(repo.store.data.machine_view(machine_type))
        if rows < self.MIN_FIT_ROWS:
            raise ValueError(
                f"insufficient_data: job "
                f"{job if job is not None else repo.job!r} has {rows} "
                f"stored row(s) for machine type {machine_type!r} "
                f"(needs >= {self.MIN_FIT_ROWS} to fit; store has "
                f"{len(repo.store)} row(s) total)")

    def _resolve(self, job: str, n_features: Optional[int] = None):
        """Serving repo for ``job``: ``(repo, transfer_source, confidence)``.

        Without a transfer policy this is exactly ``_repo``.  With one, an
        unknown job — or a published job whose store is below the policy's
        ``min_rows`` — borrows the nearest donor's repo: the returned
        ``transfer_source``/``confidence`` are stamped on the result
        envelope.  ``n_features`` (when the request's payload shape gives
        one) restricts donors to schema-compatible jobs.  An unknown job
        with no usable donor still raises ``UnknownJobError``."""
        try:
            repo = self._repo(job)
        except UnknownJobError:
            if self.transfer is None:
                raise
            match = self.hub.transfer_index(self.transfer).nearest(
                job, n_features)
            if match is None:
                raise
            return self._repo(match.source), match.source, match.confidence
        if self.transfer is not None \
                and len(repo.store) < self.transfer.min_rows:
            match = self.hub.transfer_index(self.transfer).nearest(
                job, repo.schema.n_features)
            if match is not None:
                return (self._repo(match.source), match.source,
                        match.confidence)
        return repo, "", 1.0

    # ------------------------- trust admission ----------------------------
    def _admit(self, request, expect=None):
        """Unwrap + authenticate one request BEFORE it touches any repo.

        Returns ``(inner_request, contributor_id, error_response)``.  On
        admission ``error_response`` is None and ``contributor_id`` is the
        token's identity (None on an unauthenticated gateway).  Refusals
        come back as typed ``unauthorized`` / ``quota_exceeded`` error
        envelopes — admission never raises."""
        token = None
        inner = request
        if isinstance(inner, AuthedRequest):
            token = inner.token
            inner = inner.request
        cid = None
        if self.auth is not None:
            cid, code, detail = self.auth.admit(token)
            if cid is None:
                return inner, None, Response.failure(code, detail)
        if expect is not None and not isinstance(inner, expect):
            return inner, cid, Response.failure(
                ERR_BAD_REQUEST,
                f"expected a {expect.__name__}, got "
                f"{type(inner).__name__}")
        return inner, cid, None

    # ------------------------- operations ---------------------------------
    def predict(self, req) -> Response[PredictResult]:
        req, _, err = self._admit(req, PredictRequest)
        return err if err is not None else self._respond(self._predict, req)

    def _seed(self, seed: Optional[int]) -> int:
        """Request-level seed override; None means the gateway default."""
        return self.seed if seed is None else int(seed)

    def _predict(self, req: PredictRequest) -> PredictResult:
        X = np.asarray(req.X, np.float64)
        repo, source, conf = self._resolve(
            req.job, X.shape[1] if X.ndim == 2 else None)
        X = self._rows(repo, X)
        machine = self._machine(repo, req.machine_type, job=req.job)
        self._support(repo, machine, job=req.job)
        pred = repo.predictor_for(machine, seed=self._seed(req.seed))
        t = pred.predict(X)
        return PredictResult(tuple(float(v) for v in t), pred.selected,
                             float(pred.mu), float(pred.sigma),
                             source, conf)

    def predict_batch(self, job: str, machine_type: str,
                      seed: Optional[int], X) -> list:
        """Batched predict entry point for the per-(job, machine) lanes:
        one ``predictor.predict`` dispatch for a coalesced [C, d] block
        of SINGLE-ROW requests, answered as C per-row ``Response``
        envelopes.  Row i's envelope is byte-identical to what the
        inline path (``predict`` with a one-row ``PredictRequest``)
        would have returned — the models are row-independent, so
        batching changes wall-clock, never values (parity pinned in
        ``tests/test_edge.py``)."""
        X = np.asarray(X, np.float64)
        repo, source, conf = self._resolve(
            job, X.shape[1] if X.ndim == 2 else None)
        machine = self._machine(repo, machine_type, job=job)
        self._support(repo, machine, job=job)
        pred = repo.predictor_for(machine, seed=self._seed(seed))
        t = pred.predict(X)
        selected, mu, sigma = pred.selected, float(pred.mu), float(pred.sigma)
        return [Response.success(PredictResult((float(v),), selected, mu,
                                               sigma, source, conf))
                for v in t]

    def choose(self, req) -> Response[ChooseResult]:
        req, _, err = self._admit(req, ChooseRequest)
        return err if err is not None else self._respond(self._choose, req)

    def _choose(self, req: ChooseRequest) -> ChooseResult:
        ctx = np.asarray(req.context, np.float64).reshape(-1)
        repo, source, conf = self._resolve(req.job, len(ctx) + 1)
        if len(ctx) != repo.schema.n_features - 1:
            raise ValueError(
                f"context row has width {len(ctx)}, job {repo.job!r} "
                f"expects {repo.schema.n_features - 1}")
        if (req.zones is not None or req.purchase_options is not None) \
                and self.market is None:
            raise MarketError(
                "placement constraints (zones / purchase_options) require "
                "a market-enabled gateway: construct HubGateway with "
                "market=PriceBook(...)")
        # a borrowed answer runs the DONOR's configuration service (its
        # fitted predictors over the shared grid), keyed under the donor
        # so cold jobs share the donor's warm service state
        choice = self._service(source or req.job, req.seed) \
            .choose_cluster_batch(
                ctx[None, :], np.asarray([req.t_max], np.float64),
                zones=req.zones, options=req.purchase_options)[0]
        return ChooseResult.from_choice(choice, source, conf)

    def contribute(self, req) -> Response[ContributeResult]:
        req, cid, err = self._admit(req, ContributeRequest)
        if err is not None:
            return err
        if cid is not None and req.contributor_id != cid:
            # the TOKEN is the identity on an auth-enabled gateway: a
            # client cannot stamp rows (or reputations) onto someone else
            req = replace(req, contributor_id=cid)
        return self._respond(self._contribute, req)

    def _contribute(self, req: ContributeRequest) -> ContributeResult:
        repo = self._repo(req.job)
        X = self._rows(repo, req.X, req.y)
        if len(req.machine_type) != len(X):
            raise ValueError(f"{len(X)} feature rows but "
                             f"{len(req.machine_type)} machine types")
        # machine names / contributor ids that the TSV codec cannot
        # round-trip are rejected by the store itself (ValueError ->
        # bad_request envelope)
        rows = RuntimeData(repo.schema, np.asarray(req.machine_type), X,
                           np.asarray(req.y, np.float64))
        report = repo.contribute(rows, contributor=req.contributor_id)
        self._evict_superseded(req.job)   # judged: version/trust moved
        return ContributeResult(
            bool(report.accepted), float(report.baseline_mape),
            float(report.candidate_mape), report.reason, req.contributor_id,
            len(repo.store), repo.store.version, repo.store.fingerprint)

    def compact(self, req) -> Response[CompactResult]:
        """Store lifecycle admin op: epoch transition via coverage-aware
        reduction.  Auth-enabled gateways serve it to OPERATORS only —
        an admitted but non-operator identity gets a typed
        ``unauthorized`` envelope before any repo is touched."""
        req, cid, err = self._admit(req, CompactRequest)
        if err is not None:
            return err
        if self.auth is not None and not self.auth.is_operator(cid):
            return Response.failure(
                ERR_UNAUTHORIZED,
                f"store compaction is operator-only: contributor {cid!r} "
                "holds no operator standing (grant_operator)")
        return self._respond(self._compact, req)

    def _compact(self, req: CompactRequest) -> CompactResult:
        repo = self._repo(req.job)
        report = repo.store.compact(
            max_rows_per_cell=int(req.max_rows_per_cell),
            support_floor=int(req.support_floor),
            cell_rel_width=float(req.cell_rel_width),
            accuracy_budget=float(req.accuracy_budget),
            min_store_rows=int(req.min_store_rows),
            seed=self._seed(req.seed))
        if report.accepted:
            # the old epoch's store version is a dead key no request can
            # revalidate: evict eagerly instead of waiting for LRU pressure
            self._evict_superseded(req.job)
        return CompactResult(
            bool(report.accepted), report.code, report.reason,
            int(report.rows_before), int(report.rows_after),
            int(report.epoch), int(report.cells),
            float(report.baseline_mape), float(report.candidate_mape),
            repo.store.version, repo.store.fingerprint)

    def model_errors(self, req) -> Response[ModelErrorsResult]:
        req, _, err = self._admit(req, ModelErrorsRequest)
        return err if err is not None else self._respond(self._model_errors,
                                                         req)

    def _model_errors(self, req: ModelErrorsRequest) -> ModelErrorsResult:
        repo = self._repo(req.job)
        X = self._rows(repo, req.X, req.y)
        machine = self._machine(repo, req.machine_type)
        self._support(repo, machine)
        test = RuntimeData(repo.schema, np.full(len(X), machine), X,
                           np.asarray(req.y, np.float64))
        errs, selected = repo.model_errors(
            machine, test, track_models=req.track_models,
            seed=self._seed(req.seed))
        table = tuple((m, float(mape), float(mae))
                      for m, (mape, mae) in sorted(errs.items()))
        return ModelErrorsResult(table, selected)

    def search(self, req) -> Response[SearchResult]:
        req, _, err = self._admit(req, SearchRequest)
        return err if err is not None else self._respond(self._search, req)

    def _job_info(self, repo) -> JobInfo:
        """Per-(job, store version) cached metadata: contributor counts
        and machine lists are O(rows) scans that only change when a
        contribution is accepted — not per search request."""
        key = (repo.store.version, repo.store.epoch,
               tuple(repo.model_names))
        entry = self._jobinfo.get(repo.job)
        if entry is None or entry[0] != key:
            data = repo.store.data
            info = JobInfo(
                repo.job, repo.algorithm, len(data),
                data.present_machines(), key[2],
                tuple(sorted(data.contributor_counts().items())),
                epoch=repo.store.epoch,
                compactions=repo.store.compactions,
                rows_contributed=repo.store.rows_contributed)
            self._jobinfo[repo.job] = entry = (key, info)
        return entry[1]

    def _search(self, req: SearchRequest) -> SearchResult:
        return SearchResult(tuple(
            self._job_info(repo)
            for repo in sorted(self.hub.search(req.algorithm),
                               key=lambda r: r.job)))

    def contributor_stats(self, job: str) -> Response[Tuple[Tuple[str, int],
                                                            ...]]:
        """Per-contributor row counts for one job's shared store."""
        return self._respond(
            lambda j: tuple(sorted(
                self._repo(j).store.data.contributor_counts().items())), job)

    def trust_state(self, req) -> Response[TrustStateResult]:
        req, _, err = self._admit(req, TrustStateRequest)
        return err if err is not None else self._respond(self._trust_state,
                                                         req)

    def _trust_state(self, req: TrustStateRequest) -> TrustStateResult:
        cid = str(req.contributor_id)
        if self.auth is not None:
            known = self.auth.known(cid)
            banned = self.auth.is_banned(cid)
            quota = float(self.auth.quota_remaining(cid))
        else:
            known, banned, quota = False, False, UNMETERED
        reps = []
        for job in self.hub.jobs():
            trust = self.hub.get(job).store.trust
            if trust is not None and cid in trust:
                rec = trust.stats(cid)
                reps.append((job, float(trust.reputation(cid)),
                             int(rec.accepted), int(rec.rejected)))
        return TrustStateResult(cid, known, banned, quota, tuple(reps))

    # ------------------------- admin surface ------------------------------
    # Operator-side token management: these are direct method calls (not
    # wire requests) because whoever holds the gateway object IS the hub
    # operator.  They raise on an unauthenticated gateway — there is no
    # authority to manage.

    def _authority(self) -> TrustAuthority:
        if self.auth is None:
            raise RuntimeError(
                "gateway has no TrustAuthority: construct it with "
                "auth=TrustAuthority(...) to manage tokens")
        return self.auth

    def issue_token(self, contributor_id: str) -> str:
        return self._authority().issue_token(contributor_id)

    def revoke_token(self, token: str) -> bool:
        return self._authority().revoke_token(token)

    def ban_contributor(self, contributor_id: str) -> None:
        self._authority().ban(contributor_id)

    def unban_contributor(self, contributor_id: str) -> bool:
        return self._authority().unban(contributor_id)

    def grant_operator(self, contributor_id: str) -> None:
        self._authority().grant_operator(contributor_id)

    def revoke_operator(self, contributor_id: str) -> bool:
        return self._authority().revoke_operator(contributor_id)

    # ------------------------- uniform dispatch ---------------------------
    _HANDLERS = {
        PredictRequest: "predict", ChooseRequest: "choose",
        ContributeRequest: "contribute", ModelErrorsRequest: "model_errors",
        SearchRequest: "search", TrustStateRequest: "trust_state",
        CompactRequest: "compact",
    }

    def handle(self, request) -> Response:
        """Serve any API v1 request object (front-end dispatch point).
        ``AuthedRequest`` wrappers route on their INNER request; the
        wrapper itself travels on to the operation so admission sees the
        token."""
        inner = request.request if isinstance(request, AuthedRequest) \
            else request
        name = self._HANDLERS.get(type(inner))
        if name is None:
            return Response.failure(
                ERR_BAD_REQUEST,
                f"not an API v1 request: {type(inner).__name__}")
        return getattr(self, name)(request)

    def _respond(self, fn, req) -> Response:
        try:
            return Response.success(fn(req))
        except UnknownJobError as e:
            return Response.failure(ERR_UNKNOWN_JOB,
                                    f"no published repo for job {e.args[0]!r}")
        except (ValueError, TypeError, KeyError) as e:
            return Response.failure(ERR_BAD_REQUEST, str(e))
        except Exception as e:                       # noqa: BLE001
            return Response.failure(ERR_INTERNAL,
                                    f"{type(e).__name__}: {e}")


class AsyncHubGateway:
    """Per-job micro-batch lanes over a ``HubGateway``.

    Concurrent ``choose`` requests are enqueued on their job's
    ``BatchLane``; each lane answers everything pending per tick with one
    ``choose_cluster_batch`` engine dispatch, resolving the job's CURRENT
    service each tick so accepted contributions take effect without lane
    restarts.  Single-row ``predict`` requests ride their own lanes,
    keyed per (job, source job, machine type, seed, store version) — the
    source job is the transfer donor when the gateway is answering a cold
    job from borrowed models, so borrowed predictions batch correctly —
    and concurrent predicts coalesce into one ``predictor.predict``
    dispatch per tick;
    the store version rides in the key because an accepted contribution
    (or compaction) is a data discontinuity: post-bump requests open a
    fresh lane and the superseded one is evicted at creation.  Multi-row
    predicts and all other operations pass through to the sync gateway
    (they are not single-row dispatch-bound).

        async with AsyncHubGateway(gateway) as agw:
            resp = await agw.choose(ChooseRequest(job="grep", ...))
            resp = await agw.predict(PredictRequest(job="grep", ...))
    """

    #: bound on live lanes: the seed is client-supplied, and every lane
    #: owns a worker task — hostile seed churn must not grow them forever.
    #: Evicting a lane cancels whatever is still queued on it, so the cap
    #: only bites under seed-spraying traffic, never steady serving.
    MAX_LANES = 64

    def __init__(self, gateway: HubGateway, *, max_batch: int = 256,
                 tick_s: float = 0.0, timeout_s: Optional[float] = None):
        self.gateway = gateway
        self.max_batch = max_batch
        self.tick_s = tick_s
        # per-dispatch deadline forwarded to every lane: a tick that
        # exceeds it answers ITS requests with typed ``timeout`` error
        # envelopes while the lane worker keeps serving (None = no bound)
        self.timeout_s = timeout_s
        self._lanes: "OrderedDict[Tuple[str, int], BatchLane]" = OrderedDict()
        # predict lanes, keyed (job, machine_type, seed, store_version)
        self._predict_lanes: "OrderedDict[tuple, BatchLane]" = OrderedDict()
        # strong refs to in-flight eviction stop() tasks: the event loop
        # only holds tasks weakly, and a GC'd stop task would leak the
        # evicted lane's worker
        self._stopping: set = set()

    # ------------------------- lifecycle ----------------------------------
    async def __aenter__(self) -> "AsyncHubGateway":
        return self

    async def __aexit__(self, *exc) -> None:
        await self.stop()

    async def stop(self) -> None:
        lanes, self._lanes = self._lanes, OrderedDict()
        plane, self._predict_lanes = self._predict_lanes, OrderedDict()
        # dropped, not retained: a request after stop() would otherwise
        # enqueue onto a lane whose worker is gone and hang forever —
        # fresh lanes are created (and started) on the next choose().
        # In-flight eviction stops are awaited too, so shutdown leaves no
        # dangling worker
        await asyncio.gather(*(lane.stop() for lane in lanes.values()),
                             *(lane.stop() for lane in plane.values()),
                             *list(self._stopping))

    # ------------------------- lanes --------------------------------------
    def _lane(self, job: str, seed: Optional[int],
              n_features: Optional[int] = None) -> BatchLane:
        # one lane per (job, SOURCE job, seed): requests with different
        # seeds answer from different predictor states and must not share
        # a dispatch, and a cold job borrowing a donor dispatches on the
        # donor's service — the source rides in the key so a resolution
        # flip (the cold job's own store crossing min_rows) opens a fresh
        # lane instead of mislabeling batches.  Keyed on the TUPLE — a
        # job literally named "x#seed=1" must not collide with job "x" at
        # seed 1; the formatted name is display only (lane_stats)
        seed = self.gateway._seed(seed)
        repo, source, _ = self.gateway._resolve(job, n_features)
        key = (job, source or job, seed)
        lane = self._lanes.get(key)
        if lane is None:
            for k in [k for k in self._lanes
                      if k[0] == key[0] and k[2] == key[2] and k != key]:
                self._stop_lane(self._lanes.pop(k))   # stale resolution

            def dispatch(contexts, t_max, _job=job, _seed=seed):
                # resolve the service at dispatch time: a contribution
                # accepted between ticks rebuilds it (store-version keyed),
                # and the transfer resolution is re-checked so lane
                # envelopes match the sync path byte-for-byte.  The whole
                # tick's envelopes are built here in one tight loop —
                # per-request coroutines just hand the finished Response
                # through
                _, src, conf = self.gateway._resolve(
                    _job, contexts.shape[1] + 1)
                choices = self.gateway._service(
                    src or _job, _seed).choose_cluster_batch(contexts, t_max)
                return [Response.success(
                            ChooseResult.from_choice(c, src, conf))
                        for c in choices]

            lane = BatchLane(dispatch, width=repo.schema.n_features - 1,
                             max_batch=self.max_batch, tick_s=self.tick_s,
                             timeout_s=self.timeout_s)
            lane.start()
            self._lanes[key] = lane
            while len(self._lanes) > self.MAX_LANES:
                _, old = self._lanes.popitem(last=False)   # LRU lane
                self._stop_lane(old)
        self._lanes.move_to_end(key)
        return lane

    def _stop_lane(self, lane: BatchLane) -> None:
        """Detach a lane's worker asynchronously (strong-ref'd so the
        stop task cannot be GC'd mid-flight)."""
        task = asyncio.get_running_loop().create_task(lane.stop())
        self._stopping.add(task)
        task.add_done_callback(self._stopping.discard)

    def _predict_lane(self, job: str, machine_type: str,
                      seed: Optional[int],
                      n_features: Optional[int] = None) -> BatchLane:
        # one lane per (job, SOURCE job, machine, seed, STORE VERSION): a
        # predict dispatch binds one fitted predictor, and the SERVING
        # store's version is exactly its invalidation key — requests
        # racing an accepted contribution keep answering from the epoch
        # they arrived under, while post-bump requests open a fresh lane.
        # The source job rides in the key so borrowed predictions batch
        # on their donor's predictor and a resolution flip (cold job
        # graduating to its own models) opens a fresh lane
        seed = self.gateway._seed(seed)
        repo, source, _ = self.gateway._resolve(job, n_features)
        key = (job, source or job, machine_type, seed, repo.store.version)
        lane = self._predict_lanes.get(key)
        if lane is None:
            # the machine must be known AND fit-supported NOW:
            # enqueue-time refusal, so a typo (or a vocabulary machine
            # whose rows were compacted away) cannot open (and leak) a
            # lane that can never answer
            self.gateway._machine(repo, machine_type, job=job)
            self.gateway._support(repo, machine_type, job=job)
            for k in [k for k in self._predict_lanes
                      if k[0] == key[0] and k[2] == key[2]
                      and k[3] == key[3] and k != key]:
                self._stop_lane(self._predict_lanes.pop(k))  # superseded

            def dispatch(X, _t_max, _job=job, _machine=machine_type,
                         _seed=seed):
                # t_max is the lane's deadline slot — predicts carry none
                return self.gateway.predict_batch(_job, _machine, _seed, X)

            lane = BatchLane(dispatch, width=repo.schema.n_features,
                             max_batch=self.max_batch, tick_s=self.tick_s,
                             timeout_s=self.timeout_s)
            lane.start()
            self._predict_lanes[key] = lane
            while len(self._predict_lanes) > self.MAX_LANES:
                _, old = self._predict_lanes.popitem(last=False)
                self._stop_lane(old)
        self._predict_lanes.move_to_end(key)
        return lane

    @property
    def lane_stats(self) -> Dict[str, ServeStats]:
        """Stats per lane: choose lanes are named ``job``, predict lanes
        ``job@machine`` — both with a ``<-source`` suffix when a cold job
        is borrowing a donor's models and a ``#seed=N`` suffix off the
        default seed (display names; routing uses tuples).  Predict lanes
        for superseded store versions are already evicted, so one name
        maps to one live lane."""
        out = {}
        for (job, src, seed), lane in self._lanes.items():
            name = job if src == job else f"{job}<-{src}"
            if seed != self.gateway.seed:
                name = f"{name}#seed={seed}"
            out[name] = lane.stats
        for (job, src, machine, seed,
             _ver), lane in self._predict_lanes.items():
            name = f"{job}@{machine}"
            if src != job:
                name = f"{name}<-{src}"
            if seed != self.gateway.seed:
                name = f"{name}#seed={seed}"
            out[name] = lane.stats
        return out

    # ------------------------- request path -------------------------------
    async def predict(self, req) -> Response[PredictResult]:
        """Predict, micro-batched: single-row requests coalesce on their
        (job, machine, seed, store-version) lane into ONE
        ``predictor.predict`` dispatch per tick; multi-row requests are
        already a batch and dispatch inline (sync path, same envelope)."""
        req, _, err = self.gateway._admit(req, PredictRequest)
        if err is not None:
            return err
        try:
            if len(req.X) != 1:
                # already admitted: dispatch directly, not via the sync
                # entry point (re-admission would double-charge quota and
                # refuse the unwrapped request on an auth-enabled gateway)
                return self.gateway._respond(self.gateway._predict, req)
            row = req.X[0]
            lane = self._predict_lane(
                req.job, req.machine_type, req.seed,
                len(row) if hasattr(row, "__len__") else None)
            return await lane.submit(row, None)
        except UnknownJobError as e:
            return Response.failure(
                ERR_UNKNOWN_JOB, f"no published repo for job {e.args[0]!r}")
        except LaneTimeoutError as e:
            return Response.failure(ERR_TIMEOUT, str(e))
        except (ValueError, TypeError) as e:
            return Response.failure(ERR_BAD_REQUEST, str(e))
        except asyncio.CancelledError:
            raise
        except Exception as e:                       # noqa: BLE001
            return Response.failure(ERR_INTERNAL,
                                    f"{type(e).__name__}: {e}")

    async def choose(self, req) -> Response[ChooseResult]:
        # admission (auth + quota) happens HERE, before the request is
        # enqueued on any lane: a rate-limited contributor never occupies
        # micro-batch capacity
        req, _, err = self.gateway._admit(req, ChooseRequest)
        if err is not None:
            return err
        try:
            if req.zones is not None or req.purchase_options is not None:
                # placement-constrained choices cannot share a lane's
                # packed dispatch (a lane batches per (job, seed) with
                # ONE placement universe per tick) — dispatch inline,
                # already admitted, same envelope as the sync path.  A
                # bad constraint therefore answers a typed bad_request
                # without ever creating a lane.
                return self.gateway._respond(self.gateway._choose, req)
            ctx = req.context
            lane = self._lane(
                req.job, req.seed,
                len(ctx) + 1 if hasattr(ctx, "__len__") else None)
            # submit() canonicalizes the row; the lane dispatch already
            # wrapped the answer in a Response envelope
            return await lane.submit(ctx, req.t_max)
        except UnknownJobError as e:
            return Response.failure(
                ERR_UNKNOWN_JOB, f"no published repo for job {e.args[0]!r}")
        except LaneTimeoutError as e:
            return Response.failure(ERR_TIMEOUT, str(e))
        except (ValueError, TypeError) as e:
            # same classification as the sync path's _respond: a payload
            # the lane cannot parse is the CLIENT's error, not a fault
            return Response.failure(ERR_BAD_REQUEST, str(e))
        except asyncio.CancelledError:
            raise
        except Exception as e:                       # noqa: BLE001
            return Response.failure(ERR_INTERNAL,
                                    f"{type(e).__name__}: {e}")

    def handle(self, request) -> Response:
        """Synchronous pass-through for non-choose operations."""
        return self.gateway.handle(request)

    async def handle_async(self, request) -> Response:
        """Uniform async dispatch: choose and single-row predict
        requests ride the micro-batch lanes, everything else serves
        inline (AuthedRequest wrappers route on their inner request,
        like the sync ``handle``)."""
        inner = request.request if isinstance(request, AuthedRequest) \
            else request
        if isinstance(inner, ChooseRequest):
            return await self.choose(request)
        if isinstance(inner, PredictRequest):
            return await self.predict(request)
        return self.gateway.handle(request)
