"""Typed request/response envelopes for the Hub Gateway API v1.

Every message is a frozen dataclass built from JSON-serializable scalars
and (nested) tuples only — no numpy arrays, no live objects — so one
envelope value round-trips deterministically through ``repro.api.codec``
and works identically in-process and over a wire.  Conventions:

  * feature rows are tuples of floats with scale-out FIRST (the repo-wide
    feature layout, see ``repro.core.features``);
  * ``ChooseRequest.context`` is the context row WITHOUT scale-out — the
    gateway sweeps the (machine x scale-out) grid for it;
  * a NaN deadline means "no deadline" (the micro-batch lanes pack
    heterogeneous requests into one dispatch that way);
  * operation outcomes that are *answers* (e.g. a rejected contribution)
    travel as ``status="ok"`` results; ``status="error"`` is reserved for
    requests the gateway could not serve (unknown job, malformed payload,
    internal failure) and carries a machine-readable ``error_code``.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Generic, Optional, Tuple, TypeVar

API_VERSION = "v1"

#: machine-readable error codes carried by error envelopes
ERR_UNKNOWN_JOB = "unknown_job"
ERR_BAD_REQUEST = "bad_request"
ERR_INTERNAL = "internal"
#: trust plane: request carried no/invalid/revoked token, or the
#: contributor is banned (auth-enabled gateways only)
ERR_UNAUTHORIZED = "unauthorized"
#: trust plane: the contributor's token-bucket rate quota is exhausted
ERR_QUOTA_EXCEEDED = "quota_exceeded"
#: serving: the micro-batch lane's dispatch missed its per-tick deadline
ERR_TIMEOUT = "timeout"
#: serving edge: the front-end is draining for shutdown — in-flight
#: requests finish, new ones are refused with this typed envelope
ERR_SHUTTING_DOWN = "shutting_down"

T = TypeVar("T")


# ---------------------------------------------------------------------------
# requests
# ---------------------------------------------------------------------------

@dataclass(frozen=True, slots=True)
class PredictRequest:
    """Predict runtimes for explicit feature rows on one machine type."""
    job: str
    machine_type: str
    X: Tuple[Tuple[float, ...], ...]      # [n, d] rows, scale-out first
    seed: Optional[int] = None            # None = gateway's default seed


@dataclass(frozen=True, slots=True)
class ChooseRequest:
    """Best (machine type, scale-out) for one execution context.

    ``zones``/``purchase_options`` constrain market-aware placement on a
    market-enabled gateway (None — and absent on the wire — means
    unconstrained; an empty tuple or an unknown name is a typed
    ``bad_request``)."""
    job: str
    context: Tuple[float, ...]            # context row (no scale-out)
    t_max: float = math.nan               # deadline seconds; NaN = none
    seed: Optional[int] = None            # None = gateway's default seed
    zones: Optional[Tuple[str, ...]] = field(
        default=None, metadata={"omit_default": True})
    purchase_options: Optional[Tuple[str, ...]] = field(
        default=None, metadata={"omit_default": True})


@dataclass(frozen=True, slots=True)
class ContributeRequest:
    """Runtime measurements flowing back to the shared store (workflow
    step 6), stamped with the contributing collaborator's identity."""
    job: str
    machine_type: Tuple[str, ...]         # per-row machine names
    X: Tuple[Tuple[float, ...], ...]      # [n, d] rows, scale-out first
    y: Tuple[float, ...]                  # measured runtimes (seconds)
    contributor_id: str = "unknown"


@dataclass(frozen=True, slots=True)
class ModelErrorsRequest:
    """Held-out (MAPE, MAE) of tracked models + the C3O predictor on
    caller-supplied test rows for one machine type."""
    job: str
    machine_type: str
    X: Tuple[Tuple[float, ...], ...]
    y: Tuple[float, ...]
    track_models: Optional[Tuple[str, ...]] = None
    seed: Optional[int] = None            # None = gateway's default seed


@dataclass(frozen=True, slots=True)
class SearchRequest:
    """Discover published job repos by algorithm/job substring."""
    algorithm: str = ""


@dataclass(frozen=True, slots=True)
class TrustStateRequest:
    """Inspect one contributor's trust state (auth standing, remaining
    quota, per-job reputation) — the admin/inspection surface of the
    trust plane."""
    contributor_id: str


@dataclass(frozen=True, slots=True)
class CompactRequest:
    """Admin op: epoch transition via coverage-aware training-data
    reduction of one job's store (``RuntimeDataStore.compact``).

    On an auth-enabled gateway this is OPERATOR-ONLY: the wrapped
    identity must hold operator standing with the gateway's
    ``TrustAuthority`` — an ordinary contributor token is refused with
    ``unauthorized``.  A compaction the store declines (support floor,
    tiny store, accuracy budget, nothing to remove) is an ``ok`` envelope
    whose result carries ``code="compaction_rejected"`` — a verdict, not
    a transport failure."""
    job: str
    max_rows_per_cell: int = 4
    support_floor: int = 2
    cell_rel_width: float = 0.15
    accuracy_budget: float = 0.01
    min_store_rows: int = 64
    seed: Optional[int] = None            # None = gateway's default seed


@dataclass(frozen=True, slots=True)
class AuthedRequest:
    """Any API v1 request wrapped with a bearer token.

    On an auth-enabled gateway EVERY operation must arrive wrapped; the
    gateway authenticates the token, charges the contributor's rate
    quota, and serves the inner request under the authenticated identity
    (a wrapped ``ContributeRequest``'s ``contributor_id`` is overridden
    by the token's identity — clients cannot spoof provenance).  On an
    unauthenticated gateway (the default) the wrapper is transparently
    unwrapped, so clients can adopt tokens before their hub turns auth
    on."""
    token: str
    request: object                       # one of the request envelopes


# ---------------------------------------------------------------------------
# results
# ---------------------------------------------------------------------------

@dataclass(frozen=True, slots=True)
class PredictResult:
    runtimes_s: Tuple[float, ...]
    selected_model: str
    mu: float                             # CV error calibration (paper §IV-B)
    sigma: float
    # cold-start transfer provenance: when the gateway answered from a
    # donor job's fitted models (Flora-style cross-job transfer), which
    # job lent them and at what discounted confidence.  Omitted from the
    # wire for self-served answers (the overwhelmingly common case), so
    # pre-transfer payloads and goldens are byte-identical.
    transfer_source: str = field(default="",
                                 metadata={"omit_default": True})
    transfer_confidence: float = field(default=1.0,
                                       metadata={"omit_default": True})


@dataclass(frozen=True, slots=True)
class ChooseResult:
    """Wire form of ``repro.core.configurator.ClusterChoice``.

    ``transfer_source``/``transfer_confidence`` mark answers served from
    a donor job's models for a cold job (empty/1.0 — and absent on the
    wire — when the job answered for itself).

    Market-enabled gateways additionally stamp the placement the choice
    buys (``zone`` + ``purchase_option``) and the naive-vs-adjusted cost
    breakdown: ``cost_usd`` stays the naive listed-price cost while
    ``expected_cost_usd`` is the interruption-adjusted expected cost the
    selection actually ranked on.  All three default (and are absent on
    the wire) on static-price gateways, so pre-market payloads are
    byte-identical."""
    machine_type: str
    scale_out: int
    predicted_runtime_s: float
    runtime_bound_s: float
    cost_usd: float
    bottleneck: bool
    transfer_source: str = field(default="",
                                 metadata={"omit_default": True})
    transfer_confidence: float = field(default=1.0,
                                       metadata={"omit_default": True})
    zone: str = field(default="", metadata={"omit_default": True})
    purchase_option: str = field(default="",
                                 metadata={"omit_default": True})
    expected_cost_usd: float = field(default=0.0,
                                     metadata={"omit_default": True})

    @classmethod
    def from_choice(cls, choice, transfer_source: str = "",
                    transfer_confidence: float = 1.0) -> "ChooseResult":
        return cls(choice.machine_type, choice.scale_out,
                   choice.predicted_runtime_s, choice.runtime_bound_s,
                   choice.cost_usd, choice.bottleneck,
                   transfer_source, transfer_confidence,
                   getattr(choice, "zone", ""),
                   getattr(choice, "purchase_option", ""),
                   getattr(choice, "expected_cost_usd", 0.0))

    def to_choice(self):
        from repro.core.configurator import ClusterChoice
        return ClusterChoice(self.machine_type, self.scale_out,
                             self.predicted_runtime_s, self.runtime_bound_s,
                             self.cost_usd, self.bottleneck,
                             self.zone, self.purchase_option,
                             self.expected_cost_usd)


@dataclass(frozen=True, slots=True)
class ContributeResult:
    """Validation verdict (paper §III-C.b) plus post-ingest store state."""
    accepted: bool
    baseline_mape: float
    candidate_mape: float
    reason: str
    contributor_id: str
    store_rows: int
    store_version: int
    fingerprint: str


@dataclass(frozen=True, slots=True)
class CompactResult:
    """Compaction verdict plus post-attempt store lifecycle state.

    ``code`` is ``"compacted"`` or ``"compaction_rejected"``; on
    rejection the store is untouched (``store_version``/``fingerprint``
    still name the pre-attempt state and ``epoch`` did not advance)."""
    accepted: bool
    code: str
    reason: str
    rows_before: int
    rows_after: int
    epoch: int
    cells: int
    baseline_mape: float
    candidate_mape: float
    store_version: int
    fingerprint: str


@dataclass(frozen=True, slots=True)
class ModelErrorsResult:
    errors: Tuple[Tuple[str, float, float], ...]   # (model, mape, mae)
    selected_model: str


@dataclass(frozen=True, slots=True)
class JobInfo:
    """One search hit: repo metadata + provenance stats."""
    job: str
    algorithm: str
    rows: int
    machines: Tuple[str, ...]
    models: Tuple[str, ...]
    contributors: Tuple[Tuple[str, int], ...]      # (contributor, rows)
    # store lifecycle (defaults keep pre-epoch payloads decodable)
    epoch: int = 0
    compactions: int = 0
    rows_contributed: int = 0             # lifetime ingested (never shrinks)


@dataclass(frozen=True, slots=True)
class SearchResult:
    jobs: Tuple[JobInfo, ...]


@dataclass(frozen=True, slots=True)
class HealthResult:
    """``GET /healthz`` on the serving edge: liveness plus what the edge
    serves.  ``status`` is ``"ok"`` or ``"draining"`` (shutdown started;
    new work is being refused with ``shutting_down`` envelopes)."""
    status: str
    api_version: str
    jobs: Tuple[str, ...]


@dataclass(frozen=True, slots=True)
class LaneSnapshot:
    """One micro-batch lane's serving counters: dispatched requests,
    ticks, realized mean batch, and latency percentiles (milliseconds,
    enqueue-to-answer, from the lane's bounded reservoir; NaN until the
    lane has dispatched)."""
    lane: str
    requests: int
    batches: int
    mean_batch: float
    p50_ms: float
    p95_ms: float
    p99_ms: float


@dataclass(frozen=True, slots=True)
class StatsResult:
    """``GET /stats`` on the serving edge: HTTP-level request counters
    and latency percentiles (milliseconds, receive-to-response, bounded
    reservoir) plus one ``LaneSnapshot`` per live micro-batch lane —
    choose lanes are named ``job``, predict lanes ``job@machine`` (both
    with a ``#seed=N`` suffix off the default seed)."""
    requests: int
    errors: int
    in_flight: int
    draining: bool
    p50_ms: float
    p95_ms: float
    p99_ms: float
    lanes: Tuple[LaneSnapshot, ...]


@dataclass(frozen=True, slots=True)
class TrustStateResult:
    """One contributor's trust state across the gateway.

    ``reputations`` carries one ``(job, reputation, accepted, rejected)``
    row per job whose store ledger has judged this contributor;
    ``quota_remaining`` is +inf on an unauthenticated gateway (no quota
    accounting)."""
    contributor_id: str
    known: bool                           # has an issued (unrevoked) token
    banned: bool
    quota_remaining: float
    reputations: Tuple[Tuple[str, float, int, int], ...]


# ---------------------------------------------------------------------------
# the uniform envelope
# ---------------------------------------------------------------------------

@dataclass(frozen=True, slots=True)
class Response(Generic[T]):
    """Uniform response envelope: ``status`` is ``"ok"`` (``result`` holds
    the typed payload) or ``"error"`` (``error_code``/``detail`` say why;
    ``result`` is None)."""
    status: str
    result: Optional[T] = None
    error_code: str = ""
    detail: str = ""

    @property
    def ok(self) -> bool:
        return self.status == "ok"

    @classmethod
    def success(cls, result: T) -> "Response[T]":
        return cls("ok", result)

    @classmethod
    def failure(cls, error_code: str, detail: str) -> "Response[T]":
        return cls("error", None, error_code, detail)


REQUEST_TYPES = (PredictRequest, ChooseRequest, ContributeRequest,
                 ModelErrorsRequest, SearchRequest, TrustStateRequest,
                 CompactRequest, AuthedRequest)
RESULT_TYPES = (PredictResult, ChooseResult, ContributeResult,
                ModelErrorsResult, JobInfo, SearchResult, TrustStateResult,
                CompactResult, HealthResult, LaneSnapshot, StatsResult)
MESSAGE_TYPES = REQUEST_TYPES + RESULT_TYPES + (Response,)
