"""Architecture registry: importing this package registers all configs."""
from repro.configs import (deepseek_7b, gemma2_2b, gemma3_1b, internvl2_2b,
                           jamba_1_5_large, kimi_k2_1t_a32b, minicpm3_4b,
                           olmoe_1b_7b, rwkv6_3b, seamless_m4t_medium)
from repro.configs.base import (SHAPES, ModelConfig, ShapeConfig, get_config,
                                list_archs, supports_shape)
import dataclasses


def smoke_config(name: str, **extra) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests: same layer pattern and
    code paths, tiny dims, fp32, exactness-oracle impls."""
    cfg = get_config(name)
    period = cfg.pattern_period
    small = dict(
        n_layers=min(cfg.n_layers, period + cfg.n_tail_layers if cfg.n_tail_layers
                     else period),
        d_model=128,
        n_heads=4, n_kv_heads=min(cfg.n_kv_heads, 4) if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=32,
        d_ff=256, vocab_size=512,
        window_size=min(cfg.window_size, 16) if cfg.window_size else 0,
        dtype="float32", param_dtype="float32",
        attention_impl="reference", moe_impl="dense",
        remat="none", seq_shard_residual=False, grad_accum=1,
        optimizer="adamw",
    )
    if cfg.n_kv_heads == 1:
        small["n_kv_heads"] = 1
    if cfg.n_experts:
        small.update(n_experts=8, n_experts_active=2, moe_d_ff=64)
    if cfg.use_mla:
        small.update(q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=16,
                     qk_rope_dim=8, v_head_dim=16)
    if cfg.frontend != "none":
        small.update(frontend_dim=24)
    if cfg.n_encoder_layers:
        small.update(n_encoder_layers=2)
    if cfg.block_pattern and "rwkv" in cfg.block_pattern:
        small.update(rwkv_head_dim=32, d_model=128)  # 4 rwkv heads
    small.update(extra)
    return dataclasses.replace(cfg, **small)
