"""Model / workload configuration system.

Every assigned architecture is a ``ModelConfig`` registered under its public id
(``--arch <id>``).  Input shapes are ``ShapeConfig`` instances; the cross product
(arch x shape) defines the dry-run / roofline cells.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass

from typing import Callable, Dict, Tuple


# Layer kinds appearing in ``block_pattern`` (repeated cyclically over depth).
ATTN = "attn"            # full (global) attention
ATTN_LOCAL = "attn_local"  # sliding-window attention
MAMBA = "mamba"          # selective-scan SSM layer
RWKV = "rwkv"            # RWKV6 time-mix layer


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class ModelConfig:
    """Complete architecture description (decoder unless ``n_encoder_layers``>0)."""

    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // n_heads

    # --- layer pattern -------------------------------------------------
    block_pattern: Tuple[str, ...] = (ATTN,)
    window_size: int = 0             # sliding window for ATTN_LOCAL layers
    attn_logit_softcap: float = 0.0
    final_logit_softcap: float = 0.0
    rope_theta: float = 10_000.0

    # --- MLA (multi-head latent attention) -----------------------------
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # --- MoE ------------------------------------------------------------
    n_experts: int = 0
    n_experts_active: int = 0
    moe_d_ff: int = 0
    moe_period: int = 1              # layer i is MoE iff n_experts>0 and i % moe_period == moe_period-1
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # --- SSM ------------------------------------------------------------
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    mamba_dt_rank: int = 0           # 0 -> ceil(d_model / 16)
    rwkv_head_dim: int = 64

    # --- encoder/decoder + modality frontend ----------------------------
    n_encoder_layers: int = 0        # >0 => encoder-decoder
    frontend: str = "none"           # none | vit_stub | audio_stub
    frontend_dim: int = 0            # raw embedding dim produced by the stub frontend

    # --- numerics / perf knobs ------------------------------------------
    act: str = "swiglu"              # swiglu | gelu_mlp
    norm_eps: float = 1e-6
    post_norm: bool = False          # gemma2-style post-layer norms
    tie_embeddings: bool = True
    dtype: str = "bfloat16"          # activation dtype
    param_dtype: str = "bfloat16"
    remat: str = "full"              # none | full | dots
    scan_layers: bool = True
    seq_shard_residual: bool = False  # SP on the scan carry (giant archs)
    attention_impl: str = "reference"  # reference | blocked | blocked_tri
    moe_impl: str = "ep"             # ep (shard_map expert parallel) | dense
    optimizer: str = "adamw"         # adamw | adafactor
    grad_accum: int = 1              # microbatch count in the train step
    grad_accum_dtype: str = "float32"  # bfloat16 halves the accum buffer
    loss_chunk: int = 512            # seq-chunked cross-entropy (0 = full)
    fsdp: bool = True                # shard weights over (pod,data) axes
    pure_dp: bool = False            # small models: use ALL axes as data
                                     # parallelism (no TP), replicated weights
    kv_cache_dtype: str = ""         # "int8" = quantized serving KV cache

    # ---------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def padded_vocab_size(self) -> int:
        return _round_up(self.vocab_size, 256)

    @property
    def pattern_period(self) -> int:
        """Layers per scanned block: lcm(attention pattern, MoE period)."""
        p = len(self.block_pattern)
        if self.n_experts > 0:
            p = math.lcm(p, self.moe_period)
        return p

    @property
    def n_scan_blocks(self) -> int:
        return self.n_layers // self.pattern_period

    @property
    def n_tail_layers(self) -> int:
        return self.n_layers - self.n_scan_blocks * self.pattern_period

    def layer_kind(self, i: int) -> str:
        return self.block_pattern[i % len(self.block_pattern)]

    def is_moe_layer(self, i: int) -> bool:
        return self.n_experts > 0 and (i % self.moe_period) == self.moe_period - 1

    @property
    def mamba_d_inner(self) -> int:
        return self.mamba_expand * self.d_model

    @property
    def resolved_dt_rank(self) -> int:
        return self.mamba_dt_rank or -(-self.d_model // 16)

    # --- parameter counting (for roofline MODEL_FLOPS = 6*N*D) -----------
    def param_counts(self) -> Dict[str, int]:
        """Returns {"total": N, "active": N_active} (embedding included)."""
        d, hd = self.d_model, self.resolved_head_dim
        n_total = 0
        n_active = 0

        def attn_params() -> int:
            if self.use_mla:
                q = d * self.q_lora_rank + self.q_lora_rank * self.n_heads * (
                    self.qk_nope_dim + self.qk_rope_dim)
                kv = d * (self.kv_lora_rank + self.qk_rope_dim) + self.kv_lora_rank * (
                    self.n_heads * (self.qk_nope_dim + self.v_head_dim))
                o = self.n_heads * self.v_head_dim * d
                return q + kv + o
            return d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d

        def dense_ffn(ff: int) -> int:
            mult = 3 if self.act == "swiglu" else 2
            return mult * d * ff

        def mamba_params() -> int:
            din, n, dtr = self.mamba_d_inner, self.mamba_d_state, self.resolved_dt_rank
            return (d * 2 * din + din * self.mamba_d_conv + din * (dtr + 2 * n)
                    + dtr * din + din * n + din + din * d)

        def rwkv_params() -> int:
            # time-mix: r/k/v/g/o projections + decay & mix loras; channel-mix: k/v/r
            tm = 5 * d * d + d * 64 * 2 + d * 32 * 5 + 5 * 32 * d
            cm = d * self.d_ff + self.d_ff * d + d * d
            return tm + cm

        layers = self.n_layers + self.n_encoder_layers
        for i in range(layers):
            kind = self.layer_kind(i % max(self.n_layers, 1)) if i < self.n_layers else ATTN
            if kind in (ATTN, ATTN_LOCAL):
                n_total += attn_params(); n_active += attn_params()
            elif kind == MAMBA:
                n_total += mamba_params(); n_active += mamba_params()
            elif kind == RWKV:
                n_total += rwkv_params(); n_active += rwkv_params()
            if kind != RWKV:  # rwkv_params already includes channel-mix
                if self.is_moe_layer(i % max(self.n_layers, 1)) and i < self.n_layers:
                    mult = 3 if self.act == "swiglu" else 2
                    n_total += self.n_experts * mult * d * self.moe_d_ff + d * self.n_experts
                    n_active += self.n_experts_active * mult * d * self.moe_d_ff + d * self.n_experts
                else:
                    n_total += dense_ffn(self.d_ff); n_active += dense_ffn(self.d_ff)
        if self.n_encoder_layers > 0:       # decoder cross-attention blocks
            n_total += self.n_layers * attn_params()
            n_active += self.n_layers * attn_params()
        if self.frontend != "none":
            n_total += self.frontend_dim * d
            n_active += self.frontend_dim * d
        emb = self.padded_vocab_size * d * (1 if self.tie_embeddings else 2)
        n_total += emb; n_active += emb
        return {"total": n_total, "active": n_active}


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


# The four assigned LM shapes.
SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register(name: str):
    def deco(fn):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_config(name: str, **overrides) -> ModelConfig:
    if name not in _REGISTRY:
        from repro import configs  # noqa: F401  (trigger registration)
    cfg = _REGISTRY[name]()
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


def list_archs():
    from repro import configs  # noqa: F401
    return sorted(_REGISTRY)


def supports_shape(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """(runnable, reason-if-skipped) for an (arch x shape) cell."""
    if shape.name == "long_500k":
        sub_quadratic = any(k in (MAMBA, RWKV, ATTN_LOCAL) for k in cfg.block_pattern)
        if not sub_quadratic:
            return False, "pure full-attention arch: long_500k requires sub-quadratic attention"
        if cfg.n_encoder_layers > 0:
            return False, "encoder-decoder: 500k-token decode out of domain"
    return True, ""
