"""DeepSeek-7B: llama-architecture dense. [arXiv:2401.02954; hf]"""
from repro.configs.base import ATTN, ModelConfig, register


@register("deepseek-7b")
def deepseek_7b() -> ModelConfig:
    return ModelConfig(
        name="deepseek-7b", family="dense",
        n_layers=30, d_model=4096, n_heads=32, n_kv_heads=32,
        d_ff=11008, vocab_size=102400,
        block_pattern=(ATTN,),
        attention_impl="blocked",
        grad_accum=8,
    )
