"""Gemma2-2B: local/global alternating attention, logit softcaps, post-norms.
[arXiv:2408.00118; hf]"""
from repro.configs.base import ATTN, ATTN_LOCAL, ModelConfig, register


@register("gemma2-2b")
def gemma2_2b() -> ModelConfig:
    return ModelConfig(
        name="gemma2-2b", family="dense",
        n_layers=26, d_model=2304, n_heads=8, n_kv_heads=4, head_dim=256,
        d_ff=9216, vocab_size=256000,
        block_pattern=(ATTN_LOCAL, ATTN), window_size=4096,
        attn_logit_softcap=50.0, final_logit_softcap=30.0,
        post_norm=True, act="gelu_mlp",
        attention_impl="blocked",
        grad_accum=4,
    )
