"""Gemma3-1B: 5:1 local:global attention, window 512, 128k-capable rope.
[hf:google/gemma-3-1b-pt; unverified]"""
from repro.configs.base import ATTN, ATTN_LOCAL, ModelConfig, register


@register("gemma3-1b")
def gemma3_1b() -> ModelConfig:
    return ModelConfig(
        name="gemma3-1b", family="dense",
        n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1, head_dim=256,
        d_ff=6912, vocab_size=262144,
        block_pattern=(ATTN_LOCAL,) * 5 + (ATTN,), window_size=512,
        rope_theta=1_000_000.0, act="gelu_mlp",
        attention_impl="blocked",
        grad_accum=4,
    )
