"""InternVL2-2B: InternViT frontend (stub) + InternLM2-1.8B backbone.
[arXiv:2404.16821; hf]"""
from repro.configs.base import ATTN, ModelConfig, register


@register("internvl2-2b")
def internvl2_2b() -> ModelConfig:
    return ModelConfig(
        name="internvl2-2b", family="vlm",
        n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8,
        d_ff=8192, vocab_size=92553,
        block_pattern=(ATTN,),
        rope_theta=1_000_000.0,
        frontend="vit_stub", frontend_dim=1024,
        attention_impl="blocked",
        grad_accum=4,
    )
