"""Jamba-1.5-Large (398B): Mamba+attention 7:1 interleave, MoE 16e top-2.
[arXiv:2403.19887; hf]"""
from repro.configs.base import ATTN, MAMBA, ModelConfig, register


@register("jamba-1.5-large-398b")
def jamba() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b", family="hybrid",
        n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8,
        d_ff=24576, vocab_size=65536,
        block_pattern=(MAMBA, MAMBA, MAMBA, ATTN, MAMBA, MAMBA, MAMBA, MAMBA),
        n_experts=16, n_experts_active=2, moe_d_ff=24576, moe_period=2,
        optimizer="adafactor", seq_shard_residual=True,
        attention_impl="blocked", grad_accum=8, grad_accum_dtype="bfloat16",
    )
