"""Kimi K2: trillion-parameter MoE, 384 experts top-8 (paper-table config).
[arXiv:2501.kimi2; unverified]"""
from repro.configs.base import ATTN, ModelConfig, register


@register("kimi-k2-1t-a32b")
def kimi_k2() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-1t-a32b", family="moe",
        n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8,
        d_ff=2048, vocab_size=163840,
        block_pattern=(ATTN,),
        n_experts=384, n_experts_active=8, moe_d_ff=2048, moe_period=1,
        rope_theta=50_000.0,
        optimizer="adafactor", seq_shard_residual=True,
        attention_impl="blocked", grad_accum=8, grad_accum_dtype="bfloat16",
    )
