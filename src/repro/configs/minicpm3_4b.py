"""MiniCPM3-4B: dense with multi-head latent attention (MLA).
[hf:openbmb/MiniCPM3-4B; hf]"""
from repro.configs.base import ATTN, ModelConfig, register


@register("minicpm3-4b")
def minicpm3() -> ModelConfig:
    return ModelConfig(
        name="minicpm3-4b", family="dense",
        n_layers=62, d_model=2560, n_heads=40, n_kv_heads=40,
        d_ff=6400, vocab_size=73448,
        block_pattern=(ATTN,),
        use_mla=True, q_lora_rank=768, kv_lora_rank=256,
        qk_nope_dim=64, qk_rope_dim=32, v_head_dim=64,
        attention_impl="blocked",
        seq_shard_residual=True,
        grad_accum=8,
    )
