"""OLMoE-1B-7B: 64 experts top-8 MoE. [arXiv:2409.02060; hf]"""
from repro.configs.base import ATTN, ModelConfig, register


@register("olmoe-1b-7b")
def olmoe() -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b", family="moe",
        n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=1024, vocab_size=50304,
        block_pattern=(ATTN,),
        n_experts=64, n_experts_active=8, moe_d_ff=1024, moe_period=1,
        attention_impl="blocked",
        grad_accum=4,
    )
