"""RWKV6-3B "Finch": attention-free, data-dependent decay.
[arXiv:2404.05892; hf]"""
from repro.configs.base import RWKV, ModelConfig, register


@register("rwkv6-3b")
def rwkv6_3b() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-3b", family="ssm",
        n_layers=32, d_model=2560, n_heads=40, n_kv_heads=40,
        d_ff=8960, vocab_size=65536,
        block_pattern=(RWKV,), rwkv_head_dim=64,
        grad_accum=8,
    )
