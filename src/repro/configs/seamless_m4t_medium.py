"""SeamlessM4T-medium backbone: 12L encoder + 12L decoder, audio frontend stub.
[arXiv:2308.11596; hf]"""
from repro.configs.base import ATTN, ModelConfig, register


@register("seamless-m4t-medium")
def seamless() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-medium", family="audio",
        n_layers=12, n_encoder_layers=12,
        d_model=1024, n_heads=16, n_kv_heads=16,
        d_ff=4096, vocab_size=256206,
        block_pattern=(ATTN,),
        frontend="audio_stub", frontend_dim=80,
        attention_impl="blocked",
        grad_accum=4,
    )
