"""The paper's primary contribution: collaborative cluster-configuration
optimization — runtime prediction models, dynamic model selection, the
confidence-based configurator, and the shared-data machinery."""
from repro.core.configurator import (ClusterChoice, Configurator,
                                     choose_machine_type, confidence_margin)
from repro.core.datastore import RuntimeDataStore, ValidationReport
from repro.core.features import JobSchema, RuntimeData
from repro.core.hub import Hub, JobRepo
from repro.core.predictor import DEFAULT_MODELS, C3OPredictor, evaluate_split
from repro.core.service import ConfigurationService
