"""C3O cluster configurator (paper §IV).

Machine type first (job-dependent, scale-out-independent — maintainer choice
or cheapest-by-prediction fallback), then the scale-out:

    s_hat = min{ s in S | t_s + mu + sqrt(2)*erfinv(2c-1)*sigma <= t_max }

with (mu, sigma) the Gaussian error calibration from the predictor's
cross-validation residuals.  Configurations with an expected hardware
bottleneck (dataset missing cluster memory) are excluded unless nothing else
satisfies the deadline (paper §IV-B).  When no deadline is given, the user is
handed (scale-out, runtime, cost) pairs to choose from.

Candidate scoring goes through the prediction engine (repro.core.engine):
the whole (scale-out x context-batch) grid is evaluated in one predictor
call and choices are selected with vectorized numpy — ``choose_batch``
serves many contexts per dispatch, and ``choose_scaleout`` is its
single-context special case (choice-for-choice identical to the scalar
reference semantics).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np
from scipy.special import erfinv

from repro.core import engine
from repro.core.market import validate_prices
from repro.core.predictor import C3OPredictor


def confidence_margin(c: float, mu: float, sigma: float) -> float:
    """mu + sqrt(2) * erfinv(2c - 1) * sigma   (c=0.95 -> mu + 1.64485 sigma)."""
    return mu + float(erfinv(2.0 * c - 1.0)) * np.sqrt(2.0) * sigma


def validate_confidence(c: float) -> float:
    """Require 0 < c < 1: ``erfinv(2c-1)`` is ±inf at the endpoints, which
    would make every runtime bound infinite (c=1: every deadline silently
    unsatisfiable, falling through to the fastest-bound path)."""
    if not 0.0 < float(c) < 1.0:
        raise ValueError(
            f"confidence must lie in the open interval (0, 1), got {c!r}: "
            "the erfinv confidence bound is infinite at the endpoints")
    return float(c)


@dataclass(frozen=True)
class ClusterChoice:
    machine_type: str
    scale_out: int
    predicted_runtime_s: float
    runtime_bound_s: float          # runtime + confidence margin
    cost_usd: float                 # listed price * hours * nodes
    bottleneck: bool                # expected memory bottleneck at this s
    # market-aware selection (repro.core.market) stamps WHERE the cluster
    # is bought and what it is expected to really cost once interruption
    # risk is priced in; the static-price path leaves the defaults, so
    # pre-market construction sites (and wire encodings) are unchanged
    zone: str = ""                  # availability zone ("" = no market)
    purchase_option: str = ""       # "on_demand" / "spot" ("" = no market)
    expected_cost_usd: float = 0.0  # interruption-adjusted expected cost


@dataclass
class Configurator:
    predictor: C3OPredictor
    machine_type: str
    prices: Dict[str, float]                     # $ per node-hour
    scaleouts: Sequence[int]
    confidence: float = 0.95                     # paper default
    # optional bottleneck model: (context_row, scale_out) -> True if the
    # working set misses cluster memory at this scale-out
    bottleneck_fn: Optional[Callable[[np.ndarray, int], bool]] = None

    def __post_init__(self):
        validate_confidence(self.confidence)
        # fail at construction, not as a bare KeyError mid-score (and
        # never let a zero/negative price win cheapest-cost selection)
        validate_prices(self.prices, (self.machine_type,))

    # ------------------------- grid scoring -------------------------------
    def _score(self, contexts: np.ndarray):
        """(t, bound, cost, bottleneck) arrays, each [C, S]."""
        contexts = np.atleast_2d(np.asarray(contexts, np.float64))
        t, mu, sigma = engine.score_grid(self.predictor, self.scaleouts,
                                         contexts)
        # a model extrapolating to a negative runtime must not produce a
        # negative cost (which would win the cheapest-choice path)
        t = np.maximum(t, 0.0)
        margin = confidence_margin(self.confidence, mu, sigma)
        S = np.asarray(self.scaleouts, np.float64)
        bound = t + margin
        cost = self.prices[self.machine_type] * (t / 3600.0) * S[None, :]
        if self.bottleneck_fn is not None:
            bott = np.array([[bool(self.bottleneck_fn(ctx, int(s)))
                              for s in self.scaleouts] for ctx in contexts])
        else:
            bott = np.zeros(t.shape, bool)
        return t, bound, cost, bott

    def _choices(self, context_row: np.ndarray) -> List[ClusterChoice]:
        t, bound, cost, bott = self._score(context_row)
        return [ClusterChoice(self.machine_type, int(s), float(t[0, j]),
                              float(bound[0, j]), float(cost[0, j]),
                              bool(bott[0, j]))
                for j, s in enumerate(self.scaleouts)]

    # ------------------------- choice selection ---------------------------
    def choose_batch(self, contexts: np.ndarray,
                     t_max: Union[None, float, np.ndarray] = None
                     ) -> List[ClusterChoice]:
        """Per-context choices for a whole context batch in one dispatch.

        Selection semantics match ``choose_scaleout`` choice-for-choice:
        smallest clean scale-out meeting the deadline with confidence c,
        falling back to bottlenecked options, then to the fastest bound;
        without a deadline, the cheapest clean (else cheapest any) choice.
        ``t_max`` may be a scalar (shared deadline) or a [C] array.
        """
        contexts = np.atleast_2d(np.asarray(contexts, np.float64))
        t, bound, cost, bott = self._score(contexts)
        C = len(contexts)
        S = np.asarray(self.scaleouts, np.float64)[None, :]
        if t_max is None:
            clean_cost = np.where(bott, np.inf, cost)
            has_clean = np.isfinite(clean_cost).any(1)
            idx = np.where(has_clean, clean_cost.argmin(1), cost.argmin(1))
        else:
            tm = np.broadcast_to(np.asarray(t_max, np.float64), (C,))
            ok_any = bound <= tm[:, None]
            ok_clean = ok_any & ~bott
            idx = np.where(
                ok_clean.any(1), np.where(ok_clean, S, np.inf).argmin(1),
                np.where(ok_any.any(1), np.where(ok_any, S, np.inf).argmin(1),
                         bound.argmin(1)))
        return [ClusterChoice(self.machine_type, int(self.scaleouts[j]),
                              float(t[c, j]), float(bound[c, j]),
                              float(cost[c, j]), bool(bott[c, j]))
                for c, j in enumerate(idx)]

    def choose_scaleout(self, context_row: np.ndarray,
                        t_max: Optional[float] = None) -> ClusterChoice:
        """Smallest scale-out meeting the deadline with confidence c.

        Bottlenecked scale-outs are skipped unless no clean option meets the
        deadline; without a deadline, returns the cheapest clean choice."""
        return self.choose_batch(np.atleast_2d(context_row), t_max)[0]

    def runtime_cost_pairs(self, context_row: np.ndarray
                           ) -> List[Tuple[int, float, float]]:
        """(scale-out, predicted runtime, cost) menu (paper §IV-B end)."""
        return [(c.scale_out, c.predicted_runtime_s, c.cost_usd)
                for c in self._choices(context_row)]


def choose_machine_type(predictors: Dict[str, C3OPredictor],
                        prices: Dict[str, float],
                        scaleouts: Sequence[int],
                        context_row: np.ndarray) -> str:
    """Fallback machine-type selection (paper §IV-A): cheapest expected cost
    at each machine's best scale-out, using per-machine-type predictors.

    The full (machine x scale-out) grid is dispatched through the engine
    before the first host sync (one batched predict per machine)."""
    validate_prices(prices, predictors)
    names, _t, cost = engine.machine_grid_costs(predictors, prices,
                                                scaleouts, context_row)
    best = cost[:, 0, :].min(axis=1)            # [M] cheapest per machine
    return names[int(best.argmin())]            # ties: first in dict order
