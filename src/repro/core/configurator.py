"""C3O cluster configurator (paper §IV).

Machine type first (job-dependent, scale-out-independent — maintainer choice
or cheapest-by-prediction fallback), then the scale-out:

    s_hat = min{ s in S | t_s + mu + sqrt(2)*erfinv(2c-1)*sigma <= t_max }

with (mu, sigma) the Gaussian error calibration from the predictor's
cross-validation residuals.  Configurations with an expected hardware
bottleneck (dataset missing cluster memory) are excluded unless nothing else
satisfies the deadline (paper §IV-B).  When no deadline is given, the user is
handed (scale-out, runtime, cost) pairs to choose from.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np
from scipy.special import erfinv

from repro.core.predictor import C3OPredictor


def confidence_margin(c: float, mu: float, sigma: float) -> float:
    """mu + sqrt(2) * erfinv(2c - 1) * sigma   (c=0.95 -> mu + 1.64485 sigma)."""
    return mu + float(erfinv(2.0 * c - 1.0)) * np.sqrt(2.0) * sigma


@dataclass(frozen=True)
class ClusterChoice:
    machine_type: str
    scale_out: int
    predicted_runtime_s: float
    runtime_bound_s: float          # runtime + confidence margin
    cost_usd: float                 # price * hours * nodes
    bottleneck: bool                # expected memory bottleneck at this s


@dataclass
class Configurator:
    predictor: C3OPredictor
    machine_type: str
    prices: Dict[str, float]                     # $ per node-hour
    scaleouts: Sequence[int]
    confidence: float = 0.95                     # paper default
    # optional bottleneck model: (context_row, scale_out) -> True if the
    # working set misses cluster memory at this scale-out
    bottleneck_fn: Optional[Callable[[np.ndarray, int], bool]] = None

    def _choices(self, context_row: np.ndarray) -> List[ClusterChoice]:
        rows = np.stack([np.concatenate([[s], context_row])
                         for s in self.scaleouts])
        t, mu, sigma = self.predictor.predict_with_error(rows)
        margin = confidence_margin(self.confidence, mu, sigma)
        price = self.prices[self.machine_type]
        out = []
        for s, ts in zip(self.scaleouts, t):
            bott = bool(self.bottleneck_fn(context_row, int(s))) \
                if self.bottleneck_fn else False
            out.append(ClusterChoice(
                self.machine_type, int(s), float(ts), float(ts + margin),
                float(price * (ts / 3600.0) * s), bott))
        return out

    def choose_scaleout(self, context_row: np.ndarray,
                        t_max: Optional[float] = None) -> ClusterChoice:
        """Smallest scale-out meeting the deadline with confidence c.

        Bottlenecked scale-outs are skipped unless no clean option meets the
        deadline; without a deadline, returns the cheapest clean choice."""
        choices = self._choices(context_row)
        clean = [c for c in choices if not c.bottleneck]
        if t_max is None:
            pool = clean or choices
            return min(pool, key=lambda c: c.cost_usd)
        ok_clean = [c for c in clean if c.runtime_bound_s <= t_max]
        if ok_clean:
            return min(ok_clean, key=lambda c: c.scale_out)
        ok_any = [c for c in choices if c.runtime_bound_s <= t_max]
        if ok_any:
            return min(ok_any, key=lambda c: c.scale_out)
        # nothing meets the deadline: return the fastest bound
        return min(choices, key=lambda c: c.runtime_bound_s)

    def runtime_cost_pairs(self, context_row: np.ndarray
                           ) -> List[Tuple[int, float, float]]:
        """(scale-out, predicted runtime, cost) menu (paper §IV-B end)."""
        return [(c.scale_out, c.predicted_runtime_s, c.cost_usd)
                for c in self._choices(context_row)]


def choose_machine_type(predictors: Dict[str, C3OPredictor],
                        prices: Dict[str, float],
                        scaleouts: Sequence[int],
                        context_row: np.ndarray) -> str:
    """Fallback machine-type selection (paper §IV-A): cheapest expected cost
    at each machine's best scale-out, using per-machine-type predictors."""
    best_m, best_cost = None, np.inf
    for m, pred in predictors.items():
        rows = np.stack([np.concatenate([[s], context_row])
                         for s in scaleouts])
        t = pred.predict(rows)
        cost = np.min(prices[m] * (t / 3600.0) * np.asarray(scaleouts))
        if cost < best_cost:
            best_m, best_cost = m, float(cost)
    return best_m
