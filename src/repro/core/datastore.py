"""Collaborative runtime-data store with contribution validation (paper §III-C).

Runtime data lives as TSV alongside the job (one store per job repo), but
in memory the store is columnar (``repro.core.features.RuntimeData``) and
ingestion is incremental:

  * accepted contributions are *appended* into spare column capacity
    (amortized O(delta), no full-store copy);
  * the content fingerprint is a streaming SHA-256 over the canonical TSV
    byte stream, advanced per accepted delta — byte-for-byte identical to
    hashing the full TSV export, with no O(N) re-encode per contribution;
  * validation (§III-C.b) routes through the prediction engine's cached
    fit executables (``engine.holdout_mape``) instead of constructing a
    fresh CV predictor per machine group.

``contribute`` implements §III-C.b: retrain the model pool with the
candidate rows included and evaluate on a held-out test set of *previously
existing* points; reject the contribution if the error increases
significantly (corrupted or fabricated data would poison every
collaborator's models).
"""
from __future__ import annotations

import hashlib
import os
from dataclasses import dataclass
from typing import Optional, Sequence

import numpy as np

from repro.core.features import (UNKNOWN_CONTRIBUTOR, JobSchema, RuntimeData)
from repro.core.trust import ReputationLedger


@dataclass
class ValidationReport:
    accepted: bool
    baseline_mape: float
    candidate_mape: float
    reason: str = ""


def _waterfill(parts: Sequence[np.ndarray], cap: int) -> np.ndarray:
    """Concatenate prefix samples of ``parts`` under a total row cap.

    Water-filling allocation: groups are visited smallest-first and each
    receives ``min(len(group), remaining_cap // remaining_groups)`` rows, so
    small (rare-machine) groups keep ALL their rows while large groups share
    whatever budget is left.  Each part is pre-permuted by the caller, so a
    prefix is a uniform subsample of that group."""
    out = []
    cap = int(cap)
    for i, p in enumerate(sorted(parts, key=len)):
        take = min(len(p), cap // (len(parts) - i))
        out.append(p[:take])
        cap -= take
    return (np.concatenate(out) if out
            else np.empty(0, np.int64))


class RuntimeDataStore:
    """One shared store per (job, repository)."""

    def __init__(self, data: RuntimeData, *, reject_ratio: float = 1.5,
                 reject_slack: float = 0.02, seed: int = 0,
                 model_names: Optional[Sequence[str]] = None,
                 max_validation_rows: int = 1024,
                 trust: Optional[ReputationLedger] = None):
        self.reject_ratio = reject_ratio
        self.reject_slack = reject_slack
        self.seed = seed
        self.model_names = model_names
        # optional reputation ledger (repro.core.trust): when present,
        # every judged contribution records an outcome against its
        # contributor, acceptance thresholds adapt to reputation, and
        # row_weights() derives per-row fit weights from it.  None (the
        # default) keeps the §III-C.b scheme byte-identical to the
        # trust-free store.
        self.trust = trust
        # validation retrains/tests on at most this many existing rows per
        # side: keeps the per-contribution cost flat as the collaborative
        # store grows (the optimistic models' group aux is O(n^2), so
        # unbounded validation would dominate ingestion at hub scale)
        self.max_validation_rows = max_validation_rows
        self._version = 0
        self.data = data          # property setter seeds the fingerprint

    @property
    def data(self) -> RuntimeData:
        return self._data

    @data.setter
    def data(self, value: RuntimeData) -> None:
        """Replacing the data wholesale re-seeds the streaming fingerprint
        from the new content (O(N), correct for arbitrary edits); the
        ``contribute`` fast path bypasses this and advances the existing
        chain with just its delta."""
        self._data = value
        self._hasher = hashlib.sha256(value.to_tsv().encode())

    def __len__(self):
        return len(self.data)

    @property
    def version(self) -> int:
        """Monotonic data version: bumps only when a contribution is
        accepted, so downstream fit caches (JobRepo.predictor_for) refit
        exactly when the data actually changed."""
        return self._version

    @property
    def fingerprint(self) -> str:
        """Content hash of the TSV encoding.  Unlike ``version`` (an
        in-process counter that restarts at 0), the fingerprint survives
        save/load round-trips, so persisted fit caches key on it to decide
        whether saved params still match the data on disk.

        Maintained as a chained digest: the hasher consumed the initial
        store's canonical TSV bytes once at construction and each accepted
        contribution's delta rows since.  Because SHA-256 is a stream hash,
        the chained value equals ``sha256(data.to_tsv())`` at every point —
        contribution boundaries leave no trace — while ``contribute`` pays
        O(delta), not O(N), to advance it."""
        return self._hasher.hexdigest()

    # ----------------------- trust plane ----------------------------------
    @property
    def trust_version(self) -> int:
        """Ledger version for downstream cache keys (-1 = no ledger).

        A REJECTED contribution never bumps ``version`` (no data changed)
        but does change its contributor's reputation — and therefore the
        reputation-derived row weights of rows ALREADY in the store at the
        next fit.  Fit/service caches must key on this alongside the data
        version."""
        return -1 if self.trust is None else self.trust.version

    def row_weights(self, view: RuntimeData) -> Optional[np.ndarray]:
        """Reputation-derived per-row fit weights for ``view`` (typically
        a cached ``machine_view`` of this store's data), or None when
        every row is at full weight — the None fast path keeps trust-free
        (and all-neutral) fits on the exact unweighted engine path."""
        if self.trust is None or len(view) == 0:
            return None
        vocab = view.contributors or (UNKNOWN_CONTRIBUTOR,)
        per = np.asarray([self.trust.row_weight(c) for c in vocab],
                         np.float64)
        if np.all(per >= 1.0 - 1e-12):
            return None
        if not view.contributors:        # pre-provenance store: all rows
            return np.full(len(view), per[0])
        return per[view.ccodes]

    def _reject_limit(self, baseline_mape: float,
                      threshold_scale: float = 1.0) -> float:
        """The §III-C.b acceptance limit, scaled by the contributor's
        reputation-derived strictness (scale < 1 = stricter)."""
        return (baseline_mape * self.reject_ratio + self.reject_slack) \
            * threshold_scale

    # ----------------------- persistence ---------------------------------
    def save(self, path: str) -> None:
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            f.write(self.data.to_tsv())
        os.replace(tmp, path)            # atomic, like checkpoints

    @classmethod
    def load(cls, path: str, schema: JobSchema, **kw) -> "RuntimeDataStore":
        with open(path) as f:
            return cls(RuntimeData.from_tsv(f.read(), schema), **kw)

    @staticmethod
    def _accountable_contributor(contribution: RuntimeData) -> str:
        """The identity a contribution's validation outcome is recorded
        against: its single contributor when provenance is unambiguous,
        else ``UNKNOWN_CONTRIBUTOR`` (mixed-provenance batches cannot pin
        blame on one collaborator; anonymous ones pool under the unknown
        identity, which down-weights unattributed data collectively if
        anonymous contributions keep failing)."""
        ids = sorted(contribution.contributor_counts())
        return ids[0] if len(ids) == 1 else UNKNOWN_CONTRIBUTOR

    # ----------------------- validation (§III-C.b) ------------------------
    def _model_specs(self):
        from repro.core.models.api import get_model
        from repro.core.predictor import DEFAULT_MODELS
        names = self.model_names or DEFAULT_MODELS
        return [get_model(n) for n in names]

    def _mape(self, train: RuntimeData, test: RuntimeData,
              machine: str) -> float:
        """Held-out MAPE of the best model in the pool for one machine type.

        All models fit through the engine's process-wide cached executables
        (one dispatch each, single sync) — no throwaway CV predictor is
        constructed per validation call.

        With a trust ledger the fit is REPUTATION-WEIGHTED (same weights
        the serving fits use): previously ingested suspect rows cannot
        balloon the baseline error — and with it the §III-C.b reject
        limit — so one accepted poison batch does not hold the door open
        for the next.  Validation measures the marginal damage a
        contribution would do under the weighting it would actually enter
        the store with."""
        from repro.core import engine
        tr = train.machine_view(machine)
        te = test.machine_view(machine)
        if len(tr) < 5 or len(te) < 2:
            return np.nan
        return engine.holdout_mape(self._model_specs(), tr.X, tr.y,
                                   te.X, te.y,
                                   row_weight=self.row_weights(tr))

    def _stratified_split(self, rng) -> tuple:
        """Stratified-by-machine (holdout, train) index split.

        Each machine-type group is permuted independently and split 20/80,
        then each side is capped at ``max_validation_rows`` by water-filling
        (see ``_waterfill``): rare machine types keep all of their rows
        while frequent ones share the remaining budget.  A uniform
        permutation of the whole store (the previous scheme) could starve a
        rare machine below the 2-holdout/5-train minimum ``_mape`` needs,
        silently waving its contributions through unvalidated."""
        data = self.data
        holds, trains = [], []
        for m in data.present_machines():
            g = data.machine_indices(m)
            g = g[rng.permutation(len(g))]
            k = min(max(2, len(g) // 5), len(g))
            holds.append(g[:k])
            trains.append(g[k:])
        return (_waterfill(holds, self.max_validation_rows),
                _waterfill(trains, self.max_validation_rows))

    def validate(self, contribution: RuntimeData,
                 machine: Optional[str] = None,
                 threshold_scale: float = 1.0) -> ValidationReport:
        """Validate EVERY machine type present in the contribution.

        A mixed contribution used to be judged only against its first row's
        machine type, so poisoned rows for any other machine type entered
        the store unvalidated.  Now each machine-type group must pass on its
        own partition of the held-out set; one failing group rejects the
        whole contribution.  Groups the store holds too little data to
        judge are accepted (the paper's scheme needs existing points to
        validate against — that is how a new machine type bootstraps) but
        named in the report reason so the bypass is visible.  ``machine``
        restricts validation to one explicit machine type (legacy
        single-machine call sites).  ``threshold_scale`` scales the reject
        limit (< 1 = stricter; the trust plane passes the contributor's
        reputation-derived strictness)."""
        if len(contribution) == 0:
            return ValidationReport(
                False, np.nan, np.nan,
                "empty contribution: no rows to validate or ingest")
        rng = np.random.default_rng(self.seed)
        machines = ([machine] if machine is not None
                    else list(contribution.present_machines()))
        hold, rest = self._stratified_split(rng)
        test = self.data.subset(hold)
        train = self.data.subset(rest)
        # the candidate set keeps the FULL contribution on top of the capped
        # train subset — poisoned rows must never be sampled away
        cand_data = train.append(contribution)
        worst: Optional[ValidationReport] = None
        unjudged = []
        for m in machines:
            base = self._mape(train, test, m)
            cand = self._mape(cand_data, test, m)
            if np.isnan(base) or np.isnan(cand):
                unjudged.append(str(m))  # too little data to judge this group
                continue
            limit = self._reject_limit(base, threshold_scale)
            if cand > limit:
                return ValidationReport(
                    False, base, cand,
                    f"machine {m}: error {cand:.3f} exceeds {limit:.3f} "
                    f"(baseline {base:.3f}) — contribution rejected")
            if worst is None or cand - base > \
                    worst.candidate_mape - worst.baseline_mape:
                worst = ValidationReport(True, base, cand, "accepted")
        note = (f"; unvalidated (insufficient data): {', '.join(unjudged)}"
                if unjudged else "")
        if worst is None:
            return ValidationReport(True, np.nan, np.nan,
                                    "insufficient data for validation")
        return ValidationReport(True, worst.baseline_mape,
                                worst.candidate_mape, worst.reason + note)

    def contribute(self, contribution: RuntimeData,
                   contributor: Optional[str] = None) -> ValidationReport:
        """Validate and (if accepted) ingest incrementally: columnar append
        into tail capacity plus an O(delta) fingerprint-chain advance — the
        stored rows are never re-encoded or re-hashed.

        ``contributor`` stamps every contributed row with one collaborator
        identity (gateway provenance); rows already carrying per-row
        provenance are ingested as-is when it is None.  The first known
        contributor transitions the store's canonical TSV encoding to the
        provenance format, which re-seeds the fingerprint chain from the
        full re-encoded content once (O(N)); before and after the
        transition the chain advances per delta as usual, so the
        fingerprint always equals ``sha256(data.to_tsv())`` — and a store
        that never sees provenance keeps byte-identical legacy
        fingerprints."""
        from repro.core.features import check_tsv_field
        # every ingest path (gateway, JobRepo, replay) funnels here: a
        # machine name or contributor id the TSV codec cannot round-trip
        # must never reach the persisted store — including per-row
        # provenance carried by the contribution itself (which bypasses
        # the constructors' own validation via from_columns)
        for m in contribution.machines:
            check_tsv_field(m, "machine type")
        for c in contribution.contributors:
            check_tsv_field(c, "contributor id")
        if contributor is not None:
            contribution = contribution.with_contributor(contributor)
        cid = self._accountable_contributor(contribution)
        scale = (1.0 if self.trust is None
                 else self.trust.threshold_scale(cid))
        report = self.validate(contribution, threshold_scale=scale)
        graced = False
        if (not report.accepted and self.trust is not None
                and len(contribution)
                and np.isfinite(report.baseline_mape)
                and np.isfinite(report.candidate_mape)
                and self.trust.allows_grace(cid)):
            # graceful degradation for contributors in high standing: a
            # near-miss is ingested anyway (their history says the data is
            # probably fine and the emulated validation split noisy) — but
            # only within GRACE_RATIO of the limit, and the zero-quality
            # outcome recorded below drains the reputation that earned the
            # grace, so repeated failures revert to hard rejection AND
            # down-weight the rows this grace let in
            limit = self._reject_limit(report.baseline_mape, scale)
            if report.candidate_mape <= limit * self.trust.GRACE_RATIO:
                graced = True
                report = ValidationReport(
                    True, report.baseline_mape, report.candidate_mape,
                    "accepted via graceful degradation (reputation "
                    f"{self.trust.reputation(cid):.2f}): {report.reason}")
        if (self.trust is not None and np.isfinite(report.baseline_mape)
                and np.isfinite(report.candidate_mape)):
            # judged contributions record an outcome (unjudged ones —
            # empty stores, bootstrap machine types — carry no evidence
            # about the contributor either way)
            quality = 0.0 if (graced or not report.accepted) else \
                self.trust.quality_of(
                    report.baseline_mape, report.candidate_mape,
                    self._reject_limit(report.baseline_mape, scale))
            self.trust.record_outcome(cid, report.accepted, quality)
        if report.accepted:
            was_provenance = self._data.has_provenance
            # bypass the data setter: the append only adds the delta rows,
            # so the chained hash advances in O(delta), not O(N)
            self._data = self._data.append(contribution)
            if not was_provenance and self._data.has_provenance:
                # encoding transition: every stored row gained the
                # contributor column, so the old chain's bytes no longer
                # prefix the canonical encoding — re-seed once
                self._hasher = hashlib.sha256(self._data.to_tsv().encode())
            else:
                # delta bytes in the STORE's format: a provenance-format
                # store encodes even an unknown-contributor delta with the
                # contributor column
                self._hasher.update(
                    contribution.tsv_delta_bytes(was_provenance))
            self._version += 1
        return report
