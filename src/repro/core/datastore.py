"""Collaborative runtime-data store with contribution validation (paper §III-C).

Runtime data lives as TSV alongside the job (one store per job repo).
``contribute`` implements §III-C.b: retrain the predictor with the candidate
rows included and evaluate on a held-out test set of *previously existing*
points; reject the contribution if the error increases significantly
(corrupted or fabricated data would poison every collaborator's models).
"""
from __future__ import annotations

import hashlib
import os
from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.core.features import JobSchema, RuntimeData
from repro.core.predictor import C3OPredictor


@dataclass
class ValidationReport:
    accepted: bool
    baseline_mape: float
    candidate_mape: float
    reason: str = ""


class RuntimeDataStore:
    """One shared store per (job, repository)."""

    def __init__(self, data: RuntimeData, *, reject_ratio: float = 1.5,
                 reject_slack: float = 0.02, seed: int = 0):
        self.data = data
        self.reject_ratio = reject_ratio
        self.reject_slack = reject_slack
        self.seed = seed
        self._version = 0

    def __len__(self):
        return len(self.data)

    @property
    def version(self) -> int:
        """Monotonic data version: bumps only when a contribution is
        accepted, so downstream fit caches (JobRepo.predictor_for) refit
        exactly when the data actually changed."""
        return self._version

    @property
    def fingerprint(self) -> str:
        """Content hash of the TSV encoding.  Unlike ``version`` (an
        in-process counter that restarts at 0), the fingerprint survives
        save/load round-trips, so persisted fit caches key on it to decide
        whether saved params still match the data on disk."""
        return hashlib.sha256(self.data.to_tsv().encode()).hexdigest()

    # ----------------------- persistence ---------------------------------
    def save(self, path: str) -> None:
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            f.write(self.data.to_tsv())
        os.replace(tmp, path)            # atomic, like checkpoints

    @classmethod
    def load(cls, path: str, schema: JobSchema, **kw) -> "RuntimeDataStore":
        with open(path) as f:
            return cls(RuntimeData.from_tsv(f.read(), schema), **kw)

    # ----------------------- validation (§III-C.b) ------------------------
    def _mape(self, train: RuntimeData, test: RuntimeData,
              machine: str) -> float:
        tr = train.filter_machine(machine)
        te = test.filter_machine(machine)
        if len(tr) < 5 or len(te) < 2:
            return np.nan
        pred = C3OPredictor(max_cv_folds=15, seed=self.seed).fit(tr.X, tr.y)
        p = np.nan_to_num(pred.predict(te.X), nan=1e12, posinf=1e12)
        return float(np.mean(np.abs(p - te.y) / np.maximum(te.y, 1e-9)))

    def validate(self, contribution: RuntimeData,
                 machine: Optional[str] = None) -> ValidationReport:
        """Validate EVERY machine type present in the contribution.

        A mixed contribution used to be judged only against its first row's
        machine type, so poisoned rows for any other machine type entered
        the store unvalidated.  Now each machine-type group must pass on its
        own partition of the held-out set; one failing group rejects the
        whole contribution.  Groups the store holds too little data to
        judge are accepted (the paper's scheme needs existing points to
        validate against — that is how a new machine type bootstraps) but
        named in the report reason so the bypass is visible.  ``machine``
        restricts validation to one explicit machine type (legacy
        single-machine call sites)."""
        rng = np.random.default_rng(self.seed)
        machines = ([machine] if machine is not None
                    else list(dict.fromkeys(contribution.machine_type)))
        n = len(self.data)
        idx = rng.permutation(n)
        hold = idx[: max(2, n // 5)]
        rest = idx[max(2, n // 5):]
        test = self.data.subset(hold)
        train = self.data.subset(rest)
        cand_data = train.concat(contribution)
        worst: Optional[ValidationReport] = None
        unjudged = []
        for m in machines:
            base = self._mape(train, test, m)
            cand = self._mape(cand_data, test, m)
            if np.isnan(base) or np.isnan(cand):
                unjudged.append(str(m))  # too little data to judge this group
                continue
            limit = base * self.reject_ratio + self.reject_slack
            if cand > limit:
                return ValidationReport(
                    False, base, cand,
                    f"machine {m}: error {cand:.3f} exceeds {limit:.3f} "
                    f"(baseline {base:.3f}) — contribution rejected")
            if worst is None or cand - base > \
                    worst.candidate_mape - worst.baseline_mape:
                worst = ValidationReport(True, base, cand, "accepted")
        note = (f"; unvalidated (insufficient data): {', '.join(unjudged)}"
                if unjudged else "")
        if worst is None:
            return ValidationReport(True, np.nan, np.nan,
                                    "insufficient data for validation")
        return ValidationReport(True, worst.baseline_mape,
                                worst.candidate_mape, worst.reason + note)

    def contribute(self, contribution: RuntimeData) -> ValidationReport:
        report = self.validate(contribution)
        if report.accepted:
            self.data = self.data.concat(contribution)
            self._version += 1
        return report
