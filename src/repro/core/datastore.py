"""Collaborative runtime-data store with contribution validation (paper §III-C).

Runtime data lives as TSV alongside the job (one store per job repo), but
in memory the store is columnar (``repro.core.features.RuntimeData``) and
ingestion is incremental:

  * accepted contributions are *appended* into spare column capacity
    (amortized O(delta), no full-store copy);
  * the content fingerprint is a streaming SHA-256 over the canonical TSV
    byte stream, advanced per accepted delta — byte-for-byte identical to
    hashing the full TSV export, with no O(N) re-encode per contribution;
  * validation (§III-C.b) routes through the prediction engine's cached
    fit executables (``engine.holdout_mape``) instead of constructing a
    fresh CV predictor per machine group.

``contribute`` implements §III-C.b: retrain the model pool with the
candidate rows included and evaluate on a held-out test set of *previously
existing* points; reject the contribution if the error increases
significantly (corrupted or fabricated data would poison every
collaborator's models).
"""
from __future__ import annotations

import hashlib
import os
from dataclasses import dataclass
from typing import Optional, Sequence

import numpy as np

from repro.core.features import (UNKNOWN_CONTRIBUTOR, JobSchema, RuntimeData)
from repro.core.trust import ReputationLedger


@dataclass
class ValidationReport:
    accepted: bool
    baseline_mape: float
    candidate_mape: float
    reason: str = ""


#: machine-readable compaction outcome codes (`CompactionReport.code`,
#: carried verbatim on the gateway's `CompactResult` envelope)
COMPACTED = "compacted"
COMPACTION_REJECTED = "compaction_rejected"


@dataclass
class CompactionReport:
    """Outcome of one ``RuntimeDataStore.compact`` attempt.

    A rejected attempt is a strict no-op: no rows move, no version bump,
    no fingerprint reseed — ``code`` says which, ``reason`` says why."""
    accepted: bool
    code: str                         # COMPACTED | COMPACTION_REJECTED
    reason: str
    rows_before: int
    rows_after: int
    epoch: int                        # store epoch AFTER the attempt
    cells: int = 0                    # occupied (machine, cell, scale) cells
    baseline_mape: float = float("nan")
    candidate_mape: float = float("nan")

    @property
    def retained_ratio(self) -> float:
        return self.rows_after / max(self.rows_before, 1)


def _gap_bins(col: np.ndarray, rel_width: float) -> np.ndarray:
    """Cluster one context feature by relative gaps between sorted values.

    Consecutive unique values split into separate cells where their gap
    exceeds ``rel_width`` RELATIVE to the larger magnitude — collaborators
    jitter the same canonical context cell multiplicatively, so their
    values never coincide exactly but sit within a narrow relative band
    that this clustering collapses into one shared coverage cell.

    Compaction stays idempotent under it: removing rows only widens the
    remaining consecutive gaps, and for ``rel_width <= 1`` a widened pair
    spanning an old split still satisfies the split criterion — cells can
    only ever SUBDIVIDE after a compaction, never merge, so every new cell
    is a subset of an old (already capped) one."""
    u, inv = np.unique(col, return_inverse=True)
    if len(u) <= 1:
        return np.zeros(len(col), np.int64)
    a, b = u[:-1], u[1:]
    split = (b - a) > rel_width * np.maximum(np.abs(a), np.abs(b))
    return np.concatenate(([0], np.cumsum(split)))[inv.reshape(-1)]


def _waterfill(parts: Sequence[np.ndarray], cap: int) -> np.ndarray:
    """Concatenate prefix samples of ``parts`` under a total row cap.

    Water-filling allocation: groups are visited smallest-first and each
    receives ``min(len(group), remaining_cap // remaining_groups)`` rows, so
    small (rare-machine) groups keep ALL their rows while large groups share
    whatever budget is left.  Each part is pre-permuted by the caller, so a
    prefix is a uniform subsample of that group."""
    out = []
    cap = int(cap)
    for i, p in enumerate(sorted(parts, key=len)):
        take = min(len(p), cap // (len(parts) - i))
        out.append(p[:take])
        cap -= take
    return (np.concatenate(out) if out
            else np.empty(0, np.int64))


class RuntimeDataStore:
    """One shared store per (job, repository)."""

    def __init__(self, data: RuntimeData, *, reject_ratio: float = 1.5,
                 reject_slack: float = 0.02, seed: int = 0,
                 model_names: Optional[Sequence[str]] = None,
                 max_validation_rows: int = 1024,
                 trust: Optional[ReputationLedger] = None):
        self.reject_ratio = reject_ratio
        self.reject_slack = reject_slack
        self.seed = seed
        self.model_names = model_names
        # optional reputation ledger (repro.core.trust): when present,
        # every judged contribution records an outcome against its
        # contributor, acceptance thresholds adapt to reputation, and
        # row_weights() derives per-row fit weights from it.  None (the
        # default) keeps the §III-C.b scheme byte-identical to the
        # trust-free store.
        self.trust = trust
        # validation retrains/tests on at most this many existing rows per
        # side: keeps the per-contribution cost flat as the collaborative
        # store grows (the optimistic models' group aux is O(n^2), so
        # unbounded validation would dominate ingestion at hub scale)
        self.max_validation_rows = max_validation_rows
        self._version = 0
        # epoch lifecycle: contributions append WITHIN the current epoch
        # (O(delta) fingerprint chain); compact() transitions to the next
        # epoch, re-seeding the chain once.  Pre-epoch TSV stores load as
        # epoch 0 with byte-identical fingerprints (nothing here touches
        # the on-disk format).
        self._epoch = 0
        self._compactions = 0
        self._rows_contributed = len(data)
        self.last_compaction: Optional[CompactionReport] = None
        self.data = data          # property setter seeds the fingerprint

    @property
    def data(self) -> RuntimeData:
        return self._data

    @data.setter
    def data(self, value: RuntimeData) -> None:
        """Replacing the data wholesale re-seeds the streaming fingerprint
        from the new content (O(N), correct for arbitrary edits); the
        ``contribute`` fast path bypasses this and advances the existing
        chain with just its delta."""
        self._data = value
        self._hasher = hashlib.sha256(value.to_tsv().encode())

    def __len__(self):
        return len(self.data)

    @property
    def version(self) -> int:
        """Monotonic data version: bumps only when a contribution is
        accepted, so downstream fit caches (JobRepo.predictor_for) refit
        exactly when the data actually changed."""
        return self._version

    @property
    def fingerprint(self) -> str:
        """Content hash of the TSV encoding.  Unlike ``version`` (an
        in-process counter that restarts at 0), the fingerprint survives
        save/load round-trips, so persisted fit caches key on it to decide
        whether saved params still match the data on disk.

        Maintained as a chained digest: the hasher consumed the initial
        store's canonical TSV bytes once at construction and each accepted
        contribution's delta rows since.  Because SHA-256 is a stream hash,
        the chained value equals ``sha256(data.to_tsv())`` at every point —
        contribution boundaries leave no trace — while ``contribute`` pays
        O(delta), not O(N), to advance it."""
        return self._hasher.hexdigest()

    # ----------------------- epoch lifecycle ------------------------------
    @property
    def epoch(self) -> int:
        """Compaction epoch: 0 for a freshly constructed/loaded store,
        +1 per accepted ``compact`` transition.  Appends never change it —
        the (version, epoch) pair distinguishes an epoch transition (both
        moved) from a plain append (version only)."""
        return self._epoch

    @property
    def compactions(self) -> int:
        """Accepted compactions over this store's in-process lifetime."""
        return self._compactions

    @property
    def rows_contributed(self) -> int:
        """Lifetime ingest counter: seed rows plus every accepted
        contribution's rows.  Compaction does NOT decrease it — the
        retained/contributed ratio is the compaction frontier's x-axis."""
        return self._rows_contributed

    def restore_epoch(self, epoch: int, compactions: int = 0) -> None:
        """Fast-forward epoch metadata recorded out-of-process (the fits
        sidecar stamps it next to the fingerprint): a reloaded store starts
        at epoch 0, and a sidecar whose fingerprint matched proves the TSV
        on disk IS that later epoch's content.  Only ever moves forward."""
        if epoch > self._epoch:
            self._epoch = int(epoch)
            self._compactions = max(self._compactions, int(compactions))

    # ----------------------- trust plane ----------------------------------
    @property
    def trust_version(self) -> int:
        """Ledger version for downstream cache keys (-1 = no ledger).

        A REJECTED contribution never bumps ``version`` (no data changed)
        but does change its contributor's reputation — and therefore the
        reputation-derived row weights of rows ALREADY in the store at the
        next fit.  Fit/service caches must key on this alongside the data
        version."""
        return -1 if self.trust is None else self.trust.version

    def row_weights(self, view: RuntimeData) -> Optional[np.ndarray]:
        """Reputation-derived per-row fit weights for ``view`` (typically
        a cached ``machine_view`` of this store's data), or None when
        every row is at full weight — the None fast path keeps trust-free
        (and all-neutral) fits on the exact unweighted engine path."""
        if self.trust is None or len(view) == 0:
            return None
        vocab = view.contributors or (UNKNOWN_CONTRIBUTOR,)
        per = np.asarray([self.trust.row_weight(c) for c in vocab],
                         np.float64)
        if np.all(per >= 1.0 - 1e-12):
            return None
        if not view.contributors:        # pre-provenance store: all rows
            return np.full(len(view), per[0])
        return per[view.ccodes]

    def _reject_limit(self, baseline_mape: float,
                      threshold_scale: float = 1.0) -> float:
        """The §III-C.b acceptance limit, scaled by the contributor's
        reputation-derived strictness (scale < 1 = stricter)."""
        return (baseline_mape * self.reject_ratio + self.reject_slack) \
            * threshold_scale

    # ----------------------- persistence ---------------------------------
    def save(self, path: str) -> None:
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            f.write(self.data.to_tsv())
        os.replace(tmp, path)            # atomic, like checkpoints

    @classmethod
    def load(cls, path: str, schema: JobSchema, **kw) -> "RuntimeDataStore":
        with open(path) as f:
            return cls(RuntimeData.from_tsv(f.read(), schema), **kw)

    @staticmethod
    def _accountable_contributor(contribution: RuntimeData) -> str:
        """The identity a contribution's validation outcome is recorded
        against: its single contributor when provenance is unambiguous,
        else ``UNKNOWN_CONTRIBUTOR`` (mixed-provenance batches cannot pin
        blame on one collaborator; anonymous ones pool under the unknown
        identity, which down-weights unattributed data collectively if
        anonymous contributions keep failing)."""
        ids = sorted(contribution.contributor_counts())
        return ids[0] if len(ids) == 1 else UNKNOWN_CONTRIBUTOR

    # ----------------------- validation (§III-C.b) ------------------------
    def _model_specs(self):
        from repro.core.models.api import get_model
        from repro.core.predictor import DEFAULT_MODELS
        names = self.model_names or DEFAULT_MODELS
        return [get_model(n) for n in names]

    def _mape(self, train: RuntimeData, test: RuntimeData,
              machine: str) -> float:
        """Held-out MAPE of the best model in the pool for one machine type.

        All models fit through the engine's process-wide cached executables
        (one dispatch each, single sync) — no throwaway CV predictor is
        constructed per validation call.

        With a trust ledger the fit is REPUTATION-WEIGHTED (same weights
        the serving fits use): previously ingested suspect rows cannot
        balloon the baseline error — and with it the §III-C.b reject
        limit — so one accepted poison batch does not hold the door open
        for the next.  Validation measures the marginal damage a
        contribution would do under the weighting it would actually enter
        the store with."""
        from repro.core import engine
        tr = train.machine_view(machine)
        te = test.machine_view(machine)
        if len(tr) < 5 or len(te) < 2:
            return np.nan
        return engine.holdout_mape(self._model_specs(), tr.X, tr.y,
                                   te.X, te.y,
                                   row_weight=self.row_weights(tr))

    def _stratified_split(self, rng) -> tuple:
        """Stratified-by-machine (holdout, train) index split.

        Each machine-type group is permuted independently and split 20/80,
        then each side is capped at ``max_validation_rows`` by water-filling
        (see ``_waterfill``): rare machine types keep all of their rows
        while frequent ones share the remaining budget.  A uniform
        permutation of the whole store (the previous scheme) could starve a
        rare machine below the 2-holdout/5-train minimum ``_mape`` needs,
        silently waving its contributions through unvalidated."""
        data = self.data
        holds, trains = [], []
        for m in data.present_machines():
            g = data.machine_indices(m)
            g = g[rng.permutation(len(g))]
            k = min(max(2, len(g) // 5), len(g))
            holds.append(g[:k])
            trains.append(g[k:])
        return (_waterfill(holds, self.max_validation_rows),
                _waterfill(trains, self.max_validation_rows))

    def validate(self, contribution: RuntimeData,
                 machine: Optional[str] = None,
                 threshold_scale: float = 1.0) -> ValidationReport:
        """Validate EVERY machine type present in the contribution.

        A mixed contribution used to be judged only against its first row's
        machine type, so poisoned rows for any other machine type entered
        the store unvalidated.  Now each machine-type group must pass on its
        own partition of the held-out set; one failing group rejects the
        whole contribution.  Groups the store holds too little data to
        judge are accepted (the paper's scheme needs existing points to
        validate against — that is how a new machine type bootstraps) but
        named in the report reason so the bypass is visible.  ``machine``
        restricts validation to one explicit machine type (legacy
        single-machine call sites).  ``threshold_scale`` scales the reject
        limit (< 1 = stricter; the trust plane passes the contributor's
        reputation-derived strictness)."""
        if len(contribution) == 0:
            return ValidationReport(
                False, np.nan, np.nan,
                "empty contribution: no rows to validate or ingest")
        rng = np.random.default_rng(self.seed)
        machines = ([machine] if machine is not None
                    else list(contribution.present_machines()))
        hold, rest = self._stratified_split(rng)
        test = self.data.subset(hold)
        train = self.data.subset(rest)
        # the candidate set keeps the FULL contribution on top of the capped
        # train subset — poisoned rows must never be sampled away
        cand_data = train.append(contribution)
        worst: Optional[ValidationReport] = None
        unjudged = []
        for m in machines:
            base = self._mape(train, test, m)
            cand = self._mape(cand_data, test, m)
            if np.isnan(base) or np.isnan(cand):
                unjudged.append(str(m))  # too little data to judge this group
                continue
            limit = self._reject_limit(base, threshold_scale)
            if cand > limit:
                return ValidationReport(
                    False, base, cand,
                    f"machine {m}: error {cand:.3f} exceeds {limit:.3f} "
                    f"(baseline {base:.3f}) — contribution rejected")
            if worst is None or cand - base > \
                    worst.candidate_mape - worst.baseline_mape:
                worst = ValidationReport(True, base, cand, "accepted")
        note = (f"; unvalidated (insufficient data): {', '.join(unjudged)}"
                if unjudged else "")
        if worst is None:
            return ValidationReport(True, np.nan, np.nan,
                                    "insufficient data for validation")
        return ValidationReport(True, worst.baseline_mape,
                                worst.candidate_mape, worst.reason + note)

    def contribute(self, contribution: RuntimeData,
                   contributor: Optional[str] = None) -> ValidationReport:
        """Validate and (if accepted) ingest incrementally: columnar append
        into tail capacity plus an O(delta) fingerprint-chain advance — the
        stored rows are never re-encoded or re-hashed.

        ``contributor`` stamps every contributed row with one collaborator
        identity (gateway provenance); rows already carrying per-row
        provenance are ingested as-is when it is None.  The first known
        contributor transitions the store's canonical TSV encoding to the
        provenance format, which re-seeds the fingerprint chain from the
        full re-encoded content once (O(N)); before and after the
        transition the chain advances per delta as usual, so the
        fingerprint always equals ``sha256(data.to_tsv())`` — and a store
        that never sees provenance keeps byte-identical legacy
        fingerprints."""
        from repro.core.features import check_tsv_field
        # every ingest path (gateway, JobRepo, replay) funnels here: a
        # machine name or contributor id the TSV codec cannot round-trip
        # must never reach the persisted store — including per-row
        # provenance carried by the contribution itself (which bypasses
        # the constructors' own validation via from_columns)
        for m in contribution.machines:
            check_tsv_field(m, "machine type")
        for c in contribution.contributors:
            check_tsv_field(c, "contributor id")
        if contributor is not None:
            contribution = contribution.with_contributor(contributor)
        cid = self._accountable_contributor(contribution)
        scale = (1.0 if self.trust is None
                 else self.trust.threshold_scale(cid))
        report = self.validate(contribution, threshold_scale=scale)
        graced = False
        if (not report.accepted and self.trust is not None
                and len(contribution)
                and np.isfinite(report.baseline_mape)
                and np.isfinite(report.candidate_mape)
                and self.trust.allows_grace(cid)):
            # graceful degradation for contributors in high standing: a
            # near-miss is ingested anyway (their history says the data is
            # probably fine and the emulated validation split noisy) — but
            # only within GRACE_RATIO of the limit, and the zero-quality
            # outcome recorded below drains the reputation that earned the
            # grace, so repeated failures revert to hard rejection AND
            # down-weight the rows this grace let in
            limit = self._reject_limit(report.baseline_mape, scale)
            if report.candidate_mape <= limit * self.trust.GRACE_RATIO:
                graced = True
                report = ValidationReport(
                    True, report.baseline_mape, report.candidate_mape,
                    "accepted via graceful degradation (reputation "
                    f"{self.trust.reputation(cid):.2f}): {report.reason}")
        if (self.trust is not None and np.isfinite(report.baseline_mape)
                and np.isfinite(report.candidate_mape)):
            # judged contributions record an outcome (unjudged ones —
            # empty stores, bootstrap machine types — carry no evidence
            # about the contributor either way)
            quality = 0.0 if (graced or not report.accepted) else \
                self.trust.quality_of(
                    report.baseline_mape, report.candidate_mape,
                    self._reject_limit(report.baseline_mape, scale))
            self.trust.record_outcome(cid, report.accepted, quality)
        if report.accepted:
            was_provenance = self._data.has_provenance
            # bypass the data setter: the append only adds the delta rows,
            # so the chained hash advances in O(delta), not O(N)
            self._data = self._data.append(contribution)
            if not was_provenance and self._data.has_provenance:
                # encoding transition: every stored row gained the
                # contributor column, so the old chain's bytes no longer
                # prefix the canonical encoding — re-seed once
                self._hasher = hashlib.sha256(self._data.to_tsv().encode())
            else:
                # delta bytes in the STORE's format: a provenance-format
                # store encodes even an unknown-contributor delta with the
                # contributor column
                self._hasher.update(
                    contribution.tsv_delta_bytes(was_provenance))
            self._version += 1
            self._rows_contributed += len(contribution)
        return report

    # ----------------------- compaction (epoch transition) ----------------
    def _compaction_grid(self, cell_rel_width: float,
                         data: Optional[RuntimeData] = None) -> tuple:
        """Per-row (cell id, group id) over the coverage grid.

        A CELL is one (machine, context-cell, scale-out) triple — the unit
        the per-cell row cap applies to; a GROUP is its (machine,
        context-cell) projection across scale-outs — the unit the support
        floor protects.  Context cells come from ``_gap_bins`` so rows from
        different contributors collapse into shared coverage units."""
        data = self.data if data is None else data
        ctx = data.context
        parts = [data.codes.astype(np.float64)]
        parts += [_gap_bins(ctx[:, j], cell_rel_width).astype(np.float64)
                  for j in range(ctx.shape[1])]
        gkey = np.column_stack(parts)
        ckey = np.column_stack(parts + [data.scale_out.astype(np.float64)])
        _, grp = np.unique(gkey, axis=0, return_inverse=True)
        _, cell = np.unique(ckey, axis=0, return_inverse=True)
        return cell.reshape(-1), grp.reshape(-1)

    def _select_retained(self, cell: np.ndarray, grp: np.ndarray,
                         max_rows_per_cell: int, support_floor: int,
                         data: Optional[RuntimeData] = None) -> np.ndarray:
        """Boolean keep-mask: per-cell cap, reputation-first, spread-aware.

        Within each over-full cell, rows whose reputation row weight is
        strictly above the cell's k-th largest always stay; the remaining
        slots are filled from the weight-tied rows by greedy farthest-point
        (k-center) selection over the cell-normalized (context, runtime)
        space — a cell that swallowed a range of context values keeps rows
        covering ALL of its varying dimensions (and, for exact-duplicate
        configs, a spread of measured runtimes), not an arbitrary corner.
        A lone slot takes the cell medoid.  Fully deterministic: distance
        ties break on the lowest original row position (argmin/argmax).
        After capping, any (machine, context-cell) group below ``min(group
        size, support_floor)`` is topped back up with its best dropped
        rows."""
        data = self.data if data is None else data
        n = len(cell)
        w = self.row_weights(data)
        w = np.ones(n) if w is None else np.round(
            np.asarray(w, np.float64), 9)
        feats = np.column_stack([data.context, data.runtime])
        order = np.argsort(cell, kind="stable")
        cs = cell[order]
        bounds = np.r_[np.flatnonzero(np.r_[True, cs[1:] != cs[:-1]]), n]
        k = max_rows_per_cell
        keep = np.zeros(n, bool)
        for lo, hi in zip(bounds[:-1], bounds[1:]):
            rows = order[lo:hi]
            if hi - lo <= k:
                keep[rows] = True
                continue
            wc = w[rows]
            thr = np.partition(wc, hi - lo - k)[hi - lo - k]  # k-th largest
            above = wc > thr
            keep[rows[above]] = True
            need = k - int(above.sum())
            if need == 0:
                continue
            tied = rows[wc == thr]
            # per-cell min-max normalization; constant dims drop out
            f = feats[tied]
            span = f.max(axis=0) - f.min(axis=0)
            f = (f - f.min(axis=0)) / np.where(span > 0, span, 1.0)
            d = np.linalg.norm(f - f.mean(axis=0), axis=1)
            if need == 1:
                # medoid-like: the row closest to the cell centroid
                keep[tied[int(np.argmin(d))]] = True
                continue
            # seed with the row farthest off-center, then repeatedly add
            # the row farthest from everything chosen so far
            pick = int(np.argmax(d))
            keep[tied[pick]] = True
            dist = np.linalg.norm(f - f[pick], axis=1)
            for _ in range(need - 1):
                pick = int(np.argmax(dist))
                keep[tied[pick]] = True
                dist = np.minimum(dist,
                                  np.linalg.norm(f - f[pick], axis=1))
        # support floor: top up shorted groups with their best dropped rows
        n_grp = int(grp.max()) + 1
        deficit = np.maximum(
            support_floor - np.bincount(grp[keep], minlength=n_grp), 0)
        if deficit.any():
            prio = np.lexsort((np.arange(n), -w))
            ordpos = np.empty(n, np.int64)
            ordpos[prio] = np.arange(n)
            drop = np.where(~keep)[0]
            o3 = drop[np.lexsort((ordpos[drop], grp[drop]))]
            gstarts = np.searchsorted(grp[o3], np.arange(n_grp))
            grank = np.arange(len(o3)) - gstarts[grp[o3]]
            keep[o3[grank < deficit[grp[o3]]]] = True
        return keep

    def _compaction_gate(self, keep: np.ndarray, retained: np.ndarray,
                         accuracy_budget: float, rng,
                         max_rows_per_cell: int, support_floor: int,
                         cell_rel_width: float) -> tuple:
        """Engine-backed "accuracy holds" check for a compaction candidate.

        Collaborative stores are judged the way they are USED: leave one
        contributor out (up to three, drawn without replacement under the
        compaction seed), rerun the REDUCTION POLICY on the remaining
        rows, refit on them twice — full vs policy-reduced — and compare
        bucketed holdout MAPE per machine type on the held-out
        contributor's measurements, averaged across held contributors.
        Rerunning the selection per split matters: subtracting the held
        contributor from the full-store selection would strip exactly the
        coverage rows chosen near their contexts and misread coverage loss
        as policy damage.  A stratified row split would err the other way,
        testing same-context in-fill where losing near-duplicate
        neighbours reads as damage even when cross-contributor
        generalization — the serving task — is unharmed.  Provenance-free
        stores (no known contributors) fall back to testing on the DROPPED
        stratified-holdout rows, unseen by either side.

        Returns ``(reason, baseline_mape, candidate_mape)``; ``reason`` is
        ``None`` when every judged machine holds the budget, else the
        typed rollback message.  The reported pair is the judged machine
        with the worst degradation."""
        data = self.data

        def capped(idx: np.ndarray) -> np.ndarray:
            if len(idx) <= self.max_validation_rows:
                return idx
            codes = data.codes[idx]
            parts = [idx[codes == c][rng.permutation(
                int(np.sum(codes == c)))] for c in np.unique(codes)]
            return np.asarray(_waterfill(parts, self.max_validation_rows))

        def judge(splits) -> dict:
            per: dict = {}
            for test_idx, base_idx, cand_idx in splits:
                test = data.subset(np.sort(test_idx))
                base_d = data.subset(np.sort(capped(base_idx)))
                cand_d = data.subset(np.sort(capped(cand_idx)))
                for m in test.present_machines():
                    b = self._mape(base_d, test, m)
                    c = self._mape(cand_d, test, m)
                    if np.isnan(b) or np.isnan(c):
                        continue      # too little data to judge this group
                    per.setdefault(m, []).append((b, c))
            return {m: (float(np.mean([p[0] for p in v])),
                        float(np.mean([p[1] for p in v])))
                    for m, v in per.items()}

        splits = []
        ids = data.contributor
        uniq = np.unique(ids[ids != UNKNOWN_CONTRIBUTOR])
        if len(uniq) >= 3:            # leave-one-contributor-out gate
            held = rng.choice(uniq, size=min(3, len(uniq)), replace=False)
            for h in held:
                mask = ids == h
                test_idx = np.where(mask)[0]
                base_idx = np.where(~mask)[0]
                if not len(test_idx) or not len(base_idx):
                    continue
                view = data.subset(base_idx)
                vcell, vgrp = self._compaction_grid(cell_rel_width, view)
                vkeep = self._select_retained(vcell, vgrp, max_rows_per_cell,
                                              support_floor, view)
                splits.append((test_idx, base_idx, base_idx[vkeep]))
        per = judge(splits) if splits else {}
        if not per:                   # provenance-free (or unjudgeable)
            hold, rest = self._stratified_split(rng)
            hold_eff = hold[~keep[hold]]
            if len(hold_eff):
                per = judge([(hold_eff, np.asarray(rest), retained)])
        worst = (float("nan"), float("nan"))
        for m in sorted(per):
            b, c = per[m]
            if c > b + accuracy_budget:
                return (f"accuracy budget exceeded on machine {m}: "
                        f"candidate MAPE {c:.4f} > baseline {b:.4f} + "
                        f"budget {accuracy_budget:g} — rolled back", b, c)
            if np.isnan(worst[0]) or c - b > worst[1] - worst[0]:
                worst = (b, c)
        return None, worst[0], worst[1]

    def compact(self, *, max_rows_per_cell: int = 4, support_floor: int = 2,
                cell_rel_width: float = 0.15, accuracy_budget: float = 0.01,
                min_store_rows: int = 64,
                seed: Optional[int] = None) -> CompactionReport:
        """Epoch transition via coverage-aware training-data reduction.

        Downsamples the store over the (machine x context-cell x scale-out)
        grid: each occupied cell keeps at most ``max_rows_per_cell`` rows
        (highest reputation first), each (machine, context-cell) group
        keeps at least ``min(group size, support_floor)``.  The transition
        is gated on an engine-backed accuracy check: the candidate reduced
        training set must hold bucketed holdout MAPE within
        ``accuracy_budget`` (additive, percentage points as a fraction) of
        the pre-compaction baseline per machine type, else the attempt
        rolls back untouched.  ``accuracy_budget=inf`` skips the gate.

        Accepting re-seeds the fingerprint chain from the retained rows'
        canonical TSV once (the data-setter path, like the provenance
        transition) and bumps version AND epoch; a rejected attempt is a
        strict no-op with a typed ``compaction_rejected`` code."""
        if max_rows_per_cell < 1:
            raise ValueError("max_rows_per_cell must be >= 1")
        if support_floor < 0:
            raise ValueError("support_floor must be >= 0")
        if not 0 < cell_rel_width <= 1:
            # > 1 would let row removal erase a cell split (see _gap_bins),
            # breaking compaction idempotence
            raise ValueError("cell_rel_width must be in (0, 1]")
        n = len(self.data)

        def rejected(reason: str, b: float = float("nan"),
                     c: float = float("nan"),
                     cells: int = 0) -> CompactionReport:
            report = CompactionReport(False, COMPACTION_REJECTED, reason,
                                      n, n, self._epoch, cells=cells,
                                      baseline_mape=float(b),
                                      candidate_mape=float(c))
            self.last_compaction = report
            return report

        if n < max(min_store_rows, 1):
            return rejected(
                f"store too small to compact: {n} rows < "
                f"min_store_rows={max(min_store_rows, 1)}")
        cell, grp = self._compaction_grid(cell_rel_width)
        n_cells = int(cell.max()) + 1
        if support_floor > 0:
            counts = np.bincount(grp)
            short = int(np.sum(counts < support_floor))
            if short:
                return rejected(
                    f"{short} (machine, context-cell) group(s) hold fewer "
                    f"than support_floor={support_floor} rows: compacting "
                    "would drop them below the floor")
        rng = np.random.default_rng(self.seed if seed is None else seed)
        keep = self._select_retained(cell, grp, max_rows_per_cell,
                                     support_floor)
        rows_after = int(keep.sum())
        if rows_after >= n:
            return rejected(
                f"already compact at this resolution: every occupied cell "
                f"holds <= {max_rows_per_cell} row(s), nothing to remove")
        retained = np.where(keep)[0]      # ascending: original row order
        base_mape = cand_mape = np.nan
        if np.isfinite(accuracy_budget):
            reason, base_mape, cand_mape = self._compaction_gate(
                keep, retained, accuracy_budget, rng, max_rows_per_cell,
                support_floor, cell_rel_width)
            if reason is not None:
                return rejected(reason, base_mape, cand_mape, n_cells)
        self.data = self.data.subset(retained)   # setter re-seeds the chain
        self._version += 1
        self._epoch += 1
        self._compactions += 1
        report = CompactionReport(
            True, COMPACTED,
            f"compacted {n} -> {rows_after} rows over {n_cells} cells",
            n, rows_after, self._epoch, cells=n_cells,
            baseline_mape=float(base_mape), candidate_mape=float(cand_mape))
        self.last_compaction = report
        return report
