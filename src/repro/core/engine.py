"""Fused prediction engine: the single compiled entry point for fit, model
selection (LOO-CV), and candidate-grid scoring.

DESIGN
======
The C3O compute hot-spot is evaluating the runtime predictor over every
candidate configuration (machine types x scale-outs x contexts) and
re-predicting during leave-one-out model selection (paper §IV-§VI).  The seed
implementation paid three avoidable costs on that path:

  1. retracing — ``jax.jit(spec.fit)`` / ``jax.jit(spec.predict)`` built a
     *fresh* jit wrapper (with an empty executable cache) on every
     ``FittedModel`` construction and every ``predict`` call;
  2. host round-trips — model selection pulled each model's fold predictions
     to the host before the next model was even dispatched, serializing the
     device pipeline and computing MAPE/residual statistics in numpy;
  3. per-row Python loops — the configurator scored candidates one context at
     a time, and machine-type selection re-built the scale-out grid per
     machine.

This module removes all three.  Everything routes through process-wide
executable caches:

Cache keys
----------
``fit_executable(spec)`` / ``predict_executable(spec)`` / ``cv_executable(spec)``
    LRU-cached per ``ModelSpec`` (frozen dataclass: equality is
    (name, make_aux, fit, predict) identity).  Each cached wrapper is a
    single ``jax.jit`` object, so XLA keeps **one executable per
    (ModelSpec, input shape/dtype)** — repeated fits/predicts on the same
    data shape never retrace, across any number of ``FittedModel`` or
    ``C3OPredictor`` instances.

``val_executable(spec)``
    Fused fit + masked holdout (MAPE, MAE) for contribution validation
    (``RuntimeDataStore``) and the evaluation replay plane's per-model
    error trajectories (``holdout_errors``): inputs are zero-padded to
    power-of-two row buckets, so evaluating against a store that grows row
    by row keeps hitting the same compiled executable.

``cv_executable_sharded(spec, n_devices)``
    LOO-CV with the fold axis partitioned over a one-dimensional "cv" mesh
    (``shard_map``; fold-weight buffers donated off-CPU).  ``cv_select``
    routes here when the host has multiple devices (or ``C3O_CV_SHARD=on``)
    and falls back to the numerically-reference single-device path
    otherwise.

``_gbm_kernel_executable(interpret)``
    The Pallas boosted-ensemble inference kernel
    (``repro.kernels.gbm_predict``) jitted once per interpret mode.  Batched
    predictions of GBM-selected predictors route through it on TPU backends
    (``C3O_GBM_KERNEL=auto``, the default); set ``on``/``interpret``/``off``
    to force the kernel, the interpreted kernel (CPU correctness path), or
    the jnp scan fallback.

``JobRepo.predictor_for`` (see ``repro.core.hub``)
    fitted predictors cached per
    ``(machine_type, seed, datastore version, model list)`` — ``contribute``
    bumps the datastore version only when data is actually accepted, so hub
    traffic triggers a refit only when the data changed.

Fused multi-model CV
--------------------
``cv_select`` builds the fold-weight matrix ``W = 1 - onehot(folds)`` once,
dispatches every model's vmapped LOO refit+predict **and** its on-device
MAPE/residual reduction back-to-back (no host sync between models), then
performs a single host pull at the end.  The device pipeline therefore
overlaps model k's compute with model k+1's dispatch.

Grid-scored configuration
-------------------------
``score_grid`` evaluates a (scale-out x context-batch) grid in one predictor
call; ``machine_grid_costs`` stacks that over machine types, dispatching all
machines before the first sync.  ``Configurator.choose_batch`` turns the
scored grid into per-context choices with vectorized numpy selection —
semantics identical, choice-for-choice, to the scalar ``choose_scaleout``.
"""
from __future__ import annotations

import functools
import os
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.models.api import ModelSpec

# --------------------------------------------------------------------------
# Executable caches (one jit wrapper per ModelSpec; XLA then caches one
# executable per input shape under each wrapper).
# --------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def fit_executable(spec: ModelSpec):
    """Cached jitted ``spec.fit``: (X, y, w, aux) -> params."""
    return jax.jit(spec.fit)


@functools.lru_cache(maxsize=None)
def predict_executable(spec: ModelSpec):
    """Cached jitted ``spec.predict``: (params, X, aux) -> yhat."""
    return jax.jit(spec.predict)


@functools.lru_cache(maxsize=None)
def val_executable(spec: ModelSpec):
    """Cached jitted fused fit + holdout-error for one model.

    (X_tr, y_tr, w, X_te, y_te, valid, aux) -> (MAPE, MAE) on the valid
    rows of the held-out split; the contribution validator and the
    evaluation replay plane dispatch every pool model through this (one
    executable per spec, shared process-wide) instead of constructing a
    throwaway CV predictor per call.  ``w`` and ``valid`` are 0/1 masks so
    callers can pad both splits to bucketed shapes — XLA then keeps one
    executable per bucket, not one per exact store size.
    """

    def _val(X_tr, y_tr, w, X_te, y_te, valid, aux):
        params = spec.fit(X_tr, y_tr, w, aux)
        pred = spec.predict(params, X_te, aux)
        pred = jnp.nan_to_num(pred, nan=1e12, posinf=1e12, neginf=-1e12)
        err = jnp.abs(pred - y_te)
        cnt = jnp.maximum(valid.sum(), 1.0)
        ape = err / jnp.maximum(jnp.abs(y_te), 1e-9)
        return (ape * valid).sum() / cnt, (err * valid).sum() / cnt

    return jax.jit(_val)


def _bucket(n: int, lo: int = 32) -> int:
    """Next power-of-two shape bucket >= n (stable executables while the
    collaborative store grows row by row)."""
    b = lo
    while b < n:
        b *= 2
    return b


def bucket_rows(n: int, lo: int = 32) -> int:
    """Public row-bucketing policy (``C3OPredictor(pad_rows=True)`` and the
    evaluation replay plane pad training batches to this)."""
    return _bucket(n, lo)


def holdout_errors(specs: Sequence[ModelSpec], X_tr: np.ndarray,
                   y_tr: np.ndarray, X_te: np.ndarray,
                   y_te: np.ndarray,
                   row_weight: Optional[np.ndarray] = None
                   ) -> Dict[str, Tuple[float, float]]:
    """Held-out (MAPE, MAE) per model, one fused dispatch per model and a
    single host sync at the end — the batched primitive behind both
    contribution validation and the evaluation replay plane's per-model
    error trajectories.

    Inputs are zero-padded to power-of-two row buckets with 0-weight /
    invalid masks (every pool model fits weighted, so w=0 rows are inert):
    repeated evaluations against a growing store hit the SAME compiled
    executable instead of retracing per store size.

    ``row_weight`` (fractional, [n_tr]) scales each training row's weight
    in the fit — the trust plane's reputation-derived weights; None keeps
    every real row at 1.0 (the exact historical path).
    """
    X_tr64 = np.asarray(X_tr, np.float64)
    n_tr, n_te = len(y_tr), len(y_te)
    b_tr, b_te = _bucket(n_tr), _bucket(n_te)
    Xp = np.zeros((b_tr, X_tr64.shape[1]), np.float64)
    Xp[:n_tr] = X_tr64
    yp = np.ones(b_tr, np.float32)
    yp[:n_tr] = y_tr
    w = np.zeros(b_tr, np.float32)
    w[:n_tr] = 1.0 if row_weight is None else row_weight
    Xq = np.zeros((b_te, Xp.shape[1]), np.float64)
    Xq[:n_te] = np.asarray(X_te, np.float64)
    yq = np.ones(b_te, np.float32)
    yq[:n_te] = y_te
    valid = np.zeros(b_te, np.float32)
    valid[:n_te] = 1.0
    Xtr, ytr = jnp.asarray(Xp, jnp.float32), jnp.asarray(yp)
    Xte, yte = jnp.asarray(Xq, jnp.float32), jnp.asarray(yq)
    wj, vj = jnp.asarray(w), jnp.asarray(valid)
    pending = [(spec.name, val_executable(spec)(Xtr, ytr, wj, Xte, yte, vj,
                                                spec.make_aux(Xp)))
               for spec in specs]
    return {name: (float(mape), float(mae))
            for name, (mape, mae) in pending}              # single sync pass


def holdout_mape(specs: Sequence[ModelSpec], X_tr: np.ndarray,
                 y_tr: np.ndarray, X_te: np.ndarray,
                 y_te: np.ndarray,
                 row_weight: Optional[np.ndarray] = None) -> float:
    """Best (lowest) held-out MAPE over the model pool (§III-C.b
    contribution validation consumes exactly this scalar)."""
    errs = holdout_errors(specs, X_tr, y_tr, X_te, y_te,
                          row_weight=row_weight)
    return min(mape for mape, _ in errs.values())


@functools.lru_cache(maxsize=None)
def cv_executable(spec: ModelSpec):
    """Cached jitted fused LOO-CV for one model.

    (X, y, W, fold_idx, valid, aux) -> (mape, resid_mu, resid_sigma); all
    folds are one vmapped weighted refit and the MAPE/residual reductions
    happen on-device, so selection needs a single scalar pull per model.
    ``valid`` is a 0/1 mask over the fold axis: callers may pad the fold
    list (and, via 0-weight rows in ``W``, the data rows) to bucketed
    shapes so a store growing row by row keeps hitting one executable.
    """

    def _cv(X, y, W, fold_idx, valid, aux):
        def one_fold(w, i):
            params = spec.fit(X, y, w, aux)
            return spec.predict(params, X[i][None, :], aux)[0]

        pred = jax.vmap(one_fold)(W, fold_idx)
        pred = jnp.nan_to_num(pred, nan=1e12, posinf=1e12, neginf=-1e12)
        y_f = y[fold_idx]
        ape = jnp.abs(pred - y_f) / jnp.maximum(jnp.abs(y_f), 1e-9)
        resid = pred - y_f
        cnt = jnp.maximum(valid.sum(), 1.0)
        mape = (ape * valid).sum() / cnt
        mu = (resid * valid).sum() / cnt
        sigma = jnp.sqrt(jnp.maximum(
            (resid * resid * valid).sum() / cnt - mu * mu, 0.0))
        return mape, mu, sigma

    return jax.jit(_cv)


# --------------------------------------------------------------------------
# Device-sharded CV (fold axis partitioned over the mesh)
# --------------------------------------------------------------------------

@functools.lru_cache(maxsize=8)
def _cv_mesh(n_devices: int):
    """One-dimensional "cv" mesh over the first ``n_devices`` devices."""
    from jax.sharding import Mesh
    return Mesh(np.asarray(jax.devices()[:n_devices]), ("cv",))


@functools.lru_cache(maxsize=None)
def cv_executable_sharded(spec: ModelSpec, n_devices: int):
    """Cached jitted LOO-CV for one model, folds sharded over the mesh.

    The (model pool x folds) work grid is partitioned across devices: fold
    shards run data-parallel under ``shard_map`` (each device refits its
    slice of the fold-weight matrix) while the pool dimension pipelines
    dispatches exactly like the single-device path.  Inputs are the padded
    fold arrays (F_pad divisible by the device count) plus a 0/1 validity
    mask; MAPE/residual moments reduce via ``psum`` so every device holds
    the replicated scalars and the host pulls once per model.  The
    fold-weight buffer is donated — at F_pad x n floats it is the dominant
    allocation and is dead after the refits.
    """
    from jax.sharding import PartitionSpec as P

    from repro.distributed.sharding import shard_map

    mesh = _cv_mesh(n_devices)

    def _shard(X, y, W, fold_idx, valid, aux):
        # local shards: W [F_pad/dev, n], fold_idx/valid [F_pad/dev]
        def one_fold(w, i):
            params = spec.fit(X, y, w, aux)
            return spec.predict(params, X[i][None, :], aux)[0]

        pred = jax.vmap(one_fold)(W, fold_idx)
        pred = jnp.nan_to_num(pred, nan=1e12, posinf=1e12, neginf=-1e12)
        y_f = y[fold_idx]
        ape = jnp.abs(pred - y_f) / jnp.maximum(jnp.abs(y_f), 1e-9)
        resid = pred - y_f
        cnt = jax.lax.psum((valid).sum(), "cv")
        ape_s = jax.lax.psum((ape * valid).sum(), "cv")
        r_s = jax.lax.psum((resid * valid).sum(), "cv")
        r2_s = jax.lax.psum((resid * resid * valid).sum(), "cv")
        mape = ape_s / cnt
        mu = r_s / cnt
        sigma = jnp.sqrt(jnp.maximum(r2_s / cnt - mu * mu, 0.0))
        return mape, mu, sigma

    fn = shard_map(_shard, mesh=mesh,
                   in_specs=(P(), P(), P("cv"), P("cv"), P("cv"), P()),
                   out_specs=(P(), P(), P()), check_vma=False)
    # donating on CPU only triggers "donation not implemented" warnings
    donate = () if jax.default_backend() == "cpu" else (2,)
    return jax.jit(fn, donate_argnums=donate)


def _cv_shard_devices() -> int:
    """How many devices the sharded CV path should span (0 = stay on the
    single-device path).  ``C3O_CV_SHARD``: ``auto`` shards when the host
    has more than one device, ``on`` forces the shard_map path (even over a
    1-device mesh — the parity tests use this), ``off`` disables it."""
    mode = os.environ.get("C3O_CV_SHARD", "auto").lower()
    if mode == "off":
        return 0
    n = len(jax.devices())
    if mode == "on":
        return n
    return n if n > 1 else 0


def cache_stats() -> Dict[str, int]:
    """Executable-cache occupancy (introspection for tests/benchmarks)."""
    return {"fit": fit_executable.cache_info().currsize,
            "predict": predict_executable.cache_info().currsize,
            "cv": cv_executable.cache_info().currsize,
            "cv_sharded": cv_executable_sharded.cache_info().currsize,
            "val": val_executable.cache_info().currsize}


def cache_clear() -> None:
    """Drop all cached executables (tests emulate a fresh process with this:
    after a warm-start restore, a zero fit/cv occupancy proves no refit)."""
    fit_executable.cache_clear()
    predict_executable.cache_clear()
    cv_executable.cache_clear()
    cv_executable_sharded.cache_clear()
    val_executable.cache_clear()


# --------------------------------------------------------------------------
# Prediction dispatch (with Pallas GBM ensemble routing)
# --------------------------------------------------------------------------

@functools.lru_cache(maxsize=2)
def _gbm_kernel_executable(interpret: bool):
    from repro.kernels.gbm_predict import gbm_predict as pallas_gbm

    def run(X, feat, thr, leaf, f0, y_scale):
        raw = pallas_gbm(X, feat, thr, leaf, f0, 1.0, interpret=interpret)
        # same normalization contract as models.gbm.gbm_predict: y_scale==0
        # is the log-target sentinel
        return jnp.where(y_scale == 0.0,
                         jnp.exp(jnp.clip(raw, -30.0, 30.0)),
                         raw * jnp.maximum(y_scale, 1e-12))

    return jax.jit(run)


def _gbm_kernel_mode() -> str:
    mode = os.environ.get("C3O_GBM_KERNEL", "auto").lower()
    if mode == "auto":
        return "on" if jax.default_backend() == "tpu" else "off"
    return mode


def predict(spec: ModelSpec, params, X, aux) -> jnp.ndarray:
    """Batched prediction through the cached executable for ``spec``.

    GBM predictors route through the Pallas ensemble kernel when enabled
    (TPU backend, or ``C3O_GBM_KERNEL`` in {on, interpret}); everything else
    (and the CPU default) uses the cached jnp executable.
    """
    Xj = jnp.asarray(X, jnp.float32)
    from repro.core.models.gbm import GBM_SPEC
    if spec is GBM_SPEC:        # identity, not name: a maintainer model
        mode = _gbm_kernel_mode()   # re-registered as "gbm" has foreign params
        if mode in ("on", "interpret"):
            return _gbm_kernel_executable(mode == "interpret")(
                Xj, params.feat, params.thr, params.leaf, params.f0,
                params.y_scale)
    return predict_executable(spec)(params, Xj, aux)


# --------------------------------------------------------------------------
# Fused multi-model cross-validation / selection
# --------------------------------------------------------------------------

def cv_select(specs: Sequence[ModelSpec], X: np.ndarray, y: np.ndarray,
              folds: np.ndarray, *, sharded: Optional[bool] = None,
              row_weight: Optional[np.ndarray] = None
              ) -> Tuple[str, Dict[str, float], float, float]:
    """LOO-CV every model in one pipelined batch; returns
    (selected name, {name: mape}, resid mu, resid sigma of the selected).

    All models are dispatched before any host synchronization: the shared
    fold-weight matrix lives on device once, and each model's executable
    reduces MAPE/residual statistics on-device, so the only host traffic is
    a few scalars per model at the end.

    ``row_weight`` (0/1 per row of ``X``) marks padding rows as inert:
    every fold's weight vector is multiplied by it, so callers (the
    replay plane's ``C3OPredictor(pad_rows=True)``) can zero-pad the data
    to power-of-two row buckets and the fold list to power-of-two fold
    buckets (masked via ``valid``) — selection against a store growing row
    by row then reuses one compiled executable per bucket instead of
    retracing per exact store size.  Folds must index real rows.

    With more than one device (or ``C3O_CV_SHARD=on``) the fold axis is
    partitioned over a "cv" mesh via shard_map — see
    ``cv_executable_sharded`` — with fold-weight buffers donated.  The
    single-device path is the numerical reference; the sharded path matches
    it to float tolerance (same selected model, allclose mape/mu/sigma).
    ``sharded`` overrides the environment policy when not None.
    """
    X64 = np.asarray(X, np.float64)
    Xj = jnp.asarray(X64, jnp.float32)
    yj = jnp.asarray(y, jnp.float32)
    folds = np.asarray(folds)
    rw = (None if row_weight is None
          else jnp.asarray(np.asarray(row_weight, np.float32)))
    n_dev = _cv_shard_devices() if sharded is None else \
        (len(jax.devices()) if sharded else 0)
    F = len(folds)
    # bucket the fold axis whenever rows are padded (the caller is asking
    # for shape stability); the sharded path additionally pads to a
    # device-count multiple
    F_pad = _bucket(F, 8) if rw is not None else F
    if n_dev:
        F_pad += (-F_pad) % n_dev
    folds_p = np.concatenate([folds, np.zeros(F_pad - F, folds.dtype)])
    valid = jnp.asarray(np.concatenate([np.ones(F, np.float32),
                                        np.zeros(F_pad - F, np.float32)]))
    fold_j = jnp.asarray(folds_p)

    def weights():
        W = 1.0 - jax.nn.one_hot(fold_j, len(yj))          # [F_pad, n]
        return W if rw is None else W * rw[None, :]

    pending = []
    if n_dev:
        # off-CPU the executable donates its fold-weight buffer, so each
        # spec needs a fresh [F_pad, n] matrix; on CPU donation is disabled
        # and one shared W serves every spec
        donating = jax.default_backend() != "cpu"
        W_shared = None if donating else weights()
        for spec in specs:
            aux = spec.make_aux(X64)
            W = weights() if donating else W_shared
            pending.append((spec.name, cv_executable_sharded(spec, n_dev)(
                Xj, yj, W, fold_j, valid, aux)))
    else:
        W = weights()                                      # [F_pad, n] shared
        for spec in specs:
            aux = spec.make_aux(X64)
            pending.append((spec.name,
                            cv_executable(spec)(Xj, yj, W, fold_j, valid,
                                                aux)))
    mapes: Dict[str, float] = {}
    stats: Dict[str, Tuple[float, float]] = {}
    for name, (mape, mu, sigma) in pending:                 # single sync pass
        mapes[name] = float(mape)
        stats[name] = (float(mu), float(sigma))
    best = min(mapes, key=mapes.get)        # ties: first in model order
    mu, sigma = stats[best]
    return best, mapes, mu, sigma + 1e-12


# --------------------------------------------------------------------------
# Grid-scored configuration
# --------------------------------------------------------------------------

def grid_rows(scaleouts: Sequence[int], contexts: np.ndarray) -> np.ndarray:
    """[S*C, 1+k] feature rows for the (scale-out x context) grid,
    scale-out-major (row s*C + c pairs scaleouts[s] with contexts[c])."""
    contexts = np.atleast_2d(np.asarray(contexts, np.float64))
    S = np.asarray(scaleouts, np.float64)
    C, k = contexts.shape
    rows = np.empty((len(S), C, k + 1), np.float64)
    rows[..., 0] = S[:, None]
    rows[..., 1:] = contexts[None, :, :]
    return rows.reshape(-1, k + 1)


def _predict_rows(predictor, rows: np.ndarray):
    """Prefer the device-level (non-syncing) predict when available so
    multi-predictor sweeps pipeline their dispatches."""
    dev = getattr(predictor, "predict_device", None)
    return dev(rows) if dev is not None else predictor.predict(rows)


def score_grid(predictor, scaleouts: Sequence[int], contexts: np.ndarray
               ) -> Tuple[np.ndarray, float, float]:
    """Runtime predictions for the whole (scale-out x context) grid in ONE
    predictor call: -> (t [C, S], mu, sigma)."""
    contexts = np.atleast_2d(np.asarray(contexts, np.float64))
    rows = grid_rows(scaleouts, contexts)
    t, mu, sigma = predictor.predict_with_error(rows)
    t = np.asarray(t, np.float64).reshape(len(scaleouts), len(contexts)).T
    return t, mu, sigma


def machine_grid_runtimes(predictors: Dict[str, object],
                          scaleouts: Sequence[int],
                          contexts: np.ndarray
                          ) -> Tuple[List[str], np.ndarray]:
    """Fused runtime predictions for the (machine x scale-out x context)
    grid: every machine's grid prediction is dispatched before the first
    host sync.  Returns (machine names, t [M, C, S]) with runtimes
    clamped at >= 0 (a negative runtime would make a negative cost win
    every cheapest-choice selection downstream)."""
    contexts = np.atleast_2d(np.asarray(contexts, np.float64))
    rows = grid_rows(scaleouts, contexts)
    names, pending = [], []
    for m, pred in predictors.items():
        names.append(m)
        pending.append(_predict_rows(pred, rows))           # async dispatch
    t = np.stack([np.asarray(p, np.float64)
                  .reshape(len(scaleouts), len(contexts)).T
                  for p in pending])
    return names, np.maximum(t, 0.0)


def machine_grid_costs(predictors: Dict[str, object],
                       prices: Dict[str, float],
                       scaleouts: Sequence[int],
                       contexts: np.ndarray
                       ) -> Tuple[List[str], np.ndarray, np.ndarray]:
    """Score the full (machine x scale-out x context) grid.

    Dispatches every machine's grid prediction before the first host sync;
    returns (machine names, t [M, C, S], cost [M, C, S])."""
    names, t = machine_grid_runtimes(predictors, scaleouts, contexts)
    S = np.asarray(scaleouts, np.float64)
    cost = np.stack([prices[m] for m in names])[:, None, None] \
        * (t / 3600.0) * S[None, None, :]
    return names, t, cost


def placement_grid_costs(predictors: Dict[str, object], book,
                         scaleouts: Sequence[int], contexts: np.ndarray,
                         zones=None, options=None):
    """Score the (machine x placement x context x scale-out) grid.

    The placement axis is pure broadcasting over the SAME fused runtime
    dispatch as ``machine_grid_costs`` — predicted runtime does not
    depend on where the cluster is bought, so a Z-zone book adds a numpy
    axis, not a prediction loop.  ``book`` is a
    ``repro.core.market.PriceBook``; returns

        (names, placements, t [M, C, S],
         et [M, P, C, S], naive [M, P, C, S], adjusted [M, P, C, S])

    where ``et`` is the interruption-adjusted expected completion time,
    ``naive`` the listed-price cost (price x t x nodes) and ``adjusted``
    the interruption-adjusted expected cost (price x E[t] x nodes)."""
    from repro.core.market import expected_completion_time_s
    names, t = machine_grid_runtimes(predictors, scaleouts, contexts)
    placements = book.resolve(zones, options)
    prices = book.price_matrix(names, placements)           # [M, P]
    rates = book.rates(placements)                          # [P]
    S = np.asarray(scaleouts, np.float64)
    et = expected_completion_time_s(t[:, None, :, :],
                                    rates[None, :, None, None],
                                    book.restart_overhead_s)
    # same op order as machine_grid_costs so a flat (single-placement,
    # rate-0) book reproduces the legacy cost bit-for-bit
    p4 = prices[:, :, None, None]
    naive = p4 * (t[:, None, :, :] / 3600.0) * S[None, None, None, :]
    adjusted = p4 * (et / 3600.0) * S[None, None, None, :]
    return names, placements, t, et, naive, adjusted
