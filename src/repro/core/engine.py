"""Fused prediction engine: the single compiled entry point for fit, model
selection (LOO-CV), and candidate-grid scoring.

DESIGN
======
The C3O compute hot-spot is evaluating the runtime predictor over every
candidate configuration (machine types x scale-outs x contexts) and
re-predicting during leave-one-out model selection (paper §IV-§VI).  The seed
implementation paid three avoidable costs on that path:

  1. retracing — ``jax.jit(spec.fit)`` / ``jax.jit(spec.predict)`` built a
     *fresh* jit wrapper (with an empty executable cache) on every
     ``FittedModel`` construction and every ``predict`` call;
  2. host round-trips — model selection pulled each model's fold predictions
     to the host before the next model was even dispatched, serializing the
     device pipeline and computing MAPE/residual statistics in numpy;
  3. per-row Python loops — the configurator scored candidates one context at
     a time, and machine-type selection re-built the scale-out grid per
     machine.

This module removes all three.  Everything routes through process-wide
executable caches:

Cache keys
----------
``fit_executable(spec)`` / ``predict_executable(spec)`` / ``cv_executable(spec)``
    LRU-cached per ``ModelSpec`` (frozen dataclass: equality is
    (name, make_aux, fit, predict) identity).  Each cached wrapper is a
    single ``jax.jit`` object, so XLA keeps **one executable per
    (ModelSpec, input shape/dtype)** — repeated fits/predicts on the same
    data shape never retrace, across any number of ``FittedModel`` or
    ``C3OPredictor`` instances.

``_gbm_kernel_executable(interpret)``
    The Pallas boosted-ensemble inference kernel
    (``repro.kernels.gbm_predict``) jitted once per interpret mode.  Batched
    predictions of GBM-selected predictors route through it on TPU backends
    (``C3O_GBM_KERNEL=auto``, the default); set ``on``/``interpret``/``off``
    to force the kernel, the interpreted kernel (CPU correctness path), or
    the jnp scan fallback.

``JobRepo.predictor_for`` (see ``repro.core.hub``)
    fitted predictors cached per
    ``(machine_type, seed, datastore version, model list)`` — ``contribute``
    bumps the datastore version only when data is actually accepted, so hub
    traffic triggers a refit only when the data changed.

Fused multi-model CV
--------------------
``cv_select`` builds the fold-weight matrix ``W = 1 - onehot(folds)`` once,
dispatches every model's vmapped LOO refit+predict **and** its on-device
MAPE/residual reduction back-to-back (no host sync between models), then
performs a single host pull at the end.  The device pipeline therefore
overlaps model k's compute with model k+1's dispatch.

Grid-scored configuration
-------------------------
``score_grid`` evaluates a (scale-out x context-batch) grid in one predictor
call; ``machine_grid_costs`` stacks that over machine types, dispatching all
machines before the first sync.  ``Configurator.choose_batch`` turns the
scored grid into per-context choices with vectorized numpy selection —
semantics identical, choice-for-choice, to the scalar ``choose_scaleout``.
"""
from __future__ import annotations

import functools
import os
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.models.api import ModelSpec

# --------------------------------------------------------------------------
# Executable caches (one jit wrapper per ModelSpec; XLA then caches one
# executable per input shape under each wrapper).
# --------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def fit_executable(spec: ModelSpec):
    """Cached jitted ``spec.fit``: (X, y, w, aux) -> params."""
    return jax.jit(spec.fit)


@functools.lru_cache(maxsize=None)
def predict_executable(spec: ModelSpec):
    """Cached jitted ``spec.predict``: (params, X, aux) -> yhat."""
    return jax.jit(spec.predict)


@functools.lru_cache(maxsize=None)
def cv_executable(spec: ModelSpec):
    """Cached jitted fused LOO-CV for one model.

    (X, y, W, fold_idx, aux) -> (mape, resid_mu, resid_sigma, preds); all
    folds are one vmapped weighted refit and the MAPE/residual reductions
    happen on-device, so selection needs a single scalar pull per model.
    """

    def _cv(X, y, W, fold_idx, aux):
        def one_fold(w, i):
            params = spec.fit(X, y, w, aux)
            return spec.predict(params, X[i][None, :], aux)[0]

        pred = jax.vmap(one_fold)(W, fold_idx)
        pred = jnp.nan_to_num(pred, nan=1e12, posinf=1e12, neginf=-1e12)
        y_f = y[fold_idx]
        ape = jnp.abs(pred - y_f) / jnp.maximum(jnp.abs(y_f), 1e-9)
        resid = pred - y_f
        return ape.mean(), resid.mean(), resid.std(), pred

    return jax.jit(_cv)


def cache_stats() -> Dict[str, int]:
    """Executable-cache occupancy (introspection for tests/benchmarks)."""
    return {"fit": fit_executable.cache_info().currsize,
            "predict": predict_executable.cache_info().currsize,
            "cv": cv_executable.cache_info().currsize}


def cache_clear() -> None:
    """Drop all cached executables (tests emulate a fresh process with this:
    after a warm-start restore, a zero fit/cv occupancy proves no refit)."""
    fit_executable.cache_clear()
    predict_executable.cache_clear()
    cv_executable.cache_clear()


# --------------------------------------------------------------------------
# Prediction dispatch (with Pallas GBM ensemble routing)
# --------------------------------------------------------------------------

@functools.lru_cache(maxsize=2)
def _gbm_kernel_executable(interpret: bool):
    from repro.kernels.gbm_predict import gbm_predict as pallas_gbm

    def run(X, feat, thr, leaf, f0, y_scale):
        raw = pallas_gbm(X, feat, thr, leaf, f0, 1.0, interpret=interpret)
        # same normalization contract as models.gbm.gbm_predict: y_scale==0
        # is the log-target sentinel
        return jnp.where(y_scale == 0.0,
                         jnp.exp(jnp.clip(raw, -30.0, 30.0)),
                         raw * jnp.maximum(y_scale, 1e-12))

    return jax.jit(run)


def _gbm_kernel_mode() -> str:
    mode = os.environ.get("C3O_GBM_KERNEL", "auto").lower()
    if mode == "auto":
        return "on" if jax.default_backend() == "tpu" else "off"
    return mode


def predict(spec: ModelSpec, params, X, aux) -> jnp.ndarray:
    """Batched prediction through the cached executable for ``spec``.

    GBM predictors route through the Pallas ensemble kernel when enabled
    (TPU backend, or ``C3O_GBM_KERNEL`` in {on, interpret}); everything else
    (and the CPU default) uses the cached jnp executable.
    """
    Xj = jnp.asarray(X, jnp.float32)
    from repro.core.models.gbm import GBM_SPEC
    if spec is GBM_SPEC:        # identity, not name: a maintainer model
        mode = _gbm_kernel_mode()   # re-registered as "gbm" has foreign params
        if mode in ("on", "interpret"):
            return _gbm_kernel_executable(mode == "interpret")(
                Xj, params.feat, params.thr, params.leaf, params.f0,
                params.y_scale)
    return predict_executable(spec)(params, Xj, aux)


# --------------------------------------------------------------------------
# Fused multi-model cross-validation / selection
# --------------------------------------------------------------------------

def cv_select(specs: Sequence[ModelSpec], X: np.ndarray, y: np.ndarray,
              folds: np.ndarray
              ) -> Tuple[str, Dict[str, float], float, float]:
    """LOO-CV every model in one pipelined batch; returns
    (selected name, {name: mape}, resid mu, resid sigma of the selected).

    All models are dispatched before any host synchronization: the shared
    fold-weight matrix lives on device once, and each model's executable
    reduces MAPE/residual statistics on-device, so the only host traffic is
    four scalars per model at the end.
    """
    X64 = np.asarray(X, np.float64)
    Xj = jnp.asarray(X64, jnp.float32)
    yj = jnp.asarray(y, jnp.float32)
    fold_j = jnp.asarray(np.asarray(folds))
    W = 1.0 - jax.nn.one_hot(fold_j, len(y))               # [F, n] shared
    pending = []
    for spec in specs:
        aux = spec.make_aux(X64)
        pending.append((spec.name,
                        cv_executable(spec)(Xj, yj, W, fold_j, aux)))
    mapes: Dict[str, float] = {}
    stats: Dict[str, Tuple[float, float]] = {}
    for name, (mape, mu, sigma, _pred) in pending:          # single sync pass
        mapes[name] = float(mape)
        stats[name] = (float(mu), float(sigma))
    best = min(mapes, key=mapes.get)        # ties: first in model order
    mu, sigma = stats[best]
    return best, mapes, mu, sigma + 1e-12


# --------------------------------------------------------------------------
# Grid-scored configuration
# --------------------------------------------------------------------------

def grid_rows(scaleouts: Sequence[int], contexts: np.ndarray) -> np.ndarray:
    """[S*C, 1+k] feature rows for the (scale-out x context) grid,
    scale-out-major (row s*C + c pairs scaleouts[s] with contexts[c])."""
    contexts = np.atleast_2d(np.asarray(contexts, np.float64))
    S = np.asarray(scaleouts, np.float64)
    C, k = contexts.shape
    rows = np.empty((len(S), C, k + 1), np.float64)
    rows[..., 0] = S[:, None]
    rows[..., 1:] = contexts[None, :, :]
    return rows.reshape(-1, k + 1)


def _predict_rows(predictor, rows: np.ndarray):
    """Prefer the device-level (non-syncing) predict when available so
    multi-predictor sweeps pipeline their dispatches."""
    dev = getattr(predictor, "predict_device", None)
    return dev(rows) if dev is not None else predictor.predict(rows)


def score_grid(predictor, scaleouts: Sequence[int], contexts: np.ndarray
               ) -> Tuple[np.ndarray, float, float]:
    """Runtime predictions for the whole (scale-out x context) grid in ONE
    predictor call: -> (t [C, S], mu, sigma)."""
    contexts = np.atleast_2d(np.asarray(contexts, np.float64))
    rows = grid_rows(scaleouts, contexts)
    t, mu, sigma = predictor.predict_with_error(rows)
    t = np.asarray(t, np.float64).reshape(len(scaleouts), len(contexts)).T
    return t, mu, sigma


def machine_grid_costs(predictors: Dict[str, object],
                       prices: Dict[str, float],
                       scaleouts: Sequence[int],
                       contexts: np.ndarray
                       ) -> Tuple[List[str], np.ndarray, np.ndarray]:
    """Score the full (machine x scale-out x context) grid.

    Dispatches every machine's grid prediction before the first host sync;
    returns (machine names, t [M, C, S], cost [M, C, S])."""
    contexts = np.atleast_2d(np.asarray(contexts, np.float64))
    rows = grid_rows(scaleouts, contexts)
    S = np.asarray(scaleouts, np.float64)
    names, pending = [], []
    for m, pred in predictors.items():
        names.append(m)
        pending.append(_predict_rows(pred, rows))           # async dispatch
    t = np.stack([np.asarray(p, np.float64)
                  .reshape(len(S), len(contexts)).T for p in pending])
    # clamp extrapolated negative runtimes: a negative cost would win every
    # cheapest-choice selection downstream
    t = np.maximum(t, 0.0)
    cost = np.stack([prices[m] for m in names])[:, None, None] \
        * (t / 3600.0) * S[None, None, :]
    return names, t, cost
