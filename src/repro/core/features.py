"""Feature schema and TSV codec for shared runtime data (paper §VI-A).

Row layout follows the paper: machine type and scale-out first, job-specific
context features after, runtime (seconds) last.  Column 0 of the encoded
matrix is ALWAYS the scale-out (models such as the optimistic SSM depend on
that convention); the machine type is a partition key, not a model feature
(paper §VI-C: models only train on data from the target machine type).
"""
from __future__ import annotations

import io
from dataclasses import dataclass

from typing import Tuple

import numpy as np


@dataclass(frozen=True)
class JobSchema:
    job: str
    context_features: Tuple[str, ...]        # job-specific columns
    base_features: Tuple[str, ...] = ("scale_out", "data_size_gb")

    @property
    def feature_names(self) -> Tuple[str, ...]:
        return self.base_features + self.context_features

    @property
    def n_features(self) -> int:
        return len(self.feature_names)

    @property
    def columns(self) -> Tuple[str, ...]:
        return ("machine_type",) + self.feature_names + ("runtime_s",)


@dataclass
class RuntimeData:
    """Rows of shared runtime data for one job."""
    schema: JobSchema
    machine_type: np.ndarray                 # [n] str
    X: np.ndarray                            # [n, d] float64 (scale-out first)
    y: np.ndarray                            # [n] float64 runtimes (seconds)

    def __len__(self) -> int:
        return len(self.y)

    def filter_machine(self, machine: str) -> "RuntimeData":
        m = self.machine_type == machine
        return RuntimeData(self.schema, self.machine_type[m], self.X[m],
                           self.y[m])

    def subset(self, idx) -> "RuntimeData":
        return RuntimeData(self.schema, self.machine_type[idx], self.X[idx],
                           self.y[idx])

    def concat(self, other: "RuntimeData") -> "RuntimeData":
        assert self.schema.job == other.schema.job
        return RuntimeData(
            self.schema,
            np.concatenate([self.machine_type, other.machine_type]),
            np.concatenate([self.X, other.X]),
            np.concatenate([self.y, other.y]))

    # ---------------- TSV (the sharing format, paper §VI-A) ----------------
    def to_tsv(self) -> str:
        buf = io.StringIO()
        buf.write("\t".join(self.schema.columns) + "\n")
        for mt, x, t in zip(self.machine_type, self.X, self.y):
            vals = [mt] + [f"{v:.6g}" for v in x] + [f"{t:.4f}"]
            buf.write("\t".join(vals) + "\n")
        return buf.getvalue()

    @classmethod
    def from_tsv(cls, text: str, schema: JobSchema) -> "RuntimeData":
        lines = [l for l in text.strip().splitlines() if l]
        header = lines[0].split("\t")
        assert tuple(header) == schema.columns, \
            f"schema mismatch: {header} vs {schema.columns}"
        mts, xs, ys = [], [], []
        for line in lines[1:]:
            parts = line.split("\t")
            mts.append(parts[0])
            xs.append([float(v) for v in parts[1:-1]])
            ys.append(float(parts[-1]))
        return cls(schema, np.asarray(mts), np.asarray(xs, np.float64),
                   np.asarray(ys, np.float64))
