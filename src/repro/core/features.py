"""Columnar feature schema and TSV codec for shared runtime data (paper §VI-A).

The runtime-data plane is a struct-of-arrays: machine codes (int32 indices
into a small machine vocabulary), scale-outs, context features, and runtimes
each live in their own contiguous array.  Row layout of the *assembled*
feature matrix follows the paper: column 0 of ``X`` is ALWAYS the scale-out
(models such as the optimistic SSM depend on that convention); the machine
type is a partition key, not a model feature (paper §VI-C: models only train
on data from the target machine type).

Columnar storage is growable: ``append`` writes contributions into spare
tail capacity (amortized doubling) instead of re-copying the whole store,
and per-machine index views plus assembled-``X`` caches are carried forward
incrementally so ``predictor_for`` -> engine dispatch re-uses one assembled
batch per (machine, data version) without re-filtering.  TSV remains
strictly an import/export format at the edges — the codec is vectorized
(``np.loadtxt`` / ``np.char``) and never materializes Python row objects.
"""
from __future__ import annotations

import io
from dataclasses import dataclass

from typing import Dict, Optional, Sequence, Tuple

import numpy as np

# Instrumentation for the incremental view plane (read by tests and the
# replay harness): full (re)builds should happen once per cold store, while
# steady-state ingestion only ever extends cached state by the delta.
VIEW_STATS: Dict[str, int] = {
    "machine_view_builds": 0,     # machine_view assembled via full subset scan
    "machine_view_extends": 0,    # cached view carried forward with the delta
    "x_builds": 0,                # assembled-X buffer allocated from scratch
    "x_extends": 0,               # assembled-X extended in place by new rows
}

#: default contributor identity for rows whose provenance is unrecorded —
#: every pre-provenance store decodes to this (the TSV format without a
#: contributor column is the canonical encoding for such data, so legacy
#: files keep their fingerprints byte-for-byte)
UNKNOWN_CONTRIBUTOR = "unknown"


def view_stats_reset() -> None:
    for k in VIEW_STATS:
        VIEW_STATS[k] = 0


@dataclass(frozen=True)
class JobSchema:
    job: str
    context_features: Tuple[str, ...]        # job-specific columns
    base_features: Tuple[str, ...] = ("scale_out", "data_size_gb")

    @property
    def feature_names(self) -> Tuple[str, ...]:
        return self.base_features + self.context_features

    @property
    def n_features(self) -> int:
        return len(self.feature_names)

    @property
    def columns(self) -> Tuple[str, ...]:
        return ("machine_type",) + self.feature_names + ("runtime_s",)

    @property
    def columns_with_provenance(self) -> Tuple[str, ...]:
        """TSV header once any row carries a known contributor: the
        contributor column rides at the end so numeric parsing of the
        legacy prefix is unchanged."""
        return self.columns + ("contributor",)


class _Columns:
    """Growable column buffers shared by ``RuntimeData`` frontier views.

    ``used`` is the number of globally valid rows; each ``RuntimeData`` view
    covers a prefix ``[:n]`` with ``n <= used``.  Rows are append-only —
    existing rows are never mutated in place — so prefix views (and any
    numpy slices handed out from them) stay valid across later appends and
    buffer growth.
    """

    __slots__ = ("codes", "scale_out", "context", "runtime", "ccodes",
                 "used", "xbuf", "xrows")

    def __init__(self, codes, scale_out, context, runtime, ccodes=None):
        self.codes = np.ascontiguousarray(codes, np.int32)
        self.scale_out = np.ascontiguousarray(scale_out, np.float64)
        self.context = np.ascontiguousarray(context, np.float64)
        self.runtime = np.ascontiguousarray(runtime, np.float64)
        self.ccodes = (np.zeros(len(self.codes), np.int32) if ccodes is None
                       else np.ascontiguousarray(ccodes, np.int32))
        self.used = len(self.codes)
        self.xbuf = None          # [capacity, 1+k] assembled-X mirror (lazy)
        self.xrows = 0            # valid assembled rows (<= used)

    @property
    def capacity(self) -> int:
        return len(self.codes)

    def grow(self, need: int) -> None:
        """Reallocate to >= ``need`` rows (amortized doubling); valid rows
        are copied, so views over the OLD buffers keep their contents."""
        cap = max(8, 2 * self.capacity)
        while cap < need:
            cap *= 2
        for name in ("codes", "scale_out", "runtime", "ccodes"):
            old = getattr(self, name)
            new = np.empty(cap, old.dtype)
            new[:self.used] = old[:self.used]
            setattr(self, name, new)
        old = self.context
        new = np.empty((cap, old.shape[1]), old.dtype)
        new[:self.used] = old[:self.used]
        self.context = new
        if self.xbuf is not None:
            newx = np.empty((cap, self.xbuf.shape[1]), np.float64)
            newx[:self.xrows] = self.xbuf[:self.xrows]
            self.xbuf = newx

    def x_view(self, n: int) -> np.ndarray:
        """Assembled [n, 1+k] feature matrix over the first ``n`` rows.

        The buffer mirrors (scale_out | context) at column-buffer capacity
        and is extended IN PLACE as views grow past previously assembled
        rows: after an append of ``m`` rows the next ``X`` access assembles
        only those ``m`` — refit preparation is O(delta), not O(n).  Rows
        are append-only, so slices handed out earlier stay valid."""
        if self.xbuf is None:
            self.xbuf = np.empty((self.capacity, self.context.shape[1] + 1),
                                 np.float64)
            self.xrows = 0
            VIEW_STATS["x_builds"] += 1
        if self.xrows < n:
            lo = self.xrows
            self.xbuf[lo:n, 0] = self.scale_out[lo:n]
            self.xbuf[lo:n, 1:] = self.context[lo:n]
            if lo:
                VIEW_STATS["x_extends"] += 1
            self.xrows = n
        return self.xbuf[:n]


def check_tsv_field(value: str, what: str = "field") -> str:
    """A string destined for a TSV column must survive the codec round
    trip byte-for-byte: no tab (the delimiter), no line-breaking
    character (``splitlines`` splits on \\v, \\f, \\x1c-\\x1e, \\x85,
    U+2028/U+2029 too, shearing the persisted store), and no leading or
    trailing whitespace (the parser strips it, silently changing the
    value — and therefore the fingerprint — on reload); not empty (a
    trailing empty field is dropped on reload, shifting every column)."""
    value = str(value)
    if (not value or "\t" in value or len(value.splitlines()) > 1
            or value != value.strip()):
        raise ValueError(
            f"{what} {value!r} would not survive the TSV codec "
            "(empty, tab, line-breaking character, or leading/trailing "
            "whitespace): it would corrupt the store's canonical "
            "encoding")
    return value


def check_contributor_id(name: str) -> str:
    """Contributor ids live in a TSV column; reject at the door anything
    the codec cannot round-trip."""
    return check_tsv_field(name, "contributor id")


def _contributor_columns(contributor, n: int):
    """(vocabulary, int32 codes) for a per-row/scalar/absent contributor."""
    if contributor is None:
        return (UNKNOWN_CONTRIBUTOR,), np.zeros(n, np.int32)
    if isinstance(contributor, str):
        return (check_contributor_id(contributor),), np.zeros(n, np.int32)
    names = np.asarray(contributor)
    if not len(names):
        return (UNKNOWN_CONTRIBUTOR,), np.empty(0, np.int32)
    vocab, ccodes = np.unique(names, return_inverse=True)
    return (tuple(check_contributor_id(c) for c in vocab),
            ccodes.astype(np.int32))


class RuntimeData:
    """Columnar runtime data for one job (struct-of-arrays).

    Columns (all length ``n``):
      ``codes``      int32 indices into the ``machines`` vocabulary
      ``scale_out``  float64 number of nodes
      ``context``    float64 [n, d-1] remaining features (data size + job
                     context), in ``schema.feature_names[1:]`` order
      ``runtime``    float64 measured runtime in seconds
      ``ccodes``     int32 indices into the ``contributors`` vocabulary
                     (provenance: which collaborator measured the row)

    ``machine_type`` / ``X`` / ``y`` are assembled-on-demand compatibility
    views (cached); hot paths should consume the columns directly or go
    through ``machine_view`` for the cached per-machine batch.  Provenance
    is metadata, never a model feature: predictors and validation ignore
    the contributor column entirely.
    """

    def __init__(self, schema: JobSchema, machine_type, X, y,
                 contributor=None):
        """Row-oriented compatibility constructor (decodes to columns).

        ``contributor`` may be a per-row array of contributor ids or a
        single id for every row; omitted means provenance unrecorded
        (``UNKNOWN_CONTRIBUTOR``)."""
        X = np.asarray(X, np.float64)
        if X.ndim != 2:
            X = X.reshape(-1, schema.n_features)
        mt = np.asarray(machine_type)
        if len(mt):
            machines, codes = np.unique(mt, return_inverse=True)
            machines = tuple(str(m) for m in machines)
        else:
            machines, codes = (), np.empty(0, np.int32)
        contributors, ccodes = _contributor_columns(contributor, len(codes))
        self._init(schema, machines,
                   _Columns(codes, X[:, 0], X[:, 1:],
                            np.asarray(y, np.float64), ccodes),
                   len(codes), contributors)

    def _init(self, schema, machines, cols, n,
              contributors=(UNKNOWN_CONTRIBUTOR,)):
        self.schema = schema
        self.machines = tuple(machines)
        self.contributors = tuple(contributors)
        self._cols = cols
        self._n = int(n)
        self._mindex = {}            # machine -> row-index array (cached)
        self._mview = {}             # machine -> RuntimeData (cached)
        self._X = None               # assembled [n, d] cache
        self._has_prov = None        # lazy has_provenance (append-carried)

    @classmethod
    def from_columns(cls, schema: JobSchema, machines: Sequence[str],
                     codes, scale_out, context, runtime, *,
                     contributors: Sequence[str] = (UNKNOWN_CONTRIBUTOR,),
                     ccodes=None) -> "RuntimeData":
        """Zero-copy columnar constructor (arrays are adopted, not copied,
        when already contiguous with the right dtype)."""
        self = cls.__new__(cls)
        context = np.asarray(context, np.float64)
        if context.ndim != 2:
            context = context.reshape(len(np.atleast_1d(scale_out)), -1)
        cols = _Columns(codes, scale_out, context, runtime, ccodes)
        self._init(schema, machines, cols, cols.used, contributors)
        return self

    @classmethod
    def empty(cls, schema: JobSchema) -> "RuntimeData":
        k = schema.n_features - 1
        return cls.from_columns(schema, (), np.empty(0, np.int32),
                                np.empty(0), np.empty((0, k)), np.empty(0))

    # ---------------- columns (views over the shared buffers) --------------
    @property
    def codes(self) -> np.ndarray:
        return self._cols.codes[:self._n]

    @property
    def scale_out(self) -> np.ndarray:
        return self._cols.scale_out[:self._n]

    @property
    def context(self) -> np.ndarray:
        return self._cols.context[:self._n]

    @property
    def runtime(self) -> np.ndarray:
        return self._cols.runtime[:self._n]

    @property
    def ccodes(self) -> np.ndarray:
        return self._cols.ccodes[:self._n]

    def __len__(self) -> int:
        return self._n

    # ---------------- contributor provenance -------------------------------
    @property
    def contributor(self) -> np.ndarray:
        """[n] contributor-id strings (decoded from codes on demand).

        A store assembled before provenance existed can carry an EMPTY
        contributor vocabulary (``from_columns(..., contributors=())``);
        its rows are provenance-unrecorded, which decodes to
        ``UNKNOWN_CONTRIBUTOR`` — not to empty strings, which would
        corrupt a TSV encoding and mislead provenance stats."""
        if not self.contributors:
            return np.full(self._n, UNKNOWN_CONTRIBUTOR)
        return np.asarray(self.contributors)[self.ccodes]

    @property
    def has_provenance(self) -> bool:
        """True when any row carries a KNOWN contributor.  Decides the TSV
        encoding: provenance-free data keeps the legacy column set, so
        pre-provenance files round-trip byte-identically (same
        fingerprint); once a known contributor appears the canonical
        encoding gains the trailing ``contributor`` column.

        Computed at most once per object — the full-column scan only runs
        when the vocabulary is ambiguous — and carried forward by
        ``append`` (rows are append-only, so ``merged = self or delta``),
        keeping ``contribute`` O(delta) on provenance-format stores."""
        if self._has_prov is None:
            if self._n == 0 or all(c == UNKNOWN_CONTRIBUTOR
                                   for c in self.contributors):
                self._has_prov = False
            else:
                used = np.unique(self.ccodes)
                self._has_prov = any(
                    self.contributors[c] != UNKNOWN_CONTRIBUTOR
                    for c in used)
        return self._has_prov

    def with_contributor(self, contributor_id: str) -> "RuntimeData":
        """Same rows stamped with one contributor identity (shares every
        non-provenance column buffer; used by ``RuntimeDataStore.contribute``
        to thread the gateway's ``contributor_id`` into the store)."""
        return RuntimeData.from_columns(
            self.schema, self.machines, self.codes, self.scale_out,
            self.context, self.runtime,
            contributors=(check_contributor_id(contributor_id),),
            ccodes=np.zeros(self._n, np.int32))

    def contributor_counts(self) -> Dict[str, int]:
        """Rows per contributor id (provenance stats for the gateway).

        Codes outside the vocabulary — a store that predates provenance
        entirely (empty vocabulary) or was assembled from raw columns with
        dangling codes — aggregate under ``UNKNOWN_CONTRIBUTOR`` instead
        of raising: the gateway's ``contributor_stats`` must answer with a
        well-formed table for every store it can serve."""
        used, counts = np.unique(self.ccodes, return_counts=True)
        out: Dict[str, int] = {}
        for c, k in zip(used, counts):
            name = (self.contributors[c]
                    if 0 <= c < len(self.contributors)
                    else UNKNOWN_CONTRIBUTOR)
            out[name] = out.get(name, 0) + int(k)
        return out

    # ---------------- assembled compatibility views ------------------------
    @property
    def machine_type(self) -> np.ndarray:
        """[n] machine-name strings (decoded from codes on demand)."""
        if not self.machines:
            return np.empty(self._n, dtype="<U1")
        return np.asarray(self.machines)[self.codes]

    @property
    def X(self) -> np.ndarray:
        """[n, d] float64 feature matrix, scale-out first.  Backed by the
        shared assembled-X buffer in ``_Columns``: built once per buffer,
        then extended in place by exactly the delta rows as the data grows
        (views are append-safe, see ``_Columns``)."""
        if self._X is None or len(self._X) != self._n:
            self._X = self._cols.x_view(self._n)
        return self._X

    @property
    def y(self) -> np.ndarray:
        return self.runtime

    @y.setter
    def y(self, value) -> None:
        """Replace runtimes (tests perturb contributions this way).  The
        view detaches onto private buffers first so sibling views sharing
        the columns are never mutated."""
        self._detach()
        self._cols.runtime = np.ascontiguousarray(value, np.float64)
        assert len(self._cols.runtime) == self._n
        self._mview = {}

    def _detach(self) -> None:
        if self._cols.used != self._n or self._cols.capacity != self._n:
            self._cols = _Columns(self.codes.copy(), self.scale_out.copy(),
                                  self.context.copy(), self.runtime.copy(),
                                  self.ccodes.copy())
        else:
            self._cols = _Columns(self._cols.codes, self._cols.scale_out,
                                  self._cols.context, self._cols.runtime,
                                  self._cols.ccodes)

    # ---------------- per-machine index views ------------------------------
    def machine_code(self, machine: str) -> int:
        """Vocabulary index of ``machine`` (-1 when absent)."""
        try:
            return self.machines.index(machine)
        except ValueError:
            return -1

    def present_machines(self) -> Tuple[str, ...]:
        """Machine names present in the data, first-appearance order."""
        codes, first = np.unique(self.codes, return_index=True)
        order = np.argsort(first)
        return tuple(self.machines[c] for c in codes[order])

    def machine_indices(self, machine: str) -> np.ndarray:
        """Row indices for one machine type (computed once, then carried
        forward incrementally across ``append``)."""
        idx = self._mindex.get(machine)
        if idx is None:
            code = self.machine_code(machine)
            idx = np.nonzero(self.codes == code)[0] if code >= 0 \
                else np.empty(0, np.int64)
            self._mindex[machine] = idx
        return idx

    def machine_view(self, machine: str) -> "RuntimeData":
        """Cached columnar batch for one machine type: repeated calls (the
        ``predictor_for`` hot path) return the SAME object, so its assembled
        ``X`` is built at most once per (machine, data version).  ``append``
        carries cached views forward by appending only the delta rows, so
        after an accepted contribution refit preparation never re-scans the
        full store (see ``VIEW_STATS``)."""
        view = self._mview.get(machine)
        if view is None:
            VIEW_STATS["machine_view_builds"] += 1
            view = self.subset(self.machine_indices(machine))
            self._mview[machine] = view
        return view

    def _light_clone(self) -> "RuntimeData":
        """Distinct object over the same columns (and shared ``X`` cache).
        Mutating the clone's ``y`` detaches it onto private buffers, so the
        original — e.g. the cached ``machine_view`` — is untouched."""
        out = RuntimeData.__new__(RuntimeData)
        out._init(self.schema, self.machines, self._cols, self._n,
                  self.contributors)
        out._X = self._X
        out._mindex = dict(self._mindex)
        out._has_prov = self._has_prov
        return out

    def filter_machine(self, machine: str) -> "RuntimeData":
        """Per-machine rows, sharing storage with the cached view but safe
        to perturb (the pre-refactor contract returned an independent copy;
        callers may legitimately edit the result's runtimes)."""
        return self.machine_view(machine)._light_clone()

    # ---------------- subset / append --------------------------------------
    def subset(self, idx) -> "RuntimeData":
        idx = np.asarray(idx)
        return RuntimeData.from_columns(
            self.schema, self.machines, self.codes[idx], self.scale_out[idx],
            self.context[idx], self.runtime[idx],
            contributors=self.contributors, ccodes=self.ccodes[idx])

    @staticmethod
    def _merge_names(ours: Sequence[str], theirs: Sequence[str],
                     their_codes: np.ndarray):
        """(merged vocabulary, their codes remapped into it)."""
        merged = list(ours)
        lut = {m: i for i, m in enumerate(merged)}
        remap = np.empty(max(len(theirs), 1), np.int32)
        for j, m in enumerate(theirs):
            if m not in lut:
                lut[m] = len(merged)
                merged.append(m)
            remap[j] = lut[m]
        out = remap[their_codes] if len(their_codes) else their_codes
        return tuple(merged), out

    def _merged_vocab(self, other: "RuntimeData"):
        """(merged machine vocabulary, other's codes remapped into it)."""
        return self._merge_names(self.machines, other.machines, other.codes)

    def append(self, other: "RuntimeData") -> "RuntimeData":
        """Columnar append in amortized O(len(other)).

        When ``self`` is the frontier view of its buffers (nothing appended
        past it yet), the delta is written into spare tail capacity and the
        returned view shares storage; otherwise a compact copy is made.
        ``self`` remains a valid, unchanged view either way.  Cached
        per-machine indices are extended incrementally, not recomputed.
        """
        assert self.schema.job == other.schema.job
        if len(other) == 0:
            return self
        machines, ocodes = self._merged_vocab(other)
        contributors, occodes = self._merge_names(
            self.contributors, other.contributors, other.ccodes)
        m = len(other)
        n = self._n
        cols = self._cols
        if cols.used != n or cols.context.shape[1] != other.context.shape[1]:
            cols = _Columns(self.codes.copy(), self.scale_out.copy(),
                            self.context.copy(), self.runtime.copy(),
                            self.ccodes.copy())
        if n + m > cols.capacity:
            cols.grow(n + m)
        cols.codes[n:n + m] = ocodes
        cols.scale_out[n:n + m] = other.scale_out
        cols.context[n:n + m] = other.context
        cols.runtime[n:n + m] = other.runtime
        cols.ccodes[n:n + m] = occodes
        cols.used = n + m
        out = RuntimeData.__new__(RuntimeData)
        out._init(self.schema, machines, cols, n + m, contributors)
        # rows are append-only, so the provenance flag composes: one O(N)
        # evaluation at the head of an append chain, O(delta) after
        out._has_prov = self.has_provenance or other.has_provenance
        # carry cached per-machine indices forward with just the delta rows
        for machine, pidx in self._mindex.items():
            code = machines.index(machine) if machine in machines else -1
            didx = np.nonzero(ocodes == code)[0] + n
            out._mindex[machine] = (np.concatenate([pidx, didx])
                                    if len(didx) else pidx)
        # carry cached per-machine VIEWS forward too: extend each cached
        # view with only its delta rows (columnar tail append), so refit
        # preparation after an accepted contribution is O(delta) — the
        # per-machine matrices are never rebuilt from a full-store scan
        for machine, view in self._mview.items():
            code = machines.index(machine) if machine in machines else -1
            didx = np.nonzero(ocodes == code)[0]
            if len(didx):
                VIEW_STATS["machine_view_extends"] += 1
                delta = RuntimeData.from_columns(
                    other.schema, machines, ocodes[didx],
                    other.scale_out[didx], other.context[didx],
                    other.runtime[didx],
                    contributors=contributors, ccodes=occodes[didx])
                out._mview[machine] = view.append(delta)
            else:
                out._mview[machine] = view
        return out

    def concat(self, other: "RuntimeData") -> "RuntimeData":
        return self.append(other)

    # ---------------- TSV (the sharing format, paper §VI-A) ----------------
    def tsv_lines(self, with_contributor: Optional[bool] = None) -> np.ndarray:
        """Canonical per-row TSV lines (no header, no newlines) as a string
        array — the unit of the datastore's chained fingerprint.

        ``with_contributor`` selects the encoding; None means "whatever is
        canonical for this data" (``has_provenance``).  Callers advancing a
        fingerprint chain pass the STORE's format explicitly so delta bytes
        match the full encoding even when the delta itself is provenance-
        free."""
        if self._n == 0:
            return np.empty(0, dtype=object)
        if with_contributor is None:
            with_contributor = self.has_provenance
        out = self.machine_type.astype(object)
        X = self.X
        for j in range(X.shape[1]):
            out = out + "\t" + np.char.mod("%.6g", X[:, j]).astype(object)
        out = out + "\t" + np.char.mod("%.4f", self.runtime).astype(object)
        if with_contributor:
            out = out + "\t" + self.contributor.astype(object)
        return out

    def tsv_delta_bytes(self, with_contributor: Optional[bool] = None
                        ) -> bytes:
        """This view's rows in canonical TSV byte form (one trailing newline
        per row) — what an append contributes to the fingerprint chain."""
        lines = self.tsv_lines(with_contributor)
        if not len(lines):
            return b""
        return ("\n".join(lines) + "\n").encode()

    def to_tsv(self) -> str:
        prov = self.has_provenance
        header = "\t".join(self.schema.columns_with_provenance if prov
                           else self.schema.columns) + "\n"
        return header + self.tsv_delta_bytes(prov).decode()

    @classmethod
    def from_tsv(cls, text: str, schema: JobSchema) -> "RuntimeData":
        lines = text.strip().splitlines()
        header = tuple(lines[0].split("\t")) if lines else ()
        prov = header == schema.columns_with_provenance
        assert prov or header == schema.columns, \
            f"schema mismatch: {header} vs {schema.columns}"
        body = [ln for ln in lines[1:] if ln]
        if not body:
            return cls.empty(schema)
        arr = np.loadtxt(io.StringIO("\n".join(body)), dtype=str,
                         delimiter="\t", ndmin=2, comments=None)
        stop = -1 if prov else arr.shape[1]
        nums = arr[:, 1:stop].astype(np.float64)
        machines, codes = np.unique(arr[:, 0], return_inverse=True)
        if prov:
            contributors, ccodes = _contributor_columns(arr[:, -1], len(arr))
        else:
            contributors, ccodes = _contributor_columns(None, len(arr))
        return cls.from_columns(schema, tuple(str(m) for m in machines),
                                codes, nums[:, 0], nums[:, 1:-1],
                                nums[:, -1], contributors=contributors,
                                ccodes=ccodes)


def assemble_X(scale_out: np.ndarray, context: np.ndarray,
               out: Optional[np.ndarray] = None) -> np.ndarray:
    """Assemble the [n, d] model feature matrix from columns (scale-out
    first) — the one place the columnar plane flattens for the engine."""
    scale_out = np.asarray(scale_out, np.float64)
    context = np.atleast_2d(np.asarray(context, np.float64))
    n, k = context.shape
    if out is None:
        out = np.empty((n, k + 1), np.float64)
    out[:, 0] = scale_out
    out[:, 1:] = context
    return out
