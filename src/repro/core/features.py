"""Columnar feature schema and TSV codec for shared runtime data (paper §VI-A).

The runtime-data plane is a struct-of-arrays: machine codes (int32 indices
into a small machine vocabulary), scale-outs, context features, and runtimes
each live in their own contiguous array.  Row layout of the *assembled*
feature matrix follows the paper: column 0 of ``X`` is ALWAYS the scale-out
(models such as the optimistic SSM depend on that convention); the machine
type is a partition key, not a model feature (paper §VI-C: models only train
on data from the target machine type).

Columnar storage is growable: ``append`` writes contributions into spare
tail capacity (amortized doubling) instead of re-copying the whole store,
and per-machine index views plus assembled-``X`` caches are carried forward
incrementally so ``predictor_for`` -> engine dispatch re-uses one assembled
batch per (machine, data version) without re-filtering.  TSV remains
strictly an import/export format at the edges — the codec is vectorized
(``np.loadtxt`` / ``np.char``) and never materializes Python row objects.
"""
from __future__ import annotations

import io
from dataclasses import dataclass

from typing import Dict, Optional, Sequence, Tuple

import numpy as np

# Instrumentation for the incremental view plane (read by tests and the
# replay harness): full (re)builds should happen once per cold store, while
# steady-state ingestion only ever extends cached state by the delta.
VIEW_STATS: Dict[str, int] = {
    "machine_view_builds": 0,     # machine_view assembled via full subset scan
    "machine_view_extends": 0,    # cached view carried forward with the delta
    "x_builds": 0,                # assembled-X buffer allocated from scratch
    "x_extends": 0,               # assembled-X extended in place by new rows
}


def view_stats_reset() -> None:
    for k in VIEW_STATS:
        VIEW_STATS[k] = 0


@dataclass(frozen=True)
class JobSchema:
    job: str
    context_features: Tuple[str, ...]        # job-specific columns
    base_features: Tuple[str, ...] = ("scale_out", "data_size_gb")

    @property
    def feature_names(self) -> Tuple[str, ...]:
        return self.base_features + self.context_features

    @property
    def n_features(self) -> int:
        return len(self.feature_names)

    @property
    def columns(self) -> Tuple[str, ...]:
        return ("machine_type",) + self.feature_names + ("runtime_s",)


class _Columns:
    """Growable column buffers shared by ``RuntimeData`` frontier views.

    ``used`` is the number of globally valid rows; each ``RuntimeData`` view
    covers a prefix ``[:n]`` with ``n <= used``.  Rows are append-only —
    existing rows are never mutated in place — so prefix views (and any
    numpy slices handed out from them) stay valid across later appends and
    buffer growth.
    """

    __slots__ = ("codes", "scale_out", "context", "runtime", "used",
                 "xbuf", "xrows")

    def __init__(self, codes, scale_out, context, runtime):
        self.codes = np.ascontiguousarray(codes, np.int32)
        self.scale_out = np.ascontiguousarray(scale_out, np.float64)
        self.context = np.ascontiguousarray(context, np.float64)
        self.runtime = np.ascontiguousarray(runtime, np.float64)
        self.used = len(self.codes)
        self.xbuf = None          # [capacity, 1+k] assembled-X mirror (lazy)
        self.xrows = 0            # valid assembled rows (<= used)

    @property
    def capacity(self) -> int:
        return len(self.codes)

    def grow(self, need: int) -> None:
        """Reallocate to >= ``need`` rows (amortized doubling); valid rows
        are copied, so views over the OLD buffers keep their contents."""
        cap = max(8, 2 * self.capacity)
        while cap < need:
            cap *= 2
        for name in ("codes", "scale_out", "runtime"):
            old = getattr(self, name)
            new = np.empty(cap, old.dtype)
            new[:self.used] = old[:self.used]
            setattr(self, name, new)
        old = self.context
        new = np.empty((cap, old.shape[1]), old.dtype)
        new[:self.used] = old[:self.used]
        self.context = new
        if self.xbuf is not None:
            newx = np.empty((cap, self.xbuf.shape[1]), np.float64)
            newx[:self.xrows] = self.xbuf[:self.xrows]
            self.xbuf = newx

    def x_view(self, n: int) -> np.ndarray:
        """Assembled [n, 1+k] feature matrix over the first ``n`` rows.

        The buffer mirrors (scale_out | context) at column-buffer capacity
        and is extended IN PLACE as views grow past previously assembled
        rows: after an append of ``m`` rows the next ``X`` access assembles
        only those ``m`` — refit preparation is O(delta), not O(n).  Rows
        are append-only, so slices handed out earlier stay valid."""
        if self.xbuf is None:
            self.xbuf = np.empty((self.capacity, self.context.shape[1] + 1),
                                 np.float64)
            self.xrows = 0
            VIEW_STATS["x_builds"] += 1
        if self.xrows < n:
            lo = self.xrows
            self.xbuf[lo:n, 0] = self.scale_out[lo:n]
            self.xbuf[lo:n, 1:] = self.context[lo:n]
            if lo:
                VIEW_STATS["x_extends"] += 1
            self.xrows = n
        return self.xbuf[:n]


class RuntimeData:
    """Columnar runtime data for one job (struct-of-arrays).

    Columns (all length ``n``):
      ``codes``      int32 indices into the ``machines`` vocabulary
      ``scale_out``  float64 number of nodes
      ``context``    float64 [n, d-1] remaining features (data size + job
                     context), in ``schema.feature_names[1:]`` order
      ``runtime``    float64 measured runtime in seconds

    ``machine_type`` / ``X`` / ``y`` are assembled-on-demand compatibility
    views (cached); hot paths should consume the columns directly or go
    through ``machine_view`` for the cached per-machine batch.
    """

    def __init__(self, schema: JobSchema, machine_type, X, y):
        """Row-oriented compatibility constructor (decodes to columns)."""
        X = np.asarray(X, np.float64)
        if X.ndim != 2:
            X = X.reshape(-1, schema.n_features)
        mt = np.asarray(machine_type)
        if len(mt):
            machines, codes = np.unique(mt, return_inverse=True)
            machines = tuple(str(m) for m in machines)
        else:
            machines, codes = (), np.empty(0, np.int32)
        self._init(schema, machines,
                   _Columns(codes, X[:, 0], X[:, 1:],
                            np.asarray(y, np.float64)),
                   len(codes))

    def _init(self, schema, machines, cols, n):
        self.schema = schema
        self.machines = tuple(machines)
        self._cols = cols
        self._n = int(n)
        self._mindex = {}            # machine -> row-index array (cached)
        self._mview = {}             # machine -> RuntimeData (cached)
        self._X = None               # assembled [n, d] cache

    @classmethod
    def from_columns(cls, schema: JobSchema, machines: Sequence[str],
                     codes, scale_out, context, runtime) -> "RuntimeData":
        """Zero-copy columnar constructor (arrays are adopted, not copied,
        when already contiguous with the right dtype)."""
        self = cls.__new__(cls)
        context = np.asarray(context, np.float64)
        if context.ndim != 2:
            context = context.reshape(len(np.atleast_1d(scale_out)), -1)
        cols = _Columns(codes, scale_out, context, runtime)
        self._init(schema, machines, cols, cols.used)
        return self

    @classmethod
    def empty(cls, schema: JobSchema) -> "RuntimeData":
        k = schema.n_features - 1
        return cls.from_columns(schema, (), np.empty(0, np.int32),
                                np.empty(0), np.empty((0, k)), np.empty(0))

    # ---------------- columns (views over the shared buffers) --------------
    @property
    def codes(self) -> np.ndarray:
        return self._cols.codes[:self._n]

    @property
    def scale_out(self) -> np.ndarray:
        return self._cols.scale_out[:self._n]

    @property
    def context(self) -> np.ndarray:
        return self._cols.context[:self._n]

    @property
    def runtime(self) -> np.ndarray:
        return self._cols.runtime[:self._n]

    def __len__(self) -> int:
        return self._n

    # ---------------- assembled compatibility views ------------------------
    @property
    def machine_type(self) -> np.ndarray:
        """[n] machine-name strings (decoded from codes on demand)."""
        if not self.machines:
            return np.empty(self._n, dtype="<U1")
        return np.asarray(self.machines)[self.codes]

    @property
    def X(self) -> np.ndarray:
        """[n, d] float64 feature matrix, scale-out first.  Backed by the
        shared assembled-X buffer in ``_Columns``: built once per buffer,
        then extended in place by exactly the delta rows as the data grows
        (views are append-safe, see ``_Columns``)."""
        if self._X is None or len(self._X) != self._n:
            self._X = self._cols.x_view(self._n)
        return self._X

    @property
    def y(self) -> np.ndarray:
        return self.runtime

    @y.setter
    def y(self, value) -> None:
        """Replace runtimes (tests perturb contributions this way).  The
        view detaches onto private buffers first so sibling views sharing
        the columns are never mutated."""
        self._detach()
        self._cols.runtime = np.ascontiguousarray(value, np.float64)
        assert len(self._cols.runtime) == self._n
        self._mview = {}

    def _detach(self) -> None:
        if self._cols.used != self._n or self._cols.capacity != self._n:
            self._cols = _Columns(self.codes.copy(), self.scale_out.copy(),
                                  self.context.copy(), self.runtime.copy())
        else:
            self._cols = _Columns(self._cols.codes, self._cols.scale_out,
                                  self._cols.context, self._cols.runtime)

    # ---------------- per-machine index views ------------------------------
    def machine_code(self, machine: str) -> int:
        """Vocabulary index of ``machine`` (-1 when absent)."""
        try:
            return self.machines.index(machine)
        except ValueError:
            return -1

    def present_machines(self) -> Tuple[str, ...]:
        """Machine names present in the data, first-appearance order."""
        codes, first = np.unique(self.codes, return_index=True)
        order = np.argsort(first)
        return tuple(self.machines[c] for c in codes[order])

    def machine_indices(self, machine: str) -> np.ndarray:
        """Row indices for one machine type (computed once, then carried
        forward incrementally across ``append``)."""
        idx = self._mindex.get(machine)
        if idx is None:
            code = self.machine_code(machine)
            idx = np.nonzero(self.codes == code)[0] if code >= 0 \
                else np.empty(0, np.int64)
            self._mindex[machine] = idx
        return idx

    def machine_view(self, machine: str) -> "RuntimeData":
        """Cached columnar batch for one machine type: repeated calls (the
        ``predictor_for`` hot path) return the SAME object, so its assembled
        ``X`` is built at most once per (machine, data version).  ``append``
        carries cached views forward by appending only the delta rows, so
        after an accepted contribution refit preparation never re-scans the
        full store (see ``VIEW_STATS``)."""
        view = self._mview.get(machine)
        if view is None:
            VIEW_STATS["machine_view_builds"] += 1
            view = self.subset(self.machine_indices(machine))
            self._mview[machine] = view
        return view

    def _light_clone(self) -> "RuntimeData":
        """Distinct object over the same columns (and shared ``X`` cache).
        Mutating the clone's ``y`` detaches it onto private buffers, so the
        original — e.g. the cached ``machine_view`` — is untouched."""
        out = RuntimeData.__new__(RuntimeData)
        out._init(self.schema, self.machines, self._cols, self._n)
        out._X = self._X
        out._mindex = dict(self._mindex)
        return out

    def filter_machine(self, machine: str) -> "RuntimeData":
        """Per-machine rows, sharing storage with the cached view but safe
        to perturb (the pre-refactor contract returned an independent copy;
        callers may legitimately edit the result's runtimes)."""
        return self.machine_view(machine)._light_clone()

    # ---------------- subset / append --------------------------------------
    def subset(self, idx) -> "RuntimeData":
        idx = np.asarray(idx)
        return RuntimeData.from_columns(
            self.schema, self.machines, self.codes[idx], self.scale_out[idx],
            self.context[idx], self.runtime[idx])

    def _merged_vocab(self, other: "RuntimeData"):
        """(merged vocabulary, other's codes remapped into it)."""
        machines = list(self.machines)
        lut = {m: i for i, m in enumerate(machines)}
        remap = np.empty(max(len(other.machines), 1), np.int32)
        for j, m in enumerate(other.machines):
            if m not in lut:
                lut[m] = len(machines)
                machines.append(m)
            remap[j] = lut[m]
        ocodes = remap[other.codes] if len(other) else other.codes
        return tuple(machines), ocodes

    def append(self, other: "RuntimeData") -> "RuntimeData":
        """Columnar append in amortized O(len(other)).

        When ``self`` is the frontier view of its buffers (nothing appended
        past it yet), the delta is written into spare tail capacity and the
        returned view shares storage; otherwise a compact copy is made.
        ``self`` remains a valid, unchanged view either way.  Cached
        per-machine indices are extended incrementally, not recomputed.
        """
        assert self.schema.job == other.schema.job
        if len(other) == 0:
            return self
        machines, ocodes = self._merged_vocab(other)
        m = len(other)
        n = self._n
        cols = self._cols
        if cols.used != n or cols.context.shape[1] != other.context.shape[1]:
            cols = _Columns(self.codes.copy(), self.scale_out.copy(),
                            self.context.copy(), self.runtime.copy())
        if n + m > cols.capacity:
            cols.grow(n + m)
        cols.codes[n:n + m] = ocodes
        cols.scale_out[n:n + m] = other.scale_out
        cols.context[n:n + m] = other.context
        cols.runtime[n:n + m] = other.runtime
        cols.used = n + m
        out = RuntimeData.__new__(RuntimeData)
        out._init(self.schema, machines, cols, n + m)
        # carry cached per-machine indices forward with just the delta rows
        for machine, pidx in self._mindex.items():
            code = machines.index(machine) if machine in machines else -1
            didx = np.nonzero(ocodes == code)[0] + n
            out._mindex[machine] = (np.concatenate([pidx, didx])
                                    if len(didx) else pidx)
        # carry cached per-machine VIEWS forward too: extend each cached
        # view with only its delta rows (columnar tail append), so refit
        # preparation after an accepted contribution is O(delta) — the
        # per-machine matrices are never rebuilt from a full-store scan
        for machine, view in self._mview.items():
            code = machines.index(machine) if machine in machines else -1
            didx = np.nonzero(ocodes == code)[0]
            if len(didx):
                VIEW_STATS["machine_view_extends"] += 1
                delta = RuntimeData.from_columns(
                    other.schema, machines, ocodes[didx],
                    other.scale_out[didx], other.context[didx],
                    other.runtime[didx])
                out._mview[machine] = view.append(delta)
            else:
                out._mview[machine] = view
        return out

    def concat(self, other: "RuntimeData") -> "RuntimeData":
        return self.append(other)

    # ---------------- TSV (the sharing format, paper §VI-A) ----------------
    def tsv_lines(self) -> np.ndarray:
        """Canonical per-row TSV lines (no header, no newlines) as a string
        array — the unit of the datastore's chained fingerprint."""
        if self._n == 0:
            return np.empty(0, dtype=object)
        out = self.machine_type.astype(object)
        X = self.X
        for j in range(X.shape[1]):
            out = out + "\t" + np.char.mod("%.6g", X[:, j]).astype(object)
        return out + "\t" + np.char.mod("%.4f", self.runtime).astype(object)

    def tsv_delta_bytes(self) -> bytes:
        """This view's rows in canonical TSV byte form (one trailing newline
        per row) — what an append contributes to the fingerprint chain."""
        lines = self.tsv_lines()
        if not len(lines):
            return b""
        return ("\n".join(lines) + "\n").encode()

    def to_tsv(self) -> str:
        header = "\t".join(self.schema.columns) + "\n"
        return header + self.tsv_delta_bytes().decode()

    @classmethod
    def from_tsv(cls, text: str, schema: JobSchema) -> "RuntimeData":
        lines = text.strip().splitlines()
        header = lines[0].split("\t") if lines else []
        assert tuple(header) == schema.columns, \
            f"schema mismatch: {header} vs {schema.columns}"
        body = [ln for ln in lines[1:] if ln]
        if not body:
            return cls.empty(schema)
        arr = np.loadtxt(io.StringIO("\n".join(body)), dtype=str,
                         delimiter="\t", ndmin=2, comments=None)
        nums = arr[:, 1:].astype(np.float64)
        machines, codes = np.unique(arr[:, 0], return_inverse=True)
        return cls.from_columns(schema, tuple(str(m) for m in machines),
                                codes, nums[:, 0], nums[:, 1:-1],
                                nums[:, -1])


def assemble_X(scale_out: np.ndarray, context: np.ndarray,
               out: Optional[np.ndarray] = None) -> np.ndarray:
    """Assemble the [n, d] model feature matrix from columns (scale-out
    first) — the one place the columnar plane flattens for the engine."""
    scale_out = np.asarray(scale_out, np.float64)
    context = np.atleast_2d(np.asarray(context, np.float64))
    n, k = context.shape
    if out is None:
        out = np.empty((n, k + 1), np.float64)
    out[:, 0] = scale_out
    out[:, 1:] = context
    return out
