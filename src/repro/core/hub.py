"""C3O Hub emulation (paper §III-B): job repositories carrying code +
shared runtime data + optional maintainer-supplied custom models.

A JobRepo is what a user "downloads" in workflow step (2): it bundles the
job's schema, the shared RuntimeDataStore, the candidate model list (default
models plus any maintainer custom models registered under the common model
API), and metadata for discovery on the hub.
"""
from __future__ import annotations

import logging
import os
import pickle
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.configurator import Configurator
from repro.core.datastore import RuntimeDataStore, ValidationReport
from repro.core.features import JobSchema, RuntimeData
from repro.core.models.api import ModelSpec, register_model
from repro.core.predictor import DEFAULT_MODELS, C3OPredictor


@dataclass
class JobRepo:
    job: str
    algorithm: str                       # hub metadata: underlying algorithm
    schema: JobSchema
    store: RuntimeDataStore
    model_names: List[str] = field(default_factory=lambda: list(DEFAULT_MODELS))
    maintainer_machine_type: Optional[str] = None   # paper §IV-A
    # extra C3OPredictor constructor kwargs (fixed per repo, so they need
    # no cache-key slot): the evaluation replay plane sets
    # {"pad_rows": True} here so per-checkpoint refits against the growing
    # store reuse bucketed executables
    predictor_kw: Dict = field(default_factory=dict)
    # fitted-predictor cache, keyed on everything the fit depends on:
    # (machine_type, seed, datastore version, trust version, model list).
    # ``contribute`` bumps the store version only when data is accepted —
    # and the TRUST version whenever a judged contribution moved a
    # reputation — so hub traffic triggers a refit exactly when the data
    # or the reputation-derived row weights changed.
    _fit_cache: Dict[tuple, C3OPredictor] = field(default_factory=dict,
                                                  repr=False, compare=False)

    def add_custom_model(self, spec: ModelSpec) -> None:
        """Maintainers ship job-specific models behind the common API
        (paper §III-C.c); they join the predictor's CV selection pool."""
        register_model(spec)
        if spec.name not in self.model_names:
            self.model_names.append(spec.name)

    def predictor_for(self, machine_type: str, seed: int = 0) -> C3OPredictor:
        from repro.core.models.api import get_model
        # key on the spec OBJECTS, not names: re-registering a custom model
        # under an existing name must invalidate the cached fit.  The trust
        # version rides in the key because a REJECTED contribution changes
        # reputation (hence the row weights of rows already stored) without
        # bumping the data version.
        key = (machine_type, seed, self.store.version,
               self.store.trust_version,
               tuple(get_model(n) for n in self.model_names))
        pred = self._fit_cache.get(key)
        if pred is None:
            # cached columnar machine view: the assembled (X, y) batch is
            # built once per (machine, data version) and handed to the
            # engine as-is — no per-call re-filter or row copies
            d = self.store.data.machine_view(machine_type)
            pred = C3OPredictor(model_names=tuple(self.model_names),
                                seed=seed, **self.predictor_kw) \
                .fit_data(d, row_weight=self.store.row_weights(d))
            # stale versions can never be requested again: evict them
            self._fit_cache = {
                k: v for k, v in self._fit_cache.items()
                if k[2] == self.store.version
                and k[3] == self.store.trust_version}
            self._fit_cache[key] = pred
        return pred

    # ------------------- fit-cache persistence ----------------------------
    # Saved alongside the TSV store, each entry keyed on everything the fit
    # depends on: (machine_type, seed, store fingerprint, model list).  The
    # fingerprint is the cross-process form of the in-memory store version —
    # an accepted ``contribute`` changes the data, hence the fingerprint,
    # hence invalidates every persisted fit.

    FITS_VERSION = 3                     # v3: payload carries store epoch
    #                                      (v2: entries carry trust_version)

    @staticmethod
    def fits_path(store_path: str) -> str:
        """Conventional sidecar location for a store at ``store_path``."""
        return store_path + ".fits.pkl"

    def save_fits(self, path: str) -> int:
        """Serialize the cached fitted predictors; returns the entry count.

        Only entries fitted at the CURRENT store version are saved:
        ``predictor_for`` evicts stale versions lazily (on its next miss),
        so right after an accepted ``contribute`` the cache can still hold
        fits of the pre-contribution data — stamping those with the new
        fingerprint would let a fresh process serve stale predictions."""
        entries = []
        for (machine_type, seed, ver, tv, specs), pred in \
                self._fit_cache.items():
            if ver != self.store.version or tv != self.store.trust_version:
                continue
            entries.append({"machine_type": str(machine_type), "seed": seed,
                            "model_names": tuple(s.name for s in specs),
                            "trust_version": tv,
                            "state": pred.export_state()})
        blob = pickle.dumps({"format": self.FITS_VERSION,
                             "job": self.job,
                             "fingerprint": self.store.fingerprint,
                             "epoch": self.store.epoch,
                             "compactions": self.store.compactions,
                             "entries": entries})
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:
            f.write(blob)
        os.replace(tmp, path)            # atomic, like the store itself
        return len(entries)

    def load_fits(self, path: str) -> int:
        """Warm-start the fit cache from a sidecar; returns how many entries
        were restored.  Entries are dropped (forcing a refit on demand) when
        the store content no longer matches the saved fingerprint, the model
        list changed, or the selected model is no longer registered.  A
        corrupt or unreadable sidecar (truncated write, bad pickle, foreign
        format) is a CACHE MISS, not an error: it is logged and every
        predictor refits on demand — a damaged cache file must never take
        the hub down."""
        from repro.core.models.api import get_model
        from repro.core.predictor import C3OPredictor
        try:
            with open(path, "rb") as f:
                payload = pickle.load(f)
            entries = payload["entries"]
            fingerprint = payload.get("fingerprint")
            fmt = payload.get("format")
        except Exception as e:           # noqa: BLE001 — any damage = miss
            logging.getLogger(__name__).warning(
                "fit-cache sidecar %s unreadable (%s: %s); refitting on "
                "demand", path, type(e).__name__, e)
            return 0
        if fmt != self.FITS_VERSION or fingerprint != self.store.fingerprint:
            return 0
        # the TSV codec carries rows, not lifecycle state: a fresh process
        # re-opening a compacted store starts at epoch 0.  The sidecar is
        # written by the process that ran the compactions, so a fingerprint
        # match also vouches for its epoch counters — fast-forward (an
        # epoch transition is a version discontinuity appends never cause,
        # and downstream caches key on it via store info).
        self.store.restore_epoch(int(payload.get("epoch", 0)),
                                 int(payload.get("compactions", 0)))
        restored = 0
        for e in entries:
            try:
                if tuple(e["model_names"]) != tuple(self.model_names):
                    continue
                # a fit made under different reputation state used
                # different row weights: restoring it would serve stale
                # weighted predictions (trust ledgers are process state —
                # a fresh process's ledger rarely matches the saved one)
                if e["trust_version"] != self.store.trust_version:
                    continue
                specs = tuple(get_model(n) for n in self.model_names)
                d = self.store.data.machine_view(e["machine_type"])
                pred = C3OPredictor.from_state(e["state"], d.X)
                key = (e["machine_type"], e["seed"], self.store.version,
                       self.store.trust_version, specs)
            except KeyError:             # a model left the registry, or a
                continue                 # malformed entry: skip, refit later
            except Exception as exc:     # noqa: BLE001
                logging.getLogger(__name__).warning(
                    "fit-cache entry in %s unusable (%s: %s); skipping",
                    path, type(exc).__name__, exc)
                continue
            self._fit_cache[key] = pred
            restored += 1
        return restored

    def model_errors(self, machine_type: str, test: RuntimeData,
                     track_models: Optional[Sequence[str]] = None,
                     seed: int = 0) -> tuple:
        """Held-out (MAPE, MAE) of every tracked model on ``test`` plus the
        C3O predictor itself — one evaluation checkpoint of the replay
        plane (paper §VI-C protocol: individual models refit on the shared
        store; the ``"c3o"`` row additionally runs LOO-CV model selection
        via ``predictor_for``/``cv_select`` first).

        Returns ``({model: (mape, mae)}, selected_model_name)``.  Tracked
        models dispatch through the engine's fused, shape-bucketed
        ``val_executable``s; the C3O row predicts through the selected
        model's cached batched executable.  ``track_models`` may include
        baselines outside the repo's selection pool (e.g. ``"linreg"``)."""
        from repro.core import engine
        from repro.core.models.api import get_model
        specs = [get_model(n) for n in
                 (self.model_names if track_models is None else track_models)]
        tr = self.store.data.machine_view(machine_type)
        te = test.machine_view(machine_type)
        errs = engine.holdout_errors(specs, tr.X, tr.y, te.X, te.y)
        pred = self.predictor_for(machine_type, seed=seed)
        yhat = np.nan_to_num(pred.predict(te.X), nan=1e12, posinf=1e12,
                             neginf=-1e12)
        ae = np.abs(yhat - te.y)
        errs["c3o"] = (float(np.mean(ae / np.maximum(np.abs(te.y), 1e-9))),
                       float(np.mean(ae)))
        return errs, pred.selected

    def configurator(self, machine_type: str, prices: Dict[str, float],
                     scaleouts: Sequence[int], **kw) -> Configurator:
        return Configurator(self.predictor_for(machine_type), machine_type,
                            prices, scaleouts, **kw)

    def contribute(self, rows: RuntimeData,
                   contributor: Optional[str] = None) -> ValidationReport:
        """Workflow step (6): captured runtime data flows back, validated.
        ``contributor`` stamps the rows with the collaborator's identity
        (see ``RuntimeDataStore.contribute``)."""
        return self.store.contribute(rows, contributor=contributor)


class Hub:
    """The discovery index (paper Fig. 4, step 1).

    Note: ``Hub``/``JobRepo`` remain the in-process object layer, but the
    canonical public surface is the versioned gateway API —
    ``repro.api.HubGateway`` routes typed requests (predict / choose /
    contribute / model-errors / search) across every published repo, adds
    per-job micro-batch lanes and contributor provenance, and serves the
    same results request-for-request (``tests/test_api_gateway.py`` parity
    suite).  New front-ends should talk to the gateway, not to these
    objects directly."""

    def __init__(self):
        self._repos: Dict[str, JobRepo] = {}
        self._transfer = None             # lazy shared TransferIndex

    def publish(self, repo: JobRepo) -> None:
        self._repos[repo.job] = repo

    def search(self, algorithm: str) -> List[JobRepo]:
        q = algorithm.lower()
        return [r for r in self._repos.values()
                if q in r.algorithm.lower() or q in r.job.lower()]

    def get(self, job: str) -> JobRepo:
        return self._repos[job]

    def jobs(self) -> List[str]:
        return sorted(self._repos)

    def transfer_index(self, policy=None):
        """The hub's shared cross-job transfer index (lazily built).

        One index per hub: its signature / pairwise-similarity caches are
        keyed on each store's (version, epoch), so sharing it across
        gateways is what makes repeated nearest-job lookups amortize.
        Passing a different ``policy`` rebuilds it (the caches key on
        store state, not policy, so a rebuild only re-prices lookups)."""
        from repro.core.transfer import TransferIndex
        if self._transfer is None or (
                policy is not None and self._transfer.policy != policy):
            self._transfer = TransferIndex(self, policy)
        return self._transfer

    def nearest_job(self, job: str, n_features: Optional[int] = None,
                    policy=None):
        """Nearest-job lookup for cold-start transfer (None if no donor)."""
        return self.transfer_index(policy).nearest(job, n_features)

    def gateway(self, prices: Dict[str, float], scaleouts: Sequence[int],
                **kw):
        """Convenience constructor for the canonical API surface."""
        from repro.api.gateway import HubGateway
        return HubGateway(self, prices, scaleouts, **kw)
