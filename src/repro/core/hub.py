"""C3O Hub emulation (paper §III-B): job repositories carrying code +
shared runtime data + optional maintainer-supplied custom models.

A JobRepo is what a user "downloads" in workflow step (2): it bundles the
job's schema, the shared RuntimeDataStore, the candidate model list (default
models plus any maintainer custom models registered under the common model
API), and metadata for discovery on the hub.
"""
from __future__ import annotations

import os
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.configurator import Configurator
from repro.core.datastore import RuntimeDataStore, ValidationReport
from repro.core.features import JobSchema, RuntimeData
from repro.core.models.api import ModelSpec, register_model
from repro.core.predictor import DEFAULT_MODELS, C3OPredictor


@dataclass
class JobRepo:
    job: str
    algorithm: str                       # hub metadata: underlying algorithm
    schema: JobSchema
    store: RuntimeDataStore
    model_names: List[str] = field(default_factory=lambda: list(DEFAULT_MODELS))
    maintainer_machine_type: Optional[str] = None   # paper §IV-A
    # fitted-predictor cache, keyed on everything the fit depends on:
    # (machine_type, seed, datastore version, model list).  ``contribute``
    # bumps the store version only when data is accepted, so hub traffic
    # triggers a refit exactly when the data changed.
    _fit_cache: Dict[tuple, C3OPredictor] = field(default_factory=dict,
                                                  repr=False, compare=False)

    def add_custom_model(self, spec: ModelSpec) -> None:
        """Maintainers ship job-specific models behind the common API
        (paper §III-C.c); they join the predictor's CV selection pool."""
        register_model(spec)
        if spec.name not in self.model_names:
            self.model_names.append(spec.name)

    def predictor_for(self, machine_type: str, seed: int = 0) -> C3OPredictor:
        from repro.core.models.api import get_model
        # key on the spec OBJECTS, not names: re-registering a custom model
        # under an existing name must invalidate the cached fit
        key = (machine_type, seed, self.store.version,
               tuple(get_model(n) for n in self.model_names))
        pred = self._fit_cache.get(key)
        if pred is None:
            d = self.store.data.filter_machine(machine_type)
            pred = C3OPredictor(model_names=tuple(self.model_names),
                                seed=seed).fit(d.X, d.y)
            # stale versions can never be requested again: evict them
            self._fit_cache = {k: v for k, v in self._fit_cache.items()
                               if k[2] == self.store.version}
            self._fit_cache[key] = pred
        return pred

    def configurator(self, machine_type: str, prices: Dict[str, float],
                     scaleouts: Sequence[int], **kw) -> Configurator:
        return Configurator(self.predictor_for(machine_type), machine_type,
                            prices, scaleouts, **kw)

    def contribute(self, rows: RuntimeData) -> ValidationReport:
        """Workflow step (6): captured runtime data flows back, validated."""
        return self.store.contribute(rows)


class Hub:
    """The discovery index (paper Fig. 4, step 1)."""

    def __init__(self):
        self._repos: Dict[str, JobRepo] = {}

    def publish(self, repo: JobRepo) -> None:
        self._repos[repo.job] = repo

    def search(self, algorithm: str) -> List[JobRepo]:
        q = algorithm.lower()
        return [r for r in self._repos.values()
                if q in r.algorithm.lower() or q in r.job.lower()]

    def get(self, job: str) -> JobRepo:
        return self._repos[job]

    def jobs(self) -> List[str]:
        return sorted(self._repos)
