"""Cloud market plane: spot prices, interruption risk, multi-AZ placement.

C3O selects the cheapest configuration meeting a deadline, but a static
``$ per node-hour`` dict is not how public clouds price: the same machine
type costs differently per availability zone and purchase option, and the
spot discount is paid for with interruption risk.  This module is the
typed market model the selection stack scores against:

``PriceBook``
    Per-(machine type, zone, purchase option) *time-varying* price
    vectors plus per-(zone, option) interruption rates, validated at
    construction — a missing price, a non-positive price, or an unknown
    purchase option is a typed ``MarketError`` (a ``ValueError``
    subclass, so the gateway maps it to a ``bad_request`` envelope), not
    a bare ``KeyError`` mid-score or a negative cost that silently wins
    cheapest-choice selection.

Interruption model
    Interruptions arrive Poisson with rate ``lambda`` per hour; an
    interrupted attempt loses its work and pays a fixed restart overhead
    ``R`` before retrying.  A job needing ``T`` uninterrupted hours then
    completes in expectation in

        E[T_total] = (e^{lambda T} - 1) (1/lambda + R)

    (renewal argument: E = E[min(U, T)] + P(U < T) (R + E) with
    U ~ Exp(lambda)).  ``E`` is exactly ``T`` at rate 0, is monotone
    non-decreasing in the rate, and blows up exponentially in
    ``lambda T`` — which is precisely why long jobs on flaky spot
    capacity must lose to on-demand while short jobs keep the discount.
    ``expected_completion_time_s`` / ``expected_cost_usd`` are the
    vectorized closed forms the engine broadcasts over the whole
    (machine x placement x context x scale-out) grid;
    ``realized_completion_time_s`` draws one seeded realization for the
    evaluation replay's realized-cost scoring.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Iterable, Mapping, Optional, Sequence, Tuple

import numpy as np

#: purchase options a placement can name (the wire vocabulary)
ON_DEMAND = "on_demand"
SPOT = "spot"
PURCHASE_OPTIONS = (ON_DEMAND, SPOT)

#: zone name used by ``PriceBook.flat`` when wrapping a legacy price dict
DEFAULT_ZONE = "default"

#: cap on ``lambda * T`` inside ``expm1``: e^50 ~ 5e21 keeps the expected
#: cost finite (so argmin selection stays well defined) while still making
#: any such placement lose to literally anything else on the grid
_LAMT_MAX = 50.0


class MarketError(ValueError):
    """Typed market-model rejection (missing/invalid price, unknown zone
    or purchase option, empty placement constraint).  A ``ValueError``
    subclass so the gateway's error classification answers it as a
    ``bad_request`` envelope."""


@dataclass(frozen=True)
class Placement:
    """One purchasable location: an availability zone + purchase option."""
    zone: str
    option: str = ON_DEMAND

    def __post_init__(self):
        if self.option not in PURCHASE_OPTIONS:
            raise MarketError(
                f"unknown purchase option {self.option!r} for zone "
                f"{self.zone!r} (valid: {', '.join(PURCHASE_OPTIONS)})")


def validate_prices(prices: Mapping[str, float],
                    machine_types: Iterable[str]) -> None:
    """Require a positive finite $/node-hour price for every machine type.

    Construction-time guard for the legacy flat-dict cost model: a machine
    type absent from the dict used to surface as a bare ``KeyError`` deep
    in grid scoring, and a zero/negative price silently won every
    cheapest-cost selection."""
    for m in machine_types:
        if m not in prices:
            known = ", ".join(sorted(map(repr, prices))) or "none"
            raise MarketError(
                f"no $/node-hour price for machine type {m!r} "
                f"(priced machine types: {known})")
        p = prices[m]
        try:
            p = float(p)
        except (TypeError, ValueError):
            p = math.nan
        if not math.isfinite(p) or p <= 0.0:
            raise MarketError(
                f"invalid price {prices[m]!r} for machine type {m!r}: "
                "every price must be a positive finite $/node-hour")


# ---------------------------- interruption math ----------------------------

def expected_completion_time_s(runtime_s, rate_per_hour,
                               restart_overhead_s: float):
    """E[wall-clock seconds to completion] under Poisson interruptions.

    ``runtime_s`` and ``rate_per_hour`` broadcast (numpy semantics); rate
    0 returns ``runtime_s`` exactly.  Monotone non-decreasing in the rate
    and always >= ``runtime_s``."""
    t = np.asarray(runtime_s, np.float64)
    lam = np.asarray(rate_per_hour, np.float64)
    t_h = t / 3600.0
    r_h = float(restart_overhead_s) / 3600.0
    # Cap the RATE (not the lam*t product) at the overflow guard: capping
    # the product alone would freeze expm1 while 1/lam kept shrinking,
    # making E[t] locally DECREASING in the rate past the cap.  Clamping
    # lam to cap/t keeps the exact formula below the cap and holds E[t]
    # constant above it, preserving monotonicity.
    safe = np.where(lam > 0.0, lam, 1.0)
    lam_cap = np.where(t_h > 0.0,
                       _LAMT_MAX / np.where(t_h > 0.0, t_h, 1.0), np.inf)
    safe = np.minimum(safe, lam_cap)
    e_h = np.expm1(safe * t_h) * (1.0 / safe + r_h)
    return np.where(lam > 0.0, e_h * 3600.0, t)


def expected_cost_usd(runtime_s, price_per_hour, nodes, rate_per_hour,
                      restart_overhead_s: float):
    """Interruption-adjusted expected $ cost: price x E[hours] x nodes."""
    e_s = expected_completion_time_s(runtime_s, rate_per_hour,
                                     restart_overhead_s)
    return np.asarray(price_per_hour, np.float64) * (e_s / 3600.0) \
        * np.asarray(nodes, np.float64)


def realized_completion_time_s(runtime_s: float, rate_per_hour: float,
                               restart_overhead_s: float, rng,
                               max_restarts: int = 100_000) -> float:
    """One seeded realization of the interruption process.

    Draws Exp(rate) interruption times until an attempt survives the full
    ``runtime_s``; every failed attempt contributes its partial progress
    plus the restart overhead.  Expectation over ``rng`` matches
    ``expected_completion_time_s``."""
    t = float(runtime_s)
    rate = float(rate_per_hour)
    if rate <= 0.0 or t <= 0.0:
        return t
    total = 0.0
    mean_gap_s = 3600.0 / rate
    for _ in range(max_restarts):
        u = float(rng.exponential(mean_gap_s))
        if u >= t:
            return total + t
        total += u + float(restart_overhead_s)
    return total + t        # pathological rate: cap the retry loop


# -------------------------------- PriceBook --------------------------------

class PriceBook:
    """Validated per-(machine, zone, purchase option) market state.

    ``prices`` maps ``(machine_type, zone, option)`` to a price *series*
    (a scalar or a 1-D sequence of $/node-hour over ticks); ``tick``
    indexes the current point in time (series shorter than the tick wrap
    around).  ``interruption`` maps ``(zone, option)`` to an hourly
    interruption rate — required for every spot placement, forced to 0
    for on-demand.  Construction validates everything up front:

    * every price finite and > 0 (``MarketError`` otherwise — a zero or
      negative price would win every cheapest-cost selection);
    * dense coverage — every machine priced in every placement the book
      lists (a sparse book would make argmin over the grid ill-posed);
    * every spot placement carries a finite rate >= 0, and no rate names
      a placement the book does not price.

    ``restart_overhead_s`` is the fixed per-interruption restart cost the
    expected-completion model amortizes against predicted runtime.
    """

    def __init__(self, prices: Mapping[Tuple[str, str, str], object],
                 interruption: Optional[Mapping[Tuple[str, str],
                                               float]] = None,
                 *, restart_overhead_s: float = 120.0):
        if not prices:
            raise MarketError("empty price book: no (machine type, zone, "
                              "purchase option) prices given")
        if not (math.isfinite(float(restart_overhead_s))
                and float(restart_overhead_s) >= 0.0):
            raise MarketError(
                f"invalid restart overhead {restart_overhead_s!r}: must be "
                "a finite number of seconds >= 0")
        self.restart_overhead_s = float(restart_overhead_s)
        series: Dict[Tuple[str, str, str], np.ndarray] = {}
        for key, raw in prices.items():
            try:
                m, z, o = key
            except (TypeError, ValueError):
                raise MarketError(
                    f"price key {key!r} is not a (machine type, zone, "
                    "purchase option) triple") from None
            Placement(str(z), str(o))       # validates the option name
            vec = np.atleast_1d(np.asarray(raw, np.float64))
            if vec.ndim != 1 or len(vec) == 0:
                raise MarketError(
                    f"price series for machine {m!r} zone {z!r} option "
                    f"{o!r} must be a scalar or non-empty 1-D sequence")
            if not (np.isfinite(vec).all() and (vec > 0.0).all()):
                raise MarketError(
                    f"invalid price in series for machine {m!r} zone "
                    f"{z!r} option {o!r}: every price must be a positive "
                    "finite $/node-hour")
            series[(str(m), str(z), str(o))] = vec
        self._series = series
        self.machines: Tuple[str, ...] = tuple(
            sorted({k[0] for k in series}))
        self.placements: Tuple[Placement, ...] = tuple(
            Placement(z, o)
            for z, o in sorted({(k[1], k[2]) for k in series}))
        for m in self.machines:             # dense (machine x placement)
            for p in self.placements:
                if (m, p.zone, p.option) not in series:
                    raise MarketError(
                        f"machine type {m!r} has no price for zone "
                        f"{p.zone!r} option {p.option!r}: the book must "
                        "price every machine in every placement it lists")
        rates: Dict[Tuple[str, str], float] = {}
        interruption = dict(interruption or {})
        for key, r in interruption.items():
            try:
                z, o = key
            except (TypeError, ValueError):
                raise MarketError(
                    f"interruption key {key!r} is not a (zone, purchase "
                    "option) pair") from None
            if Placement(str(z), str(o)) not in self.placements:
                raise MarketError(
                    f"interruption rate given for zone {z!r} option "
                    f"{o!r}, but the book prices no such placement")
            r = float(r)
            if not math.isfinite(r) or r < 0.0:
                raise MarketError(
                    f"invalid interruption rate {r!r} for zone {z!r} "
                    f"option {o!r}: must be finite and >= 0 per hour")
            rates[(str(z), str(o))] = r
        for p in self.placements:
            if p.option == ON_DEMAND:
                rates.setdefault((p.zone, p.option), 0.0)
            elif (p.zone, p.option) not in rates:
                raise MarketError(
                    f"no interruption rate for spot placement zone "
                    f"{p.zone!r}: every spot placement must declare one "
                    "(0.0 for never-interrupted capacity)")
        self._rates = rates
        self.n_ticks = max(len(v) for v in series.values())
        self.tick = 0

    # ------------------------- construction helpers -----------------------
    @classmethod
    def flat(cls, prices: Mapping[str, float], zone: str = DEFAULT_ZONE,
             *, restart_overhead_s: float = 120.0) -> "PriceBook":
        """Wrap a legacy ``{machine: $/hour}`` dict as a single-zone,
        on-demand-only, interruption-free book (market scoring then
        reduces exactly to the static cost model)."""
        validate_prices(prices, prices)
        return cls({(m, zone, ON_DEMAND): float(p)
                    for m, p in prices.items()},
                   restart_overhead_s=restart_overhead_s)

    def naive_view(self) -> "PriceBook":
        """Same prices, every interruption rate forced to 0 — the
        cheapest-listed-price baseline the replay scores against."""
        book = PriceBook(dict(self._series),
                         {k: 0.0 for k in self._rates},
                         restart_overhead_s=self.restart_overhead_s)
        book.tick = self.tick
        return book

    # ------------------------------ time ----------------------------------
    def seek(self, tick: int) -> None:
        """Position the book at ``tick`` (series wrap modulo length)."""
        self.tick = int(tick)

    def advance(self, n: int = 1) -> None:
        self.tick += int(n)

    # ----------------------------- lookups --------------------------------
    def resolve(self, zones: Optional[Sequence[str]] = None,
                options: Optional[Sequence[str]] = None
                ) -> Tuple[Placement, ...]:
        """Placements matching the constraints (None = unconstrained).

        Empty constraint sets and names the book does not know are typed
        ``MarketError``s naming the offending zone/option."""
        known_zones = tuple(dict.fromkeys(p.zone for p in self.placements))
        known_opts = tuple(dict.fromkeys(p.option for p in self.placements))
        if zones is not None:
            zones = tuple(str(z) for z in zones)
            if not zones:
                raise MarketError(
                    "empty placement constraint: zones=() matches no "
                    f"placement (known zones: {', '.join(known_zones)})")
            for z in zones:
                if z not in known_zones:
                    raise MarketError(
                        f"unknown zone {z!r} (known zones: "
                        f"{', '.join(known_zones)})")
        if options is not None:
            options = tuple(str(o) for o in options)
            if not options:
                raise MarketError(
                    "empty placement constraint: purchase_options=() "
                    "matches no placement (known options: "
                    f"{', '.join(known_opts)})")
            for o in options:
                if o not in known_opts:
                    raise MarketError(
                        f"unknown purchase option {o!r} (known options: "
                        f"{', '.join(known_opts)})")
        out = tuple(p for p in self.placements
                    if (zones is None or p.zone in zones)
                    and (options is None or p.option in options))
        if not out:
            raise MarketError(
                f"no placement matches zones={zones!r} "
                f"purchase_options={options!r} (the book prices: "
                f"{', '.join(f'{p.zone}/{p.option}' for p in self.placements)})")
        return out

    def _at(self, vec: np.ndarray, tick: Optional[int]) -> float:
        t = self.tick if tick is None else int(tick)
        return float(vec[t % len(vec)])

    def price_of(self, machine: str, zone: str, option: str,
                 tick: Optional[int] = None) -> float:
        """Current listed $/node-hour for one (machine, placement)."""
        vec = self._series.get((machine, zone, option))
        if vec is None:
            priced = ", ".join(map(repr, self.machines))
            raise MarketError(
                f"machine type {machine!r} has no price for zone {zone!r} "
                f"option {option!r} in the market book (priced machine "
                f"types: {priced})")
        return self._at(vec, tick)

    def rate_of(self, zone: str, option: str) -> float:
        """Hourly interruption rate of one placement."""
        r = self._rates.get((zone, option))
        if r is None:
            raise MarketError(
                f"no placement zone {zone!r} option {option!r} in the "
                "market book")
        return r

    def price_matrix(self, machines: Sequence[str],
                     placements: Optional[Sequence[Placement]] = None,
                     tick: Optional[int] = None) -> np.ndarray:
        """[M, P] listed prices at the current (or given) tick."""
        placements = self.placements if placements is None \
            else tuple(placements)
        return np.array(
            [[self.price_of(m, p.zone, p.option, tick) for p in placements]
             for m in machines], np.float64)

    def rates(self, placements: Optional[Sequence[Placement]] = None
              ) -> np.ndarray:
        """[P] hourly interruption rates."""
        placements = self.placements if placements is None \
            else tuple(placements)
        return np.array([self.rate_of(p.zone, p.option)
                         for p in placements], np.float64)

    def validate_machines(self, machines: Iterable[str]) -> None:
        """Construction-time coverage check: every machine priced."""
        for m in machines:
            if (m, self.placements[0].zone,
                    self.placements[0].option) not in self._series:
                priced = ", ".join(map(repr, self.machines)) or "none"
                raise MarketError(
                    f"machine type {m!r} has no price in the market book "
                    f"(priced machine types: {priced})")
