from repro.core.models import ernest, gbm, linear, optimistic  # noqa: F401
from repro.core.models.api import (FittedModel, ModelSpec, get_model,
                                   model_names, register_model)
