"""Common runtime-model API (paper §III-C.c: custom models share one API).

Models are *functional* so the C3O predictor can ``vmap`` leave-one-out
cross-validation over fold weight masks — every fold is a weighted refit on
identical static shapes, which jit+vmap turns into one batched computation
(the paper's sklearn implementation refits sequentially; this is our
beyond-paper systems contribution for the model-selection hot loop).

Each model is three *static* functions (stable identities, so jax.jit caches
one executable per data shape, not per train/test split):

  make_aux(X_np)            -> aux pytree of arrays (sort orders, group
                               one-hots, ...), shape-stable for fixed n,d
  fit(X, y, w, aux)         -> params pytree   (weighted; w=0 drops a sample)
  predict(params, X, aux)   -> yhat
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class ModelSpec:
    name: str
    make_aux: Callable          # (X np [n,d]) -> aux pytree
    fit: Callable               # (X, y, w, aux) -> params
    predict: Callable           # (params, X, aux) -> yhat


_REGISTRY: Dict[str, ModelSpec] = {}


def register_model(spec: ModelSpec) -> ModelSpec:
    _REGISTRY[spec.name] = spec
    return spec


def get_model(name: str) -> ModelSpec:
    from repro.core.models import ernest, gbm, linear, optimistic  # noqa: F401
    return _REGISTRY[name]


def model_names():
    from repro.core.models import ernest, gbm, linear, optimistic  # noqa: F401
    return sorted(_REGISTRY)


class FittedModel:
    """Object wrapper for single-fit use (configurator, examples).

    Fit and predict go through the engine's process-wide executable caches
    (repro.core.engine): constructing many FittedModels for the same spec
    and data shape reuses one compiled executable instead of retracing.
    """

    def __init__(self, spec: ModelSpec, X: np.ndarray, y: np.ndarray,
                 w: Optional[np.ndarray] = None):
        from repro.core import engine      # local import: engine imports us
        X = np.asarray(X, np.float64)
        self.spec = spec
        self.aux = spec.make_aux(X)
        w = np.ones(len(y)) if w is None else w
        self.params = engine.fit_executable(spec)(
            jnp.asarray(X, jnp.float32), jnp.asarray(y, jnp.float32),
            jnp.asarray(w, jnp.float32), self.aux)
        self.name = spec.name

    @classmethod
    def from_params(cls, spec: ModelSpec, X: np.ndarray,
                    params) -> "FittedModel":
        """Rebuild a fitted model from persisted params WITHOUT fitting.

        ``aux`` is recomputed from the training features (deterministic,
        host-side numpy); ``params`` is the fit-output pytree (possibly with
        numpy leaves from deserialization) — no fit executable is touched,
        which is what lets a fresh process warm-start from a saved store."""
        self = cls.__new__(cls)
        X = np.asarray(X, np.float64)
        self.spec = spec
        self.aux = spec.make_aux(X)
        self.params = jax.tree_util.tree_map(jnp.asarray, params)
        self.name = spec.name
        return self

    def predict_device(self, X) -> jax.Array:
        """Device-resident prediction (no host sync) — lets grid sweeps
        pipeline many dispatches before pulling results."""
        from repro.core import engine
        return engine.predict(self.spec, self.params, X, self.aux)

    def predict(self, X) -> np.ndarray:
        return np.asarray(self.predict_device(X), np.float64)
