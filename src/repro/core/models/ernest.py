"""Ernest baseline (Venkataraman et al., NSDI'16), paper §VI baseline.

t(s, z) = θ0 + θ1 * z/s + θ2 * log(s) + θ3 * s,   θ >= 0  (NNLS)

Only understands dataset size (column 1) and scale-out (column 0) — by
construction it cannot model other context features, which is exactly the
property the paper's Table II exposes on *global* training data.

NNLS via projected gradient on the normal equations (Lipschitz step), which
is jit/vmap-friendly (fixed iteration count), unlike Lawson–Hanson.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.models.api import ModelSpec, register_model


class ErnestParams(NamedTuple):
    theta: jnp.ndarray       # [4] >= 0
    scale: jnp.ndarray       # [] target normalization


def _basis(X):
    s = jnp.maximum(X[:, 0], 1.0)
    z = X[:, 1] if X.shape[1] > 1 else jnp.ones_like(s)
    return jnp.stack([jnp.ones_like(s), z / s, jnp.log(s), s], axis=1)


def ernest_fit(X, y, w, iters: int = 400) -> ErnestParams:
    A = _basis(X)
    w = w.astype(jnp.float32)
    scale = jnp.maximum((w * jnp.abs(y)).sum() / jnp.maximum(w.sum(), 1e-12),
                        1e-12)
    yn = y / scale
    # column-normalize for conditioning
    cn = jnp.maximum(jnp.sqrt((w[:, None] * A ** 2).sum(0)), 1e-12)
    An = A / cn
    G = (An * w[:, None]).T @ An
    b = (An * w[:, None]).T @ yn
    L = jnp.linalg.norm(G, ord=2) + 1e-6         # Lipschitz constant

    def step(th, _):
        g = G @ th - b
        return jnp.maximum(th - g / L, 0.0), None

    th0 = jnp.maximum(b / jnp.maximum(jnp.diag(G), 1e-9), 0.0)
    th, _ = jax.lax.scan(step, th0, None, length=iters)
    return ErnestParams(th / cn, scale)


def ernest_predict(p: ErnestParams, X) -> jnp.ndarray:
    return (_basis(X) @ p.theta) * p.scale


register_model(ModelSpec(
    "ernest",
    lambda X: {},
    lambda X, y, w, aux: ernest_fit(X, y, w),
    lambda p, X, aux: ernest_predict(p, X)))
