"""Gradient-boosted regression trees, fully in JAX (paper §V-A).

Exact greedy splits over presorted features; weighted samples (w=0 excludes a
sample, enabling vmapped leave-one-out refits).  Tree structure is a static
level-order array layout, so fitting is jit-compatible: python loops only over
static depth/feature counts, ``lax.scan`` over boosting rounds.

Leaf values are computed from predict-consistent routing (samples routed with
the same (feature, threshold, <=) rule used at inference), so duplicate
feature values can never cause fit/predict disagreement.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.models.api import ModelSpec, register_model

NEG = -1e30


class GBMParams(NamedTuple):
    f0: jnp.ndarray           # [] base prediction
    feat: jnp.ndarray         # [T, n_internal] int32
    thr: jnp.ndarray          # [T, n_internal] f32
    leaf: jnp.ndarray         # [T, n_leaves] f32
    y_scale: jnp.ndarray      # [] normalization


def _route(feat, thr, X):
    """Route samples down one tree. feat/thr [n_internal], X [n,d] ->
    leaf index [n]."""
    n = X.shape[0]
    idx = jnp.zeros(n, jnp.int32)           # node id in level order
    depth = int(np.log2(feat.shape[0] + 1))
    for _ in range(depth):
        f = feat[idx]
        t = thr[idx]
        go_right = X[jnp.arange(n), f] > t
        idx = 2 * idx + 1 + go_right.astype(jnp.int32)
    return idx - feat.shape[0]              # leaf-local index


def _fit_tree(X, r, w, orders, depth):
    """One regression tree minimizing weighted MSE on residuals r."""
    n, d = X.shape
    n_internal = 2 ** depth - 1
    feat = jnp.zeros(n_internal, jnp.int32)
    thr = jnp.full(n_internal, jnp.inf, jnp.float32)
    node = jnp.zeros(n, jnp.int32)          # local node id at current level

    for level in range(depth):
        M = 2 ** level
        best_gain = jnp.full((M,), NEG)
        best_feat = jnp.zeros((M,), jnp.int32)
        best_thr = jnp.full((M,), jnp.inf, jnp.float32)
        for f in range(d):
            o = orders[f]
            a_s, w_s, r_s, x_s = node[o], w[o], r[o], X[o, f]
            oh = (a_s[:, None] == jnp.arange(M)).astype(jnp.float32)
            ws = w_s[:, None] * oh                       # [n, M]
            cw = jnp.cumsum(ws, 0)
            cwr = jnp.cumsum(ws * r_s[:, None], 0)
            tw, twr = cw[-1], cwr[-1]
            lw, lr_ = cw, cwr
            rw, rr = tw - cw, twr - cwr
            gain = (jnp.square(lr_) / jnp.maximum(lw, 1e-12)
                    + jnp.square(rr) / jnp.maximum(rw, 1e-12)
                    - jnp.square(twr) / jnp.maximum(tw, 1e-12))
            x_next = jnp.concatenate([x_s[1:], x_s[-1:]])
            valid = (lw > 1e-9) & (rw > 1e-9) & ((x_next > x_s)[:, None])
            gain = jnp.where(valid, gain, NEG)
            gi = jnp.argmax(gain, axis=0)                # [M]
            gv = jnp.take_along_axis(gain, gi[None], 0)[0]
            tv = 0.5 * (x_s[gi] + x_next[gi])
            better = gv > best_gain
            best_gain = jnp.where(better, gv, best_gain)
            best_feat = jnp.where(better, f, best_feat)
            best_thr = jnp.where(better, tv.astype(jnp.float32), best_thr)
        base = 2 ** level - 1
        feat = feat.at[base + jnp.arange(M)].set(best_feat)
        # unsplittable nodes: thr=inf sends everything left
        thr = thr.at[base + jnp.arange(M)].set(
            jnp.where(best_gain > NEG / 2, best_thr, jnp.inf))
        # descend
        f_cur = best_feat[node]
        t_cur = jnp.where(best_gain > NEG / 2, best_thr, jnp.inf)[node]
        node = 2 * node + (X[jnp.arange(n), f_cur] > t_cur).astype(jnp.int32)

    # predict-consistent leaf values
    leaf_idx = _route(feat, thr, X)
    n_leaves = 2 ** depth
    oh = (leaf_idx[:, None] == jnp.arange(n_leaves)).astype(jnp.float32)
    sw = (w[:, None] * oh).sum(0)
    swr = (w[:, None] * oh * r[:, None]).sum(0)
    leaf = swr / jnp.maximum(sw, 1e-12)
    return feat, thr, leaf


def gbm_fit(X, y, w, orders, *, n_trees=100, depth=3, lr=0.1,
            log_target=False) -> GBMParams:
    """log_target: fit log(y) (multiplicative runtime surfaces become
    additive, which piecewise-constant trees approximate far better)."""
    w = w.astype(jnp.float32)
    if log_target:
        y = jnp.log(jnp.maximum(y, 1e-6))
        y_scale = jnp.asarray(0.0)       # sentinel: log mode
        yn = y
        wsum = jnp.maximum(w.sum(), 1e-12)
    else:
        wsum = jnp.maximum(w.sum(), 1e-12)
        y_scale = jnp.maximum((w * jnp.abs(y)).sum() / wsum, 1e-12)
        yn = y / y_scale
    f0 = (w * yn).sum() / wsum
    pred = jnp.full_like(yn, f0)

    def boost(pred, _):
        r = yn - pred
        feat, thr, leaf = _fit_tree(X, r, w, orders, depth)
        leaf_idx = _route(feat, thr, X)
        pred = pred + lr * leaf[leaf_idx]
        return pred, (feat, thr, leaf)

    _, (feats, thrs, leaves) = jax.lax.scan(boost, pred, None, length=n_trees)
    return GBMParams(f0, feats, thrs, lr * leaves, y_scale)


def gbm_predict(params: GBMParams, X) -> jnp.ndarray:
    def one(carry, tree):
        feat, thr, leaf = tree
        return carry + leaf[_route(feat, thr, X)], None

    out, _ = jax.lax.scan(one, jnp.full(X.shape[0], params.f0),
                          (params.feat, params.thr, params.leaf))
    return jnp.where(params.y_scale == 0.0,
                     jnp.exp(jnp.clip(out, -30.0, 30.0)),
                     out * jnp.maximum(params.y_scale, 1e-12))


def _make_aux(X: np.ndarray):
    return {"orders": jnp.asarray(np.argsort(X, axis=0).T)}   # [d, n]


def _fit(X, y, w, aux):
    return gbm_fit(X, y, w, aux["orders"], n_trees=200, depth=3, lr=0.1,
                   log_target=True)


def _predict(params, X, aux):
    return gbm_predict(params, X)


# canonical spec object: the engine routes Pallas-kernel inference on spec
# identity (a re-registered "gbm" with different params must not match)
GBM_SPEC = register_model(ModelSpec("gbm", _make_aux, _fit, _predict))
