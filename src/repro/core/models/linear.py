"""Weighted ridge regression + polynomial bases (building blocks for BOM)."""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.core.models.api import ModelSpec, register_model


class RidgeParams(NamedTuple):
    beta: jnp.ndarray       # [d+1] (bias last)
    mu: jnp.ndarray         # [d] feature means
    sd: jnp.ndarray         # [d] feature stds


def ridge_fit(X, y, w, lam=1e-4) -> RidgeParams:
    w = w.astype(jnp.float32)
    wsum = jnp.maximum(w.sum(), 1e-12)
    mu = (w[:, None] * X).sum(0) / wsum
    var = (w[:, None] * jnp.square(X - mu)).sum(0) / wsum
    sd = jnp.sqrt(jnp.maximum(var, 1e-12))
    Xn = (X - mu) / sd
    A = jnp.concatenate([Xn, jnp.ones((X.shape[0], 1))], 1)
    Aw = A * w[:, None]
    G = A.T @ Aw + lam * jnp.eye(A.shape[1])
    b = Aw.T @ y
    beta = jnp.linalg.solve(G, b)
    return RidgeParams(beta, mu, sd)


def ridge_predict(p: RidgeParams, X) -> jnp.ndarray:
    Xn = (X - p.mu) / p.sd
    A = jnp.concatenate([Xn, jnp.ones((X.shape[0], 1))], 1)
    return A @ p.beta


def poly_basis(s, degree: int):
    """s [n] -> [n, degree] powers 1..degree (no constant)."""
    return jnp.stack([s ** k for k in range(1, degree + 1)], axis=1)


register_model(ModelSpec(
    "linreg",
    lambda X: {},
    lambda X, y, w, aux: ridge_fit(X, y, w),
    lambda p, X, aux: ridge_predict(p, X)))
