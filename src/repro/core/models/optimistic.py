"""Optimistic models (paper §V-B): SSM (x) IBM factorization.

Assumes runtime-influencing factors are pairwise independent:
    t(s, ctx) = IBM(ctx) * g(s),   g(1) = 1
The scale-out-to-speedup model (SSM) g is learned from *context groups* —
sets of runs identical in every feature except the scale-out (column 0).
Groups with fewer than two (weighted) members carry no scale-out signal and
are excluded from the SSM fit; if no group qualifies, the SSM is
underdetermined and predictions degrade sharply — reproducing the paper's
observation that BOM is "gravely incorrect" below ~10 training points.

  BOM: third-degree-polynomial SSM, linear-regression IBM
  OGB: GBM SSM, GBM IBM

The group one-hot is padded to [n, n] columns so its shape depends only on
the training-set size: jit compiles once per scenario, not per split.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.core.models.api import ModelSpec, register_model
from repro.core.models.gbm import gbm_fit, gbm_predict
from repro.core.models.linear import ridge_fit, ridge_predict

MIN_RATIO = 0.05


class OptimisticParams(NamedTuple):
    ssm: object               # RidgeParams (poly basis) or GBMParams
    ssm_ref: jnp.ndarray      # g(1) normalizer
    ibm: object               # RidgeParams or GBMParams


def _poly_feats(s):
    s = jnp.maximum(s, 1e-6)
    return jnp.stack([s, s ** 2, s ** 3], axis=1)


def _split(X):
    s = X[:, 0]
    ctx = X[:, 1:] if X.shape[1] > 1 else jnp.zeros((X.shape[0], 1))
    return s, ctx


def _make_aux(X: np.ndarray):
    n = X.shape[0]
    ctx = np.round(X[:, 1:].astype(np.float64), 9)
    if ctx.shape[1] == 0:
        gid = np.zeros(n, np.int64)
    else:
        _, gid = np.unique(ctx, axis=0, return_inverse=True)
    onehot = np.zeros((n, n), np.float32)        # padded to n groups
    onehot[np.arange(n), gid] = 1.0
    s_np = X[:, :1]
    ctx_np = X[:, 1:] if X.shape[1] > 1 else np.zeros((n, 1))
    return {"onehot": jnp.asarray(onehot),
            "ssm_orders": jnp.asarray(np.argsort(s_np, axis=0).T),
            "ibm_orders": jnp.asarray(np.argsort(ctx_np, axis=0).T)}


def _make(ssm_kind: str, ibm_kind: str, name: str):
    def ssm_fit(s, ratio, w, aux):
        if ssm_kind == "poly3":
            # cubic in log space: g(s) strictly positive; wild coefficients
            # (the small-data failure mode) still blow up via exp
            return ridge_fit(_poly_feats(s),
                             jnp.log(jnp.maximum(ratio, 1e-3)), w, lam=3e-3)
        return gbm_fit(s[:, None], ratio, w, aux["ssm_orders"],
                       n_trees=50, depth=2, lr=0.15, log_target=True)

    def ssm_eval(p, s):
        if ssm_kind == "poly3":
            return jnp.exp(jnp.clip(ridge_predict(p, _poly_feats(s)),
                                    -4.0, 4.0))
        return gbm_predict(p, s[:, None])

    def ibm_fit(ctx, t1, w, aux):
        if ibm_kind == "linreg":
            return ridge_fit(ctx, t1, w)
        return gbm_fit(ctx, t1, w, aux["ibm_orders"], n_trees=100, depth=3,
                       lr=0.1, log_target=True)

    def ibm_eval(p, ctx):
        if ibm_kind == "linreg":
            return ridge_predict(p, ctx)
        return gbm_predict(p, ctx)

    def fit(X, y, w, aux):
        s, ctx = _split(X)
        onehot = aux["onehot"]
        w = w.astype(jnp.float32)
        logt = jnp.log(jnp.maximum(y, 1e-6))
        wg = w[:, None] * onehot                             # [n, G]
        cnt = wg.sum(0)
        beta = (wg * logt[:, None]).sum(0) / jnp.maximum(cnt, 1e-12)
        eligible_g = (cnt >= 1.5).astype(jnp.float32)        # >=2 members
        base = jnp.exp(onehot @ beta)
        ratio = y / jnp.maximum(base, 1e-9)
        w_ssm = w * (onehot @ eligible_g)
        ssm_p = ssm_fit(s, ratio, w_ssm, aux)
        g_raw = jnp.maximum(ssm_eval(ssm_p, s), MIN_RATIO)
        g1 = jnp.maximum(ssm_eval(ssm_p, jnp.ones((1,)))[0], MIN_RATIO)
        t1 = y / (g_raw / g1)                                # project s -> 1
        ibm_p = ibm_fit(ctx, t1, w, aux)
        return OptimisticParams(ssm_p, g1, ibm_p)

    def predict(p: OptimisticParams, X, aux):
        s, ctx = _split(X)
        g = jnp.maximum(ssm_eval(p.ssm, s), MIN_RATIO) / p.ssm_ref
        return ibm_eval(p.ibm, ctx) * g

    return ModelSpec(name, _make_aux, fit, predict)


register_model(_make("poly3", "linreg", "bom"))
register_model(_make("gbm", "gbm", "ogb"))
