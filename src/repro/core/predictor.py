"""The C3O runtime predictor (paper §V): dynamic model selection.

On every (re)fit, all candidate models are cross-validated on the current
training data with leave-one-out folds (capped, paper §VI-C: the selection
phase must be bounded as data grows) and the lowest-MAPE model is selected.
The CV residuals of the selected model calibrate the Gaussian error model
(mu, sigma) the configurator's confidence formula consumes (paper §IV-B).

All folds of one model are evaluated as a single vmapped, jitted computation.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.models.api import get_model

DEFAULT_MODELS = ("ernest", "gbm", "bom", "ogb")


@functools.lru_cache(maxsize=None)
def _cv_fn(spec):
    """Batched LOO-CV executable per model spec (stable identity -> one jit
    cache entry per data shape, shared across all train/test splits)."""

    def one_fold(X, y, aux, w, i):
        params = spec.fit(X, y, w, aux)
        return spec.predict(params, X[i][None, :], aux)[0]

    return jax.jit(jax.vmap(one_fold, in_axes=(None, None, None, 0, 0)))


def _cv_predictions(spec, X, y, folds: np.ndarray):
    """Held-out predictions for each LOO fold (vmapped weighted refits)."""
    n = len(y)
    aux = spec.make_aux(np.asarray(X, np.float64))
    Xj = jnp.asarray(X, jnp.float32)
    yj = jnp.asarray(y, jnp.float32)
    W = 1.0 - jax.nn.one_hot(jnp.asarray(folds), n)          # [F, n]
    out = _cv_fn(spec)(Xj, yj, aux, W, jnp.asarray(folds))
    return np.asarray(out, np.float64)


@dataclass
class C3OPredictor:
    model_names: Sequence[str] = DEFAULT_MODELS
    max_cv_folds: int = 30
    seed: int = 0

    # set by fit():
    selected: Optional[str] = None
    cv_mape: Dict[str, float] = field(default_factory=dict)
    mu: float = 0.0
    sigma: float = 0.0

    def fit(self, X: np.ndarray, y: np.ndarray) -> "C3OPredictor":
        X = np.asarray(X, np.float64)
        y = np.asarray(y, np.float64)
        n = len(y)
        rng = np.random.default_rng(self.seed)
        folds = (np.arange(n) if n <= self.max_cv_folds
                 else rng.choice(n, self.max_cv_folds, replace=False))
        best, best_err = None, np.inf
        residuals = None
        for name in self.model_names:
            spec = get_model(name)
            pred = _cv_predictions(spec, X, y, folds)
            pred = np.nan_to_num(pred, nan=1e12, posinf=1e12, neginf=-1e12)
            ape = np.abs(pred - y[folds]) / np.maximum(np.abs(y[folds]), 1e-9)
            mape = float(np.mean(ape))
            self.cv_mape[name] = mape
            if mape < best_err:
                best, best_err = name, mape
                residuals = pred - y[folds]          # seconds, signed
        self.selected = best
        self.mu = float(np.mean(residuals))
        self.sigma = float(np.std(residuals) + 1e-12)
        from repro.core.models.api import FittedModel
        self._fitted = FittedModel(get_model(best), X, y)
        return self

    def predict(self, X) -> np.ndarray:
        return self._fitted.predict(np.asarray(X, np.float64))

    def predict_with_error(self, X) -> Tuple[np.ndarray, float, float]:
        """(predictions, mu, sigma) — sigma from CV residuals (paper §IV-B)."""
        return self.predict(X), self.mu, self.sigma


def evaluate_split(model_names, X_tr, y_tr, X_te, y_te,
                   include_c3o: bool = True, max_cv_folds: int = 20,
                   seed: int = 0) -> Dict[str, float]:
    """MAPE of each model (and the C3O predictor) for one train/test split.

    This is the evaluation protocol of paper §VI-C: individual models are fit
    on the train split and scored on the test split; the C3O row additionally
    runs model selection (LOO on the train split) before scoring.
    """
    from repro.core.models.api import FittedModel
    out = {}
    for name in model_names:
        fm = FittedModel(get_model(name), X_tr, y_tr)
        pred = np.nan_to_num(fm.predict(X_te), nan=1e12, posinf=1e12,
                             neginf=-1e12)
        out[name] = float(np.mean(np.abs(pred - y_te)
                                  / np.maximum(np.abs(y_te), 1e-9)))
    if include_c3o:
        p = C3OPredictor(model_names=model_names, max_cv_folds=max_cv_folds,
                         seed=seed).fit(X_tr, y_tr)
        pred = np.nan_to_num(p.predict(X_te), nan=1e12, posinf=1e12,
                             neginf=-1e12)
        out["c3o"] = float(np.mean(np.abs(pred - y_te)
                                   / np.maximum(np.abs(y_te), 1e-9)))
        out["c3o_selected"] = p.selected
    return out
