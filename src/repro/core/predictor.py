"""The C3O runtime predictor (paper §V): dynamic model selection.

On every (re)fit, all candidate models are cross-validated on the current
training data with leave-one-out folds (capped, paper §VI-C: the selection
phase must be bounded as data grows) and the lowest-MAPE model is selected.
The CV residuals of the selected model calibrate the Gaussian error model
(mu, sigma) the configurator's confidence formula consumes (paper §IV-B).

All models' folds dispatch as one pipelined batch through the prediction
engine (repro.core.engine): the fold-weight matrix is built once, every
model's vmapped refit + on-device MAPE/residual reduction is enqueued
back-to-back, and the host synchronizes a single time at the end.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core import engine
from repro.core.models.api import get_model

DEFAULT_MODELS = ("ernest", "gbm", "bom", "ogb")


@dataclass
class C3OPredictor:
    model_names: Sequence[str] = DEFAULT_MODELS
    max_cv_folds: int = 30
    seed: int = 0
    # pad the training rows to power-of-two buckets (0-weight rows are
    # inert for every weighted model): refitting against a store that
    # grows row by row — the evaluation replay plane's hot loop — keeps
    # hitting one compiled fit/CV executable per bucket instead of
    # retracing per exact store size.  Off by default: one-shot fits pay
    # nothing for exact shapes, and unpadded numerics stay the reference.
    pad_rows: bool = False

    # set by fit():
    selected: Optional[str] = None
    cv_mape: Dict[str, float] = field(default_factory=dict)
    mu: float = 0.0
    sigma: float = 0.0

    def fit_data(self, data, row_weight=None) -> "C3OPredictor":
        """Fit from a columnar ``RuntimeData`` view (typically a cached
        ``machine_view``): the assembled feature batch is adopted as-is —
        ``data.X`` is built once per (machine, data version) and reused by
        every dispatch downstream."""
        return self.fit(data.X, data.y, row_weight=row_weight)

    def fit(self, X: np.ndarray, y: np.ndarray,
            row_weight: Optional[np.ndarray] = None) -> "C3OPredictor":
        """``row_weight`` (fractional, [n]) down-weights suspect rows in
        CV selection AND the final fit — the trust plane derives it from
        contributor reputation (``RuntimeDataStore.row_weights``).  None
        keeps the exact unweighted path (byte-identical numerics)."""
        X = np.asarray(X, np.float64)
        y = np.asarray(y, np.float64)
        n = len(y)
        if row_weight is not None:
            row_weight = np.asarray(row_weight, np.float64)
            if row_weight.shape != (n,):
                raise ValueError(f"row_weight has shape {row_weight.shape},"
                                 f" expected ({n},)")
        rng = np.random.default_rng(self.seed)
        folds = (np.arange(n) if n <= self.max_cv_folds
                 else rng.choice(n, self.max_cv_folds, replace=False))
        w = row_weight
        if self.pad_rows:
            # always hand cv_select a weight vector — even when n already
            # sits on a bucket boundary — so the fold axis is bucketed too
            # and no store size compiles its own CV executable
            b = engine.bucket_rows(n)
            Xp = np.zeros((b, X.shape[1]), np.float64)
            Xp[:n] = X
            yp = np.ones(b, np.float64)           # inert targets (w=0)
            yp[:n] = y
            w = np.zeros(b, np.float64)
            w[:n] = 1.0 if row_weight is None else row_weight
            X, y = Xp, yp
        specs = [get_model(name) for name in self.model_names]
        best, mapes, mu, sigma = engine.cv_select(specs, X, y, folds,
                                                  row_weight=w)
        self.cv_mape.update(mapes)
        self.selected = best
        self.mu = mu
        self.sigma = sigma
        from repro.core.models.api import FittedModel
        self._fitted = FittedModel(get_model(best), X, y, w)
        return self

    # ------------------- warm-start persistence ---------------------------
    def export_state(self) -> Dict:
        """Everything a fresh process needs to serve predictions without
        refitting: selected model, its fitted params (numpy leaves, so the
        state is picklable without jax in the loop), and the CV calibration
        the configurator's confidence bounds consume."""
        if self.selected is None:
            raise ValueError("predictor not fitted; nothing to export")
        params_np = jax.tree_util.tree_map(lambda a: np.asarray(a),
                                           self._fitted.params)
        return {"model_names": tuple(self.model_names),
                "max_cv_folds": self.max_cv_folds,
                "seed": self.seed,
                "selected": self.selected,
                "cv_mape": dict(self.cv_mape),
                "mu": self.mu,
                "sigma": self.sigma,
                "params": params_np}

    @classmethod
    def from_state(cls, state: Dict, X: np.ndarray) -> "C3OPredictor":
        """Rebuild a fitted predictor from ``export_state`` output plus the
        training data it was fitted on (the store's rows for this machine
        type).  No fit or CV executable runs — only ``make_aux`` (numpy)."""
        from repro.core.models.api import FittedModel
        pred = cls(model_names=tuple(state["model_names"]),
                   max_cv_folds=int(state["max_cv_folds"]),
                   seed=int(state["seed"]))
        pred.selected = state["selected"]
        pred.cv_mape = dict(state["cv_mape"])
        pred.mu = float(state["mu"])
        pred.sigma = float(state["sigma"])
        pred._fitted = FittedModel.from_params(
            get_model(pred.selected), np.asarray(X, np.float64),
            state["params"])
        return pred

    def predict_device(self, X) -> jax.Array:
        """Device-resident batched prediction (no host sync); grid sweeps
        use this to pipeline dispatches across predictors."""
        return self._fitted.predict_device(np.asarray(X, np.float64))

    def predict(self, X) -> np.ndarray:
        return self._fitted.predict(np.asarray(X, np.float64))

    def predict_with_error(self, X) -> Tuple[np.ndarray, float, float]:
        """(predictions, mu, sigma) — sigma from CV residuals (paper §IV-B)."""
        return self.predict(X), self.mu, self.sigma


def evaluate_split(model_names, X_tr, y_tr, X_te, y_te,
                   include_c3o: bool = True, max_cv_folds: int = 20,
                   seed: int = 0) -> Dict[str, float]:
    """MAPE of each model (and the C3O predictor) for one train/test split.

    This is the evaluation protocol of paper §VI-C: individual models are fit
    on the train split and scored on the test split; the C3O row additionally
    runs model selection (LOO on the train split) before scoring.
    """
    from repro.core.models.api import FittedModel
    out = {}
    pending = []                # dispatch every model before the first sync
    for name in model_names:
        fm = FittedModel(get_model(name), X_tr, y_tr)
        pending.append((name, fm.predict_device(np.asarray(X_te, np.float64))))
    for name, p in pending:
        pred = np.nan_to_num(np.asarray(p, np.float64), nan=1e12,
                             posinf=1e12, neginf=-1e12)
        out[name] = float(np.mean(np.abs(pred - y_te)
                                  / np.maximum(np.abs(y_te), 1e-9)))
    if include_c3o:
        p = C3OPredictor(model_names=model_names, max_cv_folds=max_cv_folds,
                         seed=seed).fit(X_tr, y_tr)
        pred = np.nan_to_num(p.predict(X_te), nan=1e12, posinf=1e12,
                             neginf=-1e12)
        out["c3o"] = float(np.mean(np.abs(pred - y_te)
                                   / np.maximum(np.abs(y_te), 1e-9)))
        out["c3o_selected"] = p.selected
    return out
