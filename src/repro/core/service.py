"""Configuration service: joint (machine type, scale-out) selection for
context batches in ONE engine dispatch.

The paper's workflow (§III-§IV) treats machine type and scale-out as one
cluster configuration decision; the two-phase path (``choose_machine_type``
then ``Configurator.choose_scaleout``) approximates it with two separate
calls and cannot see deadline interactions across machines.
``ConfigurationService.choose_cluster_batch`` scores the full
(machine x scale-out x context) grid through ``engine.machine_grid_costs``
— every machine's grid prediction is dispatched before the first host sync,
no per-machine Python-loop syncs — then selects machine and scale-out
simultaneously with vectorized numpy:

    deadline given:  cheapest (m, s) whose runtime bound meets the deadline
                     (clean options first, bottlenecked fallback, then the
                     fastest bound anywhere on the grid);
    no deadline:     cheapest clean (m, s), else cheapest overall.

Per-context deadlines may be a scalar, a [C] array, or NaN entries meaning
"no deadline for this context" — that is what lets the async front-end
(repro.serve.config_service) micro-batch heterogeneous requests into a
single dispatch per tick.

On grids where predicted cost increases with scale-out and one machine
dominates (cheapest at every scale-out), the joint choice coincides with
the composed two-phase path — tests/test_service.py proves that parity
choice-for-choice.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Union

import numpy as np

from repro.core import engine
from repro.core.configurator import (ClusterChoice, confidence_margin,
                                     validate_confidence)
from repro.core.market import MarketError, PriceBook, validate_prices


@dataclass
class ConfigurationService:
    """Answers "best (machine type, scale-out) for these contexts under
    these deadlines" over per-machine-type predictors.

    Predictors must expose ``predict``/``predict_device`` plus the CV error
    calibration attributes ``mu``/``sigma`` (``C3OPredictor`` does)."""

    predictors: Dict[str, object]                # machine type -> predictor
    prices: Dict[str, float]                     # $ per node-hour
    scaleouts: Sequence[int]
    confidence: float = 0.95
    # optional bottleneck model: (machine, context_row, scale_out) -> True
    # if the working set misses cluster memory on that machine at that s
    bottleneck_fn: Optional[Callable[[str, np.ndarray, int], bool]] = None
    # optional cloud market (repro.core.market.PriceBook): when set,
    # selection scores the (machine x PLACEMENT x scale-out) grid on
    # interruption-adjusted expected cost and ``prices`` is ignored
    market: Optional[PriceBook] = None

    def __post_init__(self):
        validate_confidence(self.confidence)
        # construction-time price validation: a machine type without a
        # price used to be a bare KeyError mid-score, and a zero/negative
        # price silently won every cheapest-cost selection
        if self.market is not None:
            self.market.validate_machines(self.predictors)
        else:
            validate_prices(self.prices, self.predictors)

    @classmethod
    def from_repo(cls, repo, machine_types: Optional[Sequence[str]],
                  prices: Dict[str, float], scaleouts: Sequence[int],
                  seed: int = 0, **kw) -> "ConfigurationService":
        """Build from a hub JobRepo: one (cached, possibly warm-started)
        predictor per machine type via ``repo.predictor_for``.  With
        ``machine_types=None`` the store's columnar machine vocabulary
        decides — every machine type with shared runtime data gets a
        predictor."""
        if machine_types is None:
            machine_types = repo.store.data.present_machines()
        preds = {m: repo.predictor_for(m, seed=seed) for m in machine_types}
        return cls(preds, prices, scaleouts, **kw)

    # ------------------------- grid scoring -------------------------------
    def score_cluster_grid(self, contexts: np.ndarray):
        """(machine names, t, bound, cost, bottleneck), arrays [M, C, S].

        One engine dispatch: every machine's grid prediction is enqueued
        before the first host sync; runtimes are clamped at >= 0 so a model
        extrapolating negative can never yield a cost that wins selection."""
        contexts = np.atleast_2d(np.asarray(contexts, np.float64))
        names, t, cost = engine.machine_grid_costs(
            self.predictors, self.prices, self.scaleouts, contexts)
        margins = np.asarray([
            confidence_margin(self.confidence,
                              getattr(self.predictors[m], "mu", 0.0),
                              getattr(self.predictors[m], "sigma", 0.0))
            for m in names])
        bound = t + margins[:, None, None]
        if self.bottleneck_fn is not None:
            bott = np.array([[[bool(self.bottleneck_fn(m, ctx, int(s)))
                               for s in self.scaleouts]
                              for ctx in contexts] for m in names])
        else:
            bott = np.zeros(t.shape, bool)
        return names, t, bound, cost, bott

    def score_market_grid(self, contexts: np.ndarray, zones=None,
                          options=None):
        """Market-mode grid: placement is a vectorized axis on the SAME
        fused dispatch (``engine.placement_grid_costs``), not a loop.

        Returns (names, placements, t [M, C, S], then [M, P, C, S]
        arrays: expected completion time, runtime bound, naive listed
        cost, interruption-adjusted expected cost, bottleneck flags).
        The runtime bound rides the interruption-adjusted expected time,
        so flaky spot placements also lose deadline selection."""
        contexts = np.atleast_2d(np.asarray(contexts, np.float64))
        names, placements, t, et, naive, adj = engine.placement_grid_costs(
            self.predictors, self.market, self.scaleouts, contexts,
            zones=zones, options=options)
        margins = np.asarray([
            confidence_margin(self.confidence,
                              getattr(self.predictors[m], "mu", 0.0),
                              getattr(self.predictors[m], "sigma", 0.0))
            for m in names])
        bound = et + margins[:, None, None, None]
        if self.bottleneck_fn is not None:
            bott = np.array([[[bool(self.bottleneck_fn(m, ctx, int(s)))
                               for s in self.scaleouts]
                              for ctx in contexts] for m in names])
        else:
            bott = np.zeros(t.shape, bool)
        bott = np.broadcast_to(bott[:, None], et.shape)
        return names, placements, t, et, bound, naive, adj, bott

    # ------------------------- choice selection ---------------------------
    @staticmethod
    def _select(cf, bf, of, t_max, C):
        """Vectorized [C, K] flat-grid selection shared by the static and
        market paths: cheapest (clean first) meeting the deadline, then
        bottlenecked fallback, then fastest bound; cheapest clean (else
        cheapest) when there is no deadline (NaN entries = per-context
        "no deadline")."""

        def masked_argmin(val, mask):
            return np.where(mask, val, np.inf).argmin(1)

        has_clean = (~of).any(1)
        idx_nd = np.where(has_clean, masked_argmin(cf, ~of), cf.argmin(1))
        if t_max is None:
            return idx_nd
        tm = np.broadcast_to(np.asarray(t_max, np.float64), (C,))
        ok = bf <= tm[:, None]                     # NaN deadline -> all False
        ok_clean = ok & ~of
        idx_dl = np.where(
            ok_clean.any(1), masked_argmin(cf, ok_clean),
            np.where(ok.any(1), masked_argmin(cf, ok), bf.argmin(1)))
        return np.where(np.isnan(tm), idx_nd, idx_dl)

    def choose_cluster_batch(self, contexts: np.ndarray,
                             t_max: Union[None, float, np.ndarray] = None,
                             zones=None, options=None
                             ) -> List[ClusterChoice]:
        """Joint per-context (machine, scale-out) choices, one dispatch.

        ``t_max``: scalar shared deadline, [C] per-context deadlines, or
        None; NaN entries in the array mean "no deadline for this context"
        (those contexts get the cheapest-clean rule).

        With a ``market`` book the grid gains a placement axis and
        selection runs on interruption-adjusted expected cost
        (``zones``/``options`` optionally constrain the placements);
        without one, placement constraints are a typed error."""
        if self.market is not None:
            return self._choose_market(contexts, t_max, zones, options)
        if zones is not None or options is not None:
            raise MarketError(
                "placement constraints (zones / purchase_options) require "
                "a market-enabled service: construct with "
                "market=PriceBook(...)")
        contexts = np.atleast_2d(np.asarray(contexts, np.float64))
        names, t, bound, cost, bott = self.score_cluster_grid(contexts)
        C, S = len(contexts), len(self.scaleouts)
        K = len(names) * S
        # [C, M*S] flat grids, machine-major (ties resolve to the first
        # machine in dict order, matching choose_machine_type)
        tf = np.transpose(t, (1, 0, 2)).reshape(C, K)
        bf = np.transpose(bound, (1, 0, 2)).reshape(C, K)
        cf = np.transpose(cost, (1, 0, 2)).reshape(C, K)
        of = np.transpose(bott, (1, 0, 2)).reshape(C, K)
        idx = self._select(cf, bf, of, t_max, C)
        out = []
        for c, j in enumerate(idx):
            m, s = int(j) // S, int(j) % S
            out.append(ClusterChoice(names[m], int(self.scaleouts[s]),
                                     float(tf[c, j]), float(bf[c, j]),
                                     float(cf[c, j]), bool(of[c, j])))
        return out

    def _choose_market(self, contexts: np.ndarray,
                       t_max: Union[None, float, np.ndarray],
                       zones, options) -> List[ClusterChoice]:
        """Market-mode selection over the flat [C, M*P*S] grid (machine-
        major, then placement, then scale-out — a single-placement flat
        book therefore reproduces the static path index-for-index).
        Cost-ranked on interruption-adjusted expected cost; the reported
        ``cost_usd`` stays the naive listed cost so the envelope carries
        the naive-vs-adjusted breakdown."""
        contexts = np.atleast_2d(np.asarray(contexts, np.float64))
        names, placements, t, et, bound, naive, adj, bott = \
            self.score_market_grid(contexts, zones, options)
        C, S = len(contexts), len(self.scaleouts)
        P = len(placements)
        K = len(names) * P * S
        t4 = np.broadcast_to(t[:, None], et.shape)
        # [C, M*P*S] flat grids ([M, P, C, S] -> [C, M, P, S])
        tf = np.transpose(t4, (2, 0, 1, 3)).reshape(C, K)
        bf = np.transpose(bound, (2, 0, 1, 3)).reshape(C, K)
        nf = np.transpose(naive, (2, 0, 1, 3)).reshape(C, K)
        af = np.transpose(adj, (2, 0, 1, 3)).reshape(C, K)
        of = np.transpose(bott, (2, 0, 1, 3)).reshape(C, K)
        idx = self._select(af, bf, of, t_max, C)
        out = []
        for c, j in enumerate(idx):
            j = int(j)
            m, p, s = j // (P * S), (j // S) % P, j % S
            out.append(ClusterChoice(
                names[m], int(self.scaleouts[s]), float(tf[c, j]),
                float(bf[c, j]), float(nf[c, j]), bool(of[c, j]),
                zone=placements[p].zone,
                purchase_option=placements[p].option,
                expected_cost_usd=float(af[c, j])))
        return out

    def choose_cluster(self, context_row: np.ndarray,
                       t_max: Optional[float] = None) -> ClusterChoice:
        """Single-context convenience wrapper."""
        return self.choose_cluster_batch(np.atleast_2d(context_row),
                                         t_max)[0]
