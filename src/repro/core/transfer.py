"""Cross-job cold-start transfer: Flora-style job similarity (PAPERS.md,
arxiv 2502.21046).

C3O's runtime models need per-job history, so a real hub is permanently
in cold-start for some jobs.  Flora's answer is classification: relate a
NEW job to jobs that already have history and reuse their resource
knowledge.  This module implements the data side of that idea over the
columnar store:

  * ``job_signature`` compresses one job's shared runtime data into a
    fixed-size, schema-agnostic :class:`JobSignature` — per-machine
    log-runtime quantile sketches plus a (scale-out x data-size)
    occupancy histogram — computed vectorized over the columns (no
    per-row Python loops);
  * ``similarity`` scores two signatures in ``[0, 1]``: symmetric,
    invariant under row/contribution order (quantiles and histograms are
    permutation-free), and maximal for a signature against itself
    (``tests/test_transfer.py`` property-proves all three);
  * ``TransferIndex`` is the hub-side nearest-job lookup.  Signatures
    and pairwise similarities are cached keyed on each store's
    ``(version, epoch)``, so repeated lookups are dictionary hits until
    a contribution or compaction actually changes the data — the
    ``transfer`` benchmark lane hard-gates that amortization.

The gateway uses ``TransferIndex.nearest`` to serve ``predict``/``choose``
for unknown or under-supported jobs from the nearest donor's fitted
models, answering envelopes stamped with ``transfer_source`` and a
discounted ``transfer_confidence`` instead of an ``unknown_job`` error.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.features import RuntimeData

#: interior deciles of the per-machine log-runtime distribution — enough
#: to separate the emulated job families, small enough that a signature
#: is a few hundred bytes
_QUANTILES = np.linspace(0.1, 0.9, 9)

#: fixed occupancy grid: scale-out in log2 bins (1..2048 nodes), data
#: size in sixth-decade log10 bins (1e-2..1e4 GB — fine enough that
#: e.g. 10/20/30 GB working sets land in distinct bins).  Fixed global
#: bins — not per-job adaptive ones — so occupancy vectors of different
#: jobs are directly comparable
_SCALE_BINS = 12
_SIZE_BINS = 36


@dataclass(frozen=True)
class TransferPolicy:
    """Knobs of the cold-start fallback.

    ``min_rows`` splits the world: jobs with at least this many stored
    rows are donors and serve themselves; jobs below it (including
    unpublished ones) borrow.  ``discount`` converts a similarity into
    the envelope's ``transfer_confidence`` — borrowed answers are never
    reported at full confidence.  ``min_similarity`` refuses donors that
    match the probe no better than noise; ``unknown_prior`` is the
    (pre-discount) confidence basis when the job has NO rows at all and
    the lookup can only fall back to the best-supported
    schema-compatible donor."""
    min_rows: int = 24
    discount: float = 0.8
    min_similarity: float = 0.05
    unknown_prior: float = 0.25


@dataclass(frozen=True)
class JobSignature:
    """Fixed-size sketch of one job's runtime data (see module docstring).

    ``machines`` is SORTED (not first-appearance order) so signatures are
    invariant under row permutation; ``runtime_q`` holds one tuple of
    log-runtime quantiles per machine, aligned with ``machines``."""
    job: str
    n_features: int
    rows: int
    machines: Tuple[str, ...]
    runtime_q: Tuple[Tuple[float, ...], ...]
    counts: Tuple[int, ...]
    occupancy: Tuple[float, ...]
    #: one log10-quantile sketch per context feature BEYOND data size
    #: (empty for context-free jobs like sort) — k-means' k in 3..9 and
    #: SGD's iterations in 10..100 occupy visibly different ranges, which
    #: is what separates families whose runtimes overlap
    context_q: Tuple[Tuple[float, ...], ...] = ()


@dataclass(frozen=True)
class TransferMatch:
    """One nearest-job lookup answer: borrow ``source``'s fitted models.

    ``similarity`` is the raw signature score (0.0 when the borrowing job
    had no rows to sketch); ``confidence`` is what the gateway stamps on
    envelopes — similarity (or the unknown-job prior) times the policy
    discount."""
    source: str
    similarity: float
    confidence: float


def job_signature(data: RuntimeData, job: Optional[str] = None
                  ) -> JobSignature:
    """Sketch ``data`` into a :class:`JobSignature`, vectorized.

    Works on any non-empty ``RuntimeData`` — donors' full stores and
    a new job's few probe rows go through the same code path."""
    if len(data) == 0:
        raise ValueError("cannot sketch a job with no runtime data")
    machines = tuple(sorted(data.present_machines()))
    runtime_q = []
    counts = []
    for m in machines:
        view = data.machine_view(m)
        q = np.quantile(np.log(np.maximum(view.runtime, 1e-9)), _QUANTILES)
        runtime_q.append(tuple(float(v) for v in q))
        counts.append(len(view))
    sbin = np.clip(np.floor(np.log2(np.maximum(data.scale_out, 1.0))),
                   0, _SCALE_BINS - 1).astype(np.int64)
    size = np.maximum(data.context[:, 0], 1e-9)
    zbin = np.clip(np.floor(6.0 * np.log10(size)) + 12,
                   0, _SIZE_BINS - 1).astype(np.int64)
    hist = np.bincount(sbin * _SIZE_BINS + zbin,
                       minlength=_SCALE_BINS * _SIZE_BINS)
    occ = hist.astype(np.float64) / len(data)
    ctx = np.log10(np.maximum(np.abs(data.context[:, 1:]), 1e-9))
    context_q = tuple(
        tuple(float(v) for v in np.quantile(ctx[:, j], _QUANTILES))
        for j in range(ctx.shape[1]))
    return JobSignature(
        job if job is not None else data.schema.job,
        data.schema.n_features, len(data), machines,
        tuple(runtime_q), tuple(counts), tuple(float(v) for v in occ),
        context_q)


def similarity(a: JobSignature, b: JobSignature) -> float:
    """Signature similarity in ``[0, 1]``: symmetric in (a, b), and 1.0
    for a signature against itself.

    Three components: histogram intersection of the (scale-out x data
    size) occupancy grids (which execution regimes the jobs visit),
    ``exp(-d)`` of the mean L1 distance between log-runtime quantile
    sketches over the machines BOTH jobs have run on (how the jobs
    behave where they are comparable), and ``exp(-d)`` over the context
    quantile sketches (whether the jobs' parameter spaces coincide —
    context-free pairs score 1.0 there, incompatible widths 0.0).  No
    shared machine zeroes the runtime component — occupancy and context
    alone can still rank donors."""
    occ = float(np.minimum(np.asarray(a.occupancy),
                           np.asarray(b.occupancy)).sum())
    shared = sorted(set(a.machines) & set(b.machines))
    if shared:
        qa = np.asarray([a.runtime_q[a.machines.index(m)] for m in shared])
        qb = np.asarray([b.runtime_q[b.machines.index(m)] for m in shared])
        run = float(np.exp(-np.mean(np.abs(qa - qb))))
    else:
        run = 0.0
    if len(a.context_q) != len(b.context_q):
        ctx = 0.0
    elif not a.context_q:
        ctx = 1.0
    else:
        ctx = float(np.exp(-np.mean(np.abs(
            np.asarray(a.context_q) - np.asarray(b.context_q)))))
    return 0.4 * run + 0.3 * occ + 0.3 * ctx


class TransferIndex:
    """Hub-side nearest-job lookup with store-version-keyed caching.

    Signatures are cached per job keyed on the store's
    ``(version, epoch)`` — an accepted contribution or an epoch
    transition invalidates exactly that job's entry.  Pairwise
    similarities are cached keyed on BOTH jobs' cache keys, so a lookup
    against unchanged stores is pure dictionary traffic
    (``stats["signature_builds"]`` / ``stats["pair_evals"]`` stay flat;
    the ``transfer`` bench lane gates on it)."""

    def __init__(self, hub, policy: Optional[TransferPolicy] = None):
        self.hub = hub
        self.policy = policy if policy is not None else TransferPolicy()
        # job -> ((version, epoch), JobSignature)
        self._sigs: Dict[str, tuple] = {}
        # (job_a, key_a, job_b, key_b) normalized a<b -> similarity
        self._pairs: Dict[tuple, float] = {}
        self.stats: Dict[str, int] = {
            "lookups": 0, "signature_builds": 0, "pair_evals": 0}

    # ------------------------- cached primitives --------------------------
    def _key(self, job: str) -> tuple:
        store = self.hub.get(job).store
        return (store.version, store.epoch)

    def signature(self, job: str) -> Optional[JobSignature]:
        """Cached signature of a published job; None while it has no rows."""
        repo = self.hub.get(job)
        if len(repo.store) == 0:
            return None
        key = (repo.store.version, repo.store.epoch)
        entry = self._sigs.get(job)
        if entry is None or entry[0] != key:
            self.stats["signature_builds"] += 1
            entry = (key, job_signature(repo.store.data, job))
            self._sigs[job] = entry
            # drop pair entries computed against the superseded signature
            for k in [k for k in self._pairs
                      if (k[0] == job and k[1] != key)
                      or (k[2] == job and k[3] != key)]:
                del self._pairs[k]
        return entry[1]

    def _pair(self, a: str, b: str) -> float:
        """Cached ``similarity(signature(a), signature(b))``; symmetric."""
        if a > b:
            a, b = b, a
        key = (a, self._key(a), b, self._key(b))
        sim = self._pairs.get(key)
        if sim is None:
            self.stats["pair_evals"] += 1
            sim = similarity(self.signature(a), self.signature(b))
            self._pairs[key] = sim
        return sim

    # ------------------------- lookup -------------------------------------
    def donors(self, n_features: Optional[int] = None,
               exclude: str = "") -> List[str]:
        """Jobs with enough history to lend models, sorted by name."""
        out = []
        for job in self.hub.jobs():
            if job == exclude:
                continue
            repo = self.hub.get(job)
            if len(repo.store) < self.policy.min_rows:
                continue
            if n_features is not None \
                    and repo.schema.n_features != n_features:
                continue
            out.append(job)
        return out

    def nearest(self, job: str, n_features: Optional[int] = None
                ) -> Optional[TransferMatch]:
        """Best donor for ``job``, or None when transfer cannot help.

        A job published with SOME rows (even a handful of probe
        measurements, too few to fit) is ranked by signature similarity;
        a job with no rows at all falls back to the best-supported
        schema-compatible donor at the low ``unknown_prior`` confidence.
        Ties break deterministically on (similarity, donor name)."""
        self.stats["lookups"] += 1
        pool = self.donors(n_features, exclude=job)
        if not pool:
            return None
        try:
            probe = self.signature(job)
        except KeyError:
            probe = None
        if probe is None:
            source = max(pool, key=lambda j: (len(self.hub.get(j).store), j))
            return TransferMatch(
                source, 0.0,
                self.policy.unknown_prior * self.policy.discount)
        scored = sorted(((self._pair(job, d), d) for d in pool),
                        key=lambda t: (-t[0], t[1]))
        sim, source = scored[0]
        if sim < self.policy.min_similarity:
            return None
        return TransferMatch(source, sim, sim * self.policy.discount)
