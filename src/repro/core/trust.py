"""Contributor trust primitives: reputation ledger + token-bucket quotas.

C3O's collaborative premise — runtime models fit on *shared* historical
data — makes data quality the system's biggest robustness risk (the
research-overview follow-up names trust in shared training data as THE
open problem for collaborative optimization).  This module holds the two
mechanism primitives the trust plane is built from:

``ReputationLedger``
    Persistent per-contributor reputation derived from validation history
    at the ``RuntimeDataStore.contribute`` chokepoint.  Every judged
    contribution records one *outcome* — accepted/rejected plus a quality
    score in [0, 1] derived from the candidate-vs-baseline MAPE margin —
    and reputation is the Beta-mean estimate

        rep = (PRIOR_A + sum(quality)) / (PRIOR_A + PRIOR_B + n_outcomes)

    which starts every contributor at the NEUTRAL point (0.5) and is
    *order-independent* for commutative outcome batches (a pure sum — the
    property suite pins this).  Reputation drives two defenses:

      * ``threshold_scale``: contributors below neutral face a stricter
        §III-C.b acceptance threshold (scaled down toward
        MIN_THRESHOLD_SCALE as reputation approaches 0);
      * ``row_weight``: rows from below-neutral contributors enter
        ``cv_select``/fitting down-weighted (decaying cubically toward
        MIN_ROW_WEIGHT) instead of trusted equally — suspect data
        degrades gracefully out of the models rather than poisoning them
        at full weight.  Validation fits use the SAME weights, so
        already-suspect rows cannot inflate the baseline error and loosen
        the §III-C.b reject limit for the next poison batch.

    High-reputation contributors (>= GRACE_REPUTATION) get graceful
    degradation instead of hard rejection: a failing contribution within
    GRACE_RATIO of the reject limit is still ingested, but records a
    zero-quality outcome, so repeated failures drain the reputation that
    earned the grace (and down-weight the rows already ingested — the
    store's row weights are reputation-derived at fit time, not frozen
    at ingest time).

``TokenBucket``
    Deterministic rate-quota accounting with an injectable clock:
    ``admit(now)`` refills ``rate`` tokens per second up to ``burst`` and
    admits while a token is available.  Under ANY call interleaving the
    number of admissions is bounded by ``burst + rate * elapsed``
    (property-pinned); a skewed or rewinding caller clock can never mint
    tokens because the refill origin only moves forward.

Neither primitive knows about gateways or stores; ``repro.api.auth``
composes buckets into the gateway's token-auth surface and
``RuntimeDataStore`` consumes the ledger at its validation chokepoint.
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass
from typing import Dict, Optional, Tuple


class TokenBucket:
    """Token-bucket rate limiter over an explicit clock.

    ``rate`` tokens/second refill up to ``burst`` capacity; each admitted
    request consumes ``cost`` tokens.  The caller supplies ``now`` (any
    monotone-ish float timeline), which keeps the accounting deterministic
    under test and lets one authority drive many buckets off one clock.
    """

    __slots__ = ("rate", "burst", "tokens", "last")

    def __init__(self, rate: float, burst: float):
        if not (rate > 0 and burst > 0):
            raise ValueError(f"rate and burst must be positive, got "
                             f"rate={rate!r} burst={burst!r}")
        self.rate = float(rate)
        self.burst = float(burst)
        self.tokens = float(burst)          # a fresh bucket starts full
        self.last: Optional[float] = None   # refill origin (first admit)

    def _refill(self, now: float) -> None:
        if self.last is None:
            self.last = now
        if now > self.last:
            # the origin only moves FORWARD: a caller clock that jumps
            # backward (or repeats a timestamp) refills nothing, so the
            # burst + rate*elapsed admission bound holds under arbitrary
            # interleavings
            self.tokens = min(self.burst,
                              self.tokens + (now - self.last) * self.rate)
            self.last = now

    def admit(self, now: float, cost: float = 1.0) -> bool:
        """True (and ``cost`` tokens consumed) if the request fits the
        quota at time ``now``; False leaves the bucket unchanged."""
        self._refill(float(now))
        if self.tokens >= cost:
            self.tokens -= cost
            return True
        return False

    def remaining(self, now: Optional[float] = None) -> float:
        """Tokens currently available (refilled to ``now`` if given)."""
        if now is not None:
            self._refill(float(now))
        return self.tokens


@dataclass
class TrustRecord:
    """Per-contributor validation history (pure sums: commutative)."""
    quality_sum: float = 0.0
    outcomes: int = 0
    accepted: int = 0
    rejected: int = 0


class ReputationLedger:
    """Validation-history reputation for every contributor of one store."""

    #: Beta prior: one pseudo-success + one pseudo-failure, so an unseen
    #: contributor sits exactly at NEUTRAL (threshold scale 1, row
    #: weight 1 — a trust-enabled store treats fresh contributors exactly
    #: like a trust-free store treats everyone)
    PRIOR_A = 1.0
    PRIOR_B = 1.0
    #: the neutral reputation: above it contributors are in good standing
    NEUTRAL = 0.5
    #: floor on the reputation-derived fit weight of a row (rows are
    #: down-weighted, never erased: the data stays auditable in the store)
    MIN_ROW_WEIGHT = 0.2
    #: floor on the acceptance-threshold scale for zero-reputation
    #: contributors (half the normal §III-C.b reject budget)
    MIN_THRESHOLD_SCALE = 0.5
    #: reputation at or above which a failing contribution is eligible for
    #: graceful degradation instead of hard rejection
    GRACE_REPUTATION = 0.75
    #: grace only stretches the reject limit this far — catastrophically
    #: bad data is rejected no matter who measured it
    GRACE_RATIO = 2.0

    FORMAT = 1

    def __init__(self):
        self._records: Dict[str, TrustRecord] = {}
        self._version = 0

    # ------------------------- outcome recording --------------------------
    @property
    def version(self) -> int:
        """Monotonic counter, bumped on every recorded outcome.  Fit and
        service caches key on it: a REJECTED contribution changes no store
        data (no store-version bump) but does change this contributor's
        reputation — and therefore the row weights of their already-stored
        rows at the next fit."""
        return self._version

    def record_outcome(self, contributor: str, accepted: bool,
                       quality: float) -> None:
        """Record one judged contribution.  ``quality`` in [0, 1] is the
        validation margin (see ``quality_of``); the running state is pure
        sums, so any commutative batch of outcomes yields the same
        reputation in any order (up to float associativity)."""
        rec = self._records.setdefault(str(contributor), TrustRecord())
        rec.quality_sum += float(min(max(quality, 0.0), 1.0))
        rec.outcomes += 1
        if accepted:
            rec.accepted += 1
        else:
            rec.rejected += 1
        self._version += 1

    @staticmethod
    def quality_of(baseline_mape: float, candidate_mape: float,
                   limit: float) -> float:
        """Validation margin of an ACCEPTED contribution in [0, 1]:
        1 when the candidate error is at or below the baseline, falling
        linearly to 0 as it approaches the reject limit.  Rejected (and
        grace-accepted) contributions record quality 0 directly."""
        span = max(limit - baseline_mape, 1e-9)
        return float(min(max((limit - candidate_mape) / span, 0.0), 1.0))

    # ------------------------- derived trust state ------------------------
    def __contains__(self, contributor: str) -> bool:
        return str(contributor) in self._records

    def contributors(self) -> Tuple[str, ...]:
        return tuple(sorted(self._records))

    def stats(self, contributor: str) -> TrustRecord:
        rec = self._records.get(str(contributor), TrustRecord())
        return TrustRecord(rec.quality_sum, rec.outcomes, rec.accepted,
                           rec.rejected)

    def reputation(self, contributor: str) -> float:
        rec = self._records.get(str(contributor))
        if rec is None:
            return self.NEUTRAL
        return (self.PRIOR_A + rec.quality_sum) / \
            (self.PRIOR_A + self.PRIOR_B + rec.outcomes)

    def row_weight(self, contributor: str) -> float:
        """Fit weight for this contributor's rows: 1.0 at or above
        neutral, decaying CUBICALLY toward MIN_ROW_WEIGHT as reputation
        falls to 0 — one clearly-bad outcome (reputation ~0.4) already
        cuts a contributor's influence roughly in half, instead of the
        token trim a linear ramp would give.  Weights never exceed 1 —
        good standing earns *equal* trust, not extra leverage over
        everyone else's models."""
        rep = self.reputation(contributor)
        if rep >= self.NEUTRAL:
            return 1.0
        frac = (rep / self.NEUTRAL) ** 3
        return self.MIN_ROW_WEIGHT + (1.0 - self.MIN_ROW_WEIGHT) * frac

    def threshold_scale(self, contributor: str) -> float:
        """Multiplier on the §III-C.b reject limit: 1.0 at or above
        neutral, tightening linearly to MIN_THRESHOLD_SCALE at
        reputation 0 (low-reputation contributors face stricter
        validation)."""
        rep = self.reputation(contributor)
        if rep >= self.NEUTRAL:
            return 1.0
        return self.MIN_THRESHOLD_SCALE + \
            (1.0 - self.MIN_THRESHOLD_SCALE) * (rep / self.NEUTRAL)

    def allows_grace(self, contributor: str) -> bool:
        return self.reputation(contributor) >= self.GRACE_REPUTATION

    # ------------------------- persistence --------------------------------
    def save(self, path: str) -> None:
        """Atomic JSON snapshot (sidecar next to the store TSV)."""
        payload = {"format": self.FORMAT,
                   "contributors": {
                       c: {"quality_sum": r.quality_sum,
                           "outcomes": r.outcomes,
                           "accepted": r.accepted,
                           "rejected": r.rejected}
                       for c, r in sorted(self._records.items())}}
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.write("\n")
        os.replace(tmp, path)

    @classmethod
    def load(cls, path: str) -> "ReputationLedger":
        with open(path) as f:
            payload = json.load(f)
        if payload.get("format") != cls.FORMAT:
            raise ValueError(
                f"unsupported reputation-ledger format in {path}: "
                f"{payload.get('format')!r}")
        ledger = cls()
        for c, r in payload["contributors"].items():
            rec = TrustRecord(float(r["quality_sum"]), int(r["outcomes"]),
                              int(r["accepted"]), int(r["rejected"]))
            ledger._records[str(c)] = rec
        return ledger


__all__ = ["TokenBucket", "TrustRecord", "ReputationLedger"]
