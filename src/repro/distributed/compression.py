"""Error-feedback int8 gradient compression for the data-parallel reduce.

At 1000+-node scale the gradient all-reduce competes with FSDP weight
gathers for ICI/DCN bandwidth; quantizing the DP reduction to int8 cuts that
term ~4x (fp32) / ~2x (bf16).  Plain quantized SGD diverges, so we keep the
canonical error-feedback (EF-SGD / 1-bit-Adam style) residual: the
quantization error of step t is added back into the gradient at t+1 —
unbiased in the long run, provably convergent for smooth objectives.

Implementation: per-leaf symmetric int8 quantization with a power-of-two
block scale, psum'd inside shard_map over the DP axes; the "model" axis
gradient reduction (tensor-parallel partial sums) stays full precision since
those collectives are intra-layer latency-critical.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def _quantize(x, block: int = 256):
    """Symmetric int8 with per-block scales. x [..] f32 -> (q int8, scale)."""
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    q = jnp.round(blocks / jnp.maximum(scale, 1e-12)).astype(jnp.int8)
    return q, scale, x.shape, pad


def _dequantize(q, scale, shape, pad):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(shape)


def compress_decompress(x, block: int = 256):
    """Round-trip quantization (the lossy channel a DP all-reduce would see).

    Returns (x_hat, err) with err = x - x_hat (the error-feedback residual)."""
    q, scale, shape, pad = _quantize(x, block)
    x_hat = _dequantize(q, scale, shape, pad)
    return x_hat, x - x_hat


def make_ef_compressor(block: int = 256):
    """Returns (init_state, transform) for train_step's grad hook.

    transform(grads, state) -> (grads_hat, new_state): adds the carried
    residual, quantize/dequantizes, and stores the fresh residual."""

    def init_state(grads_like):
        return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32),
                            grads_like)

    def transform(grads, state):
        def leaf(g, e):
            g = g.astype(jnp.float32) + e
            g_hat, err = compress_decompress(g, block)
            return g_hat, err
        pairs = jax.tree.map(leaf, grads, state)
        g_hat = jax.tree.map(lambda t: t[0], pairs,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_state = jax.tree.map(lambda t: t[1], pairs,
                                 is_leaf=lambda x: isinstance(x, tuple))
        return g_hat, new_state

    return init_state, transform


def quantized_psum(x, axis_names: Tuple[str, ...], mesh, in_spec: P,
                   block: int = 256):
    """int8-wire psum over DP axes via shard_map (each participant sends its
    quantized shard; the sum is computed in f32 after dequantization).

    This is the collective-level view of the compression (HLO shows the int8
    operand on the wire); training uses the simpler EF hook above."""
    def body(xs):
        flat = xs.reshape(-1)
        pad = (-flat.shape[0]) % block
        flat = jnp.pad(flat, (0, pad))
        blocks = flat.reshape(-1, block)
        local_scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
        scale = jax.lax.pmax(local_scale, axis_names)    # shared block scale
        q = jnp.clip(jnp.round(blocks / jnp.maximum(scale, 1e-12)),
                     -127, 127).astype(jnp.int8)
        q_sum = jax.lax.psum(q.astype(jnp.int32), axis_names)   # int8 wire
        out = (q_sum.astype(jnp.float32) * scale).reshape(-1)
        if pad:
            out = out[:-pad]
        return out.reshape(xs.shape)

    from repro.distributed.sharding import shard_map
    return shard_map(body, mesh=mesh, in_specs=(in_spec,),
                     out_specs=in_spec, check_vma=False)(x)
