"""Logical-axis sharding rules (MaxText-style) -> PartitionSpec.

Physical mesh axes:
  "pod"   - inter-pod data parallelism (only on multi-pod meshes)
  "data"  - intra-pod data parallelism / FSDP
  "model" - tensor / expert / sequence parallelism

Logical axes used by the model code:
  batch       -> ("pod", "data")
  fsdp        -> ("pod", "data")   weight embed dims (ZeRO-3 style)
  model       -> "model"           heads / ff-hidden / experts / vocab
  seq_sp      -> "model"           residual-stream sequence dim when SP enabled
  None        -> replicated
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

if hasattr(jax, "shard_map"):           # jax >= 0.5 top-level export
    shard_map = jax.shard_map
else:                                   # older releases: experimental home,
    from jax.experimental.shard_map import shard_map as _shard_map_exp

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True):
        # ...where check_vma was still called check_rep
        return _shard_map_exp(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=check_vma)

# logical name -> tuple of preferred physical axes (tried in order, all used)
DEFAULT_RULES = {
    "batch": ("pod", "data"),
    "fsdp": ("pod", "data"),
    "model": ("model",),
    "seq_sp": ("model",),
    None: (),
}

_state = threading.local()


def _ctx():
    if not hasattr(_state, "mesh"):
        _state.mesh = None
        _state.rules = DEFAULT_RULES
    return _state


@contextmanager
def use_mesh(mesh: Optional[Mesh], rules=None):
    st = _ctx()
    prev = (st.mesh, st.rules)
    st.mesh, st.rules = mesh, (rules or DEFAULT_RULES)
    try:
        yield
    finally:
        st.mesh, st.rules = prev


def current_mesh() -> Optional[Mesh]:
    return _ctx().mesh


def rules_for(cfg) -> dict:
    """Per-arch rule overrides: small models turn FSDP off (replicated
    weights kill the per-microbatch all-gathers, §Perf gemma3 hillclimb)."""
    rules = dict(DEFAULT_RULES)
    if getattr(cfg, "pure_dp", False):
        rules.update(batch=("pod", "data", "model"), fsdp=(), model=(),
                     seq_sp=())
        return rules
    if not getattr(cfg, "fsdp", True):
        rules["fsdp"] = ()
    return rules


def mesh_axis_size(mesh: Mesh, names: Sequence[str]) -> int:
    n = 1
    for a in names:
        if a in mesh.shape:
            n *= mesh.shape[a]
    return n


def resolve_spec(logical: Sequence[Optional[str]],
                 dims: Optional[Sequence[int]] = None,
                 mesh: Optional[Mesh] = None) -> P:
    """Build a PartitionSpec from logical axis names.

    Physical axes absent from the mesh are dropped; if ``dims`` is given, an
    axis is only used when the dimension is divisible by its total size
    (uneven GSPMD sharding avoided; e.g. kv_heads=4 on model=16 -> replicate).
    """
    st = _ctx()
    mesh = mesh or st.mesh
    rules = st.rules
    out = []
    for i, name in enumerate(logical):
        axes: Tuple[str, ...] = tuple(rules.get(name, ()) or ())
        if mesh is not None:
            axes = tuple(a for a in axes if a in mesh.shape)
            if dims is not None and axes:
                size = mesh_axis_size(mesh, axes)
                if size == 0 or dims[i] % size != 0:
                    # try progressively fewer axes (drop leading "pod" first)
                    while axes and (dims[i] % mesh_axis_size(mesh, axes) != 0):
                        axes = axes[1:]
        if not axes:
            out.append(None)
        elif len(axes) == 1:
            out.append(axes[0])
        else:
            out.append(tuple(axes))
    return P(*out)


def shard(x, *logical: Optional[str]):
    """with_sharding_constraint by logical axis names (no-op without a mesh)."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = resolve_spec(logical, dims=x.shape, mesh=mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(mesh: Mesh, *logical: Optional[str],
                   dims: Optional[Sequence[int]] = None) -> NamedSharding:
    return NamedSharding(mesh, resolve_spec(logical, dims=dims, mesh=mesh))
