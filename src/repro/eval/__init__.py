"""Collaborative evaluation replay plane (paper §VI, Fig. 5/6 analogue).

Reproduces the paper's headline empirical protocol: many users with
heterogeneous execution contexts contribute runtime data to the shared
collaborative store over time, and prediction error for a *held-out* user
is measured as a function of store size — leave-one-user-out over the
multi-user dataset emulated by ``repro.workloads.spark_emul``.

``repro.eval.dataset``   multi-user dataset assembly + contribution chunking
``repro.eval.replay``    the deterministic replay harness and its CLI
                         (``python -m repro.eval.replay``)
"""
from repro.eval.dataset import (MultiUserData, build_multi_user,
                                contribution_chunks)

__all__ = ["MultiUserData", "build_multi_user", "contribution_chunks"]

# NOTE: repro.eval.replay is intentionally NOT imported here — it is the
# ``python -m repro.eval.replay`` entry point, and importing it from the
# package __init__ would double-execute the module under runpy.
