"""Adversarial replay: measuring the trust plane under poisoned traffic.

The collaborative premise is attacked directly: a fraction of the
emulated users are adversaries (``spark_emul.adversarial_user_data`` —
runtime-scaling poisoners, high-variance noise, dataset-size column
shift, near-duplicate spam), and each job is replayed twice over the
SAME contribution stream:

  * ``weighting=off`` — the plain §III-C.b store: validation accepts or
    rejects each chunk against the fixed threshold, accepted rows enter
    at full weight;
  * ``weighting=on``  — the same store with a ``ReputationLedger``:
    per-contributor acceptance thresholds adapt, accepted rows enter
    fits at reputation-derived weights, and high-reputation contributors
    get graceful degradation.

After every contribution the held-out honest user's rows are scored
(exactly the replay plane's checkpoint), producing twin error
trajectories whose gap IS the trust plane's measured value.  The run
passes when the reputation-weighted arm's final C3O MAPE is strictly
below the weighting-off arm's on EVERY job.

Determinism mirrors ``repro.eval.replay``: all RNGs derive from
SHA-256 identity keys, the trajectory TSV is canonical, and its SHA-256
fingerprint is byte-identical across runs of the same config.

CLI:
    PYTHONPATH=src python -m repro.eval.adversarial --users 8 \
        --poison 0.25 --seed 0
"""
from __future__ import annotations

import argparse
import hashlib
import math
import os
import sys
import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.datastore import RuntimeDataStore
from repro.core.hub import JobRepo
from repro.core.predictor import DEFAULT_MODELS
from repro.core.trust import ReputationLedger
from repro.eval.dataset import contribution_chunks, derived_rng, \
    user_contributor
from repro.eval.replay import TRAJECTORY_COLUMNS, _checkpoint
from repro.workloads.spark_emul import (ADVERSARY_KINDS, SCHEMAS,
                                        adversarial_user_data,
                                        generate_user_data)

#: the replay columns plus which arm (off/on) a record belongs to
ADV_TRAJECTORY_COLUMNS = ("weighting",) + TRAJECTORY_COLUMNS

WEIGHTING_ARMS = ("off", "on")


@dataclass(frozen=True)
class AdversarialConfig:
    jobs: Tuple[str, ...] = tuple(SCHEMAS)
    n_users: int = 8
    poison_fraction: float = 0.25
    seed: int = 0
    chunks_per_user: int = 2          # early outcomes inform later chunks
    holdouts: int = 1                 # honest users held out per job
    model_names: Tuple[str, ...] = DEFAULT_MODELS
    track_models: Tuple[str, ...] = DEFAULT_MODELS + ("linreg",)
    max_cv_folds: int = 20
    max_validation_rows: int = 1024

    def poisoners(self) -> Tuple[int, ...]:
        """The LAST ceil(n_users * poison_fraction) user ids are the
        adversaries (a fixed, order-independent convention)."""
        k = math.ceil(self.n_users * self.poison_fraction)
        return tuple(range(self.n_users - k, self.n_users))

    def honest(self) -> Tuple[int, ...]:
        cut = self.n_users - len(self.poisoners())
        return tuple(range(cut))

    def attack_of(self, user: int) -> str:
        """Deterministic attack assignment: poisoners cycle through the
        repertoire in id order."""
        poisoners = self.poisoners()
        return ADVERSARY_KINDS[poisoners.index(user) % len(ADVERSARY_KINDS)]


@dataclass
class AdversarialResult:
    config: AdversarialConfig
    records: List[dict]
    tsv: str
    fingerprint: str
    summary: Dict[str, dict]
    wall_s: float
    contributions: int = 0            # attempted, across both arms
    accepted: int = 0

    @property
    def ok(self) -> bool:
        return bool(self.summary) and \
            all(s["ok"] for s in self.summary.values())


# ---------------------------------------------------------------------------
# replay core
# ---------------------------------------------------------------------------

def _user_chunks(job: str, user: int, cfg: AdversarialConfig):
    """One user's contribution batches, poisoned if the user is an
    adversary, stamped with real provenance either way."""
    if user in cfg.poisoners():
        data = adversarial_user_data(job, user, cfg.seed,
                                     cfg.attack_of(user))
    else:
        data = generate_user_data(job, user, cfg.seed)
    return [c.with_contributor(user_contributor(user))
            for c in contribution_chunks(
                data, cfg.chunks_per_user,
                derived_rng("adv-chunks", job, user, cfg.seed))]


def replay_job_adversarial(job: str, cfg: AdversarialConfig
                           ) -> Tuple[List[dict], int, int]:
    """Twin-arm adversarial replay of one job.

    Returns (trajectory records, contributions attempted, accepted)."""
    poisoners = set(cfg.poisoners())
    honest = cfg.honest()
    if len(honest) < 2:
        raise ValueError(
            f"{cfg.n_users} users at poison_fraction="
            f"{cfg.poison_fraction} leaves {len(honest)} honest users; "
            "need >= 2 (a held-out honest user plus at least one honest "
            "contributor)")
    records: List[dict] = []
    contributions = accepted = 0
    for held in honest[:max(1, cfg.holdouts)]:
        test = generate_user_data(job, held, cfg.seed)
        chunks = []                    # (is_poison, RuntimeData)
        for u in range(cfg.n_users):
            if u == held:
                continue
            chunks.extend((u in poisoners, c)
                          for c in _user_chunks(job, u, cfg))
        order = list(derived_rng("adv-order", job, held, cfg.seed)
                     .permutation(len(chunks)))
        # the seeding chunk bypasses validation (it IS the baseline), so
        # rotate the shared order until an honest chunk leads: an
        # adversary must not get a free pass into either arm's store
        while chunks[order[0]][0]:
            order = order[1:] + order[:1]
        for arm in WEIGHTING_ARMS:
            trust = None if arm == "off" else ReputationLedger()
            store = RuntimeDataStore(
                chunks[order[0]][1], seed=cfg.seed,
                model_names=list(cfg.model_names),
                max_validation_rows=cfg.max_validation_rows, trust=trust)
            repo = JobRepo(job, job, test.schema, store,
                           model_names=list(cfg.model_names),
                           predictor_kw={"pad_rows": True,
                                         "max_cv_folds": cfg.max_cv_folds})
            extra = {"weighting": arm}
            records += _checkpoint(job, held, 0, repo, test, cfg,
                                   extra=extra)
            for step, ci in enumerate(order[1:], start=1):
                report = store.contribute(chunks[ci][1])
                contributions += 1
                accepted += bool(report.accepted)
                records += _checkpoint(job, held, step, repo, test, cfg,
                                       extra=extra)
    return records, contributions, accepted


# ---------------------------------------------------------------------------
# trajectory TSV + summary
# ---------------------------------------------------------------------------

def trajectory_tsv(records: Sequence[dict]) -> str:
    """Canonical TSV (byte-identical across runs of the same config)."""
    lines = ["\t".join(ADV_TRAJECTORY_COLUMNS)]
    for r in records:
        lines.append("\t".join((
            r["weighting"], r["job"], str(r["held_out"]), str(r["step"]),
            str(r["store_rows"]),
            str(r.get("rows_contributed", r["store_rows"])),
            str(r.get("epoch", 0)), r["machine"], r["model"],
            "%.6g" % r["mape"], "%.6g" % r["mae"], r["selected"])))
    return "\n".join(lines) + "\n"


def summarize(records: Sequence[dict],
              cfg: AdversarialConfig) -> Dict[str, dict]:
    """Per-job rollup: final-store C3O MAPE per arm; ``ok`` iff the
    reputation-weighted arm strictly beats weighting-off."""
    summary: Dict[str, dict] = {}
    for job in cfg.jobs:
        rows = [r for r in records if r["job"] == job and r["model"] == "c3o"]
        if not rows:
            continue
        finals: Dict[str, float] = {}
        for arm in WEIGHTING_ARMS:
            arm_rows = [r for r in rows if r["weighting"] == arm]
            last: Dict[int, int] = {}
            for r in arm_rows:
                last[r["held_out"]] = max(r["step"],
                                          last.get(r["held_out"], 0))
            vals = [r["mape"] for r in arm_rows
                    if r["step"] == last[r["held_out"]]]
            finals[arm] = sum(vals) / len(vals)
        improvement = finals["off"] - finals["on"]
        summary[job] = {
            "off_final": finals["off"],
            "on_final": finals["on"],
            "improvement": improvement,
            "ok": finals["on"] < finals["off"],
        }
    return summary


def run_adversarial(cfg: AdversarialConfig) -> AdversarialResult:
    t0 = time.time()
    records: List[dict] = []
    contributions = accepted = 0
    for job in cfg.jobs:
        recs, contribs, acc = replay_job_adversarial(job, cfg)
        records += recs
        contributions += contribs
        accepted += acc
    tsv = trajectory_tsv(records)
    return AdversarialResult(
        config=cfg, records=records, tsv=tsv,
        fingerprint=hashlib.sha256(tsv.encode()).hexdigest(),
        summary=summarize(records, cfg), wall_s=time.time() - t0,
        contributions=contributions, accepted=accepted)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.eval.adversarial",
        description="Adversarial replay: reputation weighting on vs off "
                    "under a poisoned contributor mix")
    ap.add_argument("--users", type=int, default=8)
    ap.add_argument("--poison", type=float, default=0.25,
                    help="fraction of users that are adversaries")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--jobs", default=",".join(SCHEMAS),
                    help="comma-separated job subset")
    ap.add_argument("--chunks", type=int, default=2,
                    help="contributions each user splits their data into")
    ap.add_argument("--holdouts", type=int, default=1,
                    help="honest users held out per job")
    ap.add_argument("--out", default=None,
                    help="trajectory TSV path (default: eval_out/"
                         "adversarial_users<N>_poison<P>_seed<S>.tsv)")
    args = ap.parse_args(argv)
    cfg = AdversarialConfig(jobs=tuple(args.jobs.split(",")),
                            n_users=args.users,
                            poison_fraction=args.poison, seed=args.seed,
                            chunks_per_user=args.chunks,
                            holdouts=args.holdouts)
    res = run_adversarial(cfg)

    out = args.out or os.path.join(
        "eval_out", f"adversarial_users{cfg.n_users}_poison"
        f"{cfg.poison_fraction:g}_seed{cfg.seed}.tsv")
    os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
    with open(out, "w") as f:
        f.write(res.tsv)

    kinds = ",".join(f"{user_contributor(u)}:{cfg.attack_of(u)}"
                     for u in cfg.poisoners())
    print(f"adversarial.poisoners {kinds}")
    for job, s in res.summary.items():
        print(f"adversarial.{job} off_final={s['off_final']:.4f} "
              f"on_final={s['on_final']:.4f} "
              f"improvement={s['improvement']:.4f} ok={s['ok']}")
    print(f"adversarial.contributions {res.accepted}/{res.contributions} "
          f"accepted")
    print(f"adversarial.trajectory {out} rows={len(res.records)}")
    print(f"adversarial.fingerprint {res.fingerprint}")
    print(f"adversarial.wall_s {res.wall_s:.1f}")
    print(f"adversarial.ok {res.ok}")
    return 0 if res.ok else 1


if __name__ == "__main__":
    sys.exit(main())
