"""Multi-user dataset assembly for the collaborative replay plane.

Each simulated user runs one job under their own execution context
(``spark_emul.user_design``: a user-specific subset of context cells and
scale-outs with smoothly perturbed continuous features) and measures it
with a user-specific noise stream.  Users therefore overlap in *structure*
but never in exact context — the heterogeneity leave-one-user-out
generalization is measured over.

Everything here is deterministic in (job, user id, seed): RNGs are seeded
from SHA-256 of the identity key, never from global state.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Tuple

import numpy as np

from repro.core.features import RuntimeData
from repro.workloads import spark_emul
from repro.workloads.spark_emul import derived_rng  # single seed mapping

__all__ = ["MultiUserData", "build_multi_user", "contribution_chunks",
           "derived_rng", "user_contributor", "split_by_contributor"]


def user_contributor(user: int) -> str:
    """Canonical contributor id an emulated user's contributions carry."""
    return f"user{int(user)}"


def split_by_contributor(data: RuntimeData) -> Dict[str, RuntimeData]:
    """Partition provenance-carrying rows back into per-contributor
    datasets (row order preserved).  This is the leave-one-user-out
    inverse over REAL provenance: a store grown through contributions
    stamped with contributor ids — replay output, gateway traffic —
    splits into exactly the per-user datasets that built it, no synthetic
    user bookkeeping needed."""
    out = {}
    for code, name in enumerate(data.contributors):
        rows = np.nonzero(data.ccodes == code)[0]
        if len(rows):
            out[name] = data.subset(rows)
    return out


@dataclass(frozen=True)
class MultiUserData:
    """One job's multi-user dataset: per-user contribution-ready rows."""
    job: str
    users: Tuple[int, ...]
    per_user: Dict[int, RuntimeData]

    def rows_total(self) -> int:
        return sum(len(d) for d in self.per_user.values())


def build_multi_user(job: str, n_users: int, seed: int = 0,
                     **design_kw) -> MultiUserData:
    """Emulate ``n_users`` collaborating users of one job.

    Every user's row count is identical by construction (see
    ``spark_emul.user_design``), so replayed store sizes coincide across
    held-out users and the engine's shape-bucketed executables are shared
    across the whole leave-one-user-out sweep."""
    users = tuple(range(n_users))
    per_user = {u: spark_emul.generate_user_data(job, u, seed, **design_kw)
                for u in users}
    return MultiUserData(job, users, per_user)


def contribution_chunks(data: RuntimeData, n_chunks: int,
                        rng: np.random.Generator) -> List[RuntimeData]:
    """Split one user's rows into contribution batches.

    Rows are assigned to batches by a seeded permutation (a user uploads
    measurements in no particular order) but keep their original relative
    order inside each batch, so batch TSV encodings — and therefore the
    store's fingerprint chain — are canonical."""
    n = len(data)
    n_chunks = max(1, min(n_chunks, n))
    perm = rng.permutation(n)
    return [data.subset(np.sort(part))
            for part in np.array_split(perm, n_chunks) if len(part)]
