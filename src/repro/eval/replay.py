"""Deterministic collaborative-replay harness (paper §VI, Fig. 5/6).

Leave-one-user-out over a multi-user emulated dataset: for each held-out
user, the remaining users' measurements are ingested into a fresh
``RuntimeDataStore`` through ``contribute`` (validated, fingerprint-chained)
in a seeded shuffled contribution order, and after every contribution the
held-out user's configurations are scored — per machine type, per model —
producing MAPE/MAE *trajectories versus store size*: the paper's
error-vs-training-data curves, with all model selection flowing through
``engine.cv_select`` (via ``JobRepo.predictor_for``) and all per-model
scoring through the engine's fused, shape-bucketed ``val_executable``s.

Determinism: every RNG is seeded from SHA-256 of a structured identity key
(job, user, seed); trajectory rows are emitted in a canonical order and the
harness reports a SHA-256 fingerprint of the trajectory TSV — two runs of
``python -m repro.eval.replay --users 8 --seed 0`` produce byte-identical
trajectories.

Periodic-compaction mode (``--compact-every N``) additionally attempts a
store epoch transition (``RuntimeDataStore.compact``, cap-escalation
ladder) every N contributions, tracing the accuracy-vs-store-size
frontier: trajectory rows carry both the live ``store_rows`` and the
lifetime ``rows_contributed``/``epoch``, so compacted and append-only
runs plot on the same x-axis.

CLI:
    PYTHONPATH=src python -m repro.eval.replay --users 8 --seed 0
"""
from __future__ import annotations

import argparse
import hashlib
import math
import os
import sys
import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.api.types import ChooseRequest, PredictRequest
from repro.core.datastore import RuntimeDataStore
from repro.core.market import realized_completion_time_s
from repro.core.hub import Hub, JobRepo
from repro.core.predictor import DEFAULT_MODELS
from repro.core.transfer import TransferPolicy
from repro.eval.dataset import (MultiUserData, build_multi_user,
                                contribution_chunks, derived_rng,
                                user_contributor)
from repro.workloads import spark_emul as W
from repro.workloads.spark_emul import SCHEMAS

TRAJECTORY_COLUMNS = ("job", "held_out", "step", "store_rows",
                      "rows_contributed", "epoch", "machine",
                      "model", "mape", "mae", "selected")

#: cap-escalation ladder for periodic compaction: caps are tried tightest
#: first and the first ACCEPTED compaction wins — rejections are free
#: no-ops (no version bump, no reseed), so one config adapts per job to
#: however much redundancy the store actually carries
COMPACT_CAPS = (2, 3, 4, 6)

#: the C3O row must strictly beat these at full store size (ISSUE/paper
#: Table II: the optimistic BOM and a plain linear regressor are the
#: reference baselines the specialized selection is measured against)
BASELINE_MODELS = ("bom", "linreg")


@dataclass(frozen=True)
class ReplayConfig:
    jobs: Tuple[str, ...] = tuple(SCHEMAS)
    n_users: int = 8
    seed: int = 0
    chunks_per_user: int = 1          # contributions each user splits into
    model_names: Tuple[str, ...] = DEFAULT_MODELS      # c3o selection pool
    track_models: Tuple[str, ...] = DEFAULT_MODELS + ("linreg",)
    max_cv_folds: int = 20
    max_validation_rows: int = 1024
    # periodic store compaction (0 = off): every N accepted-or-not
    # contributions the store attempts an epoch transition through the
    # COMPACT_CAPS escalation ladder — the accuracy-vs-size frontier mode
    compact_every: int = 0
    compact_caps: Tuple[int, ...] = COMPACT_CAPS
    compact_floor: int = 2
    compact_width: float = 0.15
    compact_budget: float = 0.01
    compact_min_rows: int = 64


@dataclass
class ReplayResult:
    config: ReplayConfig
    records: List[dict]
    tsv: str
    fingerprint: str
    summary: Dict[str, dict]
    wall_s: float
    contributions: int = 0
    accepted: int = 0
    compactions_attempted: int = 0    # ladder rungs tried (incl. rejected)
    compactions: int = 0              # epoch transitions actually taken

    @property
    def ok(self) -> bool:
        return all(s["ok"] for s in self.summary.values())


# ---------------------------------------------------------------------------
# replay core
# ---------------------------------------------------------------------------

def _checkpoint(job: str, held: int, step: int, repo: JobRepo,
                test, cfg, extra: Optional[dict] = None) -> List[dict]:
    """Score the held-out user's rows against the current store state.

    ``extra`` key/values are merged into every record — the adversarial
    replay stamps its ``weighting`` arm here so on/off trajectories share
    one record stream."""
    out = []
    store_rows = len(repo.store)
    for machine in test.present_machines():
        tr = repo.store.data.machine_view(machine)
        te = test.machine_view(machine)
        if len(tr) < 5 or len(te) < 2:
            continue            # too little shared data for this machine yet
        errs, selected = repo.model_errors(machine, test,
                                           track_models=cfg.track_models,
                                           seed=cfg.seed)
        for model, (mape, mae) in errs.items():
            rec = {"job": job, "held_out": held, "step": step,
                   "store_rows": store_rows,
                   "rows_contributed": repo.store.rows_contributed,
                   "epoch": repo.store.epoch, "machine": machine,
                   "model": model, "mape": mape, "mae": mae,
                   "selected": selected if model == "c3o" else ""}
            if extra:
                rec.update(extra)
            out.append(rec)
    return out


def _maybe_compact(store: RuntimeDataStore, cfg: ReplayConfig
                   ) -> Tuple[int, int]:
    """Run the cap-escalation ladder once: tightest cap first, first
    accepted epoch transition wins.  Returns (rungs tried, accepted 0/1);
    every rejected rung is a guaranteed no-op on the store."""
    tried = 0
    for cap in cfg.compact_caps:
        tried += 1
        report = store.compact(
            max_rows_per_cell=int(cap), support_floor=cfg.compact_floor,
            cell_rel_width=cfg.compact_width,
            accuracy_budget=cfg.compact_budget,
            min_store_rows=cfg.compact_min_rows, seed=cfg.seed)
        if report.accepted:
            return tried, 1
    return tried, 0


def replay_job(job: str, mu: MultiUserData, cfg: ReplayConfig
               ) -> Tuple[List[dict], int, int, int, int]:
    """Leave-one-user-out replay of one job.

    Returns (trajectory records, contributions attempted, accepted,
    compaction rungs attempted, compactions accepted)."""
    if len(mu.users) < 2:
        raise ValueError(
            f"leave-one-user-out needs at least 2 users, got {len(mu.users)}"
            " (with 1 user there is nobody left to contribute)")
    records: List[dict] = []
    contributions = accepted = 0
    comp_tried = comp_done = 0
    for held in mu.users:
        test = mu.per_user[held]
        chunks = []
        for u in mu.users:
            if u == held:
                continue
            # contributions carry REAL provenance: each chunk is stamped
            # with its user's contributor id, so the replayed store can be
            # split back into per-user datasets (eval.dataset.
            # split_by_contributor) and the gateway reports true
            # per-contributor stats over replay output
            chunks.extend(
                c.with_contributor(user_contributor(u))
                for c in contribution_chunks(
                    mu.per_user[u], cfg.chunks_per_user,
                    derived_rng("chunks", job, u, cfg.seed)))
        order = derived_rng("order", job, held, cfg.seed) \
            .permutation(len(chunks))
        store = RuntimeDataStore(chunks[order[0]], seed=cfg.seed,
                                 model_names=list(cfg.model_names),
                                 max_validation_rows=cfg.max_validation_rows)
        repo = JobRepo(job, job, test.schema, store,
                       model_names=list(cfg.model_names),
                       predictor_kw={"pad_rows": True,
                                     "max_cv_folds": cfg.max_cv_folds})
        records += _checkpoint(job, held, 0, repo, test, cfg)
        for step, ci in enumerate(order[1:], start=1):
            report = store.contribute(chunks[ci])
            contributions += 1
            accepted += bool(report.accepted)
            # compaction runs BEFORE the checkpoint so each trajectory row
            # scores the store state the next reader would actually see
            if cfg.compact_every > 0 and step % cfg.compact_every == 0:
                t, d = _maybe_compact(store, cfg)
                comp_tried += t
                comp_done += d
            records += _checkpoint(job, held, step, repo, test, cfg)
    return records, contributions, accepted, comp_tried, comp_done


# ---------------------------------------------------------------------------
# trajectory TSV + summary
# ---------------------------------------------------------------------------

def trajectory_tsv(records: Sequence[dict]) -> str:
    """Canonical TSV of the trajectory records (the determinism artifact:
    byte-identical across runs of the same config on the same platform)."""
    lines = ["\t".join(TRAJECTORY_COLUMNS)]
    for r in records:
        lines.append("\t".join((
            r["job"], str(r["held_out"]), str(r["step"]),
            str(r["store_rows"]),
            str(r.get("rows_contributed", r["store_rows"])),
            str(r.get("epoch", 0)), r["machine"], r["model"],
            "%.6g" % r["mape"], "%.6g" % r["mae"], r["selected"])))
    return "\n".join(lines) + "\n"


def _quartile_medians(sizes: np.ndarray, errs: np.ndarray) -> List[float]:
    """Median error per store-size quartile (Fig. 5's x-axis compressed to
    four buckets; medians across users/machines tame measurement noise).

    Quartiles are equal-count over the size-sorted records (stable sort, so
    ties split deterministically) — every bucket is non-empty even when the
    replay only visited a few distinct store sizes."""
    order = np.argsort(sizes, kind="stable")
    return [float(np.median(errs[part]))
            for part in np.array_split(order, 4) if len(part)]


def summarize(records: Sequence[dict], cfg: ReplayConfig) -> Dict[str, dict]:
    """Per-job rollup of the acceptance criteria: final-store MAPE per
    model, C3O vs baselines, and quartile-median error monotonicity."""
    summary: Dict[str, dict] = {}
    for job in cfg.jobs:
        rows = [r for r in records if r["job"] == job]
        if not rows:
            continue
        # final-store errors: the last checkpoint of each held-out user
        last_step: Dict[int, int] = {}
        for r in rows:
            last_step[r["held_out"]] = max(r["step"],
                                           last_step.get(r["held_out"], 0))
        final: Dict[str, List[float]] = {}
        for r in rows:
            if r["step"] == last_step[r["held_out"]]:
                final.setdefault(r["model"], []).append(r["mape"])
        final_mape = {m: float(np.mean(v)) for m, v in final.items()}
        c3o = [r for r in rows if r["model"] == "c3o"]
        # the x-axis is LIFETIME ingested rows (== live rows while the
        # store is append-only): under periodic compaction the live store
        # shrinks at epoch transitions, but collaboration progress — what
        # Fig. 5 plots — is how much data flowed in, not what was retained
        sizes = np.asarray([r.get("rows_contributed", r["store_rows"])
                            for r in c3o], np.float64)
        errs = np.asarray([r["mape"] for r in c3o], np.float64)
        quart = _quartile_medians(sizes, errs)
        # non-increasing across store-size quartiles, with a small noise
        # band between ADJACENT quartiles (5% relative + 0.005 absolute —
        # the emulator's measurement-noise floor: a job that converges in
        # the first quartile sits at its error floor, where medians wiggle
        # at that level) — but the full-store quartile must be STRICTLY
        # below the small-store one: a flat trajectory means collaboration
        # taught the predictor nothing, which is a failure, not a pass
        monotone = (all(quart[i + 1] <= quart[i] * 1.05 + 5e-3
                        for i in range(len(quart) - 1))
                    and quart[-1] < quart[0])
        baselines = {b: final_mape[b] for b in BASELINE_MODELS
                     if b in final_mape}
        beats = all(final_mape["c3o"] < v for v in baselines.values())
        selected = {}
        for r in c3o:
            if r["step"] == last_step[r["held_out"]] and r["selected"]:
                selected[r["selected"]] = selected.get(r["selected"], 0) + 1
        # store-size frontier at the final checkpoint: retained / ingested
        # (1.0 when compaction is off), and the epoch the store reached
        fin = [r for r in c3o if r["step"] == last_step[r["held_out"]]]
        retention = float(np.mean(
            [r["store_rows"] / max(r.get("rows_contributed",
                                         r["store_rows"]), 1)
             for r in fin])) if fin else 1.0
        final_epoch = max((r.get("epoch", 0) for r in fin), default=0)
        summary[job] = {
            "final_mape": final_mape,
            "c3o_final": final_mape["c3o"],
            "baselines": baselines,
            "beats_baselines": beats,
            "quartile_medians": quart,
            "monotone": monotone,
            "selected_counts": selected,
            "retention": retention,
            "final_epoch": final_epoch,
            "ok": final_mape["c3o"] < 0.10 and beats and monotone,
        }
    return summary


def run_replay(cfg: ReplayConfig) -> ReplayResult:
    t0 = time.time()
    records: List[dict] = []
    contributions = accepted = 0
    comp_tried = comp_done = 0
    for job in cfg.jobs:
        mu = build_multi_user(job, cfg.n_users, cfg.seed)
        recs, contribs, acc, ct, cd = replay_job(job, mu, cfg)
        records += recs
        contributions += contribs
        accepted += acc
        comp_tried += ct
        comp_done += cd
    tsv = trajectory_tsv(records)
    return ReplayResult(
        config=cfg, records=records, tsv=tsv,
        fingerprint=hashlib.sha256(tsv.encode()).hexdigest(),
        summary=summarize(records, cfg), wall_s=time.time() - t0,
        contributions=contributions, accepted=accepted,
        compactions_attempted=comp_tried, compactions=comp_done)


# ---------------------------------------------------------------------------
# zero-history cold-start evaluation (--cold-start-job)
# ---------------------------------------------------------------------------

COLD_COLUMNS = ("job", "step", "store_rows", "source", "confidence",
                "machine", "model", "mape", "mae")


@dataclass(frozen=True)
class ColdStartConfig:
    """Zero-history transfer evaluation: per job family, a held-out cold
    twin (``spark_emul.cold_probe`` — a few probe rows, far below the
    transfer policy's ``min_rows``) is served by a transfer-enabled
    gateway while the families' donor stores grow user by user, charting
    borrowed-model error vs donor store size against the no-history
    global-mean baseline."""
    jobs: Tuple[str, ...] = tuple(SCHEMAS)
    n_users: int = 6
    seed: int = 0
    model_names: Tuple[str, ...] = DEFAULT_MODELS
    max_cv_folds: int = 20
    max_validation_rows: int = 1024
    min_rows: int = 24                # TransferPolicy.min_rows


@dataclass
class ColdStartResult:
    config: ColdStartConfig
    records: List[dict]
    tsv: str
    fingerprint: str
    summary: Dict[str, dict]
    wall_s: float

    @property
    def ok(self) -> bool:
        """Borrowed models must beat the no-history baseline at the final
        store size on >= 80% of the emulated families (4 of 5)."""
        need = math.ceil(0.8 * len(self.summary))
        return sum(bool(s["beats_mean"])
                   for s in self.summary.values()) >= need


def cold_tsv(records: Sequence[dict]) -> str:
    """Canonical TSV of the cold-start records (byte-identical across
    reruns of the same config on the same platform)."""
    lines = ["\t".join(COLD_COLUMNS)]
    for r in records:
        lines.append("\t".join((
            r["job"], str(r["step"]), str(r["store_rows"]), r["source"],
            "%.6g" % r["confidence"], r["machine"], r["model"],
            "%.6g" % r["mape"], "%.6g" % r["mae"])))
    return "\n".join(lines) + "\n"


def _cold_checkpoint(step: int, gw, stores: Dict[str, RuntimeDataStore],
                     tests: Dict[str, object],
                     cfg: ColdStartConfig) -> List[dict]:
    """Score every cold twin's full ground-truth dataset through the
    transfer-enabled gateway at the current donor store sizes.

    Two models per (family, machine): ``borrowed`` — the gateway's
    cold-start answer, stamped with its transfer source/confidence — and
    ``mean`` — the no-history baseline that predicts the global mean
    runtime pooled over every donor store (what a hub with no transfer
    and no job history could do)."""
    out = []
    pooled = np.concatenate([s.data.runtime for s in stores.values()])
    gmean = float(pooled.mean())
    for job in cfg.jobs:
        test = tests[job]
        cold_name = W.cold_job_name(job)
        rows = len(stores[job])
        for machine in sorted(test.present_machines()):
            te = test.machine_view(machine)
            y = np.asarray(te.y, np.float64)
            resp = gw.predict(PredictRequest(
                cold_name, machine,
                tuple(tuple(r) for r in te.X.tolist()), seed=cfg.seed))
            if not resp.ok:
                raise RuntimeError(
                    f"cold-start predict failed for {cold_name!r} on "
                    f"{machine!r}: {resp.error_code}: {resp.detail}")
            pred = np.asarray(resp.result.runtimes_s, np.float64)
            for model, p, src, conf in (
                    ("borrowed", pred, resp.result.transfer_source,
                     resp.result.transfer_confidence),
                    ("mean", np.full_like(y, gmean), "", 1.0)):
                out.append({
                    "job": job, "step": step, "store_rows": rows,
                    "source": src, "confidence": float(conf),
                    "machine": machine, "model": model,
                    "mape": float(np.mean(np.abs(p - y) / y)),
                    "mae": float(np.mean(np.abs(p - y)))})
    return out


def summarize_cold(records: Sequence[dict],
                   cfg: ColdStartConfig) -> Dict[str, dict]:
    """Per-family rollup: final borrowed vs baseline MAPE, the donors the
    lookup actually picked, and whether growing donor stores helped."""
    summary: Dict[str, dict] = {}
    for job in cfg.jobs:
        rows = [r for r in records if r["job"] == job]
        if not rows:
            continue
        last = max(r["step"] for r in rows)
        fin_b = [r["mape"] for r in rows
                 if r["step"] == last and r["model"] == "borrowed"]
        fin_m = [r["mape"] for r in rows
                 if r["step"] == last and r["model"] == "mean"]
        first_b = [r["mape"] for r in rows
                   if r["step"] == 0 and r["model"] == "borrowed"]
        summary[job] = {
            "borrowed_final": float(np.mean(fin_b)),
            "borrowed_first": float(np.mean(first_b)),
            "mean_final": float(np.mean(fin_m)),
            "beats_mean": bool(np.mean(fin_b) < np.mean(fin_m)),
            "sources": sorted({r["source"] for r in rows
                               if r["model"] == "borrowed"}),
            "confidence_final": float(np.mean(
                [r["confidence"] for r in rows
                 if r["step"] == last and r["model"] == "borrowed"])),
        }
    return summary


def run_cold_start(cfg: ColdStartConfig) -> ColdStartResult:
    """The zero-history evaluation loop (see ``ColdStartConfig``)."""
    t0 = time.time()
    hub = Hub()
    stores: Dict[str, RuntimeDataStore] = {}
    tests: Dict[str, object] = {}
    mus: Dict[str, MultiUserData] = {}
    repo_kw = dict(model_names=list(cfg.model_names),
                   predictor_kw={"pad_rows": True,
                                 "max_cv_folds": cfg.max_cv_folds})
    for job in cfg.jobs:
        mus[job] = build_multi_user(job, cfg.n_users, cfg.seed)
        first = mus[job].users[0]
        store = RuntimeDataStore(
            mus[job].per_user[first].with_contributor(
                user_contributor(first)),
            seed=cfg.seed, model_names=list(cfg.model_names),
            max_validation_rows=cfg.max_validation_rows)
        stores[job] = store
        hub.publish(JobRepo(job, job, SCHEMAS[job], store, **repo_kw))
        # the cold twin: published with only its probe rows (below
        # min_rows, so the gateway will borrow), tested on its full
        # ground-truth dataset (which a real hub never has)
        hub.publish(JobRepo(
            W.cold_job_name(job), f"{job} (cold twin)", W.cold_schema(job),
            RuntimeDataStore(W.cold_probe(job, cfg.seed), seed=cfg.seed,
                             model_names=list(cfg.model_names)), **repo_kw))
        tests[job] = W.generate_cold_job_data(job, cfg.seed)
    prices = {m.name: m.price for m in W.MACHINES.values()}
    gw = hub.gateway(prices, (2, 3, 4, 6, 8, 12), seed=cfg.seed,
                     transfer=TransferPolicy(min_rows=cfg.min_rows))
    records = _cold_checkpoint(0, gw, stores, tests, cfg)
    for step, pos in enumerate(range(1, cfg.n_users), start=1):
        for job in cfg.jobs:
            u = mus[job].users[pos]
            stores[job].contribute(mus[job].per_user[u].with_contributor(
                user_contributor(u)))
        records += _cold_checkpoint(step, gw, stores, tests, cfg)
    tsv = cold_tsv(records)
    return ColdStartResult(
        config=cfg, records=records, tsv=tsv,
        fingerprint=hashlib.sha256(tsv.encode()).hexdigest(),
        summary=summarize_cold(records, cfg), wall_s=time.time() - t0)


# ---------------------------------------------------------------------------
# spot-market replay (cloud market plane evaluation)
# ---------------------------------------------------------------------------

SPOT_COLUMNS = ("job", "query", "tick", "arm", "machine", "zone", "option",
                "scale_out", "predicted_s", "true_s", "realized_s",
                "listed_cost", "expected_cost", "realized_cost")


@dataclass(frozen=True)
class SpotMarketConfig:
    """Interruption-aware placement evaluation: per job family, a seeded
    stream of choose queries is answered by two gateways over the SAME
    emulated spot market (``spark_emul.generate_price_book``) — one
    ranking on interruption-adjusted expected cost, one on the naive
    cheapest listed price (the same book with every interruption rate
    zeroed).  Both choices are then charged their *realized* completion
    cost: true emulated runtime plus seeded Exp(rate) interruption draws
    with restart overhead, priced at the placement's listed rate."""
    jobs: Tuple[str, ...] = tuple(SCHEMAS)
    seed: int = 0
    n_queries: int = 40
    n_ticks: int = 64
    #: seeded interruption realizations averaged per (query, choice) —
    #: the workload recurs (a daily production job), so its realized cost
    #: is a mean over runs, not one lucky/unlucky draw
    n_trials: int = 16
    model_names: Tuple[str, ...] = DEFAULT_MODELS
    max_cv_folds: int = 20
    scaleouts: Tuple[int, ...] = (2, 3, 4, 6, 8, 12)


@dataclass
class SpotMarketResult:
    config: SpotMarketConfig
    records: List[dict]
    tsv: str
    fingerprint: str
    summary: Dict[str, dict]
    wall_s: float

    @property
    def ok(self) -> bool:
        """Interruption-adjusted selection must strictly beat the naive
        cheapest-listed-price baseline on total realized completion cost
        for EVERY emulated job family."""
        return bool(self.summary) and all(s["ok"]
                                          for s in self.summary.values())


def spot_tsv(records: Sequence[dict]) -> str:
    """Canonical TSV of the spot-market records (byte-identical across
    reruns of the same config on the same platform)."""
    lines = ["\t".join(SPOT_COLUMNS)]
    for r in records:
        lines.append("\t".join((
            r["job"], str(r["query"]), str(r["tick"]), r["arm"],
            r["machine"], r["zone"], r["option"], str(r["scale_out"]),
            "%.6g" % r["predicted_s"], "%.6g" % r["true_s"],
            "%.6g" % r["realized_s"], "%.6g" % r["listed_cost"],
            "%.6g" % r["expected_cost"], "%.6g" % r["realized_cost"])))
    return "\n".join(lines) + "\n"


def _spot_query_context(job: str, q: int, seed: int) -> Tuple[float, ...]:
    """Seeded query context: a canonical design cell with the (physically
    continuous) dataset size jittered, integer parameters kept on-grid."""
    cells, _ = W._job_cells(job)
    rng = derived_rng("spot-query", job, q, seed)
    cell = list(cells[int(rng.integers(len(cells)))])
    cell[0] = float(cell[0]) * float(rng.uniform(0.85, 1.15))
    return tuple(float(v) for v in cell)


def _spot_realize(job: str, q: int, choice, book, n_trials: int,
                  seed: int) -> Tuple[float, float, float]:
    """(true runtime, realized wall-clock, realized $) for one choice,
    averaged over ``n_trials`` seeded interruption realizations.

    The realizations draw from the REAL market's interruption rate for
    the chosen placement — reality does not care whether the chooser
    priced the risk in — keyed on (job, query, placement, machine,
    scale-out) so both arms making the SAME choice are charged the
    identical draws."""
    ctx = _spot_query_context(job, q, seed)
    true_t = W.true_runtime(job, choice.machine_type,
                            float(choice.scale_out), ctx)
    rate = book.rate_of(choice.zone, choice.purchase_option)
    rng = derived_rng("spot-realize", job, q, choice.zone,
                      choice.purchase_option, choice.machine_type,
                      choice.scale_out, seed)
    realized_s = float(np.mean([
        realized_completion_time_s(true_t, rate, book.restart_overhead_s,
                                   rng) for _ in range(n_trials)]))
    price = book.price_of(choice.machine_type, choice.zone,
                          choice.purchase_option)
    realized_cost = price * (realized_s / 3600.0) * choice.scale_out
    return float(true_t), float(realized_s), float(realized_cost)


def summarize_spot(records: Sequence[dict],
                   cfg: SpotMarketConfig) -> Dict[str, dict]:
    """Per-family rollup: total realized cost per arm, the savings
    ratio, and how often the two arms actually chose differently."""
    summary: Dict[str, dict] = {}
    for job in cfg.jobs:
        rows = [r for r in records if r["job"] == job]
        if not rows:
            continue
        adj = sum(r["realized_cost"] for r in rows
                  if r["arm"] == "adjusted")
        nai = sum(r["realized_cost"] for r in rows if r["arm"] == "naive")
        by_q: Dict[int, dict] = {}
        for r in rows:
            by_q.setdefault(r["query"], {})[r["arm"]] = (
                r["machine"], r["zone"], r["option"], r["scale_out"])
        diverged = sum(1 for d in by_q.values()
                       if d.get("adjusted") != d.get("naive"))
        summary[job] = {
            "adjusted_cost": float(adj), "naive_cost": float(nai),
            "savings": float(nai / adj) if adj > 0 else float("inf"),
            "diverged": int(diverged), "queries": len(by_q),
            "ok": bool(adj < nai),
        }
    return summary


def run_spot_market(cfg: SpotMarketConfig) -> SpotMarketResult:
    """The spot-market evaluation loop (see ``SpotMarketConfig``)."""
    t0 = time.time()
    hub = Hub()
    for job in cfg.jobs:
        store = RuntimeDataStore(
            W.generate_job_data(job, cfg.seed), seed=cfg.seed,
            model_names=list(cfg.model_names))
        hub.publish(JobRepo(
            job, job, SCHEMAS[job], store,
            model_names=list(cfg.model_names),
            predictor_kw={"pad_rows": True,
                          "max_cv_folds": cfg.max_cv_folds}))
    prices = {m.name: m.price for m in W.MACHINES.values()}
    book = W.generate_price_book(cfg.seed, cfg.n_ticks)
    naive_book = book.naive_view()
    gw_adj = hub.gateway(prices, cfg.scaleouts, seed=cfg.seed, market=book)
    gw_naive = hub.gateway(prices, cfg.scaleouts, seed=cfg.seed,
                           market=naive_book)
    records: List[dict] = []
    for job in cfg.jobs:
        for q in range(cfg.n_queries):
            tick = q % cfg.n_ticks
            book.seek(tick)
            naive_book.seek(tick)
            ctx = _spot_query_context(job, q, cfg.seed)
            for arm, gw in (("adjusted", gw_adj), ("naive", gw_naive)):
                resp = gw.choose(ChooseRequest(job, ctx, seed=cfg.seed))
                if not resp.ok:
                    raise RuntimeError(
                        f"spot-market choose failed for {job!r}: "
                        f"{resp.error_code}: {resp.detail}")
                c = resp.result
                true_t, realized_s, realized_cost = _spot_realize(
                    job, q, c, book, cfg.n_trials, cfg.seed)
                records.append({
                    "job": job, "query": q, "tick": tick, "arm": arm,
                    "machine": c.machine_type, "zone": c.zone,
                    "option": c.purchase_option,
                    "scale_out": int(c.scale_out),
                    "predicted_s": float(c.predicted_runtime_s),
                    "true_s": true_t, "realized_s": realized_s,
                    "listed_cost": float(c.cost_usd),
                    "expected_cost": float(c.expected_cost_usd),
                    "realized_cost": realized_cost})
    tsv = spot_tsv(records)
    return SpotMarketResult(
        config=cfg, records=records, tsv=tsv,
        fingerprint=hashlib.sha256(tsv.encode()).hexdigest(),
        summary=summarize_spot(records, cfg), wall_s=time.time() - t0)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.eval.replay",
        description="Leave-one-user-out collaborative replay (paper §VI)")
    ap.add_argument("--users", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--jobs", default=",".join(SCHEMAS),
                    help="comma-separated job subset")
    ap.add_argument("--chunks", type=int, default=1,
                    help="contributions each user splits their data into")
    ap.add_argument("--track-models", default=None,
                    help="comma-separated model names to track per "
                         "checkpoint instead of the default pool (e.g. "
                         "'linreg,gbm'; registered custom maintainer "
                         "models are valid — the c3o row is always "
                         "reported)")
    ap.add_argument("--compact-every", type=int, default=0, metavar="N",
                    help="attempt a store compaction (epoch transition, "
                         "cap-escalation ladder) every N contributions; "
                         "0 disables — the accuracy-vs-size frontier mode")
    ap.add_argument("--spot-market", action="store_true",
                    help="cloud-market evaluation: a seeded multi-AZ "
                         "spot/on-demand market (spark_emul."
                         "generate_price_book) answers choose queries "
                         "via interruption-adjusted expected cost vs the "
                         "naive cheapest-listed-price baseline, scored "
                         "on realized completion cost (replay flags "
                         "other than --jobs/--seed/--queries/--out are "
                         "ignored)")
    ap.add_argument("--queries", type=int, default=40,
                    help="choose queries per job family in --spot-market "
                         "mode")
    ap.add_argument("--cold-start-job", default=None, metavar="JOB",
                    help="zero-history transfer evaluation: emulate a "
                         "held-out cold twin of JOB ('all' = every job) "
                         "served by a transfer-enabled gateway, charting "
                         "borrowed-model error vs donor store size "
                         "against the global-mean baseline (replay flags "
                         "other than --users/--seed/--out are ignored)")
    ap.add_argument("--out", default=None,
                    help="trajectory TSV path (default: "
                         "eval_out/replay_users<N>_seed<S>[_compact<N>]"
                         ".tsv)")
    args = ap.parse_args(argv)
    if args.compact_every < 0:
        ap.error("--compact-every must be >= 0")
    if args.spot_market:
        return _main_spot_market(ap, args)
    if args.cold_start_job is not None:
        return _main_cold_start(ap, args)
    track_kw = ({} if args.track_models is None else
                {"track_models": tuple(args.track_models.split(","))})
    cfg = ReplayConfig(jobs=tuple(args.jobs.split(",")), n_users=args.users,
                       seed=args.seed, chunks_per_user=args.chunks,
                       compact_every=args.compact_every, **track_kw)
    res = run_replay(cfg)

    tag = f"_compact{cfg.compact_every}" if cfg.compact_every else ""
    out = args.out or os.path.join(
        "eval_out", f"replay_users{cfg.n_users}_seed{cfg.seed}{tag}.tsv")
    os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
    with open(out, "w") as f:
        f.write(res.tsv)

    for job, s in res.summary.items():
        base = " ".join(f"{m}={v:.4f}" for m, v in sorted(s["baselines"].items()))
        quart = ">".join(f"{q:.4f}" for q in s["quartile_medians"])
        sel = ",".join(f"{k}:{v}" for k, v in sorted(s["selected_counts"].items()))
        comp = (f" retention={s['retention']:.3f} "
                f"epoch={s['final_epoch']}" if cfg.compact_every else "")
        print(f"replay.{job} c3o_final={s['c3o_final']:.4f} {base} "
              f"beats_baselines={s['beats_baselines']} "
              f"quartile_medians={quart} monotone={s['monotone']} "
              f"selected={sel}{comp} ok={s['ok']}")
    print(f"replay.contributions {res.accepted}/{res.contributions} accepted")
    if cfg.compact_every:
        print(f"replay.compactions {res.compactions}/"
              f"{res.compactions_attempted} ladder rungs accepted")
    print(f"replay.trajectory {out} rows={len(res.records)}")
    print(f"replay.fingerprint {res.fingerprint}")
    print(f"replay.wall_s {res.wall_s:.1f}")
    print(f"replay.ok {res.ok}")
    return 0 if res.ok else 1


def _main_spot_market(ap, args) -> int:
    """--spot-market branch of the CLI."""
    jobs = tuple(args.jobs.split(","))
    unknown = [j for j in jobs if j not in SCHEMAS]
    if unknown:
        ap.error(f"--jobs names unknown job(s) {', '.join(unknown)} "
                 f"(known: {', '.join(SCHEMAS)})")
    if args.queries < 1:
        ap.error("--queries must be >= 1")
    cfg = SpotMarketConfig(jobs=jobs, seed=args.seed,
                           n_queries=args.queries)
    res = run_spot_market(cfg)
    out = args.out or os.path.join(
        "eval_out", f"spotmarket_q{cfg.n_queries}_seed{cfg.seed}.tsv")
    os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
    with open(out, "w") as f:
        f.write(res.tsv)
    for job, s in res.summary.items():
        print(f"spotmarket.{job} adjusted=${s['adjusted_cost']:.4f} "
              f"naive=${s['naive_cost']:.4f} savings={s['savings']:.2f}x "
              f"diverged={s['diverged']}/{s['queries']} ok={s['ok']}")
    print(f"spotmarket.trajectory {out} rows={len(res.records)}")
    print(f"spotmarket.fingerprint {res.fingerprint}")
    print(f"spotmarket.wall_s {res.wall_s:.1f}")
    print(f"spotmarket.ok {res.ok}")
    return 0 if res.ok else 1


def _main_cold_start(ap, args) -> int:
    """--cold-start-job branch of the CLI."""
    jobs = tuple(SCHEMAS) if args.cold_start_job == "all" \
        else tuple(args.cold_start_job.split(","))
    unknown = [j for j in jobs if j not in SCHEMAS]
    if unknown:
        ap.error(f"--cold-start-job names unknown job(s) "
                 f"{', '.join(unknown)} (known: {', '.join(SCHEMAS)} "
                 "or 'all')")
    cfg = ColdStartConfig(jobs=jobs, n_users=args.users, seed=args.seed)
    res = run_cold_start(cfg)
    out = args.out or os.path.join(
        "eval_out", f"coldstart_users{cfg.n_users}_seed{cfg.seed}.tsv")
    os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
    with open(out, "w") as f:
        f.write(res.tsv)
    for job, s in res.summary.items():
        print(f"coldstart.{job} borrowed_final={s['borrowed_final']:.4f} "
              f"borrowed_first={s['borrowed_first']:.4f} "
              f"mean_final={s['mean_final']:.4f} "
              f"beats_mean={s['beats_mean']} "
              f"sources={','.join(s['sources'])} "
              f"confidence={s['confidence_final']:.3f}")
    print(f"coldstart.trajectory {out} rows={len(res.records)}")
    print(f"coldstart.fingerprint {res.fingerprint}")
    print(f"coldstart.wall_s {res.wall_s:.1f}")
    print(f"coldstart.ok {res.ok}")
    return 0 if res.ok else 1


if __name__ == "__main__":
    sys.exit(main())
