"""Deterministic collaborative-replay harness (paper §VI, Fig. 5/6).

Leave-one-user-out over a multi-user emulated dataset: for each held-out
user, the remaining users' measurements are ingested into a fresh
``RuntimeDataStore`` through ``contribute`` (validated, fingerprint-chained)
in a seeded shuffled contribution order, and after every contribution the
held-out user's configurations are scored — per machine type, per model —
producing MAPE/MAE *trajectories versus store size*: the paper's
error-vs-training-data curves, with all model selection flowing through
``engine.cv_select`` (via ``JobRepo.predictor_for``) and all per-model
scoring through the engine's fused, shape-bucketed ``val_executable``s.

Determinism: every RNG is seeded from SHA-256 of a structured identity key
(job, user, seed); trajectory rows are emitted in a canonical order and the
harness reports a SHA-256 fingerprint of the trajectory TSV — two runs of
``python -m repro.eval.replay --users 8 --seed 0`` produce byte-identical
trajectories.

CLI:
    PYTHONPATH=src python -m repro.eval.replay --users 8 --seed 0
"""
from __future__ import annotations

import argparse
import hashlib
import os
import sys
import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.datastore import RuntimeDataStore
from repro.core.hub import JobRepo
from repro.core.predictor import DEFAULT_MODELS
from repro.eval.dataset import (MultiUserData, build_multi_user,
                                contribution_chunks, derived_rng,
                                user_contributor)
from repro.workloads.spark_emul import SCHEMAS

TRAJECTORY_COLUMNS = ("job", "held_out", "step", "store_rows", "machine",
                      "model", "mape", "mae", "selected")

#: the C3O row must strictly beat these at full store size (ISSUE/paper
#: Table II: the optimistic BOM and a plain linear regressor are the
#: reference baselines the specialized selection is measured against)
BASELINE_MODELS = ("bom", "linreg")


@dataclass(frozen=True)
class ReplayConfig:
    jobs: Tuple[str, ...] = tuple(SCHEMAS)
    n_users: int = 8
    seed: int = 0
    chunks_per_user: int = 1          # contributions each user splits into
    model_names: Tuple[str, ...] = DEFAULT_MODELS      # c3o selection pool
    track_models: Tuple[str, ...] = DEFAULT_MODELS + ("linreg",)
    max_cv_folds: int = 20
    max_validation_rows: int = 1024


@dataclass
class ReplayResult:
    config: ReplayConfig
    records: List[dict]
    tsv: str
    fingerprint: str
    summary: Dict[str, dict]
    wall_s: float
    contributions: int = 0
    accepted: int = 0

    @property
    def ok(self) -> bool:
        return all(s["ok"] for s in self.summary.values())


# ---------------------------------------------------------------------------
# replay core
# ---------------------------------------------------------------------------

def _checkpoint(job: str, held: int, step: int, repo: JobRepo,
                test, cfg, extra: Optional[dict] = None) -> List[dict]:
    """Score the held-out user's rows against the current store state.

    ``extra`` key/values are merged into every record — the adversarial
    replay stamps its ``weighting`` arm here so on/off trajectories share
    one record stream."""
    out = []
    store_rows = len(repo.store)
    for machine in test.present_machines():
        tr = repo.store.data.machine_view(machine)
        te = test.machine_view(machine)
        if len(tr) < 5 or len(te) < 2:
            continue            # too little shared data for this machine yet
        errs, selected = repo.model_errors(machine, test,
                                           track_models=cfg.track_models,
                                           seed=cfg.seed)
        for model, (mape, mae) in errs.items():
            rec = {"job": job, "held_out": held, "step": step,
                   "store_rows": store_rows, "machine": machine,
                   "model": model, "mape": mape, "mae": mae,
                   "selected": selected if model == "c3o" else ""}
            if extra:
                rec.update(extra)
            out.append(rec)
    return out


def replay_job(job: str, mu: MultiUserData, cfg: ReplayConfig
               ) -> Tuple[List[dict], int, int]:
    """Leave-one-user-out replay of one job.

    Returns (trajectory records, contributions attempted, accepted)."""
    if len(mu.users) < 2:
        raise ValueError(
            f"leave-one-user-out needs at least 2 users, got {len(mu.users)}"
            " (with 1 user there is nobody left to contribute)")
    records: List[dict] = []
    contributions = accepted = 0
    for held in mu.users:
        test = mu.per_user[held]
        chunks = []
        for u in mu.users:
            if u == held:
                continue
            # contributions carry REAL provenance: each chunk is stamped
            # with its user's contributor id, so the replayed store can be
            # split back into per-user datasets (eval.dataset.
            # split_by_contributor) and the gateway reports true
            # per-contributor stats over replay output
            chunks.extend(
                c.with_contributor(user_contributor(u))
                for c in contribution_chunks(
                    mu.per_user[u], cfg.chunks_per_user,
                    derived_rng("chunks", job, u, cfg.seed)))
        order = derived_rng("order", job, held, cfg.seed) \
            .permutation(len(chunks))
        store = RuntimeDataStore(chunks[order[0]], seed=cfg.seed,
                                 model_names=list(cfg.model_names),
                                 max_validation_rows=cfg.max_validation_rows)
        repo = JobRepo(job, job, test.schema, store,
                       model_names=list(cfg.model_names),
                       predictor_kw={"pad_rows": True,
                                     "max_cv_folds": cfg.max_cv_folds})
        records += _checkpoint(job, held, 0, repo, test, cfg)
        for step, ci in enumerate(order[1:], start=1):
            report = store.contribute(chunks[ci])
            contributions += 1
            accepted += bool(report.accepted)
            records += _checkpoint(job, held, step, repo, test, cfg)
    return records, contributions, accepted


# ---------------------------------------------------------------------------
# trajectory TSV + summary
# ---------------------------------------------------------------------------

def trajectory_tsv(records: Sequence[dict]) -> str:
    """Canonical TSV of the trajectory records (the determinism artifact:
    byte-identical across runs of the same config on the same platform)."""
    lines = ["\t".join(TRAJECTORY_COLUMNS)]
    for r in records:
        lines.append("\t".join((
            r["job"], str(r["held_out"]), str(r["step"]),
            str(r["store_rows"]), r["machine"], r["model"],
            "%.6g" % r["mape"], "%.6g" % r["mae"], r["selected"])))
    return "\n".join(lines) + "\n"


def _quartile_medians(sizes: np.ndarray, errs: np.ndarray) -> List[float]:
    """Median error per store-size quartile (Fig. 5's x-axis compressed to
    four buckets; medians across users/machines tame measurement noise).

    Quartiles are equal-count over the size-sorted records (stable sort, so
    ties split deterministically) — every bucket is non-empty even when the
    replay only visited a few distinct store sizes."""
    order = np.argsort(sizes, kind="stable")
    return [float(np.median(errs[part]))
            for part in np.array_split(order, 4) if len(part)]


def summarize(records: Sequence[dict], cfg: ReplayConfig) -> Dict[str, dict]:
    """Per-job rollup of the acceptance criteria: final-store MAPE per
    model, C3O vs baselines, and quartile-median error monotonicity."""
    summary: Dict[str, dict] = {}
    for job in cfg.jobs:
        rows = [r for r in records if r["job"] == job]
        if not rows:
            continue
        # final-store errors: the last checkpoint of each held-out user
        last_step: Dict[int, int] = {}
        for r in rows:
            last_step[r["held_out"]] = max(r["step"],
                                           last_step.get(r["held_out"], 0))
        final: Dict[str, List[float]] = {}
        for r in rows:
            if r["step"] == last_step[r["held_out"]]:
                final.setdefault(r["model"], []).append(r["mape"])
        final_mape = {m: float(np.mean(v)) for m, v in final.items()}
        c3o = [r for r in rows if r["model"] == "c3o"]
        sizes = np.asarray([r["store_rows"] for r in c3o], np.float64)
        errs = np.asarray([r["mape"] for r in c3o], np.float64)
        quart = _quartile_medians(sizes, errs)
        # non-increasing across store-size quartiles, with a small noise
        # band between ADJACENT quartiles (5% relative + 0.005 absolute —
        # the emulator's measurement-noise floor: a job that converges in
        # the first quartile sits at its error floor, where medians wiggle
        # at that level) — but the full-store quartile must be STRICTLY
        # below the small-store one: a flat trajectory means collaboration
        # taught the predictor nothing, which is a failure, not a pass
        monotone = (all(quart[i + 1] <= quart[i] * 1.05 + 5e-3
                        for i in range(len(quart) - 1))
                    and quart[-1] < quart[0])
        baselines = {b: final_mape[b] for b in BASELINE_MODELS
                     if b in final_mape}
        beats = all(final_mape["c3o"] < v for v in baselines.values())
        selected = {}
        for r in c3o:
            if r["step"] == last_step[r["held_out"]] and r["selected"]:
                selected[r["selected"]] = selected.get(r["selected"], 0) + 1
        summary[job] = {
            "final_mape": final_mape,
            "c3o_final": final_mape["c3o"],
            "baselines": baselines,
            "beats_baselines": beats,
            "quartile_medians": quart,
            "monotone": monotone,
            "selected_counts": selected,
            "ok": final_mape["c3o"] < 0.10 and beats and monotone,
        }
    return summary


def run_replay(cfg: ReplayConfig) -> ReplayResult:
    t0 = time.time()
    records: List[dict] = []
    contributions = accepted = 0
    for job in cfg.jobs:
        mu = build_multi_user(job, cfg.n_users, cfg.seed)
        recs, contribs, acc = replay_job(job, mu, cfg)
        records += recs
        contributions += contribs
        accepted += acc
    tsv = trajectory_tsv(records)
    return ReplayResult(
        config=cfg, records=records, tsv=tsv,
        fingerprint=hashlib.sha256(tsv.encode()).hexdigest(),
        summary=summarize(records, cfg), wall_s=time.time() - t0,
        contributions=contributions, accepted=accepted)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.eval.replay",
        description="Leave-one-user-out collaborative replay (paper §VI)")
    ap.add_argument("--users", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--jobs", default=",".join(SCHEMAS),
                    help="comma-separated job subset")
    ap.add_argument("--chunks", type=int, default=1,
                    help="contributions each user splits their data into")
    ap.add_argument("--track-models", default=None,
                    help="comma-separated model names to track per "
                         "checkpoint instead of the default pool (e.g. "
                         "'linreg,gbm'; registered custom maintainer "
                         "models are valid — the c3o row is always "
                         "reported)")
    ap.add_argument("--out", default=None,
                    help="trajectory TSV path (default: "
                         "eval_out/replay_users<N>_seed<S>.tsv)")
    args = ap.parse_args(argv)
    track_kw = ({} if args.track_models is None else
                {"track_models": tuple(args.track_models.split(","))})
    cfg = ReplayConfig(jobs=tuple(args.jobs.split(",")), n_users=args.users,
                       seed=args.seed, chunks_per_user=args.chunks,
                       **track_kw)
    res = run_replay(cfg)

    out = args.out or os.path.join(
        "eval_out", f"replay_users{cfg.n_users}_seed{cfg.seed}.tsv")
    os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
    with open(out, "w") as f:
        f.write(res.tsv)

    for job, s in res.summary.items():
        base = " ".join(f"{m}={v:.4f}" for m, v in sorted(s["baselines"].items()))
        quart = ">".join(f"{q:.4f}" for q in s["quartile_medians"])
        sel = ",".join(f"{k}:{v}" for k, v in sorted(s["selected_counts"].items()))
        print(f"replay.{job} c3o_final={s['c3o_final']:.4f} {base} "
              f"beats_baselines={s['beats_baselines']} "
              f"quartile_medians={quart} monotone={s['monotone']} "
              f"selected={sel} ok={s['ok']}")
    print(f"replay.contributions {res.accepted}/{res.contributions} accepted")
    print(f"replay.trajectory {out} rows={len(res.records)}")
    print(f"replay.fingerprint {res.fingerprint}")
    print(f"replay.wall_s {res.wall_s:.1f}")
    print(f"replay.ok {res.ok}")
    return 0 if res.ok else 1


if __name__ == "__main__":
    sys.exit(main())
