"""Deterministic collaborative-replay harness (paper §VI, Fig. 5/6).

Leave-one-user-out over a multi-user emulated dataset: for each held-out
user, the remaining users' measurements are ingested into a fresh
``RuntimeDataStore`` through ``contribute`` (validated, fingerprint-chained)
in a seeded shuffled contribution order, and after every contribution the
held-out user's configurations are scored — per machine type, per model —
producing MAPE/MAE *trajectories versus store size*: the paper's
error-vs-training-data curves, with all model selection flowing through
``engine.cv_select`` (via ``JobRepo.predictor_for``) and all per-model
scoring through the engine's fused, shape-bucketed ``val_executable``s.

Determinism: every RNG is seeded from SHA-256 of a structured identity key
(job, user, seed); trajectory rows are emitted in a canonical order and the
harness reports a SHA-256 fingerprint of the trajectory TSV — two runs of
``python -m repro.eval.replay --users 8 --seed 0`` produce byte-identical
trajectories.

Periodic-compaction mode (``--compact-every N``) additionally attempts a
store epoch transition (``RuntimeDataStore.compact``, cap-escalation
ladder) every N contributions, tracing the accuracy-vs-store-size
frontier: trajectory rows carry both the live ``store_rows`` and the
lifetime ``rows_contributed``/``epoch``, so compacted and append-only
runs plot on the same x-axis.

CLI:
    PYTHONPATH=src python -m repro.eval.replay --users 8 --seed 0
"""
from __future__ import annotations

import argparse
import hashlib
import os
import sys
import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.datastore import RuntimeDataStore
from repro.core.hub import JobRepo
from repro.core.predictor import DEFAULT_MODELS
from repro.eval.dataset import (MultiUserData, build_multi_user,
                                contribution_chunks, derived_rng,
                                user_contributor)
from repro.workloads.spark_emul import SCHEMAS

TRAJECTORY_COLUMNS = ("job", "held_out", "step", "store_rows",
                      "rows_contributed", "epoch", "machine",
                      "model", "mape", "mae", "selected")

#: cap-escalation ladder for periodic compaction: caps are tried tightest
#: first and the first ACCEPTED compaction wins — rejections are free
#: no-ops (no version bump, no reseed), so one config adapts per job to
#: however much redundancy the store actually carries
COMPACT_CAPS = (2, 3, 4, 6)

#: the C3O row must strictly beat these at full store size (ISSUE/paper
#: Table II: the optimistic BOM and a plain linear regressor are the
#: reference baselines the specialized selection is measured against)
BASELINE_MODELS = ("bom", "linreg")


@dataclass(frozen=True)
class ReplayConfig:
    jobs: Tuple[str, ...] = tuple(SCHEMAS)
    n_users: int = 8
    seed: int = 0
    chunks_per_user: int = 1          # contributions each user splits into
    model_names: Tuple[str, ...] = DEFAULT_MODELS      # c3o selection pool
    track_models: Tuple[str, ...] = DEFAULT_MODELS + ("linreg",)
    max_cv_folds: int = 20
    max_validation_rows: int = 1024
    # periodic store compaction (0 = off): every N accepted-or-not
    # contributions the store attempts an epoch transition through the
    # COMPACT_CAPS escalation ladder — the accuracy-vs-size frontier mode
    compact_every: int = 0
    compact_caps: Tuple[int, ...] = COMPACT_CAPS
    compact_floor: int = 2
    compact_width: float = 0.15
    compact_budget: float = 0.01
    compact_min_rows: int = 64


@dataclass
class ReplayResult:
    config: ReplayConfig
    records: List[dict]
    tsv: str
    fingerprint: str
    summary: Dict[str, dict]
    wall_s: float
    contributions: int = 0
    accepted: int = 0
    compactions_attempted: int = 0    # ladder rungs tried (incl. rejected)
    compactions: int = 0              # epoch transitions actually taken

    @property
    def ok(self) -> bool:
        return all(s["ok"] for s in self.summary.values())


# ---------------------------------------------------------------------------
# replay core
# ---------------------------------------------------------------------------

def _checkpoint(job: str, held: int, step: int, repo: JobRepo,
                test, cfg, extra: Optional[dict] = None) -> List[dict]:
    """Score the held-out user's rows against the current store state.

    ``extra`` key/values are merged into every record — the adversarial
    replay stamps its ``weighting`` arm here so on/off trajectories share
    one record stream."""
    out = []
    store_rows = len(repo.store)
    for machine in test.present_machines():
        tr = repo.store.data.machine_view(machine)
        te = test.machine_view(machine)
        if len(tr) < 5 or len(te) < 2:
            continue            # too little shared data for this machine yet
        errs, selected = repo.model_errors(machine, test,
                                           track_models=cfg.track_models,
                                           seed=cfg.seed)
        for model, (mape, mae) in errs.items():
            rec = {"job": job, "held_out": held, "step": step,
                   "store_rows": store_rows,
                   "rows_contributed": repo.store.rows_contributed,
                   "epoch": repo.store.epoch, "machine": machine,
                   "model": model, "mape": mape, "mae": mae,
                   "selected": selected if model == "c3o" else ""}
            if extra:
                rec.update(extra)
            out.append(rec)
    return out


def _maybe_compact(store: RuntimeDataStore, cfg: ReplayConfig
                   ) -> Tuple[int, int]:
    """Run the cap-escalation ladder once: tightest cap first, first
    accepted epoch transition wins.  Returns (rungs tried, accepted 0/1);
    every rejected rung is a guaranteed no-op on the store."""
    tried = 0
    for cap in cfg.compact_caps:
        tried += 1
        report = store.compact(
            max_rows_per_cell=int(cap), support_floor=cfg.compact_floor,
            cell_rel_width=cfg.compact_width,
            accuracy_budget=cfg.compact_budget,
            min_store_rows=cfg.compact_min_rows, seed=cfg.seed)
        if report.accepted:
            return tried, 1
    return tried, 0


def replay_job(job: str, mu: MultiUserData, cfg: ReplayConfig
               ) -> Tuple[List[dict], int, int, int, int]:
    """Leave-one-user-out replay of one job.

    Returns (trajectory records, contributions attempted, accepted,
    compaction rungs attempted, compactions accepted)."""
    if len(mu.users) < 2:
        raise ValueError(
            f"leave-one-user-out needs at least 2 users, got {len(mu.users)}"
            " (with 1 user there is nobody left to contribute)")
    records: List[dict] = []
    contributions = accepted = 0
    comp_tried = comp_done = 0
    for held in mu.users:
        test = mu.per_user[held]
        chunks = []
        for u in mu.users:
            if u == held:
                continue
            # contributions carry REAL provenance: each chunk is stamped
            # with its user's contributor id, so the replayed store can be
            # split back into per-user datasets (eval.dataset.
            # split_by_contributor) and the gateway reports true
            # per-contributor stats over replay output
            chunks.extend(
                c.with_contributor(user_contributor(u))
                for c in contribution_chunks(
                    mu.per_user[u], cfg.chunks_per_user,
                    derived_rng("chunks", job, u, cfg.seed)))
        order = derived_rng("order", job, held, cfg.seed) \
            .permutation(len(chunks))
        store = RuntimeDataStore(chunks[order[0]], seed=cfg.seed,
                                 model_names=list(cfg.model_names),
                                 max_validation_rows=cfg.max_validation_rows)
        repo = JobRepo(job, job, test.schema, store,
                       model_names=list(cfg.model_names),
                       predictor_kw={"pad_rows": True,
                                     "max_cv_folds": cfg.max_cv_folds})
        records += _checkpoint(job, held, 0, repo, test, cfg)
        for step, ci in enumerate(order[1:], start=1):
            report = store.contribute(chunks[ci])
            contributions += 1
            accepted += bool(report.accepted)
            # compaction runs BEFORE the checkpoint so each trajectory row
            # scores the store state the next reader would actually see
            if cfg.compact_every > 0 and step % cfg.compact_every == 0:
                t, d = _maybe_compact(store, cfg)
                comp_tried += t
                comp_done += d
            records += _checkpoint(job, held, step, repo, test, cfg)
    return records, contributions, accepted, comp_tried, comp_done


# ---------------------------------------------------------------------------
# trajectory TSV + summary
# ---------------------------------------------------------------------------

def trajectory_tsv(records: Sequence[dict]) -> str:
    """Canonical TSV of the trajectory records (the determinism artifact:
    byte-identical across runs of the same config on the same platform)."""
    lines = ["\t".join(TRAJECTORY_COLUMNS)]
    for r in records:
        lines.append("\t".join((
            r["job"], str(r["held_out"]), str(r["step"]),
            str(r["store_rows"]),
            str(r.get("rows_contributed", r["store_rows"])),
            str(r.get("epoch", 0)), r["machine"], r["model"],
            "%.6g" % r["mape"], "%.6g" % r["mae"], r["selected"])))
    return "\n".join(lines) + "\n"


def _quartile_medians(sizes: np.ndarray, errs: np.ndarray) -> List[float]:
    """Median error per store-size quartile (Fig. 5's x-axis compressed to
    four buckets; medians across users/machines tame measurement noise).

    Quartiles are equal-count over the size-sorted records (stable sort, so
    ties split deterministically) — every bucket is non-empty even when the
    replay only visited a few distinct store sizes."""
    order = np.argsort(sizes, kind="stable")
    return [float(np.median(errs[part]))
            for part in np.array_split(order, 4) if len(part)]


def summarize(records: Sequence[dict], cfg: ReplayConfig) -> Dict[str, dict]:
    """Per-job rollup of the acceptance criteria: final-store MAPE per
    model, C3O vs baselines, and quartile-median error monotonicity."""
    summary: Dict[str, dict] = {}
    for job in cfg.jobs:
        rows = [r for r in records if r["job"] == job]
        if not rows:
            continue
        # final-store errors: the last checkpoint of each held-out user
        last_step: Dict[int, int] = {}
        for r in rows:
            last_step[r["held_out"]] = max(r["step"],
                                           last_step.get(r["held_out"], 0))
        final: Dict[str, List[float]] = {}
        for r in rows:
            if r["step"] == last_step[r["held_out"]]:
                final.setdefault(r["model"], []).append(r["mape"])
        final_mape = {m: float(np.mean(v)) for m, v in final.items()}
        c3o = [r for r in rows if r["model"] == "c3o"]
        # the x-axis is LIFETIME ingested rows (== live rows while the
        # store is append-only): under periodic compaction the live store
        # shrinks at epoch transitions, but collaboration progress — what
        # Fig. 5 plots — is how much data flowed in, not what was retained
        sizes = np.asarray([r.get("rows_contributed", r["store_rows"])
                            for r in c3o], np.float64)
        errs = np.asarray([r["mape"] for r in c3o], np.float64)
        quart = _quartile_medians(sizes, errs)
        # non-increasing across store-size quartiles, with a small noise
        # band between ADJACENT quartiles (5% relative + 0.005 absolute —
        # the emulator's measurement-noise floor: a job that converges in
        # the first quartile sits at its error floor, where medians wiggle
        # at that level) — but the full-store quartile must be STRICTLY
        # below the small-store one: a flat trajectory means collaboration
        # taught the predictor nothing, which is a failure, not a pass
        monotone = (all(quart[i + 1] <= quart[i] * 1.05 + 5e-3
                        for i in range(len(quart) - 1))
                    and quart[-1] < quart[0])
        baselines = {b: final_mape[b] for b in BASELINE_MODELS
                     if b in final_mape}
        beats = all(final_mape["c3o"] < v for v in baselines.values())
        selected = {}
        for r in c3o:
            if r["step"] == last_step[r["held_out"]] and r["selected"]:
                selected[r["selected"]] = selected.get(r["selected"], 0) + 1
        # store-size frontier at the final checkpoint: retained / ingested
        # (1.0 when compaction is off), and the epoch the store reached
        fin = [r for r in c3o if r["step"] == last_step[r["held_out"]]]
        retention = float(np.mean(
            [r["store_rows"] / max(r.get("rows_contributed",
                                         r["store_rows"]), 1)
             for r in fin])) if fin else 1.0
        final_epoch = max((r.get("epoch", 0) for r in fin), default=0)
        summary[job] = {
            "final_mape": final_mape,
            "c3o_final": final_mape["c3o"],
            "baselines": baselines,
            "beats_baselines": beats,
            "quartile_medians": quart,
            "monotone": monotone,
            "selected_counts": selected,
            "retention": retention,
            "final_epoch": final_epoch,
            "ok": final_mape["c3o"] < 0.10 and beats and monotone,
        }
    return summary


def run_replay(cfg: ReplayConfig) -> ReplayResult:
    t0 = time.time()
    records: List[dict] = []
    contributions = accepted = 0
    comp_tried = comp_done = 0
    for job in cfg.jobs:
        mu = build_multi_user(job, cfg.n_users, cfg.seed)
        recs, contribs, acc, ct, cd = replay_job(job, mu, cfg)
        records += recs
        contributions += contribs
        accepted += acc
        comp_tried += ct
        comp_done += cd
    tsv = trajectory_tsv(records)
    return ReplayResult(
        config=cfg, records=records, tsv=tsv,
        fingerprint=hashlib.sha256(tsv.encode()).hexdigest(),
        summary=summarize(records, cfg), wall_s=time.time() - t0,
        contributions=contributions, accepted=accepted,
        compactions_attempted=comp_tried, compactions=comp_done)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.eval.replay",
        description="Leave-one-user-out collaborative replay (paper §VI)")
    ap.add_argument("--users", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--jobs", default=",".join(SCHEMAS),
                    help="comma-separated job subset")
    ap.add_argument("--chunks", type=int, default=1,
                    help="contributions each user splits their data into")
    ap.add_argument("--track-models", default=None,
                    help="comma-separated model names to track per "
                         "checkpoint instead of the default pool (e.g. "
                         "'linreg,gbm'; registered custom maintainer "
                         "models are valid — the c3o row is always "
                         "reported)")
    ap.add_argument("--compact-every", type=int, default=0, metavar="N",
                    help="attempt a store compaction (epoch transition, "
                         "cap-escalation ladder) every N contributions; "
                         "0 disables — the accuracy-vs-size frontier mode")
    ap.add_argument("--out", default=None,
                    help="trajectory TSV path (default: "
                         "eval_out/replay_users<N>_seed<S>[_compact<N>]"
                         ".tsv)")
    args = ap.parse_args(argv)
    if args.compact_every < 0:
        ap.error("--compact-every must be >= 0")
    track_kw = ({} if args.track_models is None else
                {"track_models": tuple(args.track_models.split(","))})
    cfg = ReplayConfig(jobs=tuple(args.jobs.split(",")), n_users=args.users,
                       seed=args.seed, chunks_per_user=args.chunks,
                       compact_every=args.compact_every, **track_kw)
    res = run_replay(cfg)

    tag = f"_compact{cfg.compact_every}" if cfg.compact_every else ""
    out = args.out or os.path.join(
        "eval_out", f"replay_users{cfg.n_users}_seed{cfg.seed}{tag}.tsv")
    os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
    with open(out, "w") as f:
        f.write(res.tsv)

    for job, s in res.summary.items():
        base = " ".join(f"{m}={v:.4f}" for m, v in sorted(s["baselines"].items()))
        quart = ">".join(f"{q:.4f}" for q in s["quartile_medians"])
        sel = ",".join(f"{k}:{v}" for k, v in sorted(s["selected_counts"].items()))
        comp = (f" retention={s['retention']:.3f} "
                f"epoch={s['final_epoch']}" if cfg.compact_every else "")
        print(f"replay.{job} c3o_final={s['c3o_final']:.4f} {base} "
              f"beats_baselines={s['beats_baselines']} "
              f"quartile_medians={quart} monotone={s['monotone']} "
              f"selected={sel}{comp} ok={s['ok']}")
    print(f"replay.contributions {res.accepted}/{res.contributions} accepted")
    if cfg.compact_every:
        print(f"replay.compactions {res.compactions}/"
              f"{res.compactions_attempted} ladder rungs accepted")
    print(f"replay.trajectory {out} rows={len(res.records)}")
    print(f"replay.fingerprint {res.fingerprint}")
    print(f"replay.wall_s {res.wall_s:.1f}")
    print(f"replay.ok {res.ok}")
    return 0 if res.ok else 1


if __name__ == "__main__":
    sys.exit(main())
