"""Flash-decode kernel (Pallas, TPU target): one query token vs. a long KV
cache, parallelized over cache blocks.

Grid (batch, kv-heads, cache-blocks), cache-block dim innermost with running
(max, sum, acc) scratch over the G=H/KV query rows of this kv head — the same
online-softmax trick as flash attention, but with the *cache length* as the
streamed dimension, which is what serving long contexts (decode_32k /
long_500k cells) needs.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -2.0e38


def _decode_kernel(q_ref, k_ref, v_ref, pos_ref, o_ref, m_scr, l_scr, acc_scr,
                   *, scale, window, softcap, blk, n_blocks, length):
    bi = pl.program_id(2)

    @pl.when(bi == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * scale       # [G, hd]
    k = k_ref[0, 0].astype(jnp.float32)               # [blk, hd]
    v = v_ref[0, 0].astype(jnp.float32)
    pos = pos_ref[0]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # [G, blk]
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    k_pos = bi * blk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    ok = (k_pos <= pos) & (k_pos < length)
    if window:
        ok &= k_pos > pos - window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_scr[...] = l_scr[...] * corr + p.sum(axis=1)
    acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))
    m_scr[...] = m_new

    @pl.when(bi == n_blocks - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def decode_attention(q, k_cache, v_cache, pos, *, window=0, softcap=0.0,
                     scale=None, block=1024, interpret=False):
    """q [B,H,hd]; caches [B,L,KV,hd]; pos scalar int32 -> [B,H,hd]."""
    B, H, hd = q.shape
    L, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    scale = hd ** -0.5 if scale is None else scale
    blk = min(block, L)
    Lp = math.ceil(L / blk) * blk
    qg = q.reshape(B, KV, G, hd)
    kt = jnp.pad(k_cache.transpose(0, 2, 1, 3),
                 ((0, 0), (0, 0), (0, Lp - L), (0, 0)))
    vt = jnp.pad(v_cache.transpose(0, 2, 1, 3),
                 ((0, 0), (0, 0), (0, Lp - L), (0, 0)))
    n_blocks = Lp // blk
    pos_arr = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (1,))

    kernel = functools.partial(_decode_kernel, scale=scale, window=window,
                               softcap=softcap, blk=blk, n_blocks=n_blocks,
                               length=L)
    out = pl.pallas_call(
        kernel,
        grid=(B, KV, n_blocks),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, h, bi: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, blk, hd), lambda b, h, bi: (b, h, bi, 0)),
            pl.BlockSpec((1, 1, blk, hd), lambda b, h, bi: (b, h, bi, 0)),
            pl.BlockSpec((1,), lambda b, h, bi: (0,)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, bi: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        scratch_shapes=[_vmem((G,)), _vmem((G,)), _vmem((G, hd))],
        interpret=interpret,
    )(qg, kt, vt, pos_arr)
    return out.reshape(B, H, hd)


def _vmem(shape):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, jnp.float32)
