"""Flash attention forward kernel (Pallas, TPU target).

Blocked online-softmax: grid (batch, q-heads, q-blocks, kv-blocks) with the
kv-block dimension innermost (sequential on TPU), carrying the running
(max, sum, accumulator) in VMEM scratch.  Supports causal masking, sliding
windows, attention-logit softcapping (gemma2) and GQA (kv head = q head // G
via the k/v BlockSpec index maps).

Block shapes are MXU-aligned (multiples of 128 on the contracting/lane dims);
the VMEM working set per program is
  q_blk*hd + 2*kv_blk*hd (+ scores q_blk*kv_blk) floats,
e.g. 512x128 blocks with hd=128 -> ~0.7 MB, far under the ~16 MB VMEM budget.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -2.0e38
DEFAULT_Q_BLOCK = 512
DEFAULT_KV_BLOCK = 512


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale, causal, window, softcap, q_blk, kv_blk, n_kv_blocks,
                  seq_len):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # [q_blk, hd]
    k = k_ref[0, 0].astype(jnp.float32)                  # [kv_blk, hd]
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # [q_blk, kv_blk]
    if softcap:
        s = softcap * jnp.tanh(s / softcap)

    q_pos = qi * q_blk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    k_pos = ki * kv_blk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    ok = k_pos < seq_len
    if causal:
        ok &= k_pos <= q_pos
    if window:
        ok &= k_pos > q_pos - window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_scr[...] = l_scr[...] * corr + p.sum(axis=1)
    acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())))
    m_scr[...] = m_new

    @pl.when(ki == n_kv_blocks - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal=True, window=0, softcap=0.0,
                    scale=None, q_block=DEFAULT_Q_BLOCK,
                    kv_block=DEFAULT_KV_BLOCK, interpret=False):
    """q [B,S,H,h]; k,v [B,S,KV,h] -> [B,S,H,h] (forward only)."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = hd ** -0.5 if scale is None else scale
    q_blk = min(q_block, S)
    kv_blk = min(kv_block, S)
    # pad S to block multiples
    Sp = math.ceil(S / q_blk) * q_blk
    Skp = math.ceil(S / kv_blk) * kv_blk
    Sp = Skp = max(Sp, Skp)
    qt = jnp.pad(q.transpose(0, 2, 1, 3), ((0, 0), (0, 0), (0, Sp - S), (0, 0)))
    kt = jnp.pad(k.transpose(0, 2, 1, 3), ((0, 0), (0, 0), (0, Sp - S), (0, 0)))
    vt = jnp.pad(v.transpose(0, 2, 1, 3), ((0, 0), (0, 0), (0, Sp - S), (0, 0)))
    n_q, n_kv = Sp // q_blk, Sp // kv_blk

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, q_blk=q_blk, kv_blk=kv_blk, n_kv_blocks=n_kv,
        seq_len=S)
    out = pl.pallas_call(
        kernel,
        grid=(B, H, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, q_blk, hd), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, kv_blk, hd), lambda b, h, qi, ki: (b, h // G, ki, 0)),
            pl.BlockSpec((1, 1, kv_blk, hd), lambda b, h, qi, ki: (b, h // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, q_blk, hd),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sp, hd), q.dtype),
        scratch_shapes=[
            _vmem((q_blk,)), _vmem((q_blk,)), _vmem((q_blk, hd)),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out[:, :, :S].transpose(0, 2, 1, 3)


def _vmem(shape):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, jnp.float32)
