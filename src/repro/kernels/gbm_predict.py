"""Boosted-tree ensemble inference kernel (Pallas, TPU target).

The paper's own compute hot-spot: the cluster configurator evaluates the
runtime predictor over every candidate configuration (machine types x
scale-outs x contexts), and model selection re-predicts during
cross-validation.  This kernel evaluates a full GBM ensemble for a block of
input rows per grid step.

TPU adaptation (see DESIGN.md): tree traversal is gather-heavy on CPUs/GPUs;
here every data-dependent gather is re-cast as a one-hot contraction
(node-index one-hot @ [n_nodes] arrays, feature one-hot @ [rows, d] block),
turning the whole traversal into dense VPU/MXU work with no scatter/gather.

Grid: (row-blocks,); trees run in a fori_loop with the accumulator in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gbm_kernel(x_ref, feat_ref, thr_ref, leaf_ref, f0_ref, o_ref, *,
                n_trees, depth, n_rows):
    x = x_ref[...].astype(jnp.float32)            # [Bn, d]
    Bn, d = x.shape
    n_int = 2 ** depth - 1
    n_leaf = 2 ** depth

    def one_tree(t, acc):
        feat = feat_ref[t].astype(jnp.int32)      # [n_int]
        thr = thr_ref[t].astype(jnp.float32)
        leaf = leaf_ref[t].astype(jnp.float32)    # [n_leaf]
        idx = jnp.zeros((Bn,), jnp.int32)
        for _ in range(depth):
            node_oh = (idx[:, None] ==
                       jax.lax.broadcasted_iota(jnp.int32, (Bn, n_int), 1)
                       ).astype(jnp.float32)
            f_idx = node_oh @ feat.astype(jnp.float32)        # [Bn]
            t_val = node_oh @ thr                             # [Bn]
            feat_oh = (f_idx[:, None] ==
                       jax.lax.broadcasted_iota(jnp.float32, (Bn, d), 1)
                       ).astype(jnp.float32)
            x_f = (x * feat_oh).sum(axis=1)                   # [Bn]
            idx = 2 * idx + 1 + (x_f > t_val).astype(jnp.int32)
        leaf_oh = ((idx - n_int)[:, None] ==
                   jax.lax.broadcasted_iota(jnp.int32, (Bn, n_leaf), 1)
                   ).astype(jnp.float32)
        return acc + leaf_oh @ leaf

    acc = jnp.full((Bn,), f0_ref[0], jnp.float32)
    acc = jax.lax.fori_loop(0, n_trees, one_tree, acc)
    o_ref[...] = acc.astype(o_ref.dtype)


def gbm_predict(X, feat, thr, leaf, f0, y_scale=1.0, *, row_block=256,
                interpret=False):
    """X [n,d]; feat/thr [T,n_int]; leaf [T,n_leaf]; f0 scalar -> [n]."""
    n, d = X.shape
    T, n_int = feat.shape
    depth = int(n_int + 1).bit_length() - 1
    rb = min(row_block, max(n, 8))
    n_pad = -(-n // rb) * rb
    Xp = jnp.pad(X.astype(jnp.float32), ((0, n_pad - n), (0, 0)))
    # unsplittable nodes carry thr=inf; the one-hot contraction would turn
    # 0*inf into NaN, so clamp to a large finite sentinel (same routing)
    thr = jnp.where(jnp.isfinite(thr), thr, 1e30)
    f0_arr = jnp.broadcast_to(jnp.asarray(f0, jnp.float32), (1,))

    kernel = functools.partial(_gbm_kernel, n_trees=T, depth=depth, n_rows=n)
    out = pl.pallas_call(
        kernel,
        grid=(n_pad // rb,),
        in_specs=[
            pl.BlockSpec((rb, d), lambda i: (i, 0)),
            pl.BlockSpec((T, n_int), lambda i: (0, 0)),
            pl.BlockSpec((T, n_int), lambda i: (0, 0)),
            pl.BlockSpec((T, n_int + 1), lambda i: (0, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((rb,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_pad,), jnp.float32),
        interpret=interpret,
    )(Xp, feat, thr, leaf, f0_arr)
    return out[:n] * y_scale
