"""Chunked selective-scan kernel (Pallas, TPU target) for Mamba layers.

Grid (batch, d_inner-blocks, chunks); the chunk dimension is innermost and
carries the [Db, N] state in VMEM scratch.  Within a chunk the recurrence
  h_t = exp(dt_t * A) h_{t-1} + (dt_t * u_t) B_t
is unrolled as a fori_loop over C steps of vector ops on the [Db, N] tile —
the d_inner axis (thousands of channels) provides the SIMD parallelism, which
is the TPU-native layout for this kernel (VPU lanes across channels), in
contrast to CUDA implementations that parallelize across the state dim.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mamba_kernel(u_ref, dt_ref, a_ref, b_ref, c_ref, h0_ref, y_ref, h_out_ref,
                  h_scr, *, n_chunks, chunk):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = h0_ref[0, 0].astype(jnp.float32)

    u = u_ref[0, 0].astype(jnp.float32)             # [C, Db]
    dt = dt_ref[0, 0].astype(jnp.float32)           # [C, Db]
    A = a_ref[...].astype(jnp.float32)           # [Db, N]
    Bm = b_ref[0].astype(jnp.float32)            # [C, N]
    Cm = c_ref[0].astype(jnp.float32)            # [C, N]

    def step(t, carry):
        h, y = carry
        dA = jnp.exp(dt[t][:, None] * A)                     # [Db, N]
        h = dA * h + (dt[t] * u[t])[:, None] * Bm[t][None, :]
        y = y.at[t].set((h * Cm[t][None, :]).sum(axis=1))
        return h, y

    y0 = jnp.zeros((chunk, u.shape[1]), jnp.float32)
    h, y = jax.lax.fori_loop(0, chunk, step, (h_scr[...], y0))
    h_scr[...] = h
    y_ref[0, 0] = y.astype(y_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _finish():
        h_out_ref[0, 0] = h.astype(h_out_ref.dtype)


def mamba_scan(u, dt, A, B_in, C_in, h0=None, *, chunk=64, d_block=512,
               interpret=False):
    """u,dt [B,S,D]; A [D,N]; B_in,C_in [B,S,N]; h0 [B,D,N] ->
    (y [B,S,D], h_end [B,D,N])."""
    B, S, D = u.shape
    N = A.shape[1]
    assert S % chunk == 0
    db = min(d_block, D)
    assert D % db == 0
    n_chunks = S // chunk
    nd = D // db
    if h0 is None:
        h0 = jnp.zeros((B, D, N), jnp.float32)
    # layouts: u/dt [B, nd, S, db] via [B,S,D] -> [B, S, nd, db]
    ur = u.reshape(B, S, nd, db).transpose(0, 2, 1, 3)
    dtr = dt.reshape(B, S, nd, db).transpose(0, 2, 1, 3)
    h0r = h0.reshape(B, nd, db, N)

    kernel = functools.partial(_mamba_kernel, n_chunks=n_chunks, chunk=chunk)
    y, h_end = pl.pallas_call(
        kernel,
        grid=(B, nd, n_chunks),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, db), lambda b, d, ci: (b, d, ci, 0)),
            pl.BlockSpec((1, 1, chunk, db), lambda b, d, ci: (b, d, ci, 0)),
            pl.BlockSpec((db, N), lambda b, d, ci: (d, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, d, ci: (b, ci, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, d, ci: (b, ci, 0)),
            pl.BlockSpec((1, 1, db, N), lambda b, d, ci: (b, d, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, db), lambda b, d, ci: (b, d, ci, 0)),
            pl.BlockSpec((1, 1, db, N), lambda b, d, ci: (b, d, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, nd, S, db), u.dtype),
            jax.ShapeDtypeStruct((B, nd, db, N), jnp.float32),
        ],
        scratch_shapes=[_vmem((db, N))],
        interpret=interpret,
    )(ur, dtr, A, B_in, C_in, h0r)
    y = y.transpose(0, 2, 1, 3).reshape(B, S, D)
    return y, h_end.reshape(B, D, N)


def _vmem(shape):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, jnp.float32)
