"""Jit'd public wrappers for the Pallas kernels.

On TPU the kernels compile natively; on CPU (this container, CI) they run
under ``interpret=True`` which executes the kernel body in Python — the
correctness path used by the test suite's allclose sweeps against ref.py.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels import (decode_attention as _da, flash_attention as _fa,
                           gbm_predict as _gp, mamba_scan as _ms, wkv6 as _wk)


def _interpret() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "q_block", "kv_block"))
def flash_attention(q, k, v, *, causal=True, window=0, softcap=0.0,
                    q_block=512, kv_block=512):
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               softcap=softcap, q_block=q_block,
                               kv_block=kv_block, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("window", "softcap", "block"))
def decode_attention(q, k_cache, v_cache, pos, *, window=0, softcap=0.0,
                     block=1024):
    return _da.decode_attention(q, k_cache, v_cache, pos, window=window,
                                softcap=softcap, block=block,
                                interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("chunk",))
def wkv6(r, k, v, w, u, s0=None, *, chunk=16):
    return _wk.wkv6(r, k, v, w, u, s0, chunk=chunk, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("chunk", "d_block"))
def mamba_scan(u, dt, A, B_in, C_in, h0=None, *, chunk=64, d_block=512):
    return _ms.mamba_scan(u, dt, A, B_in, C_in, h0, chunk=chunk,
                          d_block=d_block, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("row_block",))
def gbm_predict(X, feat, thr, leaf, f0, y_scale=1.0, *, row_block=256):
    return _gp.gbm_predict(X, feat, thr, leaf, f0, y_scale,
                           row_block=row_block, interpret=_interpret())
