"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.0e38


# ------------------------- flash attention --------------------------------

def attention_ref(q, k, v, *, causal=True, window=0, softcap=0.0, scale=None):
    """q [B,S,H,h]; k,v [B,S,KV,h]. Naive full-matrix attention."""
    B, Sq, H, h = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = h ** -0.5 if scale is None else scale
    qg = q.reshape(B, Sq, KV, G, h)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k).astype(jnp.float32) * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    qp, kp = jnp.arange(Sq), jnp.arange(Sk)
    ok = jnp.ones((Sq, Sk), bool)
    if causal:
        ok &= kp[None, :] <= qp[:, None]
    if window:
        ok &= kp[None, :] > qp[:, None] - window
    s = jnp.where(ok, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bqkgh", p.astype(v.dtype), v)
    return o.reshape(B, Sq, H, h)


# ------------------------- decode attention -------------------------------

def decode_attention_ref(q, k_cache, v_cache, *, pos, window=0, softcap=0.0,
                         scale=None):
    """q [B,H,h]; caches [B,L,KV,h]; attends positions [max(0,pos-window+1)..pos]."""
    B, H, h = q.shape
    L, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    scale = h ** -0.5 if scale is None else scale
    qg = q.reshape(B, KV, G, h) * scale
    s = jnp.einsum("bkgh,blkh->bkgl", qg, k_cache).astype(jnp.float32)
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    kp = jnp.arange(L)
    ok = kp <= pos
    if window:
        ok &= kp > pos - window
    s = jnp.where(ok[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgl,blkh->bkgh", p.astype(v_cache.dtype), v_cache)
    return o.reshape(B, H, h)


# ------------------------------- wkv6 -------------------------------------

def wkv6_ref(r, k, v, w, u, s0=None):
    """Sequential RWKV6 recurrence (exact oracle).

    r,k,v,w [B,S,H,hd] (w = decay in (0,1)); u [H,hd]; s0 [B,H,hd,hd].
    Returns (y [B,S,H,hd], s_end)."""
    B, S, H, hd = r.shape
    s = (jnp.zeros((B, H, hd, hd), jnp.float32) if s0 is None
         else s0.astype(jnp.float32))

    def step(s, inp):
        r_t, k_t, v_t, w_t = inp
        kv = k_t[..., :, None] * v_t[..., None, :]
        out = jnp.einsum("bhk,bhkv->bhv", r_t,
                         s + u[None, :, :, None] * kv)
        s = w_t[..., :, None] * s + kv
        return s, out

    xs = tuple(a.astype(jnp.float32).transpose(1, 0, 2, 3)
               for a in (r, k, v, w))
    s_end, ys = jax.lax.scan(step, s, xs)
    return ys.transpose(1, 0, 2, 3), s_end


def wkv6_chunked_ref(r, k, v, w, u, s0=None, chunk=16):
    """Chunked (intra-parallel / inter-recurrent) WKV6 — same math as the
    Pallas kernel, in jnp.

    Log-space decay products; the intra-chunk score pair is referenced to the
    mid-chunk decay prefix so both exp() factors stay bounded by
    exp(chunk/2 * |log w|_max) — with chunk=16 safely inside fp32 range for
    the full RWKV decay range."""
    B, S, H, hd = r.shape
    assert S % chunk == 0
    n = S // chunk
    f32 = jnp.float32
    rc, kc, vc, wc = [a.astype(f32).reshape(B, n, chunk, H, hd)
                      .transpose(1, 0, 3, 2, 4)  # [n,B,H,C,hd]
                      for a in (r, k, v, w)]
    lw = jnp.maximum(jnp.log(jnp.maximum(wc, 1e-38)), -9.0)   # see wkv6.py
    s_init = (jnp.zeros((B, H, hd, hd), f32) if s0 is None
              else s0.astype(f32))
    u_ = u.astype(f32)

    def per_chunk(s, inp):
        r_, k_, v_, lw_ = inp                       # [B,H,C,hd]
        C = r_.shape[2]
        cum = jnp.cumsum(lw_, axis=2)               # inclusive decay prefix
        cum_excl = cum - lw_                        # exclusive prefix
        ref = cum[:, :, C // 2:C // 2 + 1, :]       # mid-chunk reference
        # intra-chunk: score[t,s'] = sum_d r_t k_s' exp(cum_excl_t - cum_s')
        a_sc = r_ * jnp.exp(cum_excl - ref)
        b_sc = k_ * jnp.exp(ref - cum)
        sc = jnp.einsum("bhtd,bhsd->bhts", a_sc, b_sc)
        mask = jnp.tril(jnp.ones((C, C), bool), k=-1)
        sc = jnp.where(mask, sc, 0.0)
        diag = jnp.einsum("bhtd,bhtd->bht", r_ * u_[None, :, None, :], k_)
        y = jnp.einsum("bhts,bhsd->bhtd", sc, v_) + diag[..., None] * v_
        # cross-chunk: r_t decayed against carried state (exp(cum_excl) <= 1)
        y = y + jnp.einsum("bhtd,bhdv->bhtv", r_ * jnp.exp(cum_excl), s)
        # state update: S' = diag(prod w) S + sum_s (prod_{i>s} w_i) k_s v_s
        decay_all = jnp.exp(cum[:, :, -1:, :])      # [B,H,1,hd]
        kd = k_ * jnp.exp(cum[:, :, -1:, :] - cum)
        s = decay_all[:, :, 0, :, None] * s + jnp.einsum(
            "bhsd,bhsv->bhdv", kd, v_)
        return s, y

    s_end, ys = jax.lax.scan(per_chunk, s_init, (rc, kc, vc, lw))
    y = ys.transpose(1, 0, 3, 2, 4).reshape(B, S, H, hd)
    return y, s_end


# ------------------------------ mamba scan --------------------------------

def mamba_scan_ref(u, dt, A, B_in, C_in, h0=None):
    """Selective scan oracle.  u,dt [B,S,D]; A [D,N]; B_in,C_in [B,S,N].

    h_t = exp(dt_t A) h_{t-1} + dt_t B_t u_t;  y_t = C_t . h_t
    Returns (y [B,S,D], h_end [B,D,N])."""
    Bb, S, D = u.shape
    N = A.shape[1]
    f32 = jnp.float32
    h = jnp.zeros((Bb, D, N), f32) if h0 is None else h0.astype(f32)

    def step(h, inp):
        u_t, dt_t, b_t, c_t = inp
        dA = jnp.exp(dt_t[..., None] * A[None])
        h = dA * h + (dt_t * u_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    xs = (u.astype(f32).transpose(1, 0, 2), dt.astype(f32).transpose(1, 0, 2),
          B_in.astype(f32).transpose(1, 0, 2), C_in.astype(f32).transpose(1, 0, 2))
    h_end, ys = jax.lax.scan(step, h, xs)
    return ys.transpose(1, 0, 2), h_end


# ------------------------------ gbm predict -------------------------------

def gbm_predict_ref(X, feat, thr, leaf, f0):
    """Boosted-ensemble inference oracle.  X [n,d]; feat/thr [T, n_internal];
    leaf [T, n_leaves]; returns [n]."""
    n = X.shape[0]
    T, n_int = feat.shape
    import numpy as np
    depth = int(np.log2(n_int + 1))
    out = jnp.full((n,), f0, jnp.float32)

    def tree(out, t):
        ft, th, lf = t
        idx = jnp.zeros(n, jnp.int32)
        for _ in range(depth):
            f = ft[idx]
            go_right = X[jnp.arange(n), f] > th[idx]
            idx = 2 * idx + 1 + go_right.astype(jnp.int32)
        return out + lf[idx - n_int], None

    out, _ = jax.lax.scan(tree, out, (feat, thr, leaf))
    return out
