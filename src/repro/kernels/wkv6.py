"""WKV6 chunked linear-attention kernel (Pallas, TPU target).

RWKV6 recurrence  S_t = diag(w_t) S_{t-1} + k_t v_t^T,
                  y_t = r_t . (S_{t-1} + diag(u) k_t v_t^T)
evaluated chunk-parallel: within a chunk of C tokens the interaction is a
[C, C] masked score matrix (log-space decay products, mid-chunk reference so
all exponents stay inside fp32 range); across chunks only the [hd, hd] state
is carried in VMEM scratch.  Grid (batch, heads, chunks), chunk dim innermost.

This is the TPU adaptation of the CUDA wkv kernels (hardware-adaptation note
in DESIGN.md): instead of per-thread serial state updates, the chunk-local
work is cast as two MXU matmuls ([C,hd]x[hd,C] scores, [C,C]x[C,hd] values)
plus a rank-C state update, which is how the systolic array wants it.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _wkv6_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, s0_ref, y_ref, s_out_ref,
                 s_scr, *, n_chunks, chunk):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = s0_ref[0, 0].astype(jnp.float32)

    r = r_ref[0, 0].astype(jnp.float32)          # [C, hd]
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    lw = lw_ref[0, 0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)             # [hd]
    s = s_scr[...]                               # [hd, hd]

    C = chunk
    cum = jnp.cumsum(lw, axis=0)                 # [C, hd] inclusive
    cum_excl = cum - lw
    ref = cum[C // 2][None, :]                   # mid-chunk reference
    a_sc = r * jnp.exp(cum_excl - ref)
    b_sc = k * jnp.exp(ref - cum)
    sc = jax.lax.dot_general(a_sc, b_sc, (((1,), (1,)), ((), ())))  # [C, C]
    ti = jax.lax.broadcasted_iota(jnp.int32, (C, C), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (C, C), 1)
    sc = jnp.where(si < ti, sc, 0.0)
    diag = (r * u[None, :] * k).sum(axis=1)      # [C]
    y = jax.lax.dot_general(sc, v, (((1,), (0,)), ((), ())))
    y = y + diag[:, None] * v
    y = y + jax.lax.dot_general(r * jnp.exp(cum_excl), s,
                                (((1,), (0,)), ((), ())))
    # state update
    decay_all = jnp.exp(cum[C - 1])              # [hd]
    kd = k * jnp.exp(cum[C - 1][None, :] - cum)
    s_scr[...] = decay_all[:, None] * s + jax.lax.dot_general(
        kd, v, (((0,), (0,)), ((), ())))
    y_ref[0, 0] = y.astype(y_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _finish():
        s_out_ref[0, 0] = s_scr[...].astype(s_out_ref.dtype)


def wkv6(r, k, v, w, u, s0=None, *, chunk=16, interpret=False):
    """r,k,v,w [B,S,H,hd]; u [H,hd]; s0 [B,H,hd,hd] -> (y, s_end)."""
    B, S, H, hd = r.shape
    assert S % chunk == 0, f"S={S} % chunk={chunk}"
    n = S // chunk
    if s0 is None:
        s0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    # per-step log-decay clamped at -9 (w >= 1.2e-4): contributions below
    # that die within a step at fp32 precision, and the clamp bounds the
    # chunk-local exponents to chunk/2 * 9 = 72, inside fp32 range
    lw = jnp.maximum(jnp.log(jnp.maximum(w.astype(jnp.float32), 1e-38)), -9.0)
    # [B,H,S,hd] layout
    rt, kt, vt = [a.transpose(0, 2, 1, 3) for a in (r, k, v)]
    lwt = lw.transpose(0, 2, 1, 3)

    kernel = functools.partial(_wkv6_kernel, n_chunks=n, chunk=chunk)
    y, s_end = pl.pallas_call(
        kernel,
        grid=(B, H, n),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, hd), lambda b, h, ci: (b, h, ci, 0)),
            pl.BlockSpec((1, 1, chunk, hd), lambda b, h, ci: (b, h, ci, 0)),
            pl.BlockSpec((1, 1, chunk, hd), lambda b, h, ci: (b, h, ci, 0)),
            pl.BlockSpec((1, 1, chunk, hd), lambda b, h, ci: (b, h, ci, 0)),
            pl.BlockSpec((1, hd), lambda b, h, ci: (h, 0)),
            pl.BlockSpec((1, 1, hd, hd), lambda b, h, ci: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, hd), lambda b, h, ci: (b, h, ci, 0)),
            pl.BlockSpec((1, 1, hd, hd), lambda b, h, ci: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, S, hd), r.dtype),
            jax.ShapeDtypeStruct((B, H, hd, hd), jnp.float32),
        ],
        scratch_shapes=[_vmem((hd, hd))],
        interpret=interpret,
    )(rt, kt, vt, lwt, u, s0)
    return y.transpose(0, 2, 1, 3), s_end


def _vmem(shape):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, jnp.float32)
