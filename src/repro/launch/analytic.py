"""Analytic FLOP / HBM-byte / collective-byte model per (arch x shape x mesh).

Why this exists: XLA's ``compiled.cost_analysis()`` counts a ``scan`` body
ONCE regardless of trip count (verified experimentally — see EXPERIMENTS.md
§Dry-run methodology), and every model here scans over layers, microbatches
and sequence chunks.  The roofline therefore uses this analytic model as the
primary FLOPs/bytes source; it is validated against cost_analysis on
fully-unrolled miniature variants (tests/test_analytic.py) and collective
bytes are cross-checked against finite-differenced HLO parses.

Conventions:
  - matmul FLOPs = 2*M*N*K; backward = 2x forward; full remat adds +1x
    forward of the rematerialized stack (train multiplier 4, no-remat 3).
  - attention: impl-aware (blocked rectangle = full S*S_pad even under the
    causal mask; triangle = exact causal; banded = S*(window+chunk)).
  - HBM bytes: weights 3x per microbatch (fwd read, bwd read, grad write) +
    optimizer state traffic + major activation streams; the jnp blocked-
    attention path materializes per-chunk score tiles in HBM whereas the
    Pallas flash kernel keeps them in VMEM — both are modeled so the kernel's
    memory-term win is visible in §Perf.
  - collectives: FSDP all-gathers (x3 with remat: fwd, bwd-recompute, bwd),
    grad reduce-scatter per microbatch, TP all-reduces (or SP AG+RS), MoE
    psum, logits all-reduce.  Ring formulas: AG/RS (n-1)/n, AR 2(n-1)/n.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict

from repro.configs.base import (ATTN, ATTN_LOCAL, MAMBA, RWKV, ModelConfig,
                                ShapeConfig)

BYTES = {"bfloat16": 2, "float32": 4, "float16": 2, "int8": 1}


@dataclass
class Cost:
    flops: float = 0.0                 # per device
    hbm_bytes: float = 0.0             # per device
    coll: Dict[str, float] = field(default_factory=dict)  # wire bytes/device

    def add_coll(self, kind: str, b: float):
        self.coll[kind] = self.coll.get(kind, 0.0) + b

    @property
    def coll_bytes(self) -> float:
        return sum(self.coll.values())


def _ring_ag(total_bytes, n):          # all-gather / reduce-scatter wire
    return total_bytes * (n - 1) / max(n, 1)


def _ring_ar(total_bytes, n):          # all-reduce wire
    return 2.0 * total_bytes * (n - 1) / max(n, 1)


def analytic_cost(cfg: ModelConfig, shape: ShapeConfig,
                  mesh_shape: Dict[str, int]) -> Cost:
    c = Cost()
    dp = mesh_shape.get("pod", 1) * mesh_shape.get("data", 1)
    tp = mesh_shape.get("model", 1)
    n_dev = dp * tp
    D = cfg.d_model
    hd = cfg.resolved_head_dim
    H, KV = cfg.n_heads, cfg.n_kv_heads
    V = cfg.padded_vocab_size
    act_b = BYTES[cfg.dtype]
    par_b = BYTES[cfg.param_dtype]
    train = shape.kind == "train"
    decode = shape.kind == "decode"
    # tokens processed this step, per device (batch sharded over dp)
    B_loc = max(shape.global_batch // dp, 1)
    S = 1 if decode else shape.seq_len
    L_ctx = shape.seq_len            # cache length for decode
    toks = B_loc * S
    k_micro = cfg.grad_accum if train else 1
    # fwd-multiplier: fwd + bwd(2x) + remat recompute(1x)
    fmul = (4.0 if cfg.remat != "none" else 3.0) if train else 1.0

    counts = cfg.param_counts()
    n_embed = cfg.padded_vocab_size * D * (1 if cfg.tie_embeddings else 2)
    # dense per-token matmul params, active (moe top-k only)
    n_matmul_active = counts["active"] - n_embed

    # ---------------- matmul FLOPs (projections, ffn, moe, logits) --------
    c.flops += fmul * 2.0 * n_matmul_active / tp * toks
    if cfg.n_experts:
        # EP capacity slack: dispatch buffers padded to capacity_factor
        moe_layers = sum(cfg.is_moe_layer(i) for i in range(cfg.n_layers))
        mult = 3 if cfg.act == "swiglu" else 2
        moe_flops = 2.0 * mult * D * cfg.moe_d_ff * cfg.n_experts_active
        c.flops += fmul * (cfg.capacity_factor - 1.0) * moe_flops \
            * moe_layers / tp * toks
    c.flops += fmul * 2.0 * D * V / tp * toks          # logits head

    # ---------------- attention score/value FLOPs -------------------------
    def layer_kinds():
        for i in range(cfg.n_layers):
            yield cfg.layer_kind(i)

    CHUNK = 1024
    for kind in layer_kinds():
        if kind not in (ATTN, ATTN_LOCAL):
            continue
        if cfg.use_mla:
            qk_d, v_d, heads = (cfg.qk_nope_dim + cfg.qk_rope_dim,
                                cfg.v_head_dim, H)
        else:
            qk_d, v_d, heads = hd, hd, H
        h_loc = max(heads // tp, 1)
        if decode:
            kv_len = min(L_ctx, cfg.window_size) if (
                kind == ATTN_LOCAL and cfg.window_size) else L_ctx
            if not cfg.use_mla and KV % 16 != 0:
                kv_len = kv_len / tp      # cache sharded on sequence
                h_loc = heads             # all heads, partial seq
            c.flops += 2.0 * B_loc * h_loc * kv_len * (qk_d + v_d)
            continue
        if kind == ATTN_LOCAL and cfg.window_size:
            kv_eff = min(cfg.window_size + CHUNK, S)   # banded
        elif cfg.attention_impl == "blocked_tri":
            kv_eff = (S + CHUNK) / 2.0                 # exact triangle
        elif cfg.attention_impl == "reference":
            kv_eff = S
        else:
            kv_eff = S                                  # rectangle (masked)
        c.flops += fmul * 2.0 * B_loc * h_loc * S * kv_eff * (qk_d + v_d)

    # ---------------- ssm FLOPs -------------------------------------------
    for kind in layer_kinds():
        if kind == MAMBA:
            din_loc = cfg.mamba_d_inner / tp
            c.flops += fmul * 6.0 * toks * din_loc * cfg.mamba_d_state
        elif kind == RWKV:
            hw = cfg.rwkv_head_dim
            n_h_loc = (D / hw) / tp
            chunk = 16
            # intra scores+values 2*(2*C*hw) + cross/state 2*(2*hw*hw)/token
            c.flops += fmul * toks * n_h_loc * (4.0 * chunk * hw + 4.0 * hw * hw)

    # ---------------- HBM bytes -------------------------------------------
    w_dev = counts["total"] * par_b / n_dev
    if train:
        c.hbm_bytes += 3.0 * w_dev * k_micro           # fwd+bwd reads, grad w
        opt_b = 8.0 if cfg.optimizer == "adamw" else 0.1
        c.hbm_bytes += counts["total"] * opt_b / n_dev * 2.0   # read+write
    else:
        c.hbm_bytes += w_dev
    # activation streams: ~12 tensor reads/writes of [toks, D] per layer
    seq_div = tp if cfg.seq_shard_residual else 1
    c.hbm_bytes += fmul * cfg.n_layers * 12.0 * toks * D * act_b / seq_div
    # jnp blocked attention spills per-chunk score tiles (flash kernel: no)
    if not decode and cfg.attention_impl in ("blocked", "reference"):
        n_attn = sum(1 for k in layer_kinds() if k in (ATTN, ATTN_LOCAL))
        c.hbm_bytes += fmul * n_attn * B_loc * (H / tp) * S * min(S, 1024) * 4.0 * 2
    if decode:
        # KV cache read (the decode bottleneck)
        for i, kind in enumerate(layer_kinds()):
            if kind not in (ATTN, ATTN_LOCAL):
                if kind == MAMBA:
                    c.hbm_bytes += 2 * B_loc * cfg.mamba_d_inner \
                        * cfg.mamba_d_state * 4.0 / tp
                elif kind == RWKV:
                    c.hbm_bytes += 2 * B_loc * D * cfg.rwkv_head_dim * 4.0 / tp
                continue
            if cfg.use_mla:
                per_tok = cfg.kv_lora_rank + cfg.qk_rope_dim
                c.hbm_bytes += B_loc * (L_ctx / tp) * per_tok * act_b
            else:
                kv_len = min(L_ctx, cfg.window_size) if (
                    kind == ATTN_LOCAL and cfg.window_size) else L_ctx
                kv_b = BYTES[cfg.kv_cache_dtype or cfg.dtype]
                if cfg.kv_cache_dtype == "int8":
                    kv_b += 2.0 / hd              # per-(pos,head) bf16 scale
                c.hbm_bytes += 2 * B_loc * kv_len * KV * hd * kv_b / tp
        c.hbm_bytes += B_loc * V / tp * 4.0            # logits

    # ---------------- collectives ----------------------------------------
    # FSDP weight all-gather (weights sharded over dp on the fsdp dims)
    acc_b = BYTES.get(cfg.grad_accum_dtype, 4)
    if dp > 1 and cfg.fsdp:
        ag_rounds = (3.0 * k_micro if train and cfg.remat != "none"
                     else (2.0 * k_micro if train else 1.0))
        c.add_coll("all-gather", ag_rounds * _ring_ag(
            counts["total"] * par_b / tp, dp))
        if train:
            # grad reduce-scatter per microbatch (accum-dtype partials)
            c.add_coll("reduce-scatter", k_micro * _ring_ag(
                counts["total"] * acc_b / tp, dp))
    elif dp > 1 and train:
        # replicated weights: grads accumulate locally, one DP all-reduce
        c.add_coll("all-reduce", _ring_ar(counts["total"] * acc_b / tp, dp))
    # TP activation collectives: 2 per layer fwd (+2 bwd) of [toks, D]
    if tp > 1:
        rounds = 4.0 * k_micro if train else 2.0
        per_layer = toks / k_micro * D * act_b if train else toks * D * act_b
        n_res_layers = cfg.n_layers * 2            # attn/ssm + ffn sublayers
        if cfg.seq_shard_residual:
            # SP: AG + RS instead of AR (half wire each, same sum)
            c.add_coll("all-gather", rounds / 2 * n_res_layers
                       * _ring_ag(per_layer, tp))
            c.add_coll("reduce-scatter", rounds / 2 * n_res_layers
                       * _ring_ag(per_layer, tp))
        else:
            c.add_coll("all-reduce", rounds / 2 * n_res_layers
                       * _ring_ar(per_layer, tp))
        # logits softmax partial reductions (small) + embedding grads
        c.add_coll("all-reduce", _ring_ar(toks * 4.0, tp))
    return c
