"""C3O-for-TPU: the paper's technique applied to the framework's own domain.

"Machine types" are TPU slice families, "scale-out" is the chip count, and a
"job" is an (arch x input-shape) workload.  Shared runtime records — step
times from real runs (launch/train.py --runtime-log) and roofline-derived
estimates from the dry-run — feed the identical C3O predictor + configurator
stack: LOO-CV model selection, Gaussian-confidence scale-out choice, cost
menus.  A new user bringing kimi-k2 to a fresh project gets a mesh
recommendation from collaboratively shared records without profiling runs —
exactly the paper's value proposition, transplanted to pods.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Optional, Sequence

import numpy as np

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig, get_config
from repro.core.configurator import Configurator
from repro.core.datastore import RuntimeDataStore
from repro.core.features import JobSchema, RuntimeData
from repro.core.predictor import C3OPredictor
from repro.launch.analytic import analytic_cost
from repro.launch.roofline import HBM_BW, ICI_BW, PEAK_FLOPS


@dataclass(frozen=True)
class SliceFamily:
    name: str
    peak_flops: float
    hbm_bw: float
    ici_bw: float
    hbm_gb: float
    price_per_chip_h: float


SLICES: Dict[str, SliceFamily] = {
    "v5e": SliceFamily("v5e", PEAK_FLOPS, HBM_BW, ICI_BW, 16.0, 1.20),
    "v5p": SliceFamily("v5p", 459e12, 2765e9, 90e9, 95.0, 4.20),
    "v4": SliceFamily("v4", 275e12, 1228e9, 60e9, 32.0, 3.22),
}

TPU_SCHEMA = JobSchema(
    "tpu_step", ("tokens_per_step", "params_b", "active_params_b"),
    base_features=("scale_out", "seq_len"))


def _mesh_for(chips: int) -> Dict[str, int]:
    model = 16 if chips >= 256 else max(chips // 16, 1)
    return {"data": chips // model, "model": model}


def predicted_step_time(cfg: ModelConfig, shape: ShapeConfig,
                        slice_fam: SliceFamily, chips: int) -> float:
    """Roofline-model step time on a slice family (the 'simulator' that
    stands in for real multi-pod measurements in this offline container)."""
    ana = analytic_cost(cfg, shape, _mesh_for(chips))
    return max(ana.flops / slice_fam.peak_flops,
               ana.hbm_bytes / slice_fam.hbm_bw,
               ana.coll_bytes / slice_fam.ici_bw)


def simulate_runtime_records(arch: str, shape_name: str,
                             slice_name: str = "v5e",
                             chip_counts: Sequence[int] = (64, 128, 256, 512),
                             contexts: int = 4, reps: int = 3,
                             noise: float = 0.06, seed: int = 0
                             ) -> RuntimeData:
    """Shared runtime data as produced by many users' training runs: the
    same arch at several chip counts, with varying per-user context (batch
    scaling) and measurement noise; medians of ``reps`` runs."""
    rng = np.random.default_rng(seed)
    shape0 = SHAPES[shape_name]
    cfg = get_config(arch)
    counts = cfg.param_counts()
    rows, ys = [], []
    fam = SLICES[slice_name]
    for ctx in range(contexts):
        bs = max(shape0.global_batch >> ctx, 32)
        shape = dataclasses.replace(shape0, global_batch=bs)
        for chips in chip_counts:
            t = predicted_step_time(cfg, shape, fam, chips)
            runs = t * rng.lognormal(0.0, noise, reps)
            rows.append([chips, shape.seq_len, bs * shape.seq_len,
                         counts["total"] / 1e9, counts["active"] / 1e9])
            ys.append(float(np.median(runs)))
    n = len(ys)
    return RuntimeData(TPU_SCHEMA, np.asarray([slice_name] * n),
                       np.asarray(rows, np.float64), np.asarray(ys))


def autoconfigure(arch: str, shape_name: str, *,
                  step_budget_s: Optional[float] = None,
                  slice_name: str = "v5e",
                  chip_counts: Sequence[int] = (64, 128, 256, 512),
                  store: Optional[RuntimeDataStore] = None,
                  confidence: float = 0.95, seed: int = 0):
    """Pick (slice, chips) for a workload from shared runtime records.

    Returns (ClusterChoice, predictor) — the paper's workflow steps 2-5 with
    TPU slices in place of EC2 machine types."""
    data = (store.data if store is not None
            else simulate_runtime_records(arch, shape_name,
                                          slice_name=slice_name,
                                          chip_counts=chip_counts, seed=seed))
    d = data.filter_machine(slice_name)
    pred = C3OPredictor(seed=seed).fit(d.X, d.y)
    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    counts = cfg.param_counts()
    ctx_row = np.asarray([shape.seq_len,
                          shape.global_batch * shape.seq_len,
                          counts["total"] / 1e9, counts["active"] / 1e9])

    fam = SLICES[slice_name]

    def bottleneck(ctx, chips):
        # weights + optimizer must fit the slice's HBM
        opt_b = 8.0 if cfg.optimizer == "adamw" else 0.5
        need = counts["total"] * (2.0 + opt_b) / chips
        return need > 0.9 * fam.hbm_gb * 2 ** 30

    conf = Configurator(pred, slice_name,
                        {s.name: s.price_per_chip_h for s in SLICES.values()},
                        chip_counts, confidence=confidence,
                        bottleneck_fn=bottleneck)
    choice = conf.choose_scaleout(ctx_row, t_max=step_budget_s)
    return choice, pred
