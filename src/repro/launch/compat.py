"""JAX version-compat helpers for AOT introspection.

``Compiled.cost_analysis()`` returned a list with one dict per program on
older JAX releases (<= 0.4.x) and a plain dict on newer ones; every consumer
(dryrun records, roofline inputs, tests) goes through ``cost_analysis_dict``
so both shapes look the same.
"""
from __future__ import annotations


def cost_analysis_dict(compiled) -> dict:
    """Flat {metric: value} cost analysis for a ``jax`` Compiled object."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        merged: dict = {}
        for entry in cost:
            for k, v in entry.items():
                merged[k] = merged.get(k, 0.0) + v
        return merged
    return dict(cost)
