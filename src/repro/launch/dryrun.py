import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: AOT lower + compile every (arch x shape) cell on the
production meshes, prove memory fit, and extract roofline inputs.

The two lines above MUST run before any jax import: jax locks the device
count at first initialization.  This module is the only place the 512
placeholder host devices exist; tests and benchmarks see the real device(s).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch deepseek-7b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both --out experiments/dryrun.json
"""
import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402

from repro.configs import SHAPES, get_config, list_archs, supports_shape  # noqa: E402
from repro.distributed import sharding  # noqa: E402
from repro.launch import specs as SP    # noqa: E402
from repro.launch.compat import cost_analysis_dict  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.analytic import analytic_cost  # noqa: E402
from repro.launch.roofline import (collective_bytes, model_flops,  # noqa: E402
                                   roofline_terms)


def _compile_cell(cfg, shape, mesh):
    with sharding.use_mesh(mesh, rules=sharding.rules_for(cfg)):
        step_fn, args, in_sh, donate = SP.cell_for(cfg, shape, mesh)
        lowered = jax.jit(step_fn, in_shardings=in_sh,
                          donate_argnums=donate).lower(*args)
        return lowered.compile()


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             overrides=None, counting: bool = True) -> dict:
    """Lower+compile one cell; returns the dry-run record (JSON-safe).

    Methodology (see module docstring of launch/analytic.py):
      1. FULL compile proves the cell lowers, partitions and fits memory.
      2. cost_analysis() undercounts scan bodies (counted once per trip), so
         roofline FLOPs/HBM-bytes come from the validated analytic model.
      3. Collective wire bytes: finite difference over the layer-scan length
         — compile nb=1 and nb=2 block variants, per-block collective bytes
         = C2-C1, total = k_microbatches * (C1 + (nb_full-1)*(C2-C1)); all
         collectives sit outside the inner (chunk) scans by construction.
    """
    import dataclasses
    shape = SHAPES[shape_name]
    cfg = get_config(arch, **(overrides or {}))
    ok, reason = supports_shape(cfg, shape)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16"}
    if not ok:
        rec.update(status="skipped", reason=reason)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    n_chips = mesh.devices.size
    t0 = time.time()
    try:
        compiled = _compile_cell(cfg, shape, mesh)
        t_compile = time.time() - t0
        mem = compiled.memory_analysis()
        cost = cost_analysis_dict(compiled)
        peak = int(getattr(mem, "argument_size_in_bytes", 0)
                   + getattr(mem, "temp_size_in_bytes", 0))

        # --- collective finite difference over scan blocks ---------------
        coll_kinds, coll_total = {}, None
        if counting:
            period, tail = cfg.pattern_period, cfg.n_tail_layers
            nb_full = cfg.n_scan_blocks
            c_by_nb = []
            for nb in (1, 2):
                # scale the encoder with nb too (seamless: enc depth == dec
                # depth, so one finite difference covers both scans)
                enc = (nb if cfg.n_encoder_layers else 0)
                cfg_n = dataclasses.replace(cfg, n_layers=nb * period + tail,
                                            n_encoder_layers=enc)
                comp_n = _compile_cell(cfg_n, shape, mesh)
                c_by_nb.append(collective_bytes(comp_n.as_text()))
            (c1, k1), (c2, k2) = c_by_nb
            k_micro = cfg.grad_accum if shape.kind == "train" else 1
            coll_total = k_micro * (c1 + (nb_full - 1) * (c2 - c1))
            coll_kinds = {kk: k_micro * (k1.get(kk, 0) + (nb_full - 1)
                                         * (k2.get(kk, 0) - k1.get(kk, 0)))
                          for kk in set(k1) | set(k2)}

        # --- analytic roofline -------------------------------------------
        ana = analytic_cost(cfg, shape, mesh_shape)
        coll_dev = coll_total if coll_total is not None else ana.coll_bytes
        rl = roofline_terms({"flops": ana.flops, "bytes accessed":
                             ana.hbm_bytes}, coll_dev)
        mf = model_flops(cfg, shape)
        rec.update(
            status="ok",
            compile_s=round(t_compile, 1), n_chips=n_chips,
            mem={k: int(getattr(mem, k)) for k in
                 ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes")
                 if hasattr(mem, k)},
            peak_bytes_per_device=peak,
            fits_hbm=bool(peak <= 16 * 2 ** 30),
            raw_cost_analysis={"flops": float(cost.get("flops", 0)),
                               "bytes": float(cost.get("bytes accessed", 0))},
            flops_per_device=rl.flops,
            bytes_per_device=rl.bytes_accessed,
            collective_bytes_per_device=coll_dev,
            collective_bytes_analytic=ana.coll_bytes,
            collective_by_kind=coll_kinds,
            roofline={"compute_s": rl.compute_s, "memory_s": rl.memory_s,
                      "collective_s": rl.collective_s,
                      "dominant": rl.dominant, "bound_s": rl.bound_s},
            model_flops_global=mf,
            useful_flops_ratio=(mf / (rl.flops * n_chips)
                                if rl.flops else 0.0),
        )
    except Exception as e:  # a failing cell is a bug in the system
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    archs = list_archs() if args.all or not args.arch else [args.arch]
    shapes = (list(SHAPES) if args.all or not args.shape or args.shape == "__all__"
              else [args.shape])
    pods = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    records = []
    for arch in archs:
        for shape in shapes:
            for mp in pods:
                rec = run_cell(arch, shape, multi_pod=mp)
                records.append(rec)
                tag = f"{arch} x {shape} @ {rec['mesh']}"
                if rec["status"] == "ok":
                    r = rec["roofline"]
                    print(f"[ok]   {tag}: compile={rec['compile_s']}s "
                          f"peak={rec['peak_bytes_per_device']/2**30:.2f}GiB/dev "
                          f"fits={'Y' if rec['fits_hbm'] else 'N'} "
                          f"compute={r['compute_s']*1e3:.1f}ms "
                          f"memory={r['memory_s']*1e3:.1f}ms "
                          f"coll={r['collective_s']*1e3:.1f}ms "
                          f"dom={r['dominant']} "
                          f"useful={rec['useful_flops_ratio']:.2f}",
                          flush=True)
                elif rec["status"] == "skipped":
                    print(f"[skip] {tag}: {rec['reason']}", flush=True)
                else:
                    print(f"[FAIL] {tag}: {rec['error']}", flush=True)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(records, f, indent=1)
        print(f"wrote {args.out}")
    n_fail = sum(r["status"] == "error" for r in records)
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
