import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb driver: re-lowers selected cells with optimization
overrides, recording hypothesis -> change -> before/after roofline terms.

Three cells (chosen per the assignment: worst roofline fraction, most
collective-bound, most representative of serving the technique at scale):
  gemma3-1b  train_4k    collective-bound -> FSDP off (+bf16 grad accum)
  kimi-k2    train_4k    memory violation + compute-bound -> chunked CE,
                         bf16 accumulation, triangle attention, accum 8->4
  deepseek   decode_32k  memory-bound serving -> int8 KV cache
"""
import json  # noqa: E402

from repro.launch.dryrun import run_cell  # noqa: E402

ITERS = [
    # (tag, arch, shape, overrides, hypothesis)
    ("gemma3_train.baseline+chunkedCE", "gemma3-1b", "train_4k", {},
     "iteration 0 (chunked CE now default): same collective bound as baseline"),
    ("gemma3_train.no_fsdp", "gemma3-1b", "train_4k",
     {"fsdp": False},
     "1B params: replicating weights kills 3x-per-microbatch FSDP gathers; "
     "one gradient all-reduce replaces per-microbatch reduce-scatter -> "
     "collective term down ~2.5-3x"),
    ("gemma3_train.no_fsdp+bf16acc", "gemma3-1b", "train_4k",
     {"fsdp": False, "grad_accum_dtype": "bfloat16"},
     "bf16 gradient all-reduce halves the remaining DP wire bytes"),
    ("kimi_train.memfix", "kimi-k2-1t-a32b", "train_4k", {},
     "chunked CE + bf16 accumulation (now config defaults) remove the 43GB "
     "logits+accum buffers -> fits 16GB HBM"),
    ("kimi_train.triangle", "kimi-k2-1t-a32b", "train_4k",
     {"attention_impl": "blocked_tri"},
     "exact-triangle attention halves causal attention FLOPs -> compute term "
     "down by the attention share (~10-15%)"),
    ("kimi_train.accum4", "kimi-k2-1t-a32b", "train_4k",
     {"attention_impl": "blocked_tri", "grad_accum": 4},
     "half the microbatches -> half the FSDP weight-gather rounds; activation "
     "memory doubles (check fits)"),
    ("deepseek_decode.int8kv", "deepseek-7b", "decode_32k",
     {"kv_cache_dtype": "int8"},
     "int8 KV cache: 2 bytes->1.06 bytes per cache element: memory term "
     "~-45%, and the 27GB cache fits"),
    ("jamba_train.memfix", "jamba-1.5-large-398b", "train_4k", {},
     "post-fix re-run of the worst-bound cell (chunked CE + bf16 accum)"),
    ("jamba_decode.spfix", "jamba-1.5-large-398b", "decode_32k", {},
     "EP shard_map SP guard: decode (S=1) no longer asserts"),
    ("kimi_decode.spfix", "kimi-k2-1t-a32b", "decode_32k", {},
     "EP shard_map SP guard: decode (S=1) no longer asserts"),
    ("jamba_long.spfix", "jamba-1.5-large-398b", "long_500k", {},
     "EP shard_map SP guard + int8-free long-context decode"),
    ("minicpm3_train.spfix", "minicpm3-4b", "train_4k", {},
     "SP residuals for the 40-head (indivisible) arch -> seq-parallel "
     "attention instead of replicated compute; chunked CE"),
    ("gemma2_train.memfix", "gemma2-2b", "train_4k", {},
     "chunked CE removes the 17GB fp32 logits for the 256k vocab"),
    ("seamless_train.memfix", "seamless-m4t-medium", "train_4k", {},
     "chunked CE (256k vocab)"),
    ("kimi_prefill.memfix", "kimi-k2-1t-a32b", "prefill_32k", {},
     "prefill computes the head only for the last position -> 43GB logits "
     "buffer gone"),
    ("jamba_prefill.memfix", "jamba-1.5-large-398b", "prefill_32k", {},
     "prefill last-position head"),
    ("minicpm3_prefill.memfix", "minicpm3-4b", "prefill_32k", {},
     "prefill last-position head + SP"),
]


def main():
    out = []
    for tag, arch, shape, ov, hyp in ITERS:
        rec = run_cell(arch, shape, multi_pod=False, overrides=ov)
        rec["tag"] = tag
        rec["hypothesis"] = hyp
        rec["overrides"] = {k: str(v) for k, v in ov.items()}
        out.append(rec)
        if rec["status"] == "ok":
            r = rec["roofline"]
            print(f"[ok] {tag}: peak={rec['peak_bytes_per_device']/2**30:.2f}GiB "
                  f"fits={rec['fits_hbm']} c={r['compute_s']*1e3:.1f}ms "
                  f"m={r['memory_s']*1e3:.1f}ms coll={r['collective_s']*1e3:.1f}ms "
                  f"dom={r['dominant']}", flush=True)
        else:
            print(f"[{rec['status']}] {tag}: {rec.get('error','')[:300]}",
                  flush=True)
    with open("experiments/perf_iters.json", "w") as f:
        json.dump(out, f, indent=1)
    print("wrote experiments/perf_iters.json")


if __name__ == "__main__":
    main()
