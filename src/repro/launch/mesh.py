"""Production mesh construction.

``make_production_mesh`` is a function (never a module-level constant) so that
importing this module does not touch jax device state.

``mesh_axis_types`` shims the ``jax.sharding.AxisType`` API across JAX
versions: older releases (< 0.5) have neither the enum nor the
``axis_types=`` kwarg on ``jax.make_mesh``, where every axis is implicitly
Auto — the behavior we request explicitly on newer releases.
"""
from __future__ import annotations

import jax


def mesh_axis_types(n_axes: int) -> dict:
    """kwargs for ``jax.make_mesh``: explicit Auto axis types when the
    running JAX supports them, empty (implicit Auto) otherwise."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_mesh_auto(shape, axes, **kw):
    """``jax.make_mesh`` with Auto axis types on every JAX version."""
    return jax.make_mesh(shape, axes, **mesh_axis_types(len(axes)), **kw)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_auto(shape, axes)


def make_host_mesh(model_axis: int = 1):
    """Small mesh over whatever devices exist (tests / examples on CPU)."""
    n = len(jax.devices())
    assert n % model_axis == 0
    return make_mesh_auto((n // model_axis, model_axis), ("data", "model"))
