"""Roofline-term derivation from compiled dry-run artifacts.

Three terms (seconds, per training/serving step), per (arch x shape x mesh):

  compute    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
  memory     = HLO_bytes_per_device / HBM_bandwidth_per_chip
  collective = collective_wire_bytes_per_device / ICI_bandwidth_per_chip

cost_analysis() reports per-device FLOPs/bytes of the partitioned program.
Collective bytes are not in cost_analysis, so we parse the optimized HLO and
apply per-op wire-byte formulas (ring algorithms, n = participant group size):

  all-gather:          result_bytes * (n-1)/n
  reduce-scatter:      operand_bytes * (n-1)/n
  all-reduce:          2 * result_bytes * (n-1)/n        (RS + AG)
  all-to-all:          result_bytes * (n-1)/n
  collective-permute:  result_bytes
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Dict, List, Tuple

# TPU v5e-class hardware constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_SHAPE_RE = re.compile(r"(bf16|f64|f32|f16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|"
                       r"s16|u16|s8|u8|pred)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"^\s*(?:%\S+\s*=\s*)?(\(?[a-z0-9\[\],{}: \)]*?\)?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(sig: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(sig):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> List[Dict]:
    """Extract collectives with per-device wire bytes from optimized HLO."""
    out = []
    for line in hlo_text.splitlines():
        m = re.match(
            r"\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+?)\s+"
            r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
            r"collective-permute)(?:-start|-done)?\(", line)
        if not m:
            continue
        if "-done(" in line:       # avoid double counting start/done pairs
            continue
        result_sig, kind = m.group(1), m.group(2)
        rb = _shape_bytes(result_sig)
        # participant group size
        n = 1
        g = _GROUPS_RE.search(line)
        if g:
            n = len(g.group(1).split(","))
        else:
            g2 = _GROUPS2_RE.search(line)
            if g2:
                n = int(g2.group(2))
        if n <= 1:
            wire = 0
        elif kind == "all-gather":
            wire = rb * (n - 1) // n
        elif kind == "reduce-scatter":
            wire = rb * (n - 1)          # operand = result * n for RS
        elif kind == "all-reduce":
            wire = 2 * rb * (n - 1) // n
        elif kind == "all-to-all":
            wire = rb * (n - 1) // n
        else:                            # collective-permute
            wire = rb
        out.append({"kind": kind, "result_bytes": rb, "group": n,
                    "wire_bytes": wire})
    return out


def collective_bytes(hlo_text: str) -> Tuple[int, Dict[str, int]]:
    per_kind: Dict[str, int] = {}
    total = 0
    for c in parse_collectives(hlo_text):
        per_kind[c["kind"]] = per_kind.get(c["kind"], 0) + c["wire_bytes"]
        total += c["wire_bytes"]
    return total, per_kind


@dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    flops: float
    bytes_accessed: float
    coll_bytes: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)


def roofline_terms(cost: dict, coll_bytes_per_dev: float) -> Roofline:
    flops = float(cost.get("flops", 0.0))
    by = float(cost.get("bytes accessed", 0.0))
    return Roofline(
        compute_s=flops / PEAK_FLOPS,
        memory_s=by / HBM_BW,
        collective_s=coll_bytes_per_dev / ICI_BW,
        flops=flops, bytes_accessed=by, coll_bytes=coll_bytes_per_dev)


def model_flops(cfg, shape) -> float:
    """Analytic MODEL_FLOPS: 6*N*D (dense) / 6*N_active*D (MoE), D = tokens
    processed per step; decode steps process global_batch tokens."""
    counts = cfg.param_counts()
    n = counts["active"]
    if shape.kind == "train":
        toks = shape.global_batch * shape.seq_len
        return 6.0 * n * toks
    if shape.kind == "prefill":
        toks = shape.global_batch * shape.seq_len
        return 2.0 * n * toks
    return 2.0 * n * shape.global_batch
