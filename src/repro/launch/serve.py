"""Batched serving driver: continuous decode over a request batch.

Serves a (reduced or full) architecture with prefill + decode steps and the
KV-cache machinery (ring buffers, optional int8 quantization), reporting
per-token latency; measured step times feed the C3O runtime log like
launch/train.py does.

Usage (CPU, reduced config):
  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --smoke \
      --batch 4 --prompt-len 16 --max-new 32
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_config
from repro.modeling import model as M
from repro.serve.serve_step import make_decode_step, make_prefill_step


def run(arch: str, batch: int, prompt_len: int, max_new: int,
        smoke: bool = True, kv_dtype: str = "", runtime_log: str = None,
        seed: int = 0):
    cfg = (smoke_config(arch, kv_cache_dtype=kv_dtype) if smoke
           else get_config(arch, kv_cache_dtype=kv_dtype))
    params = M.init_params(cfg, jax.random.PRNGKey(seed))
    max_seq = prompt_len + max_new + 8
    cross = prompt_len if cfg.n_encoder_layers else 0
    cache = M.init_cache(cfg, batch, max_seq, cross_seq=cross)
    prefill = jax.jit(make_prefill_step(cfg))
    decode = jax.jit(make_decode_step(cfg), donate_argnums=(3,))

    key = jax.random.PRNGKey(seed + 1)
    prompts = {"tokens": jax.random.randint(key, (batch, prompt_len), 0,
                                            cfg.vocab_size)}
    if cfg.frontend != "none":
        prompts["frontend"] = jax.random.normal(
            jax.random.fold_in(key, 1),
            (batch, prompt_len if cfg.n_encoder_layers else 8,
             cfg.frontend_dim)).astype(cfg.dtype)
        if cfg.n_encoder_layers == 0:
            prompts["tokens"] = prompts["tokens"][:, 8:]

    t0 = time.time()
    logits, cache = prefill(params, prompts, cache)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    tok = jnp.argmax(logits, -1)
    pos = prompt_len
    lat = []
    outs = [tok]
    for _ in range(max_new - 1):
        t1 = time.time()
        logits, cache = decode(params, tok, jnp.asarray(pos, jnp.int32),
                               cache)
        tok = jnp.argmax(logits, -1)
        jax.block_until_ready(tok)
        lat.append(time.time() - t1)
        outs.append(tok)
        pos += 1
    med = float(np.median(lat)) if lat else 0.0
    print(f"{arch}: prefill({prompt_len} toks x {batch}) {t_prefill*1e3:.1f}ms; "
          f"decode median {med*1e3:.2f}ms/token "
          f"(kv={cfg.kv_cache_dtype or cfg.dtype})")
    if runtime_log:
        os.makedirs(os.path.dirname(runtime_log) or ".", exist_ok=True)
        with open(runtime_log, "a") as f:
            f.write(json.dumps({"arch": arch, "mode": "serve",
                                "batch": batch, "prompt_len": prompt_len,
                                "prefill_s": t_prefill,
                                "decode_median_s": med}) + "\n")
    return jnp.stack(outs, axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--kv-dtype", default="")
    ap.add_argument("--runtime-log", default=None)
    args = ap.parse_args()
    run(args.arch, args.batch, args.prompt_len, args.max_new,
        smoke=args.smoke, kv_dtype=args.kv_dtype,
        runtime_log=args.runtime_log)


if __name__ == "__main__":
    main()
