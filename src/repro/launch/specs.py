"""Abstract input/state stand-ins for AOT lowering (no device allocation).

``input_specs(cfg, shape)`` returns (args, in_shardings, donate) for the step
function the (arch x shape) cell lowers:
  train_*    -> train_step(state, batch)
  prefill_*  -> prefill_step(params, batch, cache)
  decode_* / long_* -> decode_step(params, tokens, pos, cache)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed import sharding
from repro.modeling import model as M
from repro.train import train_step as TS
from repro.train.optimizer import get_optimizer

VLM_PREFIX = 256          # stub ViT patch embeddings prepended to the text
CROSS_SEQ = 4096          # encoder length cached for enc-dec decode cells


def _pad_seq(s: int) -> int:
    return ((s + 16) // 16) * 16


def abstract_batch(cfg: ModelConfig, shape: ShapeConfig):
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if cfg.n_encoder_layers > 0:
        return {"tokens": jax.ShapeDtypeStruct((B, S), i32),
                "labels": jax.ShapeDtypeStruct((B, S), i32),
                "frontend": jax.ShapeDtypeStruct((B, S, cfg.frontend_dim),
                                                 jnp.dtype(cfg.dtype))}
    if cfg.frontend != "none":
        s_txt = S - VLM_PREFIX
        return {"tokens": jax.ShapeDtypeStruct((B, s_txt), i32),
                "labels": jax.ShapeDtypeStruct((B, s_txt), i32),
                "frontend": jax.ShapeDtypeStruct((B, VLM_PREFIX, cfg.frontend_dim),
                                                 jnp.dtype(cfg.dtype))}
    return {"tokens": jax.ShapeDtypeStruct((B, S), i32),
            "labels": jax.ShapeDtypeStruct((B, S), i32)}


def abstract_state(cfg: ModelConfig):
    params = M.abstract_params(cfg)
    get_optimizer(cfg.optimizer)      # validates the optimizer name
    f32 = jnp.float32

    def opt_leaf_adamw(p):
        return jax.ShapeDtypeStruct(p.shape, f32)

    if cfg.optimizer == "adamw":
        opt_state = {"m": jax.tree.map(opt_leaf_adamw, params),
                     "v": jax.tree.map(opt_leaf_adamw, params),
                     "count": jax.ShapeDtypeStruct((), jnp.int32)}
    else:
        def leaf(p):
            if len(p.shape) >= 2:
                return {"vr": jax.ShapeDtypeStruct(p.shape[:-1], f32),
                        "vc": jax.ShapeDtypeStruct(p.shape[:-2] + p.shape[-1:], f32)}
            return {"v": jax.ShapeDtypeStruct(p.shape, f32)}
        opt_state = {"leaves": jax.tree.map(leaf, params),
                     "count": jax.ShapeDtypeStruct((), jnp.int32)}
    return {"params": params, "opt": opt_state,
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def _ns(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def train_cell(cfg: ModelConfig, shape: ShapeConfig, mesh):
    state = abstract_state(cfg)
    batch = abstract_batch(cfg, shape)
    in_sh = (_ns(mesh, TS.state_specs(cfg, mesh)),
             _ns(mesh, TS.batch_specs(batch, mesh)))
    return (state, batch), in_sh


def prefill_cell(cfg: ModelConfig, shape: ShapeConfig, mesh):
    B, S = shape.global_batch, shape.seq_len
    max_seq = _pad_seq(S)
    cross = CROSS_SEQ if cfg.n_encoder_layers > 0 else 0
    params = M.abstract_params(cfg)
    batch = abstract_batch(cfg, shape)
    batch.pop("labels")
    cache = M.abstract_cache(cfg, B, max_seq, cross_seq=cross)
    in_sh = (_ns(mesh, M.param_specs(cfg, mesh=mesh)),
             _ns(mesh, TS.batch_specs(batch, mesh)),
             _ns(mesh, M.cache_specs(cfg, B, max_seq, cross_seq=cross, mesh=mesh)))
    return (params, batch, cache), in_sh


def decode_cell(cfg: ModelConfig, shape: ShapeConfig, mesh):
    B, S = shape.global_batch, shape.seq_len
    max_seq = _pad_seq(S)
    cross = CROSS_SEQ if cfg.n_encoder_layers > 0 else 0
    params = M.abstract_params(cfg)
    tokens = jax.ShapeDtypeStruct((B,), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    cache = M.abstract_cache(cfg, B, max_seq, cross_seq=cross)
    tok_spec = sharding.resolve_spec(("batch",), dims=(B,), mesh=mesh)
    in_sh = (_ns(mesh, M.param_specs(cfg, mesh=mesh)),
             NamedSharding(mesh, tok_spec),
             NamedSharding(mesh, P()),
             _ns(mesh, M.cache_specs(cfg, B, max_seq, cross_seq=cross, mesh=mesh)))
    return (params, tokens, pos, cache), in_sh


def cell_for(cfg: ModelConfig, shape: ShapeConfig, mesh):
    """Returns (step_fn, args, in_shardings, donate_argnums)."""
    from repro.serve import serve_step as SS
    if shape.kind == "train":
        args, in_sh = train_cell(cfg, shape, mesh)
        return TS.make_train_step(cfg), args, in_sh, (0,)
    if shape.kind == "prefill":
        args, in_sh = prefill_cell(cfg, shape, mesh)
        return SS.make_prefill_step(cfg), args, in_sh, (2,)
    args, in_sh = decode_cell(cfg, shape, mesh)
    return SS.make_decode_step(cfg), args, in_sh, (3,)
