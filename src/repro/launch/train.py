"""End-to-end training driver with fault tolerance and C3O runtime capture.

Features exercised here (and in tests/test_train_loop.py):
  - checkpoint/restart: CheckpointManager.maybe_restore resumes mid-run,
    including after a simulated crash (--crash-at-step) — deterministic data
    means the resumed loss curve continues exactly;
  - elastic re-shard: a restart may use a different host mesh;
  - straggler/failure mitigation: per-step wall-clock watchdog — a step
    exceeding ``--step-timeout`` x median aborts the process with the
    checkpoint intact (the cluster manager restarts it elsewhere);
  - collaborative capture (paper workflow step 6): measured step times are
    appended to a C3O runtime datastore for launch/autoconfig.py.

Usage (CPU, reduced config):
  PYTHONPATH=src python -m repro.launch.train --arch deepseek-7b --smoke \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from repro.configs import get_config, smoke_config
from repro.distributed import sharding
from repro.launch.mesh import make_host_mesh
from repro.train import train_step as TS
from repro.train.checkpoint import CheckpointManager
from repro.train.data import make_batch
from repro.train.optimizer import get_optimizer


def run(arch: str, steps: int, batch: int, seq: int, ckpt_dir: str,
        smoke: bool = True, ckpt_every: int = 20, crash_at_step: int = -1,
        step_timeout: float = 10.0, model_axis: int = 1, seed: int = 0,
        runtime_log: str = None, compress_grads: bool = False):
    cfg = smoke_config(arch) if smoke else get_config(arch)
    mesh = make_host_mesh(model_axis)
    opt = get_optimizer(cfg.optimizer)

    grad_transform = None
    ef_state = None
    if compress_grads:
        from repro.distributed.compression import make_ef_compressor
        init_ef, ef = make_ef_compressor()
        # stateful wrapper kept host-side (error feedback residual)
        state_box = {}

        def grad_transform(grads):   # noqa: F811
            nonlocal ef_state
            if ef_state is None:
                ef_state = init_ef(grads)
            g, ef_state_new = ef(grads, ef_state)
            state_box["s"] = ef_state_new
            return g

    step_fn = TS.make_train_step(cfg, opt=opt, grad_transform=grad_transform)
    mgr = CheckpointManager(ckpt_dir, keep=3)

    with sharding.use_mesh(mesh):
        state0 = TS.init_train_state(cfg, jax.random.PRNGKey(seed), opt=opt)
        state, start = mgr.maybe_restore(state0)
        step_jit = jax.jit(step_fn, donate_argnums=(0,))

        times, losses = [], []
        for step in range(start, steps):
            if compress_grads and "s" in (state_box or {}):
                ef_state = state_box["s"]
            t0 = time.time()
            data = make_batch(cfg, batch, seq, step, seed=seed)
            state, metrics = step_jit(state, data)
            loss = float(metrics["loss"])
            dt = time.time() - t0
            times.append(dt)
            losses.append(loss)
            # straggler watchdog: a wedged step must not hang the job
            if len(times) > 5 and dt > step_timeout * np.median(times[1:]):
                mgr.save(step + 1, state)
                raise SystemExit(f"straggler watchdog: step {step} took "
                                 f"{dt:.1f}s (median {np.median(times):.2f}s)"
                                 " — checkpointed and aborting for restart")
            if (step + 1) % ckpt_every == 0 or step == steps - 1:
                mgr.save(step + 1, state)
            if crash_at_step == step:
                raise SystemExit(f"simulated crash at step {step}")
        final_loss = losses[-1] if losses else float("nan")

    if runtime_log and times:
        rec = {"arch": arch, "smoke": smoke, "batch": batch, "seq": seq,
               "n_devices": len(jax.devices()), "model_axis": model_axis,
               "median_step_s": float(np.median(times[1:]) if len(times) > 1
                                      else times[0]),
               "final_loss": final_loss}
        os.makedirs(os.path.dirname(runtime_log) or ".", exist_ok=True)
        with open(runtime_log, "a") as f:
            f.write(json.dumps(rec) + "\n")
    return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--crash-at-step", type=int, default=-1)
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--runtime-log", default=None)
    args = ap.parse_args()
    losses = run(args.arch, args.steps, args.batch, args.seq, args.ckpt_dir,
                 smoke=args.smoke, ckpt_every=args.ckpt_every,
                 crash_at_step=args.crash_at_step,
                 model_axis=args.model_axis,
                 compress_grads=args.compress_grads,
                 runtime_log=args.runtime_log)
    print(f"first loss {losses[0]:.4f} -> last loss {losses[-1]:.4f} "
          f"({len(losses)} steps)")


if __name__ == "__main__":
    main()
