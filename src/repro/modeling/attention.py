"""Attention: GQA/MQA/MHA, MLA, sliding-window, and KV caches.

Three train/prefill implementations (selected by ``cfg.attention_impl``):
  reference    naive full [S,S] scores (exactness oracle, smoke tests)
  blocked      kv-chunked online-softmax scan (bounded memory; causal masked
               rectangle -> ~2x FLOP overcount on causal, see EXPERIMENTS §Perf)
  blocked_tri  q-chunk-unrolled triangle (exact causal FLOPs; hillclimb result)

Local (sliding-window) layers use a banded gather path; decode uses single-step
cache attention (ring buffer for windowed layers, absorbed-matmul for MLA).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ATTN_LOCAL
from repro.distributed import sharding
from repro.modeling.layers import ParamDef, apply_rope, rope_freqs

NEG_INF = -2.0e38


# ---------------------------------------------------------------------------
# parameter / cache definitions
# ---------------------------------------------------------------------------

def attn_defs(cfg: ModelConfig, cross: bool = False) -> dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    if cfg.use_mla and not cross:
        nr = cfg.qk_nope_dim + cfg.qk_rope_dim
        return {
            "wq_a": ParamDef((d, cfg.q_lora_rank), ("fsdp", None)),
            "q_norm": ParamDef((cfg.q_lora_rank,), (None,), "zeros"),
            "wq_b": ParamDef((cfg.q_lora_rank, cfg.n_heads, nr), (None, "model", None)),
            "wkv_a": ParamDef((d, cfg.kv_lora_rank + cfg.qk_rope_dim), ("fsdp", None)),
            "kv_norm": ParamDef((cfg.kv_lora_rank,), (None,), "zeros"),
            "wkv_b": ParamDef((cfg.kv_lora_rank, cfg.n_heads,
                               cfg.qk_nope_dim + cfg.v_head_dim), (None, "model", None)),
            "wo": ParamDef((cfg.n_heads, cfg.v_head_dim, d), ("model", None, "fsdp")),
        }
    return {
        "wq": ParamDef((d, cfg.n_heads, hd), ("fsdp", "model", None)),
        "wk": ParamDef((d, cfg.n_kv_heads, hd), ("fsdp", "model", None)),
        "wv": ParamDef((d, cfg.n_kv_heads, hd), ("fsdp", "model", None)),
        "wo": ParamDef((cfg.n_heads, hd, d), ("model", None, "fsdp")),
    }


def attn_cache_defs(cfg: ModelConfig, batch: int, max_seq: int,
                    kind: str, cross_seq: int = 0) -> dict:
    hd = cfg.resolved_head_dim
    if cross_seq:   # encoder-decoder cross attention: static K/V from encoder
        return {
            "k": ParamDef((batch, cross_seq, cfg.n_kv_heads, hd),
                          ("batch", None, "model", None), "zeros"),
            "v": ParamDef((batch, cross_seq, cfg.n_kv_heads, hd),
                          ("batch", None, "model", None), "zeros"),
        }
    if cfg.use_mla:
        # latent cache has no heads dim -> shard the sequence ("flash-decode")
        return {
            "ckv": ParamDef((batch, max_seq, cfg.kv_lora_rank),
                            ("batch", "model", None), "zeros"),
            "krope": ParamDef((batch, max_seq, cfg.qk_rope_dim),
                              ("batch", "model", None), "zeros"),
        }
    buf = min(max_seq, cfg.window_size) if (kind == ATTN_LOCAL and cfg.window_size) \
        else max_seq
    # Shard KV heads over "model" when they divide the production model axis
    # (16); otherwise shard the cache *sequence* so long-context caches still
    # spread over the mesh (flash-decode style partial softmax; GSPMD inserts
    # the max/sum all-reduces).
    if cfg.n_kv_heads % 16 == 0:
        kv_ax, seq_ax = "model", None
    else:
        kv_ax, seq_ax = None, "model"
    kv_dt = cfg.kv_cache_dtype or None
    out = {
        "k": ParamDef((batch, buf, cfg.n_kv_heads, hd),
                      ("batch", seq_ax, kv_ax, None), "zeros", dtype=kv_dt),
        "v": ParamDef((batch, buf, cfg.n_kv_heads, hd),
                      ("batch", seq_ax, kv_ax, None), "zeros", dtype=kv_dt),
    }
    if kv_dt == "int8":      # per-(position, head) symmetric scales
        for nm in ("k_scale", "v_scale"):
            out[nm] = ParamDef((batch, buf, cfg.n_kv_heads, 1),
                               ("batch", seq_ax, kv_ax, None), "zeros",
                               dtype="bfloat16")
    return out


# ---------------------------------------------------------------------------
# core score/value computation paths
# ---------------------------------------------------------------------------

def _mask_bias(q_pos, k_pos, causal: bool, window: int):
    """[... Sq, Sk] additive bias from position masks (fp32)."""
    ok = jnp.ones(q_pos.shape[-1:] + k_pos.shape[-1:], bool)
    if causal:
        ok &= k_pos[None, :] <= q_pos[:, None]
    if window:
        ok &= k_pos[None, :] > (q_pos[:, None] - window)
    return jnp.where(ok, 0.0, NEG_INF)


def _sdpa(q, k, v, bias, cap: float, scale: float):
    """Naive softmax attention. q [B,Sq,K,G,h], k [B,Sk,K,h], v [B,Sk,K,hv]."""
    s = jnp.einsum("bqkgh,bskh->bkgqs", q, k).astype(jnp.float32) * scale
    if cap:
        s = cap * jnp.tanh(s / cap)
    s = s + bias
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bqkgh", p.astype(v.dtype), v)
    return o


def attention_reference(q, k, v, *, q_pos, k_pos, causal=True, window=0,
                        cap=0.0, scale=None):
    """q [B,Sq,H,h]; k,v [B,Sk,KV,h(v)] -> [B,Sq,H,hv]."""
    B, Sq, H, h = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = scale if scale is not None else h ** -0.5
    qg = q.reshape(B, Sq, KV, G, h)
    bias = _mask_bias(q_pos, k_pos, causal, window)
    o = _sdpa(qg, k, v, bias, cap, scale)
    return o.reshape(B, Sq, H, v.shape[-1])


def attention_blocked(q, k, v, *, q_pos, k_pos, causal=True, window=0, cap=0.0,
                      scale=None, chunk=1024):
    """KV-chunked online-softmax (rectangle, masked). Bounded memory."""
    B, Sq, H, h = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    hv = v.shape[-1]
    G = H // KV
    scale = scale if scale is not None else h ** -0.5
    chunk = min(chunk, Sk)
    n = -(-Sk // chunk)
    pad = n * chunk - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, (0, pad), constant_values=2**30)
    qg = (q.reshape(B, Sq, KV, G, h) * scale).astype(q.dtype)
    kc = k.reshape(B, n, chunk, KV, h).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n, chunk, KV, hv).transpose(1, 0, 2, 3, 4)
    pc = k_pos.reshape(n, chunk)

    def step(carry, xs):
        m, den, acc = carry
        kj, vj, pj = xs
        s = jnp.einsum("bqkgh,bskh->bkgqs", qg, kj).astype(jnp.float32)
        if cap:
            s = cap * jnp.tanh(s / cap)
        s = s + _mask_bias(q_pos, pj, causal, window)
        m_new = jnp.maximum(m, s.max(axis=-1))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        den = den * corr + p.sum(axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bkgqs,bskh->bkgqh", p.astype(vj.dtype), vj).astype(jnp.float32)
        return (m_new, den, acc), None

    m0 = jnp.full((B, KV, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KV, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, KV, G, Sq, hv), jnp.float32)
    (m, den, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kc, vc, pc))
    o = acc / jnp.maximum(den, 1e-30)[..., None]
    return o.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, hv).astype(v.dtype)


def attention_triangle(q, k, v, *, q_pos, k_pos, cap=0.0, scale=None,
                       chunk=2048):
    """Causal attention with q-chunk unrolling and static growing kv slices.

    Exact-triangle FLOPs (no masked-rectangle waste): q chunk i attends
    kv[: (i+1)*chunk].  HLO grows O(S/chunk) - chunk chosen to keep that small.
    """
    B, Sq, H, h = q.shape
    KV = k.shape[2]
    hv = v.shape[-1]
    G = H // KV
    scale = scale if scale is not None else h ** -0.5
    chunk = min(chunk, Sq)
    assert Sq % chunk == 0, "triangle path requires seq % chunk == 0"
    n = Sq // chunk
    outs = []
    for i in range(n):
        qi = q[:, i * chunk:(i + 1) * chunk].reshape(B, chunk, KV, G, h)
        hi = (i + 1) * chunk
        ki, vi = k[:, :hi], v[:, :hi]
        bias = _mask_bias(q_pos[i * chunk:(i + 1) * chunk], k_pos[:hi], True, 0)
        outs.append(_sdpa(qi * scale, ki, vi, bias, cap, 1.0)
                    .reshape(B, chunk, H, hv).astype(v.dtype))
    return jnp.concatenate(outs, axis=1)


def attention_banded(q, k, v, *, q_pos, k_pos, window: int, cap=0.0,
                     scale=None, chunk=1024):
    """Sliding-window attention: per-q-chunk banded kv gather (causal).

    FLOPs O(S * (window + chunk)) instead of O(S^2)."""
    B, Sq, H, h = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    hv = v.shape[-1]
    G = H // KV
    scale = scale if scale is not None else h ** -0.5
    chunk = min(chunk, Sq)
    assert Sq % chunk == 0
    n = Sq // chunk
    band = window + chunk

    qg = (q.reshape(B, n, chunk, KV, G, h) * scale).transpose(1, 0, 2, 3, 4, 5)
    qp = q_pos.reshape(n, chunk)
    starts = jnp.maximum(jnp.arange(n) * chunk + chunk - band, 0)

    def one(qi, qpi, start):
        kb = jax.lax.dynamic_slice_in_dim(k, start, min(band, Sk), axis=1)
        vb = jax.lax.dynamic_slice_in_dim(v, start, min(band, Sk), axis=1)
        pb = jax.lax.dynamic_slice_in_dim(k_pos, start, min(band, Sk), axis=0)
        bias = _mask_bias(qpi, pb, True, window)
        return _sdpa(qi, kb, vb, bias, cap, 1.0)

    o = jax.lax.map(lambda xs: one(*xs), (qg, qp, starts))   # [n,B,K,G,chunk,hv]
    o = o.transpose(1, 4, 0, 2, 3, 5).reshape(B, n, chunk, H, hv)
    return o.reshape(B, Sq, H, hv).astype(v.dtype)


def decode_attention(q, k_cache, v_cache, *, pos, window=0, buf_offset=None,
                     cap=0.0, scale=None):
    """Single-token attention over a cache. q [B,1,H,h]; caches [B,L,KV,h].

    ``pos``: current absolute position (int32 scalar).  For ring-buffer
    (windowed) caches, ``buf_offset`` maps buffer slot -> absolute position.
    """
    B, _, H, h = q.shape
    L, KV = k_cache.shape[1], k_cache.shape[2]
    hv = v_cache.shape[-1]
    G = H // KV
    scale = scale if scale is not None else h ** -0.5
    qg = q.reshape(B, KV, G, h) * scale
    s = jnp.einsum("bkgh,blkh->bkgl", qg, k_cache).astype(jnp.float32)
    if cap:
        s = cap * jnp.tanh(s / cap)
    k_pos = buf_offset if buf_offset is not None else jnp.arange(L)
    ok = k_pos <= pos
    if window:
        ok &= k_pos > pos - window
    s = jnp.where(ok[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgl,blkh->bkgh", p.astype(v_cache.dtype), v_cache)
    return o.reshape(B, 1, H, hv)


# ---------------------------------------------------------------------------
# full attention layer (projections + rope + cache management)
# ---------------------------------------------------------------------------

def _quantize_kv(x):
    """[..., hd] -> (int8 values, bf16 scale[..., 1])."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True) / 127.0
    q = jnp.round(x.astype(jnp.float32)
                  / jnp.maximum(scale, 1e-8)).astype(jnp.int8)
    return q, scale.astype(jnp.bfloat16)


def _dequantize_kv(q, scale, dtype):
    return (q.astype(jnp.float32) * scale.astype(jnp.float32)).astype(dtype)


def _maybe_shard_heads(x, heads_axis: int = 2):
    """Shard the heads dim over "model" only when it divides evenly; with
    odd head counts (minicpm3: 40) the constraint would force sequence
    replication, so we leave layout propagation to XLA instead."""
    mesh = sharding.current_mesh()
    if mesh is None:
        return x
    msize = mesh.shape.get("model", 1)
    if msize > 1 and x.shape[heads_axis] % msize == 0:
        return sharding.shard(x, "batch", None, "model", None)
    return x


def _select_impl(cfg: ModelConfig, kind: str, causal: bool):
    if cfg.attention_impl == "reference":
        return "reference"
    if kind == ATTN_LOCAL and cfg.window_size and causal:
        return "banded"
    if cfg.attention_impl == "blocked_tri" and causal:
        return "triangle"
    return "blocked"


def _run_attention(cfg, q, k, v, q_pos, k_pos, kind, causal, cap):
    impl = _select_impl(cfg, kind, causal)
    window = cfg.window_size if kind == ATTN_LOCAL else 0
    if impl == "reference":
        return attention_reference(q, k, v, q_pos=q_pos, k_pos=k_pos,
                                   causal=causal, window=window, cap=cap)
    if impl == "banded":
        return attention_banded(q, k, v, q_pos=q_pos, k_pos=k_pos,
                                window=window, cap=cap)
    if impl == "triangle":
        return attention_triangle(q, k, v, q_pos=q_pos, k_pos=k_pos, cap=cap)
    return attention_blocked(q, k, v, q_pos=q_pos, k_pos=k_pos,
                             causal=causal, window=window, cap=cap)


def attn_apply(cfg: ModelConfig, p: dict, x, *, kind: str, mode: str,
               pos0, cache: Optional[dict], causal: bool = True,
               kv_source=None, is_cross: bool = False,
               ) -> Tuple[jax.Array, Optional[dict]]:
    """One attention layer.  mode: train | prefill | decode.

    pos0: absolute position of x[:, 0] (python int or traced scalar).
    kv_source: encoder output for cross attention (K/V from there, no rope).
    is_cross: cross-attention layer (during decode K/V come from the cache).
    """
    is_cross = is_cross or kv_source is not None
    if cfg.use_mla and not is_cross:
        return _mla_apply(cfg, p, x, mode=mode, pos0=pos0, cache=cache)

    B, S, D = x.shape
    hd = cfg.resolved_head_dim
    cap = cfg.attn_logit_softcap
    theta = cfg.rope_theta if kind != ATTN_LOCAL else min(cfg.rope_theta, 10_000.0)

    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    q = _maybe_shard_heads(q)
    if is_cross and mode == "decode":          # K/V are static, from the cache
        o = decode_attention(q, cache["k"], cache["v"], pos=jnp.asarray(2**30),
                             cap=cap)
        out = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x.dtype))
        return out, cache
    src = kv_source if is_cross else x
    k = jnp.einsum("bsd,dhk->bshk", src, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", src, p["wv"].astype(x.dtype))
    k = _maybe_shard_heads(k)
    v = _maybe_shard_heads(v)

    q_pos = pos0 + jnp.arange(S)
    if not is_cross:                           # self attention: rope q and k
        sin, cos = rope_freqs(q_pos, hd, theta)
        q = apply_rope(q, sin, cos)
        k = apply_rope(k, sin, cos)

    new_cache = cache
    int8_kv = cache is not None and "k_scale" in cache
    if mode == "decode":
        assert S == 1
        buf = cache["k"].shape[1]
        slot = jnp.asarray(pos0) % buf
        if int8_kv:
            kq, ks = _quantize_kv(k)
            vq, vs = _quantize_kv(v)
            new_cache = {
                "k": jax.lax.dynamic_update_slice_in_dim(cache["k"], kq, slot, 1),
                "v": jax.lax.dynamic_update_slice_in_dim(cache["v"], vq, slot, 1),
                "k_scale": jax.lax.dynamic_update_slice_in_dim(
                    cache["k_scale"], ks, slot, 1),
                "v_scale": jax.lax.dynamic_update_slice_in_dim(
                    cache["v_scale"], vs, slot, 1),
            }
            ck = _dequantize_kv(new_cache["k"], new_cache["k_scale"], x.dtype)
            cv = _dequantize_kv(new_cache["v"], new_cache["v_scale"], x.dtype)
        else:
            ck = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k.astype(cache["k"].dtype), slot, 1)
            cv = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v.astype(cache["v"].dtype), slot, 1)
            new_cache = {"k": ck, "v": cv}
        window = cfg.window_size if kind == ATTN_LOCAL else 0
        if window and buf == window:
            # ring buffer: recover the absolute position held in each slot;
            # first-turn slots beyond the write head are EMPTY (would map to
            # negative positions) and must be masked out
            idx = jnp.arange(buf)
            turn = jnp.asarray(pos0) // buf
            offs = jnp.where(idx <= slot, turn * buf + idx,
                             (turn - 1) * buf + idx)
            offs = jnp.where(offs < 0, 2 ** 30, offs)
        else:
            offs = jnp.arange(buf)
        o = decode_attention(q, ck, cv, pos=jnp.asarray(pos0), window=window,
                             buf_offset=offs, cap=cap)
    else:
        if cache is not None and not is_cross:        # prefill: write cache
            buf = cache["k"].shape[1]
            if int8_kv and buf >= S:
                kq, ks = _quantize_kv(k)
                vq, vs = _quantize_kv(v)
                p0 = jnp.asarray(pos0)
                new_cache = {
                    "k": jax.lax.dynamic_update_slice_in_dim(cache["k"], kq, p0, 1),
                    "v": jax.lax.dynamic_update_slice_in_dim(cache["v"], vq, p0, 1),
                    "k_scale": jax.lax.dynamic_update_slice_in_dim(
                        cache["k_scale"], ks, p0, 1),
                    "v_scale": jax.lax.dynamic_update_slice_in_dim(
                        cache["v_scale"], vs, p0, 1),
                }
            elif buf >= S:
                ck = jax.lax.dynamic_update_slice_in_dim(
                    cache["k"], k.astype(cache["k"].dtype), jnp.asarray(pos0), 1)
                cv = jax.lax.dynamic_update_slice_in_dim(
                    cache["v"], v.astype(cache["v"].dtype), jnp.asarray(pos0), 1)
            else:            # windowed ring: keep the tail in ring layout,
                # slot(p) = p % buf, so decode's ring arithmetic lines up
                shift = (jnp.asarray(pos0) + S) % buf
                ck = jnp.roll(k[:, -buf:], shift, axis=1).astype(cache["k"].dtype)
                cv = jnp.roll(v[:, -buf:], shift, axis=1).astype(cache["v"].dtype)
            if not int8_kv:
                new_cache = {"k": ck, "v": cv}
        if cache is not None and is_cross:            # cross K/V: static cache
            new_cache = {"k": k.astype(cache["k"].dtype),
                         "v": v.astype(cache["v"].dtype)}
        k_pos = jnp.arange(k.shape[1])
        o = _run_attention(cfg, q, k, v, q_pos, k_pos, kind,
                           causal and not is_cross, cap)

    o = _maybe_shard_heads(o)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x.dtype))
    return out, new_cache


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention, MiniCPM3 / DeepSeek-V2 style)
# ---------------------------------------------------------------------------

def _mla_apply(cfg: ModelConfig, p, x, *, mode, pos0, cache):
    from repro.modeling.layers import rms_norm
    B, S, D = x.shape
    nd, rd, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    cap = cfg.attn_logit_softcap
    scale = (nd + rd) ** -0.5

    cq = rms_norm(jnp.einsum("bsd,dr->bsr", x, p["wq_a"].astype(x.dtype)),
                  p["q_norm"], cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", cq, p["wq_b"].astype(x.dtype))
    q_nope, q_rope = q[..., :nd], q[..., nd:]

    ckv_full = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"].astype(x.dtype))
    ckv = rms_norm(ckv_full[..., :cfg.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    k_rope = ckv_full[..., cfg.kv_lora_rank:][:, :, None, :]   # shared head

    q_pos = pos0 + jnp.arange(S)
    sin, cos = rope_freqs(q_pos, rd, cfg.rope_theta)
    q_rope = apply_rope(q_rope, sin, cos)
    k_rope = apply_rope(k_rope, sin, cos)[:, :, 0, :]

    wkv_b = p["wkv_b"].astype(x.dtype)
    wk_b, wv_b = wkv_b[..., :nd], wkv_b[..., nd:]

    if mode == "decode":
        assert S == 1
        c_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["ckv"], ckv.astype(cache["ckv"].dtype), jnp.asarray(pos0), 1)
        r_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["krope"], k_rope.astype(cache["krope"].dtype), jnp.asarray(pos0), 1)
        # absorbed path: project q into latent space via wk_b [c,h,k]
        q_lat = jnp.einsum("bshk,chk->bshc", q_nope, wk_b)
        s = (jnp.einsum("bshc,blc->bhsl", q_lat, c_cache)
             + jnp.einsum("bshk,blk->bhsl", q_rope, r_cache)).astype(jnp.float32) * scale
        if cap:
            s = cap * jnp.tanh(s / cap)
        L = c_cache.shape[1]
        ok = jnp.arange(L) <= jnp.asarray(pos0)
        s = jnp.where(ok[None, None, None, :], s, NEG_INF)
        pr = jax.nn.softmax(s, axis=-1)
        o_lat = jnp.einsum("bhsl,blc->bshc", pr.astype(c_cache.dtype), c_cache)
        o = jnp.einsum("bshc,chv->bshv", o_lat, wv_b)
        new_cache = {"ckv": c_cache, "krope": r_cache}
    else:
        k_nope = jnp.einsum("bsc,chk->bshk", ckv, wk_b)
        v = jnp.einsum("bsc,chv->bshv", ckv, wv_b)
        k = jnp.concatenate([k_nope, jnp.broadcast_to(
            k_rope[:, :, None, :], (*k_nope.shape[:3], rd))], axis=-1)
        qq = jnp.concatenate([q_nope, q_rope], axis=-1)
        new_cache = cache
        if cache is not None:
            new_cache = {
                "ckv": jax.lax.dynamic_update_slice_in_dim(
                    cache["ckv"], ckv.astype(cache["ckv"].dtype), jnp.asarray(pos0), 1),
                "krope": jax.lax.dynamic_update_slice_in_dim(
                    cache["krope"], k_rope.astype(cache["krope"].dtype), jnp.asarray(pos0), 1),
            }
        o = _run_attention(cfg, qq, k, v, q_pos, jnp.arange(S), "attn", True, cap)

    out = jnp.einsum("bshv,hvd->bsd", o, p["wo"].astype(x.dtype))
    return out, new_cache
