"""Common building blocks: ParamDef machinery, norms, FFN, RoPE, embeddings.

Parameters are described structurally once (``ParamDef`` pytrees) so that
``init_params`` (materialize random values), ``param_specs`` (PartitionSpecs)
and ``abstract_params`` (ShapeDtypeStructs for AOT lowering) all derive from a
single source of truth.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed import sharding


@dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]   # logical sharding axis per dim
    init: str = "normal"                 # normal | zeros | ones | embed
    scale: float = 1.0                   # stddev multiplier (normal) / value
    dtype: Optional[str] = None          # None -> container default

    def with_leading(self, n: int) -> "ParamDef":
        return dataclasses.replace(self, shape=(n, *self.shape),
                                   logical=(None, *self.logical))


jax.tree_util.register_pytree_node(  # treat ParamDef as a leaf inside pytrees
    ParamDef, lambda p: ((), p), lambda p, _: p)


def is_def(x) -> bool:
    return isinstance(x, ParamDef)


def _fan_in(shape: Tuple[int, ...]) -> int:
    # contracting-dim heuristic: everything but the trailing (output) dim
    if len(shape) <= 1:
        return max(shape[0] if shape else 1, 1)
    return int(np.prod(shape[:-1]))


def materialize(defs, key: jax.Array, dtype) -> dict:
    """Deterministically init every ParamDef leaf (fold_in by flattened path)."""
    leaves, treedef = jax.tree_util.tree_flatten_with_path(defs, is_leaf=is_def)

    out = []
    for i, (path, d) in enumerate(leaves):
        k = jax.random.fold_in(key, i)
        dt = jnp.dtype(d.dtype) if d.dtype else dtype
        if d.init == "zeros":
            v = jnp.zeros(d.shape, dt)
        elif d.init == "ones":
            v = jnp.full(d.shape, d.scale, dt)
        else:
            std = d.scale / np.sqrt(max(_fan_in(d.shape), 1))
            if d.init == "embed":
                std = d.scale
            v = (jax.random.normal(k, d.shape, jnp.float32) * std).astype(dt)
        out.append(v)
    return jax.tree_util.tree_unflatten(treedef, out)


def specs_of(defs, mesh=None):
    """ParamDef tree -> PartitionSpec tree."""
    return jax.tree.map(
        lambda d: sharding.resolve_spec(d.logical, dims=d.shape, mesh=mesh),
        defs, is_leaf=is_def)


def abstract_of(defs, dtype):
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape,
                                       jnp.dtype(d.dtype) if d.dtype else dtype),
        defs, is_leaf=is_def)


# ---------------------------------------------------------------------------
# numerics helpers
# ---------------------------------------------------------------------------

def rms_norm(x, w, eps: float):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + w.astype(jnp.float32))).astype(dt)


def softcap(x, cap: float):
    if not cap:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


def rope_freqs(positions, dim: int, theta: float):
    """positions [*, S] -> (sin, cos) each [*, S, dim//2], fp32."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x, sin, cos):
    """x [..., S, H, hd]; sin/cos [..., S, hd//2] broadcast over heads."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    x1, x2 = jnp.split(x, 2, axis=-1)
    s, c = sin[..., None, :], cos[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(dt)


def ffn_defs(d_model: int, d_ff: int, act: str) -> dict:
    defs = {
        "w_up": ParamDef((d_model, d_ff), ("fsdp", "model")),
        "w_down": ParamDef((d_ff, d_model), ("model", "fsdp")),
    }
    if act == "swiglu":
        defs["w_gate"] = ParamDef((d_model, d_ff), ("fsdp", "model"))
    return defs


def ffn_apply(p, x, act: str):
    h = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(x.dtype))
    if act == "swiglu":
        g = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(x.dtype))
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    h = sharding.shard(h, "batch", None, "model")
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(x.dtype))
