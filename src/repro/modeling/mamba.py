"""Mamba (selective state-space) layer for the Jamba hybrid architecture.

Train/prefill path: chunked scan over the sequence — within a chunk the
recurrence h_t = dA_t * h_{t-1} + dB_t u_t is evaluated with an associative
scan on [B, C, d_inner, N]; across chunks only the (state, conv-tail) carry
survives, bounding memory.  Decode: O(1) single-step update.

TP: d_inner is sharded on "model" (all ops are elementwise or contract D/din),
so the layer needs no collectives beyond the out-projection reduce.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed import sharding
from repro.modeling.layers import ParamDef

CHUNK = 128


def mamba_defs(cfg: ModelConfig) -> dict:
    d, din = cfg.d_model, cfg.mamba_d_inner
    n, dtr, dc = cfg.mamba_d_state, cfg.resolved_dt_rank, cfg.mamba_d_conv
    return {
        "in_proj": ParamDef((d, 2 * din), ("fsdp", "model")),
        "conv_w": ParamDef((dc, din), (None, "model")),
        "conv_b": ParamDef((din,), ("model",), "zeros"),
        "x_proj": ParamDef((din, dtr + 2 * n), ("model", None)),
        "dt_proj": ParamDef((dtr, din), (None, "model")),
        "dt_bias": ParamDef((din,), ("model",), "ones", 0.01),
        "A_log": ParamDef((din, n), ("model", None), "ones", 0.5),
        "D_skip": ParamDef((din,), ("model",), "ones", 1.0),
        "out_proj": ParamDef((din, d), ("model", "fsdp")),
    }


def mamba_cache_defs(cfg: ModelConfig, batch: int) -> dict:
    din, n, dc = cfg.mamba_d_inner, cfg.mamba_d_state, cfg.mamba_d_conv
    return {
        "h": ParamDef((batch, din, n), ("batch", "model", None), "zeros"),
        "conv": ParamDef((batch, dc - 1, din), ("batch", None, "model"), "zeros"),
    }


def _causal_conv(u, tail, w, b):
    """u [B,C,din], tail [B,dc-1,din], w [dc,din] -> (y [B,C,din], new_tail)."""
    dc = w.shape[0]
    full = jnp.concatenate([tail.astype(u.dtype), u], axis=1)      # [B, C+dc-1, din]
    y = sum(full[:, k:k + u.shape[1], :] * w[k] for k in range(dc))
    new_tail = full[:, -(dc - 1):, :] if dc > 1 else tail
    return y + b, new_tail


def _ssm_chunk(p, u_c, h_prev, dtype):
    """One chunk of the selective scan.  u_c [B,C,din] (post conv+silu)."""
    n = p["A_log"].shape[-1]
    dtBC = jnp.einsum("bcd,dk->bck", u_c, p["x_proj"].astype(u_c.dtype))
    dtr = p["dt_proj"].shape[0]
    dt_raw, B_ssm, C_ssm = jnp.split(dtBC, [dtr, dtr + n], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("bcr,rd->bcd", dt_raw, p["dt_proj"].astype(u_c.dtype))
        .astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                   # [din,N]
    dA = jnp.exp(dt[..., None] * A[None, None])                    # [B,C,din,N]
    dBu = (dt * u_c.astype(jnp.float32))[..., None] * B_ssm.astype(jnp.float32)[:, :, None, :]

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a2 * a1, a2 * b1 + b2

    a_cum, b_cum = jax.lax.associative_scan(combine, (dA, dBu), axis=1)
    states = a_cum * h_prev[:, None] + b_cum                       # [B,C,din,N]
    y = jnp.einsum("bcdn,bcn->bcd", states, C_ssm.astype(jnp.float32))
    y = y + p["D_skip"].astype(jnp.float32) * u_c.astype(jnp.float32)
    return y.astype(dtype), states[:, -1]


def mamba_apply(cfg: ModelConfig, p, x, *, mode: str,
                cache: Optional[dict]) -> Tuple[jax.Array, Optional[dict]]:
    B, S, D = x.shape
    din = cfg.mamba_d_inner
    uz = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(x.dtype))
    uz = sharding.shard(uz, "batch", None, "model")
    u, z = jnp.split(uz, 2, axis=-1)

    if mode == "decode":
        assert S == 1 and cache is not None
        y_c, new_tail = _causal_conv(u, cache["conv"], p["conv_w"].astype(x.dtype),
                                     p["conv_b"].astype(x.dtype))
        u_c = jax.nn.silu(y_c)
        y, h_new = _ssm_chunk(p, u_c, cache["h"].astype(jnp.float32), x.dtype)
        new_cache = {"h": h_new.astype(cache["h"].dtype),
                     "conv": new_tail.astype(cache["conv"].dtype)}
    else:
        chunk = min(CHUNK, S)
        assert S % chunk == 0
        nch = S // chunk
        h0 = (cache["h"].astype(jnp.float32) if cache is not None
              else jnp.zeros((B, din, cfg.mamba_d_state), jnp.float32))
        tail0 = (cache["conv"].astype(x.dtype) if cache is not None
                 else jnp.zeros((B, cfg.mamba_d_conv - 1, din), x.dtype))
        uc = u.reshape(B, nch, chunk, din).transpose(1, 0, 2, 3)

        def step(carry, u_i):
            h, tail = carry
            y_c, tail = _causal_conv(u_i, tail, p["conv_w"].astype(x.dtype),
                                     p["conv_b"].astype(x.dtype))
            u_i = jax.nn.silu(y_c)
            y, h = _ssm_chunk(p, u_i, h, x.dtype)
            return (h, tail), y

        (h_end, tail_end), ys = jax.lax.scan(step, (h0, tail0), uc)
        y = ys.transpose(1, 0, 2, 3).reshape(B, S, din)
        new_cache = None
        if cache is not None:
            new_cache = {"h": h_end.astype(cache["h"].dtype),
                         "conv": tail_end.astype(cache["conv"].dtype)}

    y = y * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(x.dtype))
    return out, new_cache
