"""Model assembly: embeddings -> scanned blocks -> head, for all families.

Layer stacking uses ``jax.lax.scan`` over *pattern periods* (gemma2: [local,
global]; gemma3: 5xlocal+global; jamba: 7xmamba+attn with MoE every 2nd layer)
so compiled HLO size is O(period), not O(depth).  Remainder layers that do not
fill a period are unrolled ("tail").

``forward`` covers train / prefill / decode; caches are pytrees mirroring the
block structure with a leading scan dimension.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import (ATTN, ATTN_LOCAL, MAMBA, RWKV, ModelConfig)
from repro.distributed import sharding
from repro.modeling import attention, mamba, moe, rwkv
from repro.modeling.layers import (ParamDef, abstract_of, ffn_apply, ffn_defs,
                                   materialize, rms_norm, softcap, specs_of)

# ---------------------------------------------------------------------------
# parameter structure
# ---------------------------------------------------------------------------

def _norm_def(cfg):
    return ParamDef((cfg.d_model,), (None,), "zeros")


def layer_defs(cfg: ModelConfig, i: int, role: str = "decoder") -> dict:
    kind = cfg.layer_kind(i) if role == "decoder" else ATTN
    d = {"ln1": _norm_def(cfg)}
    if kind in (ATTN, ATTN_LOCAL):
        d["attn"] = attention.attn_defs(cfg)
    elif kind == MAMBA:
        d["mamba"] = mamba.mamba_defs(cfg)
    elif kind == RWKV:
        d["tm"] = rwkv.rwkv_tm_defs(cfg)
    if role == "decoder" and cfg.n_encoder_layers > 0:
        d["ln_cross"] = _norm_def(cfg)
        d["cross"] = attention.attn_defs(cfg, cross=True)
    d["ln2"] = _norm_def(cfg)
    if kind == RWKV:
        d["cm"] = rwkv.rwkv_cm_defs(cfg)
    elif role == "decoder" and cfg.is_moe_layer(i):
        d["moe"] = moe.moe_defs(cfg)
    else:
        d["ffn"] = ffn_defs(cfg.d_model, cfg.d_ff, cfg.act)
    if cfg.post_norm:
        d["ln1_post"] = _norm_def(cfg)
        d["ln2_post"] = _norm_def(cfg)
    return d


def block_defs(cfg: ModelConfig, role: str = "decoder") -> dict:
    period = cfg.pattern_period if role == "decoder" else 1
    return {f"l{j}": layer_defs(cfg, j, role) for j in range(period)}


def model_defs(cfg: ModelConfig) -> dict:
    D = cfg.d_model
    defs = {
        "embed": ParamDef((cfg.padded_vocab_size, D), ("model", "fsdp"),
                          "embed", 0.02),
        "final_norm": _norm_def(cfg),
    }
    if cfg.frontend != "none":
        defs["frontend_proj"] = ParamDef((cfg.frontend_dim, D), (None, "fsdp"))
    if not cfg.tie_embeddings:
        defs["lm_head"] = ParamDef((D, cfg.padded_vocab_size), ("fsdp", "model"))
    nb = cfg.n_scan_blocks
    if cfg.scan_layers and nb > 0:
        defs["blocks"] = jax.tree.map(lambda p: p.with_leading(nb),
                                      block_defs(cfg), is_leaf=lambda x: isinstance(x, ParamDef))
    else:
        defs["blocks_unrolled"] = {
            f"b{i}": block_defs(cfg) for i in range(nb)} if nb else {}
    defs["tail"] = {f"l{j}": layer_defs(cfg, cfg.n_scan_blocks * cfg.pattern_period + j)
                    for j in range(cfg.n_tail_layers)}
    if cfg.n_encoder_layers > 0:
        defs["enc_blocks"] = jax.tree.map(
            lambda p: p.with_leading(cfg.n_encoder_layers),
            block_defs(cfg, role="encoder"),
            is_leaf=lambda x: isinstance(x, ParamDef))
        defs["enc_norm"] = _norm_def(cfg)
    return defs


# --------------------------- caches ---------------------------------------

def layer_cache_defs(cfg: ModelConfig, i: int, batch: int, max_seq: int,
                     cross_seq: int = 0) -> dict:
    kind = cfg.layer_kind(i)
    d = {}
    if kind in (ATTN, ATTN_LOCAL):
        d["attn"] = attention.attn_cache_defs(cfg, batch, max_seq, kind)
    elif kind == MAMBA:
        d["mamba"] = mamba.mamba_cache_defs(cfg, batch)
    elif kind == RWKV:
        d["rwkv"] = rwkv.rwkv_cache_defs(cfg, batch)
    if cross_seq:
        d["cross"] = attention.attn_cache_defs(cfg, batch, max_seq, ATTN,
                                               cross_seq=cross_seq)
    return d


def cache_defs(cfg: ModelConfig, batch: int, max_seq: int,
               cross_seq: int = 0) -> dict:
    period = cfg.pattern_period
    block = {f"l{j}": layer_cache_defs(cfg, j, batch, max_seq, cross_seq)
             for j in range(period)}
    nb = cfg.n_scan_blocks
    out = {}
    if cfg.scan_layers and nb > 0:
        out["blocks"] = jax.tree.map(lambda p: p.with_leading(nb), block,
                                     is_leaf=lambda x: isinstance(x, ParamDef))
    else:
        out["blocks_unrolled"] = {f"b{i}": block for i in range(nb)}
    out["tail"] = {f"l{j}": layer_cache_defs(
        cfg, nb * period + j, batch, max_seq, cross_seq)
        for j in range(cfg.n_tail_layers)}
    return out


# ---------------------------------------------------------------------------
# layer / block application
# ---------------------------------------------------------------------------

def _residual_shard(cfg, x):
    if cfg.seq_shard_residual:
        return sharding.shard(x, "batch", "seq_sp", None)
    return sharding.shard(x, "batch", None, None)


def layer_apply(cfg: ModelConfig, i: int, p: dict, x, *, mode: str, pos0,
                cache: Optional[dict], enc_out=None, causal: bool = True):
    kind = cfg.layer_kind(i) if causal else ATTN
    new_cache = dict(cache) if cache else None
    aux = jnp.zeros((), jnp.float32)

    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if kind in (ATTN, ATTN_LOCAL):
        h, ac = attention.attn_apply(cfg, p["attn"], h, kind=kind, mode=mode,
                                     pos0=pos0,
                                     cache=cache.get("attn") if cache else None,
                                     causal=causal)
        if new_cache is not None and ac is not None:
            new_cache["attn"] = ac
    elif kind == MAMBA:
        h, mc = mamba.mamba_apply(cfg, p["mamba"], h, mode=mode,
                                  cache=cache.get("mamba") if cache else None)
        if new_cache is not None and mc is not None:
            new_cache["mamba"] = mc
    elif kind == RWKV:
        rc = cache.get("rwkv") if cache else None
        h, s_new, x_carry = rwkv.rwkv_time_mix(
            cfg, p["tm"], h,
            cache_s=rc["s"] if rc else None,
            cache_x=rc["x_tm"] if rc else None)
        if new_cache is not None:
            new_cache["rwkv"] = dict(new_cache["rwkv"])
            new_cache["rwkv"]["s"] = s_new.astype(rc["s"].dtype)
            new_cache["rwkv"]["x_tm"] = x_carry.astype(rc["x_tm"].dtype)
    if cfg.post_norm:
        h = rms_norm(h, p["ln1_post"], cfg.norm_eps)
    x = _residual_shard(cfg, x + h)

    if enc_out is not None or (cache and "cross" in cache):
        h = rms_norm(x, p["ln_cross"], cfg.norm_eps)
        h, cc = attention.attn_apply(
            cfg, p["cross"], h, kind=ATTN, mode=mode, pos0=pos0,
            cache=cache.get("cross") if cache else None,
            causal=False, kv_source=enc_out, is_cross=True)
        if new_cache is not None and cc is not None:
            new_cache["cross"] = cc
        x = _residual_shard(cfg, x + h)

    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    if kind == RWKV:
        rc = (new_cache or {}).get("rwkv") if new_cache else None
        h, x_carry = rwkv.rwkv_channel_mix(
            cfg, p["cm"], h, cache_x=rc["x_cm"] if rc else None)
        if new_cache is not None:
            new_cache["rwkv"]["x_cm"] = x_carry.astype(rc["x_cm"].dtype)
    elif "moe" in p:
        h, aux = moe.moe_apply(cfg, p["moe"], h, impl=cfg.moe_impl)
    else:
        h = ffn_apply(p["ffn"], h, cfg.act)
    if cfg.post_norm:
        h = rms_norm(h, p["ln2_post"], cfg.norm_eps)
    x = _residual_shard(cfg, x + h)
    return x, new_cache, aux


def block_apply(cfg: ModelConfig, p: dict, x, *, mode, pos0, cache,
                enc_out=None, causal=True, base_layer: int = 0):
    period = len(p)
    aux_tot = jnp.zeros((), jnp.float32)
    new_cache = {} if cache is not None else None
    for j in range(period):
        lp = p[f"l{j}"]
        lc = cache.get(f"l{j}") if cache is not None else None
        x, nc, aux = layer_apply(cfg, base_layer + j, lp, x, mode=mode,
                                 pos0=pos0, cache=lc, enc_out=enc_out,
                                 causal=causal)
        if new_cache is not None:
            new_cache[f"l{j}"] = nc if nc is not None else {}
        aux_tot = aux_tot + aux
    return x, new_cache, aux_tot


# ---------------------------------------------------------------------------
# full forward
# ---------------------------------------------------------------------------

def _run_stack(cfg, params, caches, x, *, mode, pos0, enc_out=None,
               causal=True):
    aux_tot = jnp.zeros((), jnp.float32)
    has_cache = caches is not None
    new_caches = {} if has_cache else None

    def one_block(x, bp, bc, base):
        return block_apply(cfg, bp, x, mode=mode, pos0=pos0, cache=bc,
                           enc_out=enc_out, causal=causal, base_layer=base)

    if "blocks" in params:
        def body(carry, xs):
            x, aux = carry
            bp, bc = xs
            x, nc, a = one_block(x, bp, bc, 0)
            return (x, aux + a), nc

        body_fn = body
        if cfg.remat != "none" and mode == "train":
            policy = (jax.checkpoint_policies.nothing_saveable
                      if cfg.remat == "full"
                      else jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
            body_fn = jax.checkpoint(body, policy=policy)
        bc = caches.get("blocks") if has_cache else None
        if bc is None:
            (x, aux_tot), _ = jax.lax.scan(
                lambda c, bp: (body_fn(c, (bp, None))[0], None),
                (x, aux_tot), params["blocks"])
        else:
            (x, aux_tot), new_bc = jax.lax.scan(
                body_fn, (x, aux_tot), (params["blocks"], bc))
            new_caches["blocks"] = new_bc
    elif "blocks_unrolled" in params:
        for i, (k, bp) in enumerate(sorted(params["blocks_unrolled"].items(),
                                           key=lambda kv: int(kv[0][1:]))):
            bc = caches["blocks_unrolled"][k] if has_cache else None
            x, nc, a = one_block(x, bp, bc, i * cfg.pattern_period)
            if has_cache:
                new_caches.setdefault("blocks_unrolled", {})[k] = nc
            aux_tot = aux_tot + a

    base = cfg.n_scan_blocks * cfg.pattern_period
    for j in range(cfg.n_tail_layers):
        lp = params["tail"][f"l{j}"]
        lc = caches["tail"][f"l{j}"] if has_cache else None
        x, nc, a = layer_apply(cfg, base + j, lp, x, mode=mode, pos0=pos0,
                               cache=lc, enc_out=enc_out, causal=causal)
        if has_cache:
            new_caches.setdefault("tail", {})[f"l{j}"] = nc
        aux_tot = aux_tot + a
    if has_cache and "tail" not in new_caches:
        new_caches["tail"] = {}
    return x, new_caches, aux_tot


def encode(cfg: ModelConfig, params, frames):
    """Encoder stack for enc-dec models. frames [B, S_enc, frontend_dim]."""
    x = jnp.einsum("bsf,fd->bsd", frames.astype(cfg.dtype),
                   params["frontend_proj"].astype(cfg.dtype))
    x = _residual_shard(cfg, x)

    def body(carry, bp):
        x, aux = carry
        x, _, a = block_apply(cfg, bp, x, mode="train", pos0=0, cache=None,
                              causal=False)
        return (x, aux + a), None

    body_fn = body
    if cfg.remat != "none":     # same remat policy as the decoder stack
        body_fn = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    (x, _), _ = jax.lax.scan(body_fn, (x, jnp.zeros((), jnp.float32)),
                             params["enc_blocks"])
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def embed_tokens(cfg, params, tokens):
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
    return (x * np.sqrt(cfg.d_model)).astype(cfg.dtype)


def lm_logits(cfg, params, x):
    w = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = jnp.einsum("bsd,dv->bsv", x, w.astype(x.dtype))
    logits = softcap(logits, cfg.final_logit_softcap)
    return logits


def hidden_forward(cfg: ModelConfig, params, batch: dict, *,
                   mode: str = "train", pos0=0,
                   cache: Optional[dict] = None):
    """Backbone up to (and including) the final norm: returns
    (hidden [B,S,D], new_cache, aux_loss) — the head is applied separately so
    training can use sequence-chunked cross-entropy and prefill can project
    only the last position (§Perf: the full-vocab logits tensor dominated
    prefill/train memory for the 256k-vocab architectures)."""
    tokens = batch["tokens"]
    enc_out = None
    if cfg.n_encoder_layers > 0 and mode != "decode":
        enc_out = encode(cfg, params, batch["frontend"])

    x = embed_tokens(cfg, params, tokens)
    if cfg.frontend != "none" and cfg.n_encoder_layers == 0 and mode != "decode":
        pre = jnp.einsum("bsf,fd->bsd", batch["frontend"].astype(cfg.dtype),
                         params["frontend_proj"].astype(cfg.dtype))
        x = jnp.concatenate([pre, x], axis=1)          # vlm prefix
    x = _residual_shard(cfg, x)

    x, new_cache, aux = _run_stack(cfg, params, cache, x, mode=mode,
                                   pos0=pos0, enc_out=enc_out)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, new_cache, aux


def forward(cfg: ModelConfig, params, batch: dict, *, mode: str = "train",
            pos0=0, cache: Optional[dict] = None):
    """Returns (logits, new_cache, aux_loss).

    batch keys: tokens [B,S_txt]; optional frontend [B,S_f,frontend_dim]
    (vlm prefix or audio encoder input); enc-dec models use 'frontend' as the
    encoder input.
    """
    x, new_cache, aux = hidden_forward(cfg, params, batch, mode=mode,
                                       pos0=pos0, cache=cache)
    if mode == "prefill":
        x = x[:, -1:]              # only the next-token head is needed
    logits = lm_logits(cfg, params, x)
    return logits, new_cache, aux


# ---------------------------------------------------------------------------
# init / spec helpers
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key):
    return materialize(model_defs(cfg), key, jnp.dtype(cfg.param_dtype))


def param_specs(cfg: ModelConfig, mesh=None):
    return specs_of(model_defs(cfg), mesh=mesh)


def abstract_params(cfg: ModelConfig):
    return abstract_of(model_defs(cfg), jnp.dtype(cfg.param_dtype))


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, cross_seq: int = 0):
    return materialize(cache_defs(cfg, batch, max_seq, cross_seq),
                       jax.random.PRNGKey(0), jnp.dtype(cfg.dtype))


def cache_specs(cfg: ModelConfig, batch: int, max_seq: int, cross_seq: int = 0,
                mesh=None):
    return specs_of(cache_defs(cfg, batch, max_seq, cross_seq), mesh=mesh)


def abstract_cache(cfg: ModelConfig, batch: int, max_seq: int,
                   cross_seq: int = 0):
    return abstract_of(cache_defs(cfg, batch, max_seq, cross_seq),
                       jnp.dtype(cfg.dtype))
