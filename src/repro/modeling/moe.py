"""Mixture-of-Experts: top-k router + expert-parallel execution.

Two implementations:
  dense  - one-hot capacity dispatch einsum; exactness oracle, smoke tests
  ep     - shard_map expert parallelism: experts sharded over "model"; every
           device computes, for its local experts, the contribution of all
           locally-replicated tokens via sort+capacity gather and a batched
           [E_loc, C, D] x [E_loc, D, F] matmul, then psum over "model".
           With sequence-sharded residuals the input is all-gathered along
           "model" and the output reduce-scattered back (SP).

Both paths drop tokens beyond per-expert capacity (capacity_factor), like
capacity-based MoE training systems; the router aux (load-balance) loss is
returned so the trainer can regularize toward uniform load.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.distributed import sharding
from repro.modeling.layers import ParamDef


def moe_defs(cfg: ModelConfig) -> dict:
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    defs = {
        "router": ParamDef((d, e), (None, None)),
        "w_up": ParamDef((e, d, f), ("model", "fsdp", None)),
        "w_down": ParamDef((e, f, d), ("model", None, "fsdp")),
    }
    if cfg.act == "swiglu":
        defs["w_gate"] = ParamDef((e, d, f), ("model", "fsdp", None))
    return defs


def _route(cfg: ModelConfig, router_w, x) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x [T, D] -> (expert ids [T,K], gates [T,K], aux loss scalar)."""
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(probs, cfg.n_experts_active)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance aux: E * sum_e f_e * p_e
    e = cfg.n_experts
    f_e = jnp.zeros((e,), jnp.float32).at[ids.reshape(-1)].add(1.0)
    f_e = f_e / jnp.maximum(f_e.sum(), 1.0)
    p_e = probs.mean(axis=0)
    aux = e * jnp.sum(f_e * p_e)
    return ids, gates, aux


def _expert_ffn(p, xs, act: str, e_slice=None):
    """xs [E, C, D] per-expert batches -> [E, C, D]."""
    w_up = p["w_up"] if e_slice is None else p["w_up"][e_slice]
    w_down = p["w_down"] if e_slice is None else p["w_down"][e_slice]
    h = jnp.einsum("ecd,edf->ecf", xs, w_up.astype(xs.dtype))
    if act == "swiglu":
        w_gate = p["w_gate"] if e_slice is None else p["w_gate"][e_slice]
        g = jnp.einsum("ecd,edf->ecf", xs, w_gate.astype(xs.dtype))
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    return jnp.einsum("ecf,efd->ecd", h, w_down.astype(xs.dtype))


def _capacity(cfg: ModelConfig, tokens: int, experts: int) -> int:
    c = int(math.ceil(tokens * cfg.n_experts_active / cfg.n_experts
                      * cfg.capacity_factor))
    return max(c, 1)


# ---------------------------------------------------------------------------
# dense one-hot oracle
# ---------------------------------------------------------------------------

def moe_apply_dense(cfg: ModelConfig, p, x) -> Tuple[jax.Array, jax.Array]:
    B, S, D = x.shape
    T = B * S
    xt = x.reshape(T, D)
    ids, gates, aux = _route(cfg, p["router"], xt)
    E, K = cfg.n_experts, cfg.n_experts_active
    C = _capacity(cfg, T, E)

    # position of each (t, k) assignment within its expert queue
    onehot = jax.nn.one_hot(ids, E, dtype=jnp.int32)               # [T,K,E]
    pos = jnp.cumsum(onehot.reshape(T * K, E), axis=0).reshape(T, K, E) - 1
    pos = (pos * onehot).sum(-1)                                    # [T,K]
    keep = pos < C
    # dispatch tensor [T, E, C]
    disp = jnp.einsum("tke,tkc->tec",
                      jax.nn.one_hot(ids, E, dtype=xt.dtype) * keep[..., None],
                      jax.nn.one_hot(pos, C, dtype=xt.dtype))
    xs = jnp.einsum("tec,td->ecd", disp, xt)
    ys = _expert_ffn(p, xs, cfg.act)
    comb = jnp.einsum("tke,tkc,tk->tec",
                      jax.nn.one_hot(ids, E, dtype=xt.dtype) * keep[..., None],
                      jax.nn.one_hot(pos, C, dtype=xt.dtype),
                      gates.astype(xt.dtype))
    out = jnp.einsum("tec,ecd->td", comb, ys)
    return out.reshape(B, S, D), aux


# ---------------------------------------------------------------------------
# expert-parallel shard_map path
# ---------------------------------------------------------------------------

def moe_apply_ep(cfg: ModelConfig, p, x) -> Tuple[jax.Array, jax.Array]:
    mesh = sharding.current_mesh()
    if mesh is None or "model" not in mesh.shape:
        return moe_apply_dense(cfg, p, x)
    n_shards = mesh.shape["model"]
    E = cfg.n_experts
    assert E % n_shards == 0, f"experts {E} % model axis {n_shards} != 0"
    E_loc = E // n_shards
    # SP only when the sequence actually divides the model axis (decode S=1
    # or odd lengths fall back to replicated-sequence activations)
    sp = cfg.seq_shard_residual and x.shape[1] % n_shards == 0
    dp_total = 1
    for a in ("pod", "data"):
        dp_total *= mesh.shape.get(a, 1)
    if x.shape[0] % max(dp_total, 1) != 0:
        return moe_apply_dense(cfg, p, x)

    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    x_spec = P(batch_axes, "model" if sp else None, None)
    # in_specs MUST match the stored FSDP sharding of the expert weights:
    # declaring them unsharded on the fsdp axes makes XLA all-gather the
    # whole stacked scan weight (hoisted out of the layer scan -> tens of GB
    # of temp for kimi-k2).  Instead we re-gather per layer inside the body,
    # which stays inside the scan and is freed after the layer (§Perf).
    from repro.distributed.sharding import resolve_spec
    w_spec = {}
    gather_axis = {}
    for k, v in p.items():
        if k == "router":
            w_spec[k] = P(None, None)
            continue
        logical = ("model", "fsdp", None) if k in ("w_up", "w_gate") \
            else ("model", None, "fsdp")
        spec = resolve_spec(logical, dims=v.shape, mesh=mesh)
        w_spec[k] = spec
        ax = 1 if k in ("w_up", "w_gate") else 2
        gather_axis[k] = ax if spec[ax] is not None else None

    def body(xs, ps):
        x_loc = xs
        # per-layer weight regather over the fsdp axes (bounded transient)
        ps = {k: (jax.lax.all_gather(v, batch_axes, axis=gather_axis[k],
                                     tiled=True)
                  if gather_axis.get(k) is not None else v)
              for k, v in ps.items()}
        if sp:
            x_loc = jax.lax.all_gather(x_loc, "model", axis=1, tiled=True)
        B, S, D = x_loc.shape
        T = B * S
        xt = x_loc.reshape(T, D)
        ids, gates, aux = _route(cfg, ps["router"], xt)
        # mean over all shards -> replicated scalar (tokens differ per data shard)
        aux = jax.lax.pmean(aux, axis_name=batch_axes + ("model",))
        K = cfg.n_experts_active
        C = _capacity(cfg, T, E)

        shard_id = jax.lax.axis_index("model")
        e_lo = shard_id * E_loc
        flat_e = ids.reshape(-1)                               # [T*K]
        flat_g = gates.reshape(-1)
        local_e = flat_e - e_lo
        is_local = (local_e >= 0) & (local_e < E_loc)
        key = jnp.where(is_local, local_e, E_loc)              # bucket E_loc = drop
        order = jnp.argsort(key, stable=True)                  # [T*K]
        sorted_key = key[order]
        counts = jnp.bincount(key, length=E_loc + 1)
        starts = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                                  jnp.cumsum(counts)[:-1]])
        seg_pos = jnp.arange(T * K) - starts[sorted_key]       # pos within expert
        tok = order // K
        valid = (sorted_key < E_loc) & (seg_pos < C)
        # dispatch buffer [E_loc, C, D]
        dst = jnp.where(valid, sorted_key * C + seg_pos, E_loc * C)
        xs_buf = jnp.zeros((E_loc * C + 1, D), x_loc.dtype).at[dst].set(xt[tok])
        ys = _expert_ffn(ps, xs_buf[:-1].reshape(E_loc, C, D), cfg.act,
                         e_slice=None)
        # combine back, gate-weighted
        y_flat = ys.reshape(E_loc * C, D)
        contrib = jnp.where(valid, flat_g[order], 0.0)[:, None].astype(x_loc.dtype)
        src = jnp.where(valid, dst, 0)
        y_tok = jnp.zeros((T, D), x_loc.dtype).at[tok].add(y_flat[src] * contrib)
        y = y_tok.reshape(B, S, D)
        if sp:
            y = jax.lax.psum_scatter(y, "model", scatter_dimension=1, tiled=True)
        else:
            y = jax.lax.psum(y, "model")
        return y, aux

    y, aux = sharding.shard_map(
        body, mesh=mesh,
        in_specs=(x_spec, w_spec),
        out_specs=(x_spec, P()),
        check_vma=False,
    )(x, p)
    return y, aux


def moe_apply(cfg: ModelConfig, p, x, impl: str) -> Tuple[jax.Array, jax.Array]:
    if impl == "dense":
        return moe_apply_dense(cfg, p, x)
    return moe_apply_ep(cfg, p, x)
