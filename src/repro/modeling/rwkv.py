"""RWKV6 ("Finch") layer: data-dependent-decay linear attention.

Faithful to the RWKV6 formulation:
  token shift  : ddlerp mixing of x_t with x_{t-1} (per-projection deltas from
                 a small 2-layer lora over the shifted difference)
  time mix     : per-channel data-dependent decay w_t = exp(-exp(...)),
                 matrix-valued per-head state  S_t = diag(w_t) S_{t-1} + k_t v_t^T
                 out_t = r_t . (diag(u) k_t v_t^T + S_{t-1}), grouped-norm'd and
                 gated by silu(g_t)
  channel mix  : token-shifted squared-relu FFN with sigmoid receptance gate

The reference path here evaluates the recurrence with a sequential scan
(numerically exact; O(S) steps, O(1) memory per step) — the chunked Pallas
kernel (kernels/wkv6) is the TPU performance path and is validated against
this implementation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed import sharding
from repro.modeling.layers import ParamDef

LORA_MIX = 32
LORA_DECAY = 64


def n_heads(cfg: ModelConfig) -> int:
    return cfg.d_model // cfg.rwkv_head_dim


def rwkv_tm_defs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    h, hd = n_heads(cfg), cfg.rwkv_head_dim
    return {
        "maa_x": ParamDef((d,), (None,), "zeros"),
        "maa_rkvwg": ParamDef((5, d), (None, None), "zeros"),
        "maa_w1": ParamDef((d, 5 * LORA_MIX), ("fsdp", None), "normal", 0.1),
        "maa_w2": ParamDef((5, LORA_MIX, d), (None, None, None), "normal", 0.1),
        "decay": ParamDef((d,), (None,), "ones", -4.0),
        "decay_w1": ParamDef((d, LORA_DECAY), ("fsdp", None), "normal", 0.1),
        "decay_w2": ParamDef((LORA_DECAY, d), (None, None), "normal", 0.1),
        "bonus_u": ParamDef((h, hd), ("model", None), "normal", 0.5),
        "wr": ParamDef((d, d), ("fsdp", "model")),
        "wk": ParamDef((d, d), ("fsdp", "model")),
        "wv": ParamDef((d, d), ("fsdp", "model")),
        "wg": ParamDef((d, d), ("fsdp", "model")),
        "wo": ParamDef((d, d), ("model", "fsdp")),
        "ln_x_scale": ParamDef((d,), (None,), "ones", 1.0),
        "ln_x_bias": ParamDef((d,), (None,), "zeros"),
    }


def rwkv_cm_defs(cfg: ModelConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "maa_k": ParamDef((d,), (None,), "zeros"),
        "maa_r": ParamDef((d,), (None,), "zeros"),
        "wk": ParamDef((d, f), ("fsdp", "model")),
        "wv": ParamDef((f, d), ("model", "fsdp")),
        "wr": ParamDef((d, d), ("fsdp", "model")),
    }


def rwkv_cache_defs(cfg: ModelConfig, batch: int) -> dict:
    h, hd = n_heads(cfg), cfg.rwkv_head_dim
    d = cfg.d_model
    return {
        "s": ParamDef((batch, h, hd, hd), ("batch", "model", None, None), "zeros"),
        "x_tm": ParamDef((batch, d), ("batch", None), "zeros"),
        "x_cm": ParamDef((batch, d), ("batch", None), "zeros"),
    }


def _shift(x, x_prev):
    """x [B,S,D], x_prev [B,D] -> x_{t-1} sequence and the new carry."""
    prev_seq = jnp.concatenate([x_prev[:, None, :], x[:, :-1, :]], axis=1)
    return prev_seq, x[:, -1, :]


def _group_norm(x, scale, bias, h, eps=64e-5):
    """Per-head group norm over [B,S,D] viewed as [B,S,H,hd]."""
    B, S, D = x.shape
    xh = x.reshape(B, S, h, D // h).astype(jnp.float32)
    mu = xh.mean(-1, keepdims=True)
    var = xh.var(-1, keepdims=True)
    xh = (xh - mu) * jax.lax.rsqrt(var + eps)
    return (xh.reshape(B, S, D) * scale + bias).astype(x.dtype)


def rwkv_time_mix(cfg: ModelConfig, p, x, *, cache_s=None, cache_x=None):
    """Returns (out [B,S,D], new_state [B,H,hd,hd], new_x_carry [B,D])."""
    B, S, D = x.shape
    h, hd = n_heads(cfg), cfg.rwkv_head_dim
    x_prev0 = cache_x if cache_x is not None else jnp.zeros((B, D), x.dtype)
    prev, x_carry = _shift(x, x_prev0)
    xx = prev - x

    # ddlerp: data-dependent interpolation deltas for r,k,v,w,g
    xxx = x + xx * p["maa_x"].astype(x.dtype)
    lora = jnp.tanh(jnp.einsum("bsd,dm->bsm", xxx, p["maa_w1"].astype(x.dtype)))
    lora = lora.reshape(B, S, 5, LORA_MIX)
    deltas = jnp.einsum("bsfm,fmd->bsfd", lora, p["maa_w2"].astype(x.dtype))
    mixed = (x[:, :, None, :] + xx[:, :, None, :]
             * (p["maa_rkvwg"].astype(x.dtype)[None, None] + deltas))
    xr, xk, xv, xw, xg = [mixed[:, :, i] for i in range(5)]

    r = jnp.einsum("bsd,de->bse", xr, p["wr"].astype(x.dtype))
    k = jnp.einsum("bsd,de->bse", xk, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,de->bse", xv, p["wv"].astype(x.dtype))
    g = jnp.einsum("bsd,de->bse", xg, p["wg"].astype(x.dtype))
    logw = -jnp.exp(
        (p["decay"].astype(jnp.float32)
         + jnp.einsum("bsm,md->bsd",
                      jnp.tanh(jnp.einsum("bsd,dm->bsm", xw,
                                          p["decay_w1"].astype(x.dtype))),
                      p["decay_w2"].astype(x.dtype)).astype(jnp.float32)))
    w = jnp.exp(logw)                                              # [B,S,D] in (0,1)

    rh = r.reshape(B, S, h, hd).astype(jnp.float32)
    kh = k.reshape(B, S, h, hd).astype(jnp.float32)
    vh = v.reshape(B, S, h, hd).astype(jnp.float32)
    wh = w.reshape(B, S, h, hd)
    u = p["bonus_u"].astype(jnp.float32)

    s0 = (cache_s.astype(jnp.float32) if cache_s is not None
          else jnp.zeros((B, h, hd, hd), jnp.float32))

    if S >= 32 and S % 16 == 0:
        # chunked linear-attention form (mirrors the Pallas wkv6 kernel):
        # O(S/C) scan steps instead of O(S) -> bounded backward-pass memory
        from repro.kernels.ref import wkv6_chunked_ref
        y, s_end = wkv6_chunked_ref(rh, kh, vh, wh, u, s0, chunk=16)
        y = y.reshape(B, S, D)
    else:
        def step(s, inp):
            r_t, k_t, v_t, w_t = inp                               # [B,h,hd]
            kv = k_t[..., :, None] * v_t[..., None, :]             # [B,h,hd,hd]
            out = jnp.einsum("bhk,bhkv->bhv", r_t,
                             s + u[None, :, :, None] * kv)
            s = w_t[..., :, None] * s + kv
            return s, out

        xs = (rh.transpose(1, 0, 2, 3), kh.transpose(1, 0, 2, 3),
              vh.transpose(1, 0, 2, 3), wh.transpose(1, 0, 2, 3))
        s_end, outs = jax.lax.scan(step, s0, xs)
        y = outs.transpose(1, 0, 2, 3).reshape(B, S, D)
    y = _group_norm(y, p["ln_x_scale"].astype(jnp.float32),
                    p["ln_x_bias"].astype(jnp.float32), h)
    y = (y * jax.nn.silu(g).astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", y, p["wo"].astype(x.dtype))
    return out, s_end, x_carry


def rwkv_channel_mix(cfg: ModelConfig, p, x, *, cache_x=None):
    B, S, D = x.shape
    x_prev0 = cache_x if cache_x is not None else jnp.zeros((B, D), x.dtype)
    prev, x_carry = _shift(x, x_prev0)
    xx = prev - x
    xk = x + xx * p["maa_k"].astype(x.dtype)
    xr = x + xx * p["maa_r"].astype(x.dtype)
    kk = jnp.einsum("bsd,df->bsf", xk, p["wk"].astype(x.dtype))
    kk = jnp.square(jax.nn.relu(kk))
    kk = sharding.shard(kk, "batch", None, "model")
    vv = jnp.einsum("bsf,fd->bsd", kk, p["wv"].astype(x.dtype))
    rr = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["wr"].astype(x.dtype)))
    return rr * vv, x_carry
