"""Serving front-ends: model-serving steps (serve_step) and the async
cluster-configuration service (config_service)."""
