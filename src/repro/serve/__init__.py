"""Serving front-ends: model-serving steps (serve_step), the async
micro-batched cluster-configuration service (config_service), the
socket-level HTTP/ASGI edge for Hub Gateway API v1 (edge), and the
closed-loop load generator that drives it (loadgen)."""
