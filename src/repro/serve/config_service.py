"""Async front-end for the configuration service: micro-batched serving.

Concurrent ``choose`` calls land on an asyncio queue; a single worker task
drains everything pending each tick and answers the whole batch with ONE
``ConfigurationService.choose_cluster_batch`` dispatch (one engine call for
the full machine x scale-out x context grid).  Per-request deadlines are
packed into a [C] array with NaN for "no deadline", which the service
resolves per context — heterogeneous requests still share a dispatch.

Usage:

    svc = ConfigurationService(...)
    async with AsyncConfigService(svc) as front:
        choice = await front.choose(ctx, t_max=400.0)

Throughput is measured by the ``serve`` benchmark lane
(``python -m benchmarks.run --only serve``), which reports requests/s and
the realized mean micro-batch size.
"""
from __future__ import annotations

import asyncio
import math
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.core.configurator import ClusterChoice
from repro.core.service import ConfigurationService


@dataclass
class ServeStats:
    requests: int = 0
    batches: int = 0
    batch_sizes: list = field(default_factory=list)

    @property
    def mean_batch(self) -> float:
        return float(np.mean(self.batch_sizes)) if self.batch_sizes else 0.0


class AsyncConfigService:
    """Micro-batching wrapper around a ``ConfigurationService``.

    ``max_batch`` caps one dispatch's batch; ``tick_s`` is an optional
    accumulation window after the first request of a batch arrives (0 means
    "drain whatever is already queued", which keeps p50 latency at one
    dispatch while still coalescing concurrent arrivals)."""

    def __init__(self, service: ConfigurationService, *,
                 max_batch: int = 256, tick_s: float = 0.0):
        self.service = service
        self.max_batch = max_batch
        self.tick_s = tick_s
        self.stats = ServeStats()
        self._queue: asyncio.Queue = asyncio.Queue()
        self._worker: Optional[asyncio.Task] = None

    # ------------------------- lifecycle ----------------------------------
    async def __aenter__(self) -> "AsyncConfigService":
        self.start()
        return self

    async def __aexit__(self, *exc) -> None:
        await self.stop()

    def start(self) -> None:
        if self._worker is None:
            self._worker = asyncio.get_running_loop().create_task(self._run())

    async def stop(self) -> None:
        if self._worker is not None:
            self._worker.cancel()
            try:
                await self._worker
            except asyncio.CancelledError:
                pass
            self._worker = None
        # fail anything still enqueued so no choose() caller hangs forever
        while True:
            try:
                _, _, fut = self._queue.get_nowait()
            except asyncio.QueueEmpty:
                break
            if not fut.done():
                fut.cancel()

    # ------------------------- request path -------------------------------
    async def choose(self, context_row: np.ndarray,
                     t_max: Optional[float] = None) -> ClusterChoice:
        """Awaitable single request; answered as part of the next batch."""
        fut = asyncio.get_running_loop().create_future()
        await self._queue.put((np.asarray(context_row, np.float64),
                               math.nan if t_max is None else float(t_max),
                               fut))
        return await fut

    # ------------------------- worker loop --------------------------------
    async def _run(self) -> None:
        batch = []
        try:
            while True:
                batch = [await self._queue.get()]
                if self.tick_s > 0:
                    await asyncio.sleep(self.tick_s)   # accumulation window
                while len(batch) < self.max_batch:
                    try:
                        batch.append(self._queue.get_nowait())
                    except asyncio.QueueEmpty:
                        break
                # pack the micro-batch columnar: one [C, k] context block +
                # one [C] deadline vector, written into fresh arrays the
                # service consumes without further copies
                contexts = np.empty((len(batch), len(batch[0][0])),
                                    np.float64)
                t_max = np.empty(len(batch), np.float64)
                for i, (ctx, tm, _) in enumerate(batch):
                    contexts[i] = ctx
                    t_max[i] = tm
                try:
                    choices = self.service.choose_cluster_batch(contexts,
                                                                t_max)
                except Exception as e:               # fan the failure out
                    for _, _, fut in batch:
                        if not fut.done():
                            fut.set_exception(e)
                    batch = []
                    continue
                self.stats.requests += len(batch)
                self.stats.batches += 1
                self.stats.batch_sizes.append(len(batch))
                for (_, _, fut), choice in zip(batch, choices):
                    if not fut.done():
                        fut.set_result(choice)
                batch = []
        finally:
            for _, _, fut in batch:  # cancelled mid-batch: don't strand them
                if not fut.done():
                    fut.cancel()
