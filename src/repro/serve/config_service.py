"""Asyncio micro-batch lanes for configuration serving.

``BatchLane`` is the generic building block: concurrent ``submit`` calls
land on an asyncio queue; a single worker task drains everything pending
each tick and answers the whole batch with ONE batched dispatch.
Per-request deadlines are packed into a [C] array with NaN for "no
deadline", which the dispatch resolves per context — heterogeneous
requests still share a dispatch.  The gateway (``repro.api.gateway``)
runs one lane per job, so concurrent requests for different jobs coalesce
into one engine dispatch *per job per tick*.

``AsyncConfigService`` is the legacy single-service front-end, now a thin
shim over one ``BatchLane``:

    svc = ConfigurationService(...)
    async with AsyncConfigService(svc) as front:
        choice = await front.choose(ctx, t_max=400.0)

Throughput is measured by the ``serve`` benchmark lane
(``python -m benchmarks.run --only serve``) and the multi-job ``gateway``
lane, which report requests/s and the realized mean micro-batch size.
"""
from __future__ import annotations

import asyncio
import math
import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional

import numpy as np

from repro.core.configurator import ClusterChoice
from repro.core.service import ConfigurationService


class LaneTimeoutError(Exception):
    """A micro-batch dispatch missed the lane's per-request deadline.

    Raised INTO the affected submit() futures only — the worker itself
    survives and keeps serving later ticks (the gateway maps this to the
    typed ``timeout`` error envelope)."""


class LatencyReservoir:
    """Fixed-capacity ring buffer of latency observations (seconds).

    A serving lane records one sample per dispatched request for the
    process lifetime, so the store must stay O(capacity), never
    O(requests): the buffer is allocated ONCE and old samples are
    overwritten in ring order — percentiles answer over the most recent
    ``capacity`` observations (a sliding window, which is also what an
    operator wants from ``/stats``: current tail latency, not the cold
    compile spikes from an hour ago)."""

    __slots__ = ("capacity", "_buf", "_count")

    def __init__(self, capacity: int = 4096):
        if capacity <= 0:
            raise ValueError(f"capacity must be positive, got {capacity}")
        self.capacity = int(capacity)
        self._buf = np.empty(self.capacity, np.float64)
        self._count = 0                   # lifetime observations

    def __len__(self) -> int:
        """Live samples in the window (never exceeds ``capacity``)."""
        return min(self._count, self.capacity)

    @property
    def total(self) -> int:
        """Lifetime observation count (the window holds the last
        ``capacity`` of these)."""
        return self._count

    def record(self, seconds: float) -> None:
        self._buf[self._count % self.capacity] = seconds
        self._count += 1

    def percentile(self, p: float) -> float:
        """Nearest-rank percentile (``p`` in [0, 100]) over the live
        window, in seconds; NaN while empty."""
        n = len(self)
        if n == 0:
            return math.nan
        k = min(n - 1, max(0, math.ceil(p / 100.0 * n) - 1))
        return float(np.partition(self._buf[:n], k)[k])


@dataclass
class ServeStats:
    """Bounded serving counters: the mean batch size is exact as
    requests-over-batches instead of an ever-growing per-batch list (a
    lane on hub traffic would otherwise leak one list entry per tick,
    forever).  ``requests`` counts DISPATCHED requests only — enqueue-
    rejected submissions never reach a batch.  ``latency`` is a bounded
    ring-buffer reservoir of per-request latencies (enqueue to answer),
    so p50/p95/p99 come from the server side without unbounded lists."""
    requests: int = 0
    batches: int = 0
    latency: LatencyReservoir = field(default_factory=LatencyReservoir)

    def record_batch(self, size: int) -> None:
        self.requests += size
        self.batches += 1

    def record_latency(self, seconds: float) -> None:
        self.latency.record(float(seconds))

    def percentile(self, p: float) -> float:
        """Nearest-rank latency percentile in seconds (NaN until a
        request has been answered)."""
        return self.latency.percentile(p)

    @property
    def p50(self) -> float:
        return self.percentile(50.0)

    @property
    def p95(self) -> float:
        return self.percentile(95.0)

    @property
    def p99(self) -> float:
        return self.percentile(99.0)

    @property
    def mean_batch(self) -> float:
        return self.requests / self.batches if self.batches else 0.0


class BatchLane:
    """Micro-batching worker over a batched dispatch function.

    ``dispatch(contexts [C, k], t_max [C]) -> sequence of per-row results``
    is called once per tick with everything queued.  ``max_batch`` caps one
    dispatch's batch; ``tick_s`` is an optional accumulation window after
    the first request of a batch arrives (0 means "drain whatever is
    already queued", which keeps p50 latency at one dispatch while still
    coalescing concurrent arrivals).

    ``width`` pins the context-row width when the caller knows it (the
    gateway pins from the job schema): submissions are then validated at
    enqueue time, so a request whose width disagrees fails ALONE with
    ``ValueError`` instead of poisoning the micro-batch it would have
    been packed with (the batch pack allocates ``[C, width]``; one stray
    row used to raise there and fan the failure out to every concurrent
    caller — and kill the worker).  With ``width=None`` there is no
    authoritative width, so each tick's batch is packed and dispatched
    PER WIDTH GROUP: a stray-width request reaches the dispatch on its
    own and collects its own outcome, never another group's — a
    malformed first arrival cannot wedge the lane for every later
    well-formed request.

    ``timeout_s`` (None = unbounded, the default) is a per-dispatch
    deadline: the group's dispatch runs on the loop's executor under
    ``asyncio.wait_for``, and on expiry the group's futures fail with
    ``LaneTimeoutError`` while the worker moves on to the next tick — a
    wedged dispatch costs its own callers a typed ``timeout`` envelope,
    not the lane.
    """

    def __init__(self, dispatch: Callable, *, width: Optional[int] = None,
                 max_batch: int = 256, tick_s: float = 0.0,
                 timeout_s: Optional[float] = None):
        self.dispatch = dispatch
        self.width = width
        self.max_batch = max_batch
        self.tick_s = tick_s
        self.timeout_s = timeout_s
        self.stats = ServeStats()
        self._queue: asyncio.Queue = asyncio.Queue()
        self._worker: Optional[asyncio.Task] = None

    # ------------------------- lifecycle ----------------------------------
    def start(self) -> None:
        if self._worker is None:
            self._worker = asyncio.get_running_loop().create_task(self._run())

    async def stop(self) -> None:
        if self._worker is not None:
            self._worker.cancel()
            try:
                await self._worker
            except asyncio.CancelledError:
                pass
            self._worker = None
        # fail anything still enqueued so no submit() caller hangs forever
        while True:
            try:
                _, _, fut, _ = self._queue.get_nowait()
            except asyncio.QueueEmpty:
                break
            if not fut.done():
                fut.cancel()

    # ------------------------- request path -------------------------------
    async def submit(self, context_row,
                     t_max: Optional[float] = None):
        """Awaitable single request; answered as part of the next batch.

        ``context_row`` may be a flat tuple (gateway envelopes) or an
        ndarray.  Content is validated HERE: every enqueued row is
        float-convertible, so the worker's batch pack cannot raise on one
        request's payload — a malformed request fails its own caller at
        enqueue, never its batch."""
        ctx = tuple(map(float, context_row)) if type(context_row) is tuple \
            else np.asarray(context_row, np.float64).reshape(-1)
        if self.width is not None and len(ctx) != self.width:
            raise ValueError(
                f"context row has width {len(ctx)}, lane expects "
                f"{self.width}: request rejected at enqueue (malformed "
                "requests must not poison the shared micro-batch)")
        fut = asyncio.get_running_loop().create_future()
        await self._queue.put(
            (ctx, math.nan if t_max is None else float(t_max), fut,
             time.monotonic()))
        return await fut

    # ------------------------- worker loop --------------------------------
    async def _run(self) -> None:
        batch = []
        try:
            while True:
                batch = [await self._queue.get()]
                if self.tick_s > 0:
                    await asyncio.sleep(self.tick_s)   # accumulation window
                while len(batch) < self.max_batch:
                    try:
                        batch.append(self._queue.get_nowait())
                    except asyncio.QueueEmpty:
                        break
                # pack per width group (normally exactly one group: pinned
                # lanes enqueue-validate, unpinned lanes see one width in
                # practice), each group columnar — one [C, k] context
                # block + one [C] deadline vector the dispatch consumes
                # without further copies.  A failing group fans its error
                # to ITS requests only.
                groups: dict = {}
                for entry in batch:
                    groups.setdefault(len(entry[0]), []).append(entry)
                for group in groups.values():
                    try:
                        # the pack itself can raise (non-numeric content in
                        # a width-correct tuple): that failure belongs to
                        # this group's callers, not the worker — the lane
                        # must survive any single bad payload
                        contexts = np.empty((len(group), len(group[0][0])),
                                            np.float64)
                        t_max = np.empty(len(group), np.float64)
                        for i, (ctx, tm, _, _) in enumerate(group):
                            contexts[i] = ctx
                            t_max[i] = tm
                        results = await self._dispatch_group(contexts, t_max)
                    except (asyncio.TimeoutError, TimeoutError):
                        # deadline missed: fail THIS group with the typed
                        # lane error and keep serving — the dispatch thread
                        # finishes on the executor in the background, its
                        # result discarded (the futures are already failed)
                        err = LaneTimeoutError(
                            f"micro-batch dispatch exceeded its "
                            f"{self.timeout_s:g}s deadline "
                            f"({len(group)} request(s) affected)")
                        for _, _, fut, _ in group:
                            if not fut.done():
                                fut.set_exception(err)
                        continue
                    except Exception as e:           # fan the failure out
                        for _, _, fut, _ in group:
                            if not fut.done():
                                fut.set_exception(e)
                        continue
                    self.stats.record_batch(len(group))
                    now = time.monotonic()
                    for (_, _, fut, t0), result in zip(group, results):
                        # per-request latency: enqueue to answer, into the
                        # bounded reservoir (dispatched requests only,
                        # like the request counter)
                        self.stats.record_latency(now - t0)
                        if not fut.done():
                            fut.set_result(result)
                batch = []
        finally:
            for _, _, fut, _ in batch:  # cancelled mid-batch: don't strand
                if not fut.done():
                    fut.cancel()

    async def _dispatch_group(self, contexts, t_max):
        """One group's dispatch, under the lane deadline if configured.

        Without ``timeout_s`` the dispatch runs inline on the event loop
        (byte-for-byte the historical path); with it, the dispatch runs on
        the default executor so ``wait_for`` can abandon it at the
        deadline without blocking the loop."""
        if self.timeout_s is None:
            return self.dispatch(contexts, t_max)
        loop = asyncio.get_running_loop()
        return await asyncio.wait_for(
            loop.run_in_executor(None, self.dispatch, contexts, t_max),
            self.timeout_s)


class AsyncConfigService:
    """Micro-batching wrapper around ONE ``ConfigurationService``.

    Deprecated entry point: this is now a thin shim over ``BatchLane`` —
    new code should route through ``repro.api.gateway.AsyncHubGateway``,
    which runs one lane per published job behind the typed request
    envelopes and serves identical choices (parity pinned in
    ``tests/test_api_gateway.py``)."""

    def __init__(self, service: ConfigurationService, *,
                 max_batch: int = 256, tick_s: float = 0.0,
                 width: Optional[int] = None,
                 timeout_s: Optional[float] = None):
        self.service = service
        # width: the expected context-row width, when the caller knows it
        # (rejects malformed requests at enqueue; see BatchLane)
        self._lane = BatchLane(service.choose_cluster_batch, width=width,
                               max_batch=max_batch, tick_s=tick_s,
                               timeout_s=timeout_s)

    @property
    def stats(self) -> ServeStats:
        return self._lane.stats

    # ------------------------- lifecycle ----------------------------------
    async def __aenter__(self) -> "AsyncConfigService":
        self.start()
        return self

    async def __aexit__(self, *exc) -> None:
        await self.stop()

    def start(self) -> None:
        self._lane.start()

    async def stop(self) -> None:
        await self._lane.stop()

    # ------------------------- request path -------------------------------
    async def choose(self, context_row: np.ndarray,
                     t_max: Optional[float] = None) -> ClusterChoice:
        """Awaitable single request; answered as part of the next batch."""
        return await self._lane.submit(context_row, t_max)


__all__: List[str] = ["ServeStats", "LatencyReservoir", "BatchLane",
                      "AsyncConfigService", "LaneTimeoutError"]
