"""Asyncio micro-batch lanes for configuration serving.

``BatchLane`` is the generic building block: concurrent ``submit`` calls
land on an asyncio queue; a single worker task drains everything pending
each tick and answers the whole batch with ONE batched dispatch.
Per-request deadlines are packed into a [C] array with NaN for "no
deadline", which the dispatch resolves per context — heterogeneous
requests still share a dispatch.  The gateway (``repro.api.gateway``)
runs one lane per job, so concurrent requests for different jobs coalesce
into one engine dispatch *per job per tick*.

``AsyncConfigService`` is the legacy single-service front-end, now a thin
shim over one ``BatchLane``:

    svc = ConfigurationService(...)
    async with AsyncConfigService(svc) as front:
        choice = await front.choose(ctx, t_max=400.0)

Throughput is measured by the ``serve`` benchmark lane
(``python -m benchmarks.run --only serve``) and the multi-job ``gateway``
lane, which report requests/s and the realized mean micro-batch size.
"""
from __future__ import annotations

import asyncio
import math
from dataclasses import dataclass
from typing import Callable, List, Optional

import numpy as np

from repro.core.configurator import ClusterChoice
from repro.core.service import ConfigurationService


class LaneTimeoutError(Exception):
    """A micro-batch dispatch missed the lane's per-request deadline.

    Raised INTO the affected submit() futures only — the worker itself
    survives and keeps serving later ticks (the gateway maps this to the
    typed ``timeout`` error envelope)."""


@dataclass
class ServeStats:
    """Bounded serving counters: the mean batch size is exact as
    requests-over-batches instead of an ever-growing per-batch list (a
    lane on hub traffic would otherwise leak one list entry per tick,
    forever).  ``requests`` counts DISPATCHED requests only — enqueue-
    rejected submissions never reach a batch."""
    requests: int = 0
    batches: int = 0

    def record_batch(self, size: int) -> None:
        self.requests += size
        self.batches += 1

    @property
    def mean_batch(self) -> float:
        return self.requests / self.batches if self.batches else 0.0


class BatchLane:
    """Micro-batching worker over a batched dispatch function.

    ``dispatch(contexts [C, k], t_max [C]) -> sequence of per-row results``
    is called once per tick with everything queued.  ``max_batch`` caps one
    dispatch's batch; ``tick_s`` is an optional accumulation window after
    the first request of a batch arrives (0 means "drain whatever is
    already queued", which keeps p50 latency at one dispatch while still
    coalescing concurrent arrivals).

    ``width`` pins the context-row width when the caller knows it (the
    gateway pins from the job schema): submissions are then validated at
    enqueue time, so a request whose width disagrees fails ALONE with
    ``ValueError`` instead of poisoning the micro-batch it would have
    been packed with (the batch pack allocates ``[C, width]``; one stray
    row used to raise there and fan the failure out to every concurrent
    caller — and kill the worker).  With ``width=None`` there is no
    authoritative width, so each tick's batch is packed and dispatched
    PER WIDTH GROUP: a stray-width request reaches the dispatch on its
    own and collects its own outcome, never another group's — a
    malformed first arrival cannot wedge the lane for every later
    well-formed request.

    ``timeout_s`` (None = unbounded, the default) is a per-dispatch
    deadline: the group's dispatch runs on the loop's executor under
    ``asyncio.wait_for``, and on expiry the group's futures fail with
    ``LaneTimeoutError`` while the worker moves on to the next tick — a
    wedged dispatch costs its own callers a typed ``timeout`` envelope,
    not the lane.
    """

    def __init__(self, dispatch: Callable, *, width: Optional[int] = None,
                 max_batch: int = 256, tick_s: float = 0.0,
                 timeout_s: Optional[float] = None):
        self.dispatch = dispatch
        self.width = width
        self.max_batch = max_batch
        self.tick_s = tick_s
        self.timeout_s = timeout_s
        self.stats = ServeStats()
        self._queue: asyncio.Queue = asyncio.Queue()
        self._worker: Optional[asyncio.Task] = None

    # ------------------------- lifecycle ----------------------------------
    def start(self) -> None:
        if self._worker is None:
            self._worker = asyncio.get_running_loop().create_task(self._run())

    async def stop(self) -> None:
        if self._worker is not None:
            self._worker.cancel()
            try:
                await self._worker
            except asyncio.CancelledError:
                pass
            self._worker = None
        # fail anything still enqueued so no submit() caller hangs forever
        while True:
            try:
                _, _, fut = self._queue.get_nowait()
            except asyncio.QueueEmpty:
                break
            if not fut.done():
                fut.cancel()

    # ------------------------- request path -------------------------------
    async def submit(self, context_row,
                     t_max: Optional[float] = None):
        """Awaitable single request; answered as part of the next batch.

        ``context_row`` may be a flat tuple (gateway envelopes) or an
        ndarray.  Content is validated HERE: every enqueued row is
        float-convertible, so the worker's batch pack cannot raise on one
        request's payload — a malformed request fails its own caller at
        enqueue, never its batch."""
        ctx = tuple(map(float, context_row)) if type(context_row) is tuple \
            else np.asarray(context_row, np.float64).reshape(-1)
        if self.width is not None and len(ctx) != self.width:
            raise ValueError(
                f"context row has width {len(ctx)}, lane expects "
                f"{self.width}: request rejected at enqueue (malformed "
                "requests must not poison the shared micro-batch)")
        fut = asyncio.get_running_loop().create_future()
        await self._queue.put(
            (ctx, math.nan if t_max is None else float(t_max), fut))
        return await fut

    # ------------------------- worker loop --------------------------------
    async def _run(self) -> None:
        batch = []
        try:
            while True:
                batch = [await self._queue.get()]
                if self.tick_s > 0:
                    await asyncio.sleep(self.tick_s)   # accumulation window
                while len(batch) < self.max_batch:
                    try:
                        batch.append(self._queue.get_nowait())
                    except asyncio.QueueEmpty:
                        break
                # pack per width group (normally exactly one group: pinned
                # lanes enqueue-validate, unpinned lanes see one width in
                # practice), each group columnar — one [C, k] context
                # block + one [C] deadline vector the dispatch consumes
                # without further copies.  A failing group fans its error
                # to ITS requests only.
                groups: dict = {}
                for entry in batch:
                    groups.setdefault(len(entry[0]), []).append(entry)
                for group in groups.values():
                    try:
                        # the pack itself can raise (non-numeric content in
                        # a width-correct tuple): that failure belongs to
                        # this group's callers, not the worker — the lane
                        # must survive any single bad payload
                        contexts = np.empty((len(group), len(group[0][0])),
                                            np.float64)
                        t_max = np.empty(len(group), np.float64)
                        for i, (ctx, tm, _) in enumerate(group):
                            contexts[i] = ctx
                            t_max[i] = tm
                        results = await self._dispatch_group(contexts, t_max)
                    except (asyncio.TimeoutError, TimeoutError):
                        # deadline missed: fail THIS group with the typed
                        # lane error and keep serving — the dispatch thread
                        # finishes on the executor in the background, its
                        # result discarded (the futures are already failed)
                        err = LaneTimeoutError(
                            f"micro-batch dispatch exceeded its "
                            f"{self.timeout_s:g}s deadline "
                            f"({len(group)} request(s) affected)")
                        for _, _, fut in group:
                            if not fut.done():
                                fut.set_exception(err)
                        continue
                    except Exception as e:           # fan the failure out
                        for _, _, fut in group:
                            if not fut.done():
                                fut.set_exception(e)
                        continue
                    self.stats.record_batch(len(group))
                    for (_, _, fut), result in zip(group, results):
                        if not fut.done():
                            fut.set_result(result)
                batch = []
        finally:
            for _, _, fut in batch:  # cancelled mid-batch: don't strand them
                if not fut.done():
                    fut.cancel()

    async def _dispatch_group(self, contexts, t_max):
        """One group's dispatch, under the lane deadline if configured.

        Without ``timeout_s`` the dispatch runs inline on the event loop
        (byte-for-byte the historical path); with it, the dispatch runs on
        the default executor so ``wait_for`` can abandon it at the
        deadline without blocking the loop."""
        if self.timeout_s is None:
            return self.dispatch(contexts, t_max)
        loop = asyncio.get_running_loop()
        return await asyncio.wait_for(
            loop.run_in_executor(None, self.dispatch, contexts, t_max),
            self.timeout_s)


class AsyncConfigService:
    """Micro-batching wrapper around ONE ``ConfigurationService``.

    Deprecated entry point: this is now a thin shim over ``BatchLane`` —
    new code should route through ``repro.api.gateway.AsyncHubGateway``,
    which runs one lane per published job behind the typed request
    envelopes and serves identical choices (parity pinned in
    ``tests/test_api_gateway.py``)."""

    def __init__(self, service: ConfigurationService, *,
                 max_batch: int = 256, tick_s: float = 0.0,
                 width: Optional[int] = None,
                 timeout_s: Optional[float] = None):
        self.service = service
        # width: the expected context-row width, when the caller knows it
        # (rejects malformed requests at enqueue; see BatchLane)
        self._lane = BatchLane(service.choose_cluster_batch, width=width,
                               max_batch=max_batch, tick_s=tick_s,
                               timeout_s=timeout_s)

    @property
    def stats(self) -> ServeStats:
        return self._lane.stats

    # ------------------------- lifecycle ----------------------------------
    async def __aenter__(self) -> "AsyncConfigService":
        self.start()
        return self

    async def __aexit__(self, *exc) -> None:
        await self.stop()

    def start(self) -> None:
        self._lane.start()

    async def stop(self) -> None:
        await self._lane.stop()

    # ------------------------- request path -------------------------------
    async def choose(self, context_row: np.ndarray,
                     t_max: Optional[float] = None) -> ClusterChoice:
        """Awaitable single request; answered as part of the next batch."""
        return await self._lane.submit(context_row, t_max)


__all__: List[str] = ["ServeStats", "BatchLane", "AsyncConfigService",
                      "LaneTimeoutError"]
