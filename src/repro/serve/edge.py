"""Socket-level serving edge: an ASGI front-end for the Hub Gateway.

``HubEdgeApp`` is a dependency-light ASGI 3.0 callable (it runs under
uvicorn unchanged, no framework required) that maps HTTP bodies through
the strict-JSON wire codec (``repro.api.codec``) into ``AsyncHubGateway``
operations:

    POST /v1/predict       PredictRequest   -> PredictResult
    POST /v1/choose        ChooseRequest    -> ChooseResult
    POST /v1/contribute    ContributeRequest -> ContributeResult
    POST /v1/model_errors  ModelErrorsRequest -> ModelErrorsResult
    POST /v1/search        SearchRequest    -> SearchResult
    POST /v1/trust_state   TrustStateRequest -> TrustStateResult
    POST /v1/compact       CompactRequest   -> CompactResult
    POST /v1               any of the above (routes on "__type__")
    GET  /healthz          -> HealthResult
    GET  /stats            -> StatsResult

Every HTTP response body is a codec-encoded ``Response`` envelope —
malformed JSON, unknown ops, oversized bodies, auth refusals, and even
internal faults come back as TYPED error envelopes with a mapped HTTP
status, never a raw 500 page.  Requests wrapped in ``AuthedRequest``
carry bearer tokens exactly as in-process.  Single-row predict and
choose requests coalesce on the gateway's per-(job, machine) /
per-(job) micro-batch lanes, so socket concurrency turns into batched
engine dispatches.

``EdgeServer`` is the bundled minimal asyncio HTTP/1.1 host (keep-alive,
content-length framing) so the edge binds a REAL socket in environments
without uvicorn — the closed-loop load generator
(``repro.serve.loadgen``) and the ``edge`` benchmark lane drive it over
localhost.  Shutdown drains: in-flight requests (including in-flight
lane dispatches) finish, new requests answer a typed ``shutting_down``
envelope, and only then are the gateway lanes stopped.

Quickstart (demo hub with emulated Spark jobs):

    PYTHONPATH=src python -m repro.serve.edge --port 8787
    curl -s localhost:8787/healthz
    curl -s -X POST localhost:8787/v1/choose -d '{"__type__":
      "ChooseRequest","job":"grep","context":[15.0,0.02],"t_max":400.0}'
"""
from __future__ import annotations

import argparse
import asyncio
import math
import time
from typing import Dict, Optional, Tuple

from repro.api import codec
from repro.api.gateway import AsyncHubGateway
from repro.api.types import (API_VERSION, ERR_BAD_REQUEST, ERR_INTERNAL,
                             ERR_QUOTA_EXCEEDED, ERR_SHUTTING_DOWN,
                             ERR_TIMEOUT, ERR_UNAUTHORIZED, ERR_UNKNOWN_JOB,
                             AuthedRequest, ChooseRequest, CompactRequest,
                             ContributeRequest, HealthResult, LaneSnapshot,
                             ModelErrorsRequest, PredictRequest, Response,
                             SearchRequest, StatsResult, TrustStateRequest)
from repro.serve.config_service import ServeStats

#: request-envelope type expected by each POST /v1/<op> endpoint
OPS: Dict[str, type] = {
    "predict": PredictRequest,
    "choose": ChooseRequest,
    "contribute": ContributeRequest,
    "model_errors": ModelErrorsRequest,
    "search": SearchRequest,
    "trust_state": TrustStateRequest,
    "compact": CompactRequest,
}

#: HTTP status for each typed error code (ok envelopes are 200); the
#: body is ALWAYS a codec-encoded Response — the status is advisory for
#: generic HTTP tooling, the envelope is the contract
STATUS_FOR_ERROR: Dict[str, int] = {
    ERR_BAD_REQUEST: 400,
    ERR_UNAUTHORIZED: 403,
    ERR_UNKNOWN_JOB: 404,
    ERR_QUOTA_EXCEEDED: 429,
    ERR_INTERNAL: 500,
    ERR_SHUTTING_DOWN: 503,
    ERR_TIMEOUT: 504,
}

_REASONS = {200: "OK", 400: "Bad Request", 403: "Forbidden",
            404: "Not Found", 405: "Method Not Allowed",
            413: "Payload Too Large", 429: "Too Many Requests",
            431: "Request Header Fields Too Large",
            500: "Internal Server Error", 503: "Service Unavailable",
            504: "Gateway Timeout"}


def _ms(seconds: float) -> float:
    return seconds * 1e3 if math.isfinite(seconds) else seconds


class HubEdgeApp:
    """ASGI app serving an ``AsyncHubGateway`` over HTTP.

    ``max_body`` caps the request body (bytes); anything larger answers
    a typed ``bad_request`` envelope with HTTP 413 before the gateway is
    touched.  HTTP-level latency (receive to response) lands in a
    bounded ``ServeStats`` reservoir served back on ``GET /stats``
    alongside every micro-batch lane's snapshot."""

    def __init__(self, gateway: AsyncHubGateway, *,
                 max_body: int = 1 << 20):
        self.gateway = gateway
        self.max_body = int(max_body)
        self.stats = ServeStats()
        self.errors = 0                    # responses with error envelopes
        self.in_flight = 0
        self.draining = False

    # ------------------------- ASGI entry ---------------------------------
    async def __call__(self, scope, receive, send) -> None:
        if scope["type"] == "lifespan":
            await self._lifespan(receive, send)
            return
        if scope["type"] != "http":        # pragma: no cover - ws etc.
            raise RuntimeError(f"unsupported ASGI scope {scope['type']!r}")
        t0 = time.monotonic()
        self.in_flight += 1
        try:
            try:
                status, resp = await self._handle(scope, receive)
            except asyncio.CancelledError:
                raise
            except Exception as e:         # noqa: BLE001 — never a raw 500
                status, resp = 500, Response.failure(
                    ERR_INTERNAL, f"{type(e).__name__}: {e}")
            if not resp.ok:
                self.errors += 1
            body = codec.encode(resp).encode("ascii")
            await send({"type": "http.response.start", "status": status,
                        "headers": [(b"content-type", b"application/json"),
                                    (b"content-length",
                                     str(len(body)).encode("ascii"))]})
            await send({"type": "http.response.body", "body": body})
        finally:
            self.in_flight -= 1
            self.stats.record_batch(1)
            self.stats.record_latency(time.monotonic() - t0)

    async def _lifespan(self, receive, send) -> None:
        """Minimal lifespan protocol so uvicorn-style hosts can manage
        the drain: shutdown runs the same path as ``EdgeServer.stop``."""
        while True:
            msg = await receive()
            if msg["type"] == "lifespan.startup":
                await send({"type": "lifespan.startup.complete"})
            elif msg["type"] == "lifespan.shutdown":
                await self.shutdown()
                await send({"type": "lifespan.shutdown.complete"})
                return

    # ------------------------- lifecycle ----------------------------------
    async def shutdown(self, *, drain_timeout_s: float = 30.0) -> None:
        """Drain, then stop the gateway lanes.

        New requests answer ``shutting_down`` envelopes the moment this
        is called; requests already being served — including in-flight
        micro-batch lane dispatches — run to completion (bounded by
        ``drain_timeout_s``), and only then are the lane workers
        stopped, so no accepted request is dropped on the floor."""
        self.draining = True
        deadline = time.monotonic() + drain_timeout_s
        while self.in_flight > 0 and time.monotonic() < deadline:
            await asyncio.sleep(0.005)
        await self.gateway.stop()

    # ------------------------- request handling ---------------------------
    async def _handle(self, scope, receive) -> Tuple[int, Response]:
        method = scope["method"]
        path = scope["path"]
        if path == "/healthz":
            if method != "GET":
                return 405, Response.failure(
                    ERR_BAD_REQUEST, f"{method} not allowed on {path}: "
                    "use GET")
            return 200, Response.success(self._health())
        if path == "/stats":
            if method != "GET":
                return 405, Response.failure(
                    ERR_BAD_REQUEST, f"{method} not allowed on {path}: "
                    "use GET")
            return 200, Response.success(self.snapshot())
        if self.draining:
            # introspection stays up through the drain; API operations
            # are refused with the typed envelope so clients fail over
            return 503, Response.failure(
                ERR_SHUTTING_DOWN,
                "edge is draining for shutdown; retry against another "
                "replica")
        op = None
        if path != "/v1":
            if not path.startswith("/v1/"):
                return 404, Response.failure(
                    ERR_BAD_REQUEST,
                    f"no such endpoint: {path!r} (POST /v1/<op> with op in "
                    f"{sorted(OPS)}, GET /healthz, GET /stats)")
            op = path[len("/v1/"):]
            if op not in OPS:
                return 404, Response.failure(
                    ERR_BAD_REQUEST,
                    f"unknown operation {op!r} (known: {sorted(OPS)})")
        if method != "POST":
            return 405, Response.failure(
                ERR_BAD_REQUEST,
                f"{method} not allowed on {path}: API v1 operations are "
                "POST")
        body, overflow = await self._read_body(receive)
        if overflow:
            return 413, Response.failure(
                ERR_BAD_REQUEST,
                f"request body exceeds the {self.max_body}-byte cap")
        if body is None:
            return 400, Response.failure(
                ERR_BAD_REQUEST, "client disconnected mid-body")
        try:
            request = codec.decode(body.decode("utf-8"))
        except Exception as e:             # noqa: BLE001 — client's bytes
            return 400, Response.failure(
                ERR_BAD_REQUEST,
                f"malformed request body: {type(e).__name__}: {e}")
        inner = request.request if isinstance(request, AuthedRequest) \
            else request
        if op is not None and not isinstance(inner, OPS[op]):
            return 400, Response.failure(
                ERR_BAD_REQUEST,
                f"endpoint /v1/{op} expects a {OPS[op].__name__}, got "
                f"{type(inner).__name__}")
        if type(inner) not in OPS.values():
            return 400, Response.failure(
                ERR_BAD_REQUEST,
                f"not an API v1 request: {type(inner).__name__}")
        resp = await self.gateway.handle_async(request)
        return self._status(resp), resp

    async def _read_body(self, receive) -> Tuple[Optional[bytes], bool]:
        """Accumulate the request body up to ``max_body``; returns
        ``(body, overflow)`` — body is None if the client vanished."""
        chunks = bytearray()
        while True:
            msg = await receive()
            if msg["type"] == "http.disconnect":
                return None, False
            chunks += msg.get("body", b"")
            if len(chunks) > self.max_body:
                return None, True
            if not msg.get("more_body", False):
                return bytes(chunks), False

    # ------------------------- introspection ------------------------------
    def _status(self, resp: Response) -> int:
        return 200 if resp.ok else STATUS_FOR_ERROR.get(resp.error_code, 500)

    def _health(self) -> HealthResult:
        return HealthResult("draining" if self.draining else "ok",
                            API_VERSION,
                            tuple(self.gateway.gateway.hub.jobs()))

    def snapshot(self) -> StatsResult:
        """Server-side serving stats: HTTP-level counters/percentiles
        plus one snapshot per live micro-batch lane."""
        lanes = []
        for name, s in sorted(self.gateway.lane_stats.items()):
            lanes.append(LaneSnapshot(
                name, s.requests, s.batches, s.mean_batch,
                _ms(s.p50), _ms(s.p95), _ms(s.p99)))
        return StatsResult(self.stats.requests, self.errors, self.in_flight,
                           self.draining, _ms(self.stats.p50),
                           _ms(self.stats.p95), _ms(self.stats.p99),
                           tuple(lanes))


class EdgeServer:
    """Minimal asyncio HTTP/1.1 host for ``HubEdgeApp``.

    Speaks exactly what the edge needs over localhost and CI: request
    line + headers, content-length framing (chunked transfer encoding is
    refused with a typed envelope), keep-alive connections.  ``port=0``
    binds an ephemeral port (read it back from ``.port`` after
    ``start``).  ``stop()`` closes the listener FIRST (new connections
    are refused at the TCP layer), then drains the app — requests still
    arriving on live connections answer ``shutting_down`` envelopes —
    and finally force-closes whatever connections remain."""

    #: header-block cap (readuntil limit); requests with more header
    #: bytes than this answer 431 and close
    MAX_HEAD = 32 * 1024

    def __init__(self, app: HubEdgeApp, host: str = "127.0.0.1",
                 port: int = 0):
        self.app = app
        self.host = host
        self.port = port
        self._server: Optional[asyncio.AbstractServer] = None
        self._writers: set = set()

    async def __aenter__(self) -> "EdgeServer":
        return await self.start()

    async def __aexit__(self, *exc) -> None:
        await self.stop()

    async def start(self) -> "EdgeServer":
        self._server = await asyncio.start_server(
            self._serve_connection, self.host, self.port,
            limit=self.MAX_HEAD)
        self.port = self._server.sockets[0].getsockname()[1]
        return self

    async def stop(self) -> None:
        if self._server is not None:
            self._server.close()           # refuse NEW connections first
        await self.app.shutdown()          # drain in-flight, stop lanes
        if self._server is not None:
            await self._server.wait_closed()
            self._server = None
        for w in list(self._writers):      # idle keep-alive stragglers
            w.close()

    # ------------------------- connection loop ----------------------------
    async def _serve_connection(self, reader: asyncio.StreamReader,
                                writer: asyncio.StreamWriter) -> None:
        self._writers.add(writer)
        try:
            while True:
                head = await self._read_head(reader)
                if head is None:
                    break
                if head == "overflow":     # header block past MAX_HEAD
                    await self._write_simple(
                        writer, 431, Response.failure(
                            ERR_BAD_REQUEST,
                            f"request head exceeds {self.MAX_HEAD} bytes"))
                    break
                method, path, headers = head
                if headers.get("transfer-encoding"):
                    await self._write_simple(
                        writer, 400, Response.failure(
                            ERR_BAD_REQUEST,
                            "chunked transfer encoding is not supported: "
                            "send content-length framed bodies"))
                    break
                try:
                    length = int(headers.get("content-length", "0"))
                    if length < 0:
                        raise ValueError
                except ValueError:
                    await self._write_simple(
                        writer, 400, Response.failure(
                            ERR_BAD_REQUEST,
                            "unparseable content-length"))
                    break
                keep_alive = headers.get("connection", "").lower() != "close"
                done = await self._run_app(reader, writer, method, path,
                                           length, keep_alive)
                if not done or not keep_alive or self.app.draining:
                    break
        except (ConnectionResetError, BrokenPipeError,
                asyncio.IncompleteReadError):
            pass                           # client went away mid-exchange
        finally:
            self._writers.discard(writer)
            writer.close()
            try:
                await writer.wait_closed()
            except (ConnectionResetError, BrokenPipeError):
                pass

    async def _read_head(self, reader):
        """Parse one request head; None on clean EOF, ``"overflow"`` on
        an oversized header block."""
        try:
            raw = await reader.readuntil(b"\r\n\r\n")
        except asyncio.IncompleteReadError:
            return None                    # connection closed between reqs
        except asyncio.LimitOverrunError:
            return "overflow"
        lines = raw.decode("latin-1").split("\r\n")
        parts = lines[0].split(" ")
        if len(parts) != 3:
            return None
        method, target = parts[0], parts[1]
        headers = {}
        for line in lines[1:]:
            if ":" in line:
                k, v = line.split(":", 1)
                headers[k.strip().lower()] = v.strip()
        path = target.split("?", 1)[0]
        return method.upper(), path, headers

    async def _run_app(self, reader, writer, method, path, length,
                       keep_alive) -> bool:
        """Bridge one request through the ASGI app.  Returns False when
        the connection can no longer be reused (unconsumed body)."""
        remaining = length
        consumed_all = length == 0

        async def receive():
            nonlocal remaining, consumed_all
            if remaining <= 0:
                consumed_all = True
                return {"type": "http.request", "body": b"",
                        "more_body": False}
            chunk = await reader.read(min(65536, remaining))
            if not chunk:
                return {"type": "http.disconnect"}
            remaining -= len(chunk)
            consumed_all = remaining == 0
            return {"type": "http.request", "body": chunk,
                    "more_body": remaining > 0}

        async def send(msg):
            if msg["type"] == "http.response.start":
                status = msg["status"]
                conn = b"keep-alive" if keep_alive and not self.app.draining \
                    else b"close"
                head = [f"HTTP/1.1 {status} "
                        f"{_REASONS.get(status, 'OK')}".encode("ascii")]
                head += [k + b": " + v for k, v in msg.get("headers", [])]
                head.append(b"connection: " + conn)
                writer.write(b"\r\n".join(head) + b"\r\n\r\n")
            elif msg["type"] == "http.response.body":
                writer.write(msg.get("body", b""))
                if not msg.get("more_body", False):
                    await writer.drain()

        scope = {"type": "http", "asgi": {"version": "3.0"},
                 "http_version": "1.1", "method": method, "path": path,
                 "raw_path": path.encode("latin-1"), "query_string": b"",
                 "headers": [], "scheme": "http"}
        await self.app(scope, receive, send)
        # the app may answer before reading the body (unknown path, 405,
        # over-cap refusal); drain a small remainder so keep-alive
        # framing survives, but a large one closes the connection
        if not consumed_all and 0 < remaining <= 65536:
            try:
                await reader.readexactly(remaining)
                remaining = 0
                consumed_all = True
            except asyncio.IncompleteReadError:
                pass
        return consumed_all

    async def _write_simple(self, writer, status: int,
                            resp: Response) -> None:
        """Protocol-level refusal (bad head), outside the ASGI app."""
        body = codec.encode(resp).encode("ascii")
        writer.write((f"HTTP/1.1 {status} {_REASONS.get(status, 'OK')}\r\n"
                      "content-type: application/json\r\n"
                      f"content-length: {len(body)}\r\n"
                      "connection: close\r\n\r\n").encode("ascii"))
        writer.write(body)
        await writer.drain()


async def serve_edge(gateway, host: str = "127.0.0.1", port: int = 0, *,
                     max_batch: int = 256, tick_s: float = 0.0,
                     timeout_s: Optional[float] = None,
                     max_body: int = 1 << 20
                     ) -> Tuple[HubEdgeApp, EdgeServer]:
    """One-call edge bring-up: wrap a ``HubGateway`` in lanes, an app,
    and a bound listening server (ephemeral port with ``port=0``)."""
    agw = AsyncHubGateway(gateway, max_batch=max_batch, tick_s=tick_s,
                          timeout_s=timeout_s)
    app = HubEdgeApp(agw, max_body=max_body)
    server = await EdgeServer(app, host, port).start()
    return app, server


def _demo_gateway(jobs=("grep", "sort")):
    """A hub of emulated Spark jobs for the quickstart CLI."""
    from repro.core.datastore import RuntimeDataStore
    from repro.core.hub import Hub, JobRepo
    from repro.workloads import spark_emul as W
    hub = Hub()
    for job in jobs:
        d = W.generate_job_data(job)
        hub.publish(JobRepo(job, job, d.schema, RuntimeDataStore(d, seed=0),
                            predictor_kw=dict(pad_rows=True,
                                              max_cv_folds=15)))
    prices = {m.name: m.price for m in W.MACHINES.values()}
    return hub.gateway(prices, (2, 3, 4, 6, 8, 12, 16))


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description="serve a demo C3O hub (emulated Spark jobs) over HTTP")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8787)
    ap.add_argument("--jobs", default="grep,sort",
                    help="comma-separated emulated jobs to publish")
    ap.add_argument("--max-batch", type=int, default=256)
    args = ap.parse_args(argv)

    async def run():
        gw = _demo_gateway(tuple(j for j in args.jobs.split(",") if j))
        app, server = await serve_edge(gw, args.host, args.port,
                                       max_batch=args.max_batch)
        print(f"edge listening on http://{args.host}:{server.port} "
              f"jobs={args.jobs}", flush=True)
        try:
            await asyncio.Event().wait()
        finally:
            await server.stop()

    try:
        asyncio.run(run())
    except KeyboardInterrupt:
        pass


if __name__ == "__main__":
    main()
