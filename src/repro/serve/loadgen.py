"""Closed-loop load generator for the serving edge.

Drives a live edge (``repro.serve.edge``) over a REAL socket: N
concurrent keep-alive connections, each looping request -> full response
-> next request (closed loop), over a seeded deterministic workload of
typed API v1 envelopes (single-row predicts + chooses + searches across
a job mix).  Reports client-side req/s and p50/p95/p99 latency, then
pulls ``GET /stats`` so the realized per-lane micro-batch sizes and
server-side percentiles ride in the same report — the socket-level
numbers the ROADMAP's "millions of users" claim needs.

The default op mix is READ-ONLY (predict/choose/search): the same
workload replayed against the same store is byte-deterministic, which is
what lets the ``edge`` benchmark lane assert byte-identical responses
between the HTTP path and the in-process gateway.

CLI (against an already-running edge):

    PYTHONPATH=src python -m repro.serve.loadgen --port 8787 \\
        --connections 64 --requests 4096
"""
from __future__ import annotations

import argparse
import asyncio
import json
import math
import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.api import codec
from repro.api.types import (ChooseRequest, PredictRequest, SearchRequest,
                             StatsResult)

#: default op mix (weights): mostly the two dispatch-bound hot paths
DEFAULT_MIX: Tuple[Tuple[str, float], ...] = (
    ("predict", 0.5), ("choose", 0.45), ("search", 0.05))


def build_workload(n: int, *, jobs: Sequence[str] = ("grep", "sort"),
                   seed: int = 0,
                   mix: Sequence[Tuple[str, float]] = DEFAULT_MIX,
                   ) -> List[Tuple[str, bytes]]:
    """Seeded deterministic request stream: ``n`` (path, body) pairs.

    Rows are drawn from each job's emulated measurement grid
    (``spark_emul``), so every request is schema-valid for its job:
    predicts take one stored feature row (scale-out first), chooses take
    the row's context with a deadline jittered around feasibility.  The
    same (n, jobs, seed, mix) always builds the same byte stream."""
    from repro.workloads import spark_emul as W
    rng = np.random.default_rng(seed)
    pools = {}
    for job in jobs:
        d = W.generate_job_data(job)
        pools[job] = d
    ops, weights = zip(*mix)
    w = np.asarray(weights, np.float64)
    w = w / w.sum()
    out: List[Tuple[str, bytes]] = []
    for _ in range(n):
        op = ops[int(rng.choice(len(ops), p=w))]
        job = jobs[int(rng.integers(0, len(jobs)))]
        d = pools[job]
        i = int(rng.integers(0, len(d)))
        row = tuple(float(v) for v in d.X[i])
        if op == "predict":
            req = PredictRequest(job, str(d.machine_type[i]), (row,))
        elif op == "choose":
            t_max = math.nan if rng.random() < 0.25 \
                else float(d.y[i] * rng.uniform(1.2, 3.0))
            req = ChooseRequest(job, row[1:], t_max=t_max)
        else:
            req = SearchRequest(job if rng.random() < 0.5 else "")
        out.append((f"/v1/{op}", codec.encode(req).encode("ascii")))
    return out


@dataclass(frozen=True)
class LoadReport:
    """One closed-loop run: client-side throughput/latency plus the
    server's own ``StatsResult`` snapshot pulled after the run."""
    requests: int
    ok: int
    errors: int
    connections: int
    wall_s: float
    rps: float
    p50_ms: float
    p95_ms: float
    p99_ms: float
    op_counts: Dict[str, int]
    server: Optional[StatsResult]

    def predict_mean_batch(self) -> float:
        """Realized request-weighted mean micro-batch over the server's
        predict lanes (named ``job@machine``); 0.0 without a snapshot."""
        if self.server is None:
            return 0.0
        req = bat = 0
        for lane in self.server.lanes:
            if "@" in lane.lane:
                req += lane.requests
                bat += lane.batches
        return req / bat if bat else 0.0

    def to_json(self) -> dict:
        d = {k: getattr(self, k) for k in
             ("requests", "ok", "errors", "connections", "wall_s", "rps",
              "p50_ms", "p95_ms", "p99_ms", "op_counts")}
        if self.server is not None:
            d["server"] = json.loads(codec.encode(self.server))
            d["predict_mean_batch"] = self.predict_mean_batch()
        # through the strict-JSON codec: an empty-window report carries
        # NaN rps/percentiles, which must travel as float-tag objects
        # ({"__float__": "nan"}), not the non-standard NaN literal
        return json.loads(codec.encode(d))


async def _request(reader: asyncio.StreamReader,
                   writer: asyncio.StreamWriter, method: str, path: str,
                   body: bytes = b"") -> Tuple[int, bytes]:
    """One HTTP/1.1 exchange on an open keep-alive connection."""
    head = (f"{method} {path} HTTP/1.1\r\n"
            "host: edge\r\n"
            "content-type: application/json\r\n"
            f"content-length: {len(body)}\r\n\r\n").encode("ascii")
    writer.write(head + body)
    await writer.drain()
    raw = await reader.readuntil(b"\r\n\r\n")
    lines = raw.decode("latin-1").split("\r\n")
    status = int(lines[0].split(" ")[1])
    length = 0
    for line in lines[1:]:
        k, _, v = line.partition(":")
        if k.strip().lower() == "content-length":
            length = int(v.strip())
    payload = await reader.readexactly(length) if length else b""
    return status, payload


async def fetch_stats(host: str, port: int) -> Optional[StatsResult]:
    """One-shot ``GET /stats``, decoded; None if the edge is gone."""
    try:
        reader, writer = await asyncio.open_connection(host, port)
    except OSError:
        return None
    try:
        _, payload = await _request(reader, writer, "GET", "/stats")
        resp = codec.decode(payload.decode("utf-8"))
        return resp.result if resp.ok else None
    finally:
        writer.close()
        try:
            await writer.wait_closed()
        except (ConnectionResetError, BrokenPipeError):
            pass


async def run_loadgen(host: str, port: int, *, connections: int = 64,
                      requests: int = 2048,
                      jobs: Sequence[str] = ("grep", "sort"), seed: int = 0,
                      mix: Sequence[Tuple[str, float]] = DEFAULT_MIX,
                      workload: Optional[List[Tuple[str, bytes]]] = None,
                      ) -> LoadReport:
    """Closed-loop run: the fixed request budget is partitioned
    round-robin across ``connections`` keep-alive sockets; every
    connection plays its share strictly sequentially (send, await the
    full response, send the next), so concurrency — and therefore the
    coalescing pressure on the server's micro-batch lanes — is exactly
    the connection count."""
    if workload is None:
        workload = build_workload(requests, jobs=jobs, seed=seed, mix=mix)
    shares = [workload[c::connections] for c in range(connections)]
    latencies: List[float] = []
    statuses: List[int] = []
    op_counts: Dict[str, int] = {}

    async def worker(items):
        reader, writer = await asyncio.open_connection(host, port)
        try:
            for path, body in items:
                t0 = time.monotonic()
                status, _ = await _request(reader, writer, "POST", path,
                                           body)
                latencies.append(time.monotonic() - t0)
                statuses.append(status)
                op = path.rsplit("/", 1)[-1]
                op_counts[op] = op_counts.get(op, 0) + 1
        finally:
            writer.close()
            try:
                await writer.wait_closed()
            except (ConnectionResetError, BrokenPipeError):
                pass

    t0 = time.monotonic()
    await asyncio.gather(*(worker(s) for s in shares if s))
    wall = time.monotonic() - t0
    server = await fetch_stats(host, port)

    lat = np.sort(np.asarray(latencies, np.float64))

    def pct(p: float) -> float:
        if len(lat) == 0:
            return math.nan
        k = min(len(lat) - 1, max(0, math.ceil(p / 100 * len(lat)) - 1))
        return float(lat[k]) * 1e3

    ok = sum(1 for s in statuses if s == 200)
    return LoadReport(
        requests=len(statuses), ok=ok, errors=len(statuses) - ok,
        connections=connections, wall_s=wall,
        # a rep window with zero completed requests (warmup-only short
        # runs) has no throughput to report: NaN, like the latency
        # percentiles — never a division by zero or a fake infinity
        rps=len(statuses) / wall if statuses and wall > 0 else math.nan,
        p50_ms=pct(50), p95_ms=pct(95), p99_ms=pct(99),
        op_counts=dict(sorted(op_counts.items())), server=server)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description="closed-loop load test against a running serving edge")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, required=True)
    ap.add_argument("--connections", type=int, default=64)
    ap.add_argument("--requests", type=int, default=4096)
    ap.add_argument("--jobs", default="grep,sort")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--warmup", type=int, default=256,
                    help="unmeasured warm-up requests (compiles/fits) "
                    "before the measured run; 0 skips")
    args = ap.parse_args(argv)
    jobs = tuple(j for j in args.jobs.split(",") if j)

    async def run():
        if args.warmup:
            await run_loadgen(args.host, args.port,
                              connections=min(8, args.connections),
                              requests=args.warmup, jobs=jobs,
                              seed=args.seed + 1)
        return await run_loadgen(args.host, args.port,
                                 connections=args.connections,
                                 requests=args.requests, jobs=jobs,
                                 seed=args.seed)

    report = asyncio.run(run())
    print(json.dumps(report.to_json(), indent=2, sort_keys=True))


if __name__ == "__main__":
    main()
