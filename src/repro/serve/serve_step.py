"""Serving: prefill and single-token decode steps with explicit caches."""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.modeling import model as M


def make_prefill_step(cfg: ModelConfig) -> Callable:
    """(params, batch, cache) -> (last-position logits [B,V], cache)."""
    def prefill_step(params, batch, cache):
        logits, cache, _ = M.forward(cfg, params, batch, mode="prefill",
                                     pos0=0, cache=cache)
        return logits[:, -1], cache   # forward already sliced to [B,1,V]
    return prefill_step


def make_decode_step(cfg: ModelConfig) -> Callable:
    """(params, tokens [B], pos scalar, cache) -> (logits [B,V], cache).

    ``pos`` is the absolute position of the incoming token (= number of
    tokens already in the cache)."""
    def decode_step(params, tokens, pos, cache):
        batch = {"tokens": tokens[:, None]}
        logits, cache, _ = M.forward(cfg, params, batch, mode="decode",
                                     pos0=pos, cache=cache)
        return logits[:, 0], cache
    return decode_step


def greedy_generate(cfg: ModelConfig, params, prompt, max_new: int,
                    max_seq: int, cross_seq: int = 0, frontend=None):
    """Reference autoregressive loop (examples / tests; not the perf path)."""
    B, S0 = prompt.shape
    cache = M.init_cache(cfg, B, max_seq, cross_seq=cross_seq)
    prefill = jax.jit(make_prefill_step(cfg))
    decode = jax.jit(make_decode_step(cfg))
    batch = {"tokens": prompt}
    if frontend is not None:
        batch["frontend"] = frontend
    logits, cache = prefill(params, batch, cache)
    toks = [jnp.argmax(logits, -1)]
    pos = S0
    for _ in range(max_new - 1):
        logits, cache = decode(params, toks[-1], jnp.asarray(pos, jnp.int32),
                               cache)
        toks.append(jnp.argmax(logits, -1))
        pos += 1
    return jnp.stack(toks, axis=1)
