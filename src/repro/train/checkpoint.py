"""Fault-tolerant sharded checkpointing with elastic re-shard on load.

Design (no orbax offline):
  - Each checkpoint is a directory ``step_<N>/`` holding one ``.npz`` per
    host process with that process's shards (here: single process holds all
    addressable shards) plus a JSON manifest (tree structure, global shapes,
    dtypes, step).
  - Writes are atomic: serialize to ``<dir>.tmp`` then ``os.replace``.
  - ``restore`` takes the *target* mesh/sharding: arrays are re-laid-out on
    load, so a job may restart on a different device count or mesh shape
    (elastic scaling / failure recovery with shrunk capacity).
  - ``CheckpointManager`` keeps the newest K checkpoints, finds the latest
    valid one (torn writes are ignored), and exposes ``maybe_restore`` for
    crash-restart training loops.
"""
from __future__ import annotations

import json
import os
import re
import shutil
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_MANIFEST = "manifest.json"
_DATA = "shards.npz"


def _flatten(tree) -> Tuple[list, Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save_checkpoint(ckpt_dir: str, step: int, tree) -> str:
    """Atomic write of a full pytree (gathers addressable shards)."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = path + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    leaves, treedef = _flatten(tree)
    arrays = {f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)}
    np.savez(os.path.join(tmp, _DATA), **arrays)
    manifest = {
        "step": int(step),
        "n_leaves": len(leaves),
        "treedef": str(treedef),
        "shapes": [list(np.shape(x)) for x in leaves],
        "dtypes": [str(np.asarray(x).dtype) for x in leaves],
        "complete": True,
    }
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(path):
        shutil.rmtree(path)
    os.replace(tmp, path)                     # atomic publish
    return path


def restore_checkpoint(path: str, like_tree, shardings=None):
    """Load into the structure of ``like_tree``; if ``shardings`` (a pytree
    of NamedSharding/None) is given, arrays are placed with that layout —
    this is the elastic re-shard path (mesh may differ from save time)."""
    with open(os.path.join(path, _MANIFEST)) as f:
        manifest = json.load(f)
    if not manifest.get("complete"):
        raise IOError(f"incomplete checkpoint at {path}")
    data = np.load(os.path.join(path, _DATA))
    leaves, treedef = _flatten(like_tree)
    assert len(leaves) == manifest["n_leaves"], \
        f"leaf count mismatch: {len(leaves)} vs {manifest['n_leaves']}"
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(leaves))
    out = []
    for i, (ref, sh) in enumerate(zip(leaves, shard_leaves)):
        arr = data[f"leaf_{i}"]
        want = jnp.asarray(ref).dtype if not hasattr(ref, "dtype") else ref.dtype
        arr = arr.astype(want)
        out.append(jax.device_put(arr, sh) if sh is not None
                   else jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out), manifest["step"]


class CheckpointManager:
    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.dir = ckpt_dir
        self.keep = keep
        os.makedirs(ckpt_dir, exist_ok=True)

    def _steps(self):
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if not m:
                continue
            p = os.path.join(self.dir, name, _MANIFEST)
            if os.path.exists(p):                   # torn writes lack manifest
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self._steps()
        return s[-1] if s else None

    def save(self, step: int, tree) -> str:
        path = save_checkpoint(self.dir, step, tree)
        for old in self._steps()[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{old:08d}"),
                          ignore_errors=True)
        return path

    def maybe_restore(self, like_tree, shardings=None):
        """(tree, step) from the newest valid checkpoint, or (like_tree, 0)."""
        step = self.latest_step()
        if step is None:
            return like_tree, 0
        path = os.path.join(self.dir, f"step_{step:08d}")
        try:
            return restore_checkpoint(path, like_tree, shardings)
        except Exception:
            # torn/corrupt newest checkpoint: fall back to the previous one
            steps = self._steps()[:-1]
            if not steps:
                return like_tree, 0
            path = os.path.join(self.dir, f"step_{steps[-1]:08d}")
            return restore_checkpoint(path, like_tree, shardings)
