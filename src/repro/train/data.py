"""Deterministic synthetic LM data pipeline.

Deterministic per (seed, step): restarts resume mid-stream with no data
duplication or skip (fault-tolerance requirement) — the batch for step N is
a pure function, so a crash-restart at step N reproduces the exact stream.
A Zipfian unigram mixture with shifting bigram structure gives the model a
learnable (non-uniform) distribution so examples show loss going down.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def _zipf_logits(vocab: int, alpha: float = 1.1):
    ranks = jnp.arange(1, vocab + 1, dtype=jnp.float32)
    return -alpha * jnp.log(ranks)


def make_batch(cfg: ModelConfig, batch: int, seq: int, step, seed: int = 0,
               frontend_seq: int = 0) -> Dict[str, jax.Array]:
    """Pure function of (cfg, step): tokens, labels (+frontend embeddings)."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    k_tok, k_shift, k_front = jax.random.split(key, 3)
    logits = _zipf_logits(cfg.vocab_size)
    tokens = jax.random.categorical(k_tok, logits, shape=(batch, seq + 1))
    # inject learnable structure: token_{t+1} depends on token_t mod K
    K = 17
    shift = jax.random.randint(k_shift, (batch, 1), 0, K)
    structured = (tokens[:, :-1] * 31 + shift + 7) % cfg.vocab_size
    mix = jax.random.bernoulli(k_tok, 0.35, structured.shape)
    nxt = jnp.where(mix, structured, tokens[:, 1:])
    tokens = jnp.concatenate([tokens[:, :1], nxt], axis=1)
    out = {"tokens": tokens[:, :-1].astype(jnp.int32),
           "labels": tokens[:, 1:].astype(jnp.int32)}
    if cfg.frontend != "none":
        fs = frontend_seq or 8
        out["frontend"] = jax.random.normal(
            k_front, (batch, fs, cfg.frontend_dim), jnp.float32
        ).astype(cfg.dtype)
        if cfg.n_encoder_layers == 0:   # vlm: text tokens shrink by prefix
            out["tokens"] = out["tokens"][:, fs:]
            out["labels"] = out["labels"][:, fs:]
    return out
