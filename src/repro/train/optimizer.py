"""Optimizers as pure pytree transforms: AdamW and Adafactor.

Adafactor (factored second moment, no first moment) is the default for the
>=100B architectures (kimi-k2 1T, jamba 398B): optimizer state is ~2 floats
per *row/column* instead of 8 bytes per parameter, which is what makes those
models fit 512 x 16 GB HBM (see EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable          # (grads, state, params) -> (new_params, new_state)


def _global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    """Returns (scale, norm) — the scale is applied per leaf inside the
    update so a full fp32 copy of the gradient tree never materializes."""
    norm = _global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return scale, norm


def adamw(lr: float = 3e-4, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.01,
          max_grad_norm: float = 1.0) -> Optimizer:
    def init(params):
        def zeros(p):
            return jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        gscale, gnorm = clip_by_global_norm(grads, max_grad_norm)
        c = state["count"] + 1
        b1c = 1 - b1 ** c.astype(jnp.float32)
        b2c = 1 - b2 ** c.astype(jnp.float32)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32) * gscale
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * jnp.square(g)
            step = (m / b1c) / (jnp.sqrt(v / b2c) + eps)
            step = step + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m, v

        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"m": new_m, "v": new_v, "count": c}, gnorm

    return Optimizer(init, update)


def adafactor(lr: float = 1e-2, eps: float = 1e-30, clip_threshold: float = 1.0,
              decay_pow: float = 0.8, weight_decay: float = 0.0,
              max_grad_norm: float = 1.0) -> Optimizer:
    """Factored second-moment optimizer (Shazeer & Stern 2018), beta1=0."""

    def _factored(p) -> bool:
        return p.ndim >= 2

    def init(params):
        def leaf(p):
            if _factored(p):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"leaves": jax.tree.map(leaf, params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        gscale, gnorm = clip_by_global_norm(grads, max_grad_norm)
        c = state["count"] + 1
        beta2 = 1.0 - c.astype(jnp.float32) ** -decay_pow

        def upd(g, s, p):
            g = g.astype(jnp.float32) * gscale
            g2 = jnp.square(g) + eps
            if _factored(p):
                vr = beta2 * s["vr"] + (1 - beta2) * g2.mean(axis=-1)
                vc = beta2 * s["vc"] + (1 - beta2) * g2.mean(axis=-2)
                denom = jnp.maximum(vr.mean(axis=-1, keepdims=True), eps)
                v_hat = (vr / denom)[..., None] * vc[..., None, :]
                new_s = {"vr": vr, "vc": vc}
            else:
                v_hat = beta2 * s["v"] + (1 - beta2) * g2
                new_s = {"v": v_hat}
            u = g * jax.lax.rsqrt(v_hat + eps)
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-30)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype), new_s

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_s = tdef.flatten_up_to(state["leaves"])

        def upd_leaf(g, s, p):
            # stacked-layer leaves (scan models: [n_blocks, ...]) update via
            # lax.scan over the layer dim so the fp32 g/g^2 transients are
            # per-layer, not whole-stack (a 1T model's expert stack would
            # otherwise materialize ~5GB x3 fp32 temporaries per leaf)
            if p.ndim >= 3 and p.size > 16 * 2 ** 20:
                def body(_, xs):
                    gi, si, pi = xs
                    pi2, si2 = upd(gi, si, pi)
                    return None, (pi2, si2)
                _, (p2, s2) = jax.lax.scan(body, None, (g, s, p))
                return p2, s2
            return upd(g, s, p)

        outs = [upd_leaf(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
        new_params = tdef.unflatten([o[0] for o in outs])
        new_leaves = tdef.unflatten([o[1] for o in outs])
        return new_params, {"leaves": new_leaves, "count": c}, gnorm

    return Optimizer(init, update)


def get_optimizer(name: str, **kw) -> Optimizer:
    if name == "adamw":
        return adamw(**kw)
    if name == "adafactor":
        return adafactor(**kw)
    raise ValueError(f"unknown optimizer {name}")
