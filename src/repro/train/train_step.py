"""Training step: loss, gradient accumulation, optimizer update.

``make_train_step(cfg)`` builds a pure function
    (state, batch) -> (state, metrics)
suitable for ``jax.jit`` with sharded in/out specs.  Gradient accumulation is
a ``lax.scan`` over microbatches (cfg.grad_accum), bounding activation memory
for the giant architectures.  An optional gradient-compression hook (error-
feedback int8 all-reduce, distributed/compression.py) replaces the default
data-parallel mean.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed import sharding
from repro.modeling import model as M
from repro.train.optimizer import get_optimizer


def cross_entropy(logits, labels):
    """logits [B,S,V] (any float), labels [B,S] int (-1 = masked)."""
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    safe = jnp.maximum(labels, 0)
    nll = -jnp.take_along_axis(lp, safe[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def chunked_cross_entropy(cfg: ModelConfig, params, hidden, labels,
                          chunk: int) -> jax.Array:
    """CE without materializing the full [B,S,V] logits: the head + softmax
    run per sequence chunk under jax.checkpoint, so the peak logits buffer is
    [B,chunk,V] (recomputed in the backward pass).  For 256k-vocab models
    this was the dominant train-memory term (§Perf iteration 0)."""
    B, S, D = hidden.shape
    chunk = min(chunk, S)
    if S % chunk != 0:                    # fall back (smoke/odd shapes)
        return cross_entropy(M.lm_logits(cfg, params, hidden), labels)
    n = S // chunk
    xc = hidden.reshape(B, n, chunk, D).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, n, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def body(carry, xs):
        x_i, lab_i = xs
        logits = M.lm_logits(cfg, params, x_i).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        safe = jnp.maximum(lab_i, 0)
        gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
        mask = (lab_i >= 0).astype(jnp.float32)
        nll_sum, cnt = carry
        return (nll_sum + ((lse - gold) * mask).sum(), cnt + mask.sum()), None

    (nll, cnt), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())),
                                 (xc, lc))
    return nll / jnp.maximum(cnt, 1.0)


def make_loss_fn(cfg: ModelConfig) -> Callable:
    def loss_fn(params, batch):
        labels = batch["labels"]
        hidden, _, aux = M.hidden_forward(cfg, params, batch, mode="train")
        hidden = hidden[:, -labels.shape[1]:]       # skip vlm prefix positions
        if cfg.loss_chunk:
            loss = chunked_cross_entropy(cfg, params, hidden, labels,
                                         cfg.loss_chunk)
        else:
            loss = cross_entropy(M.lm_logits(cfg, params, hidden), labels)
        total = loss + cfg.router_aux_coef * aux
        return total, {"loss": loss, "aux_loss": aux}
    return loss_fn


def init_train_state(cfg: ModelConfig, key, opt=None):
    params = M.init_params(cfg, key)
    opt = opt or get_optimizer(cfg.optimizer)
    return {"params": params, "opt": opt.init(params),
            "step": jnp.zeros((), jnp.int32)}


def make_train_step(cfg: ModelConfig, opt=None,
                    grad_transform: Optional[Callable] = None) -> Callable:
    """grad_transform: optional (grads) -> grads hook (e.g. compression)."""
    opt = opt or get_optimizer(cfg.optimizer)
    loss_fn = make_loss_fn(cfg)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def compute_grads(params, batch):
        k = cfg.grad_accum
        if k <= 1:
            (_, metrics), grads = grad_fn(params, batch)
            return grads, metrics

        def reshape(x):
            b = x.shape[0]
            return x.reshape(k, b // k, *x.shape[1:])

        micro = jax.tree.map(reshape, batch)
        acc_dt = jnp.dtype(cfg.grad_accum_dtype)
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt), params)

        def body(acc, mb):
            (_, metrics), grads = grad_fn(params, mb)
            acc = jax.tree.map(lambda a, g: a + (g / k).astype(acc_dt),
                               acc, grads)
            return acc, metrics

        grads, metrics = jax.lax.scan(body, zeros, micro)
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        metrics = jax.tree.map(lambda m: m.mean(), metrics)
        return grads, metrics

    def train_step(state, batch):
        grads, metrics = compute_grads(state["params"], batch)
        if grad_transform is not None:
            grads = grad_transform(grads)
        params, opt_state, gnorm = opt.update(grads, state["opt"],
                                              state["params"])
        metrics["grad_norm"] = gnorm
        new_state = {"params": params, "opt": opt_state,
                     "step": state["step"] + 1}
        return new_state, metrics

    return train_step


# --------------------------- sharding specs --------------------------------

def state_specs(cfg: ModelConfig, mesh):
    """PartitionSpec tree matching init_train_state (optimizer state mirrors
    parameter sharding; factored adafactor rows/cols inherit leading dims)."""
    from jax.sharding import PartitionSpec as P
    pspecs = M.param_specs(cfg, mesh=mesh)

    def opt_spec_of(ps):
        # adamw m/v share the param spec; adafactor vr/vc drop one trailing dim
        return ps

    if cfg.optimizer == "adamw":
        opt = {"m": pspecs, "v": pspecs, "count": P()}
    else:
        def leaf(ps):
            parts = list(ps)
            if len(parts) >= 2:
                return {"vr": P(*parts[:-1]), "vc": P(*(parts[:-2] + parts[-1:]))}
            return {"v": P(*parts)}
        opt = {"leaves": jax.tree.map(leaf, pspecs,
                                      is_leaf=lambda x: isinstance(x, P)),
               "count": P()}
    return {"params": pspecs, "opt": opt, "step": P()}


def batch_specs(batch_tree, mesh):
    """tokens/labels [B,S] -> P(('pod','data'), None); frontend likewise."""
    def leaf(x):
        shape = x.shape
        return sharding.resolve_spec(
            ("batch",) + (None,) * (len(shape) - 1), dims=shape, mesh=mesh)
    return jax.tree.map(leaf, batch_tree)
