"""Calibrated emulators of the paper's five Spark jobs (Table I).

The paper's AWS/EMR runtime dataset (dos-group/c3o-experiments) is not
available offline; this module regenerates a dataset with the *same
structure* — 126 Sort / 162 Grep / 180 SGD / 180 K-Means / 282 PageRank
unique configurations, the same feature counts (3+0 … 3+2) and parameter
ranges — from first-principles runtime laws:

  parallel read/scan  ~ size / (io * scale_out)
  shuffle             ~ size / scale_out^0.85         (network overhead)
  per-iteration sync  ~ log(scale_out)                (barriers)
  startup             ~ a + b * scale_out             (provisioning)
  memory cliff: iterative jobs that do not fit in cluster memory re-read
  from disk every iteration (paper §IV-B) -> large discontinuous penalty.

Each unique configuration is "run" five times with log-normal noise plus
occasional stragglers, and the median is kept (paper §VI-B).
"""
from __future__ import annotations

import hashlib
import math
from dataclasses import dataclass
from typing import Callable, Dict, List, Tuple

import numpy as np

from repro.core.features import JobSchema, RuntimeData


@dataclass(frozen=True)
class Machine:
    name: str
    cpu: float          # relative compute throughput
    mem_gb: float       # memory per node usable for caching
    io: float           # relative disk/network throughput
    price: float        # $ per node-hour


MACHINES: Dict[str, Machine] = {
    "m5.xlarge": Machine("m5.xlarge", 1.0, 16.0, 1.0, 0.192),
    "c5.xlarge": Machine("c5.xlarge", 1.45, 8.0, 1.0, 0.170),
    "r5.xlarge": Machine("r5.xlarge", 1.0, 32.0, 0.95, 0.252),
}

SCHEMAS: Dict[str, JobSchema] = {
    "sort": JobSchema("sort", ()),
    "grep": JobSchema("grep", ("kw_hit_ratio",)),
    "sgd": JobSchema("sgd", ("iterations", "n_features")),
    "kmeans": JobSchema("kmeans", ("k", "dim")),
    "pagerank": JobSchema("pagerank", ("convergence", "unique_pages")),
}


# ---------------------------------------------------------------------------
# deterministic runtime laws (seconds)
# ---------------------------------------------------------------------------

def _startup(s: float) -> float:
    return 12.0 + 0.45 * s


def _mem_cliff(data_mem_gb: float, m: Machine, s: float) -> float:
    """>1 multiplier on per-iteration work when the dataset misses memory."""
    fit = data_mem_gb / (0.80 * m.mem_gb * s)
    return 1.0 if fit <= 1.0 else 2.1 + 0.5 * min(fit - 1.0, 2.0)


def sort_time(m: Machine, s: float, z: float) -> float:
    read = 9.0 * z / (m.io * s)
    cpu = 1.3 * z * math.log2(z * 64.0) / (m.cpu * s)
    shuffle = 2.4 * z / s ** 0.85
    write = 6.0 * z / (m.io * s)
    return read + cpu + shuffle + write + _startup(s)


def grep_time(m: Machine, s: float, z: float, kw: float) -> float:
    read = 9.0 * z / (m.io * s)
    scan = 3.1 * z / (m.cpu * s)
    # matches are written back (and shuffled for dedup): dominant when the
    # keyword is frequent — the context feature Ernest cannot see
    write = 420.0 * z * kw / (m.io * s) + 95.0 * z * kw / s ** 0.8
    return read + scan + write + _startup(s)


def sgd_time(m: Machine, s: float, z: float, iters: float,
             n_features: float) -> float:
    read = 9.0 * z / (m.io * s)
    cliff = _mem_cliff(1.15 * z, m, s)
    per_iter = (0.30 * z * (n_features / 50.0) / (m.cpu * s)) * cliff \
        + 0.22 * math.log2(s + 1)
    return read + iters * per_iter + _startup(s)


def kmeans_time(m: Machine, s: float, z: float, k: float, dim: float) -> float:
    read = 9.0 * z / (m.io * s)
    iters = (2.0 + 0.9 * k) * (1.0 + 0.15 * dim / 10.0)
    cliff = _mem_cliff(1.0 * z, m, s)
    per_iter = (0.16 * z * k * (dim / 10.0) / (m.cpu * s)) * cliff \
        + 0.05 * k * math.log2(s + 1)
    return read + iters * per_iter + _startup(s)


def pagerank_time(m: Machine, s: float, z: float, conv: float,
                  pages: float) -> float:
    links = z * 42e6          # edges per GB of edge-list text
    iters = math.ceil(math.log10(1.0 / conv)) + 3
    graph_mem = pages * 1.3e-7 + z * 2.0
    cliff = _mem_cliff(graph_mem, m, s)
    per_iter = ((links * 1.3e-8 + pages * 2.2e-7) / (m.cpu * s)) * cliff \
        + 0.35 * math.log2(s + 1)
    return 9.0 * z / (m.io * s) + iters * per_iter + _startup(s)


TIME_FNS: Dict[str, Callable] = {
    "sort": sort_time, "grep": grep_time, "sgd": sgd_time,
    "kmeans": kmeans_time, "pagerank": pagerank_time,
}


def true_runtime(job: str, machine: str, s: float, features: Tuple) -> float:
    """Noise-free ground truth (configurator oracles in tests)."""
    return TIME_FNS[job](MACHINES[machine], s, *features)


# ---------------------------------------------------------------------------
# noisy measurement: 5 repetitions, median (paper §VI-B)
# ---------------------------------------------------------------------------

def _measure(job: str, machine: str, s: float, features: Tuple,
             seed: int, noise: float = 0.02, reps: int = 5) -> float:
    key = f"{job}|{machine}|{s}|{features}|{seed}".encode()
    rng = np.random.default_rng(
        int.from_bytes(hashlib.sha256(key).digest()[:8], "little"))
    base = true_runtime(job, machine, s, features)
    runs = base * rng.lognormal(0.0, noise, size=reps)
    straggler = rng.random(reps) < 0.04
    runs = np.where(straggler, runs * rng.uniform(1.25, 2.2, size=reps), runs)
    return float(np.median(runs))


# ---------------------------------------------------------------------------
# dataset generation (Table I layout)
# ---------------------------------------------------------------------------

_SCALEOUTS7 = [2, 3, 4, 6, 8, 12, 16]
_SCALEOUTS6 = [2, 3, 4, 6, 8, 12]


def _pick(grid: List[Tuple], k: int, seed: int) -> List[Tuple]:
    rng = np.random.default_rng(seed)
    idx = rng.choice(len(grid), size=k, replace=False)
    return [grid[i] for i in sorted(idx)]


def job_design(job: str, seed: int = 7) -> List[Tuple[str, float, Tuple]]:
    """Unique (machine, scale_out, (size, ctx...)) configurations."""
    machines = list(MACHINES)
    if job == "sort":
        sizes = [10, 12, 14, 16, 18, 20]
        cells = [(z,) for z in sizes]
        scale = _SCALEOUTS7
    elif job == "grep":
        cells = [(z, kw) for z in [10, 15, 20]
                 for kw in [0.002, 0.02, 0.08]]
        scale = _SCALEOUTS6
    elif job == "sgd":
        # 5 contexts x 2 sizes: every context group spans sizes AND
        # scale-outs (the optimistic SSM needs same-context groups)
        ctxs = [(10, 50), (25, 100), (40, 50), (70, 100), (100, 50)]
        cells = [(z, it, f) for (it, f) in ctxs for z in [10, 30]]
        scale = _SCALEOUTS6
    elif job == "kmeans":
        ctxs = [(3, 10), (5, 30), (6, 10), (8, 30), (9, 10)]
        cells = [(z, k, d) for (k, d) in ctxs for z in [10, 20]]
        scale = _SCALEOUTS6
    elif job == "pagerank":
        ctxs = [(0.01, 2e5), (0.001, 1e6), (0.001, 5e6), (0.0001, 5e6),
                (0.0001, 2e7), (0.01, 1e6), (0.001, 2e7), (0.0001, 1e6)]
        cells = [(z, c, u) for (c, u) in ctxs for z in [0.13, 0.44]]
        scale = _SCALEOUTS6
    else:
        raise ValueError(job)
    design = [(m, float(s), tuple(map(float, cell)))
              for m in machines for s in scale for cell in cells]
    if job == "pagerank":        # 3*6*16=288 -> drop 6 cells (Table I: 282)
        rng = np.random.default_rng(seed + 3)
        drop = set(rng.choice(len(design), 6, replace=False).tolist())
        design = [d for i, d in enumerate(design) if i not in drop]
    return design


def generate_job_data(job: str, seed: int = 0) -> RuntimeData:
    """Emulated dataset, assembled straight into the columnar layout.

    The measurement loop is inherently per-configuration (each cell's noise
    stream is seeded from its identity hash), but the columns are written
    into preallocated arrays and adopted zero-copy by ``from_columns`` —
    no intermediate Python row lists."""
    schema = SCHEMAS[job]
    design = job_design(job)
    machines = tuple(MACHINES)
    code_of = {m: i for i, m in enumerate(machines)}
    n = len(design)
    codes = np.empty(n, np.int32)
    scale_out = np.empty(n, np.float64)
    context = np.empty((n, schema.n_features - 1), np.float64)
    runtime = np.empty(n, np.float64)
    for i, (machine, s, cell) in enumerate(design):
        codes[i] = code_of[machine]
        scale_out[i] = s
        context[i] = cell
        runtime[i] = _measure(job, machine, s, cell, seed)
    return RuntimeData.from_columns(schema, machines, codes, scale_out,
                                    context, runtime)


def generate_all(seed: int = 0) -> Dict[str, RuntimeData]:
    return {job: generate_job_data(job, seed) for job in SCHEMAS}


def context_groups(data: RuntimeData) -> List[np.ndarray]:
    """Index sets sharing all context features (the paper's 'local' sets).

    Operates on the context column block directly (column 0 of ``context``
    is the dataset size — a base feature, not a grouping key)."""
    ctx = data.context[:, 1:]
    if ctx.shape[1] == 0:
        return [np.arange(len(data))]
    _, gid = np.unique(np.round(ctx, 9), axis=0, return_inverse=True)
    return [np.where(gid == g)[0] for g in range(gid.max() + 1)]
