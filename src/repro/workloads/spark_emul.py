"""Calibrated emulators of the paper's five Spark jobs (Table I).

The paper's AWS/EMR runtime dataset (dos-group/c3o-experiments) is not
available offline; this module regenerates a dataset with the *same
structure* — 126 Sort / 162 Grep / 180 SGD / 180 K-Means / 282 PageRank
unique configurations, the same feature counts (3+0 … 3+2) and parameter
ranges — from first-principles runtime laws:

  parallel read/scan  ~ size / (io * scale_out)
  shuffle             ~ size / scale_out^0.85         (network overhead)
  per-iteration sync  ~ log(scale_out)                (barriers)
  startup             ~ a + b * scale_out             (provisioning)
  memory cliff: iterative jobs that do not fit in cluster memory re-read
  from disk every iteration (paper §IV-B) -> large discontinuous penalty.

Each unique configuration is "run" five times with log-normal noise plus
occasional stragglers, and the median is kept (paper §VI-B).
"""
from __future__ import annotations

import hashlib
import math
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.features import JobSchema, RuntimeData


@dataclass(frozen=True)
class Machine:
    name: str
    cpu: float          # relative compute throughput
    mem_gb: float       # memory per node usable for caching
    io: float           # relative disk/network throughput
    price: float        # $ per node-hour


MACHINES: Dict[str, Machine] = {
    "m5.xlarge": Machine("m5.xlarge", 1.0, 16.0, 1.0, 0.192),
    "c5.xlarge": Machine("c5.xlarge", 1.45, 8.0, 1.0, 0.170),
    "r5.xlarge": Machine("r5.xlarge", 1.0, 32.0, 0.95, 0.252),
}

SCHEMAS: Dict[str, JobSchema] = {
    "sort": JobSchema("sort", ()),
    "grep": JobSchema("grep", ("kw_hit_ratio",)),
    "sgd": JobSchema("sgd", ("iterations", "n_features")),
    "kmeans": JobSchema("kmeans", ("k", "dim")),
    "pagerank": JobSchema("pagerank", ("convergence", "unique_pages")),
}


# ---------------------------------------------------------------------------
# deterministic runtime laws (seconds)
# ---------------------------------------------------------------------------

def _startup(s: float) -> float:
    return 12.0 + 0.45 * s


def _mem_cliff(data_mem_gb: float, m: Machine, s: float) -> float:
    """>1 multiplier on per-iteration work when the dataset misses memory."""
    fit = data_mem_gb / (0.80 * m.mem_gb * s)
    return 1.0 if fit <= 1.0 else 2.1 + 0.5 * min(fit - 1.0, 2.0)


def sort_time(m: Machine, s: float, z: float) -> float:
    read = 9.0 * z / (m.io * s)
    cpu = 1.3 * z * math.log2(z * 64.0) / (m.cpu * s)
    shuffle = 2.4 * z / s ** 0.85
    write = 6.0 * z / (m.io * s)
    return read + cpu + shuffle + write + _startup(s)


def grep_time(m: Machine, s: float, z: float, kw: float) -> float:
    read = 9.0 * z / (m.io * s)
    scan = 3.1 * z / (m.cpu * s)
    # matches are written back (and shuffled for dedup): dominant when the
    # keyword is frequent — the context feature Ernest cannot see
    write = 420.0 * z * kw / (m.io * s) + 95.0 * z * kw / s ** 0.8
    return read + scan + write + _startup(s)


def sgd_time(m: Machine, s: float, z: float, iters: float,
             n_features: float) -> float:
    read = 9.0 * z / (m.io * s)
    cliff = _mem_cliff(1.15 * z, m, s)
    per_iter = (0.30 * z * (n_features / 50.0) / (m.cpu * s)) * cliff \
        + 0.22 * math.log2(s + 1)
    return read + iters * per_iter + _startup(s)


def kmeans_time(m: Machine, s: float, z: float, k: float, dim: float) -> float:
    read = 9.0 * z / (m.io * s)
    iters = (2.0 + 0.9 * k) * (1.0 + 0.15 * dim / 10.0)
    cliff = _mem_cliff(1.0 * z, m, s)
    per_iter = (0.16 * z * k * (dim / 10.0) / (m.cpu * s)) * cliff \
        + 0.05 * k * math.log2(s + 1)
    return read + iters * per_iter + _startup(s)


def pagerank_time(m: Machine, s: float, z: float, conv: float,
                  pages: float) -> float:
    links = z * 42e6          # edges per GB of edge-list text
    iters = math.ceil(math.log10(1.0 / conv)) + 3
    graph_mem = pages * 1.3e-7 + z * 2.0
    cliff = _mem_cliff(graph_mem, m, s)
    per_iter = ((links * 1.3e-8 + pages * 2.2e-7) / (m.cpu * s)) * cliff \
        + 0.35 * math.log2(s + 1)
    return 9.0 * z / (m.io * s) + iters * per_iter + _startup(s)


TIME_FNS: Dict[str, Callable] = {
    "sort": sort_time, "grep": grep_time, "sgd": sgd_time,
    "kmeans": kmeans_time, "pagerank": pagerank_time,
}


def true_runtime(job: str, machine: str, s: float, features: Tuple) -> float:
    """Noise-free ground truth (configurator oracles in tests)."""
    return TIME_FNS[job](MACHINES[machine], s, *features)


def derived_rng(*key) -> np.random.Generator:
    """Deterministic generator seeded from SHA-256 of a structured identity
    key (independent of PYTHONHASHSEED).  The SINGLE definition of the
    hash-to-seed mapping: measurement noise streams, user designs, and the
    eval replay plane all derive their RNGs here, so the byte layout of the
    seed can never drift between modules (which would silently change every
    fingerprint the harness reports)."""
    digest = hashlib.sha256("|".join(map(str, key)).encode()).digest()[:8]
    return np.random.default_rng(int.from_bytes(digest, "little"))


# ---------------------------------------------------------------------------
# noisy measurement: 5 repetitions, median (paper §VI-B)
# ---------------------------------------------------------------------------

def _measure(job: str, machine: str, s: float, features: Tuple,
             seed: int, noise: float = 0.02, reps: int = 5) -> float:
    rng = derived_rng(job, machine, s, features, seed)
    base = true_runtime(job, machine, s, features)
    runs = base * rng.lognormal(0.0, noise, size=reps)
    straggler = rng.random(reps) < 0.04
    runs = np.where(straggler, runs * rng.uniform(1.25, 2.2, size=reps), runs)
    return float(np.median(runs))


# ---------------------------------------------------------------------------
# dataset generation (Table I layout)
# ---------------------------------------------------------------------------

_SCALEOUTS7 = [2, 3, 4, 6, 8, 12, 16]
_SCALEOUTS6 = [2, 3, 4, 6, 8, 12]


def _pick(grid: List[Tuple], k: int, seed: int) -> List[Tuple]:
    rng = np.random.default_rng(seed)
    idx = rng.choice(len(grid), size=k, replace=False)
    return [grid[i] for i in sorted(idx)]


def _job_cells(job: str) -> Tuple[List[Tuple], List[int]]:
    """Canonical ((size, ctx...) cells, scale-out grid) for one job."""
    if job == "sort":
        sizes = [10, 12, 14, 16, 18, 20]
        return [(z,) for z in sizes], _SCALEOUTS7
    if job == "grep":
        return [(z, kw) for z in [10, 15, 20]
                for kw in [0.002, 0.02, 0.08]], _SCALEOUTS6
    if job == "sgd":
        # 5 contexts x 2 sizes: every context group spans sizes AND
        # scale-outs (the optimistic SSM needs same-context groups)
        ctxs = [(10, 50), (25, 100), (40, 50), (70, 100), (100, 50)]
        return [(z, it, f) for (it, f) in ctxs for z in [10, 30]], _SCALEOUTS6
    if job == "kmeans":
        ctxs = [(3, 10), (5, 30), (6, 10), (8, 30), (9, 10)]
        return [(z, k, d) for (k, d) in ctxs for z in [10, 20]], _SCALEOUTS6
    if job == "pagerank":
        ctxs = [(0.01, 2e5), (0.001, 1e6), (0.001, 5e6), (0.0001, 5e6),
                (0.0001, 2e7), (0.01, 1e6), (0.001, 2e7), (0.0001, 1e6)]
        return [(z, c, u) for (c, u) in ctxs
                for z in [0.13, 0.44]], _SCALEOUTS6
    raise ValueError(job)


def job_design(job: str, seed: int = 7) -> List[Tuple[str, float, Tuple]]:
    """Unique (machine, scale_out, (size, ctx...)) configurations."""
    machines = list(MACHINES)
    cells, scale = _job_cells(job)
    design = [(m, float(s), tuple(map(float, cell)))
              for m in machines for s in scale for cell in cells]
    if job == "pagerank":        # 3*6*16=288 -> drop 6 cells (Table I: 282)
        rng = np.random.default_rng(seed + 3)
        drop = set(rng.choice(len(design), 6, replace=False).tolist())
        design = [d for i, d in enumerate(design) if i not in drop]
    return design


# Which cell components a user's execution context may perturb smoothly:
# the PHYSICALLY continuous ones — dataset size (component 0 everywhere),
# grep's keyword-hit ratio, pagerank's page count.  Integer job parameters
# (k, iterations, n_features, dim) stay on the canonical grid: a user runs
# k-means with k=3, not k=3.07.  Jittering them would also make every
# user's context block a unique fingerprint perfectly confounded with that
# user's data size — greedy tree splits then separate users on meaningless
# epsilon differences in k and inherit the wrong user's size regime, an
# artifact of the emulation rather than the paper's setting.  pagerank's
# convergence threshold also stays fixed: the iteration count is a ceil()
# of it, so an epsilon perturbation across the 10^-k boundary jumps the
# true runtime discontinuously.
_JITTERABLE: Dict[str, Tuple[int, ...]] = {
    "sort": (0,), "grep": (0, 1), "sgd": (0,), "kmeans": (0,),
    "pagerank": (0, 2),
}


def _user_rng(job: str, user: int, seed: int) -> np.random.Generator:
    return derived_rng("user", job, user, seed)


def user_design(job: str, user: int, seed: int = 0, n_cells: int = 4,
                n_scale: int = 5,
                jitter: float = 0.10) -> List[Tuple[str, float, Tuple]]:
    """One collaborating user's execution context (paper §VI-C "global").

    Users share the job but not the exact context: each draws its own
    subset of context cells and scale-outs from the canonical grids, then
    perturbs the continuous cell components (dataset size, keyword ratio,
    iterations, ...) multiplicatively by up to ``jitter``.  Perturbation is
    applied once per cell — within a user every context group still spans
    all of its scale-outs (the optimistic SSM needs same-context groups) —
    while across users contexts never coincide, which is exactly the
    heterogeneity the leave-one-user-out replay measures generalization
    over.  The row count is a user-independent constant
    (machines x n_scale x n_cells) so replayed store sizes are identical
    across held-out users and the engine's shape-bucketed executables are
    shared."""
    rng = _user_rng(job, user, seed)
    cells, scale = _job_cells(job)
    pick_c = sorted(rng.choice(len(cells), size=min(n_cells, len(cells)),
                               replace=False).tolist())
    pick_s = sorted(rng.choice(len(scale), size=min(n_scale, len(scale)),
                               replace=False).tolist())
    jitterable = _JITTERABLE[job]
    ucells = []
    for ci in pick_c:
        cell = [float(v) for v in cells[ci]]
        for j in jitterable:
            cell[j] *= float(rng.uniform(1.0 - jitter, 1.0 + jitter))
        ucells.append(tuple(cell))
    return [(m, float(scale[si]), cell)
            for m in MACHINES for si in pick_s for cell in ucells]


def _measure_design(job: str, design: List[Tuple[str, float, Tuple]],
                    seed: int, schema: JobSchema = None,
                    runtime_scale: float = 1.0) -> RuntimeData:
    """Emulated dataset for one design, assembled straight into the
    columnar layout.

    The measurement loop is inherently per-configuration (each cell's noise
    stream is seeded from its identity hash), but the columns are written
    into preallocated arrays and adopted zero-copy by ``from_columns`` —
    no intermediate Python row lists.  ``schema`` defaults to the canonical
    one for ``job``; cold-job emulation passes its renamed schema and a
    per-job efficiency ``runtime_scale``."""
    if schema is None:
        schema = SCHEMAS[job]
    machines = tuple(MACHINES)
    code_of = {m: i for i, m in enumerate(machines)}
    n = len(design)
    codes = np.empty(n, np.int32)
    scale_out = np.empty(n, np.float64)
    context = np.empty((n, schema.n_features - 1), np.float64)
    runtime = np.empty(n, np.float64)
    for i, (machine, s, cell) in enumerate(design):
        codes[i] = code_of[machine]
        scale_out[i] = s
        context[i] = cell
        runtime[i] = _measure(job, machine, s, cell, seed) * runtime_scale
    return RuntimeData.from_columns(schema, machines, codes, scale_out,
                                    context, runtime)


def generate_job_data(job: str, seed: int = 0) -> RuntimeData:
    """The paper's Table I dataset layout (one pooled global dataset)."""
    return _measure_design(job, job_design(job), seed)


def generate_user_data(job: str, user: int, seed: int = 0,
                       **design_kw) -> RuntimeData:
    """One user's contribution-ready runtime data: their perturbed design
    (``user_design``) measured with a user-specific noise stream."""
    design = user_design(job, user, seed, **design_kw)
    return _measure_design(job, design, seed * 10007 + user + 1)


# ---------------------------------------------------------------------------
# adversarial user emulation (trust-plane evaluation)
# ---------------------------------------------------------------------------

#: attack repertoire for emulated poisoners.  Each corrupts an honest
#: user's dataset a different way; all are deliberately MODERATE —
#: egregious corruption is caught by plain §III-C.b validation, so the
#: interesting adversary is the one whose data partially slips through
#: and must be handled by reputation weighting:
#:   scale  — systematic runtime inflation (a mis-calibrated or lying
#:            harness reporting ~1.3x the true runtimes)
#:   noise  — high-variance measurements (no medians, single flaky runs)
#:   shift  — column shift: under-reports the dataset size feature, so
#:            runtimes attach to the wrong inputs
#:   spam   — high-volume near-duplicates of a few measurements (one real
#:            run uploaded many times with cosmetic jitter)
ADVERSARY_KINDS = ("scale", "noise", "shift", "spam")


def adversarial_user_data(job: str, user: int, seed: int, kind: str,
                          **design_kw) -> RuntimeData:
    """A poisoner's contribution-ready dataset: the honest measurements
    this user WOULD have produced (``generate_user_data``), corrupted by
    attack ``kind``.  Deterministic in (kind, job, user, seed) via
    ``derived_rng``, like everything the replay planes consume."""
    if kind not in ADVERSARY_KINDS:
        raise ValueError(f"unknown adversary kind {kind!r} "
                         f"(known: {', '.join(ADVERSARY_KINDS)})")
    data = generate_user_data(job, user, seed, **design_kw)
    rng = derived_rng("adversary", kind, job, user, seed)
    X = np.array(data.X, np.float64)
    y = np.array(data.y, np.float64)
    machines = np.asarray(data.machine_type)
    if kind == "scale":
        y = y * rng.uniform(1.25, 1.45, size=len(y))
    elif kind == "noise":
        y = y * rng.lognormal(0.0, 0.4, size=len(y))
    elif kind == "shift":
        # context column 0 (feature column 1: scale-out rides first) is
        # the dataset size in every job schema
        X[:, 1] = X[:, 1] * rng.uniform(0.55, 0.75, size=len(y))
    elif kind == "spam":
        take = rng.choice(len(y), size=max(1, len(y) // 4), replace=False)
        reps = 3 * (len(y) // max(1, len(take)))
        idx = np.sort(np.tile(np.sort(take), reps))
        X = X[idx]
        machines = machines[idx]
        y = y[idx] * rng.lognormal(0.0, 0.05, size=len(idx))
    return RuntimeData(data.schema, machines, X, y)


# ---------------------------------------------------------------------------
# cold-job emulation (zero-history cross-job transfer evaluation)
# ---------------------------------------------------------------------------

def cold_job_name(job: str) -> str:
    """Name of the held-out zero-history twin of a canonical job family."""
    return f"{job}-cold"


def cold_schema(job: str) -> JobSchema:
    """Schema of the cold twin: same feature layout, different job name —
    the hub treats it as a completely separate job with no history."""
    base = SCHEMAS[job]
    return JobSchema(cold_job_name(job), base.context_features,
                     base.base_features)


def cold_efficiency(job: str, seed: int = 0) -> float:
    """Systematic runtime offset of the cold twin vs its family (a
    different input dataset / code version running the same algorithm)."""
    return float(derived_rng("cold-eff", job, seed).uniform(0.92, 1.08))


def cold_design(job: str, seed: int = 0,
                jitter: float = 0.15) -> List[Tuple[str, float, Tuple]]:
    """The cold twin's execution context: every canonical cell with its
    continuous components perturbed by up to ``jitter``, over the full
    machine x scale-out grid.  Same jitter discipline as ``user_design``
    (integer parameters stay on the canonical grid)."""
    rng = derived_rng("cold", job, seed)
    cells, scale = _job_cells(job)
    jitterable = _JITTERABLE[job]
    ccells = []
    for cell in cells:
        cell = [float(v) for v in cell]
        for j in jitterable:
            cell[j] *= float(rng.uniform(1.0 - jitter, 1.0 + jitter))
        ccells.append(tuple(cell))
    return [(m, float(s), cell)
            for m in MACHINES for s in scale for cell in ccells]


def cold_true_runtime(job: str, machine: str, s: float, features: Tuple,
                      seed: int = 0) -> float:
    """Noise-free ground truth for the cold twin (family law x efficiency)."""
    return true_runtime(job, machine, s, features) * cold_efficiency(job, seed)


def generate_cold_job_data(job: str, seed: int = 0) -> RuntimeData:
    """The cold twin's full emulated dataset (evaluation ground truth —
    a real hub never has this; replay holds it out as the test set)."""
    return _measure_design(job, cold_design(job, seed), seed * 7919 + 13,
                           schema=cold_schema(job),
                           runtime_scale=cold_efficiency(job, seed))


def cold_probe(job: str, seed: int = 0,
               rows_per_machine: int = 3) -> RuntimeData:
    """The few measurements a new job's owner has actually run: a small
    deterministic slice of the cold design (``rows_per_machine`` per
    machine type) — enough to sketch a transfer signature, far too few to
    fit models."""
    design = cold_design(job, seed)
    rng = derived_rng("cold-probe", job, seed)
    by_machine: Dict[str, List[Tuple[str, float, Tuple]]] = {}
    for d in design:
        by_machine.setdefault(d[0], []).append(d)
    probe = []
    for m in sorted(by_machine):
        rows = by_machine[m]
        idx = sorted(rng.choice(len(rows),
                                size=min(rows_per_machine, len(rows)),
                                replace=False).tolist())
        probe.extend(rows[i] for i in idx)
    return _measure_design(job, probe, seed * 7919 + 13,
                           schema=cold_schema(job),
                           runtime_scale=cold_efficiency(job, seed))


def generate_all(seed: int = 0) -> Dict[str, RuntimeData]:
    return {job: generate_job_data(job, seed) for job in SCHEMAS}


def context_groups(data: RuntimeData) -> List[np.ndarray]:
    """Index sets sharing all context features (the paper's 'local' sets).

    Operates on the context column block directly (column 0 of ``context``
    is the dataset size — a base feature, not a grouping key)."""
    ctx = data.context[:, 1:]
    if ctx.shape[1] == 0:
        return [np.arange(len(data))]
    _, gid = np.unique(np.round(ctx, 9), axis=0, return_inverse=True)
    return [np.where(gid == g)[0] for g in range(gid.max() + 1)]


# ---------------------------------------------------------------------------
# spot-market emulation (cloud market plane evaluation)
# ---------------------------------------------------------------------------

#: emulated availability zones with (spot discount vs on-demand,
#: (lo, hi) hourly interruption-rate band).  The ordering is the market's
#: core trade-off: the deeper the discount, the flakier the capacity —
#: az-1c lists the lowest spot price AND interrupts so often that long
#: jobs placed there pay for the discount several times over in restarts.
SPOT_ZONES: Tuple[str, ...] = ("az-1a", "az-1b", "az-1c")
_ZONE_MARKET: Dict[str, Tuple[float, Tuple[float, float]]] = {
    "az-1a": (0.72, (0.2, 0.5)),
    "az-1b": (0.50, (1.5, 2.5)),
    # az-1c's compressed-time volatility is deliberately extreme: the
    # emulated jobs run seconds-to-minutes (not hours), so the rate that
    # makes "cheapest listed price" a trap at THIS time scale is ~30/h
    "az-1c": (0.34, (25.0, 40.0)),
}

#: fixed restart overhead (seconds) an interrupted attempt pays before
#: retrying from scratch in the emulated market
SPOT_RESTART_OVERHEAD_S = 180.0


def spot_interruption_rate(zone: str, seed: int = 0) -> float:
    """Seeded hourly interruption rate for one zone's spot capacity,
    drawn once per (zone, seed) from the zone's band."""
    lo, hi = _ZONE_MARKET[zone][1]
    return float(derived_rng("spot-rate", zone, seed).uniform(lo, hi))


def spot_price_series(machine: str, zone: str, seed: int = 0,
                      n_ticks: int = 64) -> np.ndarray:
    """Seeded time-varying spot price vector for one (machine, zone).

    A mean-reverting multiplicative walk around the zone's discount
    level, clipped to (0.12, 0.97) x on-demand — spot never beats free
    and never exceeds the listed rate."""
    base = MACHINES[machine].price
    disc = _ZONE_MARKET[zone][0]
    rng = derived_rng("spot-price", machine, zone, seed)
    x, out = 0.0, np.empty(n_ticks, np.float64)
    for t in range(n_ticks):
        x = 0.88 * x + float(rng.normal(0.0, 0.06))
        out[t] = base * float(np.clip(disc * math.exp(x), 0.12, 0.97))
    return out


def generate_price_book(seed: int = 0, n_ticks: int = 64,
                        zones: Tuple[str, ...] = SPOT_ZONES,
                        machines: Optional[Tuple[str, ...]] = None,
                        restart_overhead_s: float = SPOT_RESTART_OVERHEAD_S):
    """Seeded multi-AZ spot/on-demand ``PriceBook`` over the emulated
    machine catalog: per-zone on-demand price spread (capacity pricing
    differs a little per AZ), seeded spot price series, and
    discount-correlated interruption rates."""
    from repro.core.market import ON_DEMAND, SPOT, PriceBook
    machines = tuple(MACHINES) if machines is None else tuple(machines)
    prices: Dict[Tuple[str, str, str], np.ndarray] = {}
    rates: Dict[Tuple[str, str], float] = {}
    for z in zones:
        od_spread = float(derived_rng("od-spread", z, seed).uniform(0.985,
                                                                    1.015))
        rates[(z, ON_DEMAND)] = 0.0
        rates[(z, SPOT)] = spot_interruption_rate(z, seed)
        for m in machines:
            prices[(m, z, ON_DEMAND)] = np.full(
                n_ticks, MACHINES[m].price * od_spread)
            prices[(m, z, SPOT)] = spot_price_series(m, z, seed, n_ticks)
    return PriceBook(prices, rates, restart_overhead_s=restart_overhead_s)
