"""Minimal stand-in for ``hypothesis`` so the suite collects (and the
property tests still run as deterministic example sweeps) when hypothesis is
not installed.  ``pip install -r requirements-dev.txt`` gets the real thing.

Supports exactly the subset this test suite uses: ``given`` with keyword
strategies, ``settings(max_examples=..., deadline=...)``, and the
``floats`` / ``integers`` / ``sampled_from`` / ``booleans`` strategies.
Examples are drawn from a fixed-seed RNG, so failures reproduce.
"""
from __future__ import annotations

import functools
import random
from types import SimpleNamespace

_FALLBACK_MAX_EXAMPLES = 8      # keep the no-hypothesis lane fast


class _Strategy:
    def __init__(self, sample):
        self.sample = sample            # (random.Random) -> value


def _floats(lo, hi):
    return _Strategy(lambda r: r.uniform(lo, hi))


def _integers(lo, hi):
    return _Strategy(lambda r: r.randint(lo, hi))


def _sampled_from(seq):
    seq = list(seq)
    return _Strategy(lambda r: r.choice(seq))


def _booleans():
    return _Strategy(lambda r: r.random() < 0.5)


strategies = SimpleNamespace(floats=_floats, integers=_integers,
                             sampled_from=_sampled_from, booleans=_booleans)


def settings(max_examples: int = 10, **_ignored):
    def deco(fn):
        fn._max_examples = max_examples
        return fn
    return deco


def given(**strats):
    def deco(fn):
        @functools.wraps(fn)
        def run(*args, **kwargs):
            n = min(getattr(run, "_max_examples", 10), _FALLBACK_MAX_EXAMPLES)
            rng = random.Random(1234)
            for _ in range(n):
                drawn = {k: s.sample(rng) for k, s in strats.items()}
                fn(*args, **drawn, **kwargs)
        # pytest must not mistake the strategy kwargs for fixtures: hide the
        # wrapped signature (inspect.signature follows __wrapped__)
        del run.__wrapped__
        return run
    return deco
