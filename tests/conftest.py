import os
import sys

# tests run on the real device(s) — the 512-device dry-run flag must NOT be
# set here (see launch/dryrun.py, which sets it before any jax import).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
