"""Regenerate ``docs/api_v1.md`` from the live API surface + the golden
wire-format corpus.  The rendered page is CI-checked against this
generator (``test_api_docs_are_current``), so endpoint tables, error
codes, and payload samples can never drift from the code:

    PYTHONPATH=src python tests/make_api_docs.py
"""
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

DOCS_PATH = os.path.join(os.path.dirname(__file__), "..", "docs",
                         "api_v1.md")

#: golden sample shown under each op's endpoint row (request, response)
_OP_SAMPLES = {
    "predict": ("predict_request", "predict_response_inf_sigma"),
    "choose": ("choose_request_nan_deadline", "choose_response"),
    "contribute": ("contribute_request", "contribute_response"),
    "model_errors": ("model_errors_request", "model_errors_response"),
    "search": ("search_request", "search_response"),
    "trust_state": ("trust_state_request", "trust_state_response"),
    "compact": ("compact_request", "compact_response"),
}

#: error envelopes worth a worked sample on the page
_ERROR_SAMPLES = ("error_envelope", "unauthorized_envelope",
                  "quota_envelope", "timeout_envelope",
                  "shutting_down_envelope")


def _pretty(wire: str) -> str:
    return json.dumps(json.loads(wire), indent=2, sort_keys=True)


def render() -> str:
    """The full markdown page, deterministically, from the live surface."""
    from test_api_codec import GOLDEN_PATH, golden_samples

    from repro.api import codec
    from repro.api.types import API_VERSION
    from repro.serve.edge import OPS, STATUS_FOR_ERROR

    golden = {name: codec.encode(obj)
              for name, obj in golden_samples().items()}
    with open(GOLDEN_PATH) as f:
        pinned = json.load(f)
    assert golden == pinned, (
        "goldens are stale — run PYTHONPATH=src python "
        "tests/make_api_goldens.py first")

    out = []
    w = out.append
    w(f"# Hub Gateway API {API_VERSION} — HTTP surface")
    w("")
    w("<!-- GENERATED FILE — do not edit by hand.  Regenerate with")
    w("     PYTHONPATH=src python tests/make_api_docs.py -->")
    w("")
    w("The serving edge (`repro.serve.edge`) maps HTTP bodies through the")
    w("strict-JSON codec (`repro.api.codec`) into gateway operations.")
    w("Every request body and every response body is a codec-encoded")
    w("envelope: requests are the typed `*Request` dataclasses tagged with")
    w('`"__type__"`, responses are always a `Response` envelope'
      " (`status`")
    w('`"ok"` with a typed `result`, or `"error"` with a machine-readable')
    w("`error_code`).  Non-finite floats travel as tagged objects")
    w('(`{"__float__": "nan"}`), so the wire format is strict JSON.')
    w("")
    w("## Endpoints")
    w("")
    w("| Method | Path | Request envelope | Ok result |")
    w("|--------|------|------------------|-----------|")
    for op, req_t in OPS.items():
        resp_name = _OP_SAMPLES[op][1]
        result_t = json.loads(golden[resp_name])["result"]["__type__"]
        w(f"| POST | `/v1/{op}` | `{req_t.__name__}` | `{result_t}` |")
    w("| POST | `/v1` | any of the above (routes on `__type__`) | "
      "per request |")
    w("| GET | `/healthz` | — | `HealthResult` |")
    w("| GET | `/stats` | — | `StatsResult` |")
    w("")
    w("Any request MAY be wrapped in an `AuthedRequest` bearer-token")
    w("envelope; on auth-enabled gateways every operation MUST be.")
    w("Single-row `PredictRequest`s and `ChooseRequest`s coalesce on")
    w("per-(job, machine type) / per-job micro-batch lanes server-side;")
    w("batching is invisible in the response bytes.")
    w("")
    w("## Error codes")
    w("")
    w("Operational failures are ALWAYS typed envelopes — the HTTP status")
    w("is advisory for generic tooling, the envelope is the contract.")
    w("")
    w("| `error_code` | HTTP status |")
    w("|--------------|-------------|")
    for code, status in sorted(STATUS_FOR_ERROR.items()):
        w(f"| `{code}` | {status} |")
    w("")
    w("Protocol-level refusals (oversized header block: 431, chunked")
    w("transfer encoding: 400, body over the size cap: 413) answer the")
    w("same envelope shape with `error_code` `bad_request`.")
    w("")
    w("## Samples")
    w("")
    w("Request/response pairs below are the GOLDEN wire-format corpus")
    w("(`tests/goldens/api_v1.json`) — byte-pinned by the test suite,")
    w("pretty-printed here for reading.")
    for op in OPS:
        req_name, resp_name = _OP_SAMPLES[op]
        w("")
        w(f"### `POST /v1/{op}`")
        w("")
        w("Request:")
        w("")
        w("```json")
        w(_pretty(golden[req_name]))
        w("```")
        w("")
        w("Response:")
        w("")
        w("```json")
        w(_pretty(golden[resp_name]))
        w("```")
    w("")
    w("### `GET /healthz`")
    w("")
    w("```json")
    w(_pretty(golden["health_response"]))
    w("```")
    w("")
    w("During a drain the edge keeps answering health with status"
      ' `"draining"`:')
    w("")
    w("```json")
    w(_pretty(golden["health_response_draining"]))
    w("```")
    w("")
    w("### `GET /stats`")
    w("")
    w("```json")
    w(_pretty(golden["stats_response"]))
    w("```")
    w("")
    w("### Cold-start transfer")
    w("")
    w("On transfer-enabled gateways, `predict`/`choose` answers for a job")
    w("without enough history of its own are served from the nearest")
    w("donor job's fitted models and stamped with `transfer_source` and a")
    w("discounted `transfer_confidence`.  Self-served answers omit both")
    w("keys entirely, so pre-transfer payloads are byte-identical.")
    for name in ("predict_response_transfer", "choose_response_transfer"):
        w("")
        w("```json")
        w(_pretty(golden[name]))
        w("```")
    w("")
    w("### Spot markets & placement")
    w("")
    w("On market-enabled gateways (constructed with a")
    w("`repro.core.market.PriceBook`), `choose` scores a")
    w("(machine × zone × purchase-option × scale-out) grid on")
    w("interruption-adjusted expected cost.  Requests may constrain the")
    w("placement with `zones` / `purchase_options` (absent = any; an")
    w("empty tuple or an unknown name is a typed `bad_request`), and")
    w("answers stamp the placement bought plus the naive-vs-adjusted")
    w("cost breakdown: `cost_usd` stays the listed-price cost,")
    w("`expected_cost_usd` is what the choice is expected to really")
    w("cost once interruptions are priced in.  Market-less gateways")
    w("omit all of these keys, so pre-market payloads are")
    w("byte-identical.")
    for name in ("choose_request_market", "choose_response_market",
                 "placement_envelope"):
        w("")
        w("```json")
        w(_pretty(golden[name]))
        w("```")
    w("")
    w("### Error envelopes")
    for name in _ERROR_SAMPLES:
        w("")
        w("```json")
        w(_pretty(golden[name]))
        w("```")
    w("")
    return "\n".join(out)


def main() -> None:
    text = render()
    os.makedirs(os.path.dirname(DOCS_PATH), exist_ok=True)
    with open(DOCS_PATH, "w") as f:
        f.write(text)
    print(f"wrote {os.path.normpath(DOCS_PATH)} ({len(text)} bytes)")


if __name__ == "__main__":
    main()
