"""Regenerate the golden API v1 wire-format samples.  Run DELIBERATELY —
a diff in these goldens is a claim that the public wire format changed on
purpose (a versioned-API break):

    PYTHONPATH=src python tests/make_api_goldens.py
"""
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

from test_api_codec import GOLDEN_PATH, golden_samples  # noqa: E402

from repro.api import codec  # noqa: E402


def main() -> None:
    golden = {name: codec.encode(obj)
              for name, obj in golden_samples().items()}
    os.makedirs(os.path.dirname(GOLDEN_PATH), exist_ok=True)
    with open(GOLDEN_PATH, "w") as f:
        json.dump(golden, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {GOLDEN_PATH} ({len(golden)} samples)")


if __name__ == "__main__":
    main()
