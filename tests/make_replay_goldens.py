"""Regenerate the golden mini-replay MAPEs that pin tier-1 against silent
model/engine drift.  Run DELIBERATELY — a diff in the goldens is a claim
that prediction quality changed on purpose:

    PYTHONPATH=src python tests/make_replay_goldens.py
"""
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

from test_eval_replay import GOLDEN_PATH, MINI_CFG  # noqa: E402

from repro.eval import replay as R  # noqa: E402


def main() -> None:
    res = R.run_replay(MINI_CFG)
    golden = {job: {m: round(v, 6) for m, v in s["final_mape"].items()}
              for job, s in res.summary.items()}
    os.makedirs(os.path.dirname(GOLDEN_PATH), exist_ok=True)
    with open(GOLDEN_PATH, "w") as f:
        json.dump(golden, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {GOLDEN_PATH} (fingerprint {res.fingerprint})")
    for job, models in golden.items():
        print(f"  {job}: " + " ".join(f"{m}={v:.4f}"
                                      for m, v in sorted(models.items())))


if __name__ == "__main__":
    main()
