"""Roofline methodology validation: the analytic cost model vs XLA's
cost_analysis on configurations where cost_analysis is trustworthy
(no scans), plus a regression test documenting the scan undercount that
motivates the methodology (EXPERIMENTS.md §Dry-run)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import smoke_config
from repro.configs.base import ShapeConfig
from repro.launch import specs as SP
from repro.launch.analytic import analytic_cost
from repro.launch.compat import cost_analysis_dict
from repro.train import train_step as TS


def _hlo_flops(cfg, shape):
    batch = {"tokens": jax.ShapeDtypeStruct(
        (shape.global_batch, shape.seq_len), jnp.int32),
        "labels": jax.ShapeDtypeStruct(
        (shape.global_batch, shape.seq_len), jnp.int32)}
    state = SP.abstract_state(cfg)
    comp = jax.jit(TS.make_train_step(cfg)).lower(state, batch).compile()
    return cost_analysis_dict(comp).get("flops", 0.0)


def test_scan_undercount_regression():
    """cost_analysis counts a scan body once — the bug class that makes the
    naive roofline wrong and the analytic model necessary."""
    def make(K):
        def f(x):
            return jax.lax.scan(lambda c, _: (c @ c, None), x, None,
                                length=K)[0]
        return f
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    f1 = cost_analysis_dict(jax.jit(make(1)).lower(x).compile())["flops"]
    f8 = cost_analysis_dict(jax.jit(make(8)).lower(x).compile())["flops"]
    # trip count ignored (only loop-bookkeeping flops differ)
    assert f8 < f1 * 1.01


@pytest.mark.parametrize("remat", ["none"])
def test_analytic_flops_close_to_hlo_unrolled(remat):
    cfg = smoke_config("deepseek-7b", scan_layers=False, n_layers=4,
                       remat=remat, attention_impl="reference",
                       grad_accum=1)
    shape = ShapeConfig("t", 64, 4, "train")
    hlo = _hlo_flops(cfg, shape)
    ana = analytic_cost(cfg, shape, {"data": 1, "model": 1}).flops
    assert 0.8 < ana / hlo < 1.25, f"analytic {ana:.3e} vs hlo {hlo:.3e}"


def test_analytic_flops_moe_unrolled():
    cfg = smoke_config("olmoe-1b-7b", scan_layers=False, n_layers=2,
                       remat="none", attention_impl="reference",
                       grad_accum=1, moe_impl="dense")
    shape = ShapeConfig("t", 32, 4, "train")
    hlo = _hlo_flops(cfg, shape)
    ana = analytic_cost(cfg, shape, {"data": 1, "model": 1}).flops
    # dense one-hot dispatch adds dispatch-einsum flops the analytic EP
    # model does not charge; require same order of magnitude + lower bound
    assert ana <= hlo * 1.3
    assert ana > hlo * 0.2


def test_analytic_scales_linearly_in_depth_and_tokens():
    cfg = smoke_config("deepseek-7b")
    s1 = ShapeConfig("a", 64, 4, "train")
    s2 = ShapeConfig("b", 64, 8, "train")
    mesh = {"data": 1, "model": 1}
    c1 = analytic_cost(cfg, s1, mesh).flops
    c2 = analytic_cost(cfg, s2, mesh).flops
    assert abs(c2 / c1 - 2.0) < 0.05
    cfg2 = dataclasses.replace(cfg, n_layers=cfg.n_layers * 2)
    c3 = analytic_cost(cfg2, s1, mesh).flops
    assert c3 > c1 * 1.5


def test_collective_model_tp_vs_dp():
    """Pure TP has all-reduces, no FSDP gathers; pure DP the reverse."""
    cfg = smoke_config("deepseek-7b")
    shape = ShapeConfig("t", 128, 16, "train")
    tp = analytic_cost(cfg, shape, {"data": 1, "model": 16})
    dp = analytic_cost(cfg, shape, {"data": 16, "model": 1})
    assert tp.coll.get("all-reduce", 0) > 0
    assert tp.coll.get("all-gather", 0) == 0
    assert dp.coll.get("all-gather", 0) > 0
