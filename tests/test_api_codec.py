"""API v1 codec contracts: deterministic, byte-stable JSON round trips for
every envelope type — property-based over arbitrary payloads (NaN/inf
deadlines, unicode job names, error envelopes) plus golden-pinned sample
encodings (a diff in the goldens is a wire-format break)."""
import json
import math
import os

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # deterministic example sweeps
    from _hyp_fallback import given, settings, strategies as st

from repro.api import codec
from repro.api.types import (AuthedRequest, ChooseRequest, ChooseResult,
                             CompactRequest, CompactResult,
                             ContributeRequest, ContributeResult,
                             HealthResult, JobInfo, LaneSnapshot,
                             ModelErrorsRequest, ModelErrorsResult,
                             PredictRequest, PredictResult, Response,
                             SearchRequest, SearchResult, StatsResult,
                             TrustStateRequest, TrustStateResult)

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "goldens",
                           "api_v1.json")

#: job-name pool: plain ASCII, unicode, TSV-hostile characters
_JOBS = ("grep", "sørt-üser", "ページランク", "k\tmeans?", "job with spaces",
         '"quoted"')
_CONTRIBUTORS = ("unknown", "alice", "üser-42", "did:user:0x9f")
_SPECIALS = (math.nan, math.inf, -math.inf, 0.0, -0.0, 1e-300, 1e300)


def golden_samples():
    """The pinned wire-format corpus: one representative of every message
    type (regenerate DELIBERATELY with
    ``PYTHONPATH=src python tests/make_api_goldens.py``)."""
    return {
        "predict_request": PredictRequest(
            "grep", "m5.xlarge", ((4.0, 15.0, 0.02), (8.0, 15.0, 0.08))),
        "choose_request_nan_deadline": ChooseRequest(
            "sørt-üser", (12.5, 0.02), t_max=math.nan),
        "contribute_request": ContributeRequest(
            "grep", ("m5.xlarge", "c5.xlarge"),
            ((4.0, 15.0, 0.02), (8.0, 15.0, 0.08)), (120.5, 64.25),
            contributor_id="alice"),
        "model_errors_request": ModelErrorsRequest(
            "grep", "m5.xlarge", ((4.0, 15.0, 0.02),), (120.5,),
            track_models=("linreg", "gbm")),
        "search_request": SearchRequest("pagerank"),
        "choose_response": Response.success(ChooseResult(
            "c5.xlarge", 4, 174.8, 196.1, 0.0165, False)),
        "contribute_response": Response.success(ContributeResult(
            True, 0.031, 0.029, "accepted", "alice", 166, 1, "ab12" * 16)),
        "predict_response_inf_sigma": Response.success(PredictResult(
            (100.2, math.inf), "ogb", -3.8, math.nan)),
        "model_errors_response": Response.success(ModelErrorsResult(
            (("c3o", 0.003, 0.44), ("linreg", 0.31, 42.0)), "gbm")),
        "search_response": Response.success(SearchResult((JobInfo(
            "grep", "grep", 162, ("m5.xlarge",), ("ernest", "gbm"),
            (("alice", 4), ("unknown", 162))),))),
        "error_envelope": Response.failure(
            "unknown_job", "no published repo for job 'nope'"),
        # trust plane: token-wrapped requests, trust inspection, and the
        # typed refusal envelopes (unauthorized / quota_exceeded) plus the
        # lane-deadline timeout envelope
        "authed_choose_request": AuthedRequest(
            token="a3f1" * 8,
            request=ChooseRequest("grep", (15.0, 0.02), t_max=300.0)),
        "trust_state_request": TrustStateRequest("alice"),
        "trust_state_response": Response.success(TrustStateResult(
            "alice", True, False, 87.5,
            (("grep", 0.75, 3, 1), ("sort", 0.5, 0, 0)))),
        "trust_state_response_unmetered": Response.success(TrustStateResult(
            "üser-42", False, True, math.inf, ())),
        "unauthorized_envelope": Response.failure(
            "unauthorized", "unknown or revoked token"),
        "quota_envelope": Response.failure(
            "quota_exceeded", "rate quota exhausted for contributor "
            "'alice' (sustained 50/s, burst 100)"),
        "timeout_envelope": Response.failure(
            "timeout", "micro-batch dispatch exceeded its 0.25s deadline "
            "(3 request(s) affected)"),
        # store lifecycle: the operator-only compact op, its accepted
        # verdict, and a declined compaction (an ok envelope — a verdict,
        # not a transport failure)
        "compact_request": AuthedRequest(
            token="b2c4" * 8,
            request=CompactRequest("grep", max_rows_per_cell=2,
                                   support_floor=1, cell_rel_width=0.2,
                                   accuracy_budget=0.02, min_store_rows=32,
                                   seed=7)),
        "compact_response": Response.success(CompactResult(
            True, "compacted", "compacted 10000 -> 648 rows over 162 cells",
            10000, 648, 1, 162, 0.0096, 0.0095, 4, "cd34" * 16)),
        "compact_response_rejected": Response.success(CompactResult(
            False, "compaction_rejected",
            "store too small to compact: 42 rows < min_store_rows=64",
            42, 42, 1, 0, math.nan, math.nan, 3, "ef56" * 16)),
        # serving edge: GET /healthz and GET /stats payloads plus the
        # typed drain refusal every API op answers mid-shutdown
        "health_response": Response.success(HealthResult(
            "ok", "v1", ("grep", "sort"))),
        "health_response_draining": Response.success(HealthResult(
            "draining", "v1", ("grep",))),
        "stats_response": Response.success(StatsResult(
            1024, 3, 7, False, 12.25, 48.5, 96.125,
            (LaneSnapshot("grep", 238, 18, 13.2, 10.5, 30.25, 41.0),
             LaneSnapshot("grep@m5.xlarge#seed=7", 89, 17, 5.2, math.nan,
                          math.nan, math.nan)))),
        "shutting_down_envelope": Response.failure(
            "shutting_down", "edge is draining for shutdown; retry "
            "against another replica"),
        # cold-start transfer: answers borrowed from a donor job's models
        # carry transfer_source + a discounted transfer_confidence;
        # self-served envelopes omit both keys entirely (see the
        # omit-default samples above, which stay byte-identical)
        "predict_response_transfer": Response.success(PredictResult(
            (182.4, 96.75), "gbm", -2.1, 0.12,
            transfer_source="grep", transfer_confidence=0.56)),
        "choose_response_transfer": Response.success(ChooseResult(
            "m5.xlarge", 6, 210.0, 233.5, 0.021, True,
            transfer_source="sørt-üser", transfer_confidence=0.2)),
        # cloud market plane: placement-constrained requests, market-mode
        # answers stamped with the placement bought + the naive-vs-
        # adjusted cost breakdown, and the typed refusal for a placement
        # the book does not price (market-less envelopes above stay
        # byte-identical via the same omit-default mechanism)
        "choose_request_market": ChooseRequest(
            "grep", (15.0, 0.02), t_max=400.0,
            zones=("az-1a", "az-1b"), purchase_options=("spot",)),
        "choose_response_market": Response.success(ChooseResult(
            "c5.xlarge", 4, 174.8, 196.1, 0.0165, False,
            zone="az-1b", purchase_option="spot",
            expected_cost_usd=0.0184)),
        "placement_envelope": Response.failure(
            "bad_request", "unknown zone 'mars' (known zones: az-1a, "
            "az-1b, az-1c)"),
    }


# --------------------------------------------------------------------------
# golden-pinned wire format
# --------------------------------------------------------------------------

def test_golden_sample_encodings():
    with open(GOLDEN_PATH) as f:
        golden = json.load(f)
    samples = golden_samples()
    assert set(golden) == set(samples)
    for name, obj in samples.items():
        assert codec.encode(obj) == golden[name], \
            f"wire format drifted for {name}"
        back = codec.decode(golden[name])
        assert codec.encode(back) == golden[name]


def test_pre_epoch_jobinfo_payload_decodes_with_defaults():
    """JobInfo payloads minted before the store-lifecycle fields existed
    (no epoch/compactions/rows_contributed keys) still decode — the new
    fields default to the pre-epoch reading."""
    info = JobInfo("grep", "grep", 10, ("m5.xlarge",), ("gbm",),
                   (("alice", 10),))
    payload = json.loads(codec.encode(info))
    for k in ("epoch", "compactions", "rows_contributed"):
        payload.pop(k)
    back = codec.decode(json.dumps(payload))
    assert (back.epoch, back.compactions, back.rows_contributed) == (0, 0, 0)
    assert (back.job, back.rows) == ("grep", 10)


def test_pre_transfer_result_payloads_decode_with_defaults():
    """Result payloads minted before cold-start transfer existed (no
    transfer_source/transfer_confidence keys) decode to the self-served
    reading and re-encode byte-identically — the omit-default mechanism
    makes the legacy wire form THE canonical form for non-borrowed
    answers."""
    for legacy in (PredictResult((100.2,), "gbm", -3.8, 0.1),
                   ChooseResult("c5.xlarge", 4, 174.8, 196.1, 0.0165,
                                False)):
        text = codec.encode(legacy)
        assert "transfer" not in text
        back = codec.decode(text)
        assert back.transfer_source == ""
        assert back.transfer_confidence == 1.0
        assert codec.encode(back) == text


def test_pre_market_payloads_decode_with_defaults():
    """Choose payloads minted before the cloud market plane existed (no
    zones/purchase_options on requests, no zone/purchase_option/
    expected_cost_usd on results) decode to the static-price reading and
    re-encode byte-identically — the legacy wire form stays THE
    canonical form for market-less gateways."""
    req = ChooseRequest("grep", (15.0, 0.02), t_max=300.0)
    text = codec.encode(req)
    assert "zones" not in text and "purchase" not in text
    back = codec.decode(text)
    assert back.zones is None and back.purchase_options is None
    assert codec.encode(back) == text

    res = ChooseResult("c5.xlarge", 4, 174.8, 196.1, 0.0165, False)
    text = codec.encode(res)
    for key in ("zone", "purchase_option", "expected_cost_usd"):
        assert key not in text
    back = codec.decode(text)
    assert (back.zone, back.purchase_option, back.expected_cost_usd) \
        == ("", "", 0.0)
    assert codec.encode(back) == text
    # and the round trip back to the core dataclass carries the defaults
    choice = back.to_choice()
    assert (choice.zone, choice.purchase_option,
            choice.expected_cost_usd) == ("", "", 0.0)


def test_api_docs_are_current():
    """``docs/api_v1.md`` is generated from the live surface + goldens;
    any drift (new op, new error code, changed sample) fails here until
    ``PYTHONPATH=src python tests/make_api_docs.py`` is re-run."""
    import make_api_docs
    path = os.path.join(os.path.dirname(__file__), "..", "docs",
                        "api_v1.md")
    with open(path) as f:
        current = f.read()
    assert current == make_api_docs.render(), \
        "docs/api_v1.md is stale — regenerate with " \
        "PYTHONPATH=src python tests/make_api_docs.py"


def test_encoding_is_strict_json():
    """Every encoding parses under strict JSON rules (no NaN literals) —
    what makes the format consumable by non-Python HTTP peers."""
    for name, obj in golden_samples().items():
        parsed = json.loads(codec.encode(obj), parse_constant=lambda s: (
            _ for _ in ()).throw(AssertionError(f"{name}: non-strict {s}")))
        assert isinstance(parsed, dict)


# --------------------------------------------------------------------------
# property-based round trips
# --------------------------------------------------------------------------

def _eq(a, b):
    """Structural equality with NaN == NaN (dataclass __eq__ breaks on
    NaN fields, which are legal deadline/metric values)."""
    if isinstance(a, float) and isinstance(b, float):
        return (math.isnan(a) and math.isnan(b)) or a == b
    if type(a) is not type(b):
        return False
    if isinstance(a, tuple):
        return len(a) == len(b) and all(_eq(x, y) for x, y in zip(a, b))
    if hasattr(a, "__dataclass_fields__"):
        return all(_eq(getattr(a, f), getattr(b, f))
                   for f in a.__dataclass_fields__)
    return a == b


def _assert_roundtrip(msg):
    text = codec.encode(msg)
    back = codec.decode(text)
    assert _eq(back, msg), (msg, back)
    assert codec.encode(back) == text            # byte-stable


@settings(max_examples=40, deadline=None)
@given(job=st.sampled_from(_JOBS), c0=st.floats(-1e6, 1e6),
       special=st.sampled_from(_SPECIALS), use_special=st.booleans(),
       seed=st.integers(0, 2**31 - 1))
def test_choose_request_roundtrip(job, c0, special, use_special, seed):
    t_max = special if use_special else abs(c0) + 1.0
    _assert_roundtrip(ChooseRequest(job, (c0, special), t_max=t_max,
                                    seed=seed))


@settings(max_examples=30, deadline=None)
@given(job=st.sampled_from(_JOBS), contributor=st.sampled_from(_CONTRIBUTORS),
       n=st.integers(1, 5), v=st.floats(0.001, 1e9))
def test_contribute_request_roundtrip(job, contributor, n, v):
    _assert_roundtrip(ContributeRequest(
        job, ("m5.xlarge",) * n, tuple((float(i), v) for i in range(n)),
        tuple(v + i for i in range(n)), contributor_id=contributor))


@settings(max_examples=30, deadline=None)
@given(mape=st.sampled_from(_SPECIALS), rows=st.integers(0, 10**9),
       accepted=st.booleans(), job=st.sampled_from(_JOBS))
def test_result_envelope_roundtrip(mape, rows, accepted, job):
    _assert_roundtrip(Response.success(ContributeResult(
        accepted, mape, mape, f"verdict for {job}", "üser", rows, 3, "ff00")))
    _assert_roundtrip(Response.success(SearchResult((JobInfo(
        job, job, rows, ("m5.xlarge", "c5.xlarge"), ("gbm",),
        (("unknown", rows),)),))))


@settings(max_examples=30, deadline=None)
@given(code=st.sampled_from(("unknown_job", "bad_request", "internal")),
       detail=st.sampled_from(_JOBS))
def test_error_envelope_roundtrip(code, detail):
    msg = Response.failure(code, f"failed: {detail}")
    _assert_roundtrip(msg)
    back = codec.decode(codec.encode(msg))
    assert not back.ok and back.result is None
    assert back.error_code == code


@settings(max_examples=30, deadline=None)
@given(cid=st.sampled_from(_CONTRIBUTORS), rep=st.floats(0.0, 1.0),
       quota=st.sampled_from(_SPECIALS), banned=st.booleans(),
       job=st.sampled_from(_JOBS))
def test_trust_envelope_roundtrip(cid, rep, quota, banned, job):
    """Trust-plane envelopes round-trip byte-stably — including the
    nested request inside an AuthedRequest wrapper and the +inf
    quota_remaining of an unmetered gateway."""
    _assert_roundtrip(AuthedRequest(
        token="ff" * 16, request=TrustStateRequest(cid)))
    _assert_roundtrip(AuthedRequest(
        token="00" * 16,
        request=ChooseRequest(job, (1.0, rep), t_max=quota)))
    _assert_roundtrip(Response.success(TrustStateResult(
        cid, True, banned, quota, ((job, rep, 2, 1),))))


@settings(max_examples=30, deadline=None)
@given(job=st.sampled_from(_JOBS), draining=st.booleans(),
       p=st.sampled_from(_SPECIALS), requests=st.integers(0, 10**9),
       mean_batch=st.floats(0.0, 256.0))
def test_serving_envelope_roundtrip(job, draining, p, requests, mean_batch):
    """Serving-edge envelopes round-trip byte-stably — including NaN
    percentiles on never-dispatched lanes and the drain refusal."""
    _assert_roundtrip(Response.success(HealthResult(
        "draining" if draining else "ok", "v1", (job, "sort"))))
    _assert_roundtrip(Response.success(StatsResult(
        requests, 0, 3, draining, p, p, p,
        (LaneSnapshot(job, requests, 2, mean_batch, p, p, p),
         LaneSnapshot(f"{job}@m5.xlarge", 0, 0, 0.0, math.nan, math.nan,
                      math.nan)))))
    msg = Response.failure("shutting_down", f"draining; retry {job}")
    _assert_roundtrip(msg)
    back = codec.decode(codec.encode(msg))
    assert not back.ok and back.error_code == "shutting_down"


def test_unencodable_value_raises():
    try:
        codec.encode(object())
    except TypeError:
        pass
    else:                                 # pragma: no cover
        raise AssertionError("expected TypeError for non-API payloads")
