"""Hub Gateway API v1 contracts: request-for-request parity with the
legacy direct object path (choices, validation reports, model-error
tables), error envelopes, contributor provenance threading, per-job batch
lanes, and backward compatibility for pre-provenance TSV stores."""
import asyncio
import hashlib
import math

import numpy as np
import pytest

from repro.api import (AsyncHubGateway, ChooseRequest, ContributeRequest,
                       HubGateway, ModelErrorsRequest, PredictRequest,
                       SearchRequest)
from repro.core.datastore import RuntimeDataStore
from repro.core.hub import Hub, JobRepo
from repro.core.service import ConfigurationService
from repro.eval.dataset import split_by_contributor
from repro.workloads import spark_emul as W

SCALEOUTS = (2, 3, 4, 6, 8, 12, 16)
PRICES = {m.name: m.price for m in W.MACHINES.values()}
JOBS = ("grep", "sort")


def _hub(seed=0):
    hub = Hub()
    for job in JOBS:
        d = W.generate_job_data(job, seed=seed)
        hub.publish(JobRepo(job, job, d.schema, RuntimeDataStore(d, seed=0)))
    return hub


@pytest.fixture()
def hub():
    return _hub()


@pytest.fixture()
def gateway(hub):
    return HubGateway(hub, PRICES, SCALEOUTS)


def _contexts(job, n, seed=3):
    rng = np.random.default_rng(seed)
    if job == "grep":
        return [(float(rng.uniform(10, 20)),
                 float(rng.choice([.002, .02, .08]))) for _ in range(n)]
    return [(float(rng.uniform(10, 30)),) for _ in range(n)]


# --------------------------------------------------------------------------
# parity with the legacy direct path
# --------------------------------------------------------------------------

def test_choose_parity_with_direct_service(hub, gateway):
    for job in JOBS:
        svc = ConfigurationService.from_repo(hub.get(job), None, PRICES,
                                             SCALEOUTS)
        ctxs = _contexts(job, 6)
        t_maxes = [math.nan, 300.0, 450.0, math.nan, 600.0, 250.0]
        want = svc.choose_cluster_batch(np.asarray(ctxs),
                                        np.asarray(t_maxes))
        for ctx, tm, w in zip(ctxs, t_maxes, want):
            resp = gateway.choose(ChooseRequest(job, ctx, t_max=tm))
            assert resp.ok
            assert resp.result.to_choice() == w


def test_predict_parity_with_predictor_for(hub, gateway):
    repo = hub.get("grep")
    pred = repo.predictor_for("m5.xlarge")
    rows = ((4.0, 15.0, 0.02), (8.0, 12.0, 0.08), (16.0, 19.0, 0.002))
    resp = gateway.predict(PredictRequest("grep", "m5.xlarge", rows))
    assert resp.ok
    np.testing.assert_allclose(resp.result.runtimes_s,
                               pred.predict(np.asarray(rows)))
    assert resp.result.selected_model == pred.selected
    np.testing.assert_allclose((resp.result.mu, resp.result.sigma),
                               (pred.mu, pred.sigma))


def test_contribute_parity_and_provenance(gateway):
    """The gateway's report matches a byte-identical store driven through
    the legacy direct path, and the contributor id lands on the rows."""
    shadow = _hub()                        # independent identical store
    base = W.generate_job_data("grep")
    sub = base.subset(np.arange(6))
    req = ContributeRequest("grep", tuple(sub.machine_type),
                            tuple(map(tuple, sub.X)), tuple(sub.y),
                            contributor_id="alice")
    resp = gateway.contribute(req)
    direct = shadow.get("grep").store.contribute(sub, contributor="alice")
    assert resp.ok
    got = resp.result
    assert got.accepted == direct.accepted
    np.testing.assert_allclose(got.baseline_mape, direct.baseline_mape)
    np.testing.assert_allclose(got.candidate_mape, direct.candidate_mape)
    assert got.reason == direct.reason
    assert got.fingerprint == shadow.get("grep").store.fingerprint
    assert got.store_version == 1
    stats = gateway.contributor_stats("grep")
    assert stats.ok and ("alice", 6) in stats.result


def test_model_errors_parity(hub, gateway):
    repo = hub.get("grep")
    test = W.generate_job_data("grep", seed=9)
    sub = test.machine_view("m5.xlarge").subset(np.arange(8))
    resp = gateway.model_errors(ModelErrorsRequest(
        "grep", "m5.xlarge", tuple(map(tuple, sub.X)), tuple(sub.y),
        track_models=("linreg", "gbm")))
    errs, selected = repo.model_errors("m5.xlarge", sub,
                                       track_models=("linreg", "gbm"))
    assert resp.ok
    assert resp.result.selected_model == selected
    assert dict((m, (mape, mae)) for m, mape, mae in resp.result.errors) \
        == {m: (float(a), float(b)) for m, (a, b) in errs.items()}


def test_search_lists_repo_metadata(gateway):
    resp = gateway.search(SearchRequest(""))
    assert resp.ok
    assert tuple(j.job for j in resp.result.jobs) == ("grep", "sort")
    grep = resp.result.jobs[0]
    assert grep.rows == 162 and set(grep.machines) == set(W.MACHINES)
    assert grep.contributors == (("unknown", 162),)
    hit = gateway.search(SearchRequest("sort"))
    assert [j.job for j in hit.result.jobs] == ["sort"]


# --------------------------------------------------------------------------
# error envelopes (never exceptions)
# --------------------------------------------------------------------------

def test_unknown_job_is_an_error_envelope(gateway):
    for resp in (gateway.choose(ChooseRequest("nope", (1.0, 2.0))),
                 gateway.predict(PredictRequest("nope", "m5.xlarge",
                                                ((2.0, 1.0, 0.1),))),
                 gateway.contributor_stats("nope")):
        assert not resp.ok and resp.result is None
        assert resp.error_code == "unknown_job"
        assert "nope" in resp.detail


def test_malformed_requests_are_bad_request(gateway):
    bad = [
        ChooseRequest("grep", (1.0,)),                 # wrong context width
        PredictRequest("grep", "m5.xlarge", ((1.0, 2.0),)),  # wrong row dim
        PredictRequest("grep", "z9.xlarge",            # unknown machine
                       ((2.0, 15.0, 0.02),)),
        ContributeRequest("grep", ("m5.xlarge",),      # row count mismatch
                          ((2.0, 15.0, 0.02),), (1.0, 2.0)),
        "not a request",                               # not an envelope
    ]
    for req in bad:
        resp = gateway.handle(req)
        assert not resp.ok and resp.error_code == "bad_request", req


# --------------------------------------------------------------------------
# store-version tracking
# --------------------------------------------------------------------------

def test_accepted_contribution_refreshes_served_choices(gateway):
    """The per-job service cache is store-version keyed: an accepted
    contribution rebuilds it, so post-contribution choices come from the
    updated predictors (parity with a service built fresh)."""
    ctx = _contexts("grep", 1)[0]
    assert gateway.choose(ChooseRequest("grep", ctx)).ok
    base = W.generate_job_data("grep")
    rng = np.random.default_rng(1)
    idx = rng.choice(len(base), 40, replace=False)
    sub = base.subset(np.sort(idx))
    sub.y = sub.y * 1.04                    # benign drift, accepted
    resp = gateway.contribute(ContributeRequest(
        "grep", tuple(sub.machine_type), tuple(map(tuple, sub.X)),
        tuple(sub.y), contributor_id="bob"))
    assert resp.ok and resp.result.accepted
    fresh = ConfigurationService.from_repo(gateway.hub.get("grep"), None,
                                           PRICES, SCALEOUTS)
    want = fresh.choose_cluster_batch(np.asarray([ctx]),
                                      np.asarray([math.nan]))[0]
    got = gateway.choose(ChooseRequest("grep", ctx))
    assert got.ok and got.result.to_choice() == want


def test_custom_model_registration_invalidates_served_choices(gateway):
    """The service cache keys on the model-spec OBJECTS (the same
    contract as JobRepo.predictor_for): a maintainer registering a custom
    model after the gateway has served must change subsequent choices'
    predictor pool, not serve from the stale pool forever."""
    from repro.core.models.api import ModelSpec, get_model
    ctx = _contexts("grep", 1)[0]
    assert gateway.choose(ChooseRequest("grep", ctx)).ok   # cache warm
    repo = gateway.hub.get("grep")
    lin = get_model("linreg")
    repo.add_custom_model(ModelSpec("gw_custom", lin.make_aux, lin.fit,
                                    lin.predict))
    fresh = ConfigurationService.from_repo(repo, None, PRICES, SCALEOUTS)
    want = fresh.choose_cluster_batch(np.asarray([ctx]),
                                      np.asarray([math.nan]))[0]
    got = gateway.choose(ChooseRequest("grep", ctx))
    assert got.ok and got.result.to_choice() == want
    # search metadata refreshes too (model list is part of the key)
    hit = gateway.search(SearchRequest("grep")).result.jobs[0]
    assert "gw_custom" in hit.models


# --------------------------------------------------------------------------
# per-job micro-batch lanes
# --------------------------------------------------------------------------

def test_async_lanes_coalesce_per_job_and_match_sync(gateway):
    n = 24
    reqs = ([ChooseRequest("grep", c, t_max=400.0)
             for c in _contexts("grep", n)]
            + [ChooseRequest("sort", c) for c in _contexts("sort", n)])

    async def drive():
        async with AsyncHubGateway(gateway, max_batch=64) as agw:
            got = await asyncio.gather(*[agw.choose(q) for q in reqs])
            return got, {j: (s.requests, s.batches)
                         for j, s in agw.lane_stats.items()}

    got, stats = asyncio.run(drive())
    assert all(r.ok for r in got)
    assert set(stats) == {"grep", "sort"}
    for job in JOBS:
        requests, batches = stats[job]
        assert requests == n
        assert batches < n                 # concurrent arrivals coalesced
    for req, resp in zip(reqs, got):
        assert resp.result.to_choice() == \
            gateway.choose(req).result.to_choice()


def test_async_lane_rejects_bad_width_without_poisoning_batch(gateway):
    """Regression (micro-batch poisoning): one wrong-width request used to
    blow up the whole batch pack and fan the exception out to every
    concurrent caller.  It must now fail alone, as a bad_request envelope,
    while the good requests in the same tick are answered."""
    good = [ChooseRequest("grep", c, t_max=400.0)
            for c in _contexts("grep", 8)]
    bad = ChooseRequest("grep", (15.0,))          # width 1, schema wants 2

    async def drive():
        async with AsyncHubGateway(gateway, max_batch=64) as agw:
            return await asyncio.gather(
                *([agw.choose(q) for q in good[:4]]
                  + [agw.choose(bad)]
                  + [agw.choose(q) for q in good[4:]]))

    results = asyncio.run(drive())
    assert sum(r.ok for r in results) == len(good)
    (bad_resp,) = [r for r in results if not r.ok]
    assert bad_resp.error_code == "bad_request"
    for req, resp in zip(good, [r for r in results if r.ok]):
        assert resp.result.to_choice() == \
            gateway.choose(req).result.to_choice()


def test_async_lane_survives_non_numeric_content(gateway):
    """Regression: a width-correct context with non-numeric content used
    to blow up the worker's batch pack OUTSIDE the dispatch guard —
    cancelling every concurrent request, killing the worker, and hanging
    all later submits.  Content is now validated at enqueue: the bad
    request alone gets bad_request, its tick's good requests are served,
    and the lane keeps serving."""
    good = [ChooseRequest("grep", c, t_max=400.0)
            for c in _contexts("grep", 6)]
    bad = ChooseRequest("grep", (15.0, "oops"))    # width ok, content not

    async def drive():
        async with AsyncHubGateway(gateway, max_batch=64) as agw:
            results = await asyncio.gather(
                *([agw.choose(q) for q in good[:3]]
                  + [agw.choose(bad)]
                  + [agw.choose(q) for q in good[3:]]))
            late = await asyncio.wait_for(agw.choose(good[0]), timeout=30)
            return results, late

    results, late = asyncio.run(drive())
    (bad_resp,) = [r for r in results if not r.ok]
    assert bad_resp.error_code == "bad_request"
    assert sum(r.ok for r in results) == len(good)
    assert late.ok


def test_choose_seed_is_threaded_to_the_service(hub, gateway):
    """ChooseRequest.seed must select the same predictor state a direct
    ConfigurationService built with that seed uses (parity with how
    PredictRequest/ModelErrorsRequest thread their seeds)."""
    ctx = _contexts("grep", 1)[0]
    svc7 = ConfigurationService.from_repo(hub.get("grep"), None, PRICES,
                                          SCALEOUTS, seed=7)
    want = svc7.choose_cluster_batch(np.asarray([ctx]),
                                     np.asarray([math.nan]))[0]
    got = gateway.choose(ChooseRequest("grep", ctx, seed=7))
    assert got.ok and got.result.to_choice() == want

    async def drive():
        async with AsyncHubGateway(gateway) as agw:
            resp = await agw.choose(ChooseRequest("grep", ctx, seed=7))
            return resp, set(agw.lane_stats)

    resp, lanes = asyncio.run(drive())
    assert resp.ok and resp.result.to_choice() == want
    assert lanes == {"grep#seed=7"}        # non-default seed: its own lane


def test_async_gateway_serves_again_after_stop(gateway):
    """Regression: stop() used to retain stopped lanes, so a choose()
    after re-entering the gateway enqueued onto a dead worker and hung
    forever.  Lanes are dropped on stop and recreated on demand."""
    agw = AsyncHubGateway(gateway, max_batch=16)
    req = ChooseRequest("grep", _contexts("grep", 1)[0], t_max=400.0)

    async def drive():
        async with agw:
            first = await asyncio.wait_for(agw.choose(req), timeout=30)
        async with agw:                    # re-entered after stop()
            second = await asyncio.wait_for(agw.choose(req), timeout=30)
        return first, second

    first, second = asyncio.run(drive())
    assert first.ok and second.ok
    assert first.result == second.result


def test_contribute_rejects_tsv_delimiter_injection(gateway):
    """Contributor ids and machine names are TSV column values: anything
    the codec cannot round-trip -- tab, ANY line-breaking character
    (splitlines splits on \\v/\\x85/U+2028 too), or edge whitespace
    (silently stripped on reload, changing the value and therefore the
    fingerprint) -- would shear or mutate the persisted store, so
    ingestion refuses it as bad_request (store untouched)."""
    base = W.generate_job_data("grep")
    sub = base.subset(np.arange(4))
    ok_rows = (tuple(sub.machine_type), tuple(map(tuple, sub.X)),
               tuple(sub.y))
    for cid in ("a\tb", "a\nb", "a\x0bb", "a\x85b", "a\u2028b",
                "bob ", " bob", ""):
        resp = gateway.contribute(ContributeRequest(
            "grep", *ok_rows, contributor_id=cid))
        assert not resp.ok and resp.error_code == "bad_request", repr(cid)
    for machine in ("m5\txlarge", "m5\x0bxlarge", "m5 "):
        resp = gateway.contribute(ContributeRequest(
            "grep", (machine,) * 4, *ok_rows[1:], contributor_id="alice"))
        assert not resp.ok and resp.error_code == "bad_request", \
            repr(machine)
    assert gateway.hub.get("grep").store.version == 0
    # the legacy direct path funnels through the same chokepoint
    from repro.core.features import RuntimeData
    repo = gateway.hub.get("grep")
    bad = RuntimeData(repo.schema, np.asarray(["m5\tlarge"] * 4),
                      sub.X, sub.y)
    with pytest.raises(ValueError, match="TSV"):
        repo.contribute(bad)
    with pytest.raises(ValueError, match="TSV"):
        repo.contribute(sub, contributor="eve\u2029")
    # per-row provenance smuggled through from_columns (which skips the
    # constructors' validation) is caught at the chokepoint too
    smuggled = RuntimeData.from_columns(
        repo.schema, sub.machines, sub.codes, sub.scale_out, sub.context,
        sub.runtime, contributors=("evil\tname",),
        ccodes=np.zeros(len(sub), np.int32))
    with pytest.raises(ValueError, match="TSV"):
        repo.contribute(smuggled)
    assert repo.store.version == 0


def test_lane_cap_evicts_seed_sprayed_lanes(gateway, monkeypatch):
    """The request seed is client-supplied: without a cap, seed-spraying
    traffic would leak one lane (live worker task + service) per distinct
    seed.  The LRU cap bounds live lanes; steady traffic never hits it."""
    monkeypatch.setattr(AsyncHubGateway, "MAX_LANES", 2)
    ctx = _contexts("grep", 1)[0]

    async def drive():
        async with AsyncHubGateway(gateway) as agw:
            for s in (1, 2, 3):
                r = await agw.choose(ChooseRequest("grep", ctx, seed=s))
                assert r.ok
            return set(agw.lane_stats)

    lanes = asyncio.run(drive())
    assert len(lanes) == 2
    assert "grep#seed=3" in lanes          # newest survives


def test_async_unknown_job_does_not_create_a_lane(gateway):
    async def drive():
        async with AsyncHubGateway(gateway) as agw:
            resp = await agw.choose(ChooseRequest("nope", (1.0, 2.0)))
            return resp, dict(agw.lane_stats)

    resp, lanes = asyncio.run(drive())
    assert not resp.ok and resp.error_code == "unknown_job"
    assert lanes == {}


# --------------------------------------------------------------------------
# predict batch lanes
# --------------------------------------------------------------------------

def test_predict_lanes_coalesce_and_match_inline_byte_for_byte(gateway):
    """Concurrent single-row predicts coalesce onto per-(job, machine)
    lanes, and every lane answer is BYTE-identical (codec-encoded) to the
    inline sync path's answer for the same row — the serving edge's
    batching must be invisible in the response bytes."""
    from repro.api import encode
    rng = np.random.default_rng(7)
    reqs = [PredictRequest("grep",
                           ["m5.xlarge", "c5.xlarge"][i % 2],
                           ((float(rng.choice(SCALEOUTS)),
                             float(rng.uniform(10, 20)),
                             float(rng.choice([.002, .02, .08]))),))
            for i in range(24)]

    async def drive():
        async with AsyncHubGateway(gateway, max_batch=64) as agw:
            got = await asyncio.gather(*[agw.predict(q) for q in reqs])
            return got, {j: (s.requests, s.batches)
                         for j, s in agw.lane_stats.items()}

    got, stats = asyncio.run(drive())
    assert all(r.ok for r in got)
    assert set(stats) == {"grep@m5.xlarge", "grep@c5.xlarge"}
    for requests, batches in stats.values():
        assert requests == 12
        assert batches < 12                # concurrent arrivals coalesced
    for req, resp in zip(reqs, got):
        assert encode(resp) == encode(gateway.predict(req))


def test_multi_row_predict_bypasses_the_lanes(gateway):
    """Explicit multi-row requests answer inline (one envelope for all
    rows); only single-row traffic rides the coalescing lanes."""
    from repro.api import encode
    req = PredictRequest("grep", "m5.xlarge",
                         ((4.0, 15.0, 0.02), (8.0, 12.0, 0.08)))

    async def drive():
        async with AsyncHubGateway(gateway) as agw:
            resp = await agw.predict(req)
            return resp, dict(agw.lane_stats)

    resp, lanes = asyncio.run(drive())
    assert resp.ok and len(resp.result.runtimes_s) == 2
    assert lanes == {}                     # no lane was created
    assert encode(resp) == encode(gateway.predict(req))


def test_predict_lane_invalidates_on_store_version(gateway):
    """An accepted contribution bumps the store version; the next
    single-row predict must fit against the GROWN store (a fresh lane
    keyed on the new version replaces the superseded one, so the stale
    dispatch closure cannot serve pre-contribution predictions)."""
    row = ((4.0, 15.0, 0.02),)
    req = PredictRequest("grep", "m5.xlarge", row)
    base = W.generate_job_data("grep")
    sub = base.subset(np.arange(8))
    contrib = ContributeRequest("grep", tuple(sub.machine_type),
                                tuple(map(tuple, sub.X)), tuple(sub.y),
                                contributor_id="lane-test")

    async def drive():
        async with AsyncHubGateway(gateway) as agw:
            before = await agw.predict(req)
            accepted = await agw.handle_async(contrib)
            assert accepted.ok and accepted.result.accepted
            after = await agw.predict(req)
            return before, after, list(agw.lane_stats)

    before, after, lanes = asyncio.run(drive())
    assert before.ok and after.ok
    # superseded lane evicted: still exactly one lane for this key
    assert lanes.count("grep@m5.xlarge") == 1
    want = gateway.predict(req)            # sync path sees the new store
    np.testing.assert_array_equal(after.result.runtimes_s,
                                  want.result.runtimes_s)


def test_predict_bad_machine_is_an_envelope_without_a_lane(gateway):
    async def drive():
        async with AsyncHubGateway(gateway) as agw:
            resp = await agw.predict(
                PredictRequest("grep", "warp-drive", ((4.0, 15.0, 0.02),)))
            return resp, dict(agw.lane_stats)

    resp, lanes = asyncio.run(drive())
    assert not resp.ok and resp.error_code == "bad_request"
    assert lanes == {}                     # typo did not leak a lane


# --------------------------------------------------------------------------
# insufficient-data hardening (zero-history / thin stores)
# --------------------------------------------------------------------------

def _thin_repo(hub, job="thin", machine="c5.xlarge", rows=0):
    """Publish a repo whose store KEEPS ``machine`` in the vocabulary but
    holds only ``rows`` rows for it (what subset/compaction leave behind)."""
    d = hub.get("grep").store.data
    idx = np.where(d.machine_type == machine)[0][:rows]
    keep = np.concatenate([np.where(d.machine_type != machine)[0], idx])
    thin = d.subset(np.sort(keep))
    assert machine in thin.machines        # vocabulary outlives the rows
    hub.publish(JobRepo(job, job, d.schema, RuntimeDataStore(thin, seed=0)))


def test_zero_row_vocabulary_machine_is_a_typed_insufficient_data_error(
        gateway, hub):
    """A machine type can stay in the store vocabulary with 0 (or 1) rows
    after subset/compaction; fitting it used to raise IndexError through
    ``_respond`` as an ``internal`` envelope.  It must be a ``bad_request``
    carrying the row counts."""
    for rows in (0, 1):
        job = f"thin{rows}"
        _thin_repo(hub, job=job, rows=rows)
        resp = gateway.predict(PredictRequest(
            job, "c5.xlarge", ((4.0, 15.0, 0.02),)))
        assert resp.status == "error" and resp.error_code == "bad_request"
        assert resp.detail.startswith("insufficient_data:")
        assert f"{rows} stored row(s)" in resp.detail
        assert "c5.xlarge" in resp.detail and job in resp.detail
        # model_errors fits the same predictor: same typed refusal
        errs = gateway.model_errors(ModelErrorsRequest(
            job, "c5.xlarge", ((4.0, 15.0, 0.02), (8.0, 15.0, 0.02)),
            (60.0, 40.0)))
        assert errs.error_code == "bad_request"
        assert errs.detail.startswith("insufficient_data:")
        # other machines of the same store still serve fine
        ok = gateway.predict(PredictRequest(
            job, "m5.xlarge", ((4.0, 15.0, 0.02),)))
        assert ok.ok


def test_async_zero_row_machine_is_an_envelope_without_a_lane(gateway, hub):
    """The insufficient-data refusal happens at admit, BEFORE any lane is
    created (mirror of the unknown-machine lane-hygiene test)."""
    _thin_repo(hub, job="thin", rows=0)

    async def drive():
        async with AsyncHubGateway(gateway) as agw:
            resp = await agw.predict(PredictRequest(
                "thin", "c5.xlarge", ((4.0, 15.0, 0.02),)))
            return resp, dict(agw.lane_stats)

    resp, lanes = asyncio.run(drive())
    assert not resp.ok and resp.error_code == "bad_request"
    assert resp.detail.startswith("insufficient_data:")
    assert lanes == {}                     # refusal did not leak a lane


# --------------------------------------------------------------------------
# provenance backward compatibility
# --------------------------------------------------------------------------

def test_legacy_tsv_store_loads_with_preserved_fingerprint(tmp_path):
    """A pre-provenance TSV file (no contributor column) loads unchanged:
    same rows, same canonical encoding, same fingerprint — and only a
    KNOWN contributor transitions the encoding."""
    data = W.generate_job_data("grep")
    legacy_tsv = data.to_tsv()
    assert "contributor" not in legacy_tsv.splitlines()[0]
    path = tmp_path / "grep.tsv"
    path.write_text(legacy_tsv)
    store = RuntimeDataStore.load(str(path), data.schema)
    assert store.data.to_tsv() == legacy_tsv
    assert store.fingerprint == \
        hashlib.sha256(legacy_tsv.encode()).hexdigest()
    # legacy-format contributions leave the encoding legacy
    sub = data.subset(np.arange(5))
    assert store.contribute(sub).accepted
    assert not store.data.has_provenance
    assert store.fingerprint == hashlib.sha256(
        store.data.to_tsv().encode()).hexdigest()
    # a known contributor transitions to the provenance encoding; the
    # chain re-seeds and keeps matching a full rehash from then on
    assert store.contribute(sub, contributor="alice").accepted
    assert store.data.has_provenance
    assert "contributor" in store.data.to_tsv().splitlines()[0]
    assert store.fingerprint == hashlib.sha256(
        store.data.to_tsv().encode()).hexdigest()
    # provenance TSV round-trips through save/load
    store.save(str(path))
    again = RuntimeDataStore.load(str(path), data.schema)
    assert again.fingerprint == store.fingerprint
    assert again.data.contributor_counts() == \
        store.data.contributor_counts()


def test_split_by_contributor_inverts_contributions():
    data = W.generate_job_data("grep")
    store = RuntimeDataStore(data, seed=0)
    users = {}
    rng = np.random.default_rng(2)
    for name in ("alice", "bob"):
        idx = np.sort(rng.choice(len(data), 12, replace=False))
        users[name] = data.subset(idx)
        assert store.contribute(users[name], contributor=name).accepted
    parts = split_by_contributor(store.data)
    assert set(parts) == {"unknown", "alice", "bob"}
    assert len(parts["unknown"]) == len(data)
    for name, want in users.items():
        got = parts[name]
        np.testing.assert_array_equal(got.y, want.y)
        np.testing.assert_array_equal(got.X, want.X)
        assert (got.contributor == name).all()
