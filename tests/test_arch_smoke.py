"""Per-architecture smoke tests (reduced configs): forward/train shapes + no
NaNs, and the strongest cache-correctness check we have — teacher-forced
decode must reproduce the full forward pass logits position by position
(catches rope offsets, ring buffers, MLA absorbed decode, rwkv/mamba state
carries, cross-attention caches)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import list_archs, smoke_config
from repro.modeling import model as M
from repro.serve.serve_step import make_decode_step, make_prefill_step
from repro.train import train_step as TS

ARCHS = list_archs()


def _batch(cfg, B, S, key):
    out = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.frontend != "none":
        out["frontend"] = jax.random.normal(
            jax.random.fold_in(key, 1), (B, 8, cfg.frontend_dim))
        if cfg.n_encoder_layers == 0:
            out["tokens"] = out["tokens"][:, : S - 8]
    return out


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = smoke_config(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 32
    batch = _batch(cfg, B, S, jax.random.PRNGKey(1))
    logits, _, aux = M.forward(cfg, params, batch, mode="train")
    assert logits.shape == (B, S, cfg.padded_vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_reduces_loss(arch):
    cfg = smoke_config(arch)
    state = TS.init_train_state(cfg, jax.random.PRNGKey(0))
    step = jax.jit(TS.make_train_step(cfg))
    B, S = 4, 32
    key = jax.random.PRNGKey(2)
    batch = _batch(cfg, B, S, key)
    batch["labels"] = jax.random.randint(jax.random.fold_in(key, 9),
                                         batch["tokens"].shape, 0,
                                         cfg.vocab_size)
    losses = []
    for _ in range(5):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
        assert np.isfinite(losses[-1])
    assert losses[-1] < losses[0]          # same batch: must overfit


@pytest.mark.parametrize("arch", ARCHS)
def test_teacher_forced_decode_matches_forward(arch):
    # capacity drops in batched (train) forward are legitimate MoE semantics
    # but break per-token equality -> disable drops for this check
    cfg = smoke_config(arch, capacity_factor=16.0)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 24
    key = jax.random.PRNGKey(3)
    batch = _batch(cfg, B, S, key)
    full_logits, _, _ = M.forward(cfg, params, batch, mode="train")

    cross = 8 if cfg.n_encoder_layers else 0
    max_seq = 48
    cache = M.init_cache(cfg, B, max_seq, cross_seq=cross)
    prefill = jax.jit(make_prefill_step(cfg))
    decode = jax.jit(make_decode_step(cfg))

    tokens = batch["tokens"]
    S_txt = tokens.shape[1]
    split = S_txt - 6                      # prefill most, decode the rest
    pre_batch = dict(batch, tokens=tokens[:, :split])
    logits_last, cache = prefill(params, pre_batch, cache)
    prefix = S - S_txt                     # vlm prefix length inside cache
    np.testing.assert_allclose(
        np.asarray(logits_last), np.asarray(full_logits[:, prefix + split - 1]),
        atol=2e-3, rtol=2e-3)
    pos = prefix + split
    for i in range(split, S_txt):
        logits_i, cache = decode(params, tokens[:, i],
                                 jnp.asarray(pos, jnp.int32), cache)
        np.testing.assert_allclose(
            np.asarray(logits_i), np.asarray(full_logits[:, prefix + i]),
            atol=3e-3, rtol=3e-3,
            err_msg=f"{arch}: decode@{i} diverges from forward")
        pos += 1


def test_gemma3_ring_buffer_long_decode():
    """Windowed ring cache: decoding past the window must stay consistent
    with a full-cache run (window semantics preserved)."""
    cfg = smoke_config("gemma3-1b", window_size=8)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 1, 28
    tokens = jax.random.randint(jax.random.PRNGKey(5), (B, S), 0,
                                cfg.vocab_size)
    full_logits, _, _ = M.forward(cfg, params, {"tokens": tokens},
                                  mode="train")
    cache = M.init_cache(cfg, B, 32)
    prefill = jax.jit(make_prefill_step(cfg))
    decode = jax.jit(make_decode_step(cfg))
    logits, cache = prefill(params, {"tokens": tokens[:, :4]}, cache)
    for i in range(4, S):                 # decode far past the window
        logits, cache = decode(params, tokens[:, i],
                               jnp.asarray(i, jnp.int32), cache)
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(full_logits[:, i]),
                                   atol=3e-3, rtol=3e-3,
                                   err_msg=f"ring decode@{i}")


def test_param_counts_match_actual():
    """cfg.param_counts() (the roofline MODEL_FLOPS source) must equal the
    real parameter tree within 2%."""
    for arch in ARCHS:
        cfg = smoke_config(arch)
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        actual = sum(x.size for x in jax.tree.leaves(params))
        predicted = cfg.param_counts()["total"]
        assert abs(actual - predicted) / actual < 0.02, \
            f"{arch}: predicted {predicted} vs actual {actual}"
