"""Configurator properties (paper §IV) — includes hypothesis invariants."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # graceful degrade: example sweeps
    from _hyp_fallback import given, settings, strategies as st

from repro.core.configurator import Configurator, confidence_margin, \
    choose_machine_type
from repro.core.predictor import C3OPredictor
from repro.workloads import spark_emul as W

SCALEOUTS = [2, 3, 4, 6, 8, 12, 16]
PRICES = {m.name: m.price for m in W.MACHINES.values()}


class _FakePredictor:
    """Deterministic predictor: t(s) = a/s + b*s + c, known error stats."""

    def __init__(self, a=1000.0, b=5.0, c=50.0, mu=0.0, sigma=10.0):
        self.a, self.b, self.c = a, b, c
        self.mu, self.sigma = mu, sigma

    def predict(self, X):
        s = np.asarray(X)[:, 0]
        return self.a / s + self.b * s + self.c

    def predict_with_error(self, X):
        return self.predict(X), self.mu, self.sigma


@settings(max_examples=50, deadline=None)
@given(t_max=st.floats(60.0, 2000.0), c=st.floats(0.55, 0.999),
       sigma=st.floats(0.1, 50.0))
def test_choice_is_minimal_satisfying_scaleout(t_max, c, sigma):
    pred = _FakePredictor(sigma=sigma)
    conf = Configurator(pred, "m5.xlarge", PRICES, SCALEOUTS, confidence=c)
    ctx = np.asarray([15.0])
    choice = conf.choose_scaleout(ctx, t_max=t_max)
    margin = confidence_margin(c, pred.mu, pred.sigma)
    ok = [s for s in SCALEOUTS
          if pred.predict(np.asarray([[s, 15.0]]))[0] + margin <= t_max]
    if ok:
        assert choice.scale_out == min(ok)
    else:  # infeasible deadline -> fastest bound
        bounds = {s: pred.predict(np.asarray([[s, 15.0]]))[0] + margin
                  for s in SCALEOUTS}
        assert choice.scale_out == min(bounds, key=bounds.get)


@settings(max_examples=20, deadline=None)
@given(c1=st.floats(0.55, 0.99), c2=st.floats(0.55, 0.99))
def test_higher_confidence_needs_no_smaller_scaleout(c1, c2):
    lo, hi = min(c1, c2), max(c1, c2)
    pred = _FakePredictor(sigma=25.0)
    ctx = np.asarray([15.0])
    t_max = 400.0
    s_lo = Configurator(pred, "m5.xlarge", PRICES, SCALEOUTS,
                        confidence=lo).choose_scaleout(ctx, t_max).scale_out
    s_hi = Configurator(pred, "m5.xlarge", PRICES, SCALEOUTS,
                        confidence=hi).choose_scaleout(ctx, t_max).scale_out
    # more confidence -> larger margin -> scale-out can only grow
    feasible_lo = pred.predict(np.asarray([[s_lo, 15.0]]))[0] \
        + confidence_margin(lo, 0, 25.0) <= t_max
    if feasible_lo:
        assert s_hi >= s_lo


def test_bottleneck_scaleouts_avoided():
    pred = _FakePredictor(sigma=1.0)

    def bott(ctx, s):
        return s <= 4                       # low scale-outs thrash memory
    conf = Configurator(pred, "m5.xlarge", PRICES, SCALEOUTS,
                        bottleneck_fn=bott)
    choice = conf.choose_scaleout(np.asarray([15.0]), t_max=2000.0)
    assert choice.scale_out > 4
    # ...unless nothing else meets the deadline (paper: fall back)
    conf2 = Configurator(_FakePredictor(a=100.0, b=200.0, sigma=0.1),
                         "m5.xlarge", PRICES, SCALEOUTS, bottleneck_fn=bott)
    ch2 = conf2.choose_scaleout(np.asarray([15.0]), t_max=600.0)
    assert ch2.runtime_bound_s <= 600.0


class _NegativePredictor:
    """Extrapolates to negative runtimes at large scale-outs (t = 100-10s):
    without clamping, cost = price * t/3600 * s goes negative and *wins*
    the cheapest-choice selection."""

    mu, sigma = 0.0, 1.0

    def predict(self, X):
        s = np.asarray(X)[:, 0]
        return 100.0 - 10.0 * s

    def predict_with_error(self, X):
        return self.predict(X), self.mu, self.sigma


def test_negative_predicted_runtime_never_yields_negative_cost():
    conf = Configurator(_NegativePredictor(), "m5.xlarge", PRICES, SCALEOUTS)
    choice = conf.choose_scaleout(np.asarray([15.0]))
    assert choice.cost_usd >= 0.0
    assert choice.predicted_runtime_s >= 0.0
    for _s, t, cost in conf.runtime_cost_pairs(np.asarray([15.0])):
        assert t >= 0.0 and cost >= 0.0
    # the engine's machine-grid path clamps identically
    from repro.core import engine
    _names, t, cost = engine.machine_grid_costs(
        {"m5.xlarge": _NegativePredictor()}, PRICES, SCALEOUTS,
        np.asarray([[15.0]]))
    assert (t >= 0.0).all() and (cost >= 0.0).all()


@pytest.mark.parametrize("c", [0.0, 1.0, -0.5, 1.5])
def test_degenerate_confidence_rejected_at_construction(c):
    """confidence_margin(1, ...) is erfinv(1) = inf — every deadline would
    silently become unsatisfiable; reject the endpoints up front."""
    with pytest.raises(ValueError, match="confidence"):
        Configurator(_FakePredictor(), "m5.xlarge", PRICES, SCALEOUTS,
                     confidence=c)


def test_interior_confidence_accepted():
    conf = Configurator(_FakePredictor(), "m5.xlarge", PRICES, SCALEOUTS,
                        confidence=0.5)
    assert np.isfinite(
        conf.choose_scaleout(np.asarray([15.0]), t_max=400.0).runtime_bound_s)


def test_deadline_satisfaction_rate_on_ground_truth():
    """End-to-end §IV check: the chosen scale-out meets the deadline at
    >= the configured confidence under the true (noisy) runtime law."""
    d = W.generate_job_data("grep").filter_machine("m5.xlarge")
    pred = C3OPredictor(max_cv_folds=25).fit(d.X, d.y)
    conf = Configurator(pred, "m5.xlarge", PRICES, SCALEOUTS,
                        confidence=0.9)
    rng = np.random.default_rng(3)
    hits = total = 0
    for trial in range(40):
        z = rng.uniform(10, 20)
        kw = rng.choice([0.002, 0.02, 0.08])
        ctx = np.asarray([z, kw])
        t_max = rng.uniform(150.0, 600.0)
        ch = conf.choose_scaleout(ctx, t_max=t_max)
        truth = W._measure("grep", "m5.xlarge", ch.scale_out, (z, kw),
                           seed=trial + 1000)
        feasible = any(
            W.true_runtime("grep", "m5.xlarge", s, (z, kw)) <= t_max
            for s in SCALEOUTS)
        if not feasible:
            continue
        total += 1
        hits += truth <= t_max * 1.02
    assert total >= 15
    assert hits / total >= 0.8


def test_machine_type_selection_prefers_cheap_effective():
    preds = {}
    for m in W.MACHINES:
        d = W.generate_job_data("sort").filter_machine(m)
        preds[m] = C3OPredictor(max_cv_folds=15).fit(d.X, d.y)
    best = choose_machine_type(preds, PRICES, SCALEOUTS, np.asarray([15.0]))
    assert best in W.MACHINES
    # sort is io/cpu bound with no memory pressure: r5 (expensive memory
    # machine) should not win
    assert best != "r5.xlarge"
