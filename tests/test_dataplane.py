"""Columnar runtime-data plane: struct-of-arrays semantics, TSV round-trip
fidelity, incremental ingestion (chained fingerprint, amortized append,
O(delta) machine-view extension), stratified validation subsampling,
corrupt fit-cache sidecars, and device-sharded CV parity."""
import hashlib
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import engine, features
from repro.core.datastore import RuntimeDataStore
from repro.core.features import JobSchema, RuntimeData
from repro.core.hub import JobRepo
from repro.core.models.api import get_model
from repro.workloads import spark_emul as W


@pytest.fixture(scope="module")
def grep_data():
    return W.generate_job_data("grep")


# --------------------------------------------------------------------------
# columnar layout + TSV round-trip fidelity
# --------------------------------------------------------------------------

def test_columnar_layout_and_dtypes(grep_data):
    d = grep_data
    assert d.codes.dtype == np.int32
    assert d.scale_out.dtype == np.float64
    assert d.context.dtype == np.float64 and d.context.ndim == 2
    assert d.runtime.dtype == np.float64
    assert d.context.shape == (len(d), d.schema.n_features - 1)
    # assembled X preserves the scale-out-first convention
    np.testing.assert_array_equal(d.X[:, 0], d.scale_out)
    np.testing.assert_array_equal(d.X[:, 1:], d.context)
    # machine decode round-trips through the vocabulary
    assert set(d.machines) == set(W.MACHINES)
    for m in d.machines:
        np.testing.assert_array_equal(
            d.machine_indices(m), np.nonzero(d.machine_type == m)[0])


def test_tsv_roundtrip_fidelity_mixed_machines(grep_data):
    """Round-trip preserves row ORDER, dtypes, and machine partition even
    with interleaved machine types."""
    rng = np.random.default_rng(0)
    shuffled = grep_data.subset(rng.permutation(len(grep_data)))
    text = shuffled.to_tsv()
    back = RuntimeData.from_tsv(text, shuffled.schema)
    assert back.X.dtype == np.float64 and back.y.dtype == np.float64
    np.testing.assert_allclose(back.X, shuffled.X)           # order kept
    np.testing.assert_allclose(back.y, shuffled.y, rtol=1e-4)
    assert (back.machine_type == shuffled.machine_type).all()
    # re-encoding the decoded data is byte-identical (stable canonical form)
    assert back.to_tsv() == text


def test_tsv_roundtrip_empty_and_single_row(grep_data):
    empty = RuntimeData.empty(grep_data.schema)
    assert len(empty) == 0
    back = RuntimeData.from_tsv(empty.to_tsv(), grep_data.schema)
    assert len(back) == 0
    one = grep_data.subset(np.asarray([7]))
    back1 = RuntimeData.from_tsv(one.to_tsv(), grep_data.schema)
    assert len(back1) == 1
    np.testing.assert_allclose(back1.X, one.X)


def test_append_is_view_safe_and_incremental(grep_data):
    base = grep_data.subset(np.arange(50))
    x_before = base.X.copy()
    idx_before = base.machine_indices("m5.xlarge").copy()
    delta = grep_data.subset(np.arange(50, 80))
    grown = base.append(delta)
    assert len(grown) == 80 and len(base) == 50      # base view unchanged
    np.testing.assert_array_equal(base.X, x_before)
    np.testing.assert_array_equal(base.machine_indices("m5.xlarge"),
                                  idx_before)
    np.testing.assert_allclose(grown.X[:50], base.X)
    np.testing.assert_allclose(grown.X[50:], delta.X)
    # cached per-machine indices were carried forward, not recomputed wrong
    np.testing.assert_array_equal(
        grown.machine_indices("m5.xlarge"),
        np.nonzero(grown.machine_type == "m5.xlarge")[0])
    # appending introduces vocabulary on demand
    other = RuntimeData(base.schema, np.asarray(["z9.new"] * 2),
                        base.X[:2], base.y[:2])
    merged = grown.append(other)
    assert "z9.new" in merged.machines
    assert (merged.machine_type[-2:] == "z9.new").all()


def test_machine_view_is_cached(grep_data):
    v1 = grep_data.machine_view("m5.xlarge")
    v2 = grep_data.machine_view("m5.xlarge")
    assert v1 is v2
    x1 = v1.X
    assert v1.X is x1                 # assembled batch built exactly once


def test_filter_machine_result_is_safe_to_mutate(grep_data):
    """Perturbing a filter_machine result (the documented contribution-
    crafting pattern) must not poison the cached machine view."""
    data = grep_data.subset(np.arange(len(grep_data)))   # private copy
    before = data.machine_view("m5.xlarge").y.copy()
    d = data.filter_machine("m5.xlarge")
    d.y = d.y * 40.0
    np.testing.assert_array_equal(data.machine_view("m5.xlarge").y, before)
    np.testing.assert_array_equal(data.filter_machine("m5.xlarge").y, before)


def test_tsv_roundtrip_hash_in_machine_name(grep_data):
    """'#' in a machine name must survive the codec (np.loadtxt would treat
    it as a comment marker without comments=None)."""
    schema = grep_data.schema
    d = RuntimeData(schema, np.asarray(["node#1", "node#2", "node#1"]),
                    grep_data.X[:3], grep_data.y[:3])
    back = RuntimeData.from_tsv(d.to_tsv(), schema)
    assert (back.machine_type == d.machine_type).all()
    np.testing.assert_allclose(back.X, d.X)


# --------------------------------------------------------------------------
# incremental ingestion: chained fingerprint + store growth
# --------------------------------------------------------------------------

def test_fingerprint_chain_matches_full_rehash(grep_data):
    rng = np.random.default_rng(1)
    idx = rng.permutation(len(grep_data))
    store = RuntimeDataStore(grep_data.subset(idx[:120]))
    assert store.fingerprint == hashlib.sha256(
        store.data.to_tsv().encode()).hexdigest()
    for lo, hi in ((120, 135), (135, 150)):
        rep = store.contribute(grep_data.subset(idx[lo:hi]))
        assert rep.accepted
        # the chained O(delta) digest equals a full O(N) re-hash, so
        # persisted fit caches keyed on it stay valid across processes
        assert store.fingerprint == hashlib.sha256(
            store.data.to_tsv().encode()).hexdigest()
    assert store.version == 2
    # and save/load preserves it
    assert RuntimeDataStore(
        RuntimeData.from_tsv(store.data.to_tsv(), grep_data.schema)
    ).fingerprint == store.fingerprint


def test_data_reassignment_reseeds_fingerprint(grep_data):
    """Replacing store.data wholesale (an edge-format import, a manual
    repair) must re-derive the fingerprint from the new content — a stale
    chain would let an old fits sidecar pass its fingerprint check."""
    store = RuntimeDataStore(grep_data)
    store.data = grep_data.subset(np.arange(50))
    assert store.fingerprint == hashlib.sha256(
        store.data.to_tsv().encode()).hexdigest()


def test_empty_contribution_rejected_without_version_bump(grep_data):
    store = RuntimeDataStore(grep_data)
    fp0, v0, n0 = store.fingerprint, store.version, len(store)
    rep = store.contribute(RuntimeData.empty(grep_data.schema))
    assert not rep.accepted
    assert "empty contribution" in rep.reason
    assert store.version == v0 and store.fingerprint == fp0
    assert len(store) == n0


def test_machine_view_refit_prep_is_o_delta(grep_data):
    """Regression for the PR 3 follow-on: after an accepted contribution,
    preparing a refit (machine_view + assembled X) must never rebuild
    per-machine state from a full-store scan — cached views are carried
    forward by appending only the delta rows, and the assembled-X buffer
    is extended in place."""
    rng = np.random.default_rng(7)
    idx = rng.permutation(len(grep_data))
    store = RuntimeDataStore(grep_data.subset(idx[:150]), seed=0)
    machines = store.data.present_machines()
    before = {m: store.data.machine_view(m).X.copy() for m in machines}
    assert store.contribute(grep_data.subset(idx[150:180])).accepted

    features.view_stats_reset()
    views = {m: store.data.machine_view(m) for m in machines}
    xs = {m: v.X for m, v in views.items()}
    assert features.VIEW_STATS["machine_view_builds"] == 0, \
        "machine_view rebuilt from a full-store subset scan"
    assert features.VIEW_STATS["x_builds"] == 0, \
        "assembled X rebuilt from scratch instead of extended in place"
    assert features.VIEW_STATS["x_extends"] >= 1

    # and the incrementally extended state is CORRECT: prefix preserved,
    # delta rows appended, identical to a cold rebuild
    for m in machines:
        np.testing.assert_array_equal(xs[m][: len(before[m])], before[m])
        cold = store.data.subset(
            np.nonzero(store.data.machine_type == m)[0])
        np.testing.assert_array_equal(xs[m], cold.X)
        np.testing.assert_array_equal(views[m].y, cold.y)


# --------------------------------------------------------------------------
# stratified validation subsampling
# --------------------------------------------------------------------------

def _imbalanced_store(grep_data, n_major=800, n_minor=8, cap=32):
    """~100:1 machine-type imbalance under a small validation cap."""
    rng = np.random.default_rng(5)
    major = grep_data.filter_machine("m5.xlarge")
    minor = grep_data.filter_machine("c5.xlarge")
    maj_idx = rng.choice(len(major), n_major, replace=True)
    base = major.subset(maj_idx).append(minor.subset(np.arange(n_minor)))
    return RuntimeDataStore(base, seed=0, max_validation_rows=cap), minor


def test_stratified_validation_keeps_rare_machine_signal(grep_data):
    """A 100:1 imbalanced store under a small ``max_validation_rows`` cap
    must still JUDGE contributions for the rare machine type: uniform
    subsampling starved its holdout below 2 rows, waving poisoned rows
    through as 'insufficient data'.  The poison fabricates runtimes for
    configurations the store already holds (§III-C's threat model: wrong
    numbers for known configs poison every collaborator's fit)."""
    store, minor = _imbalanced_store(grep_data)
    poisoned = minor.subset(np.tile(np.arange(8), 3))
    poisoned = RuntimeData(poisoned.schema, poisoned.machine_type,
                           poisoned.X, poisoned.y * 40.0)
    rep = store.contribute(poisoned)
    assert not rep.accepted, \
        "poisoned rare-machine contribution slipped past validation"
    assert "c5.xlarge" in rep.reason

    honest = minor.subset(np.arange(8, 28))
    rep = store.contribute(honest)
    assert rep.accepted, rep.reason


def test_stratified_split_caps_and_floors(grep_data):
    store, _ = _imbalanced_store(grep_data)
    hold, train = store._stratified_split(np.random.default_rng(0))
    assert len(hold) <= store.max_validation_rows
    assert len(train) <= store.max_validation_rows
    mt = store.data.machine_type
    # the rare machine keeps its full 20/80 split on BOTH sides
    assert (mt[hold] == "c5.xlarge").sum() == 2
    assert (mt[train] == "c5.xlarge").sum() == 6
    # no row on both sides
    assert not set(hold.tolist()) & set(train.tolist())


# --------------------------------------------------------------------------
# bucket-padded fit/CV parity (the replay plane's shape-stable path)
# --------------------------------------------------------------------------

@pytest.mark.slow          # compiles a second (padded-shape) CV pipeline
def test_pad_rows_predictor_matches_exact_shapes(grep_data):
    """C3OPredictor(pad_rows=True) — zero-weight row padding + masked fold
    buckets — selects the same model and predicts within float tolerance
    of the exact-shape reference."""
    from repro.core.predictor import C3OPredictor
    d = grep_data.machine_view("m5.xlarge")
    ref = C3OPredictor(max_cv_folds=15).fit_data(d)
    pad = C3OPredictor(max_cv_folds=15, pad_rows=True).fit_data(d)
    assert pad.selected == ref.selected
    for name in ref.cv_mape:
        np.testing.assert_allclose(pad.cv_mape[name], ref.cv_mape[name],
                                   rtol=0.05, atol=1e-4)
    np.testing.assert_allclose(pad.predict(d.X[:16]), ref.predict(d.X[:16]),
                               rtol=0.05)
    np.testing.assert_allclose(pad.mu, ref.mu, rtol=0.05, atol=1e-2)
    np.testing.assert_allclose(pad.sigma, ref.sigma, rtol=0.05, atol=1e-2)


# --------------------------------------------------------------------------
# corrupt fit-cache sidecar = cache miss
# --------------------------------------------------------------------------

def _repo_with_saved_fits(data, tmp_path):
    store = RuntimeDataStore(data, seed=0)
    repo = JobRepo("grep", "grep", data.schema, store)
    repo.predictor_for("m5.xlarge")
    fits = JobRepo.fits_path(str(tmp_path / "grep.tsv"))
    assert repo.save_fits(fits) == 1
    return store, fits


def test_load_fits_truncated_pickle_is_cache_miss(tmp_path, grep_data,
                                                  caplog):
    store, fits = _repo_with_saved_fits(grep_data, tmp_path)
    with open(fits, "rb") as f:
        blob = f.read()
    with open(fits, "wb") as f:
        f.write(blob[: len(blob) // 2])          # simulate a torn write
    repo2 = JobRepo("grep", "grep", grep_data.schema,
                    RuntimeDataStore(grep_data, seed=0))
    with caplog.at_level("WARNING", logger="repro.core.hub"):
        assert repo2.load_fits(fits) == 0        # miss, not an exception
    assert any("unreadable" in r.message for r in caplog.records)
    assert repo2.predictor_for("m5.xlarge").selected  # refit still works


def test_load_fits_garbage_and_missing_file_are_cache_misses(tmp_path,
                                                             grep_data):
    repo = JobRepo("grep", "grep", grep_data.schema,
                   RuntimeDataStore(grep_data, seed=0))
    assert repo.load_fits(str(tmp_path / "does_not_exist.pkl")) == 0
    bad = str(tmp_path / "garbage.pkl")
    with open(bad, "wb") as f:
        f.write(b"\x80\x04 this is not a pickle")
    assert repo.load_fits(bad) == 0
    import pickle
    with open(bad, "wb") as f:
        pickle.dump({"format": 1, "not_entries": []}, f)   # wrong structure
    assert repo.load_fits(bad) == 0


# --------------------------------------------------------------------------
# sharded cross-validation parity
# --------------------------------------------------------------------------

def test_cv_select_sharded_matches_single_device(grep_data):
    """shard_map path (forced, over the available mesh) == plain path:
    same selected model, allclose mape/mu/sigma."""
    d = grep_data.machine_view("m5.xlarge")
    specs = [get_model(n) for n in ("ernest", "gbm", "bom", "ogb")]
    rng = np.random.default_rng(0)
    for n_folds in (20, 23):             # 23: exercises fold padding
        folds = rng.choice(len(d.y), n_folds, replace=False)
        ref = engine.cv_select(specs, d.X, d.y, folds, sharded=False)
        sh = engine.cv_select(specs, d.X, d.y, folds, sharded=True)
        assert sh[0] == ref[0]
        for name in ref[1]:
            np.testing.assert_allclose(sh[1][name], ref[1][name],
                                       rtol=2e-5, atol=1e-6)
        np.testing.assert_allclose(sh[2], ref[2], rtol=2e-5, atol=1e-5)
        np.testing.assert_allclose(sh[3], ref[3], rtol=2e-5, atol=1e-5)


_MULTIDEV_SCRIPT = """
import numpy as np
from repro.core import engine
from repro.core.models.api import get_model
from repro.workloads import spark_emul as W
import jax
assert len(jax.devices()) == 4, jax.devices()
d = W.generate_job_data("grep").machine_view("m5.xlarge")
specs = [get_model(n) for n in ("ernest", "gbm", "bom", "ogb")]
folds = np.random.default_rng(0).choice(len(d.y), 22, replace=False)
ref = engine.cv_select(specs, d.X, d.y, folds, sharded=False)
sh = engine.cv_select(specs, d.X, d.y, folds)      # auto: 4 devices -> shard
assert engine._cv_shard_devices() == 4
assert sh[0] == ref[0]
for name in ref[1]:
    np.testing.assert_allclose(sh[1][name], ref[1][name], rtol=2e-5,
                               atol=1e-6)
np.testing.assert_allclose(sh[2:], ref[2:], rtol=2e-5, atol=1e-5)
print("MULTIDEV_PARITY_OK")
"""


@pytest.mark.slow
def test_cv_select_parity_on_four_forced_host_devices():
    """End-to-end mesh parity on a real 4-device partition (forced host
    devices in a subprocess: the flag must be set before jax initializes)."""
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env = dict(os.environ,
               XLA_FLAGS=(os.environ.get("XLA_FLAGS", "")
                          + " --xla_force_host_platform_device_count=4"),
               PYTHONPATH=src + os.pathsep + os.environ.get("PYTHONPATH", ""))
    out = subprocess.run([sys.executable, "-c", _MULTIDEV_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "MULTIDEV_PARITY_OK" in out.stdout


def test_predictor_fit_uses_sharded_path_transparently(grep_data,
                                                       monkeypatch):
    """C3OPredictor.fit through C3O_CV_SHARD=on equals the default path."""
    from repro.core.predictor import C3OPredictor
    d = grep_data.machine_view("m5.xlarge")
    monkeypatch.setenv("C3O_CV_SHARD", "off")
    ref = C3OPredictor(max_cv_folds=15).fit_data(d)
    monkeypatch.setenv("C3O_CV_SHARD", "on")
    sh = C3OPredictor(max_cv_folds=15).fit_data(d)
    assert sh.selected == ref.selected
    np.testing.assert_allclose(sh.mu, ref.mu, rtol=2e-5, atol=1e-5)
    np.testing.assert_allclose(sh.sigma, ref.sigma, rtol=2e-5, atol=1e-5)


# --------------------------------------------------------------------------
# validation reuses engine executables (no throwaway predictors)
# --------------------------------------------------------------------------

def test_validation_runs_on_cached_val_executables(grep_data):
    engine.cache_clear()
    store = RuntimeDataStore(grep_data)
    rng = np.random.default_rng(3)
    idx = rng.permutation(len(grep_data))
    store.validate(grep_data.subset(idx[:10]))
    stats = engine.cache_stats()
    assert stats["val"] >= 1            # fused fit+holdout executables...
    assert stats["cv"] == 0             # ...no CV predictor construction
    # second validation re-uses them (no growth in the executable cache)
    store.validate(grep_data.subset(idx[10:20]))
    assert engine.cache_stats()["val"] == stats["val"]


def test_schema_mismatch_still_raises(grep_data):
    other = JobSchema("sort", ())
    with pytest.raises(AssertionError, match="schema mismatch"):
        RuntimeData.from_tsv(grep_data.to_tsv(), other)
