"""Shared-data store: TSV codec, contribution validation (paper §III-C)."""
import numpy as np
import pytest

from repro.core.datastore import RuntimeDataStore
from repro.core.features import RuntimeData
from repro.core.hub import Hub, JobRepo
from repro.workloads import spark_emul as W


@pytest.fixture(scope="module")
def grep_data():
    return W.generate_job_data("grep")


def test_tsv_roundtrip(grep_data):
    text = grep_data.to_tsv()
    back = RuntimeData.from_tsv(text, grep_data.schema)
    assert np.allclose(back.X, grep_data.X)
    assert np.allclose(back.y, grep_data.y, rtol=1e-4)
    assert (back.machine_type == grep_data.machine_type).all()


def test_store_save_load(tmp_path, grep_data):
    store = RuntimeDataStore(grep_data)
    p = str(tmp_path / "grep.tsv")
    store.save(p)
    back = RuntimeDataStore.load(p, grep_data.schema)
    assert len(back) == len(store)


def test_contribution_validation_rejects_fabricated(grep_data):
    store = RuntimeDataStore(grep_data)
    n0 = len(store)
    bad = grep_data.subset(np.arange(25))
    bad = RuntimeData(bad.schema, bad.machine_type, bad.X,
                      bad.y * 40.0)            # fabricated runtimes
    rep = store.contribute(bad)
    assert not rep.accepted
    assert len(store) == n0


def test_mixed_contribution_poisoned_group_rejected(grep_data):
    """Regression: validation used to judge only machine_type[0], so a mixed
    contribution could smuggle poisoned rows for every OTHER machine type
    into the store unvalidated."""
    store = RuntimeDataStore(grep_data)
    n0 = len(store)
    good = grep_data.filter_machine("m5.xlarge").subset(np.arange(10))
    bad = grep_data.filter_machine("c5.xlarge").subset(np.arange(25))
    bad = RuntimeData(bad.schema, bad.machine_type, bad.X, bad.y * 40.0)
    mixed = good.concat(bad)            # first row is the honest machine
    assert mixed.machine_type[0] == "m5.xlarge"
    rep = store.contribute(mixed)
    assert not rep.accepted
    assert "c5.xlarge" in rep.reason
    assert len(store) == n0
    assert store.version == 0


def test_contribution_validation_accepts_honest(grep_data):
    rng = np.random.default_rng(0)
    idx = rng.permutation(len(grep_data))
    store = RuntimeDataStore(grep_data.subset(idx[:120]))
    good = grep_data.subset(idx[120:150])
    rep = store.contribute(good)
    assert rep.accepted
    assert len(store) == 150


def test_hub_workflow(grep_data):
    """Paper Fig.4: search -> download -> predict -> configure -> contribute."""
    hub = Hub()
    repo = JobRepo("grep", "regex scan over text", grep_data.schema,
                   RuntimeDataStore(grep_data))
    hub.publish(repo)
    found = hub.search("scan")
    assert found and found[0].job == "grep"
    conf = repo.configurator(
        "m5.xlarge", {m.name: m.price for m in W.MACHINES.values()},
        [2, 3, 4, 6, 8, 12])
    choice = conf.choose_scaleout(np.asarray([15.0, 0.02]), t_max=500.0)
    assert choice.scale_out in [2, 3, 4, 6, 8, 12]
    pairs = conf.runtime_cost_pairs(np.asarray([15.0, 0.02]))
    assert len(pairs) == 6


def test_custom_model_api(grep_data):
    """Maintainer custom models join selection via the common API."""
    import jax.numpy as jnp
    from repro.core.models.api import ModelSpec

    def fit(X, y, w, aux):     # a deliberately bad custom model
        return (w * y).sum() / jnp.maximum(w.sum(), 1e-9)

    def predict(params, X, aux):
        return jnp.full(X.shape[0], params)

    repo = JobRepo("grep", "grep", grep_data.schema,
                   RuntimeDataStore(grep_data))
    repo.add_custom_model(ModelSpec("mean_only", lambda X: {}, fit, predict))
    pred = repo.predictor_for("m5.xlarge")
    assert "mean_only" in pred.cv_mape
    assert pred.selected != "mean_only"       # CV rejects the bad model
