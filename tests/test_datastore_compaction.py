"""Store lifecycle: epoch-based compaction. Accept/reject verdicts and
their exact side effects (version/epoch/fingerprint), reputation-preferred
retention, engine-backed accuracy gating with rollback, epoch restore
through the fits sidecar, and the gateway's operator-gated compact op with
superseded-epoch cache eviction."""
import hashlib

import numpy as np
import pytest

from repro.api import (AuthedRequest, CompactRequest, HubGateway,
                       SearchRequest, TrustAuthority)
from repro.api.types import ERR_UNAUTHORIZED
from repro.core.datastore import (COMPACTED, COMPACTION_REJECTED,
                                  RuntimeDataStore)
from repro.core.features import RuntimeData
from repro.core.hub import Hub, JobRepo
from repro.core.trust import ReputationLedger
from repro.workloads import spark_emul as W

SCALEOUTS = (2, 3, 4, 6, 8, 12, 16)
PRICES = {m.name: m.price for m in W.MACHINES.values()}

#: gate-free knobs — ``accuracy_budget=inf`` skips the engine entirely
GATE_FREE = dict(max_rows_per_cell=2, support_floor=1, cell_rel_width=0.15,
                 accuracy_budget=float("inf"), min_store_rows=1, seed=0)


def _multi_user_store(job="sort", users=5, seed=0, trust=None):
    """A store grown the collaborative way: user 0 seeds, the rest flow
    through ``contribute`` with real provenance."""
    store = RuntimeDataStore(W.generate_user_data(job, 0, seed), seed=seed,
                             trust=trust)
    for u in range(1, users):
        rep = store.contribute(W.generate_user_data(job, u, seed),
                               contributor=f"user-{u}")
        assert rep.accepted
    return store


def _snapshot(store):
    return (store.version, store.epoch, store.compactions,
            store.fingerprint, store.data.to_tsv())


# --------------------------------------------------------------------------
# verdicts and their side effects (gate-free: pure numpy)
# --------------------------------------------------------------------------

def test_small_store_compaction_is_typed_rejected_noop():
    store = RuntimeDataStore(W.generate_user_data("sort", 0, 0))
    before = _snapshot(store)
    report = store.compact(seed=0)        # 60 rows < default min of 64
    assert not report.accepted
    assert report.code == COMPACTION_REJECTED
    assert "too small" in report.reason
    assert report.rows_before == report.rows_after == len(store)
    assert _snapshot(store) == before     # no bump, no reseed, no mutation
    assert store.last_compaction is report


def test_accepted_compaction_bumps_epoch_and_reseeds_fingerprint():
    store = _multi_user_store()
    n, ver = len(store), store.version
    contributed = store.rows_contributed
    report = store.compact(**GATE_FREE)
    assert report.accepted and report.code == COMPACTED
    assert report.rows_before == n and report.rows_after == len(store)
    assert len(store) < n
    assert (store.version, store.epoch, store.compactions) == (ver + 1, 1, 1)
    # the reseeded chain equals a full rehash of the live TSV, and matches
    # a store freshly opened over the retained rows (migration invariant)
    assert store.fingerprint == hashlib.sha256(
        store.data.to_tsv().encode()).hexdigest()
    assert store.fingerprint == RuntimeDataStore(store.data).fingerprint
    # lifetime ingest counter is history, not live rows: it never shrinks
    assert store.rows_contributed == contributed > len(store)


def test_below_support_floor_rejects_whole_compaction():
    store = _multi_user_store()
    before = _snapshot(store)
    report = store.compact(**{**GATE_FREE, "support_floor": 10 ** 6})
    assert not report.accepted and report.code == COMPACTION_REJECTED
    assert "floor" in report.reason
    assert _snapshot(store) == before


def test_nothing_to_remove_is_rejected():
    store = _multi_user_store()
    before = _snapshot(store)
    report = store.compact(**{**GATE_FREE, "max_rows_per_cell": 10 ** 6})
    assert not report.accepted and report.code == COMPACTION_REJECTED
    assert _snapshot(store) == before


def test_compaction_knobs_are_validated():
    store = _multi_user_store(users=2)
    with pytest.raises(ValueError):
        store.compact(**{**GATE_FREE, "max_rows_per_cell": 0})
    with pytest.raises(ValueError):
        store.compact(**{**GATE_FREE, "support_floor": -1})
    with pytest.raises(ValueError):
        store.compact(**{**GATE_FREE, "cell_rel_width": 0.0})
    with pytest.raises(ValueError):
        store.compact(**{**GATE_FREE, "cell_rel_width": 1.5})


def test_reputation_preferred_retention():
    """Within a cell, rows from reputable contributors outlive rows from
    disreputable ones: the same (context, scale-out) grid contributed
    twice compacts down to the high-reputation copy."""
    led = ReputationLedger()
    for _ in range(10):
        led.record_outcome("good", True, 1.0)
        led.record_outcome("bad", False, 0.0)
    assert led.row_weight("bad") < led.row_weight("good")
    d = W.generate_user_data("sort", 0, 0)
    good = d.with_contributor("good")
    bad = RuntimeData(d.schema, d.machine_type, d.X,
                      d.y * 1.01).with_contributor("bad")
    store = RuntimeDataStore(good.append(bad), trust=led)
    report = store.compact(**{**GATE_FREE, "max_rows_per_cell": 1})
    assert report.accepted
    counts = store.data.contributor_counts()
    assert counts.get("bad", 0) == 0      # every duplicate cell kept "good"
    assert counts["good"] == len(store)


# --------------------------------------------------------------------------
# epoch restore through the fits sidecar
# --------------------------------------------------------------------------

def test_epoch_restored_from_fits_sidecar(tmp_path):
    store = _multi_user_store()
    repo = JobRepo("sort", "sort", W.SCHEMAS["sort"], store)
    assert store.compact(**GATE_FREE).accepted
    path = str(tmp_path / "sort.tsv.fits.pkl")
    repo.save_fits(path)

    # a fresh process re-opens the TSV: rows survive, lifecycle counters
    # don't (the codec carries data, not epochs) — until the sidecar,
    # whose fingerprint match vouches for them, fast-forwards the store
    reopened = RuntimeDataStore(
        RuntimeData.from_tsv(store.data.to_tsv(), store.data.schema))
    assert reopened.fingerprint == store.fingerprint
    assert (reopened.epoch, reopened.compactions) == (0, 0)
    repo2 = JobRepo("sort", "sort", W.SCHEMAS["sort"], reopened)
    repo2.load_fits(path)
    assert (reopened.epoch, reopened.compactions) == (1, 1)

    # a sidecar for DIFFERENT data must not fast-forward anything
    other = RuntimeDataStore(W.generate_user_data("sort", 7, 0))
    repo3 = JobRepo("sort", "sort", W.SCHEMAS["sort"], other)
    assert repo3.load_fits(path) == 0
    assert (other.epoch, other.compactions) == (0, 0)


def test_restore_epoch_is_forward_only():
    store = _multi_user_store(users=2)
    store.restore_epoch(3, compactions=2)
    assert (store.epoch, store.compactions) == (3, 2)
    store.restore_epoch(1, compactions=9)          # stale sidecar: ignored
    assert (store.epoch, store.compactions) == (3, 2)


# --------------------------------------------------------------------------
# gateway: operator-gated compact op + cache hygiene
# --------------------------------------------------------------------------

def _gateway(jobs=("sort",), users=5, auth=None):
    hub = Hub()
    for job in jobs:
        store = _multi_user_store(job, users)
        hub.publish(JobRepo(job, job, W.SCHEMAS[job], store))
    return HubGateway(hub, PRICES, SCALEOUTS, auth=auth)


def test_gateway_compact_parity_with_direct_store():
    gw = _gateway()
    shadow = _multi_user_store()
    req = CompactRequest("sort", accuracy_budget=float("inf"),
                         min_store_rows=1, max_rows_per_cell=2,
                         support_floor=1, seed=0)
    resp = gw.compact(req)
    direct = shadow.compact(**{**GATE_FREE, "seed": gw._seed(None)})
    assert resp.ok and resp.result.accepted
    got = resp.result
    assert (got.code, got.rows_before, got.rows_after, got.epoch,
            got.cells) == (direct.code, direct.rows_before,
                           direct.rows_after, direct.epoch, direct.cells)
    assert got.fingerprint == shadow.fingerprint
    # the verdict also lands in discovery metadata
    info = gw.search(SearchRequest("sort")).result.jobs[0]
    assert (info.rows, info.epoch, info.compactions) == (
        got.rows_after, 1, 1)
    assert info.rows_contributed == direct.rows_before


def test_gateway_rejected_compaction_is_ok_envelope():
    gw = _gateway(users=1)                # 60 rows < default min_store_rows
    resp = gw.compact(CompactRequest("sort"))
    assert resp.ok
    assert not resp.result.accepted
    assert resp.result.code == COMPACTION_REJECTED
    assert gw.search(SearchRequest("sort")).result.jobs[0].epoch == 0


def test_gateway_compact_is_operator_only_under_auth():
    auth = TrustAuthority()
    gw = _gateway(users=5, auth=auth)
    token = gw.issue_token("carol")
    req = AuthedRequest(token, CompactRequest(
        "sort", accuracy_budget=float("inf"), min_store_rows=1))
    resp = gw.compact(req)
    assert not resp.ok and resp.error_code == ERR_UNAUTHORIZED
    assert "operator" in resp.detail
    assert gw.search(AuthedRequest(
        token, SearchRequest("sort"))).result.jobs[0].epoch == 0

    gw.grant_operator("carol")
    resp = gw.compact(req)
    assert resp.ok and resp.result.accepted and resp.result.epoch == 1

    gw.revoke_operator("carol")
    assert not gw.compact(req).ok         # standing is revocable


@pytest.mark.slow
def test_gateway_cache_does_not_grow_over_compactions():
    """Regression: every epoch transition (and every accepted
    contribution) eagerly evicts superseded service entries — N
    compactions leave at most one live entry per job, never N."""
    gw = _gateway(users=4)
    repo = gw.hub.get("sort")
    ctx = (15.0,)
    from repro.api import ChooseRequest
    assert gw.choose(ChooseRequest("sort", ctx)).ok
    assert len(gw._services) == 1
    for u in range(4, 8):
        assert gw.handle(_contribute_req("sort", u)).ok
        gw.compact(CompactRequest("sort", accuracy_budget=float("inf"),
                                  min_store_rows=1, seed=0))
        assert gw.choose(ChooseRequest("sort", ctx)).ok
        # the live entry is pinned to the CURRENT store version: stale
        # epochs were evicted eagerly, not left to accumulate
        assert len(gw._services) == 1
        (key, entry), = gw._services.items()
        assert key[0] == "sort" and entry[0] == repo.store.version
    assert repo.store.epoch >= 1          # the ladder actually transitioned


def _contribute_req(job, user):
    from repro.api import ContributeRequest
    d = W.generate_user_data(job, user, 0)
    return ContributeRequest(job, tuple(d.machine_type),
                             tuple(map(tuple, d.X)), tuple(d.y),
                             contributor_id=f"user-{user}")


# --------------------------------------------------------------------------
# the engine-backed accuracy gate (slow lane)
# --------------------------------------------------------------------------

@pytest.mark.slow
def test_accuracy_gate_rejects_and_rolls_back():
    """An impossible budget forces the gate to reject: the store must
    roll back byte-identically — no epoch, no version, no reseed."""
    store = _multi_user_store()
    before = _snapshot(store)
    report = store.compact(max_rows_per_cell=2, support_floor=1,
                           accuracy_budget=-1e9, min_store_rows=1, seed=0)
    assert not report.accepted and report.code == COMPACTION_REJECTED
    assert "budget" in report.reason
    assert np.isfinite(report.baseline_mape)
    assert np.isfinite(report.candidate_mape)
    assert _snapshot(store) == before


@pytest.mark.slow
def test_accuracy_gate_accepts_redundant_store():
    """sort's contexts collapse to a handful of clusters, so the
    leave-one-contributor-out gate sees ~no accuracy loss and admits the
    epoch transition at a generous budget."""
    store = _multi_user_store()
    report = store.compact(max_rows_per_cell=2, support_floor=1,
                           accuracy_budget=0.05, min_store_rows=1, seed=0)
    assert report.accepted
    assert report.candidate_mape <= report.baseline_mape + 0.05
    assert len(store) < report.rows_before
