"""Sharding rules, MoE EP parity, gradient compression."""

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import smoke_config
from repro.distributed import sharding
from repro.distributed.compression import (compress_decompress,
                                           make_ef_compressor)
from repro.launch.mesh import make_host_mesh
from repro.modeling import moe as MOE
from repro.modeling import model as M


def test_resolve_spec_divisibility():
    mesh = make_host_mesh(1)            # (n_dev, 1) axes (data, model)
    # dim 7 not divisible by data axis -> replicated
    spec = sharding.resolve_spec(("batch", None), dims=(7, 4), mesh=mesh)
    n_data = mesh.shape["data"]
    if n_data > 1:
        assert spec == P(None, None)
    spec2 = sharding.resolve_spec(("batch", "model"), dims=(n_data * 2, 8),
                                  mesh=mesh)
    assert spec2[0] is not None or n_data == 1


def test_moe_ep_matches_dense():
    """shard_map expert-parallel path == dense one-hot oracle (1-dev mesh)."""
    cfg = smoke_config("olmoe-1b-7b", capacity_factor=8.0)  # no drops
    mesh = make_host_mesh(1)
    key = jax.random.PRNGKey(0)
    from repro.modeling.moe import moe_defs
    from repro.modeling.layers import materialize
    p = materialize(moe_defs(cfg), key, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    y_dense, aux_dense = MOE.moe_apply_dense(cfg, p, x)
    with sharding.use_mesh(mesh):
        y_ep, aux_ep = MOE.moe_apply_ep(cfg, p, x)
    np.testing.assert_allclose(np.asarray(y_dense), np.asarray(y_ep),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(float(aux_dense), float(aux_ep), rtol=1e-4)


def test_moe_capacity_drops_are_consistent():
    """With a tight capacity factor both paths drop the same tokens."""
    cfg = smoke_config("olmoe-1b-7b", capacity_factor=1.0)
    key = jax.random.PRNGKey(0)
    from repro.modeling.moe import moe_defs
    from repro.modeling.layers import materialize
    p = materialize(moe_defs(cfg), key, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    y_dense, _ = MOE.moe_apply_dense(cfg, p, x)
    with sharding.use_mesh(make_host_mesh(1)):
        y_ep, _ = MOE.moe_apply_ep(cfg, p, x)
    np.testing.assert_allclose(np.asarray(y_dense), np.asarray(y_ep),
                               atol=1e-4, rtol=1e-4)


def test_compression_roundtrip_bounded_error():
    x = jax.random.normal(jax.random.PRNGKey(0), (1000,)) * 3.0
    x_hat, err = compress_decompress(x, block=128)
    # int8 symmetric: per-block max error <= scale/2 = max|x|/254
    blocks = np.asarray(x[:896]).reshape(-1, 128)
    for b, e in zip(blocks, np.asarray(err[:896]).reshape(-1, 128)):
        assert np.abs(e).max() <= np.abs(b).max() / 127.0 + 1e-6
    np.testing.assert_allclose(np.asarray(x), np.asarray(x_hat + err),
                               atol=1e-6)


def test_error_feedback_converges_on_quadratic():
    """EF-compressed GD matches exact GD's optimum on a quadratic."""
    A = jnp.diag(jnp.asarray([1.0, 0.1, 3.0, 0.5]))
    b = jnp.asarray([1.0, -2.0, 0.5, 4.0])
    x_star = jnp.linalg.solve(A, b)
    init_ef, ef = make_ef_compressor(block=4)

    def grad(x):
        return A @ x - b

    x = jnp.zeros(4)
    state = init_ef({"g": x})
    for _ in range(300):
        g = {"g": grad(x)}
        g_hat, state = ef(g, state)
        x = x - 0.2 * g_hat["g"]
    # int8 quantization floor leaves a small limit cycle around x*
    np.testing.assert_allclose(np.asarray(x), np.asarray(x_star),
                               atol=5e-2, rtol=5e-3)


def test_sp_residual_constraint_lowers():
    """seq_shard_residual path traces on a (1,1) mesh without error."""
    cfg = smoke_config("deepseek-7b", seq_shard_residual=True)
    mesh = make_host_mesh(1)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jnp.zeros((2, 16), jnp.int32)}
    with sharding.use_mesh(mesh):
        logits, _, _ = jax.jit(
            lambda p, b: M.forward(cfg, p, b, mode="train"))(params, batch)
    assert logits.shape == (2, 16, cfg.padded_vocab_size)
