"""Serving-edge contracts over a REAL localhost socket: typed envelopes
on every path (health, stats, malformed bodies, oversized payloads,
protocol refusals), byte-for-byte parity between the HTTP path and the
in-process gateway, predict-lane survival under interleaved bad
requests, drain-on-shutdown semantics, and the closed-loop load
generator's determinism and reporting."""
import asyncio

import numpy as np
import pytest

from repro.api import (AsyncHubGateway, HubGateway, PredictRequest,
                       Response, decode, encode)
from repro.api.types import (ERR_BAD_REQUEST, ERR_SHUTTING_DOWN,
                             ChooseRequest, HealthResult, StatsResult)
from repro.core.datastore import RuntimeDataStore
from repro.core.hub import Hub, JobRepo
from repro.serve.edge import serve_edge
from repro.serve.loadgen import _request, build_workload, run_loadgen
from repro.workloads import spark_emul as W

SCALEOUTS = (2, 3, 4, 6, 8, 12, 16)
PRICES = {m.name: m.price for m in W.MACHINES.values()}

CHOOSE_BODY = encode(ChooseRequest("grep", (15.0, 0.02),
                                   t_max=400.0)).encode("ascii")


@pytest.fixture(scope="module")
def gw():
    hub = Hub()
    d = W.generate_job_data("grep")
    hub.publish(JobRepo("grep", "grep", d.schema,
                        RuntimeDataStore(d, seed=0)))
    return HubGateway(hub, PRICES, SCALEOUTS)


async def _conn(server):
    return await asyncio.open_connection(server.host, server.port)


def _decode(payload: bytes) -> Response:
    resp = decode(payload.decode("utf-8"))
    assert isinstance(resp, Response)
    return resp


# --------------------------------------------------------------------------
# health / stats / happy path
# --------------------------------------------------------------------------

def test_healthz_stats_and_ops_over_one_keepalive_connection(gw):
    async def drive():
        app, server = await serve_edge(gw)
        try:
            reader, writer = await _conn(server)
            status, payload = await _request(reader, writer, "GET",
                                             "/healthz")
            assert status == 200
            health = _decode(payload)
            assert health.ok and isinstance(health.result, HealthResult)
            assert health.result.status == "ok"
            assert health.result.jobs == ("grep",)

            # a choose and a single-row predict on the SAME connection
            status, payload = await _request(reader, writer, "POST",
                                             "/v1/choose", CHOOSE_BODY)
            assert status == 200 and _decode(payload).ok
            body = encode(PredictRequest(
                "grep", "m5.xlarge", ((4.0, 15.0, 0.02),))).encode("ascii")
            status, payload = await _request(reader, writer, "POST",
                                             "/v1/predict", body)
            assert status == 200
            predict = _decode(payload)
            assert predict.ok and len(predict.result.runtimes_s) == 1

            # generic /v1 routes on the envelope's __type__
            status, payload = await _request(reader, writer, "POST", "/v1",
                                             body)
            assert status == 200 and _decode(payload).ok

            status, payload = await _request(reader, writer, "GET",
                                             "/stats")
            assert status == 200
            stats = _decode(payload)
            assert stats.ok and isinstance(stats.result, StatsResult)
            assert stats.result.requests >= 4
            assert stats.result.errors == 0 and not stats.result.draining
            assert "grep@m5.xlarge" in {ln.lane for ln in stats.result.lanes}
            writer.close()
        finally:
            await server.stop()

    asyncio.run(drive())


def test_http_path_matches_inproc_gateway_byte_for_byte(gw):
    """The acceptance criterion: the same seeded request stream answers
    byte-identically over the socket and through the in-process
    gateway."""
    workload = build_workload(32, jobs=("grep",), seed=11)

    async def drive():
        app, server = await serve_edge(gw)
        try:
            reader, writer = await _conn(server)
            http = []
            for path, body in workload:
                status, payload = await _request(reader, writer, "POST",
                                                 path, body)
                assert status == 200
                http.append(payload)
            writer.close()
        finally:
            await server.stop()
        async with AsyncHubGateway(gw) as agw:
            inproc = [await agw.handle_async(decode(body.decode()))
                      for _, body in workload]
        return http, inproc

    http, inproc = asyncio.run(drive())
    for got, want in zip(http, inproc):
        assert got == encode(want).encode("ascii")


# --------------------------------------------------------------------------
# malformed-body hardening (satellite: typed envelopes, never raw 500s)
# --------------------------------------------------------------------------

def test_malformed_bodies_answer_typed_envelopes_and_keepalive_survives(gw):
    cases = [
        # (path, body, expected HTTP status, detail fragment)
        ("/v1/choose", b'{"__type__": "ChooseReq', 400, "malformed"),
        ("/v1/choose", b'{"__type__": "NopeRequest"}', 400, "malformed"),
        ("/v1/choose", b"[1, 2, 3]", 400, "expects a ChooseRequest"),
        ("/v1/choose",
         encode(PredictRequest("grep", "m5.xlarge",
                               ((4.0, 15.0, 0.02),))).encode(),
         400, "expects a ChooseRequest"),
        ("/v1", encode(Response.success(None)).encode(), 400,
         "not an API v1 request"),
        ("/v1/teleport", CHOOSE_BODY, 404, "unknown operation"),
        ("/nope", CHOOSE_BODY, 404, "no such endpoint"),
    ]

    async def drive():
        app, server = await serve_edge(gw)
        try:
            reader, writer = await _conn(server)
            for path, body, want_status, fragment in cases:
                status, payload = await _request(reader, writer, "POST",
                                                 path, body)
                resp = _decode(payload)
                assert status == want_status, (path, status)
                assert not resp.ok and resp.error_code == ERR_BAD_REQUEST
                assert fragment in resp.detail, (path, resp.detail)
            # wrong methods are envelopes too
            status, payload = await _request(reader, writer, "GET",
                                             "/v1/choose")
            assert status == 405 and not _decode(payload).ok
            status, payload = await _request(reader, writer, "POST",
                                             "/healthz")
            assert status == 405 and not _decode(payload).ok
            # the SAME connection still serves a good request after all
            # of the above (keep-alive framing survived every refusal)
            status, payload = await _request(reader, writer, "POST",
                                             "/v1/choose", CHOOSE_BODY)
            assert status == 200 and _decode(payload).ok
            stats = app.snapshot()
            assert stats.errors == len(cases) + 2
            writer.close()
        finally:
            await server.stop()

    asyncio.run(drive())


def test_oversized_body_answers_typed_413_within_the_cap(gw):
    async def drive():
        app, server = await serve_edge(gw, max_body=2048)
        try:
            reader, writer = await _conn(server)
            status, payload = await _request(reader, writer, "POST",
                                             "/v1/choose", b"x" * 4096)
            resp = _decode(payload)
            assert status == 413
            assert resp.error_code == ERR_BAD_REQUEST
            assert "2048-byte cap" in resp.detail
            # small overshoot was drained: the connection still serves
            status, payload = await _request(reader, writer, "POST",
                                             "/v1/choose", CHOOSE_BODY)
            assert status == 200 and _decode(payload).ok
            writer.close()
        finally:
            await server.stop()

    asyncio.run(drive())


def test_protocol_refusals_are_typed_envelopes(gw):
    """Below the ASGI app: chunked transfer encoding and unparseable
    content-length are refused with codec envelopes, not dropped."""

    async def raw_exchange(server, head: bytes):
        reader, writer = await _conn(server)
        writer.write(head)
        await writer.drain()
        raw = await reader.readuntil(b"\r\n\r\n")
        status = int(raw.split(b" ", 2)[1])
        length = 0
        for line in raw.split(b"\r\n"):
            if line.lower().startswith(b"content-length:"):
                length = int(line.split(b":", 1)[1])
        payload = await reader.readexactly(length)
        writer.close()
        return status, payload

    async def drive():
        app, server = await serve_edge(gw)
        try:
            status, payload = await raw_exchange(
                server, b"POST /v1/choose HTTP/1.1\r\n"
                        b"transfer-encoding: chunked\r\n\r\n")
            assert status == 400
            assert "chunked" in _decode(payload).detail
            status, payload = await raw_exchange(
                server, b"POST /v1/choose HTTP/1.1\r\n"
                        b"content-length: banana\r\n\r\n")
            assert status == 400
            assert "content-length" in _decode(payload).detail
        finally:
            await server.stop()

    asyncio.run(drive())


def test_bad_request_interleaved_with_good_on_the_same_predict_lane(gw):
    """A wrong-width predict row riding the same lane tick as good
    single-row predicts fails ALONE (typed bad_request); the good ones
    are answered and the lane keeps serving afterwards."""
    good_body = encode(PredictRequest(
        "grep", "m5.xlarge", ((4.0, 15.0, 0.02),))).encode("ascii")
    bad_body = encode(PredictRequest(
        "grep", "m5.xlarge", ((4.0, 15.0),))).encode("ascii")

    async def one(server, body):
        reader, writer = await _conn(server)
        try:
            return await _request(reader, writer, "POST", "/v1/predict",
                                  body)
        finally:
            writer.close()

    async def drive():
        app, server = await serve_edge(gw, tick_s=0.005)
        try:
            results = await asyncio.gather(
                one(server, good_body), one(server, bad_body),
                one(server, good_body), one(server, good_body))
            # and the lane still serves after the poisoned tick
            late_status, late_payload = await one(server, good_body)
            return results, (late_status, late_payload)
        finally:
            await server.stop()

    results, (late_status, late_payload) = asyncio.run(drive())
    statuses = sorted(s for s, _ in results)
    assert statuses == [200, 200, 200, 400]
    bad = [_decode(p) for s, p in results if s == 400]
    assert bad[0].error_code == ERR_BAD_REQUEST
    goods = [_decode(p) for s, p in results if s == 200]
    assert all(g.ok for g in goods)
    assert late_status == 200 and _decode(late_payload).ok


# --------------------------------------------------------------------------
# shutdown drain (satellite: in-flight finishes, new work refused)
# --------------------------------------------------------------------------

def test_shutdown_drains_inflight_and_refuses_new_requests(gw):
    async def drive():
        # a long lane tick holds the in-flight predict open across the
        # start of the drain
        app, server = await serve_edge(gw, tick_s=0.25)
        body = encode(PredictRequest(
            "grep", "m5.xlarge", ((4.0, 15.0, 0.02),))).encode("ascii")

        r1, w1 = await _conn(server)       # will carry the in-flight op
        r2, w2 = await _conn(server)       # opened BEFORE the drain
        inflight = asyncio.ensure_future(
            _request(r1, w1, "POST", "/v1/predict", body))
        await asyncio.sleep(0.05)          # request accepted, tick pending
        assert app.in_flight == 1
        stopping = asyncio.ensure_future(server.stop())
        await asyncio.sleep(0.02)          # draining flag is up
        assert app.draining

        # a request mid-shutdown on a live connection: typed refusal
        status, payload = await _request(r2, w2, "POST", "/v1/predict",
                                         body)
        refused = _decode(payload)
        assert status == 503
        assert refused.error_code == ERR_SHUTTING_DOWN

        # the in-flight dispatch completed with a real answer
        status, payload = await inflight
        assert status == 200
        done = _decode(payload)
        assert done.ok and len(done.result.runtimes_s) == 1
        await stopping
        for w in (w1, w2):
            w.close()

        # new connections are refused at the TCP layer once stopped
        with pytest.raises(OSError):
            await _conn(server)

    asyncio.run(drive())


def test_health_reports_draining_during_drain(gw):
    async def drive():
        app, server = await serve_edge(gw)
        try:
            reader, writer = await _conn(server)
            app.draining = True            # simulate mid-drain
            status, payload = await _request(reader, writer, "GET",
                                             "/healthz")
            health = _decode(payload)
            assert status == 200 and health.ok
            assert health.result.status == "draining"
            writer.close()
            # draining responses carry connection: close — reconnect
            reader, writer = await _conn(server)
            status, payload = await _request(reader, writer, "POST",
                                             "/v1/choose", CHOOSE_BODY)
            assert status == 503
            assert _decode(payload).error_code == ERR_SHUTTING_DOWN
            writer.close()
        finally:
            app.draining = False
            await server.stop()

    asyncio.run(drive())


# --------------------------------------------------------------------------
# closed-loop load generator
# --------------------------------------------------------------------------

def test_build_workload_is_seed_deterministic():
    a = build_workload(48, jobs=("grep", "sort"), seed=5)
    b = build_workload(48, jobs=("grep", "sort"), seed=5)
    c = build_workload(48, jobs=("grep", "sort"), seed=6)
    assert a == b
    assert a != c
    assert all(body.decode("ascii") and path.startswith("/v1/")
               for path, body in a)


def test_loadgen_closed_loop_reports_and_coalesces(gw):
    async def drive():
        app, server = await serve_edge(gw, tick_s=0.002)
        try:
            return await run_loadgen(server.host, server.port,
                                     connections=8, requests=96,
                                     jobs=("grep",), seed=2)
        finally:
            await server.stop()

    report = asyncio.run(drive())
    assert report.requests == 96 and report.errors == 0
    assert report.connections == 8
    assert report.rps > 0 and report.wall_s > 0
    assert 0 < report.p50_ms <= report.p95_ms <= report.p99_ms
    assert sum(report.op_counts.values()) == 96
    assert report.server is not None       # /stats snapshot rode along
    assert report.server.requests >= 96
    assert report.predict_mean_batch() >= 1.0
    d = report.to_json()
    assert d["requests"] == 96 and "server" in d


def test_loadgen_empty_window_reports_nan_via_float_tags():
    """A rep window with zero completed requests (warmup-only short runs)
    reports NaN throughput — never a division by zero or an infinity —
    and ``to_json`` carries it as a strict-JSON float tag."""
    import json
    import math

    # requests=0 -> no workers even run; port 1 is never connected
    report = asyncio.run(run_loadgen("127.0.0.1", 1, connections=4,
                                     requests=0, jobs=("grep",), seed=0))
    assert report.requests == 0 and report.server is None
    assert math.isnan(report.rps)
    assert math.isnan(report.p50_ms) and math.isnan(report.p99_ms)
    d = report.to_json()
    assert d["rps"] == {"__float__": "nan"}
    json.dumps(d, allow_nan=False)         # strict JSON end to end
