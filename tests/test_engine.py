"""Prediction-engine contracts: no retracing across repeated fits/predicts,
batched choose_batch parity with scalar choose_scaleout, version-keyed hub
fit caching, and Pallas GBM-kernel routing parity."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine
from repro.core.configurator import Configurator
from repro.core.models.api import FittedModel, ModelSpec, get_model
from repro.core.predictor import C3OPredictor
from repro.workloads import spark_emul as W

SCALEOUTS = [2, 3, 4, 6, 8, 12, 16]
PRICES = {m.name: m.price for m in W.MACHINES.values()}


class _FakePredictor:
    """Deterministic predictor: t(s) = a/s + b*s + c, known error stats."""

    def __init__(self, a=1000.0, b=5.0, c=50.0, mu=0.0, sigma=10.0):
        self.a, self.b, self.c = a, b, c
        self.mu, self.sigma = mu, sigma

    def predict(self, X):
        s = np.asarray(X)[:, 0]
        return self.a / s + self.b * s + self.c

    def predict_with_error(self, X):
        return self.predict(X), self.mu, self.sigma


# --------------------------------------------------------------------------
# compilation-count regression
# --------------------------------------------------------------------------

def _probe_spec(calls):
    """A ModelSpec whose fit/predict bump a Python counter when traced —
    a retrace is visible as a second increment for identical shapes."""

    def fit(X, y, w, aux):
        calls["fit"] += 1
        return {"m": (w * y).sum() / jnp.maximum(w.sum(), 1e-9)}

    def predict(params, X, aux):
        calls["predict"] += 1
        return jnp.full((X.shape[0],), params["m"])

    return ModelSpec("_trace_probe", lambda X: {}, fit, predict)


def test_no_retrace_across_repeated_fitted_models():
    calls = {"fit": 0, "predict": 0}
    spec = _probe_spec(calls)
    rng = np.random.default_rng(0)
    X = rng.uniform(1, 10, (12, 2))
    y = rng.uniform(50, 100, 12)
    for _ in range(4):
        fm = FittedModel(spec, X, y)
        fm.predict(X[:5])
    # one trace per (spec, shape), no matter how many instances/calls
    assert calls["fit"] == 1
    assert calls["predict"] == 1
    fm.predict(X[:7])                    # new shape -> exactly one more trace
    assert calls["predict"] == 2


def test_no_retrace_across_repeated_cv_selection():
    calls = {"fit": 0, "predict": 0}
    spec = _probe_spec(calls)
    rng = np.random.default_rng(1)
    X = rng.uniform(1, 10, (10, 2))
    y = rng.uniform(50, 100, 10)
    folds = np.arange(10)
    for seed in range(3):
        engine.cv_select([spec], X, y + seed, folds)
    assert calls["fit"] == 1             # vmapped LOO traces the body once
    assert calls["predict"] == 1


# --------------------------------------------------------------------------
# choose_batch parity with scalar choose_scaleout
# --------------------------------------------------------------------------

def _assert_same_choice(a, b):
    assert a.scale_out == b.scale_out
    assert a.machine_type == b.machine_type
    assert a.bottleneck == b.bottleneck
    np.testing.assert_allclose(a.predicted_runtime_s, b.predicted_runtime_s)
    np.testing.assert_allclose(a.runtime_bound_s, b.runtime_bound_s)
    np.testing.assert_allclose(a.cost_usd, b.cost_usd)


@pytest.mark.parametrize("bottleneck", [None, lambda ctx, s: s <= 4])
def test_choose_batch_matches_scalar_fake_predictor(bottleneck):
    conf = Configurator(_FakePredictor(sigma=20.0), "m5.xlarge", PRICES,
                        SCALEOUTS, confidence=0.9, bottleneck_fn=bottleneck)
    rng = np.random.default_rng(2)
    contexts = rng.uniform(10, 20, (16, 1))
    for t_max in (None, 250.0, 400.0, 1e9):
        batched = conf.choose_batch(contexts, t_max=t_max)
        assert len(batched) == len(contexts)
        for ctx, ch in zip(contexts, batched):
            _assert_same_choice(ch, conf.choose_scaleout(ctx, t_max=t_max))


def test_choose_batch_matches_scalar_real_predictor():
    d = W.generate_job_data("grep").filter_machine("m5.xlarge")
    pred = C3OPredictor(max_cv_folds=15).fit(d.X, d.y)
    conf = Configurator(pred, "m5.xlarge", PRICES, SCALEOUTS)
    rng = np.random.default_rng(3)
    contexts = np.stack([rng.uniform(10, 20, 12),
                         rng.choice([.002, .02, .08], 12)], axis=1)
    t_maxes = rng.uniform(150, 600, 12)
    batched = conf.choose_batch(contexts, t_max=t_maxes)
    for ctx, tm, ch in zip(contexts, t_maxes, batched):
        _assert_same_choice(ch, conf.choose_scaleout(ctx, t_max=float(tm)))
    # no-deadline menu path too
    for ctx, ch in zip(contexts[:4], conf.choose_batch(contexts[:4])):
        _assert_same_choice(ch, conf.choose_scaleout(ctx))


# --------------------------------------------------------------------------
# hub fit cache / datastore versioning
# --------------------------------------------------------------------------

def test_predictor_for_refits_only_on_accepted_contribution():
    from repro.core.datastore import RuntimeDataStore
    from repro.core.features import RuntimeData
    from repro.core.hub import JobRepo

    data = W.generate_job_data("grep")
    store = RuntimeDataStore(data, seed=0)
    repo = JobRepo("grep", "grep", data.schema, store)
    p1 = repo.predictor_for("m5.xlarge")
    assert repo.predictor_for("m5.xlarge") is p1          # cache hit
    assert repo.predictor_for("m5.xlarge", seed=1) is not p1

    d = data.filter_machine("m5.xlarge")
    good = RuntimeData(data.schema, np.asarray(["m5.xlarge"] * 3),
                       d.X[:3], d.y[:3] * 1.01)
    report = repo.contribute(good)
    assert report.accepted
    assert store.version == 1
    assert repo.predictor_for("m5.xlarge") is not p1      # data changed


# --------------------------------------------------------------------------
# Pallas GBM ensemble routing
# --------------------------------------------------------------------------

def test_gbm_kernel_routing_matches_jnp_path(monkeypatch):
    rng = np.random.default_rng(4)
    X = rng.uniform(1, 10, (24, 2))
    y = 20 + 5 * X[:, 1] / X[:, 0] + rng.normal(0, 0.5, 24)
    fm = FittedModel(get_model("gbm"), X, y)
    Xq = rng.uniform(1, 10, (40, 2))
    monkeypatch.setenv("C3O_GBM_KERNEL", "off")
    ref = fm.predict(Xq)
    monkeypatch.setenv("C3O_GBM_KERNEL", "interpret")
    out = fm.predict(Xq)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)
