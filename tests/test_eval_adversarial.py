"""Adversarial replay plane: twin-arm (reputation weighting off/on)
trajectories over a poisoned contributor mix are well-formed, summarized
per job, and byte-identically deterministic for a fixed config."""
import numpy as np
import pytest

from repro.eval.adversarial import (ADV_TRAJECTORY_COLUMNS, WEIGHTING_ARMS,
                                    AdversarialConfig, run_adversarial,
                                    trajectory_tsv)
from repro.workloads.spark_emul import (ADVERSARY_KINDS,
                                        adversarial_user_data,
                                        generate_user_data)

#: one tiny job keeps this inside the suite's budget — the full 5-job
#: acceptance run is the CLI / benchmark lane's business
_CFG = AdversarialConfig(jobs=("sort",), n_users=4, poison_fraction=0.25,
                         seed=0, chunks_per_user=2, holdouts=1)


@pytest.fixture(scope="module")
def result():
    return run_adversarial(_CFG)


def test_config_partitions_users_deterministically():
    assert _CFG.poisoners() == (3,)               # last ceil(4 * 0.25) ids
    assert _CFG.honest() == (0, 1, 2)
    assert _CFG.attack_of(3) == ADVERSARY_KINDS[0]
    big = AdversarialConfig(n_users=8, poison_fraction=0.25)
    assert big.poisoners() == (6, 7)
    assert [big.attack_of(u) for u in big.poisoners()] == ["scale", "noise"]


@pytest.mark.slow
def test_too_few_honest_users_is_an_explicit_error():
    with pytest.raises(ValueError, match="honest"):
        run_adversarial(AdversarialConfig(jobs=("sort",), n_users=2,
                                          poison_fraction=0.6))


def test_adversarial_data_is_deterministic_and_actually_corrupted():
    honest = generate_user_data("sort", 3, 0)
    for kind in ADVERSARY_KINDS:
        a = adversarial_user_data("sort", 3, 0, kind)
        b = adversarial_user_data("sort", 3, 0, kind)
        assert a.to_tsv() == b.to_tsv()           # deterministic in the key
        assert a.to_tsv() != honest.to_tsv()      # and genuinely corrupted
    with pytest.raises(ValueError):
        adversarial_user_data("sort", 3, 0, "nonsense")


@pytest.mark.slow
def test_trajectories_cover_both_arms_with_shared_steps(result):
    arms = {r["weighting"] for r in result.records}
    assert arms == set(WEIGHTING_ARMS)
    # the SAME contribution stream drives both arms: step ranges match
    per_arm = {arm: sorted({r["step"] for r in result.records
                            if r["weighting"] == arm})
               for arm in WEIGHTING_ARMS}
    assert per_arm["off"] == per_arm["on"]
    assert per_arm["off"][0] == 0                 # seeded-store checkpoint
    for r in result.records:
        assert set(ADV_TRAJECTORY_COLUMNS) <= set(r)
        assert np.isfinite(r["mape"]) and r["store_rows"] > 0
    assert result.contributions > 0
    assert 0 < result.accepted <= result.contributions


@pytest.mark.slow
def test_summary_rolls_up_final_mape_per_arm(result):
    assert set(result.summary) == {"sort"}
    s = result.summary["sort"]
    assert s["improvement"] == pytest.approx(s["off_final"] - s["on_final"])
    assert s["ok"] == (s["on_final"] < s["off_final"])
    assert result.ok == s["ok"]


@pytest.mark.slow
def test_replay_is_byte_identically_deterministic(result):
    again = run_adversarial(_CFG)
    assert again.tsv == result.tsv
    assert again.fingerprint == result.fingerprint
    assert trajectory_tsv(result.records) == result.tsv
    assert result.tsv.splitlines()[0] == "\t".join(ADV_TRAJECTORY_COLUMNS)
