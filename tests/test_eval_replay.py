"""Collaborative evaluation replay plane: dataset assembly invariants,
trajectory structure, golden-pinned mini-replay MAPEs (drift tripwire),
and cross-run determinism."""
import hashlib
import json
import os

import numpy as np
import pytest

from repro.eval import replay as R
from repro.eval.dataset import build_multi_user, contribution_chunks, derived_rng
from repro.workloads import spark_emul as W

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "goldens",
                           "replay_mini.json")

MINI_CFG = R.ReplayConfig(jobs=("grep", "kmeans"), n_users=2, seed=0,
                          chunks_per_user=3)


@pytest.fixture(scope="module")
def mini_result():
    return R.run_replay(MINI_CFG)


# --------------------------------------------------------------------------
# dataset assembly
# --------------------------------------------------------------------------

def test_user_datasets_are_constant_size_and_context_coherent():
    mu = build_multi_user("grep", 4, seed=0)
    sizes = {len(d) for d in mu.per_user.values()}
    assert len(sizes) == 1          # store sizes align across held-out users
    for u, d in mu.per_user.items():
        # user-level perturbation: every context group spans all of the
        # user's scale-outs (the optimistic SSM needs same-context groups)
        groups = W.context_groups(d)
        n_scale = len(np.unique(d.scale_out))
        assert all(len(np.unique(d.scale_out[g])) == n_scale for g in groups)
        assert set(d.present_machines()) == set(W.MACHINES)
    # contexts differ across users (the heterogeneity being replayed)
    c0 = mu.per_user[0].context
    c1 = mu.per_user[1].context
    assert not np.isin(np.round(c1[:, -1], 9), np.round(c0[:, -1], 9)).any()


def test_contribution_chunks_partition_rows():
    d = W.generate_user_data("grep", 0, 0)
    chunks = contribution_chunks(d, 3, derived_rng("chunks", "grep", 0, 0))
    assert sum(len(c) for c in chunks) == len(d)
    merged = chunks[0]
    for c in chunks[1:]:
        merged = merged.append(c)
    # a permutation partition: same multiset of rows
    assert sorted(merged.y.tolist()) == sorted(d.y.tolist())
    # deterministic in the rng key
    again = contribution_chunks(d, 3, derived_rng("chunks", "grep", 0, 0))
    for a, b in zip(chunks, again):
        np.testing.assert_array_equal(a.y, b.y)


# --------------------------------------------------------------------------
# trajectory structure + goldens
# --------------------------------------------------------------------------

@pytest.mark.slow
def test_trajectory_structure(mini_result):
    res = mini_result
    assert res.records, "replay produced no checkpoints"
    jobs = {r["job"] for r in res.records}
    assert jobs == set(MINI_CFG.jobs)
    models = {r["model"] for r in res.records}
    assert models == set(MINI_CFG.track_models) | {"c3o"}
    for r in res.records:
        assert r["mape"] >= 0 and r["mae"] >= 0
        if r["model"] == "c3o":
            assert r["selected"] in MINI_CFG.model_names
        else:
            assert r["selected"] == ""
    # store sizes grow along each (job, held_out) trajectory
    for job in MINI_CFG.jobs:
        for held in range(MINI_CFG.n_users):
            sizes = [r["store_rows"] for r in res.records
                     if r["job"] == job and r["held_out"] == held
                     and r["model"] == "c3o"]
            assert sizes == sorted(sizes)
    # the TSV is the canonical artifact: header + one line per record,
    # fingerprint = sha256 over it
    lines = res.tsv.strip().split("\n")
    assert lines[0].split("\t") == list(R.TRAJECTORY_COLUMNS)
    assert len(lines) == len(res.records) + 1
    assert res.fingerprint == hashlib.sha256(res.tsv.encode()).hexdigest()


@pytest.mark.slow
def test_golden_mini_replay_mapes(mini_result):
    """Fixed-seed mini replay pinned to stored goldens: silent drift in any
    model, the engine's CV/fit paths, the emulators, or the replay protocol
    fails tier-1.  Regenerate (deliberately!) with
    ``python -m tests.make_replay_goldens``."""
    with open(GOLDEN_PATH) as f:
        golden = json.load(f)
    summary = mini_result.summary
    assert set(golden) == set(MINI_CFG.jobs)
    for job, expected in golden.items():
        got = summary[job]["final_mape"]
        assert set(got) == set(expected), (job, got, expected)
        for model, mape in expected.items():
            np.testing.assert_allclose(
                got[model], mape, rtol=0.05, atol=3e-3,
                err_msg=f"{job}/{model} drifted from golden")


@pytest.mark.slow
def test_replay_deterministic_across_runs():
    cfg = R.ReplayConfig(jobs=("sort",), n_users=2, seed=0,
                         chunks_per_user=2)
    a = R.run_replay(cfg)
    b = R.run_replay(cfg)
    assert a.tsv == b.tsv
    assert a.fingerprint == b.fingerprint


# --------------------------------------------------------------------------
# CLI: custom tracked models + provenance
# --------------------------------------------------------------------------

@pytest.mark.slow
def test_cli_track_models_adds_custom_model_rows(tmp_path, capsys):
    """`--track-models` replays over a caller-chosen model set — including
    registered custom maintainer models outside the default pool — and
    their rows land in the trajectory TSV."""
    from repro.core.models.api import ModelSpec, get_model, register_model
    lin = get_model("linreg")
    register_model(ModelSpec("cli_custom", lin.make_aux, lin.fit,
                             lin.predict))
    out = tmp_path / "traj.tsv"
    rc = R.main(["--users", "2", "--jobs", "grep",
                 "--track-models", "linreg,cli_custom",
                 "--out", str(out)])
    assert rc in (0, 1)                    # summary verdict, not a crash
    capsys.readouterr()
    lines = out.read_text().strip().split("\n")
    assert lines[0].split("\t") == list(R.TRAJECTORY_COLUMNS)
    models = {ln.split("\t")[7] for ln in lines[1:]}
    # exactly the tracked set plus the always-present c3o row; the default
    # pool's extra models (ernest/bom/ogb) are NOT tracked in this run
    assert models == {"linreg", "cli_custom", "c3o"}


@pytest.mark.slow
def test_replay_store_carries_real_user_provenance():
    """Replayed contributions are stamped with their emulated user's id:
    splitting the final store by contributor recovers exactly the
    non-held-out users' datasets (leave-one-user-out over REAL provenance
    instead of synthetic bookkeeping)."""
    from repro.core.datastore import RuntimeDataStore
    from repro.eval.dataset import (build_multi_user, contribution_chunks,
                                    split_by_contributor, user_contributor)
    job, held, seed = "grep", 0, 0
    mu = build_multi_user(job, 3, seed)
    store = None
    for u in mu.users:
        if u == held:
            continue
        for c in contribution_chunks(mu.per_user[u], 2,
                                     derived_rng("chunks", job, u, seed)):
            stamped = c.with_contributor(user_contributor(u))
            if store is None:
                store = RuntimeDataStore(stamped, seed=seed)
            else:
                assert store.contribute(stamped).accepted
    parts = split_by_contributor(store.data)
    assert set(parts) == {user_contributor(u) for u in mu.users if u != held}
    for u in mu.users:
        if u == held:
            continue
        got = parts[user_contributor(u)]
        want = mu.per_user[u]
        assert sorted(got.y.tolist()) == sorted(want.y.tolist())


@pytest.mark.slow
def test_cli_compact_every_reruns_byte_identical(tmp_path, capsys):
    """Periodic-compaction replay stays a determinism artifact: two runs
    of the same ``--compact-every`` config produce byte-identical
    trajectory TSVs, and the trajectory schema carries the lifecycle
    columns (live rows AND lifetime ingested rows + epoch)."""
    out_a, out_b = tmp_path / "a.tsv", tmp_path / "b.tsv"
    args = ["--users", "2", "--jobs", "grep", "--compact-every", "2"]
    rc_a = R.main(args + ["--out", str(out_a)])
    capsys.readouterr()
    rc_b = R.main(args + ["--out", str(out_b)])
    capsys.readouterr()
    assert rc_a == rc_b
    assert out_a.read_bytes() == out_b.read_bytes()
    lines = out_a.read_text().strip().split("\n")
    header = lines[0].split("\t")
    assert header == list(R.TRAJECTORY_COLUMNS)
    i_rows = header.index("store_rows")
    i_cum = header.index("rows_contributed")
    for ln in lines[1:]:
        f = ln.split("\t")
        # live store can never exceed what was ever ingested
        assert int(f[i_rows]) <= int(f[i_cum])


# --------------------------------------------------------------------------
# summary logic (no engine involved)
# --------------------------------------------------------------------------

def _rec(job, held, step, rows, model, mape, selected=""):
    return {"job": job, "held_out": held, "step": step, "store_rows": rows,
            "machine": "m", "model": model, "mape": mape, "mae": mape,
            "selected": selected}


def test_summarize_final_and_quartiles():
    cfg = R.ReplayConfig(jobs=("grep",), n_users=2,
                         track_models=("bom", "linreg"))
    records = []
    for held, err in ((0, 0.40), (1, 0.60)):
        for step, rows in enumerate((10, 20, 30, 40)):
            decayed = err / (step + 1)
            records.append(_rec("grep", held, step, rows, "c3o", decayed,
                                selected="gbm"))
            records.append(_rec("grep", held, step, rows, "bom",
                                2 * decayed))
            records.append(_rec("grep", held, step, rows, "linreg", 0.5))
    s = R.summarize(records, cfg)["grep"]
    np.testing.assert_allclose(s["c3o_final"], np.mean([0.1, 0.15]))
    assert s["beats_baselines"]
    assert s["monotone"]                    # strictly decaying trajectories
    assert s["selected_counts"] == {"gbm": 2}
    assert len(s["quartile_medians"]) == 4
    # an error trajectory that RISES at the end must flip monotone off
    records.append(_rec("grep", 0, 4, 50, "c3o", 5.0, selected="gbm"))
    records.append(_rec("grep", 1, 4, 50, "c3o", 5.0, selected="gbm"))
    assert not R.summarize(records, cfg)["grep"]["monotone"]
