"""Per-kernel allclose sweeps vs ref.py oracles (interpret=True on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # graceful degrade: example sweeps
    from _hyp_fallback import given, settings, strategies as st

from repro.kernels import ref as R
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.gbm_predict import gbm_predict
from repro.kernels.mamba_scan import mamba_scan
from repro.kernels.wkv6 import wkv6


def _rand(key, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


ATTN_CASES = [
    # (B, S, H, KV, hd, causal, window, cap, dtype, tol)
    (2, 256, 4, 2, 64, True, 0, 0.0, jnp.float32, 2e-5),
    (1, 384, 4, 1, 128, True, 64, 0.0, jnp.float32, 2e-5),
    (2, 128, 8, 8, 64, True, 0, 50.0, jnp.float32, 2e-5),
    (1, 256, 4, 4, 64, False, 0, 0.0, jnp.float32, 2e-5),
    (1, 256, 4, 2, 64, True, 128, 30.0, jnp.bfloat16, 3e-2),
]


@pytest.mark.parametrize("case", ATTN_CASES)
def test_flash_attention_matches_ref(case):
    B, S, H, KV, hd, causal, window, cap, dtype, tol = case
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = _rand(ks[0], (B, S, H, hd), dtype)
    k = _rand(ks[1], (B, S, KV, hd), dtype)
    v = _rand(ks[2], (B, S, KV, hd), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window, softcap=cap,
                          q_block=128, kv_block=128, interpret=True)
    ref = R.attention_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                          v.astype(jnp.float32), causal=causal,
                          window=window, softcap=cap)
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref),
                               atol=tol, rtol=tol * 10)


@settings(max_examples=12, deadline=None)
@given(B=st.integers(1, 2), nq=st.integers(1, 3), H=st.sampled_from([2, 4]),
       G=st.sampled_from([1, 2]), hd=st.sampled_from([32, 64]),
       causal=st.booleans())
def test_flash_attention_hypothesis(B, nq, H, G, hd, causal):
    S = nq * 64
    KV = max(H // G, 1)
    ks = jax.random.split(jax.random.PRNGKey(B * 100 + S + H + hd), 3)
    q = _rand(ks[0], (B, S, H, hd))
    k = _rand(ks[1], (B, S, KV, hd))
    v = _rand(ks[2], (B, S, KV, hd))
    out = flash_attention(q, k, v, causal=causal, q_block=64, kv_block=64,
                          interpret=True)
    ref = R.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5,
                               rtol=2e-4)


@settings(max_examples=10, deadline=None)
@given(B=st.integers(1, 2), L=st.sampled_from([128, 256, 384]),
       KV=st.sampled_from([1, 2, 4]), G=st.sampled_from([1, 2, 4]),
       window=st.sampled_from([0, 64]))
def test_decode_attention_hypothesis(B, L, KV, G, window):
    H, hd = KV * G, 64
    pos = L // 2 + 7
    ks = jax.random.split(jax.random.PRNGKey(L + KV * 10 + G), 3)
    q = _rand(ks[0], (B, H, hd))
    kc = _rand(ks[1], (B, L, KV, hd))
    vc = _rand(ks[2], (B, L, KV, hd))
    out = decode_attention(q, kc, vc, jnp.int32(pos), window=window,
                           block=64, interpret=True)
    ref = R.decode_attention_ref(q, kc, vc, pos=pos, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5,
                               rtol=2e-4)


@settings(max_examples=8, deadline=None)
@given(B=st.integers(1, 2), n_chunks=st.integers(2, 6),
       H=st.sampled_from([2, 4]), hd=st.sampled_from([16, 32]),
       decay=st.floats(0.2, 2.0))
def test_wkv6_hypothesis(B, n_chunks, H, hd, decay):
    S = 16 * n_chunks
    ks = jax.random.split(jax.random.PRNGKey(B + S + H + hd), 5)
    r, k, v = [_rand(ks[i], (B, S, H, hd), scale=0.5) for i in range(3)]
    # RWKV6 decay domain: w = exp(-exp(x)) with trained x <= ~2
    # (the kernel clamps log w at -9, outside this domain)
    x_w = jnp.clip(_rand(ks[3], (B, S, H, hd), scale=decay), -8.0, 2.0)
    w = jnp.exp(-jnp.exp(x_w))
    u = _rand(ks[4], (H, hd), scale=0.3)
    y_k, s_k = wkv6(r, k, v, w, u, interpret=True)
    y_r, s_r = R.wkv6_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r), atol=2e-4,
                               rtol=1e-3)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r), atol=2e-4,
                               rtol=1e-3)


def test_wkv6_carried_state():
    """Splitting a sequence across two kernel calls == one call (the decode
    / prefill continuation contract)."""
    B, S, H, hd = 1, 64, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(9), 4)
    r, k, v = [_rand(ks[i], (B, S, H, hd), scale=0.5) for i in range(3)]
    w = jnp.exp(-jnp.exp(_rand(ks[3], (B, S, H, hd), scale=0.5)))
    u = jnp.zeros((H, hd))
    y_full, s_full = wkv6(r, k, v, w, u, interpret=True)
    y1, s1 = wkv6(r[:, :32], k[:, :32], v[:, :32], w[:, :32], u,
                  interpret=True)
    y2, s2 = wkv6(r[:, 32:], k[:, 32:], v[:, 32:], w[:, 32:], u, s0=s1,
                  interpret=True)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=2e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full), atol=2e-4,
                               rtol=1e-3)


@settings(max_examples=8, deadline=None)
@given(B=st.integers(1, 2), S=st.sampled_from([64, 128]),
       D=st.sampled_from([128, 256]), N=st.sampled_from([4, 8]))
def test_mamba_scan_hypothesis(B, S, D, N):
    ks = jax.random.split(jax.random.PRNGKey(S + D + N), 5)
    u = _rand(ks[0], (B, S, D), scale=0.5)
    dt = jax.nn.softplus(_rand(ks[1], (B, S, D), scale=0.3))
    A = -jnp.exp(_rand(ks[2], (D, N), scale=0.3))
    Bi = _rand(ks[3], (B, S, N), scale=0.5)
    Ci = _rand(ks[4], (B, S, N), scale=0.5)
    y_k, h_k = mamba_scan(u, dt, A, Bi, Ci, chunk=32, d_block=128,
                          interpret=True)
    y_r, h_r = R.mamba_scan_ref(u, dt, A, Bi, Ci)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r), atol=2e-5,
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_r), atol=2e-5,
                               rtol=1e-4)


@settings(max_examples=6, deadline=None)
@given(n=st.integers(5, 200), d=st.integers(2, 6), T=st.sampled_from([10, 50]))
def test_gbm_predict_kernel_hypothesis(n, d, T):
    from repro.core.models.gbm import gbm_fit, gbm_predict as gbm_jnp
    rng = np.random.default_rng(n * d)
    X = rng.uniform(0, 10, (n, d)).astype(np.float32)
    y = (X[:, 0] * 2 + np.sin(X[:, 1])).astype(np.float32)
    orders = jnp.asarray(np.argsort(X, axis=0).T)
    params = gbm_fit(jnp.asarray(X), jnp.asarray(y), jnp.ones(n), orders,
                     n_trees=T)
    ref = gbm_jnp(params, jnp.asarray(X))
    out = gbm_predict(jnp.asarray(X), params.feat, params.thr, params.leaf,
                      params.f0, params.y_scale, row_block=64, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4,
                               rtol=1e-4)
