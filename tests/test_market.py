"""Cloud market plane: PriceBook validation, interruption math
properties, market-mode grid selection, gateway placement surface."""
import asyncio
import math

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                  # pragma: no cover
    from _hyp_fallback import given, settings, strategies as st

from repro.api.gateway import AsyncHubGateway, HubGateway
from repro.api.types import ChooseRequest
from repro.core.datastore import RuntimeDataStore
from repro.core.hub import Hub, JobRepo
from repro.core.market import (DEFAULT_ZONE, ON_DEMAND, SPOT, MarketError,
                               Placement, PriceBook,
                               expected_completion_time_s, expected_cost_usd,
                               realized_completion_time_s, validate_prices)
from repro.core.service import ConfigurationService
from repro.workloads import spark_emul as W


# --------------------------------------------------------------------------
# fixtures
# --------------------------------------------------------------------------

class FakePredictor:
    """Deterministic runtime law: base * size / s + 30 * s seconds."""

    def __init__(self, base):
        self.base, self.mu, self.sigma = float(base), 0.0, 10.0

    def predict(self, rows):
        rows = np.asarray(rows, np.float64)
        return self.base * rows[:, 1] / rows[:, 0] + 30.0 * rows[:, 0]

    def predict_with_error(self, rows):
        return self.predict(rows), self.mu, self.sigma


PREDICTORS = {"m5": FakePredictor(40.0), "c5": FakePredictor(55.0)}
PRICES = {"m5": 0.2, "c5": 0.17}
SCALEOUTS = (2, 4, 8)


def two_zone_book(restart_overhead_s=180.0):
    """az-a: mild spot; az-c: deep discount, very flaky."""
    return PriceBook(
        {("m5", "az-a", ON_DEMAND): 0.2, ("m5", "az-a", SPOT): 0.14,
         ("m5", "az-c", ON_DEMAND): 0.2, ("m5", "az-c", SPOT): 0.06,
         ("c5", "az-a", ON_DEMAND): 0.17, ("c5", "az-a", SPOT): 0.12,
         ("c5", "az-c", ON_DEMAND): 0.17, ("c5", "az-c", SPOT): 0.05},
        {("az-a", SPOT): 0.2, ("az-c", SPOT): 10.0},
        restart_overhead_s=restart_overhead_s)


def emulated_gateway(market, jobs=("grep",), seed=0):
    hub = Hub()
    for job in jobs:
        data = W.generate_job_data(job, seed)
        hub.publish(JobRepo(job, job, data.schema,
                            RuntimeDataStore(data, seed=seed),
                            predictor_kw={"max_cv_folds": 10}))
    prices = {m.name: m.price for m in W.MACHINES.values()}
    return HubGateway(hub, prices, (2, 3, 4, 6), seed=seed, market=market)


# --------------------------------------------------------------------------
# PriceBook validation (tentpole + satellite: typed errors, not KeyErrors)
# --------------------------------------------------------------------------

def test_pricebook_rejects_missing_and_invalid_prices():
    with pytest.raises(MarketError, match="positive finite"):
        PriceBook({("m5", "z", ON_DEMAND): 0.0})
    with pytest.raises(MarketError, match="positive finite"):
        PriceBook({("m5", "z", ON_DEMAND): -0.1})
    with pytest.raises(MarketError, match="positive finite"):
        PriceBook({("m5", "z", ON_DEMAND): math.nan})
    with pytest.raises(MarketError, match="positive finite"):
        PriceBook({("m5", "z", ON_DEMAND): [0.2, math.inf]})
    with pytest.raises(MarketError, match="empty price book"):
        PriceBook({})
    with pytest.raises(MarketError, match="unknown purchase option"):
        PriceBook({("m5", "z", "reserved"): 0.2})


def test_pricebook_requires_dense_machine_x_placement_coverage():
    with pytest.raises(MarketError, match="has no price for zone"):
        PriceBook({("m5", "z1", ON_DEMAND): 0.2,
                   ("c5", "z2", ON_DEMAND): 0.17})


def test_pricebook_requires_spot_interruption_rates():
    with pytest.raises(MarketError, match="no interruption rate"):
        PriceBook({("m5", "z", SPOT): 0.06})
    with pytest.raises(MarketError, match="invalid interruption rate"):
        PriceBook({("m5", "z", SPOT): 0.06}, {("z", SPOT): -1.0})
    with pytest.raises(MarketError, match="prices no such placement"):
        PriceBook({("m5", "z", ON_DEMAND): 0.2}, {("y", SPOT): 1.0})


def test_pricebook_time_varying_series_wrap():
    book = PriceBook({("m5", "z", ON_DEMAND): [0.2, 0.3, 0.4]})
    assert book.n_ticks == 3
    book.seek(1)
    assert book.price_of("m5", "z", ON_DEMAND) == 0.3
    book.advance(2)                                  # tick 3 wraps to 0
    assert book.price_of("m5", "z", ON_DEMAND) == 0.2
    assert book.price_of("m5", "z", ON_DEMAND, tick=2) == 0.4


def test_pricebook_resolve_constraints_are_typed_errors():
    book = two_zone_book()
    assert [p.zone for p in book.resolve(zones=("az-a",))] \
        == ["az-a", "az-a"]
    assert [p.option for p in book.resolve(options=(SPOT,))] \
        == [SPOT, SPOT]
    with pytest.raises(MarketError, match="unknown zone 'mars'"):
        book.resolve(zones=("mars",))
    with pytest.raises(MarketError, match="unknown purchase option"):
        book.resolve(options=("reserved",))
    with pytest.raises(MarketError, match="empty placement constraint"):
        book.resolve(zones=())
    with pytest.raises(MarketError, match="empty placement constraint"):
        book.resolve(options=())


def test_validate_prices_flags_missing_zero_and_negative():
    validate_prices(PRICES, ("m5", "c5"))
    with pytest.raises(MarketError, match="no \\$/node-hour price"):
        validate_prices(PRICES, ("m5", "r5"))
    for bad in (0.0, -1.0, math.nan, math.inf, "free"):
        with pytest.raises(MarketError, match="positive finite"):
            validate_prices({"m5": bad}, ("m5",))


def test_placement_rejects_unknown_option():
    with pytest.raises(MarketError, match="unknown purchase option"):
        Placement("z", "reserved")


# --------------------------------------------------------------------------
# interruption math properties (satellite 3)
# --------------------------------------------------------------------------

@settings(max_examples=60, deadline=None)
@given(t=st.floats(1.0, 50_000.0), overhead=st.floats(0.0, 3600.0),
       r1=st.floats(0.0, 50.0), r2=st.floats(0.0, 50.0))
def test_expected_cost_monotone_in_interruption_rate(t, overhead, r1, r2):
    lo, hi = sorted((r1, r2))
    c_lo = expected_cost_usd(t, 0.2, 4, lo, overhead)
    c_hi = expected_cost_usd(t, 0.2, 4, hi, overhead)
    assert np.isfinite(c_lo) and np.isfinite(c_hi)
    assert c_lo <= c_hi * (1 + 1e-12)


@settings(max_examples=40, deadline=None)
@given(t=st.floats(0.0, 50_000.0), overhead=st.floats(0.0, 3600.0),
       price=st.floats(0.01, 10.0), nodes=st.integers(1, 64))
def test_expected_cost_at_rate_zero_is_undiscounted(t, overhead, price,
                                                    nodes):
    c = expected_cost_usd(t, price, nodes, 0.0, overhead)
    assert c == pytest.approx(price * (t / 3600.0) * nodes, rel=1e-12)
    # and expected completion time degenerates to the runtime exactly
    assert float(expected_completion_time_s(t, 0.0, overhead)) == t


@settings(max_examples=40, deadline=None)
@given(t=st.floats(1.0, 50_000.0), overhead=st.floats(0.0, 3600.0),
       price=st.floats(0.01, 10.0))
def test_spot_and_on_demand_coincide_at_equal_price_and_rate_zero(
        t, overhead, price):
    """Rate-0 spot priced AT the on-demand rate is indistinguishable
    from on-demand: the discount's only counterweight is the rate."""
    spot = expected_cost_usd(t, price, 8, 0.0, overhead)
    on_demand = expected_cost_usd(t, price, 8, 0.0, overhead)
    assert float(spot) == float(on_demand)
    book = PriceBook({("m5", "z", ON_DEMAND): price,
                      ("m5", "z", SPOT): price}, {("z", SPOT): 0.0})
    mat = book.price_matrix(["m5"])
    costs = expected_cost_usd(t, mat[0], 8, book.rates(), overhead)
    assert costs[0] == costs[1]


@settings(max_examples=40, deadline=None)
@given(t=st.floats(1.0, 50_000.0), rate=st.floats(0.0, 50.0),
       overhead=st.floats(0.0, 3600.0))
def test_expected_completion_never_below_runtime(t, rate, overhead):
    e = float(expected_completion_time_s(t, rate, overhead))
    assert np.isfinite(e)
    assert e >= t * (1 - 1e-12)


def test_expected_completion_matches_realized_mean():
    rng = np.random.default_rng(7)
    closed = float(expected_completion_time_s(1800.0, 3.0, 120.0))
    mean = np.mean([realized_completion_time_s(1800.0, 3.0, 120.0, rng)
                    for _ in range(4000)])
    assert mean == pytest.approx(closed, rel=0.05)


def test_expected_completion_broadcasts():
    t = np.arange(1.0, 7.0).reshape(2, 3)
    rates = np.array([0.0, 2.0])
    e = expected_completion_time_s(t[None], rates[:, None, None], 60.0)
    assert e.shape == (2, 2, 3)
    assert np.array_equal(e[0], t)                   # rate 0 row exact
    assert (e[1] > t).all()


# --------------------------------------------------------------------------
# market-mode grid selection
# --------------------------------------------------------------------------

def test_flat_book_reproduces_static_selection_exactly():
    """A single-zone on-demand rate-0 book is the legacy cost model:
    choices (and every reported number) match field-for-field."""
    legacy = ConfigurationService(PREDICTORS, PRICES, SCALEOUTS)
    market = ConfigurationService(PREDICTORS, {}, SCALEOUTS,
                                  market=PriceBook.flat(PRICES))
    ctx = np.array([[50.0], [400.0], [2000.0]])
    deadlines = np.array([600.0, np.nan, 900.0])
    for a, b in zip(legacy.choose_cluster_batch(ctx, deadlines),
                    market.choose_cluster_batch(ctx, deadlines)):
        assert (a.machine_type, a.scale_out, a.predicted_runtime_s,
                a.runtime_bound_s, a.cost_usd, a.bottleneck) \
            == (b.machine_type, b.scale_out, b.predicted_runtime_s,
                b.runtime_bound_s, b.cost_usd, b.bottleneck)
        assert (b.zone, b.purchase_option) == (DEFAULT_ZONE, ON_DEMAND)
        assert b.expected_cost_usd == b.cost_usd


def test_long_jobs_flee_flaky_spot_short_jobs_keep_it():
    svc = ConfigurationService(PREDICTORS, {}, SCALEOUTS,
                               market=two_zone_book())
    short, = svc.choose_cluster_batch(np.array([[5.0]]))
    long, = svc.choose_cluster_batch(np.array([[2000.0]]))
    assert (short.zone, short.purchase_option) == ("az-c", SPOT)
    assert long.zone == "az-a"             # flaky deep discount rejected
    assert short.expected_cost_usd > short.cost_usd > 0.0
    assert long.expected_cost_usd >= long.cost_usd


def test_market_deadline_uses_interruption_adjusted_bound():
    """A deadline the raw runtime meets but the interruption-adjusted
    expected completion blows must push selection off flaky spot."""
    book = two_zone_book()
    svc = ConfigurationService(PREDICTORS, {}, SCALEOUTS, market=book)
    ctx = np.array([[400.0]])
    free, = svc.choose_cluster_batch(ctx)
    t = free.predicted_runtime_s
    # az-c spot at rate 10/h roughly triples this runtime in expectation;
    # a deadline at ~1.3x the runtime is only meetable off az-c
    tight, = svc.choose_cluster_batch(ctx, np.array([1.3 * t]))
    assert tight.zone != "az-c"
    assert tight.runtime_bound_s <= 1.3 * t


def test_market_constraints_restrict_selection():
    svc = ConfigurationService(PREDICTORS, {}, SCALEOUTS,
                               market=two_zone_book())
    ctx = np.array([[5.0]])
    od, = svc.choose_cluster_batch(ctx, options=(ON_DEMAND,))
    assert od.purchase_option == ON_DEMAND
    az_a, = svc.choose_cluster_batch(ctx, zones=("az-a",))
    assert az_a.zone == "az-a"
    with pytest.raises(MarketError, match="unknown zone"):
        svc.choose_cluster_batch(ctx, zones=("mars",))
    with pytest.raises(MarketError, match="empty placement constraint"):
        svc.choose_cluster_batch(ctx, zones=())


def test_constraints_without_market_are_typed_errors():
    svc = ConfigurationService(PREDICTORS, PRICES, SCALEOUTS)
    with pytest.raises(MarketError, match="market-enabled"):
        svc.choose_cluster_batch(np.array([[5.0]]), zones=("az-a",))


def test_service_construction_validates_prices():
    with pytest.raises(MarketError, match="no \\$/node-hour price"):
        ConfigurationService(PREDICTORS, {"m5": 0.2}, SCALEOUTS)
    with pytest.raises(MarketError, match="positive finite"):
        ConfigurationService(PREDICTORS, {"m5": 0.2, "c5": 0.0},
                             SCALEOUTS)
    with pytest.raises(MarketError, match="has no price in the market"):
        ConfigurationService(
            PREDICTORS, {}, SCALEOUTS,
            market=PriceBook({("m5", "z", ON_DEMAND): 0.2}))


def test_configurator_construction_validates_prices():
    from repro.core.configurator import Configurator
    with pytest.raises(MarketError, match="no \\$/node-hour price"):
        Configurator(PREDICTORS["m5"], "m5", {"c5": 0.17}, SCALEOUTS)
    with pytest.raises(MarketError, match="positive finite"):
        Configurator(PREDICTORS["m5"], "m5", {"m5": -0.2}, SCALEOUTS)


def test_choose_machine_type_validates_prices():
    from repro.core.configurator import choose_machine_type
    with pytest.raises(MarketError, match="no \\$/node-hour price"):
        choose_machine_type(PREDICTORS, {"m5": 0.2}, SCALEOUTS,
                            np.array([5.0]))


# --------------------------------------------------------------------------
# gateway surface (sync + async, satellite 2)
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def market_gateway():
    return emulated_gateway(W.generate_price_book(0))


def test_gateway_market_choice_carries_placement(market_gateway):
    resp = market_gateway.choose(ChooseRequest("grep", (15.0, 0.02)))
    assert resp.ok, resp.detail
    c = resp.result
    assert c.zone in W.SPOT_ZONES
    assert c.purchase_option in (ON_DEMAND, SPOT)
    assert c.expected_cost_usd >= c.cost_usd > 0.0


def test_gateway_honors_placement_constraints(market_gateway):
    resp = market_gateway.choose(ChooseRequest(
        "grep", (15.0, 0.02), zones=("az-1a",),
        purchase_options=(ON_DEMAND,)))
    assert resp.ok, resp.detail
    assert (resp.result.zone, resp.result.purchase_option) \
        == ("az-1a", ON_DEMAND)


def test_gateway_unknown_placement_is_bad_request(market_gateway):
    resp = market_gateway.choose(ChooseRequest(
        "grep", (15.0, 0.02), zones=("mars",)))
    assert not resp.ok and resp.error_code == "bad_request"
    assert "mars" in resp.detail and "az-1a" in resp.detail
    resp = market_gateway.choose(ChooseRequest(
        "grep", (15.0, 0.02), purchase_options=("reserved",)))
    assert not resp.ok and resp.error_code == "bad_request"
    assert "reserved" in resp.detail
    resp = market_gateway.choose(ChooseRequest(
        "grep", (15.0, 0.02), zones=()))
    assert not resp.ok and resp.error_code == "bad_request"
    assert "empty placement constraint" in resp.detail


def test_constraints_on_marketless_gateway_are_bad_request():
    gw = emulated_gateway(None)
    resp = gw.choose(ChooseRequest("grep", (15.0, 0.02),
                                   zones=("az-1a",)))
    assert not resp.ok and resp.error_code == "bad_request"
    assert "market-enabled" in resp.detail
    # and the plain path still answers without any market stamping
    resp = gw.choose(ChooseRequest("grep", (15.0, 0.02)))
    assert resp.ok
    assert (resp.result.zone, resp.result.purchase_option,
            resp.result.expected_cost_usd) == ("", "", 0.0)


def test_gateway_missing_price_is_bad_request_envelope():
    """Satellite 1 end to end: a store machine vocabulary wider than the
    price dict answers a typed bad_request naming the machine — not a
    bare KeyError mid-score, not an internal error."""
    data = W.generate_job_data("grep", 0)
    hub = Hub()
    hub.publish(JobRepo("grep", "grep", data.schema,
                        RuntimeDataStore(data, seed=0),
                        predictor_kw={"max_cv_folds": 10}))
    gw = HubGateway(hub, {"m5.xlarge": 0.192}, (2, 3, 4))
    resp = gw.choose(ChooseRequest("grep", (15.0, 0.02)))
    assert not resp.ok and resp.error_code == "bad_request"
    assert "no $/node-hour price" in resp.detail
    # zero/negative prices are equally refused (they would silently win
    # every cheapest-cost selection)
    prices = {m.name: m.price for m in W.MACHINES.values()}
    gw = HubGateway(hub, dict(prices, **{"c5.xlarge": 0.0}), (2, 3, 4))
    resp = gw.choose(ChooseRequest("grep", (15.0, 0.02)))
    assert not resp.ok and resp.error_code == "bad_request"
    assert "positive finite" in resp.detail


def test_async_market_paths_match_sync_and_leak_no_lanes(market_gateway):
    async def run():
        async with AsyncHubGateway(market_gateway) as agw:
            ok = await agw.choose(ChooseRequest(
                "grep", (15.0, 0.02), zones=("az-1a", "az-1b")))
            bad_zone = await agw.choose(ChooseRequest(
                "grep", (15.0, 0.02), zones=("mars",)))
            bad_empty = await agw.choose(ChooseRequest(
                "grep", (15.0, 0.02), purchase_options=()))
            unconstrained = await agw.choose(ChooseRequest(
                "grep", (15.0, 0.02)))
            return ok, bad_zone, bad_empty, unconstrained, \
                dict(agw._lanes)

    ok, bad_zone, bad_empty, unconstrained, lanes = asyncio.run(run())
    assert ok.ok and ok.result.zone in ("az-1a", "az-1b")
    assert not bad_zone.ok and bad_zone.error_code == "bad_request"
    assert "mars" in bad_zone.detail
    assert not bad_empty.ok and bad_empty.error_code == "bad_request"
    # constrained requests dispatch inline; only the unconstrained one
    # may have opened a lane — bad constraints never leak one
    assert len(lanes) == 1 and all("grep" in k for k in lanes)
    # the async envelopes match the sync path byte-for-byte
    sync_ok = market_gateway.choose(ChooseRequest(
        "grep", (15.0, 0.02), zones=("az-1a", "az-1b")))
    assert ok == sync_ok
    sync_un = market_gateway.choose(ChooseRequest("grep", (15.0, 0.02)))
    assert unconstrained == sync_un


# --------------------------------------------------------------------------
# emulated market + spot replay determinism
# --------------------------------------------------------------------------

def test_generated_price_book_is_deterministic_and_ordered():
    b1 = W.generate_price_book(0, n_ticks=16)
    b2 = W.generate_price_book(0, n_ticks=16)
    assert b1.placements == b2.placements
    for m in b1.machines:
        for p in b1.placements:
            for tick in range(16):
                assert b1.price_of(m, p.zone, p.option, tick) \
                    == b2.price_of(m, p.zone, p.option, tick)
    assert b1.rates().tolist() == b2.rates().tolist()
    # spot discounts below on-demand, rate ordering tracks the discount
    for m in b1.machines:
        for z in W.SPOT_ZONES:
            od = b1.price_of(m, z, ON_DEMAND)
            for tick in range(16):
                assert b1.price_of(m, z, SPOT, tick) < od
    assert b1.rate_of("az-1a", SPOT) < b1.rate_of("az-1b", SPOT) \
        < b1.rate_of("az-1c", SPOT)
    assert all(b1.rate_of(z, ON_DEMAND) == 0.0 for z in W.SPOT_ZONES)


def test_naive_view_zeroes_rates_and_keeps_prices():
    book = W.generate_price_book(0, n_ticks=8)
    book.seek(3)
    naive = book.naive_view()
    assert naive.tick == 3
    assert (naive.rates() == 0.0).all()
    assert np.array_equal(naive.price_matrix(book.machines),
                          book.price_matrix(book.machines))


@pytest.mark.slow
def test_spot_market_replay_is_deterministic_and_wins():
    from repro.eval.replay import SpotMarketConfig, run_spot_market
    cfg = SpotMarketConfig(jobs=("grep", "pagerank"), n_queries=6)
    r1 = run_spot_market(cfg)
    r2 = run_spot_market(cfg)
    assert r1.tsv == r2.tsv
    assert r1.fingerprint == r2.fingerprint
    assert r1.ok, r1.summary
    for s in r1.summary.values():
        assert s["adjusted_cost"] < s["naive_cost"]
