"""Unit tests for the C3O runtime models (paper §V)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.models.api import FittedModel, get_model
from repro.core.models.ernest import ernest_fit, ernest_predict


def _mape(pred, y):
    return float(np.mean(np.abs(pred - y) / np.abs(y)))


@pytest.fixture(scope="module")
def rng():
    return np.random.default_rng(0)


def test_gbm_recovers_nonlinear(rng):
    X = rng.uniform(0, 10, (300, 3))
    y = 50 + 10 * X[:, 0] + 5 * np.sin(X[:, 1]) + 0.5 * X[:, 2] ** 2
    m = FittedModel(get_model("gbm"), X, y)
    assert _mape(m.predict(X), y) < 0.05


def test_gbm_weighted_excludes_samples(rng):
    """w=0 rows must not influence the fit (the LOO-CV mechanism)."""
    X = rng.uniform(0, 10, (80, 2))
    y = 10 + 3 * X[:, 0] + X[:, 1]
    y_poison = y.copy()
    y_poison[:20] = 1e6                  # corrupted rows...
    w = np.ones(80)
    w[:20] = 0.0                         # ...masked out
    spec = get_model("gbm")
    aux = spec.make_aux(X)
    params = jax.jit(spec.fit)(jnp.asarray(X, jnp.float32),
                               jnp.asarray(y_poison, jnp.float32),
                               jnp.asarray(w, jnp.float32), aux)
    pred = np.asarray(spec.predict(params, jnp.asarray(X[20:], jnp.float32),
                                   aux))
    assert _mape(pred, y[20:]) < 0.1


def test_ernest_nnls_nonnegative_and_fits(rng):
    s = rng.choice([2, 4, 8, 16], 60).astype(float)
    z = rng.uniform(10, 30, 60)
    y = 20 + 5 * z / s + 12 * np.log(s) + 0.8 * s
    X = np.stack([s, z], 1)
    p = ernest_fit(jnp.asarray(X, jnp.float32), jnp.asarray(y, jnp.float32),
                   jnp.ones(60))
    assert bool((p.theta >= 0).all())
    assert _mape(np.asarray(ernest_predict(p, jnp.asarray(X, jnp.float32))),
                 y) < 0.05


def test_ernest_ignores_context_features(rng):
    """Ernest only sees (scale-out, size): context variation = noise to it
    (the paper's Table II 'global' failure mode)."""
    s = rng.choice([2, 4, 8], 120).astype(float)
    z = rng.uniform(10, 20, 120)
    k = rng.choice([1.0, 8.0], 120)           # strong hidden factor
    y = k * (10 + 40 * z / s)
    X3 = np.stack([s, z, k], 1)
    m = FittedModel(get_model("ernest"), X3, y)
    assert _mape(m.predict(X3), y) > 0.3      # cannot explain k
    m2 = FittedModel(get_model("gbm"), X3, y)
    assert _mape(m2.predict(X3), y) < 0.1     # GBM can


def test_optimistic_factorization(rng):
    """BOM exactly fits multiplicative t = base(ctx) * g(s) data."""
    s = np.tile([1, 2, 4, 8, 16], 20).astype(float)
    ctx = np.repeat(rng.uniform(1, 5, 20), 5)
    g = 1.0 / s + 0.05 * s                     # speedup curve
    y = (30 + 20 * ctx) * g / (1.0 / 1 + 0.05)  # normalized at s=1
    X = np.stack([s, ctx], 1)
    m = FittedModel(get_model("bom"), X, y)
    # cubic SSM cannot represent 1/s exactly -> a few % residual is expected
    assert _mape(m.predict(X), y) < 0.12


def test_ogb_factorization(rng):
    s = np.tile([1, 2, 4, 8], 25).astype(float)
    ctx = np.repeat(rng.uniform(1, 5, 25), 4)
    y = (30 + 20 * ctx) * (1.0 / s + 0.05 * s) / 1.05
    m = FittedModel(get_model("ogb"), np.stack([s, ctx], 1), y)
    assert _mape(m.predict(np.stack([s, ctx], 1)), y) < 0.12


def test_bom_degrades_without_scaleout_groups(rng):
    """Paper Fig.5: no context group with >=2 members -> SSM undetermined."""
    n = 8
    s = rng.choice([2, 4, 8, 16], n).astype(float)
    ctx = np.arange(n).astype(float)           # every context unique
    y = (10 + 5 * ctx) * (8.0 / s)
    m = FittedModel(get_model("bom"), np.stack([s, ctx], 1), y)
    test_s = np.stack([np.full(4, 32.0), np.arange(4).astype(float)], 1)
    # predictions for unseen scale-out are unreliable (no SSM signal)
    t_true = (10 + 5 * test_s[:, 1]) * (8.0 / 32)
    assert _mape(m.predict(test_s), t_true) > 0.3
