"""C3O predictor: dynamic model selection + Gaussian error calibration."""
import numpy as np

from repro.core.configurator import confidence_margin
from repro.core.predictor import C3OPredictor, evaluate_split
from repro.workloads import spark_emul as W


def test_confidence_margin_closed_form():
    # paper: c=0.95 -> t_s + mu + 1.64485 sigma
    m = confidence_margin(0.95, 0.0, 1.0)
    assert abs(m - 1.64485) < 1e-4
    assert abs(confidence_margin(0.5, 0.3, 2.0) - 0.3) < 1e-9


def test_selection_picks_good_model():
    rng = np.random.default_rng(1)
    s = np.tile([2, 4, 8, 16], 20).astype(float)
    z = rng.uniform(10, 30, 80)
    y = 20 + 5 * z / s + 12 * np.log(s)
    p = C3OPredictor(max_cv_folds=20).fit(np.stack([s, z], 1), y)
    assert p.selected in ("ernest", "gbm", "bom", "ogb")
    # prediction quality close to the best constituent (paper §VI-C claim)
    best = min(v for k, v in p.cv_mape.items())
    assert p.cv_mape[p.selected] <= best + 1e-9


def test_c3o_close_to_best_model_on_spark_job():
    d = W.generate_job_data("grep").filter_machine("m5.xlarge")
    rng = np.random.default_rng(0)
    idx = rng.permutation(len(d))
    tr, te = idx[:40], idx[40:]
    r = evaluate_split(("ernest", "gbm", "bom", "ogb"),
                       d.X[tr], d.y[tr], d.X[te], d.y[te])
    best = min(r[m] for m in ("ernest", "gbm", "bom", "ogb"))
    # paper: C3O within ~0.5% (absolute) of the best constituent, usually
    assert r["c3o"] <= best + 0.03


def test_residual_calibration_quality():
    d = W.generate_job_data("sort").filter_machine("m5.xlarge")
    p = C3OPredictor(max_cv_folds=30).fit(d.X, d.y)
    pred = p.predict(d.X)
    # in-sample sanity: sigma should be of the order of observed errors
    err = np.abs(pred - d.y)
    assert p.sigma > 0
    assert np.median(err) < 5 * p.sigma + 1.0
