"""Property-based hardening of the runtime-data plane: TSV codec round
trips, fingerprint chaining, and stratified-subsampling allocation hold for
*arbitrary* machine names, float magnitudes, row counts, and delta splits —
not just the emulated Spark datasets the rest of the suite uses."""
import hashlib
import string

import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # deterministic example sweeps
    from _hyp_fallback import given, settings, strategies as st

from repro.core.datastore import RuntimeDataStore, _waterfill
from repro.core.features import JobSchema, RuntimeData

# np.loadtxt splits on the delimiter only; anything printable and
# tab/newline-free is legal in a machine name — '#' included (comments are
# disabled in the codec), plus '.', '-', and digits.
_NAME_CHARS = string.ascii_letters + string.digits + "#.-_:"


def _random_data(rng: np.random.Generator, n: int, k: int,
                 scale: float) -> RuntimeData:
    schema = JobSchema("prop", tuple(f"c{i}" for i in range(k)))
    n_machines = int(rng.integers(1, 4))
    names = []
    for _ in range(n_machines):
        length = int(rng.integers(1, 12))
        names.append("".join(rng.choice(list(_NAME_CHARS), size=length)))
    machine_type = np.asarray(names)[rng.integers(0, n_machines, size=n)]
    X = np.empty((n, k + 1))
    X[:, 0] = rng.integers(1, 64, size=n)                 # scale-out
    X[:, 1:] = rng.uniform(0.05, 1000.0, size=(n, k)) * scale
    y = rng.uniform(0.05, 5000.0, size=n) * scale
    return RuntimeData(schema, machine_type, X, y)


@settings(max_examples=30, deadline=None)
@given(n=st.integers(1, 60), k=st.integers(0, 4), seed=st.integers(0, 10**6),
       scale=st.sampled_from([0.01, 1.0, 1e3]))
def test_tsv_roundtrip_property(n, k, seed, scale):
    """decode(encode(data)) preserves order, machines, features, runtimes —
    and re-encoding the decoded data is byte-identical (canonical form)."""
    d = _random_data(np.random.default_rng(seed), n, k, scale)
    text = d.to_tsv()
    back = RuntimeData.from_tsv(text, d.schema)
    assert len(back) == n
    assert (back.machine_type == d.machine_type).all()
    np.testing.assert_allclose(back.X, d.X, rtol=1e-5)    # %.6g columns
    np.testing.assert_allclose(back.y, d.y, rtol=1e-3, atol=1e-4)  # %.4f
    assert back.to_tsv() == text


@settings(max_examples=25, deadline=None)
@given(n=st.integers(2, 80), k=st.integers(0, 3), seed=st.integers(0, 10**6),
       n_chunks=st.integers(1, 6))
def test_fingerprint_chain_property(n, k, seed, n_chunks):
    """For ANY split of the rows into contribution deltas, the streaming
    fingerprint chain equals a full O(N) rehash of the final TSV — and
    equals the fingerprint of a store built from the whole data at once."""
    rng = np.random.default_rng(seed)
    d = _random_data(rng, n, k, 1.0)
    cuts = np.sort(rng.integers(1, n, size=min(n_chunks, n - 1)))
    bounds = [0, *dict.fromkeys(cuts.tolist()), n]
    chunks = [d.subset(np.arange(lo, hi))
              for lo, hi in zip(bounds[:-1], bounds[1:]) if hi > lo]
    # reject thresholds wide open: the property under test is the hash
    # chain over accepted deltas, not the validator's judgement
    store = RuntimeDataStore(chunks[0], reject_ratio=1e30, reject_slack=1e30)
    for c in chunks[1:]:
        assert store.contribute(c).accepted
    assert store.version == len(chunks) - 1
    assert store.fingerprint == hashlib.sha256(
        store.data.to_tsv().encode()).hexdigest()
    assert store.fingerprint == RuntimeDataStore(d).fingerprint
    assert store.data.to_tsv() == d.to_tsv()


@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 60), k=st.integers(0, 3), seed=st.integers(0, 10**6),
       all_unknown=st.booleans())
def test_tsv_roundtrip_with_provenance_property(n, k, seed, all_unknown):
    """Per-row contributor provenance round-trips through the TSV codec;
    data whose every contributor is "unknown" canonically encodes in the
    LEGACY column set (what keeps pre-provenance files byte-stable)."""
    rng = np.random.default_rng(seed)
    d = _random_data(rng, n, k, 1.0)
    pool = (["unknown"] if all_unknown else
            ["unknown", "alice", "üser-" + "".join(
                rng.choice(list(_NAME_CHARS), size=4))])
    names = np.asarray(pool, object)[rng.integers(0, len(pool), n)]
    d = RuntimeData(d.schema, d.machine_type, d.X, d.y,
                    contributor=names.astype(str))
    text = d.to_tsv()
    has_known = bool((names != "unknown").any())
    assert d.has_provenance == has_known
    assert ("contributor" in text.splitlines()[0]) == has_known
    back = RuntimeData.from_tsv(text, d.schema)
    assert (back.contributor == d.contributor).all()
    assert back.to_tsv() == text
    assert back.contributor_counts() == d.contributor_counts()


@settings(max_examples=20, deadline=None)
@given(n=st.integers(2, 80), k=st.integers(0, 3), seed=st.integers(0, 10**6),
       n_chunks=st.integers(1, 5), transition=st.integers(0, 5))
def test_fingerprint_chain_with_provenance_property(n, k, seed, n_chunks,
                                                    transition):
    """The streaming fingerprint equals a full rehash at EVERY step even
    across the legacy -> provenance encoding transition (contributions
    from chunk index ``transition`` onward carry contributor ids)."""
    rng = np.random.default_rng(seed)
    d = _random_data(rng, n, k, 1.0)
    cuts = np.sort(rng.integers(1, n, size=min(n_chunks, n - 1)))
    bounds = [0, *dict.fromkeys(cuts.tolist()), n]
    chunks = [d.subset(np.arange(lo, hi))
              for lo, hi in zip(bounds[:-1], bounds[1:]) if hi > lo]
    store = RuntimeDataStore(chunks[0], reject_ratio=1e30, reject_slack=1e30)
    for i, c in enumerate(chunks[1:], start=1):
        contributor = f"u{i}" if i >= transition else None
        assert store.contribute(c, contributor=contributor).accepted
        assert store.fingerprint == hashlib.sha256(
            store.data.to_tsv().encode()).hexdigest()
    assert sum(store.data.contributor_counts().values()) == n


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10**6), cap=st.integers(1, 200),
       n_groups=st.integers(1, 6))
def test_waterfill_allocation_property(seed, cap, n_groups):
    """Water-filling: never exceeds the cap, never drops a row that fits,
    keeps every small group whole, and samples without duplication."""
    rng = np.random.default_rng(seed)
    parts = [np.arange(1000 * g, 1000 * g + rng.integers(0, 120))
             for g in range(n_groups)]
    out = _waterfill(parts, cap)
    total = sum(len(p) for p in parts)
    # exact: the budget is exhausted unless the groups run out of rows
    # first (smallest-first visiting order makes the allocation tight)
    assert len(out) == min(cap, total)
    assert len(np.unique(out)) == len(out)
    # every group at least min(len(group), cap // n_groups): the rare-
    # machine floor stratified validation relies on
    for g, p in enumerate(parts):
        got = np.sum((out >= 1000 * g) & (out < 1000 * (g + 1)))
        assert got >= min(len(p), cap // n_groups)


# ---------------------------------------------------------------------------
# store lifecycle (epoch-based compaction)
# ---------------------------------------------------------------------------

def _compact_kw(**over):
    """Gate-free knobs: ``accuracy_budget=inf`` skips the engine-backed
    accuracy check, so the lifecycle invariants are tested pure-numpy."""
    kw = dict(max_rows_per_cell=2, support_floor=1, cell_rel_width=0.2,
              accuracy_budget=float("inf"), min_store_rows=1, seed=0)
    kw.update(over)
    return kw


@settings(max_examples=25, deadline=None)
@given(n=st.integers(10, 120), k=st.integers(0, 3),
       seed=st.integers(0, 10**6))
def test_compaction_idempotent_property(n, k, seed):
    """compact(compact(store)) is a no-op: row removal only WIDENS the
    gaps the context clustering splits on, so a freshly compacted store
    re-compacts to a rejected verdict with identical rows — for ANY data
    distribution, not just the emulated grids."""
    d = _random_data(np.random.default_rng(seed), n, k, 1.0)
    store = RuntimeDataStore(d, reject_ratio=1e30, reject_slack=1e30)
    first = store.compact(**_compact_kw())
    if not first.accepted:
        return                     # nothing removable: trivially idempotent
    tsv, ver, ep = store.data.to_tsv(), store.version, store.epoch
    second = store.compact(**_compact_kw())
    assert not second.accepted
    assert second.code == "compaction_rejected"
    assert store.data.to_tsv() == tsv        # byte-identical: pure no-op
    assert store.version == ver and store.epoch == ep


@settings(max_examples=25, deadline=None)
@given(n=st.integers(10, 120), k=st.integers(0, 3),
       seed=st.integers(0, 10**6), cap=st.integers(1, 4))
def test_compaction_deterministic_property(n, k, seed, cap):
    """Two stores over the same rows compact to byte-identical retained
    data and equal fingerprints under a fixed seed."""
    d = _random_data(np.random.default_rng(seed), n, k, 1.0)
    a = RuntimeDataStore(d, reject_ratio=1e30, reject_slack=1e30)
    b = RuntimeDataStore(d, reject_ratio=1e30, reject_slack=1e30)
    ra = a.compact(**_compact_kw(max_rows_per_cell=cap))
    rb = b.compact(**_compact_kw(max_rows_per_cell=cap))
    assert ra.accepted == rb.accepted and ra.code == rb.code
    assert a.data.to_tsv() == b.data.to_tsv()
    assert a.fingerprint == b.fingerprint
    assert a.epoch == b.epoch


@settings(max_examples=20, deadline=None)
@given(n=st.integers(12, 100), k=st.integers(0, 3),
       seed=st.integers(0, 10**6))
def test_compaction_fingerprint_reseed_property(n, k, seed):
    """The epoch transition reseeds the fingerprint chain: after a
    compaction — and after further contributions chained ON TOP of the
    reseeded state — the streaming fingerprint equals a full O(N) rehash
    of the live TSV, and matches a store freshly built from the same
    retained rows."""
    rng = np.random.default_rng(seed)
    d = _random_data(rng, n, k, 1.0)
    cut = int(rng.integers(max(1, n - 8), n))
    head, tail = d.subset(np.arange(cut)), d.subset(np.arange(cut, n))
    store = RuntimeDataStore(head, reject_ratio=1e30, reject_slack=1e30)
    store.compact(**_compact_kw())
    assert store.fingerprint == hashlib.sha256(
        store.data.to_tsv().encode()).hexdigest()
    assert store.fingerprint == RuntimeDataStore(store.data).fingerprint
    if len(tail):                  # append AFTER the epoch transition
        assert store.contribute(tail).accepted
        assert store.fingerprint == hashlib.sha256(
            store.data.to_tsv().encode()).hexdigest()


@settings(max_examples=25, deadline=None)
@given(n=st.integers(10, 120), k=st.integers(0, 3),
       seed=st.integers(0, 10**6), floor=st.integers(1, 3),
       cap=st.integers(1, 3))
def test_compaction_support_floor_property(n, k, seed, floor, cap):
    """Support floors are never violated: a (machine x context-cluster)
    group below the floor rejects the WHOLE compaction; otherwise every
    group retains at least ``floor`` rows (top-up past the per-cell cap
    when needed), and the store's retained rows are exactly the
    selection's."""
    d = _random_data(np.random.default_rng(seed), n, k, 1.0)
    store = RuntimeDataStore(d, reject_ratio=1e30, reject_slack=1e30)
    kw = _compact_kw(max_rows_per_cell=cap, support_floor=floor)
    cell, grp = store._compaction_grid(kw["cell_rel_width"])
    before = np.bincount(grp)
    report = store.compact(**kw)
    if (before < floor).any():
        assert not report.accepted
        assert len(store) == n               # untouched
        return
    keep = store._select_retained(cell, grp, cap, floor) \
        if not report.accepted else None
    if report.accepted:
        # recompute the deterministic selection on the ORIGINAL rows and
        # check the store retained exactly those, floor included
        fresh = RuntimeDataStore(d)
        keep = fresh._select_retained(cell, grp, cap, floor)
        assert store.data.to_tsv() == \
            d.subset(np.flatnonzero(keep)).to_tsv()
    counts = np.bincount(grp[keep], minlength=len(before))
    assert (counts >= np.minimum(before, floor)).all()


# ---------------------------------------------------------------------------
# trust plane (repro.core.trust)
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10**6), rate=st.sampled_from([0.5, 2.0, 7.5]),
       burst=st.sampled_from([1.0, 3.0, 10.0]), n=st.integers(1, 120))
def test_token_bucket_admission_bound_property(seed, rate, burst, n):
    """Under ANY timestamp sequence — forward jumps, repeats, rewinds —
    total admissions never exceed burst + rate * (max_t - min_t): a
    skewed caller clock cannot mint quota."""
    from repro.core.trust import TokenBucket

    rng = np.random.default_rng(seed)
    times = rng.uniform(0.0, 60.0, size=n)
    if rng.integers(0, 2):                 # half the runs: adversarially
        times = times[np.argsort(times)][::-1]    # rewinding clock
    bucket = TokenBucket(rate, burst)
    admitted = sum(bucket.admit(t) for t in times)
    elapsed = float(times.max() - times.min()) if n > 1 else 0.0
    assert admitted <= burst + rate * elapsed + 1e-9
    assert bucket.remaining() >= 0.0


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10**6), n=st.integers(1, 40))
def test_reputation_order_independence_property(seed, n):
    """A commutative batch of outcomes yields the same reputation (and
    derived weights) in any replay order: the ledger is pure sums, so
    collaborative history has no order-dependent judgement."""
    import math

    from repro.core.trust import ReputationLedger

    rng = np.random.default_rng(seed)
    outcomes = [(f"u{int(rng.integers(0, 4))}", bool(rng.integers(0, 2)),
                 float(rng.uniform(0.0, 1.0))) for _ in range(n)]
    ledgers = []
    for order in (outcomes, outcomes[::-1],
                  [outcomes[i] for i in rng.permutation(n)]):
        led = ReputationLedger()
        for cid, accepted, quality in order:
            led.record_outcome(cid, accepted, quality)
        ledgers.append(led)
    a = ledgers[0]
    for b in ledgers[1:]:
        assert b.contributors() == a.contributors()
        assert b.version == a.version
        for c in a.contributors():
            # float sums commute only up to associativity: isclose, not ==
            assert math.isclose(b.reputation(c), a.reputation(c),
                                rel_tol=1e-12, abs_tol=1e-12)
            assert math.isclose(b.row_weight(c), a.row_weight(c),
                                rel_tol=1e-9, abs_tol=1e-9)
            assert b.stats(c).accepted == a.stats(c).accepted
            assert b.stats(c).rejected == a.stats(c).rejected
