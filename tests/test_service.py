"""Configuration-service contracts: joint choose_cluster_batch parity with
the composed two-phase path, one-dispatch batching, fit-cache persistence
(warm start + invalidation), and the async micro-batched front-end."""
import asyncio

import numpy as np
import pytest

from repro.core import engine
from repro.core.configurator import Configurator, choose_machine_type
from repro.core.datastore import RuntimeDataStore
from repro.core.hub import JobRepo
from repro.core.predictor import C3OPredictor
from repro.core.service import ConfigurationService
from repro.serve.config_service import AsyncConfigService
from repro.workloads import spark_emul as W

SCALEOUTS = [2, 3, 4, 6, 8, 12, 16]


class _FakePredictor:
    """Deterministic predictor t(s) = a/s + b*s + c with known error stats.

    Cost ~ t*s = a + b*s^2 + c*s increases with s, so the cheapest
    deadline-satisfying scale-out is also the smallest satisfying one —
    the regime where the joint optimum is attainable by the two-phase path.
    """

    def __init__(self, a=1000.0, b=5.0, c=50.0, mu=0.0, sigma=10.0):
        self.a, self.b, self.c = a, b, c
        self.mu, self.sigma = mu, sigma
        self.calls = 0

    def predict(self, X):
        self.calls += 1
        s = np.asarray(X)[:, 0]
        return self.a / s + self.b * s + self.c

    def predict_with_error(self, X):
        return self.predict(X), self.mu, self.sigma


def _dominated_setup():
    """Machine A dominates: lowest runtime curve AND lowest price, and is
    first in dict order (ties in any fallback resolve identically)."""
    preds = {"A": _FakePredictor(a=1000.0),
             "B": _FakePredictor(a=1000.0),
             "C": _FakePredictor(a=1200.0)}
    prices = {"A": 0.10, "B": 0.20, "C": 0.30}
    return preds, prices


def _assert_same_choice(a, b):
    assert a.machine_type == b.machine_type
    assert a.scale_out == b.scale_out
    assert a.bottleneck == b.bottleneck
    np.testing.assert_allclose(a.predicted_runtime_s, b.predicted_runtime_s)
    np.testing.assert_allclose(a.runtime_bound_s, b.runtime_bound_s)
    np.testing.assert_allclose(a.cost_usd, b.cost_usd)


# --------------------------------------------------------------------------
# joint selection: parity with the composed two-phase path
# --------------------------------------------------------------------------

@pytest.mark.parametrize("bottleneck", [False, True])
def test_joint_matches_two_phase_on_attainable_grid(bottleneck):
    preds, prices = _dominated_setup()
    svc_bott = (lambda m, ctx, s: s <= 4) if bottleneck else None
    conf_bott = (lambda ctx, s: s <= 4) if bottleneck else None
    svc = ConfigurationService(preds, prices, SCALEOUTS, confidence=0.9,
                               bottleneck_fn=svc_bott)
    rng = np.random.default_rng(7)
    contexts = rng.uniform(10, 20, (24, 1))
    t_maxes = rng.uniform(250, 800, 24)        # attainable range for A
    for tm in (None, t_maxes):
        joint = svc.choose_cluster_batch(contexts, t_max=tm)
        assert len(joint) == len(contexts)
        for i, (ctx, ch) in enumerate(zip(contexts, joint)):
            m = choose_machine_type(preds, prices, SCALEOUTS, ctx)
            conf = Configurator(preds[m], m, prices, SCALEOUTS,
                                confidence=0.9, bottleneck_fn=conf_bott)
            two_phase = conf.choose_scaleout(
                ctx, t_max=None if tm is None else float(t_maxes[i]))
            _assert_same_choice(ch, two_phase)


def test_joint_parity_on_real_predictors():
    prices = {m.name: m.price for m in W.MACHINES.values()}
    machines = sorted(W.MACHINES)
    preds = {}
    for m in machines:
        d = W.generate_job_data("grep").filter_machine(m)
        preds[m] = C3OPredictor(max_cv_folds=15).fit(d.X, d.y)
    svc = ConfigurationService(preds, prices, SCALEOUTS)
    rng = np.random.default_rng(3)
    contexts = np.stack([rng.uniform(10, 20, 8),
                         rng.choice([.002, .02, .08], 8)], axis=1)
    # no-deadline: joint cheapest == two-phase cheapest machine + cheapest s
    for ctx, ch in zip(contexts, svc.choose_cluster_batch(contexts)):
        m = choose_machine_type(preds, prices, SCALEOUTS, ctx)
        conf = Configurator(preds[m], m, prices, SCALEOUTS)
        _assert_same_choice(ch, conf.choose_scaleout(ctx))


def test_joint_is_one_dispatch_per_machine():
    """A whole context batch costs ONE predict call per machine — no
    per-context or per-scale-out Python-loop dispatches."""
    preds, prices = _dominated_setup()
    svc = ConfigurationService(preds, prices, SCALEOUTS)
    contexts = np.random.default_rng(0).uniform(10, 20, (64, 1))
    svc.choose_cluster_batch(contexts, t_max=400.0)
    assert all(p.calls == 1 for p in preds.values())


def test_mixed_nan_deadlines_resolve_per_context():
    preds, prices = _dominated_setup()
    svc = ConfigurationService(preds, prices, SCALEOUTS)
    contexts = np.asarray([[12.0], [15.0], [18.0]])
    tm = np.asarray([400.0, np.nan, 300.0])
    mixed = svc.choose_cluster_batch(contexts, t_max=tm)
    _assert_same_choice(
        mixed[1], svc.choose_cluster_batch(contexts[1:2], t_max=None)[0])
    _assert_same_choice(
        mixed[0], svc.choose_cluster_batch(contexts[:1], t_max=400.0)[0])
    _assert_same_choice(
        mixed[2], svc.choose_cluster_batch(contexts[2:], t_max=300.0)[0])


def test_service_rejects_degenerate_confidence():
    preds, prices = _dominated_setup()
    for c in (0.0, 1.0):
        with pytest.raises(ValueError, match="confidence"):
            ConfigurationService(preds, prices, SCALEOUTS, confidence=c)


# --------------------------------------------------------------------------
# fit-cache persistence: warm start + invalidation
# --------------------------------------------------------------------------

def _fresh_repo(data, seed=0):
    store = RuntimeDataStore(data, seed=seed)
    return JobRepo("grep", "grep", data.schema, store), store


def test_warm_start_roundtrip_serves_without_refit(tmp_path):
    data = W.generate_job_data("grep")
    repo, store = _fresh_repo(data)
    p1 = repo.predictor_for("m5.xlarge")
    store_path = str(tmp_path / "grep.tsv")
    store.save(store_path)
    assert repo.save_fits(JobRepo.fits_path(store_path)) == 1

    # fresh-process emulation: reload store + fits, drop every executable
    store2 = RuntimeDataStore.load(store_path, data.schema)
    repo2 = JobRepo("grep", "grep", data.schema, store2)
    assert repo2.load_fits(JobRepo.fits_path(store_path)) == 1
    engine.cache_clear()
    p2 = repo2.predictor_for("m5.xlarge")
    rng = np.random.default_rng(5)
    q = np.stack([rng.choice(SCALEOUTS, 16).astype(float),
                  rng.uniform(10, 20, 16),
                  rng.choice([.002, .02, .08], 16)], axis=1)
    out = p2.predict(q)
    stats = engine.cache_stats()
    assert stats["fit"] == 0 and stats["cv"] == 0       # zero refits
    assert stats["predict"] >= 1                        # ...but it served
    assert p2.selected == p1.selected
    np.testing.assert_allclose(p2.mu, p1.mu)
    np.testing.assert_allclose(p2.sigma, p1.sigma)
    np.testing.assert_allclose(out, p1.predict(q), rtol=2e-5, atol=1e-3)


def test_accepted_contribution_invalidates_persisted_fits(tmp_path):
    data = W.generate_job_data("grep")
    repo, store = _fresh_repo(data)
    repo.predictor_for("m5.xlarge")
    store_path = str(tmp_path / "grep.tsv")
    store.save(store_path)
    fits = JobRepo.fits_path(store_path)
    repo.save_fits(fits)

    repo2, store2 = _fresh_repo(
        RuntimeDataStore.load(store_path, data.schema).data)
    assert repo2.load_fits(fits) == 1
    p_warm = repo2.predictor_for("m5.xlarge")

    d = data.filter_machine("m5.xlarge")
    good = d.subset(np.arange(3))
    good.y = good.y * 1.01
    report = repo2.contribute(good)
    assert report.accepted and store2.version == 1
    # in-process: version bump forces a refit (warm entry is stale)
    assert repo2.predictor_for("m5.xlarge") is not p_warm
    # cross-process: the fingerprint changed, so the old sidecar is refused
    repo3, _ = _fresh_repo(store2.data)
    assert repo3.load_fits(fits) == 0


def test_save_fits_skips_stale_version_entries(tmp_path):
    """Regression: after an accepted contribute, the cache can still hold a
    fit of the PRE-contribution data (eviction is lazy).  save_fits must not
    stamp that stale fit with the new store fingerprint."""
    data = W.generate_job_data("grep")
    repo, store = _fresh_repo(data)
    repo.predictor_for("m5.xlarge")           # fitted at version 0
    d = data.filter_machine("m5.xlarge")
    good = d.subset(np.arange(3))
    good.y = good.y * 1.01
    assert repo.contribute(good).accepted     # version 1; cache entry stale
    fits = JobRepo.fits_path(str(tmp_path / "grep.tsv"))
    assert repo.save_fits(fits) == 0          # nothing current to save
    repo.predictor_for("m5.xlarge")           # refit on the real data
    assert repo.save_fits(fits) == 1


# --------------------------------------------------------------------------
# async micro-batched front-end
# --------------------------------------------------------------------------

def test_async_frontend_matches_sync_and_coalesces():
    preds, prices = _dominated_setup()
    svc = ConfigurationService(preds, prices, SCALEOUTS)
    rng = np.random.default_rng(11)
    contexts = rng.uniform(10, 20, (32, 1))
    t_maxes = [None if i % 3 == 0 else float(rng.uniform(250, 800))
               for i in range(32)]

    async def drive():
        async with AsyncConfigService(svc, max_batch=64) as front:
            got = await asyncio.gather(*[
                front.choose(contexts[i], t_max=t_maxes[i])
                for i in range(32)])
            return got, front.stats

    got, stats = asyncio.run(drive())
    tm = np.asarray([np.nan if t is None else t for t in t_maxes])
    want = svc.choose_cluster_batch(contexts, t_max=tm)
    for a, b in zip(got, want):
        _assert_same_choice(a, b)
    assert stats.requests == 32
    assert stats.batches < 32          # concurrent arrivals shared dispatches
    assert stats.mean_batch > 1.0


def test_async_frontend_rejects_mismatched_width_without_poisoning_batch():
    """Regression: a request whose context width differed from the batch
    head's poisoned the WHOLE micro-batch — the [C, k] pack raised and the
    exception fanned out to every concurrent caller (and killed the
    worker).  With a pinned width the bad request is rejected alone at
    choose() enqueue time; concurrent good requests are answered."""
    preds, prices = _dominated_setup()
    svc = ConfigurationService(preds, prices, SCALEOUTS)
    contexts = np.random.default_rng(0).uniform(10, 20, (8, 1))

    async def drive():
        async with AsyncConfigService(svc, max_batch=64, width=1) as front:
            results = await asyncio.gather(
                *([front.choose(contexts[i]) for i in range(4)]
                  + [front.choose(np.asarray([15.0, 2.0]))]  # stray width
                  + [front.choose(contexts[i]) for i in range(4, 8)]),
                return_exceptions=True)
            # the lane survives: a fresh request still gets served
            late = await front.choose(contexts[0], t_max=400.0)
            return results, late

    results, late = asyncio.run(drive())
    bad = [r for r in results if isinstance(r, Exception)]
    assert len(bad) == 1 and isinstance(bad[0], ValueError)
    assert "width" in str(bad[0])
    good = [r for r in results if not isinstance(r, Exception)]
    assert len(good) == 8
    want = svc.choose_cluster_batch(contexts)
    for a, b in zip(good, want):
        _assert_same_choice(a, b)
    assert late.machine_type == "A"


def test_async_frontend_unpinned_widths_dispatch_per_group():
    """Without a pinned width there is no authoritative row shape, so a
    mixed-width tick is packed per width group: every request reaches the
    service with a consistently shaped batch, a malformed FIRST request
    cannot wedge the lane against later well-formed traffic, and
    same-width requests still share one dispatch."""
    preds, prices = _dominated_setup()
    svc = ConfigurationService(preds, prices, SCALEOUTS)
    contexts = np.random.default_rng(1).uniform(10, 20, (6, 1))

    async def drive():
        async with AsyncConfigService(svc, max_batch=64) as front:
            # malformed FIRST request (width 2) concurrent with good ones
            results = await asyncio.gather(
                *([front.choose(np.asarray([15.0, 2.0]))]
                  + [front.choose(contexts[i]) for i in range(6)]),
                return_exceptions=True)
            return results, front.stats

    results, stats = asyncio.run(drive())
    good = [r for r in results[1:]]
    assert not any(isinstance(r, Exception) for r in good)
    want = svc.choose_cluster_batch(contexts)
    for a, b in zip(good, want):
        _assert_same_choice(a, b)
    # the width-1 group coalesced into ONE dispatch despite the stray
    # width-2 arrival (the fakes accept any width, so it also answered)
    assert stats.batches <= 3 and stats.requests == 7


def test_serve_stats_mean_batch_is_bounded_and_exact():
    """Regression: ServeStats kept every batch size in an ever-growing
    list; a long-lived lane leaked one entry per tick.  The running
    sum/count form must keep mean_batch exact."""
    from repro.serve.config_service import ServeStats
    s = ServeStats()
    assert s.mean_batch == 0.0
    sizes = [1, 7, 3, 128, 1]
    for n in sizes:
        s.record_batch(n)
    assert s.requests == sum(sizes)
    assert s.batches == len(sizes)
    np.testing.assert_allclose(s.mean_batch, np.mean(sizes))
    assert not hasattr(s, "batch_sizes")      # the unbounded list is gone


def test_latency_reservoir_bounded_memory_and_percentiles():
    """Regression: the latency reservoir must stay fixed-size no matter
    how many observations land in it — 100k records through a 4096-slot
    ring keep exactly capacity values — while percentiles track the
    sliding window (nearest-rank), not the whole history."""
    from repro.serve.config_service import LatencyReservoir
    r = LatencyReservoir(capacity=4096)
    assert len(r) == 0 and np.isnan(r.percentile(50))
    buf_id = id(r._buf)
    for i in range(100_000):
        r.record(float(i))
    assert r.total == 100_000
    assert len(r) == 4096                      # bounded, not 100k
    assert id(r._buf) == buf_id                # no reallocation ever
    assert r._buf.nbytes == 4096 * 8
    # the window holds the LAST 4096 observations: 95904..99999
    assert r.percentile(0) == 95904.0
    assert r.percentile(100) == 99999.0
    assert r.percentile(50) == 95904.0 + 2047  # nearest-rank median

    # single observation: every percentile is that observation
    r1 = LatencyReservoir(capacity=8)
    r1.record(0.25)
    assert r1.percentile(50) == r1.percentile(99) == 0.25

    with pytest.raises(ValueError):
        LatencyReservoir(capacity=0)


def test_serve_stats_percentiles_ride_the_reservoir():
    from repro.serve.config_service import ServeStats
    s = ServeStats()
    assert np.isnan(s.p50) and np.isnan(s.p99)
    for ms in (1.0, 2.0, 3.0, 4.0, 100.0):
        s.record_latency(ms / 1e3)
    np.testing.assert_allclose(s.p50, 3e-3)
    np.testing.assert_allclose(s.p99, 0.1)
    assert s.latency.total == 5


def test_async_frontend_stop_cancels_pending_requests():
    """stop() must not strand an in-flight choose(): anything still queued
    is cancelled, not left hanging forever."""
    preds, prices = _dominated_setup()
    svc = ConfigurationService(preds, prices, SCALEOUTS)

    async def drive():
        front = AsyncConfigService(svc)     # worker never started
        req = asyncio.ensure_future(front.choose(np.asarray([15.0])))
        await asyncio.sleep(0)              # let the request enqueue
        await front.stop()
        with pytest.raises(asyncio.CancelledError):
            await req

    asyncio.run(drive())
