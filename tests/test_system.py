"""End-to-end behaviour tests: the paper's full workflow (Fig. 4) and the
C3O-for-TPU integration."""
import numpy as np
import pytest

from repro.core import Hub, JobRepo, RuntimeDataStore
from repro.workloads import spark_emul as W


def test_paper_workflow_end_to_end():
    """(1) find job on hub -> (2) download data -> (3,4) inputs ->
    (5) configure cluster -> (6) contribute new runtime data."""
    hub = Hub()
    for job in ("sort", "grep"):
        data = W.generate_job_data(job)
        hub.publish(JobRepo(job, f"spark {job}", data.schema,
                            RuntimeDataStore(data)))
    repo = hub.search("grep")[0]
    prices = {m.name: m.price for m in W.MACHINES.values()}
    conf = repo.configurator("m5.xlarge", prices, [2, 3, 4, 6, 8, 12])

    ctx = np.asarray([18.0, 0.02])         # 18 GB, 2% keyword hits
    choice = conf.choose_scaleout(ctx, t_max=420.0)
    assert choice.runtime_bound_s <= 420.0
    truth = W.true_runtime("grep", "m5.xlarge", choice.scale_out,
                           (18.0, 0.02))
    assert truth <= 420.0 * 1.05           # deadline actually met

    # (6) the user's run flows back into the shared store
    from repro.core.features import RuntimeData
    new = RuntimeData(repo.schema, np.asarray(["m5.xlarge"]),
                      np.asarray([[choice.scale_out, 18.0, 0.02]]),
                      np.asarray([truth]))
    rep = repo.contribute(new)
    assert rep.accepted


@pytest.mark.slow
def test_autoconfig_tpu_integration():
    from repro.launch.autoconfig import autoconfigure
    choice, pred = autoconfigure("gemma3-1b", "train_4k",
                                 step_budget_s=None,
                                 chip_counts=(64, 128, 256))
    assert choice.scale_out in (64, 128, 256)
    assert pred.selected is not None
    # a tight step budget forces a bigger slice than a loose one allows
    fast, _ = autoconfigure("gemma3-1b", "train_4k", step_budget_s=0.05,
                            chip_counts=(64, 128, 256))
    slow, _ = autoconfigure("gemma3-1b", "train_4k", step_budget_s=10.0,
                            chip_counts=(64, 128, 256))
    assert fast.scale_out >= slow.scale_out


@pytest.mark.slow
def test_autoconfig_memory_bottleneck():
    """kimi-k2 (1T params) cannot fit 64 v5e chips: bottleneck exclusion."""
    from repro.launch.autoconfig import autoconfigure
    choice, _ = autoconfigure("kimi-k2-1t-a32b", "train_4k",
                              chip_counts=(64, 128, 256, 512))
    assert choice.scale_out >= 256
