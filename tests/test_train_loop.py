"""Fault tolerance: checkpoint/restart determinism, torn-write recovery,
elastic re-sharding, straggler watchdog wiring."""
import json
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.train import run as train_run
from repro.train.checkpoint import (CheckpointManager, restore_checkpoint,
                                    save_checkpoint)

ARCH = "gemma3-1b"


@pytest.mark.slow          # ~1 min end-to-end: two training runs + restart
def test_crash_restart_is_deterministic(tmp_path):
    d1 = str(tmp_path / "a")
    d2 = str(tmp_path / "b")
    # uninterrupted run
    losses_ref = train_run(ARCH, steps=8, batch=2, seq=32, ckpt_dir=d1,
                           ckpt_every=2)
    # crash at step 4, then resume
    with pytest.raises(SystemExit):
        train_run(ARCH, steps=8, batch=2, seq=32, ckpt_dir=d2, ckpt_every=2,
                  crash_at_step=4)
    losses_resumed = train_run(ARCH, steps=8, batch=2, seq=32, ckpt_dir=d2,
                               ckpt_every=2)
    # deterministic data + state restore => identical tail of the loss curve
    np.testing.assert_allclose(losses_resumed[-1], losses_ref[-1], rtol=1e-4)


def test_checkpoint_keep_and_torn_write(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"w": jnp.arange(8.0), "b": jnp.zeros(3)}
    for s in (2, 4, 6):
        mgr.save(s, tree)
    assert mgr.latest_step() == 6
    assert len(mgr._steps()) == 2                      # keep=2 enforced
    # torn write: directory without manifest is ignored
    os.makedirs(str(tmp_path / "step_00000099"))
    assert mgr.latest_step() == 6
    restored, step = mgr.maybe_restore(tree)
    assert step == 6
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))


def test_restore_casts_dtype(tmp_path):
    tree = {"w": jnp.arange(8.0, dtype=jnp.float32)}
    path = save_checkpoint(str(tmp_path), 1, tree)
    like = {"w": jnp.zeros(8, jnp.bfloat16)}
    out, step = restore_checkpoint(path, like)
    assert out["w"].dtype == jnp.bfloat16


@pytest.mark.slow
def test_elastic_reshard_across_device_counts(tmp_path):
    """Save sharded on an 8-device mesh, restore on a 4-device mesh (and the
    reverse) in subprocesses — elastic scaling after failures."""
    script = r"""
import os, sys
os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={sys.argv[1]}"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
sys.path.insert(0, "%SRC%")
from repro.train.checkpoint import save_checkpoint, restore_checkpoint
from repro.launch.mesh import make_mesh_auto
mesh = make_mesh_auto((len(jax.devices()),), ("data",))
sh = NamedSharding(mesh, P("data"))
x = jax.device_put(jnp.arange(32.0), sh)
mode, path = sys.argv[2], sys.argv[3]
if mode == "save":
    save_checkpoint(path, 7, {"x": x})
else:
    like = {"x": jnp.zeros(32)}
    out, step = restore_checkpoint(path + "/step_00000007", like,
                                   shardings={"x": sh})
    assert step == 7
    np.testing.assert_array_equal(np.asarray(out["x"]), np.arange(32.0))
    assert len(out["x"].sharding.device_set) == len(jax.devices())
print("OK", mode, len(jax.devices()))
"""
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    script = script.replace("%SRC%", os.path.abspath(src))
    sp = str(tmp_path / "el.py")
    with open(sp, "w") as f:
        f.write(script)
    ck = str(tmp_path / "ck")
    r1 = subprocess.run([sys.executable, sp, "8", "save", ck],
                        capture_output=True, text=True, timeout=300)
    assert r1.returncode == 0, r1.stderr[-2000:]
    r2 = subprocess.run([sys.executable, sp, "4", "load", ck],
                        capture_output=True, text=True, timeout=300)
    assert r2.returncode == 0, r2.stderr[-2000:]
    assert "OK load 4" in r2.stdout


def test_runtime_capture_for_autoconfig(tmp_path):
    log = str(tmp_path / "rt.jsonl")
    train_run(ARCH, steps=4, batch=2, seq=32, ckpt_dir=str(tmp_path / "c"),
              runtime_log=log)
    with open(log) as f:
        rec = json.loads(f.readline())
    assert rec["arch"] == ARCH and rec["median_step_s"] > 0
